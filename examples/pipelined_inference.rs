//! E15 — end-to-end functional validation (DESIGN.md §5): run a real conv
//! segment through PJRT in all three execution modes, check numerics, and
//! measure request latency/throughput over a batch of requests.
//!
//! This is the driver proving all three layers compose: L1 Pallas kernels
//! (AOT-lowered, interpret=True) → L2 JAX segment programs → L3 Rust
//! coordinator streaming pipeline intervals between stage threads.
//!
//! Run: `make artifacts && cargo run --release --example pipelined_inference`

use std::time::Instant;

use pipeorgan::coordinator as coord;
use pipeorgan::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    anyhow::ensure!(
        std::path::Path::new(&artifacts).join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );
    let rt = Runtime::new(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let spec = rt.manifest()?.segment;
    println!(
        "segment: {}x{}x{} -> {} -> {} (band {}, {} intervals)",
        spec.h, spec.w, spec.c_in, spec.c_mid, spec.c_out, spec.band, spec.h / spec.band,
    );

    // ---- correctness: three modes must agree ------------------------------
    let data = coord::SegmentData::random(spec, 42);
    let op = coord::run_op_by_op(&artifacts, &data)?;
    let fused = coord::run_fused(&artifacts, &data)?;
    let piped = coord::run_pipelined(&artifacts, &data)?;
    let d_fused = coord::compare_outputs(&op, &fused)?;
    let d_piped = coord::compare_outputs(&op, &piped)?;
    println!("max |op-fused| = {d_fused:.3e}, max |op-pipelined| = {d_piped:.3e}");
    anyhow::ensure!(d_fused < 1e-3 && d_piped < 1e-3, "modes diverge");
    println!("numerics OK\n");

    // ---- throughput over a request batch (sessions: compile once) ---------
    const REQUESTS: usize = 32;
    let op_sess = coord::OpByOpSession::new(&artifacts)?;
    let fused_sess = coord::FusedSession::new(&artifacts)?;
    let piped_sess = coord::PipelinedSession::new(&artifacts, spec)?;
    let mut table = pipeorgan::util::table::Table::new(
        "pipelined inference — batched requests (resident sessions)",
        &["mode", "requests", "total ms", "ms/request", "requests/s"],
    );
    let run_batch = |mode: &str| -> anyhow::Result<(f64, Vec<f32>)> {
        // warmup
        let _ = match mode {
            "op_by_op" => op_sess.run(&data)?,
            "fused" => fused_sess.run(&data)?,
            _ => piped_sess.run(&data)?,
        };
        let t0 = Instant::now();
        let mut last = Vec::new();
        for seed in 0..REQUESTS as u64 {
            let d = coord::SegmentData::random(spec, 1000 + seed);
            let r = match mode {
                "op_by_op" => op_sess.run(&d)?,
                "fused" => fused_sess.run(&d)?,
                _ => piped_sess.run(&d)?,
            };
            last = r.output;
        }
        Ok((t0.elapsed().as_secs_f64(), last))
    };
    let mut outputs = Vec::new();
    for mode in ["op_by_op", "fused", "pipelined"] {
        let (total, last) = run_batch(mode)?;
        outputs.push(last);
        table.row(&[
            mode.into(),
            REQUESTS.to_string(),
            format!("{:.1}", total * 1e3),
            format!("{:.2}", total * 1e3 / REQUESTS as f64),
            format!("{:.1}", REQUESTS as f64 / total),
        ]);
    }
    // the three modes saw the same final request -> outputs must agree
    for o in &outputs[1..] {
        anyhow::ensure!(
            o.iter()
                .zip(&outputs[0])
                .all(|(a, b)| (a - b).abs() < 1e-3),
            "session outputs diverge"
        );
    }
    print!("{}", table.to_markdown());
    println!("\n(sessions keep PJRT clients + compiled programs resident — the\n fused mode also shows the HBM-traffic saving modelled in Fig. 14)");
    Ok(())
}
