//! Quickstart: the smallest useful tour of the PipeOrgan API.
//!
//! 1. Build a model, run stage 1 (depth + granularity) and stage 2
//!    (spatial organization) via the PipeOrgan mapper, and evaluate it
//!    against the TANGRAM-like baseline.
//! 2. If AOT artifacts exist, load the tiled-GEMM program through PJRT and
//!    run it — proving the Rust↔XLA path works on this machine.
//!
//! Run: `cargo run --release --example quickstart`

use pipeorgan::config::ArchConfig;
use pipeorgan::cost::{evaluate, Mapper};

fn main() -> anyhow::Result<()> {
    // ---- 1. map + evaluate a model -----------------------------------------
    let cfg = ArchConfig::default(); // Table III: 32x32 PEs, 1 MB SRAM, ...
    let model = pipeorgan::workloads::keyword_detection();
    println!("model: {} ({} layers)", model.name, model.num_layers());

    let mapper = pipeorgan::mapper::PipeOrgan::default(); // stage 1 + 2, AMP
    let plan = mapper.plan(&model, &cfg);
    println!(
        "plan: {} segments, mean depth {:.2}, topology {}",
        plan.segments.len(),
        plan.mean_depth(),
        plan.topology.name()
    );
    for (i, seg) in plan.segments.iter().take(4).enumerate() {
        println!(
            "  segment {i}: layers {}..{} depth {} org {}",
            seg.segment.start,
            seg.segment.end(),
            seg.depth(),
            seg.organization.name()
        );
    }

    let cost = evaluate(&model, &plan, &cfg);
    let baseline = pipeorgan::baselines::TangramLike;
    let base_cost = evaluate(&model, &baseline.plan(&model, &cfg), &cfg);
    println!(
        "PipeOrgan: {:.3e} cycles, {:.3e} DRAM words",
        cost.cycles, cost.dram_words as f64
    );
    println!(
        "TANGRAM-like: {:.3e} cycles ({:.2}x), {:.3e} DRAM words ({:.2}x)",
        base_cost.cycles,
        base_cost.cycles / cost.cycles,
        base_cost.dram_words as f64,
        base_cost.dram_words as f64 / cost.dram_words as f64
    );

    // ---- 2. run an AOT artifact through PJRT --------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = pipeorgan::runtime::Runtime::new("artifacts")?;
        println!("\nPJRT platform: {}", rt.platform());
        let gemm = rt.load_program("gemm")?;
        let a: Vec<f32> = (0..64 * 64).map(|i| (i % 7) as f32).collect();
        let b: Vec<f32> = (0..64 * 64).map(|i| ((i + 3) % 5) as f32).collect();
        let out = gemm.run_f32(&[&a, &b])?;
        // spot-check one element against a host-side dot product
        let want: f32 = (0..64).map(|k| a[2 * 64 + k] * b[k * 64 + 5]).sum();
        anyhow::ensure!((out[2 * 64 + 5] - want).abs() < 1e-3);
        println!("gemm artifact OK: out[2,5] = {} (host {})", out[2 * 64 + 5], want);
    } else {
        println!("\n(artifacts/ not built — run `make artifacts` for the PJRT demo)");
    }
    Ok(())
}
