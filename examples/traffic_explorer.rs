//! Traffic explorer: every Fig. 8–12 scenario on every topology (mesh,
//! AMP, torus, flattened butterfly), with the analytical channel-load
//! model cross-checked against the cycle-level queueing simulator.
//!
//! Run: `cargo run --release --example traffic_explorer [rows cols]`

use pipeorgan::config::TopologyKind;
use pipeorgan::energy::EnergyModel;
use pipeorgan::noc::Topology;
use pipeorgan::sim::{analyze, simulate_interval};
use pipeorgan::traffic::{derive_flows, scenarios, Flow};
use pipeorgan::util::table::{fnum, Table};

fn main() {
    let mut args = std::env::args().skip(1);
    let rows: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(16);
    let cols: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(16);
    let em = EnergyModel::default();
    let mut table = Table::new(
        &format!("traffic explorer — {rows}x{cols} array"),
        &["scenario", "topology", "worst load", "word-hops", "NoC energy", "sim makespan", "sim/analytic"],
    );
    for scen in scenarios::all(rows, cols) {
        for kind in [
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::Torus,
            TopologyKind::FlattenedButterfly,
        ] {
            let topo = Topology::new(kind, rows, cols);
            let flows: Vec<Flow> = derive_flows(&topo, &scen.placement, &scen.handoffs)
                .into_iter()
                .map(|f| Flow { words_per_interval: f.words_per_interval.ceil(), ..f })
                .collect();
            let a = analyze(&topo, &flows);
            let sim = simulate_interval(&topo, &flows, 1);
            let ratio = if a.worst_channel_load > 0.0 {
                sim.makespan as f64 / a.worst_channel_load
            } else {
                1.0
            };
            table.row(&[
                scen.name.to_string(),
                kind.name().to_string(),
                fnum(a.worst_channel_load),
                fnum(a.total_word_hops),
                fnum(em.noc_interval_energy(&a)),
                sim.makespan.to_string(),
                fnum(ratio),
            ]);
        }
    }
    print!("{}", table.to_markdown());
}
