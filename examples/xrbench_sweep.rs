//! Full XR-bench-like evaluation sweep — regenerates the paper's headline
//! results (Fig. 13 performance, Fig. 14 DRAM accesses) plus the stage-1
//! outputs (Fig. 16 depths, Fig. 17 granularities), in parallel across
//! worker threads.
//!
//! Run: `cargo run --release --example xrbench_sweep [reports_dir]`

use pipeorgan::config::ArchConfig;

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "reports".into());
    let cfg = ArchConfig::default();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for r in [
        pipeorgan::report::fig13_performance(&cfg, workers),
        pipeorgan::report::fig14_dram(&cfg, workers),
        pipeorgan::report::fig16_depth(&cfg),
        pipeorgan::report::fig17_granularity(&cfg),
    ] {
        r.emit(&out)?;
        println!();
    }
    println!("reports written to {out}/");
    Ok(())
}
