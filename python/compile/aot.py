"""AOT lowering: JAX/Pallas programs → HLO **text** artifacts + manifest.

HLO text (not `.serialize()` / serialized HloModuleProto) is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which the `xla` crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation (return_tuple=True) → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *args):
    return jax.jit(fn).lower(*args)


def spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    x, w1, w2 = model.example_inputs()
    s = jax.ShapeDtypeStruct
    f32 = jnp.float32

    programs = {}

    # --- pipelined (fused) segment: intermediate band stays in VMEM -------
    programs["segment_fused"] = {
        "lowered": lower(
            model.segment_fused, s(x.shape, f32), s(w1.shape, f32), s(w2.shape, f32)
        ),
        "inputs": [spec(x.shape), spec(w1.shape), spec(w2.shape)],
        "output": spec((model.H, model.W, model.C_OUT)),
        "role": "pipelined depth-2 segment (fused, VMEM intermediate)",
    }

    # --- op-by-op per-layer programs ---------------------------------------
    programs["layer0"] = {
        "lowered": lower(model.layer0, s(x.shape, f32), s(w1.shape, f32)),
        "inputs": [spec(x.shape), spec(w1.shape)],
        "output": spec((model.H, model.W, model.C_MID)),
        "role": "op-by-op layer 1 (HBM round trip after)",
    }
    programs["layer1"] = {
        "lowered": lower(
            model.layer1, s((model.H, model.W, model.C_MID), f32), s(w2.shape, f32)
        ),
        "inputs": [spec((model.H, model.W, model.C_MID)), spec(w2.shape)],
        "output": spec((model.H, model.W, model.C_OUT)),
        "role": "op-by-op layer 2",
    }

    # --- per-interval tile programs for the Rust pipelined executor --------
    slab0 = (model.BAND + model.R - 1, model.W + model.S - 1, model.C_IN)
    slab1 = (model.BAND + model.R - 1, model.W + model.S - 1, model.C_MID)
    programs["tile_layer0"] = {
        "lowered": lower(model.conv_band_tile, s(slab0, f32), s(w1.shape, f32)),
        "inputs": [spec(slab0), spec(w1.shape)],
        "output": spec((model.BAND, model.W, model.C_MID)),
        "role": "stage-0 pipeline-interval tile",
    }
    programs["tile_layer1"] = {
        "lowered": lower(model.conv_band_tile, s(slab1, f32), s(w2.shape, f32)),
        "inputs": [spec(slab1), spec(w2.shape)],
        "output": spec((model.BAND, model.W, model.C_OUT)),
        "role": "stage-1 pipeline-interval tile",
    }

    # --- quickstart GEMM -----------------------------------------------------
    m = k = n = 64
    programs["gemm"] = {
        "lowered": lower(model.gemm_program, s((m, k), f32), s((k, n), f32)),
        "inputs": [spec((m, k)), spec((k, n))],
        "output": spec((m, n)),
        "role": "quickstart tiled GEMM (Eq. 1)",
    }

    manifest = {
        "segment": {
            "h": model.H,
            "w": model.W,
            "c_in": model.C_IN,
            "c_mid": model.C_MID,
            "c_out": model.C_OUT,
            "band": model.BAND,
            "r": model.R,
            "s": model.S,
        },
        "programs": {},
    }
    for name, info in programs.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(info["lowered"])
        with open(path, "w") as f:
            f.write(text)
        manifest["programs"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": info["inputs"],
            "output": info["output"],
            "role": info["role"],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
