"""L1 Pallas kernels (build-time only; interpret=True on CPU).

- gemm_tile:     tiled GEMM with a VMEM accumulator (Eq. 1)
- conv_tile:     row-band conv2d / depthwise conv2d (the fine-grained
                 pipelining granularity of Fig. 3)
- fused_segment: fused producer→consumer conv pair — the paper's
                 inter-operation pipelining re-expressed as a VMEM-resident
                 intermediate band (DESIGN.md §Hardware-Adaptation)
- ref:           pure-jnp oracle for all of the above
"""

from . import conv_tile, fused_segment, gemm_tile, ref

__all__ = ["conv_tile", "fused_segment", "gemm_tile", "ref"]
