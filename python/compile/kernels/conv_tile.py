"""L1 Pallas kernels: row-tiled conv2d and depthwise conv2d.

The conv kernel computes a *row band* of the output per grid step — exactly
the paper's fine-grained pipelining granularity (one H-row band of the
intermediate tensor, Fig. 3). Each step reads its band plus the (R−1)-row
halo from the padded input with a dynamic slice; on a real TPU the same
schedule is a double-buffered HBM→VMEM row stream (overlapping halo windows
cannot be expressed as disjoint BlockSpec blocks, so the slab is indexed
inside the kernel).

Weight layout RSCK; activations HWC; stride 1; SAME padding applied here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_band_kernel(x_ref, w_ref, o_ref, *, r, s, band):
    """One output row-band.

    x_ref: [H + r - 1, W + s - 1, C] (whole padded input)
    w_ref: [r, s, C, K]
    o_ref: [band, W, K]
    """
    i = pl.program_id(0)
    _, wd, _ = o_ref.shape
    slab = x_ref[pl.ds(i * band, band + r - 1), :, :]  # band + halo rows
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for dr in range(r):
        for ds in range(s):
            patch = slab[dr : dr + band, ds : ds + wd, :].astype(jnp.float32)
            wk = w_ref[dr, ds].astype(jnp.float32)  # [C, K]
            acc = acc + jax.lax.dot_general(
                patch,
                wk,
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = acc


def conv2d(x, w, *, band=8):
    """x: [H, W, C], w: [R, S, C, K] → [H, W, K] (stride 1, SAME)."""
    h, wd, _ = x.shape
    r, s, _, k = w.shape
    band = min(band, h)
    assert h % band == 0, f"band {band} must divide H={h}"
    pr, ps = r // 2, s // 2
    xp = jnp.pad(x, ((pr, pr), (ps, ps), (0, 0)))
    hp, wp, c = xp.shape
    return pl.pallas_call(
        functools.partial(_conv_band_kernel, r=r, s=s, band=band),
        grid=(h // band,),
        in_specs=[
            pl.BlockSpec((hp, wp, c), lambda i: (0, 0, 0)),
            pl.BlockSpec((r, s, c, k), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((band, wd, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, wd, k), jnp.float32),
        interpret=True,
    )(xp, w)


def _dw_band_kernel(x_ref, w_ref, o_ref, *, r, s, band):
    """Depthwise band: x [H+r-1, W+s-1, C] whole, w [r,s,C], o [band,W,C]."""
    i = pl.program_id(0)
    _, wd, _ = o_ref.shape
    slab = x_ref[pl.ds(i * band, band + r - 1), :, :]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for dr in range(r):
        for ds in range(s):
            acc = acc + slab[dr : dr + band, ds : ds + wd, :].astype(
                jnp.float32
            ) * w_ref[dr, ds].astype(jnp.float32)
    o_ref[...] = acc


def dwconv2d(x, w, *, band=8):
    """x: [H, W, C], w: [R, S, C] → [H, W, C] (stride 1, SAME)."""
    h, wd, _ = x.shape
    r, s, _ = w.shape
    band = min(band, h)
    assert h % band == 0, f"band {band} must divide H={h}"
    pr, ps = r // 2, s // 2
    xp = jnp.pad(x, ((pr, pr), (ps, ps), (0, 0)))
    hp, wp, c = xp.shape
    return pl.pallas_call(
        functools.partial(_dw_band_kernel, r=r, s=s, band=band),
        grid=(h // band,),
        in_specs=[
            pl.BlockSpec((hp, wp, c), lambda i: (0, 0, 0)),
            pl.BlockSpec((r, s, c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((band, wd, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, wd, c), jnp.float32),
        interpret=True,
    )(xp, w)


def conv_vmem_footprint_bytes(h, w, c, k, r, *, band=8, dtype_bytes=4):
    """Modelled VMEM residency of one grid step: input slab + weights +
    output band (perf-model input; see DESIGN.md §Perf)."""
    return dtype_bytes * (
        (band + r - 1) * (w + r - 1) * c + r * r * c * k + band * w * k
    )
