"""L1 Pallas kernel: FUSED producer→consumer conv pair.

This is the paper's core insight re-expressed for TPU (DESIGN.md
§Hardware-Adaptation): instead of writing layer 1's output feature map to
HBM and reading it back for layer 2 (the op-by-op global-buffer round trip
of Fig. 1), one grid step produces an intermediate row band *in VMEM* and
immediately consumes it into layer 2's output band — the intermediate
tensor never exists in HBM. The grid step is the pipeline interval; the
VMEM band is the pipelining granularity.

Halo handling: to emit `band` valid rows of layer 2, the step computes
`band + r2 - 1` intermediate rows from `band + r1 + r2 - 2` input rows.
Adjacent steps recompute the halo rows — the classic fused-layer trade of
a little redundant compute for eliminated traffic (Alwani et al., 2016),
which is also how the paper's checkerboard PEs avoid waiting on neighbors.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(x_ref, w1_ref, w2_ref, o_ref, *, r1, s1, r2, s2, band, h, w):
    """x_ref: whole padded input [H + r1 + r2 - 2, W + s1 + s2 - 2, C].

    w1_ref: [r1, s1, C, K1]; w2_ref: [r2, s2, K1, K2];
    o_ref: [band, W, K2]. `h`/`w` are the true feature-map dims, needed to
    zero the intermediate halo (layer 2's SAME padding must see zeros, not
    values convolved from layer 1's padding region).
    """
    i = pl.program_id(0)
    _, wd, _ = o_ref.shape
    mid_rows = band + r2 - 1
    in_rows = mid_rows + r1 - 1
    mid_cols = wd + s2 - 1
    slab = x_ref[pl.ds(i * band, in_rows), :, :]

    # ---- producer: layer-1 conv + ReLU, intermediate band stays in VMEM.
    k1 = w1_ref.shape[3]
    mid = jnp.zeros((mid_rows, mid_cols, k1), jnp.float32)
    for dr in range(r1):
        for ds in range(s1):
            patch = slab[dr : dr + mid_rows, ds : ds + mid_cols, :].astype(jnp.float32)
            mid = mid + jax.lax.dot_general(
                patch,
                w1_ref[dr, ds].astype(jnp.float32),
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    mid = jnp.maximum(mid, 0.0)
    # Zero the intermediate positions outside the real feature map: the
    # band's halo rows at the top/bottom edges and the side columns belong
    # to layer 2's padding, which op-by-op execution sees as zeros.
    grow = i * band - (r2 // 2) + jax.lax.broadcasted_iota(jnp.int32, (mid_rows, 1, 1), 0)
    gcol = -(s2 // 2) + jax.lax.broadcasted_iota(jnp.int32, (1, mid_cols, 1), 1)
    mask = ((grow >= 0) & (grow < h)) & ((gcol >= 0) & (gcol < w))
    mid = jnp.where(mask, mid, 0.0)

    # ---- consumer: layer-2 conv reads the VMEM-resident band directly.
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for dr in range(r2):
        for ds in range(s2):
            patch = mid[dr : dr + band, ds : ds + wd, :]
            acc = acc + jax.lax.dot_general(
                patch,
                w2_ref[dr, ds].astype(jnp.float32),
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = jnp.maximum(acc, 0.0)


def fused_conv_pair(x, w1, w2, *, band=8):
    """relu(conv(relu(conv(x, w1)), w2)) with the intermediate in VMEM.

    x: [H, W, C]; w1: [R1, S1, C, K1]; w2: [R2, S2, K1, K2] → [H, W, K2].
    Stride 1, SAME padding for both layers.
    """
    h, wd, _ = x.shape
    r1, s1, _, _ = w1.shape
    r2, s2, _, k2 = w2.shape
    band = min(band, h)
    assert h % band == 0, f"band {band} must divide H={h}"
    # Pad once for both layers.
    pr = (r1 // 2) + (r2 // 2)
    ps = (s1 // 2) + (s2 // 2)
    xp = jnp.pad(x, ((pr, pr), (ps, ps), (0, 0)))
    hp, wp, c = xp.shape
    k1 = w1.shape[3]
    return pl.pallas_call(
        functools.partial(
            _fused_kernel, r1=r1, s1=s1, r2=r2, s2=s2, band=band, h=h, w=wd
        ),
        grid=(h // band,),
        in_specs=[
            pl.BlockSpec((hp, wp, c), lambda i: (0, 0, 0)),
            pl.BlockSpec((r1, s1, c, k1), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((r2, s2, k1, k2), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((band, wd, k2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, wd, k2), jnp.float32),
        interpret=True,
    )(xp, w1, w2)


def _fused_chain_kernel(x_ref, *rest, rs, band, h, w):
    """Variable-depth fused chain (the paper's flexible pipeline depth at
    L1): weight refs `rest[:-1]`, output ref `rest[-1]`. `rs[i]` is the
    (square) filter size of layer i."""
    w_refs = rest[:-1]
    o_ref = rest[-1]
    i = pl.program_id(0)
    depth = len(w_refs)
    _, wd, _ = o_ref.shape
    # Rows/cols of intermediate needed at each level, innermost (output)
    # first: level d needs band + sum of halo of deeper levels.
    halos = [r // 2 for r in rs]
    # Level 0 = first conv's output; deeper levels need more halo.
    def rows_at(level):
        return band + 2 * sum(halos[level + 1 :])

    def cols_at(level):
        return wd + 2 * sum(halos[level + 1 :])

    in_rows = rows_at(0) + rs[0] - 1
    cur = x_ref[pl.ds(i * band, in_rows), :, :]
    for level, (w_ref, r) in enumerate(zip(w_refs, rs)):
        out_rows = rows_at(level)
        out_cols = cols_at(level)
        k = w_ref.shape[3]
        acc = jnp.zeros((out_rows, out_cols, k), jnp.float32)
        for dr in range(r):
            for ds in range(r):
                patch = cur[dr : dr + out_rows, ds : ds + out_cols, :].astype(
                    jnp.float32
                )
                acc = acc + jax.lax.dot_general(
                    patch,
                    w_ref[dr, ds].astype(jnp.float32),
                    (((2,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
        acc = jnp.maximum(acc, 0.0)
        # Zero this level's halo that falls outside the real feature map
        # (SAME padding of the *next* layer must see zeros).
        pad_r = sum(halos[level + 1 :])
        pad_c = sum(halos[level + 1 :])
        grow = i * band - pad_r + jax.lax.broadcasted_iota(
            jnp.int32, (out_rows, 1, 1), 0
        )
        gcol = -pad_c + jax.lax.broadcasted_iota(jnp.int32, (1, out_cols, 1), 1)
        mask = ((grow >= 0) & (grow < h)) & ((gcol >= 0) & (gcol < w))
        cur = jnp.where(mask, acc, 0.0)
    o_ref[...] = cur


def fused_conv_chain(x, weights, *, band=8):
    """Fuse an arbitrary-depth conv+ReLU chain with all intermediates in
    VMEM. `weights[i]`: [R_i, R_i, C_i, C_{i+1}] (square filters, stride 1,
    SAME). Returns [H, W, C_last]."""
    import functools as _ft

    h, wd, _ = x.shape
    rs = tuple(wt.shape[0] for wt in weights)
    band = min(band, h)
    assert h % band == 0, f"band {band} must divide H={h}"
    pr = sum(r // 2 for r in rs)
    xp = jnp.pad(x, ((pr, pr), (pr, pr), (0, 0)))
    hp, wp, c = xp.shape
    k_last = weights[-1].shape[3]
    in_specs = [pl.BlockSpec((hp, wp, c), lambda i: (0, 0, 0))]
    for wt in weights:
        shape = wt.shape
        in_specs.append(pl.BlockSpec(shape, lambda i, _s=shape: (0,) * len(_s)))
    return pl.pallas_call(
        _ft.partial(_fused_chain_kernel, rs=rs, band=band, h=h, w=wd),
        grid=(h // band,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((band, wd, k_last), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, wd, k_last), jnp.float32),
        interpret=True,
    )(xp, *weights)


def fused_hbm_traffic_words(h, w, c, k1, k2):
    """Modelled HBM words for the fused pair vs op-by-op: the saving is the
    intermediate tensor's round trip (written + read), h·w·k1 each way."""
    fused = h * w * c + h * w * k2  # in + out (weights negligible here)
    op_by_op = fused + 2 * h * w * k1
    return fused, op_by_op
