"""L1 Pallas kernel: tiled GEMM (Eq. 1 of the paper).

TPU mapping (DESIGN.md §Hardware-Adaptation): each grid step owns a
(bm × bn) output tile resident in VMEM and marches over the contracted
dimension in bk-sized slabs — the BlockSpec index maps express the
HBM↔VMEM schedule the paper expresses with PE tiles, and the (bm × bn)
accumulator is the "register file" the pipeline granularity is compared
against. interpret=True everywhere: this is the CPU correctness path; a
real-TPU lowering would emit a Mosaic custom-call the CPU PJRT client
cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    """One (bm, bn) output tile; grid dim 2 walks the contraction slabs."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == k_steps - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


def gemm(a, b, *, bm=32, bn=32, bk=32):
    """`[m,k] × [k,n] → [m,n]` (f32 accumulation) with a VMEM accumulator.

    Tile sizes are clamped to the problem and must divide it exactly —
    shapes are padded by the caller (model.py) when needed.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"tile sizes must divide the problem: {(m, n, k)} vs {(bm, bn, bk)}"
    )
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pl.MemorySpace.ANY(shape=(bm, bn), dtype=jnp.float32)],
        interpret=True,
    )(a, b)


def vmem_footprint_bytes(m, n, k, *, bm=32, bn=32, bk=32, dtype_bytes=4):
    """Modelled VMEM residency of one grid step (perf-model input for
    DESIGN.md §Perf — interpret=True wallclock is not a TPU proxy)."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    return dtype_bytes * (bm * bk + bk * bn + 2 * bm * bn)  # A, B, acc+out
