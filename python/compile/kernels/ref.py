"""Pure-jnp reference oracle for every Pallas kernel (build-time only).

All activations are NHWC (batch folded out — the paper's Table III runs
batch 1), weights are RSCK; convolutions are stride-1 with symmetric zero
padding ("SAME" for odd filters).
"""

import jax.numpy as jnp


def gemm_ref(a, b):
    """[m,k] x [k,n] -> [m,n] in f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def conv2d_ref(x, w):
    """x: [H,W,C], w: [R,S,C,K] -> [H,W,K], stride 1, SAME padding."""
    h, wd, _ = x.shape
    r, s, _, k = w.shape
    pr, ps = r // 2, s // 2
    xp = jnp.pad(x, ((pr, pr), (ps, ps), (0, 0)))
    out = jnp.zeros((h, wd, k), jnp.float32)
    for dr in range(r):
        for ds in range(s):
            patch = xp[dr : dr + h, ds : ds + wd, :].astype(jnp.float32)
            out = out + jnp.einsum("hwc,ck->hwk", patch, w[dr, ds].astype(jnp.float32))
    return out


def dwconv2d_ref(x, w):
    """Depthwise: x: [H,W,C], w: [R,S,C] -> [H,W,C], stride 1, SAME."""
    h, wd, _ = x.shape
    r, s, _ = w.shape
    pr, ps = r // 2, s // 2
    xp = jnp.pad(x, ((pr, pr), (ps, ps), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for dr in range(r):
        for ds in range(s):
            out = out + xp[dr : dr + h, ds : ds + wd, :].astype(jnp.float32) * w[
                dr, ds
            ].astype(jnp.float32)
    return out


def relu(x):
    return jnp.maximum(x, 0.0)


def segment_ref(x, weights, skip_from=None):
    """A pipeline segment: conv→relu chain with an optional skip add.

    weights: list of [R,S,C,K] tensors. skip_from: index of the layer whose
    *output* is added into the final layer's input (None = no skip), i.e. a
    reuse-distance-(depth-skip_from) residual.
    """
    acts = []
    cur = x
    for i, w in enumerate(weights):
        if skip_from is not None and i == len(weights) - 1:
            cur = cur + acts[skip_from]
        cur = relu(conv2d_ref(cur, w))
        acts.append(cur)
    return cur
