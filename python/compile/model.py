"""L2: the pipeline-segment compute graph in JAX, calling the L1 kernels.

A *segment* here is the functional realization of what the Rust coordinator
schedules: a chain of conv+ReLU layers (optionally with a residual skip)
that the paper pipelines across the PE array. Three build targets:

- `segment_fused`   — depth-2 fused pair (L1 `fused_segment` kernel):
                      intermediate band lives in VMEM, the pipelined path.
- `segment_layers`  — the same segment as separate per-layer programs:
                      the op-by-op baseline the coordinator compares against.
- `conv_band_tile`  — one halo'd conv *tile* program used by the Rust
                      functional pipelined executor to stream row bands
                      through PJRT stage by stage.

All are jitted pure functions of (activations, weights), lowered once by
aot.py. Python never runs at serving time.
"""

import jax
import jax.numpy as jnp

from .kernels import conv_tile, fused_segment, gemm_tile

# Canonical small segment (fits CPU interpret mode comfortably):
# conv3x3 C_IN→C_MID, relu, conv3x3 C_MID→C_OUT, relu.
H, W = 32, 32
C_IN, C_MID, C_OUT = 8, 16, 8
BAND = 8
R = S = 3


def segment_fused(x, w1, w2):
    """Pipelined (fused) segment: one pallas_call, VMEM intermediate."""
    return fused_segment.fused_conv_pair(x, w1, w2, band=BAND)


def layer0(x, w1):
    """Op-by-op layer 1: HBM round trip after this program returns."""
    return jnp.maximum(conv_tile.conv2d(x, w1, band=BAND), 0.0)


def layer1(mid, w2):
    """Op-by-op layer 2."""
    return jnp.maximum(conv_tile.conv2d(mid, w2, band=BAND), 0.0)


def conv_band_tile(x_slab, w):
    """One pipeline-interval tile for the Rust executor.

    x_slab: [BAND + R - 1, W + S - 1, C] pre-padded input band (the halo
    rows come from the previous/next band or zero padding — the Rust side
    assembles them, playing the role of the NoC).
    Returns [BAND, W, K] — one granularity unit of the intermediate tensor.
    """
    band, wd = BAND, W
    r, s = R, S
    acc = jnp.zeros((band, wd, w.shape[3]), jnp.float32)
    for dr in range(r):
        for ds in range(s):
            patch = x_slab[dr : dr + band, ds : ds + wd, :].astype(jnp.float32)
            acc = acc + jax.lax.dot_general(
                patch,
                w[dr, ds].astype(jnp.float32),
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    return jnp.maximum(acc, 0.0)


def gemm_program(a, b):
    """Quickstart GEMM (Eq. 1) through the L1 tiled kernel."""
    return gemm_tile.gemm(a, b)


def example_inputs(seed=0):
    """Deterministic example tensors for lowering and for tests."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    x = jax.random.normal(k1, (H, W, C_IN), jnp.float32)
    w1 = jax.random.normal(k2, (R, S, C_IN, C_MID), jnp.float32) * 0.1
    w2 = jax.random.normal(k3, (R, S, C_MID, C_OUT), jnp.float32) * 0.1
    return x, w1, w2
