"""AOT artifact tests: HLO text round-trips through the XLA parser that the
Rust runtime uses (same xla_client the `xla` crate wraps at 0.5.1-text
level), and the manifest describes every program."""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

EXPECTED = ["segment_fused", "layer0", "layer1", "tile_layer0", "tile_layer1", "gemm"]


@pytest.fixture(scope="module", autouse=True)
def artifacts_built():
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", ART],
            check=True,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
        )


def test_manifest_lists_all_programs():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name in EXPECTED:
        assert name in manifest["programs"], name
        entry = manifest["programs"][name]
        assert os.path.exists(os.path.join(ART, entry["file"]))
        assert entry["inputs"] and entry["output"]


def test_hlo_text_is_parseable_module():
    """Every artifact must start with an HLO module header and contain an
    ENTRY computation — the minimal contract of the text parser."""
    for name in EXPECTED:
        path = os.path.join(ART, f"{name}.hlo.txt")
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule"), f"{name}: {text[:40]!r}"
        assert "ENTRY" in text, name
        # jax >= 0.5 proto ids overflow xla_extension 0.5.1; text is the
        # contract, so there must be no serialized-proto leakage.
        assert "\x00" not in text


def test_segment_shapes_consistent_with_manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    seg = manifest["segment"]
    fused = manifest["programs"]["segment_fused"]
    assert fused["inputs"][0]["shape"] == [seg["h"], seg["w"], seg["c_in"]]
    assert fused["output"]["shape"] == [seg["h"], seg["w"], seg["c_out"]]
    tile = manifest["programs"]["tile_layer0"]
    assert tile["output"]["shape"] == [seg["band"], seg["w"], seg["c_mid"]]
