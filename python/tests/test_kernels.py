"""Kernel-vs-oracle correctness — the CORE L1 signal.

hypothesis sweeps shapes and dtypes; every Pallas kernel (interpret=True)
must match the pure-jnp reference to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_tile, fused_segment, gemm_tile, ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-4, atol=1e-4)


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- GEMM ----

@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([16, 32, 64]),
    n=st.sampled_from([16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_gemm_matches_ref(m, k, n, dtype, seed):
    a = rand(seed, (m, k), dtype)
    b = rand(seed + 1, (k, n), dtype)
    got = gemm_tile.gemm(a, b, bm=16, bn=16, bk=16)
    want = ref.gemm_ref(a, b)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(got, want, **tol)


def test_gemm_rejects_indivisible_tiles():
    a = jnp.ones((30, 16), jnp.float32)
    b = jnp.ones((16, 16), jnp.float32)
    with pytest.raises(AssertionError):
        gemm_tile.gemm(a, b, bm=16, bn=16, bk=16)


def test_gemm_identity():
    a = jnp.eye(32, dtype=jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(0), (32, 32), jnp.float32)
    np.testing.assert_allclose(gemm_tile.gemm(a, b, bm=16, bn=16, bk=16), b, **TOL)


# ---------------------------------------------------------------- conv ----

@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([8, 16, 32]),
    w=st.sampled_from([8, 16]),
    c=st.sampled_from([1, 3, 8]),
    k=st.sampled_from([1, 4, 16]),
    r=st.sampled_from([1, 3, 5]),
    band=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(h, w, c, k, r, band, seed):
    if h % band != 0:
        band = h
    x = rand(seed, (h, w, c), jnp.float32)
    wt = rand(seed + 1, (r, r, c, k), jnp.float32)
    got = conv_tile.conv2d(x, wt, band=band)
    want = ref.conv2d_ref(x, wt)
    np.testing.assert_allclose(got, want, **TOL)


def test_conv2d_band_independence():
    # The row-band tiling must be invisible in the result.
    x = rand(7, (32, 16, 4), jnp.float32)
    wt = rand(8, (3, 3, 4, 8), jnp.float32)
    a = conv_tile.conv2d(x, wt, band=4)
    b = conv_tile.conv2d(x, wt, band=16)
    np.testing.assert_allclose(a, b, **TOL)


@settings(max_examples=15, deadline=None)
@given(
    h=st.sampled_from([8, 16]),
    w=st.sampled_from([8, 16]),
    c=st.sampled_from([1, 4, 16]),
    r=st.sampled_from([3, 5]),
    seed=st.integers(0, 2**16),
)
def test_dwconv2d_matches_ref(h, w, c, r, seed):
    x = rand(seed, (h, w, c), jnp.float32)
    wt = rand(seed + 1, (r, r, c), jnp.float32)
    got = conv_tile.dwconv2d(x, wt, band=8)
    want = ref.dwconv2d_ref(x, wt)
    np.testing.assert_allclose(got, want, **TOL)


# ------------------------------------------------------------- fused -------

@settings(max_examples=15, deadline=None)
@given(
    h=st.sampled_from([8, 16, 32]),
    w=st.sampled_from([8, 16]),
    c=st.sampled_from([2, 8]),
    k1=st.sampled_from([4, 8]),
    k2=st.sampled_from([2, 8]),
    band=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_fused_pair_matches_op_by_op(h, w, c, k1, k2, band, seed):
    """THE paper claim, functionally: fusing the producer/consumer pair
    (intermediate in VMEM) is bit-compatible with op-by-op execution."""
    if h % band != 0:
        band = h
    x = rand(seed, (h, w, c), jnp.float32)
    w1 = rand(seed + 1, (3, 3, c, k1), jnp.float32) * 0.2
    w2 = rand(seed + 2, (3, 3, k1, k2), jnp.float32) * 0.2
    got = fused_segment.fused_conv_pair(x, w1, w2, band=band)
    want = ref.relu(ref.conv2d_ref(ref.relu(ref.conv2d_ref(x, w1)), w2))
    np.testing.assert_allclose(got, want, **TOL)


def test_fused_pair_1x1_filters():
    x = rand(3, (16, 16, 8), jnp.float32)
    w1 = rand(4, (1, 1, 8, 4), jnp.float32)
    w2 = rand(5, (1, 1, 4, 8), jnp.float32)
    got = fused_segment.fused_conv_pair(x, w1, w2, band=8)
    want = ref.relu(ref.conv2d_ref(ref.relu(ref.conv2d_ref(x, w1)), w2))
    np.testing.assert_allclose(got, want, **TOL)


def test_fused_traffic_model_saves_intermediate():
    fused, op = fused_segment.fused_hbm_traffic_words(32, 32, 8, 16, 8)
    assert op - fused == 2 * 32 * 32 * 16


# ----------------------------------------------------- fused chain -------

@settings(max_examples=8, deadline=None)
@given(
    depth=st.sampled_from([2, 3, 4]),
    h=st.sampled_from([8, 16]),
    c=st.sampled_from([2, 4]),
    band=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_fused_chain_matches_op_by_op(depth, h, c, band, seed):
    """Variable pipeline depth at L1: an N-deep fused conv chain (all
    intermediates in VMEM) matches layer-by-layer execution."""
    if h % band != 0:
        band = h
    x = rand(seed, (h, h, c), jnp.float32)
    ks = [c, 4, 2, 4, 2][: depth + 1]
    weights = [
        rand(seed + 1 + i, (3, 3, ks[i], ks[i + 1]), jnp.float32) * 0.3
        for i in range(depth)
    ]
    got = fused_segment.fused_conv_chain(x, weights, band=band)
    want = x
    for w_ in weights:
        want = ref.relu(ref.conv2d_ref(want, w_))
    np.testing.assert_allclose(got, want, **TOL)


def test_fused_chain_depth1_is_plain_conv():
    x = rand(11, (8, 8, 4), jnp.float32)
    w = rand(12, (3, 3, 4, 4), jnp.float32)
    got = fused_segment.fused_conv_chain(x, [w], band=4)
    want = ref.relu(ref.conv2d_ref(x, w))
    np.testing.assert_allclose(got, want, **TOL)


def test_fused_chain_mixed_filter_sizes():
    x = rand(13, (16, 16, 4), jnp.float32)
    ws = [
        rand(14, (1, 1, 4, 8), jnp.float32),
        rand(15, (3, 3, 8, 4), jnp.float32),
        rand(16, (5, 5, 4, 2), jnp.float32) * 0.1,
    ]
    got = fused_segment.fused_conv_chain(x, ws, band=8)
    want = x
    for w_ in ws:
        want = ref.relu(ref.conv2d_ref(want, w_))
    np.testing.assert_allclose(got, want, **TOL)
