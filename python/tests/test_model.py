"""L2 model shape/semantics tests: the lowered programs compose."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-4, atol=1e-4)


def test_example_inputs_are_deterministic():
    a = model.example_inputs(0)
    b = model.example_inputs(0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_fused_equals_layered():
    """segment_fused(x) == layer1(layer0(x)) — the artifact pair the Rust
    coordinator compares must agree at build time too."""
    x, w1, w2 = model.example_inputs()
    fused = model.segment_fused(x, w1, w2)
    layered = model.layer1(model.layer0(x, w1), w2)
    np.testing.assert_allclose(fused, layered, **TOL)


def test_layers_match_oracle():
    x, w1, w2 = model.example_inputs()
    np.testing.assert_allclose(
        model.layer0(x, w1), ref.relu(ref.conv2d_ref(x, w1)), **TOL
    )


def test_tile_program_reconstructs_layer():
    """Streaming conv_band_tile over halo'd slabs == whole-layer conv.
    This is exactly the schedule the Rust pipelined executor runs."""
    x, w1, _ = model.example_inputs()
    pr, ps = model.R // 2, model.S // 2
    xp = jnp.pad(x, ((pr, pr), (ps, ps), (0, 0)))
    bands = []
    for t in range(model.H // model.BAND):
        slab = jax.lax.dynamic_slice_in_dim(
            xp, t * model.BAND, model.BAND + model.R - 1, axis=0
        )
        bands.append(model.conv_band_tile(slab, w1))
    got = jnp.concatenate(bands, axis=0)
    want = model.layer0(x, w1)
    np.testing.assert_allclose(got, want, **TOL)


def test_gemm_program_matches_ref():
    a = jax.random.normal(jax.random.PRNGKey(1), (64, 64), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (64, 64), jnp.float32)
    np.testing.assert_allclose(model.gemm_program(a, b), ref.gemm_ref(a, b), **TOL)
