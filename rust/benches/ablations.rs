//! Ablation benches: regenerate the design-choice studies DESIGN.md calls
//! out (organization heuristic vs oracle, topology, flexible vs fixed
//! depth).
mod common;

use pipeorgan::config::ArchConfig;

fn main() {
    let cfg = ArchConfig::default();
    let out = common::out_dir();
    pipeorgan::report::ablation_organization(&cfg).emit(&out).unwrap();
    pipeorgan::report::ablation_topology(&cfg).emit(&out).unwrap();
    pipeorgan::report::ablation_depth(&cfg).emit(&out).unwrap();
    common::bench("ablation_depth_sweep", 1, 3, || {
        pipeorgan::report::ablation_depth(&cfg).table.rows.len()
    });
}
