//! Shared harness for the custom benches (criterion substitute — no
//! network in this environment, see DESIGN.md §2).
//!
//! Each bench is a `harness = false` binary that (a) regenerates its paper
//! artifact via the report module and (b) times the generation kernel with
//! warmup + repeated samples, printing a [`Summary`].

use std::time::Instant;

use pipeorgan::util::stats::Summary;

/// Time `f` with `warmup` discarded runs and `samples` measured runs.
///
/// When the `PIPEORGAN_BENCH_JSON` environment variable names a file, one
/// JSON line per bench (`{"bench": …, "mean_ns": …, "p50_ns": …, …}`) is
/// appended to it — the raw record `tools/bench_check.py` aggregates into
/// `reports/BENCH_ci.json` and gates against `BENCH_baseline.json` in the
/// CI `bench-smoke` job.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        ns.push(t0.elapsed().as_nanos() as f64);
    }
    let s = Summary::from_ns(&ns);
    println!("bench {name}: {s}");
    if let Ok(path) = std::env::var("PIPEORGAN_BENCH_JSON") {
        if let Err(e) = append_json_line(&path, name, &s) {
            eprintln!("bench {name}: could not append record to {path}: {e}");
        }
    }
    s
}

/// Append one bench record as compact JSON-per-line (JSONL keeps the file
/// trivially appendable across the separate bench binaries `cargo bench`
/// runs in sequence).
fn append_json_line(path: &str, name: &str, s: &Summary) -> std::io::Result<()> {
    use std::io::Write as _;
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut j = pipeorgan::util::json::Json::obj();
    j.set("bench", name)
        .set("n", s.n)
        .set("mean_ns", s.mean_ns)
        .set("stddev_ns", s.stddev_ns)
        .set("min_ns", s.min_ns)
        .set("p50_ns", s.p50_ns)
        .set("p95_ns", s.p95_ns)
        .set("max_ns", s.max_ns);
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "{j}")
}

/// Standard output directory for bench-generated reports.
pub fn out_dir() -> String {
    std::env::var("PIPEORGAN_REPORTS").unwrap_or_else(|_| "reports".to_string())
}
