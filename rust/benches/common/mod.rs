//! Shared harness for the custom benches (criterion substitute — no
//! network in this environment, see DESIGN.md §2).
//!
//! Each bench is a `harness = false` binary that (a) regenerates its paper
//! artifact via the report module and (b) times the generation kernel with
//! warmup + repeated samples, printing a [`Summary`].

use std::time::Instant;

use pipeorgan::util::stats::Summary;

/// Time `f` with `warmup` discarded runs and `samples` measured runs.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        ns.push(t0.elapsed().as_nanos() as f64);
    }
    let s = Summary::from_ns(&ns);
    println!("bench {name}: {s}");
    s
}

/// Standard output directory for bench-generated reports.
pub fn out_dir() -> String {
    std::env::var("PIPEORGAN_REPORTS").unwrap_or_else(|_| "reports".to_string())
}
