//! §Perf microbenchmark for the co-scheduling hot path: the memoized
//! guillotine beam on the widest canned scenario (`xr-hands`). The
//! evaluation cache is pre-warmed by one throwaway run, so the timed
//! region is the beam itself — state expansion, label pruning, and memo
//! lookups — not first-touch segment costing. `guillotine_beam_xr_hands`
//! is pinned in BENCH_baseline.json with a tightened per-entry
//! `max_ratio` that locks in the bitset-key / parent-pointer-label /
//! parallel-level rework (design and runbook: docs/PERFORMANCE.md); the
//! bands DP runs alongside for scale, not for gating.

mod common;

use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{schedule, xr_hands, CoschedConfig, PartitionKind};
use pipeorgan::dse::EvalCache;

fn main() {
    let cfg = ArchConfig::default();
    let sc = xr_hands();
    let cache = EvalCache::new();

    let cs = CoschedConfig {
        partition: PartitionKind::Guillotine,
        ..CoschedConfig::default()
    };
    schedule(&sc, &cfg, &cs, &cache, 4).expect("warm-up schedule succeeds");
    let beam = common::bench("guillotine_beam_xr_hands", 1, 5, || {
        schedule(&sc, &cfg, &cs, &cache, 4)
            .expect("schedule succeeds")
            .cosched
            .makespan_cycles as u64
    });

    let r = schedule(&sc, &cfg, &cs, &cache, 4).expect("schedule succeeds");
    println!(
        "guillotine_beam_xr_hands: makespan {:.3e} cycles, cut {} (mean {:.2} ms/solve)",
        r.cosched.makespan_cycles,
        r.cut_tree.encode(),
        beam.mean_ns / 1e6
    );

    // The 1-D bands DP on the same scenario: the cheap baseline the beam
    // must justify its cost against.
    let bands = CoschedConfig {
        partition: PartitionKind::Bands,
        ..CoschedConfig::default()
    };
    let dp = common::bench("bands_dp_xr_hands", 1, 5, || {
        schedule(&sc, &cfg, &bands, &cache, 4)
            .expect("schedule succeeds")
            .cosched
            .makespan_cycles as u64
    });
    println!(
        "bands_dp_xr_hands: {:.2}x cheaper than the guillotine beam",
        beam.mean_ns / dp.mean_ns
    );

    let stats = cache.stats();
    println!(
        "cache: {} hits / {} misses (hit rate {:.1}%)",
        stats.hits,
        stats.misses,
        100.0 * stats.hit_rate()
    );
}
