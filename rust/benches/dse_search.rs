//! §Perf microbenchmark for the DSE search hot path: the same sweep run
//! cold (fresh memoization cache per run) and warm (shared cache), for both
//! strategies. The warm run must beat the cold run — that is the memoized
//! evaluation cache doing its job (every candidate segment shared between
//! partitions is costed once). Also times the plan-time tuned mapper cold
//! vs warm, and the persistent-cache save/load roundtrip that carries the
//! warmth across processes.

mod common;

use std::sync::Arc;

use pipeorgan::config::{ArchConfig, TopologyKind};
use pipeorgan::cost::Mapper;
use pipeorgan::dse::{explore, DseConfig, EvalCache, SearchStrategy};
use pipeorgan::mapper::TunedPipeOrgan;

fn bench_strategy(strategy: SearchStrategy, task: &pipeorgan::ir::ModelGraph) {
    let cfg = ArchConfig::default();
    let dse = DseConfig {
        strategy,
        beam_width: 8,
        depth_cap: 6,
        ladder_rungs: 3,
        topologies: vec![TopologyKind::Amp, TopologyKind::Mesh],
        budget: None,
        max_labels: 64,
        ..DseConfig::default()
    };
    let name = format!("dse_{}_{}", strategy.name(), task.name);

    // Cold: a fresh cache every sample — every candidate is evaluated.
    let cold = common::bench(&format!("{name}_cold"), 1, 5, || {
        let cache = EvalCache::new();
        explore(task, &cfg, &dse, &cache, 1).best().cycles
    });

    // Warm: one shared cache, pre-populated by a first run — the sweep is
    // pure lookups.
    let cache = EvalCache::new();
    explore(task, &cfg, &dse, &cache, 1);
    let warm = common::bench(&format!("{name}_warm"), 1, 5, || {
        explore(task, &cfg, &dse, &cache, 1).best().cycles
    });

    let stats = cache.stats();
    println!(
        "{name}: cache {} entries, {} hits / {} misses (hit rate {:.1}%)",
        stats.misses,
        stats.hits,
        stats.misses,
        100.0 * stats.hit_rate()
    );
    println!(
        "{name}: warm vs cold mean speedup = {:.2}x",
        cold.mean_ns / warm.mean_ns
    );
}

/// Plan-time cost of the tuned mapper, cold vs warm, plus the persistent
/// save/load roundtrip that makes the warm case reachable across
/// processes.
fn bench_tuned(task: &pipeorgan::ir::ModelGraph) {
    let cfg = ArchConfig::default();
    let name = format!("tuned_plan_{}", task.name);

    let cold = common::bench(&format!("{name}_cold"), 0, 3, || {
        TunedPipeOrgan::new(Arc::new(EvalCache::new()))
            .plan(task, &cfg)
            .segments
            .len()
    });

    let cache = Arc::new(EvalCache::new());
    TunedPipeOrgan::new(Arc::clone(&cache)).plan(task, &cfg);
    let warm = common::bench(&format!("{name}_warm"), 1, 5, || {
        TunedPipeOrgan::new(Arc::clone(&cache))
            .plan(task, &cfg)
            .segments
            .len()
    });
    println!(
        "{name}: warm vs cold mean speedup = {:.2}x",
        cold.mean_ns / warm.mean_ns
    );

    let path = std::env::temp_dir().join(format!(
        "pipeorgan_bench_cache_{}_{}.json",
        std::process::id(),
        task.name
    ));
    common::bench(&format!("{name}_save"), 0, 3, || {
        cache.save_file(&path).unwrap();
    });
    let load = common::bench(&format!("{name}_load"), 0, 3, || {
        let (loaded, _) = EvalCache::load_file(&path);
        loaded.len()
    });
    println!(
        "{name}: persisted {} entries (load mean {:.2} ms)",
        cache.len(),
        load.mean_ns / 1e6
    );
    let _ = std::fs::remove_file(&path);
}

fn main() {
    let tasks = [
        pipeorgan::workloads::keyword_detection(),
        pipeorgan::workloads::gaze_estimation(),
    ];
    for task in &tasks {
        bench_strategy(SearchStrategy::Beam, task);
    }
    bench_strategy(SearchStrategy::Exhaustive, &tasks[0]);
    for task in &tasks {
        bench_tuned(task);
    }
}
