//! Bench E9: regenerate Fig. 13 (end-to-end performance vs TANGRAM-like
//! and SIMBA-like across the zoo; paper geomean 1.95x) and time one full
//! mapper+evaluate pass.
mod common;

use pipeorgan::config::ArchConfig;
use pipeorgan::cost::{evaluate, Mapper};
use pipeorgan::mapper::PipeOrgan;

fn main() {
    let cfg = ArchConfig::default();
    let out = common::out_dir();
    pipeorgan::report::fig13_performance(&cfg, 8).emit(&out).unwrap();

    let g = pipeorgan::workloads::eye_segmentation();
    common::bench("pipeorgan_plan_eval_eye_seg", 2, 10, || {
        let plan = PipeOrgan::default().plan(&g, &cfg);
        evaluate(&g, &plan, &cfg).cycles
    });
}
