//! Bench E10: regenerate Fig. 14 (normalized DRAM accesses; paper: 31%
//! geomean reduction) and time the memory model.
mod common;

use pipeorgan::config::ArchConfig;
use pipeorgan::memory::op_by_op_dram_traffic;

fn main() {
    let cfg = ArchConfig::default();
    let out = common::out_dir();
    pipeorgan::report::fig14_dram(&cfg, 8).emit(&out).unwrap();

    let tasks = pipeorgan::workloads::all_tasks();
    common::bench("dram_accounting_zoo", 2, 20, || {
        tasks
            .iter()
            .map(|g| op_by_op_dram_traffic(g, &cfg).total())
            .sum::<u64>()
    });
}
