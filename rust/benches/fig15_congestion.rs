//! Bench E11: regenerate Fig. 15 (worst-case channel load vs compute
//! interval for blocked/fine-1D/AMP).
mod common;

use pipeorgan::config::ArchConfig;

fn main() {
    let cfg = ArchConfig::default();
    let out = common::out_dir();
    pipeorgan::report::fig15_congestion(&cfg).emit(&out).unwrap();
    common::bench("fig15_sweep", 1, 5, || {
        pipeorgan::report::fig15_congestion(&cfg).table.rows.len()
    });
}
