//! Bench E12/E13: regenerate Fig. 16 (depths) and Fig. 17 (finest
//! granularities) and time stage 1 over the zoo.
mod common;

use pipeorgan::config::ArchConfig;
use pipeorgan::pipeline::partition;

fn main() {
    let cfg = ArchConfig::default();
    let out = common::out_dir();
    pipeorgan::report::fig16_depth(&cfg).emit(&out).unwrap();
    pipeorgan::report::fig17_granularity(&cfg).emit(&out).unwrap();

    let tasks = pipeorgan::workloads::all_tasks();
    common::bench("depth_heuristic_zoo", 3, 30, || {
        tasks.iter().map(|g| partition(g, &cfg).len()).sum::<usize>()
    });
}
