//! Bench E1/E2: regenerate Fig. 5 (A/W ratios) and Fig. 6 (skip
//! structures) and time the characterization pass.
mod common;

fn main() {
    let out = common::out_dir();
    pipeorgan::report::fig5_aw_ratios().emit(&out).unwrap();
    pipeorgan::report::fig6_skips().emit(&out).unwrap();
    common::bench("characterize_zoo", 2, 10, || {
        let tasks = pipeorgan::workloads::all_tasks();
        let n: usize = tasks
            .iter()
            .map(|g| {
                g.layers().iter().filter(|l| l.aw_ratio() > 1.0).count()
                    + pipeorgan::ir::skips::SkipProfile::of(g).num_skips()
            })
            .sum();
        n
    });
}
