//! Bench E3–E7: regenerate the Fig. 8–12 traffic analyses (mesh + AMP,
//! analytic + cycle-level) and time both analysis paths.
mod common;

use pipeorgan::config::{ArchConfig, TopologyKind};
use pipeorgan::noc::Topology;
use pipeorgan::sim::{analyze, simulate_interval};
use pipeorgan::traffic::{derive_flows, scenarios};

fn main() {
    let cfg = ArchConfig::default();
    let out = common::out_dir();
    pipeorgan::report::fig8_12_traffic(&cfg).emit(&out).unwrap();

    let topo = Topology::new(TopologyKind::Mesh, cfg.pe_rows, cfg.pe_cols);
    let scen = scenarios::fig8_depth2_blocked(cfg.pe_rows, cfg.pe_cols);
    let flows = derive_flows(&topo, &scen.placement, &scen.handoffs);
    common::bench("channel_load_analysis_32x32", 3, 30, || {
        analyze(&topo, &flows).worst_channel_load
    });
    common::bench("cycle_sim_32x32", 1, 5, || {
        simulate_interval(&topo, &flows, 1).makespan
    });
}
