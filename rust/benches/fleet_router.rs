//! §Perf microbenchmark for the fleet layer: routed event-loop throughput
//! on the canned `xr-core` scenario across a 3-chip fleet, per router
//! policy, under one second of shared diurnal traffic. Planning runs once
//! through the shared evaluation cache, so the timed region is the
//! front-door routing plus the per-chip discrete-event simulation — the
//! fleet serving hot path. The gate-watched name is
//! `fleet_router_jsq_xr_core` (see BENCH_baseline.json).

mod common;

use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{scenario_by_name, CoschedConfig};
use pipeorgan::dse::EvalCache;
use pipeorgan::obs::Obs;
use pipeorgan::serve::{
    plan_scenario, simulate_fleet, streams, ArrivalProcess, FleetConfig, Policy, RouterPolicy,
    ServePlan, SimOptions,
};

fn main() {
    let cfg = ArchConfig::default();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").expect("canned scenario");
    let chips = 3;
    // Identical chips; replans after the first are pure cache hits.
    let plans: Vec<ServePlan> = (0..chips)
        .map(|_| {
            plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 4)
                .expect("planning succeeds")
        })
        .collect();
    println!(
        "planned xr-core x{chips}: {} evaluations, {} cache hits (last chip)",
        plans[chips - 1].evaluations,
        plans[chips - 1].cache_hits
    );

    let fc = FleetConfig {
        chips,
        routers: RouterPolicy::ALL.to_vec(),
        ..FleetConfig::default()
    };
    // One second of diurnal traffic at 3x native rates (the fleet has 3x
    // the capacity of the single-array serve bench), shared by every
    // timed router so the comparisons are apples to apples.
    let arrivals = streams(
        &sc,
        &ArrivalProcess::Diurnal { period_s: 0.0, amp: 0.8 },
        3.0,
        1.0,
        7,
    );
    let requests: usize = arrivals.iter().map(Vec::len).sum();
    let obs = Obs::disabled();

    for router in RouterPolicy::ALL {
        // The JSQ run carries the gate-watched stable name; the others
        // are informational comparisons.
        let name = if router == RouterPolicy::Jsq {
            "fleet_router_jsq_xr_core".to_string()
        } else {
            format!("fleet_router_{}", router.name())
        };
        let s = common::bench(&name, 1, 5, || {
            simulate_fleet(
                &sc,
                &plans,
                Policy::Fifo,
                router,
                &fc,
                SimOptions::default(),
                &arrivals,
                &obs,
            )
            .total_requests()
        });
        println!(
            "{name}: {:.0} requests/s simulated ({requests} requests across {chips} chips)",
            requests as f64 / (s.mean_ns / 1e9)
        );
    }
}
