//! §Perf microbenchmarks: the simulator hot paths the optimization pass
//! (DESIGN.md §Perf) tracks — routing, channel-load accumulation,
//! cycle-level simulation, full mapper plan+evaluate, and the parallel
//! zoo sweep.
mod common;

use std::sync::Arc;

use pipeorgan::config::{ArchConfig, TopologyKind};
use pipeorgan::coordinator::{run_jobs, EvalJob, MapperKind};
use pipeorgan::cost::{evaluate, Mapper};
use pipeorgan::mapper::PipeOrgan;
use pipeorgan::noc::{route, Topology};
use pipeorgan::sim::{analyze, simulate_interval};
use pipeorgan::traffic::{derive_flows, scenarios};

fn main() {
    let cfg = ArchConfig::default();

    // --- routing throughput ------------------------------------------------
    for kind in [TopologyKind::Mesh, TopologyKind::Amp] {
        let topo = Topology::new(kind, 32, 32);
        common::bench(&format!("route_1k_pairs_{}", kind.name()), 3, 30, || {
            let mut hops = 0usize;
            for i in 0..1024u32 {
                let src = i % 1024;
                let dst = (i * 37 + 11) % 1024;
                hops += route(&topo, src, dst).len();
            }
            hops
        });
    }

    // --- channel-load analysis ----------------------------------------------
    let topo = Topology::new(TopologyKind::Mesh, 32, 32);
    let scen = scenarios::fig8_depth4_blocked(32, 32);
    let flows = derive_flows(&topo, &scen.placement, &scen.handoffs);
    println!("flows in fig8_depth4 scenario: {}", flows.len());
    common::bench("analyze_fig8_depth4", 3, 50, || {
        analyze(&topo, &flows).total_word_hops
    });

    // --- per-link loadmap (telemetry on top of analyze) -----------------------
    let cached = Topology::cached(TopologyKind::Mesh, 32, 32);
    common::bench("noc_loadmap", 3, 50, || {
        let a = analyze(&cached, &flows);
        let map = pipeorgan::noc::LinkLoadMap::from_analysis(cached.clone(), &a, 640.0);
        (map.max(), map.class_totals()[0].1)
    });

    // --- cycle-level sim ----------------------------------------------------
    common::bench("cycle_sim_fig8_depth4", 1, 5, || {
        simulate_interval(&topo, &flows, 1).makespan
    });

    // --- full mapper + cost evaluation ---------------------------------------
    for g in [
        pipeorgan::workloads::eye_segmentation(),
        pipeorgan::workloads::hand_tracking(),
    ] {
        common::bench(&format!("plan_eval_{}", g.name), 2, 10, || {
            let plan = PipeOrgan::default().plan(&g, &cfg);
            evaluate(&g, &plan, &cfg).cycles
        });
    }

    // --- parallel zoo sweep (the Fig. 13 inner loop) --------------------------
    let tasks: Vec<Arc<pipeorgan::ir::ModelGraph>> = pipeorgan::workloads::all_tasks()
        .into_iter()
        .map(Arc::new)
        .collect();
    common::bench("zoo_sweep_parallel", 1, 5, || {
        let jobs: Vec<EvalJob> = tasks
            .iter()
            .flat_map(|g| {
                [
                    MapperKind::PipeOrgan,
                    MapperKind::TangramLike,
                    MapperKind::SimbaLike,
                ]
                .into_iter()
                .map(|mapper| EvalJob {
                    graph: Arc::clone(g),
                    mapper,
                    cfg: cfg.clone(),
                })
            })
            .collect();
        run_jobs(jobs, 8).len()
    });
}
