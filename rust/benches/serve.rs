//! §Perf microbenchmark for the online serving engine: event-loop
//! throughput per policy on the canned `xr-core` scenario (requests and
//! trace events simulated per wall-second), the dynamic-vs-static
//! bandwidth model overhead, and the rate-sweep cost. Planning runs once
//! up front through the shared evaluation cache, so the timed region is
//! the discrete-event simulation itself — the serving hot path.

mod common;

use pipeorgan::config::ArchConfig;
use pipeorgan::cosched::{scenario_by_name, CoschedConfig};
use pipeorgan::dse::EvalCache;
use pipeorgan::serve::{
    plan_scenario, simulate, streams, sweep_max_rate, ArrivalProcess, BandwidthModel, Policy,
    SimOptions,
};

fn main() {
    let cfg = ArchConfig::default();
    let cache = EvalCache::new();
    let sc = scenario_by_name("xr-core").expect("canned scenario");
    let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 4)
        .expect("planning succeeds");
    println!(
        "planned xr-core: {} evaluations, {} cache hits",
        plan.evaluations, plan.cache_hits
    );

    // One second of Poisson traffic at the native rates, shared by every
    // timed policy so the comparisons are apples to apples.
    let arrivals = streams(&sc, &ArrivalProcess::Poisson, 1.0, 1.0, 7);
    let requests: usize = arrivals.iter().map(Vec::len).sum();

    for policy in Policy::ALL {
        let name = format!("serve_{}_dynamic", policy.name());
        let s = common::bench(&name, 1, 5, || {
            simulate(&sc, &plan, policy, &arrivals, SimOptions::default()).total_requests()
        });
        println!(
            "{name}: {:.0} requests/s simulated ({requests} requests)",
            requests as f64 / (s.mean_ns / 1e9)
        );
    }

    // The gate-watched event-loop bench: FIFO on the shared traffic with
    // observability disabled, under a stable name so BENCH_baseline.json
    // can pin the no-obs hot path (the <5% overhead budget in DESIGN.md
    // §Obs is judged against this number).
    common::bench("serve_event_loop_xr_core", 1, 5, || {
        simulate(&sc, &plan, Policy::Fifo, &arrivals, SimOptions::default()).total_requests()
    });

    // Static split: no per-epoch demand computation — the contention
    // model's overhead is the gap to the dynamic runs above.
    let static_opts = SimOptions {
        bandwidth: BandwidthModel::Static,
        ..SimOptions::default()
    };
    common::bench("serve_fifo_static", 1, 5, || {
        simulate(&sc, &plan, Policy::Fifo, &arrivals, static_opts).total_requests()
    });

    // Borrowing scans every queue on idle regions; time the worst case.
    let borrow_opts = SimOptions {
        borrow: true,
        ..SimOptions::default()
    };
    common::bench("serve_edf_borrow", 1, 5, || {
        simulate(&sc, &plan, Policy::Edf, &arrivals, borrow_opts).total_requests()
    });

    // The sweep multiplies the simulation by its probe count; short
    // windows keep it a planning-time (not serving-time) tool.
    let sweep = common::bench("serve_sweep_edf", 0, 2, || {
        sweep_max_rate(&sc, &plan, Policy::Edf, SimOptions::default(), 0.1).probes.len()
    });
    let result = sweep_max_rate(&sc, &plan, Policy::Edf, SimOptions::default(), 0.1);
    println!(
        "serve_sweep_edf: boundary {:.3}x in {} probes (mean {:.1} ms/sweep)",
        result.max_mult,
        result.probes.len(),
        sweep.mean_ns / 1e6
    );
}
