//! Bench E8: regenerate Table II (mesh bottleneck summary) and the
//! Sec. IV-A dataflow-heuristic validation.
mod common;

use pipeorgan::config::ArchConfig;

fn main() {
    let cfg = ArchConfig::default();
    let out = common::out_dir();
    pipeorgan::report::table2_bottlenecks(&cfg).emit(&out).unwrap();
    pipeorgan::report::validate_dataflow().emit(&out).unwrap();
    common::bench("table2", 1, 5, || {
        pipeorgan::report::table2_bottlenecks(&cfg).table.rows.len()
    });
}
