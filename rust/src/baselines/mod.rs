//! Baseline dataflows (Sec. V-C): TANGRAM-like and SIMBA-like mappers.

mod simba;
mod tangram;

pub use simba::SimbaLike;
pub use tangram::TangramLike;

/// Clamp a handoff so each producer PE emits at least one word per
/// interval: finer steps cannot leave the PE's MAC pipeline. Returns
/// (words_per_interval, intervals).
pub(crate) fn clamp_handoff(total_words: u64, raw_intervals: u64, producer_pes: usize) -> (u64, u64) {
    let min_words = producer_pes.max(1) as u64;
    let raw_words = crate::util::ceil_div(total_words.max(1), raw_intervals.max(1));
    let words = raw_words.max(min_words).min(total_words.max(1));
    let intervals = crate::util::ceil_div(total_words.max(1), words).max(1);
    (words, intervals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_respects_floor_and_total() {
        // element-grain request on 512 producers → clamped to 512 words.
        let (w, t) = clamp_handoff(16384, 16384, 512);
        assert_eq!(w, 512);
        assert_eq!(t, 32);
        // coarse request passes through
        let (w, t) = clamp_handoff(16384, 16, 512);
        assert_eq!(w, 1024);
        assert_eq!(t, 16);
        // granularity can never exceed the tensor
        let (w, t) = clamp_handoff(100, 1, 512);
        assert_eq!(w, 100);
        assert_eq!(t, 1);
    }
}
