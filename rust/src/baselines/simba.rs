//! SIMBA-like dataflow (Sec. V-C): "parallelizes input and output channels
//! and does pipelining only when these two dimensions cannot utilize the
//! substrate". Suffers when C×K parallelism is insufficient and from load
//! imbalance with mixed filter sizes (Sec. VI-A). Runs on a plain mesh.

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{Mapper, MappingPlan, PlannedHandoff, PlannedSegment};
use crate::dataflow::{rank_extent, DataflowStyle, Rank};
use crate::ir::{Layer, ModelGraph};
use crate::pipeline::Segment;
use crate::spatial::Organization;

use super::clamp_handoff;

/// The SIMBA-like baseline mapper.
#[derive(Debug, Default, Clone, Copy)]
pub struct SimbaLike;

/// PEs a layer can occupy when parallelism is restricted to the C and K
/// ranks (each PE consumes `dot` channels of C per cycle).
pub fn ck_parallel_pes(layer: &Layer, cfg: &ArchConfig) -> usize {
    let c = rank_extent(&layer.op, Rank::C).max(1);
    let k = rank_extent(&layer.op, Rank::K).max(1);
    let units = crate::util::ceil_div(c, cfg.pe_dot_product as u64) * k;
    (units as usize).min(cfg.num_pes()).max(1)
}

impl SimbaLike {
    /// Substrate utilization under C/K-only parallelization.
    pub fn utilization(layer: &Layer, cfg: &ArchConfig) -> f64 {
        ck_parallel_pes(layer, cfg) as f64 / cfg.num_pes() as f64
    }
}

impl Mapper for SimbaLike {
    fn name(&self) -> &'static str {
        "simba_like"
    }

    fn topology(&self) -> TopologyKind {
        TopologyKind::Mesh
    }

    fn plan(&self, graph: &ModelGraph, cfg: &ArchConfig) -> MappingPlan {
        let n = graph.num_layers();
        let mut segments = Vec::new();
        let mut l = 0usize;
        while l < n {
            let a = graph.layer(l);
            let util_a = Self::utilization(a, cfg);
            // Pipeline only when one layer cannot utilize the substrate and
            // a pairable neighbor exists.
            let pairable = util_a < 0.5
                && l + 1 < n
                && a.is_einsum()
                && !a.is_complex()
                && graph.layer(l + 1).is_einsum()
                && !graph.layer(l + 1).is_complex();
            if pairable {
                let b = graph.layer(l + 1);
                let pes_a = ck_parallel_pes(a, cfg);
                let pes_b = ck_parallel_pes(b, cfg).min(cfg.num_pes() - pes_a.min(cfg.num_pes() - 1));
                // Blocked chunks, coarse granularity: SIMBA moves tiles
                // through the global buffer between chunks.
                let total = a.output_act_words();
                let raw_intervals = a.op.output_rows().max(1);
                let (words, intervals) = clamp_handoff(total, raw_intervals, pes_a);
                segments.push(PlannedSegment {
                    segment: Segment::new(l, 2),
                    organization: Organization::Blocked1D,
                    pe_alloc: vec![pes_a.max(1), pes_b.max(1)],
                    styles: vec![DataflowStyle::MixedActivation; 2],
                    handoffs: vec![PlannedHandoff {
                        from_stage: 0,
                        to_stage: 1,
                        words_per_interval: words,
                        intervals,
                        via_gb: true,
                        is_skip: false,
                    }],
                });
                l += 2;
            } else {
                // Op-by-op on the C/K-limited allocation.
                segments.push(PlannedSegment {
                    segment: Segment::new(l, 1),
                    organization: Organization::Sequential,
                    pe_alloc: vec![ck_parallel_pes(a, cfg)],
                    styles: vec![DataflowStyle::MixedActivation],
                    handoffs: vec![],
                });
                l += 1;
            }
        }
        MappingPlan {
            mapper_name: self.name().into(),
            topology: self.topology(),
            segments,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;
    use crate::workloads;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn wide_layers_fully_utilize() {
        // C=256, K=512: ceil(256/8)*512 = 16384 units ≫ 1024 PEs.
        let l = Layer::new("big", Op::conv2d(1, 16, 16, 256, 512, 3, 3, 1, 1));
        assert_eq!(ck_parallel_pes(&l, &cfg()), 1024);
        assert_eq!(SimbaLike::utilization(&l, &cfg()), 1.0);
    }

    #[test]
    fn narrow_layers_underutilize() {
        // RITNet-class layer: C=K=32 → ceil(32/8)*32 = 128 of 1024 PEs.
        let l = Layer::new("narrow", Op::conv2d(1, 192, 320, 32, 32, 3, 3, 1, 1));
        assert_eq!(ck_parallel_pes(&l, &cfg()), 128);
        assert!(SimbaLike::utilization(&l, &cfg()) < 0.5);
    }

    #[test]
    fn pipelines_only_underutilized_layers() {
        let g = workloads::eye_segmentation(); // narrow channels
        let plan = SimbaLike.plan(&g, &cfg());
        plan.validate(&g, &cfg()).unwrap();
        assert!(
            plan.segments.iter().any(|s| s.depth() == 2),
            "narrow model should trigger pipelining"
        );
        let g2 = workloads::hand_tracking(); // wide channels
        let plan2 = SimbaLike.plan(&g2, &cfg());
        let paired = plan2.segments.iter().filter(|s| s.depth() == 2).count();
        let total = plan2.segments.len();
        assert!(
            (paired as f64) < total as f64 * 0.4,
            "wide model should mostly run op-by-op ({paired}/{total})"
        );
    }

    #[test]
    fn plans_validate_on_whole_zoo() {
        for g in workloads::all_tasks() {
            let plan = SimbaLike.plan(&g, &cfg());
            plan.validate(&g, &cfg()).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn handoffs_go_via_gb() {
        let g = workloads::eye_segmentation();
        let plan = SimbaLike.plan(&g, &cfg());
        for s in &plan.segments {
            for h in &s.handoffs {
                assert!(h.via_gb, "SIMBA-like moves tiles through the GB");
            }
        }
    }
}
