//! TANGRAM-like dataflow (Sec. V-C): fixed depth-2 fine-grained pipelining,
//! "alternating between output stationary and input stationary", with the
//! prior-work blocked spatial allocation. Runs on a plain mesh.

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{Mapper, MappingPlan, PlannedHandoff, PlannedSegment};
use crate::dataflow::{DataflowStyle, LoopNest};
use crate::ir::ModelGraph;
use crate::pipeline::{pair_granularity, Segment};
use crate::spatial::{allocate_pes, Organization};

use super::clamp_handoff;

/// The TANGRAM-like baseline mapper.
#[derive(Debug, Default, Clone, Copy)]
pub struct TangramLike;

impl Mapper for TangramLike {
    fn name(&self) -> &'static str {
        "tangram_like"
    }

    fn topology(&self) -> TopologyKind {
        TopologyKind::Mesh
    }

    fn plan(&self, graph: &ModelGraph, cfg: &ArchConfig) -> MappingPlan {
        let n = graph.num_layers();
        let mut segments = Vec::new();
        let mut l = 0usize;
        while l < n {
            let a = graph.layer(l);
            let can_pair = l + 1 < n
                && !a.is_complex()
                && !graph.layer(l + 1).is_complex()
                && a.is_einsum()
                && graph.layer(l + 1).is_einsum();
            if can_pair {
                let b = graph.layer(l + 1);
                // Alternating OS (producer) / IS (consumer).
                let styles = vec![
                    DataflowStyle::OutputStationary,
                    DataflowStyle::InputStationary,
                ];
                let np = LoopNest::for_op(&a.op, styles[0]);
                let nc = LoopNest::for_op(&b.op, styles[1]);
                let g = pair_granularity(&np, &nc, a.output_act_words());
                let pe_alloc = allocate_pes(&[a.macs(), b.macs()], cfg.num_pes());
                let (words, intervals) =
                    clamp_handoff(a.output_act_words(), g.intervals, pe_alloc[0]);
                segments.push(PlannedSegment {
                    segment: Segment::new(l, 2),
                    organization: Organization::Blocked1D,
                    pe_alloc,
                    styles,
                    handoffs: vec![PlannedHandoff {
                        from_stage: 0,
                        to_stage: 1,
                        words_per_interval: words,
                        intervals,
                        // fine-grained: PE-to-PE over the NoC
                        via_gb: false,
                        is_skip: false,
                    }],
                });
                l += 2;
            } else {
                segments.push(PlannedSegment {
                    segment: Segment::new(l, 1),
                    organization: Organization::Sequential,
                    pe_alloc: vec![cfg.num_pes()],
                    styles: vec![DataflowStyle::OutputStationary],
                    handoffs: vec![],
                });
                l += 1;
            }
        }
        MappingPlan {
            mapper_name: self.name().into(),
            topology: self.topology(),
            segments,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn pairs_consecutive_einsum_layers() {
        let g = workloads::synthetic::equal_conv_segment(4);
        let plan = TangramLike.plan(&g, &ArchConfig::default());
        plan.validate(&g, &ArchConfig::default()).unwrap();
        assert_eq!(plan.segments.len(), 2);
        assert!(plan.segments.iter().all(|s| s.depth() == 2));
        assert!(plan
            .segments
            .iter()
            .all(|s| s.organization == Organization::Blocked1D));
    }

    #[test]
    fn complex_layers_run_alone() {
        let g = workloads::object_detection();
        let plan = TangramLike.plan(&g, &ArchConfig::default());
        plan.validate(&g, &ArchConfig::default()).unwrap();
        for s in &plan.segments {
            for id in s.segment.layers() {
                if graph_is_complex(&g, id) {
                    assert_eq!(s.depth(), 1, "complex layer pipelined");
                }
            }
        }
    }

    fn graph_is_complex(g: &ModelGraph, id: usize) -> bool {
        g.layer(id).is_complex()
    }

    #[test]
    fn plans_validate_on_whole_zoo() {
        let cfg = ArchConfig::default();
        for g in workloads::all_tasks() {
            let plan = TangramLike.plan(&g, &cfg);
            plan.validate(&g, &cfg).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn depth_never_exceeds_two() {
        let g = workloads::eye_segmentation();
        let plan = TangramLike.plan(&g, &ArchConfig::default());
        assert!(plan.segments.iter().all(|s| s.depth() <= 2));
    }
}
