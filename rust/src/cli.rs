//! Tiny subcommand/flag parser (clap substitute — see DESIGN.md §2).
//!
//! Grammar: `pipeorgan <subcommand> [--key value]... [--switch]...`.
//! Flags may appear in any order; unknown flags are an error so typos
//! surface instead of silently using defaults.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `known_flags` lists accepted
    /// `--key` names; each either takes a value or is a boolean switch.
    pub fn parse(
        raw: &[String],
        known_flags: &[(&str, bool)], // (name, takes_value)
    ) -> Result<Args, String> {
        let mut it = raw.iter().peekable();
        let subcommand = it
            .next()
            .cloned()
            .ok_or_else(|| "missing subcommand".to_string())?;
        if subcommand.starts_with("--") {
            return Err(format!("expected subcommand, got flag `{subcommand}`"));
        }
        let mut flags = BTreeMap::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(format!("unexpected positional `{arg}`"));
            };
            let Some(&(_, takes_value)) =
                known_flags.iter().find(|(k, _)| *k == name)
            else {
                return Err(format!("unknown flag `--{name}`"));
            };
            let value = if takes_value {
                it.next()
                    .cloned()
                    .ok_or_else(|| format!("flag `--{name}` needs a value"))?
            } else {
                "true".to_string()
            };
            if flags.insert(name.to_string(), value).is_some() {
                return Err(format!("duplicate flag `--{name}`"));
            }
        }
        Ok(Args { subcommand, flags })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Shared parse-or-default for integer-valued flags.
    fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag `--{name}` expects an integer, got `{v}`")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_parsed(name, default)
    }

    /// Like [`Args::get_usize`] for u64-valued flags (evaluation budgets,
    /// cache sizes) where a platform-width integer would be wrong.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        self.get_parsed(name, default)
    }

    /// Float-valued flags (durations, rate multipliers). Rust's float
    /// parser happily accepts `nan` and `inf`, which no flag describing a
    /// physical quantity wants, so non-finite values are rejected here
    /// alongside garbage — callers still add their own range checks
    /// (positivity, bounds).
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse::<f64>() {
                Ok(x) if x.is_finite() => Ok(x),
                _ => Err(format!("flag `--{name}` expects a finite number, got `{v}`")),
            },
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    const FLAGS: &[(&str, bool)] = &[("out", true), ("workers", true), ("verbose", false)];

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&s(&["e2e", "--out", "reports", "--verbose"]), FLAGS).unwrap();
        assert_eq!(a.subcommand, "e2e");
        assert_eq!(a.get("out"), Some("reports"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("workers", 4).unwrap(), 4);
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(Args::parse(&s(&["e2e", "--nope"]), FLAGS).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(Args::parse(&s(&["e2e", "--out"]), FLAGS).is_err());
    }

    #[test]
    fn rejects_duplicate() {
        assert!(Args::parse(&s(&["e2e", "--out", "a", "--out", "b"]), FLAGS).is_err());
    }

    #[test]
    fn rejects_missing_subcommand() {
        assert!(Args::parse(&s(&[]), FLAGS).is_err());
        assert!(Args::parse(&s(&["--out", "x"]), FLAGS).is_err());
    }

    #[test]
    fn bad_integer_flag() {
        let a = Args::parse(&s(&["e2e", "--workers", "many"]), FLAGS).unwrap();
        assert!(a.get_usize("workers", 1).is_err());
        assert!(a.get_u64("workers", 1).is_err());
    }

    #[test]
    fn u64_flag_parses_and_defaults() {
        let a = Args::parse(&s(&["e2e", "--workers", "4096"]), FLAGS).unwrap();
        assert_eq!(a.get_u64("workers", 7).unwrap(), 4096);
        assert_eq!(a.get_u64("out", 7).unwrap(), 7);
    }

    #[test]
    fn f64_flag_parses_and_defaults() {
        let a = Args::parse(&s(&["serve", "--workers", "2.5"]), FLAGS).unwrap();
        assert_eq!(a.get_f64("workers", 1.0).unwrap(), 2.5);
        // Plain integers parse as floats too; absent flags take the default.
        let a = Args::parse(&s(&["serve", "--workers", "3"]), FLAGS).unwrap();
        assert_eq!(a.get_f64("workers", 1.0).unwrap(), 3.0);
        assert_eq!(a.get_f64("out", 0.25).unwrap(), 0.25);
    }

    #[test]
    fn f64_flag_rejects_garbage_and_duplicates() {
        let a = Args::parse(&s(&["serve", "--workers", "fast"]), FLAGS).unwrap();
        let err = a.get_f64("workers", 1.0).unwrap_err();
        assert!(err.contains("expects a finite number"), "{err}");
        // `f64::from_str` accepts "nan"/"inf"; the flag parser must not.
        for bad in ["nan", "NaN", "inf", "-inf", "infinity"] {
            let a = Args::parse(&s(&["serve", "--workers", bad]), FLAGS).unwrap();
            assert!(a.get_f64("workers", 1.0).is_err(), "{bad} must be rejected");
        }
        // Duplicate float flags are rejected at parse time like any other.
        assert!(
            Args::parse(&s(&["serve", "--workers", "1.0", "--workers", "2.0"]), FLAGS).is_err()
        );
    }
}
