//! Tiny subcommand/flag parser (clap substitute — see DESIGN.md §2).
//!
//! Grammar: `pipeorgan <subcommand> [--key value]... [--switch]...`.
//! Flags may appear in any order; unknown flags are an error so typos
//! surface instead of silently using defaults.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `known_flags` lists accepted
    /// `--key` names; each either takes a value or is a boolean switch.
    pub fn parse(
        raw: &[String],
        known_flags: &[(&str, bool)], // (name, takes_value)
    ) -> Result<Args, String> {
        let mut it = raw.iter().peekable();
        let subcommand = it
            .next()
            .cloned()
            .ok_or_else(|| "missing subcommand".to_string())?;
        if subcommand.starts_with("--") {
            return Err(format!("expected subcommand, got flag `{subcommand}`"));
        }
        let mut flags = BTreeMap::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(format!("unexpected positional `{arg}`"));
            };
            let Some(&(_, takes_value)) =
                known_flags.iter().find(|(k, _)| *k == name)
            else {
                return Err(format!("unknown flag `--{name}`"));
            };
            let value = if takes_value {
                it.next()
                    .cloned()
                    .ok_or_else(|| format!("flag `--{name}` needs a value"))?
            } else {
                "true".to_string()
            };
            if flags.insert(name.to_string(), value).is_some() {
                return Err(format!("duplicate flag `--{name}`"));
            }
        }
        Ok(Args { subcommand, flags })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Shared parse-or-default for integer-valued flags.
    fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag `--{name}` expects an integer, got `{v}`")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_parsed(name, default)
    }

    /// Like [`Args::get_usize`] for u64-valued flags (evaluation budgets,
    /// cache sizes) where a platform-width integer would be wrong.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        self.get_parsed(name, default)
    }

    /// Float-valued flags (durations, rate multipliers). Rust's float
    /// parser happily accepts `nan` and `inf`, which no flag describing a
    /// physical quantity wants, so non-finite values are rejected here
    /// alongside garbage — callers still add their own range checks
    /// (positivity, bounds).
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse::<f64>() {
                Ok(x) if x.is_finite() => Ok(x),
                _ => Err(format!("flag `--{name}` expects a finite number, got `{v}`")),
            },
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Closed-set string flag: the value must be one of `variants`
    /// (`default` is returned when the flag is absent). Rejections carry
    /// the full variant list and, for near-misses, a did-you-mean hint —
    /// one uniform error shape for every enum-like flag (`--partition`,
    /// `--arrivals`, `--bandwidth`, `--router`, ...).
    pub fn get_enum<'a>(
        &'a self,
        name: &str,
        default: &'a str,
        variants: &[&str],
    ) -> Result<&'a str, String> {
        let v = self.get_or(name, default);
        if variants.contains(&v) {
            return Ok(v);
        }
        let mut msg = format!("unknown {name} `{v}` (known: {})", variants.join(", "));
        if let Some(hint) = suggest(v, variants) {
            msg.push_str(&format!("; did you mean `{hint}`?"));
        }
        Err(msg)
    }

    /// Path-valued flag (output files, cache files). Today a thin typed
    /// wrapper over [`Args::get`]; it exists so every artifact path flows
    /// through one accessor that can later grow validation.
    pub fn get_path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.get(name).map(std::path::PathBuf::from)
    }
}

/// Nearest variant within Levenshtein distance 2 (ties break to the
/// first-listed variant), for did-you-mean errors. `None` when everything
/// is too far away — a hint worse than no hint.
pub fn suggest<'a>(input: &str, variants: &'a [&'a str]) -> Option<&'a str> {
    variants
        .iter()
        .map(|v| (levenshtein(input, v), *v))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, v)| v)
}

/// Classic two-row edit distance; inputs here are short flag values, so
/// the O(|a|·|b|) cost is irrelevant.
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    const FLAGS: &[(&str, bool)] = &[("out", true), ("workers", true), ("verbose", false)];

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&s(&["e2e", "--out", "reports", "--verbose"]), FLAGS).unwrap();
        assert_eq!(a.subcommand, "e2e");
        assert_eq!(a.get("out"), Some("reports"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("workers", 4).unwrap(), 4);
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(Args::parse(&s(&["e2e", "--nope"]), FLAGS).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(Args::parse(&s(&["e2e", "--out"]), FLAGS).is_err());
    }

    #[test]
    fn rejects_duplicate() {
        assert!(Args::parse(&s(&["e2e", "--out", "a", "--out", "b"]), FLAGS).is_err());
    }

    #[test]
    fn rejects_missing_subcommand() {
        assert!(Args::parse(&s(&[]), FLAGS).is_err());
        assert!(Args::parse(&s(&["--out", "x"]), FLAGS).is_err());
    }

    #[test]
    fn bad_integer_flag() {
        let a = Args::parse(&s(&["e2e", "--workers", "many"]), FLAGS).unwrap();
        assert!(a.get_usize("workers", 1).is_err());
        assert!(a.get_u64("workers", 1).is_err());
    }

    #[test]
    fn u64_flag_parses_and_defaults() {
        let a = Args::parse(&s(&["e2e", "--workers", "4096"]), FLAGS).unwrap();
        assert_eq!(a.get_u64("workers", 7).unwrap(), 4096);
        assert_eq!(a.get_u64("out", 7).unwrap(), 7);
    }

    #[test]
    fn f64_flag_parses_and_defaults() {
        let a = Args::parse(&s(&["serve", "--workers", "2.5"]), FLAGS).unwrap();
        assert_eq!(a.get_f64("workers", 1.0).unwrap(), 2.5);
        // Plain integers parse as floats too; absent flags take the default.
        let a = Args::parse(&s(&["serve", "--workers", "3"]), FLAGS).unwrap();
        assert_eq!(a.get_f64("workers", 1.0).unwrap(), 3.0);
        assert_eq!(a.get_f64("out", 0.25).unwrap(), 0.25);
    }

    #[test]
    fn f64_flag_rejects_garbage_and_duplicates() {
        let a = Args::parse(&s(&["serve", "--workers", "fast"]), FLAGS).unwrap();
        let err = a.get_f64("workers", 1.0).unwrap_err();
        assert!(err.contains("expects a finite number"), "{err}");
        // `f64::from_str` accepts "nan"/"inf"; the flag parser must not.
        for bad in ["nan", "NaN", "inf", "-inf", "infinity"] {
            let a = Args::parse(&s(&["serve", "--workers", bad]), FLAGS).unwrap();
            assert!(a.get_f64("workers", 1.0).is_err(), "{bad} must be rejected");
        }
        // Duplicate float flags are rejected at parse time like any other.
        assert!(
            Args::parse(&s(&["serve", "--workers", "1.0", "--workers", "2.0"]), FLAGS).is_err()
        );
    }

    #[test]
    fn enum_flag_accepts_variants_and_defaults() {
        let a = Args::parse(&s(&["serve", "--out", "static"]), FLAGS).unwrap();
        assert_eq!(a.get_enum("out", "dynamic", &["dynamic", "static"]).unwrap(), "static");
        // Absent flag -> default, even when the default is not itself
        // checked against the variant list (callers own their defaults).
        assert_eq!(a.get_enum("workers", "dynamic", &["dynamic", "static"]).unwrap(), "dynamic");
    }

    #[test]
    fn enum_flag_rejects_with_did_you_mean() {
        let a = Args::parse(&s(&["serve", "--out", "sttic"]), FLAGS).unwrap();
        let err = a.get_enum("out", "dynamic", &["dynamic", "static"]).unwrap_err();
        assert!(err.contains("unknown out `sttic`"), "{err}");
        assert!(err.contains("known: dynamic, static"), "{err}");
        assert!(err.contains("did you mean `static`?"), "{err}");
        // Far-off garbage gets the list but no misleading hint.
        let a = Args::parse(&s(&["serve", "--out", "zzzzzzz"]), FLAGS).unwrap();
        let err = a.get_enum("out", "dynamic", &["dynamic", "static"]).unwrap_err();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn suggest_picks_nearest_within_two_edits() {
        assert_eq!(suggest("bands", &["bands", "guillotine"]), Some("bands"));
        assert_eq!(suggest("band", &["bands", "guillotine"]), Some("bands"));
        assert_eq!(suggest("guilotine", &["bands", "guillotine"]), Some("guillotine"));
        assert_eq!(suggest("xyzzy", &["bands", "guillotine"]), None);
    }

    #[test]
    fn path_flag_wraps_get() {
        let a = Args::parse(&s(&["serve", "--out", "reports/x.json"]), FLAGS).unwrap();
        assert_eq!(a.get_path("out"), Some(std::path::PathBuf::from("reports/x.json")));
        assert_eq!(a.get_path("workers"), None);
    }
}
