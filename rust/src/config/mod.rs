//! Architecture + run configuration (the paper's Table III), plus a small
//! `key = value` config-file parser so experiments are reproducible from
//! checked-in config files rather than CLI flags alone.

mod parse;

pub use parse::{parse_kv, ConfigError};

use crate::util::json::Json;

/// NoC topology selector (Sec. IV-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    /// Conventional 2-D mesh.
    Mesh,
    /// Augmented Mesh for Pipelining: mesh + express links of length
    /// `round(sqrt(rows/2))` in each direction at every PE.
    Amp,
    /// Flattened butterfly (all-to-all per row/column) — the "overkill"
    /// comparison point with O(N log N) links.
    FlattenedButterfly,
    /// Torus (wraparound mesh) — ablation topology.
    Torus,
}

impl TopologyKind {
    pub fn name(self) -> &'static str {
        match self {
            TopologyKind::Mesh => "mesh",
            TopologyKind::Amp => "amp",
            TopologyKind::FlattenedButterfly => "flattened_butterfly",
            TopologyKind::Torus => "torus",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "mesh" => Some(TopologyKind::Mesh),
            "amp" => Some(TopologyKind::Amp),
            "flattened_butterfly" | "fb" => Some(TopologyKind::FlattenedButterfly),
            "torus" => Some(TopologyKind::Torus),
            _ => None,
        }
    }
}

/// Accelerator architecture parameters. Defaults reproduce Table III.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// PE array rows (Table III: 32).
    pub pe_rows: usize,
    /// PE array columns (Table III: 32).
    pub pe_cols: usize,
    /// Multiply-accumulate lanes per PE per cycle (Table III: dot product 8).
    pub pe_dot_product: usize,
    /// Bytes per tensor word (Table III: 1 B / 8-bit).
    pub bytes_per_word: usize,
    /// On-chip global buffer (SRAM) capacity in bytes (Table III: 1 MB).
    pub sram_bytes: u64,
    /// Per-PE register file capacity in bytes. The paper compares granularity
    /// against "total register file size"; Eyeriss-class PEs carry ~0.5 KB.
    pub rf_bytes_per_pe: u64,
    /// Off-chip memory bandwidth in bytes/cycle. Table III gives 256 GB/s;
    /// at a nominal 1 GHz clock that is 256 B/cycle.
    pub dram_bytes_per_cycle: f64,
    /// NoC link bandwidth in words per cycle per link.
    pub link_words_per_cycle: f64,
    /// NoC topology.
    pub topology: TopologyKind,
    /// Clock frequency (Hz), used only to convert Table III GB/s → B/cycle
    /// and to report absolute times.
    pub clock_hz: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            pe_rows: 32,
            pe_cols: 32,
            pe_dot_product: 8,
            bytes_per_word: 1,
            sram_bytes: 1 << 20,       // 1 MB
            rf_bytes_per_pe: 512,      // 0.5 KB/PE → 512 KB array-total RF
            dram_bytes_per_cycle: 256.0, // 256 GB/s @ 1 GHz
            link_words_per_cycle: 1.0,
            topology: TopologyKind::Mesh,
            clock_hz: 1.0e9,
        }
    }
}

impl ArchConfig {
    /// Table III defaults on the AMP topology.
    pub fn amp() -> Self {
        Self {
            topology: TopologyKind::Amp,
            ..Self::default()
        }
    }

    pub fn num_pes(&self) -> usize {
        self.pe_rows * self.pe_cols
    }

    /// Maximum pipeline depth considered by stage 1 (Sec. IV-A):
    /// `sqrt(numPEs)`.
    pub fn max_pipeline_depth(&self) -> usize {
        (self.num_pes() as f64).sqrt().floor() as usize
    }

    /// Peak MACs per cycle over the whole array.
    pub fn peak_macs_per_cycle(&self) -> u64 {
        (self.num_pes() * self.pe_dot_product) as u64
    }

    /// Array-total register file bytes (granularity threshold, Sec. IV-B).
    pub fn rf_total_bytes(&self) -> u64 {
        self.rf_bytes_per_pe * self.num_pes() as u64
    }

    /// Build from `key = value` text (see [`parse_kv`]); unknown keys error.
    pub fn from_kv_text(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Self::default();
        for (k, v, line) in parse_kv(text)? {
            let bad = |why: &str| ConfigError::BadValue {
                line,
                key: k.clone(),
                why: why.to_string(),
            };
            match k.as_str() {
                "pe_rows" => cfg.pe_rows = v.parse().map_err(|_| bad("expected usize"))?,
                "pe_cols" => cfg.pe_cols = v.parse().map_err(|_| bad("expected usize"))?,
                "pe_dot_product" => {
                    cfg.pe_dot_product = v.parse().map_err(|_| bad("expected usize"))?
                }
                "bytes_per_word" => {
                    cfg.bytes_per_word = v.parse().map_err(|_| bad("expected usize"))?
                }
                "sram_bytes" => cfg.sram_bytes = v.parse().map_err(|_| bad("expected u64"))?,
                "rf_bytes_per_pe" => {
                    cfg.rf_bytes_per_pe = v.parse().map_err(|_| bad("expected u64"))?
                }
                "dram_bytes_per_cycle" => {
                    cfg.dram_bytes_per_cycle = v.parse().map_err(|_| bad("expected f64"))?
                }
                "link_words_per_cycle" => {
                    cfg.link_words_per_cycle = v.parse().map_err(|_| bad("expected f64"))?
                }
                "clock_hz" => cfg.clock_hz = v.parse().map_err(|_| bad("expected f64"))?,
                "topology" => {
                    cfg.topology =
                        TopologyKind::from_name(&v).ok_or_else(|| bad("unknown topology"))?
                }
                _ => {
                    return Err(ConfigError::UnknownKey { line, key: k });
                }
            }
        }
        cfg.validate().map_err(|why| ConfigError::BadValue {
            line: 0,
            key: "<config>".into(),
            why,
        })?;
        Ok(cfg)
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.pe_rows == 0 || self.pe_cols == 0 {
            return Err("PE array must be non-empty".into());
        }
        if self.pe_dot_product == 0 {
            return Err("pe_dot_product must be > 0".into());
        }
        if self.bytes_per_word == 0 {
            return Err("bytes_per_word must be > 0".into());
        }
        if self.dram_bytes_per_cycle <= 0.0 {
            return Err("dram_bytes_per_cycle must be > 0".into());
        }
        if self.link_words_per_cycle <= 0.0 {
            return Err("link_words_per_cycle must be > 0".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("pe_rows", self.pe_rows)
            .set("pe_cols", self.pe_cols)
            .set("pe_dot_product", self.pe_dot_product)
            .set("bytes_per_word", self.bytes_per_word)
            .set("sram_bytes", self.sram_bytes)
            .set("rf_bytes_per_pe", self.rf_bytes_per_pe)
            .set("dram_bytes_per_cycle", self.dram_bytes_per_cycle)
            .set("link_words_per_cycle", self.link_words_per_cycle)
            .set("topology", self.topology.name())
            .set("clock_hz", self.clock_hz);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_defaults() {
        let c = ArchConfig::default();
        assert_eq!(c.pe_rows, 32);
        assert_eq!(c.pe_cols, 32);
        assert_eq!(c.num_pes(), 1024);
        assert_eq!(c.pe_dot_product, 8);
        assert_eq!(c.sram_bytes, 1 << 20);
        assert_eq!(c.bytes_per_word, 1);
        assert_eq!(c.max_pipeline_depth(), 32);
        assert_eq!(c.peak_macs_per_cycle(), 8192);
    }

    #[test]
    fn kv_roundtrip_overrides() {
        let cfg = ArchConfig::from_kv_text(
            "# comment\npe_rows = 16\npe_cols=16\ntopology = amp\n\nsram_bytes = 524288\n",
        )
        .unwrap();
        assert_eq!(cfg.pe_rows, 16);
        assert_eq!(cfg.topology, TopologyKind::Amp);
        assert_eq!(cfg.sram_bytes, 524288);
        // untouched defaults survive
        assert_eq!(cfg.pe_dot_product, 8);
    }

    #[test]
    fn unknown_key_rejected() {
        let e = ArchConfig::from_kv_text("nope = 3").unwrap_err();
        assert!(matches!(e, ConfigError::UnknownKey { .. }));
    }

    #[test]
    fn bad_value_rejected() {
        let e = ArchConfig::from_kv_text("pe_rows = banana").unwrap_err();
        assert!(matches!(e, ConfigError::BadValue { .. }));
    }

    #[test]
    fn zero_rows_invalid() {
        assert!(ArchConfig::from_kv_text("pe_rows = 0").is_err());
    }

    #[test]
    fn topology_names_roundtrip() {
        for t in [
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::FlattenedButterfly,
            TopologyKind::Torus,
        ] {
            assert_eq!(TopologyKind::from_name(t.name()), Some(t));
        }
        assert_eq!(TopologyKind::from_name("bogus"), None);
    }
}
