//! `key = value` config-file syntax: one assignment per line, `#` comments,
//! blank lines ignored. (serde/toml substitute — see DESIGN.md §2.)

#[derive(Debug)]
pub enum ConfigError {
    Syntax { line: usize, text: String },
    UnknownKey { line: usize, key: String },
    /// The same key assigned twice — silently keeping the last value hides
    /// config mistakes, so it is rejected like the CLI's duplicate flags.
    DuplicateKey { line: usize, key: String },
    BadValue {
        line: usize,
        key: String,
        why: String,
    },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Syntax { line, text } => {
                write!(f, "line {line}: expected `key = value`, got `{text}`")
            }
            ConfigError::UnknownKey { line, key } => {
                write!(f, "line {line}: unknown key `{key}`")
            }
            ConfigError::DuplicateKey { line, key } => {
                write!(f, "line {line}: duplicate key `{key}`")
            }
            ConfigError::BadValue { line, key, why } => {
                write!(f, "line {line}: bad value for `{key}`: {why}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Parse to `(key, value, line_number)` triples; values keep inner spaces
/// but are trimmed at the ends. Inline `#` comments are stripped.
pub fn parse_kv(text: &str) -> Result<Vec<(String, String, usize)>, ConfigError> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = match raw.find('#') {
            Some(pos) => &raw[..pos],
            None => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(ConfigError::Syntax {
                line: line_no,
                text: raw.to_string(),
            });
        };
        let key = line[..eq].trim();
        let val = line[eq + 1..].trim();
        if key.is_empty() || val.is_empty() {
            return Err(ConfigError::Syntax {
                line: line_no,
                text: raw.to_string(),
            });
        }
        if out.iter().any(|(k, _, _)| k == key) {
            return Err(ConfigError::DuplicateKey {
                line: line_no,
                key: key.to_string(),
            });
        }
        out.push((key.to_string(), val.to_string(), line_no));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_and_blanks() {
        let kv = parse_kv("# header\n\na = 1\nb = two words # trailing\n").unwrap();
        assert_eq!(
            kv,
            vec![
                ("a".into(), "1".into(), 3),
                ("b".into(), "two words".into(), 4)
            ]
        );
    }

    #[test]
    fn rejects_missing_equals() {
        assert!(parse_kv("just text").is_err());
    }

    #[test]
    fn rejects_empty_value() {
        assert!(parse_kv("a =").is_err());
        assert!(parse_kv("= 3").is_err());
    }

    #[test]
    fn rejects_duplicate_key() {
        let err = parse_kv("a = 1\nb = 2\na = 3\n").unwrap_err();
        assert!(
            matches!(err, ConfigError::DuplicateKey { line: 3, ref key } if key == "a"),
            "{err:?}"
        );
    }
}
