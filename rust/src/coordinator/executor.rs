//! Functional pipelined executor (E15): execute a real depth-2 conv
//! segment three ways through PJRT and check they agree numerically —
//!
//! 1. **op-by-op**: layer 0 over the whole feature map, write back, layer 1
//!    over the whole intermediate (the Fig. 1 baseline);
//! 2. **fused**: the single AOT program whose intermediate band lives in
//!    VMEM (the Pallas `fused_segment` kernel);
//! 3. **pipelined**: two stage *threads*, one per layer, streaming
//!    row-band tiles through a bounded channel — a faithful software
//!    realization of the paper's pipeline intervals: stage 1 consumes tile
//!    `t` while stage 0 produces tile `t+1`. The bounded channel plays the
//!    role of the register files; the one-band skew is the halo the
//!    consumer needs from the next producer tile.
//!
//! Each stage thread owns its own PJRT client and compiled program (PJRT
//! handles are not `Send` in the `xla` crate).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::{Runtime, SegmentSpec};
use crate::util::rng::SplitMix64;

/// Input + weights for the canonical segment, matching the AOT manifest.
#[derive(Debug, Clone)]
pub struct SegmentData {
    pub spec: SegmentSpec,
    /// [H, W, C_IN] row-major.
    pub x: Vec<f32>,
    /// [R, S, C_IN, C_MID].
    pub w1: Vec<f32>,
    /// [R, S, C_MID, C_OUT].
    pub w2: Vec<f32>,
}

impl SegmentData {
    /// Deterministic pseudo-random segment data for a manifest spec.
    pub fn random(spec: SegmentSpec, seed: u64) -> SegmentData {
        let mut rng = SplitMix64::new(seed);
        let mut gen = |n: usize, scale: f32| -> Vec<f32> {
            (0..n)
                .map(|_| (rng.gen_f64() as f32 - 0.5) * 2.0 * scale)
                .collect()
        };
        let x = gen(spec.h * spec.w * spec.c_in, 1.0);
        let w1 = gen(spec.r * spec.s * spec.c_in * spec.c_mid, 0.2);
        let w2 = gen(spec.r * spec.s * spec.c_mid * spec.c_out, 0.2);
        SegmentData { spec, x, w1, w2 }
    }
}

/// Result of one execution mode.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub mode: &'static str,
    /// [H, W, C_OUT] row-major.
    pub output: Vec<f32>,
    pub elapsed: Duration,
    /// Pipeline intervals executed (1 for whole-tensor modes).
    pub tiles: usize,
}

/// Zero-pad an [h, w, c] tensor by `pr` rows and `ps` cols on each side.
fn pad_hw(x: &[f32], h: usize, w: usize, c: usize, pr: usize, ps: usize) -> Vec<f32> {
    let (hp, wp) = (h + 2 * pr, w + 2 * ps);
    let mut out = vec![0f32; hp * wp * c];
    for r in 0..h {
        for col in 0..w {
            let src = (r * w + col) * c;
            let dst = ((r + pr) * wp + (col + ps)) * c;
            out[dst..dst + c].copy_from_slice(&x[src..src + c]);
        }
    }
    out
}

/// Extract rows [r0, r0+rows) of a padded [hp, wp, c] tensor.
fn slab(xp: &[f32], wp: usize, c: usize, r0: usize, rows: usize) -> Vec<f32> {
    let start = r0 * wp * c;
    xp[start..start + rows * wp * c].to_vec()
}

/// Mode 1: op-by-op (whole layers, intermediate round-trips host memory).
pub fn run_op_by_op(artifacts_dir: &str, data: &SegmentData) -> Result<ExecReport> {
    let rt = Runtime::new(artifacts_dir)?;
    let l0 = rt.load_program("layer0")?;
    let l1 = rt.load_program("layer1")?;
    let t0 = Instant::now();
    let mid = l0.run_f32(&[&data.x, &data.w1])?;
    let out = l1.run_f32(&[&mid, &data.w2])?;
    Ok(ExecReport {
        mode: "op_by_op",
        output: out,
        elapsed: t0.elapsed(),
        tiles: 1,
    })
}

/// Mode 2: fused single program (VMEM-resident intermediate).
pub fn run_fused(artifacts_dir: &str, data: &SegmentData) -> Result<ExecReport> {
    let rt = Runtime::new(artifacts_dir)?;
    let prog = rt.load_program("segment_fused")?;
    let t0 = Instant::now();
    let out = prog.run_f32(&[&data.x, &data.w1, &data.w2])?;
    Ok(ExecReport {
        mode: "fused",
        output: out,
        elapsed: t0.elapsed(),
        tiles: 1,
    })
}

/// Mode 3: two-stage threaded pipeline over row-band tiles.
pub fn run_pipelined(artifacts_dir: &str, data: &SegmentData) -> Result<ExecReport> {
    let spec = data.spec;
    let tiles = spec.h / spec.band;
    anyhow::ensure!(spec.h % spec.band == 0, "band must divide H");
    let halo = spec.r / 2;
    let dir0 = artifacts_dir.to_string();
    let dir1 = artifacts_dir.to_string();
    // Bounded channel = the register-file budget between the stages: at
    // most 2 in-flight bands (double buffering).
    let (tx, rx) = mpsc::sync_channel::<(usize, Vec<f32>)>(2);

    let t0 = Instant::now();
    let producer = {
        let xp = pad_hw(&data.x, spec.h, spec.w, spec.c_in, halo, spec.s / 2);
        let w1 = data.w1.clone();
        let wp = spec.w + 2 * (spec.s / 2);
        let c = spec.c_in;
        let band = spec.band;
        let slab_rows = band + spec.r - 1;
        std::thread::spawn(move || -> Result<()> {
            let rt = Runtime::new(&dir0)?;
            let prog = rt.load_program("tile_layer0")?;
            for t in 0..tiles {
                let s = slab(&xp, wp, c, t * band, slab_rows);
                let out = prog.run_f32(&[&s, &w1])?;
                tx.send((t, out)).context("consumer hung up")?;
            }
            Ok(())
        })
    };

    let consumer = {
        let w2 = data.w2.clone();
        std::thread::spawn(move || -> Result<Vec<f32>> {
            let rt = Runtime::new(&dir1)?;
            let prog = rt.load_program("tile_layer1")?;
            let band = spec.band;
            let ps = spec.s / 2;
            let wp = spec.w + 2 * ps;
            let c = spec.c_mid;
            // Padded intermediate assembled band by band as tiles arrive.
            let hp = spec.h + 2 * halo;
            let mut midp = vec![0f32; hp * wp * c];
            let mut out = vec![0f32; spec.h * spec.w * spec.c_out];
            let mut received = 0usize;
            let emit = |j: usize, midp: &[f32], out: &mut Vec<f32>| -> Result<()> {
                let s = slab(midp, wp, c, j * band, band + spec.r - 1);
                let o = prog.run_f32(&[&s, &w2])?;
                let dst = j * band * spec.w * spec.c_out;
                out[dst..dst + o.len()].copy_from_slice(&o);
                Ok(())
            };
            for (t, tile) in rx.iter() {
                // Place tile rows [t*band, t*band+band) at padded offset.
                for r in 0..band {
                    for col in 0..spec.w {
                        let src = (r * spec.w + col) * c;
                        let dst = ((t * band + r + halo) * wp + (col + ps)) * c;
                        midp[dst..dst + c].copy_from_slice(&tile[src..src + c]);
                    }
                }
                received += 1;
                // Band j is ready once its bottom halo exists: after tile
                // j+1 lands (pipeline skew of one interval).
                if t >= 1 {
                    emit(t - 1, &midp, &mut out)?;
                }
            }
            anyhow::ensure!(received == tiles, "missing tiles");
            emit(tiles - 1, &midp, &mut out)?; // bottom edge: zero halo
            Ok(out)
        })
    };

    producer
        .join()
        .map_err(|_| anyhow::anyhow!("producer panicked"))??;
    let out = consumer
        .join()
        .map_err(|_| anyhow::anyhow!("consumer panicked"))??;
    Ok(ExecReport {
        mode: "pipelined",
        output: out,
        elapsed: t0.elapsed(),
        tiles,
    })
}

// ---------------------------------------------------------------------------
// Sessions (§Perf opt. 3): compile once, serve many requests. The one-shot
// `run_*` functions above pay PJRT client creation + compilation per call
// (~250 ms on this CPU); a session keeps the compiled programs — and for the
// pipelined mode the two stage threads — alive across requests.
// ---------------------------------------------------------------------------

/// Op-by-op session: both layer programs compiled once.
pub struct OpByOpSession {
    l0: crate::runtime::Program,
    l1: crate::runtime::Program,
}

impl OpByOpSession {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        Ok(Self {
            l0: rt.load_program("layer0")?,
            l1: rt.load_program("layer1")?,
        })
    }

    pub fn run(&self, data: &SegmentData) -> Result<ExecReport> {
        let t0 = Instant::now();
        let mid = self.l0.run_f32(&[&data.x, &data.w1])?;
        let out = self.l1.run_f32(&[&mid, &data.w2])?;
        Ok(ExecReport {
            mode: "op_by_op",
            output: out,
            elapsed: t0.elapsed(),
            tiles: 1,
        })
    }
}

/// Fused session.
pub struct FusedSession {
    prog: crate::runtime::Program,
}

impl FusedSession {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        Ok(Self {
            prog: rt.load_program("segment_fused")?,
        })
    }

    pub fn run(&self, data: &SegmentData) -> Result<ExecReport> {
        let t0 = Instant::now();
        let out = self.prog.run_f32(&[&data.x, &data.w1, &data.w2])?;
        Ok(ExecReport {
            mode: "fused",
            output: out,
            elapsed: t0.elapsed(),
            tiles: 1,
        })
    }
}

/// Persistent two-stage pipeline: stage threads (each owning its PJRT
/// client + compiled tile program) live for the session and serve a stream
/// of requests.
pub struct PipelinedSession {
    spec: crate::runtime::SegmentSpec,
    to_producer: mpsc::SyncSender<(Vec<f32>, Vec<f32>)>, // (padded x, w1)
    to_consumer: mpsc::SyncSender<Vec<f32>>,             // w2
    from_consumer: mpsc::Receiver<Result<Vec<f32>>>,
    producer: Option<std::thread::JoinHandle<()>>,
    consumer: Option<std::thread::JoinHandle<()>>,
}

impl PipelinedSession {
    pub fn new(artifacts_dir: &str, spec: crate::runtime::SegmentSpec) -> Result<Self> {
        anyhow::ensure!(spec.h % spec.band == 0, "band must divide H");
        let tiles = spec.h / spec.band;
        let halo = spec.r / 2;
        let (req_p_tx, req_p_rx) = mpsc::sync_channel::<(Vec<f32>, Vec<f32>)>(1);
        let (req_c_tx, req_c_rx) = mpsc::sync_channel::<Vec<f32>>(1);
        let (tile_tx, tile_rx) = mpsc::sync_channel::<(usize, Vec<f32>)>(2);
        let (out_tx, out_rx) = mpsc::channel::<Result<Vec<f32>>>();

        let dir0 = artifacts_dir.to_string();
        let producer = std::thread::spawn(move || {
            let run = || -> Result<()> {
                let rt = Runtime::new(&dir0)?;
                let prog = rt.load_program("tile_layer0")?;
                let wp = spec.w + 2 * (spec.s / 2);
                let slab_rows = spec.band + spec.r - 1;
                while let Ok((xp, w1)) = req_p_rx.recv() {
                    for t in 0..tiles {
                        let s = slab(&xp, wp, spec.c_in, t * spec.band, slab_rows);
                        let out = prog.run_f32(&[&s, &w1])?;
                        tile_tx.send((t, out)).context("consumer hung up")?;
                    }
                }
                Ok(())
            };
            if let Err(e) = run() {
                log::error!("pipeline producer failed: {e:#}");
            }
        });

        let dir1 = artifacts_dir.to_string();
        let consumer = std::thread::spawn(move || {
            let run = || -> Result<()> {
                let rt = Runtime::new(&dir1)?;
                let prog = rt.load_program("tile_layer1")?;
                let band = spec.band;
                let ps = spec.s / 2;
                let wp = spec.w + 2 * ps;
                let c = spec.c_mid;
                let hp = spec.h + 2 * halo;
                while let Ok(w2) = req_c_rx.recv() {
                    let mut midp = vec![0f32; hp * wp * c];
                    let mut out = vec![0f32; spec.h * spec.w * spec.c_out];
                    let emit = |j: usize, midp: &[f32], out: &mut Vec<f32>| -> Result<()> {
                        let s = slab(midp, wp, c, j * band, band + spec.r - 1);
                        let o = prog.run_f32(&[&s, &w2])?;
                        let dst = j * band * spec.w * spec.c_out;
                        out[dst..dst + o.len()].copy_from_slice(&o);
                        Ok(())
                    };
                    for _ in 0..tiles {
                        let (t, tile) = tile_rx.recv().context("producer hung up")?;
                        for r in 0..band {
                            for col in 0..spec.w {
                                let src = (r * spec.w + col) * c;
                                let dst = ((t * band + r + halo) * wp + (col + ps)) * c;
                                midp[dst..dst + c].copy_from_slice(&tile[src..src + c]);
                            }
                        }
                        if t >= 1 {
                            emit(t - 1, &midp, &mut out)?;
                        }
                    }
                    emit(tiles - 1, &midp, &mut out)?;
                    out_tx.send(Ok(out)).ok();
                }
                Ok(())
            };
            if let Err(e) = run() {
                log::error!("pipeline consumer failed: {e:#}");
            }
        });

        Ok(Self {
            spec,
            to_producer: req_p_tx,
            to_consumer: req_c_tx,
            from_consumer: out_rx,
            producer: Some(producer),
            consumer: Some(consumer),
        })
    }

    /// Run one request through the resident pipeline.
    pub fn run(&self, data: &SegmentData) -> Result<ExecReport> {
        let spec = self.spec;
        let t0 = Instant::now();
        let xp = pad_hw(&data.x, spec.h, spec.w, spec.c_in, spec.r / 2, spec.s / 2);
        self.to_consumer
            .send(data.w2.clone())
            .map_err(|_| anyhow::anyhow!("consumer thread gone"))?;
        self.to_producer
            .send((xp, data.w1.clone()))
            .map_err(|_| anyhow::anyhow!("producer thread gone"))?;
        let out = self
            .from_consumer
            .recv()
            .map_err(|_| anyhow::anyhow!("pipeline died mid-request"))??;
        Ok(ExecReport {
            mode: "pipelined",
            output: out,
            elapsed: t0.elapsed(),
            tiles: spec.h / spec.band,
        })
    }
}

impl Drop for PipelinedSession {
    fn drop(&mut self) {
        // Closing the request channels lets both threads exit their loops.
        let (a, b) = (
            std::mem::replace(&mut self.to_producer, mpsc::sync_channel(1).0),
            std::mem::replace(&mut self.to_consumer, mpsc::sync_channel(1).0),
        );
        drop(a);
        drop(b);
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
        if let Some(h) = self.consumer.take() {
            let _ = h.join();
        }
    }
}

/// Max |a-b| between two outputs; errors on length mismatch.
pub fn compare_outputs(a: &ExecReport, b: &ExecReport) -> Result<f64> {
    anyhow::ensure!(
        a.output.len() == b.output.len(),
        "{} vs {}: size {} vs {}",
        a.mode,
        b.mode,
        a.output.len(),
        b.output.len()
    );
    Ok(a.output
        .iter()
        .zip(&b.output)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0, f64::max))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_hw_places_rows() {
        // 2x2x1 tensor padded by 1 → 4x4x1 with the block centered.
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let p = pad_hw(&x, 2, 2, 1, 1, 1);
        assert_eq!(p.len(), 16);
        assert_eq!(p[5], 1.0); // (1,1)
        assert_eq!(p[6], 2.0);
        assert_eq!(p[9], 3.0);
        assert_eq!(p[10], 4.0);
        assert_eq!(p[0], 0.0);
    }

    #[test]
    fn slab_extracts_rows() {
        let xp: Vec<f32> = (0..24).map(|i| i as f32).collect(); // 4 rows x 3 cols x 2c
        let s = slab(&xp, 3, 2, 1, 2);
        assert_eq!(s, (6..18).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn segment_data_deterministic() {
        let spec = SegmentSpec {
            h: 8,
            w: 8,
            c_in: 2,
            c_mid: 4,
            c_out: 2,
            band: 4,
            r: 3,
            s: 3,
        };
        let a = SegmentData::random(spec, 7);
        let b = SegmentData::random(spec, 7);
        assert_eq!(a.x, b.x);
        assert_ne!(a.x, SegmentData::random(spec, 8).x);
    }
}
