//! Parallel evaluation coordinator: fan a set of (task × mapper) simulation
//! jobs over worker threads. Used by the CLI `e2e` path and the Fig. 13/14
//! benches to sweep the whole zoo quickly.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{evaluate, Mapper, ModelCost};
use crate::dse::EvalCache;
use crate::ir::ModelGraph;

/// Which mapper to run (the trait objects themselves are not `Send`-bound
/// cheaply, so jobs carry an enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapperKind {
    PipeOrgan,
    PipeOrganMesh,
    /// Search-guided `mapper::TunedPipeOrgan` (the `--tuned` e2e path).
    /// Hand [`run_jobs_with_cache`] a shared — ideally file-persistent —
    /// `EvalCache` so the whole sweep plans warm; without one, each job
    /// searches against a private cold cache.
    PipeOrganTuned,
    TangramLike,
    SimbaLike,
    PipeOrganOn(TopologyKind),
}

impl MapperKind {
    pub fn instantiate(self) -> Box<dyn Mapper> {
        self.instantiate_with(None)
    }

    /// Like [`MapperKind::instantiate`], with a shared evaluation cache for
    /// the tuned mapper (the closed-form mappers ignore it).
    pub fn instantiate_with(self, cache: Option<Arc<EvalCache>>) -> Box<dyn Mapper> {
        match self {
            MapperKind::PipeOrgan => Box::new(crate::mapper::PipeOrgan::default()),
            MapperKind::PipeOrganMesh => Box::new(crate::mapper::PipeOrgan::on_mesh()),
            MapperKind::PipeOrganTuned => {
                Box::new(crate::mapper::TunedPipeOrgan::new(cache.unwrap_or_default()))
            }
            MapperKind::TangramLike => Box::new(crate::baselines::TangramLike),
            MapperKind::SimbaLike => Box::new(crate::baselines::SimbaLike),
            MapperKind::PipeOrganOn(t) => Box::new(crate::mapper::PipeOrgan::on(t)),
        }
    }
}

/// One evaluation job.
#[derive(Clone)]
pub struct EvalJob {
    pub graph: Arc<ModelGraph>,
    pub mapper: MapperKind,
    pub cfg: ArchConfig,
}

/// Its outcome.
pub struct EvalOutcome {
    pub task: String,
    pub mapper_name: String,
    pub cost: ModelCost,
    pub mean_depth: f64,
}

/// Generic order-preserving worker pool: run every task through `f` on up
/// to `workers` scoped threads and return the results in task order.
///
/// This is the one thread-fanout primitive of the crate — the (task ×
/// mapper) sweep of [`run_jobs`], the per-topology searches of
/// `dse::explore`, and the per-level state expansion of the cosched
/// guillotine beam all ride on it, so parallel behavior (work stealing
/// off a shared queue, result reordering, panic propagation at scope
/// exit) stays identical everywhere. Order preservation is load-bearing
/// for the beam: results merge back positionally, which is what makes a
/// parallel beam run bit-identical to a single-threaded one (see
/// docs/PERFORMANCE.md).
pub fn run_queue<T, R, F>(tasks: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = tasks.len();
    if n == 0 {
        return Vec::new();
    }
    let queue = Mutex::new(tasks.into_iter().enumerate().collect::<Vec<_>>());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let workers = workers.max(1).min(n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let task = { queue.lock().unwrap().pop() };
                let Some((idx, task)) = task else { break };
                let _ = tx.send((idx, f(task)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (idx, r) in rx {
            out[idx] = Some(r);
        }
        out.into_iter().map(|o| o.expect("task lost")).collect()
    })
}

/// Run all jobs over `workers` threads (order of results matches jobs).
pub fn run_jobs(jobs: Vec<EvalJob>, workers: usize) -> Vec<EvalOutcome> {
    run_jobs_with_cache(jobs, workers, None)
}

/// [`run_jobs`] with a shared segment-evaluation cache for
/// [`MapperKind::PipeOrganTuned`] jobs: every tuned plan in the sweep memo-
/// shares (and, when the cache was hydrated via `EvalCache::load_file`,
/// inherits) segment costs instead of re-searching cold.
pub fn run_jobs_with_cache(
    jobs: Vec<EvalJob>,
    workers: usize,
    cache: Option<Arc<EvalCache>>,
) -> Vec<EvalOutcome> {
    run_queue(jobs, workers, move |job: EvalJob| {
        let mapper = job.mapper.instantiate_with(cache.clone());
        let plan = mapper.plan(&job.graph, &job.cfg);
        let cost = evaluate(&job.graph, &plan, &job.cfg);
        EvalOutcome {
            task: job.graph.name.clone(),
            mapper_name: plan.mapper_name.clone(),
            cost,
            mean_depth: plan.mean_depth(),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn parallel_matches_serial() {
        let cfg = ArchConfig::default();
        let g = Arc::new(workloads::keyword_detection());
        let jobs: Vec<EvalJob> = [MapperKind::PipeOrgan, MapperKind::TangramLike, MapperKind::SimbaLike]
            .into_iter()
            .map(|mapper| EvalJob {
                graph: Arc::clone(&g),
                mapper,
                cfg: cfg.clone(),
            })
            .collect();
        let par = run_jobs(jobs.clone(), 3);
        let ser = run_jobs(jobs, 1);
        assert_eq!(par.len(), 3);
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.mapper_name, s.mapper_name);
            assert_eq!(p.cost.cycles, s.cost.cycles);
            assert_eq!(p.cost.dram_words, s.cost.dram_words);
        }
    }

    #[test]
    fn run_queue_preserves_order_and_runs_everything() {
        let tasks: Vec<usize> = (0..37).collect();
        let out = run_queue(tasks, 5, |x| x * 2);
        assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<_>>());
        assert!(run_queue(Vec::<usize>::new(), 4, |x| x).is_empty());
        // degenerate worker counts clamp instead of hanging
        assert_eq!(run_queue(vec![1, 2], 0, |x| x + 1), vec![2, 3]);
    }

    #[test]
    fn run_queue_shares_state_through_sync_closures() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        let out = run_queue((0..100).collect::<Vec<usize>>(), 8, |x| {
            counter.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 100);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn tuned_jobs_share_the_cache_and_agree() {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let g = Arc::new(workloads::keyword_detection());
        let jobs: Vec<EvalJob> = (0..2)
            .map(|_| EvalJob {
                graph: Arc::clone(&g),
                mapper: MapperKind::PipeOrganTuned,
                cfg: cfg.clone(),
            })
            .collect();
        let cache = Arc::new(EvalCache::new());
        let out = run_jobs_with_cache(jobs, 2, Some(Arc::clone(&cache)));
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|o| o.mapper_name == crate::mapper::TUNED_MAPPER_NAME));
        assert_eq!(out[0].cost.cycles, out[1].cost.cycles);
        assert!(!cache.is_empty(), "tuned jobs must populate the shared cache");
    }

    #[test]
    fn results_keep_job_order() {
        let cfg = ArchConfig::default();
        let tasks = [
            workloads::keyword_detection(),
            workloads::gaze_estimation(),
        ];
        let jobs: Vec<EvalJob> = tasks
            .iter()
            .map(|g| EvalJob {
                graph: Arc::new(g.clone()),
                mapper: MapperKind::PipeOrgan,
                cfg: cfg.clone(),
            })
            .collect();
        let out = run_jobs(jobs, 4);
        assert_eq!(out[0].task, "keyword_detection");
        assert_eq!(out[1].task, "gaze_estimation");
    }
}
