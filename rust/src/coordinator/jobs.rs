//! Parallel evaluation coordinator: fan a set of (task × mapper) simulation
//! jobs over worker threads. Used by the CLI `e2e` path and the Fig. 13/14
//! benches to sweep the whole zoo quickly.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{evaluate, Mapper, ModelCost};
use crate::ir::ModelGraph;

/// Which mapper to run (the trait objects themselves are not `Send`-bound
/// cheaply, so jobs carry an enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapperKind {
    PipeOrgan,
    PipeOrganMesh,
    TangramLike,
    SimbaLike,
    PipeOrganOn(TopologyKind),
}

impl MapperKind {
    pub fn instantiate(self) -> Box<dyn Mapper> {
        match self {
            MapperKind::PipeOrgan => Box::new(crate::mapper::PipeOrgan::default()),
            MapperKind::PipeOrganMesh => Box::new(crate::mapper::PipeOrgan::on_mesh()),
            MapperKind::TangramLike => Box::new(crate::baselines::TangramLike),
            MapperKind::SimbaLike => Box::new(crate::baselines::SimbaLike),
            MapperKind::PipeOrganOn(t) => Box::new(crate::mapper::PipeOrgan::on(t)),
        }
    }
}

/// One evaluation job.
#[derive(Clone)]
pub struct EvalJob {
    pub graph: Arc<ModelGraph>,
    pub mapper: MapperKind,
    pub cfg: ArchConfig,
}

/// Its outcome.
pub struct EvalOutcome {
    pub task: String,
    pub mapper_name: String,
    pub cost: ModelCost,
    pub mean_depth: f64,
}

/// Run all jobs over `workers` threads (order of results matches jobs).
pub fn run_jobs(jobs: Vec<EvalJob>, workers: usize) -> Vec<EvalOutcome> {
    let n = jobs.len();
    let queue = Arc::new(Mutex::new(
        jobs.into_iter().enumerate().collect::<Vec<_>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, EvalOutcome)>();
    let workers = workers.max(1).min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            scope.spawn(move || loop {
                let job = { queue.lock().unwrap().pop() };
                let Some((idx, job)) = job else { break };
                let mapper = job.mapper.instantiate();
                let plan = mapper.plan(&job.graph, &job.cfg);
                let cost = evaluate(&job.graph, &plan, &job.cfg);
                let _ = tx.send((
                    idx,
                    EvalOutcome {
                        task: job.graph.name.clone(),
                        mapper_name: plan.mapper_name.clone(),
                        cost,
                        mean_depth: plan.mean_depth(),
                    },
                ));
            });
        }
        drop(tx);
        let mut out: Vec<Option<EvalOutcome>> = (0..n).map(|_| None).collect();
        for (idx, outcome) in rx {
            out[idx] = Some(outcome);
        }
        out.into_iter().map(|o| o.expect("job lost")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn parallel_matches_serial() {
        let cfg = ArchConfig::default();
        let g = Arc::new(workloads::keyword_detection());
        let jobs: Vec<EvalJob> = [MapperKind::PipeOrgan, MapperKind::TangramLike, MapperKind::SimbaLike]
            .into_iter()
            .map(|mapper| EvalJob {
                graph: Arc::clone(&g),
                mapper,
                cfg: cfg.clone(),
            })
            .collect();
        let par = run_jobs(jobs.clone(), 3);
        let ser = run_jobs(jobs, 1);
        assert_eq!(par.len(), 3);
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.mapper_name, s.mapper_name);
            assert_eq!(p.cost.cycles, s.cost.cycles);
            assert_eq!(p.cost.dram_words, s.cost.dram_words);
        }
    }

    #[test]
    fn results_keep_job_order() {
        let cfg = ArchConfig::default();
        let tasks = [
            workloads::keyword_detection(),
            workloads::gaze_estimation(),
        ];
        let jobs: Vec<EvalJob> = tasks
            .iter()
            .map(|g| EvalJob {
                graph: Arc::new(g.clone()),
                mapper: MapperKind::PipeOrgan,
                cfg: cfg.clone(),
            })
            .collect();
        let out = run_jobs(jobs, 4);
        assert_eq!(out[0].task, "keyword_detection");
        assert_eq!(out[1].task, "gaze_estimation");
    }
}
