//! L3 coordination: the multi-threaded evaluation job system and the
//! functional pipelined executor that drives AOT tile programs through
//! PJRT in producer/consumer pipeline order (E15 in DESIGN.md).

mod executor;
pub mod jobs;

pub use executor::{
    compare_outputs, run_fused, run_op_by_op, run_pipelined, ExecReport, FusedSession,
    OpByOpSession, PipelinedSession, SegmentData,
};
pub use jobs::{run_jobs, run_jobs_with_cache, run_queue, EvalJob, EvalOutcome, MapperKind};
