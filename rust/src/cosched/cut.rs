//! Guillotine cut trees: recursive 2-D partitioning of the PE array.
//!
//! A [`CutTree`] describes how one rectangle is split into per-task
//! regions by alternating (or repeated) horizontal and vertical guillotine
//! cuts — every cut runs edge to edge of its rectangle, so the leaves are
//! always non-overlapping rectangles that tile the parent exactly (no
//! gaps, no overlap, by construction). The 1-D vertical bands the
//! co-scheduler started with are the special case of a right-leaning chain
//! of vertical cuts ([`CutTree::vertical_bands`]).
//!
//! Each leaf names the task that owns its rectangle *and* the NoC topology
//! instantiated inside it (the paper's modified mesh vs a conventional
//! mesh can be chosen per region). Trees serialize to and from the report
//! JSON ([`CutTree::to_json`] / [`CutTree::from_json`]), so a planned
//! partition round-trips through `reports/cosched.json` and can be fed
//! back into external tooling; [`CutTree::encode`] is the compact
//! single-line rendering used in tables (`V8(a:m,H4(b:A,c:m))`).

use crate::config::TopologyKind;
use crate::util::json::Json;

use super::region::{Region, RegionPartition};

/// Orientation of one guillotine cut.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CutAxis {
    /// The cut line runs horizontally: `low` is the top part (`at` rows),
    /// `high` the bottom part.
    Horizontal,
    /// The cut line runs vertically: `low` is the left part (`at`
    /// columns), `high` the right part.
    Vertical,
}

impl CutAxis {
    pub fn name(self) -> &'static str {
        match self {
            CutAxis::Horizontal => "h",
            CutAxis::Vertical => "v",
        }
    }

    pub fn from_name(s: &str) -> Option<CutAxis> {
        match s {
            "h" => Some(CutAxis::Horizontal),
            "v" => Some(CutAxis::Vertical),
            _ => None,
        }
    }
}

/// A recursive guillotine partition of a rectangle into per-task regions.
#[derive(Debug, Clone, PartialEq)]
pub enum CutTree {
    /// The rectangle belongs to `task`, served by a `topology` NoC.
    Leaf { task: usize, topology: TopologyKind },
    /// The rectangle is deliberately left unassigned (e.g. the trailing
    /// columns a band winner did not use) — the cuts still tile the array
    /// exactly, this space just powers no task.
    Idle,
    /// The rectangle is split `at` rows/columns from its origin.
    Cut {
        axis: CutAxis,
        at: usize,
        low: Box<CutTree>,
        high: Box<CutTree>,
    },
}

impl CutTree {
    /// Task leaves only — [`CutTree::Idle`] rectangles do not count.
    pub fn num_leaves(&self) -> usize {
        match self {
            CutTree::Leaf { .. } => 1,
            CutTree::Idle => 0,
            CutTree::Cut { low, high, .. } => low.num_leaves() + high.num_leaves(),
        }
    }

    /// The 1-D special case: full-height vertical bands of the given
    /// widths on a `total_cols`-wide array, task `i` owning band `i`,
    /// every region on one topology. Widths that do not use every column
    /// leave an explicit trailing [`CutTree::Idle`] rectangle, so the
    /// tree realizes exactly the band partition (no silent widening).
    pub fn vertical_bands(widths: &[usize], total_cols: usize, topology: TopologyKind) -> CutTree {
        assert!(!widths.is_empty(), "a cut tree needs at least one band");
        let used: usize = widths.iter().sum();
        assert!(
            (1..=total_cols).contains(&used),
            "band widths {widths:?} must fit {total_cols} columns"
        );
        let bands = Self::bands_from(0, widths, topology);
        if used < total_cols {
            CutTree::Cut {
                axis: CutAxis::Vertical,
                at: used,
                low: Box::new(bands),
                high: Box::new(CutTree::Idle),
            }
        } else {
            bands
        }
    }

    fn bands_from(task0: usize, widths: &[usize], topology: TopologyKind) -> CutTree {
        if widths.len() == 1 {
            return CutTree::Leaf {
                task: task0,
                topology,
            };
        }
        CutTree::Cut {
            axis: CutAxis::Vertical,
            at: widths[0],
            low: Box::new(CutTree::Leaf {
                task: task0,
                topology,
            }),
            high: Box::new(Self::bands_from(task0 + 1, &widths[1..], topology)),
        }
    }

    /// Realize the tree on an `array_rows × array_cols` array: one region
    /// per task (indexed by task, like every `RegionPartition` in the
    /// co-scheduler) plus each region's topology. Fails if a cut offset
    /// falls outside its rectangle or the leaf tasks are not exactly
    /// `0..num_leaves` (each once); the resulting partition is validated,
    /// and by construction the cuts tile the array with no gap — every PE
    /// is in exactly one task region or one explicit [`CutTree::Idle`]
    /// rectangle.
    ///
    /// # Examples
    ///
    /// ```
    /// use pipeorgan::config::TopologyKind;
    /// use pipeorgan::cosched::{CutAxis, CutTree};
    ///
    /// // Task 1 on the left 16×8 half; the right half split into two
    /// // 8×8 quadrants for tasks 0 (top) and 2 (bottom).
    /// let tree = CutTree::Cut {
    ///     axis: CutAxis::Vertical,
    ///     at: 8,
    ///     low: Box::new(CutTree::Leaf { task: 1, topology: TopologyKind::Amp }),
    ///     high: Box::new(CutTree::Cut {
    ///         axis: CutAxis::Horizontal,
    ///         at: 8,
    ///         low: Box::new(CutTree::Leaf { task: 0, topology: TopologyKind::Mesh }),
    ///         high: Box::new(CutTree::Leaf { task: 2, topology: TopologyKind::Mesh }),
    ///     }),
    /// };
    /// let (partition, topologies) = tree.partition(16, 16).unwrap();
    ///
    /// // Regions and topologies are indexed by task, not tree position.
    /// assert_eq!(partition.regions.len(), 3);
    /// assert_eq!((partition.regions[1].rows, partition.regions[1].cols), (16, 8));
    /// assert_eq!((partition.regions[0].rows, partition.regions[0].cols), (8, 8));
    /// assert_eq!(topologies[1], TopologyKind::Amp);
    /// // The three regions tile the array exactly.
    /// let pes: usize = partition.regions.iter().map(|r| r.rows * r.cols).sum();
    /// assert_eq!(pes, 16 * 16);
    /// ```
    pub fn partition(
        &self,
        array_rows: usize,
        array_cols: usize,
    ) -> Result<(RegionPartition, Vec<TopologyKind>), String> {
        let n = self.num_leaves();
        let mut slots: Vec<Option<(Region, TopologyKind)>> = vec![None; n];
        self.collect(0, 0, array_rows, array_cols, &mut slots)?;
        let mut regions = Vec::with_capacity(n);
        let mut topologies = Vec::with_capacity(n);
        for (task, slot) in slots.into_iter().enumerate() {
            let (region, topo) =
                slot.ok_or_else(|| format!("cut tree assigns no region to task {task}"))?;
            regions.push(region);
            topologies.push(topo);
        }
        let partition = RegionPartition {
            array_rows,
            array_cols,
            regions,
        };
        partition.validate()?;
        Ok((partition, topologies))
    }

    fn collect(
        &self,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
        slots: &mut [Option<(Region, TopologyKind)>],
    ) -> Result<(), String> {
        match self {
            CutTree::Leaf { task, topology } => {
                let n = slots.len();
                let slot = slots
                    .get_mut(*task)
                    .ok_or_else(|| format!("leaf task {task} outside 0..{n}"))?;
                if slot.is_some() {
                    return Err(format!("cut tree assigns task {task} twice"));
                }
                *slot = Some((
                    Region {
                        row0,
                        col0,
                        rows,
                        cols,
                    },
                    *topology,
                ));
                Ok(())
            }
            CutTree::Idle => Ok(()),
            CutTree::Cut {
                axis,
                at,
                low,
                high,
            } => {
                let dim = match axis {
                    CutAxis::Horizontal => rows,
                    CutAxis::Vertical => cols,
                };
                if *at == 0 || *at >= dim {
                    return Err(format!(
                        "cut at {at} outside its {dim}-{} rectangle",
                        match axis {
                            CutAxis::Horizontal => "row",
                            CutAxis::Vertical => "column",
                        }
                    ));
                }
                match axis {
                    CutAxis::Horizontal => {
                        low.collect(row0, col0, *at, cols, slots)?;
                        high.collect(row0 + at, col0, rows - at, cols, slots)
                    }
                    CutAxis::Vertical => {
                        low.collect(row0, col0, rows, *at, slots)?;
                        high.collect(row0, col0 + at, rows, cols - at, slots)
                    }
                }
            }
        }
    }

    /// The [`CutTree::Idle`] rectangles of the realized partition, in tree
    /// order. [`CutTree::partition`] drops them (regions are indexed by
    /// task); NoC heatmaps need them back so the exported grids tile the
    /// full array — idle space is rendered as explicit zero-load regions.
    pub fn idle_rects(&self, array_rows: usize, array_cols: usize) -> Vec<Region> {
        let mut rects = Vec::new();
        self.walk_idle(0, 0, array_rows, array_cols, &mut rects);
        rects
    }

    fn walk_idle(&self, row0: usize, col0: usize, rows: usize, cols: usize, out: &mut Vec<Region>) {
        match self {
            CutTree::Leaf { .. } => {}
            CutTree::Idle => out.push(Region {
                row0,
                col0,
                rows,
                cols,
            }),
            CutTree::Cut {
                axis,
                at,
                low,
                high,
            } => match axis {
                CutAxis::Horizontal => {
                    low.walk_idle(row0, col0, (*at).min(rows), cols, out);
                    high.walk_idle(row0 + at, col0, rows.saturating_sub(*at), cols, out);
                }
                CutAxis::Vertical => {
                    low.walk_idle(row0, col0, rows, (*at).min(cols), out);
                    high.walk_idle(row0, col0 + at, rows, cols.saturating_sub(*at), out);
                }
            },
        }
    }

    /// JSON form: leaves are `{"task": 1, "topology": "mesh"}`, idle
    /// rectangles `{"idle": true}`, cuts `{"axis": "v", "at": 8,
    /// "low": …, "high": …}`.
    pub fn to_json(&self) -> Json {
        match self {
            CutTree::Leaf { task, topology } => {
                let mut o = Json::obj();
                o.set("task", *task).set("topology", topology.name());
                o
            }
            CutTree::Idle => {
                let mut o = Json::obj();
                o.set("idle", true);
                o
            }
            CutTree::Cut {
                axis,
                at,
                low,
                high,
            } => {
                let mut o = Json::obj();
                o.set("axis", axis.name())
                    .set("at", *at)
                    .set("low", low.to_json())
                    .set("high", high.to_json());
                o
            }
        }
    }

    /// Inverse of [`CutTree::to_json`], so serialized plans round-trip
    /// through JSON reports. A leaf without a `topology` field defaults to
    /// the conventional mesh (hand-written plans stay terse).
    pub fn from_json(v: &Json) -> Result<CutTree, String> {
        if let Some(task) = v.get("task") {
            let task = task
                .as_usize()
                .filter(|_| task.as_f64().is_some_and(|x| x >= 0.0))
                .ok_or("cut-tree leaf `task` must be a non-negative number")?;
            let topology = match v.get("topology") {
                None => TopologyKind::Mesh,
                Some(t) => {
                    let name = t.as_str().ok_or("cut-tree leaf `topology` must be a string")?;
                    TopologyKind::from_name(name)
                        .ok_or_else(|| format!("unknown cut-tree topology `{name}`"))?
                }
            };
            return Ok(CutTree::Leaf { task, topology });
        }
        if v.get("idle").is_some() {
            return Ok(CutTree::Idle);
        }
        let axis_name = v
            .get("axis")
            .and_then(Json::as_str)
            .ok_or("cut-tree node needs a `task` (leaf) or string `axis` (cut)")?;
        let axis = CutAxis::from_name(axis_name)
            .ok_or_else(|| format!("unknown cut axis `{axis_name}` (known: h, v)"))?;
        let at = v
            .get("at")
            .and_then(Json::as_usize)
            .ok_or("cut-tree cut needs a numeric `at`")?;
        let low = CutTree::from_json(v.get("low").ok_or("cut-tree cut needs `low`")?)?;
        let high = CutTree::from_json(v.get("high").ok_or("cut-tree cut needs `high`")?)?;
        Ok(CutTree::Cut {
            axis,
            at,
            low: Box::new(low),
            high: Box::new(high),
        })
    }

    /// Compact single-line rendering for tables: tasks as letters (the
    /// same `a`, `b`, … the placement ASCII art uses), topologies as one
    /// letter (`m`esh, `A`mp, `t`orus, `f`lattened butterfly), idle
    /// rectangles as `_` — `V8(a:m,H4(b:A,c:m))`.
    ///
    /// # Examples
    ///
    /// ```
    /// use pipeorgan::config::TopologyKind;
    /// use pipeorgan::cosched::{CutAxis, CutTree};
    ///
    /// let tree = CutTree::Cut {
    ///     axis: CutAxis::Vertical,
    ///     at: 8,
    ///     low: Box::new(CutTree::Leaf { task: 0, topology: TopologyKind::Amp }),
    ///     high: Box::new(CutTree::Cut {
    ///         axis: CutAxis::Horizontal,
    ///         at: 6,
    ///         low: Box::new(CutTree::Leaf { task: 2, topology: TopologyKind::Mesh }),
    ///         high: Box::new(CutTree::Leaf { task: 1, topology: TopologyKind::Mesh }),
    ///     }),
    /// };
    /// assert_eq!(tree.encode(), "V8(a:A,H6(c:m,b:m))");
    /// ```
    pub fn encode(&self) -> String {
        match self {
            CutTree::Idle => "_".to_string(),
            CutTree::Leaf { task, topology } => {
                let letter = (b'a' + (task % 26) as u8) as char;
                let topo = match topology {
                    TopologyKind::Mesh => "m",
                    TopologyKind::Amp => "A",
                    TopologyKind::Torus => "t",
                    TopologyKind::FlattenedButterfly => "f",
                };
                format!("{letter}:{topo}")
            }
            CutTree::Cut {
                axis,
                at,
                low,
                high,
            } => format!(
                "{}{at}({},{})",
                match axis {
                    CutAxis::Horizontal => "H",
                    CutAxis::Vertical => "V",
                },
                low.encode(),
                high.encode()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(task: usize, topology: TopologyKind) -> Box<CutTree> {
        Box::new(CutTree::Leaf { task, topology })
    }

    #[test]
    fn vertical_bands_match_the_band_partition() {
        let tree = CutTree::vertical_bands(&[4, 8, 4], 16, TopologyKind::Mesh);
        assert_eq!(tree.num_leaves(), 3);
        let (p, topos) = tree.partition(8, 16).unwrap();
        let bands = RegionPartition::vertical(8, 16, &[4, 8, 4]);
        assert_eq!(p.regions, bands.regions);
        assert_eq!(topos, vec![TopologyKind::Mesh; 3]);
        assert_eq!(p.idle_pes(), 0);
    }

    #[test]
    fn under_full_bands_get_an_explicit_idle_tail() {
        // 4 + 8 of 16 columns used: the tree must realize bands of widths
        // 4 and 8 exactly (no silent widening of the last band) with the
        // trailing 4 columns as an explicit idle rectangle.
        let tree = CutTree::vertical_bands(&[4, 8], 16, TopologyKind::Amp);
        assert_eq!(tree.num_leaves(), 2);
        assert_eq!(tree.encode(), "V12(V4(a:A,b:A),_)");
        let (p, _) = tree.partition(8, 16).unwrap();
        assert_eq!(p.regions, RegionPartition::vertical(8, 16, &[4, 8]).regions);
        assert_eq!(p.idle_pes(), 4 * 8);
        // JSON round-trips the idle rectangle too.
        let back = CutTree::from_json(&tree.to_json()).unwrap();
        assert_eq!(back, tree);
    }

    #[test]
    fn idle_rects_complement_the_task_regions_exactly() {
        let tree = CutTree::vertical_bands(&[4, 8], 16, TopologyKind::Amp);
        let (p, _) = tree.partition(8, 16).unwrap();
        let idle = tree.idle_rects(8, 16);
        assert_eq!(idle.len(), 1);
        assert_eq!(
            idle[0],
            Region {
                row0: 0,
                col0: 12,
                rows: 8,
                cols: 4
            }
        );
        let task_pes: usize = p.regions.iter().map(Region::num_pes).sum();
        let idle_pes: usize = idle.iter().map(Region::num_pes).sum();
        assert_eq!(task_pes + idle_pes, 8 * 16, "task + idle tile the array");
        // Fully-used trees report no idle space.
        let full = CutTree::vertical_bands(&[8, 8], 16, TopologyKind::Mesh);
        assert!(full.idle_rects(8, 16).is_empty());
    }

    #[test]
    fn mixed_cuts_tile_without_gap_and_carry_topologies() {
        // Left half to task 0 on AMP; right half split top/bottom between
        // tasks 2 and 1 on meshes — leaf order need not be task order.
        let tree = CutTree::Cut {
            axis: CutAxis::Vertical,
            at: 8,
            low: leaf(0, TopologyKind::Amp),
            high: Box::new(CutTree::Cut {
                axis: CutAxis::Horizontal,
                at: 6,
                low: leaf(2, TopologyKind::Mesh),
                high: leaf(1, TopologyKind::Mesh),
            }),
        };
        let (p, topos) = tree.partition(16, 16).unwrap();
        assert_eq!(p.regions.len(), 3);
        let total: usize = p.regions.iter().map(Region::num_pes).sum();
        assert_eq!(total, 256, "guillotine partitions tile exactly");
        assert_eq!(p.idle_pes(), 0);
        let rect = |row0, col0, rows, cols| Region {
            row0,
            col0,
            rows,
            cols,
        };
        assert_eq!(p.regions[0], rect(0, 0, 16, 8));
        assert_eq!(p.regions[2], rect(0, 8, 6, 8));
        assert_eq!(p.regions[1], rect(6, 8, 10, 8));
        assert_eq!(topos[0], TopologyKind::Amp);
        assert_eq!(tree.encode(), "V8(a:A,H6(c:m,b:m))");
    }

    #[test]
    fn malformed_trees_are_rejected() {
        // Cut offset outside the rectangle.
        let tree = CutTree::Cut {
            axis: CutAxis::Vertical,
            at: 16,
            low: leaf(0, TopologyKind::Mesh),
            high: leaf(1, TopologyKind::Mesh),
        };
        assert!(tree.partition(8, 16).unwrap_err().contains("outside"));
        // Duplicate task.
        let tree = CutTree::Cut {
            axis: CutAxis::Horizontal,
            at: 4,
            low: leaf(0, TopologyKind::Mesh),
            high: leaf(0, TopologyKind::Mesh),
        };
        assert!(tree.partition(8, 16).unwrap_err().contains("twice"));
        // Task index out of range leaves a hole at task 1.
        let tree = CutTree::Cut {
            axis: CutAxis::Horizontal,
            at: 4,
            low: leaf(0, TopologyKind::Mesh),
            high: leaf(2, TopologyKind::Mesh),
        };
        assert!(tree.partition(8, 16).is_err());
    }

    #[test]
    fn json_round_trips() {
        let tree = CutTree::Cut {
            axis: CutAxis::Vertical,
            at: 12,
            low: Box::new(CutTree::Cut {
                axis: CutAxis::Horizontal,
                at: 20,
                low: leaf(1, TopologyKind::Amp),
                high: leaf(0, TopologyKind::Mesh),
            }),
            high: leaf(2, TopologyKind::Torus),
        };
        let json = tree.to_json();
        let back = CutTree::from_json(&json).unwrap();
        assert_eq!(back, tree);
        // Through the serializer + parser too (the report path).
        let reparsed = Json::parse(&json.to_pretty()).unwrap();
        assert_eq!(CutTree::from_json(&reparsed).unwrap(), tree);
    }

    #[test]
    fn from_json_defaults_topology_and_rejects_garbage() {
        let v = Json::parse(r#"{"task": 3}"#).unwrap();
        assert_eq!(
            CutTree::from_json(&v).unwrap(),
            CutTree::Leaf {
                task: 3,
                topology: TopologyKind::Mesh
            }
        );
        for bad in [
            r#"{"axis": "d", "at": 4, "low": {"task": 0}, "high": {"task": 1}}"#,
            r#"{"axis": "v", "low": {"task": 0}, "high": {"task": 1}}"#,
            r#"{"axis": "v", "at": 4, "low": {"task": 0}}"#,
            r#"{"at": 4}"#,
            r#"{"task": "zero"}"#,
            r#"{"task": 0, "topology": "ring"}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(CutTree::from_json(&v).is_err(), "{bad}");
        }
    }
}
