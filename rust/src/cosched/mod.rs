//! Multi-workload co-scheduling: several concurrent XR tasks share one PE
//! array (see DESIGN.md §Cosched).
//!
//! The single-model stack — mapper, DSE, cost model — optimizes one
//! `ModelGraph` on a dedicated array. The XR deployments the paper targets
//! run *sets* of such models concurrently (eye segmentation + gaze
//! estimation + keyword detection), so the planning question one level up
//! is: how should the array be split between them? This subsystem answers
//! it:
//!
//! - `scenario`: a [`Scenario`] is a task list with per-task rates and
//!   deadlines, with canned XR scenarios built from `workloads::tasks`;
//! - `region`: rectangular per-task array regions
//!   ([`RegionPartition`]), region-scoped architecture configs
//!   ([`region_config`]), and the composed whole-array
//!   [`ScenarioPlacement`] that validates tasks never overlap;
//! - `cut`: guillotine [`CutTree`]s — recursive H/V cuts that realize
//!   arbitrary rectangular partitions (vertical bands are the 1-D special
//!   case) with a per-region NoC topology choice, JSON-serializable so
//!   plans round-trip through reports;
//! - `search`: the co-scheduling search ([`schedule`]) — a dynamic
//!   program whose state is *array occupancy* (columns consumed so far),
//!   extending the DSE's Pareto-label machinery so per-task region widths
//!   are chosen jointly, plus (under
//!   [`PartitionKind::Guillotine`]) a memoized beam over cut trees —
//!   cut position × axis × task-to-leaf assignment — seeded with the
//!   vertical-band winner so 2-D can never lose to 1-D. Per-(task,
//!   rectangle) costs are memoized in the shared `dse::EvalCache` (region
//!   configs fingerprint distinctly, so persistent cache files warm-start
//!   co-scheduling too) and evaluated in parallel over
//!   `coordinator::run_queue`.
//!
//! The even-column split is always seeded as a candidate, so the
//! co-scheduled makespan can never exceed the naive even split — mirroring
//! the tuned mapper's never-lose guarantee one level up. `pipeorgan
//! cosched` runs it end to end and `report::cosched` tabulates per-task
//! latency/energy and scenario makespan for solo-array vs naive-split vs
//! co-scheduled allocations.

mod cut;
mod region;
mod scenario;
mod search;

pub use cut::{CutAxis, CutTree};
pub use region::{even_widths, region_config, Region, RegionPartition, ScenarioPlacement};
pub use scenario::{
    canned_scenarios, scenario_by_name, scenario_names, xr_core, xr_hands, xr_world, Scenario,
    TaskSpec,
};
pub use search::{
    canned_live_contexts, schedule, CoschedOutcome, CoschedResult, ProperSubsets, TaskAssignment,
    TaskSet,
};

/// How the array is carved into per-task regions (`--partition`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    /// Full-height vertical bands — the 1-D occupancy DP.
    Bands,
    /// Recursive guillotine rectangles ([`CutTree`]) with per-region
    /// topology choice; always seeded with the band winner, so it can
    /// never lose to [`PartitionKind::Bands`].
    Guillotine,
}

impl PartitionKind {
    pub fn name(self) -> &'static str {
        match self {
            PartitionKind::Bands => "bands",
            PartitionKind::Guillotine => "guillotine",
        }
    }

    pub fn from_name(s: &str) -> Option<PartitionKind> {
        match s {
            "bands" => Some(PartitionKind::Bands),
            "guillotine" => Some(PartitionKind::Guillotine),
            _ => None,
        }
    }
}

/// Knobs of one co-scheduling run. CLI flags map 1:1 onto these (see
/// [`COSCHED_FLAGS`]).
#[derive(Debug, Clone)]
pub struct CoschedConfig {
    /// Region-shape family searched: 1-D vertical bands or recursive 2-D
    /// guillotine rectangles.
    pub partition: PartitionKind,
    /// Column-width quantum of candidate regions: widths are multiples of
    /// this (under [`PartitionKind::Guillotine`] it is the cut grid along
    /// *both* axes; the even-split widths are always added as band
    /// candidates too). Coarser quanta shrink the search; finer quanta
    /// find tighter splits.
    pub quantum: usize,
    /// Plan each region with the budgeted tuned search
    /// (`mapper::TunedPipeOrgan`'s plan path) instead of the closed-form
    /// heuristic. Slower, never worse per region.
    pub tuned: bool,
    /// Tuned-search evaluation budget per (task, width) plan
    /// (`dse::TUNED_DEFAULT_BUDGET` when unset).
    pub budget: Option<u64>,
    /// Pareto labels kept per occupancy state in the allocation DP.
    pub max_labels: usize,
    /// Observability handle (`--obs` / `--trace-out`): guillotine-beam
    /// counters and planner phase spans. Disabled (free) by default.
    pub obs: crate::obs::Obs,
}

impl Default for CoschedConfig {
    fn default() -> Self {
        Self {
            partition: PartitionKind::Bands,
            quantum: 4,
            tuned: false,
            budget: None,
            max_labels: 16,
            obs: crate::obs::Obs::disabled(),
        }
    }
}

impl CoschedConfig {
    /// Build from parsed CLI flags (the `cosched` subcommand).
    pub fn from_cli(args: &crate::cli::Args) -> Result<CoschedConfig, String> {
        if args.has("budget") && !args.has("tuned") {
            return Err(
                "flag `--budget` on cosched requires `--tuned` (only the tuned search is budgeted)"
                    .into(),
            );
        }
        let defaults = CoschedConfig::default();
        let partition_name = args.get_or("partition", defaults.partition.name());
        let partition = PartitionKind::from_name(partition_name).ok_or_else(|| {
            format!("unknown partition kind `{partition_name}` (known: bands, guillotine)")
        })?;
        Ok(CoschedConfig {
            partition,
            quantum: args.get_usize("quantum", defaults.quantum)?.max(1),
            tuned: args.has("tuned"),
            budget: if args.has("budget") {
                Some(args.get_u64("budget", 0)?)
            } else {
                None
            },
            max_labels: defaults.max_labels,
            obs: crate::obs::Obs::from_cli(args),
        })
    }
}

/// Flags accepted by the `cosched` subcommand on top of the global ones
/// (`(name, takes_value)` — the `cli::Args` strict-flag table format).
/// `--scenario` names canned scenarios (`all`, one name, or a comma list);
/// `--partition` picks the region family (`bands` or `guillotine`);
/// `--cache-file`/`--cache-cap` manage the persistent evaluation cache
/// exactly as on `dse`. `--obs` enables the observability counters;
/// `--trace-out FILE` additionally writes the Perfetto trace there (and
/// implies `--obs`).
pub const COSCHED_FLAGS: &[(&str, bool)] = &[
    ("scenario", true),
    ("partition", true),
    ("quantum", true),
    ("tuned", false),
    ("budget", true),
    ("cache-file", true),
    ("cache-cap", true),
    ("obs", false),
    ("trace-out", true),
    ("noc-out", true),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;

    fn parse_cs(v: &[&str]) -> Result<CoschedConfig, String> {
        let mut flags: Vec<(&str, bool)> = vec![("out", true), ("workers", true)];
        flags.extend_from_slice(COSCHED_FLAGS);
        let raw: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        let args = Args::parse(&raw, &flags)?;
        CoschedConfig::from_cli(&args)
    }

    #[test]
    fn defaults_are_sane() {
        let cs = CoschedConfig::default();
        assert!(cs.quantum >= 1 && cs.max_labels >= 1);
        assert!(!cs.tuned);
        assert!(cs.budget.is_none());
        assert_eq!(cs.partition, PartitionKind::Bands);
    }

    #[test]
    fn cli_flags_parse_into_config() {
        let cs = parse_cs(&[
            "cosched",
            "--scenario",
            "xr-core",
            "--partition",
            "guillotine",
            "--quantum",
            "2",
            "--tuned",
            "--budget",
            "500",
        ])
        .unwrap();
        assert_eq!(cs.partition, PartitionKind::Guillotine);
        assert_eq!(cs.quantum, 2);
        assert!(cs.tuned);
        assert_eq!(cs.budget, Some(500));
    }

    #[test]
    fn obs_flags_enable_the_handle() {
        assert!(!parse_cs(&["cosched"]).unwrap().obs.is_enabled());
        assert!(parse_cs(&["cosched", "--obs"]).unwrap().obs.is_enabled());
        assert!(parse_cs(&["cosched", "--trace-out", "t.json"])
            .unwrap()
            .obs
            .is_enabled());
    }

    #[test]
    fn partition_kind_names_roundtrip() {
        for pk in [PartitionKind::Bands, PartitionKind::Guillotine] {
            assert_eq!(PartitionKind::from_name(pk.name()), Some(pk));
        }
        assert!(PartitionKind::from_name("diagonal").is_none());
    }

    #[test]
    fn bad_flags_rejected() {
        assert!(parse_cs(&["cosched", "--quantum", "two"]).is_err());
        assert!(parse_cs(&["cosched", "--partition", "diagonal"]).is_err());
        assert!(parse_cs(&["cosched", "--nope"]).is_err());
        // quantum 0 clamps to 1 instead of dividing by zero later
        assert_eq!(parse_cs(&["cosched", "--quantum", "0"]).unwrap().quantum, 1);
        // A budget without the tuned search would be silently dead — reject.
        assert!(parse_cs(&["cosched", "--budget", "100"]).is_err());
        assert!(parse_cs(&["cosched", "--budget", "100", "--tuned"]).is_ok());
    }
}
