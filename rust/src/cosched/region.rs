//! Rectangular array regions: how concurrent tasks split the PE array.
//!
//! A [`Region`] is a rectangle of PEs; a [`RegionPartition`] carves the
//! array into one region per task. [`RegionPartition::vertical`] builds
//! the 1-D special case (full-height bands); arbitrary non-overlapping
//! rectangles come from guillotine [`CutTree`]s
//! ([`CutTree::partition`]) — every region's NoC stays a smaller
//! instance of a whole-array topology either way. Costing a task inside
//! a region reuses the whole single-model stack unchanged:
//! [`region_config`] shrinks the architecture to the region's dimensions
//! and scales the *shared* resources (global buffer capacity, DRAM
//! bandwidth) by the region's PE share, so concurrently resident tasks
//! never double-count them.
//!
//! [`CutTree`]: super::CutTree
//! [`CutTree::partition`]: super::CutTree::partition
//!
//! [`ScenarioPlacement`] composes each task's own `spatial::Placement`
//! (built at region dimensions) into one whole-array view and rejects any
//! PE claimed twice — the structural non-overlap guarantee of a
//! co-schedule.

use crate::config::ArchConfig;
use crate::spatial::Placement;

/// A rectangle `[row0, row0+rows) × [col0, col0+cols)` of the PE array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub row0: usize,
    pub col0: usize,
    pub rows: usize,
    pub cols: usize,
}

impl Region {
    pub fn num_pes(&self) -> usize {
        self.rows * self.cols
    }

    pub fn row_end(&self) -> usize {
        self.row0 + self.rows
    }

    pub fn col_end(&self) -> usize {
        self.col0 + self.cols
    }

    pub fn contains(&self, r: usize, c: usize) -> bool {
        (self.row0..self.row_end()).contains(&r) && (self.col0..self.col_end()).contains(&c)
    }

    pub fn overlaps(&self, other: &Region) -> bool {
        self.row0 < other.row_end()
            && other.row0 < self.row_end()
            && self.col0 < other.col_end()
            && other.col0 < self.col_end()
    }
}

/// The array split into one region per task.
#[derive(Debug, Clone)]
pub struct RegionPartition {
    pub array_rows: usize,
    pub array_cols: usize,
    pub regions: Vec<Region>,
}

impl RegionPartition {
    /// Full-height vertical bands of the given column widths, left to
    /// right. Widths may leave trailing columns idle; they must not exceed
    /// the array (checked by [`RegionPartition::validate`]).
    pub fn vertical(array_rows: usize, array_cols: usize, widths: &[usize]) -> RegionPartition {
        let mut regions = Vec::with_capacity(widths.len());
        let mut col0 = 0usize;
        for &w in widths {
            regions.push(Region {
                row0: 0,
                col0,
                rows: array_rows,
                cols: w,
            });
            col0 += w;
        }
        RegionPartition {
            array_rows,
            array_cols,
            regions,
        }
    }

    /// The naive baseline: split the columns as evenly as possible across
    /// `n` bands.
    pub fn even_split(array_rows: usize, array_cols: usize, n: usize) -> RegionPartition {
        RegionPartition::vertical(array_rows, array_cols, &even_widths(array_cols, n))
    }

    /// Every region non-empty and in bounds; no two regions overlap.
    pub fn validate(&self) -> Result<(), String> {
        for (i, r) in self.regions.iter().enumerate() {
            if r.rows == 0 || r.cols == 0 {
                return Err(format!("region {i} is empty"));
            }
            if r.row_end() > self.array_rows || r.col_end() > self.array_cols {
                return Err(format!(
                    "region {i} ({}..{} × {}..{}) exceeds the {}×{} array",
                    r.row0,
                    r.row_end(),
                    r.col0,
                    r.col_end(),
                    self.array_rows,
                    self.array_cols
                ));
            }
        }
        for (i, a) in self.regions.iter().enumerate() {
            for (j, b) in self.regions.iter().enumerate().skip(i + 1) {
                if a.overlaps(b) {
                    return Err(format!("regions {i} and {j} overlap"));
                }
            }
        }
        Ok(())
    }

    /// PEs assigned to no region.
    pub fn idle_pes(&self) -> usize {
        let used: usize = self.regions.iter().map(Region::num_pes).sum();
        self.array_rows * self.array_cols - used
    }
}

/// Split `cols` columns as evenly as possible across `n` bands (leftmost
/// bands take the remainder). Requires `1 <= n <= cols`.
pub fn even_widths(cols: usize, n: usize) -> Vec<usize> {
    assert!(
        (1..=cols).contains(&n),
        "cannot split {cols} columns {n} ways"
    );
    let base = cols / n;
    let rem = cols % n;
    (0..n).map(|i| base + usize::from(i < rem)).collect()
}

/// The architecture restricted to one region. The per-PE microarchitecture
/// (dot-product width, register files, link bandwidth) carries over
/// unchanged; the *shared* resources — global-buffer capacity and DRAM
/// bandwidth — are scaled by the region's PE share, so tasks resident at
/// the same time never double-count them. Costs are translation-invariant:
/// only the region's dimensions matter, not where the band sits.
pub fn region_config(cfg: &ArchConfig, region: &Region) -> ArchConfig {
    let share = region.num_pes() as f64 / cfg.num_pes().max(1) as f64;
    ArchConfig {
        pe_rows: region.rows,
        pe_cols: region.cols,
        sram_bytes: ((cfg.sram_bytes as f64 * share) as u64).max(1),
        dram_bytes_per_cycle: (cfg.dram_bytes_per_cycle * share).max(1e-9),
        ..cfg.clone()
    }
}

/// Whole-array occupancy of a co-schedule: `(task, stage)` per PE, composed
/// from each task's region-local [`Placement`].
#[derive(Debug, Clone)]
pub struct ScenarioPlacement {
    pub rows: usize,
    pub cols: usize,
    /// `(task, stage)` per PE, row-major; `None` = idle.
    assign: Vec<Option<(u16, u16)>>,
}

impl ScenarioPlacement {
    /// Embed each region's placement at its offset. Fails if a placement's
    /// dimensions disagree with its region, or if any PE ends up claimed by
    /// two tasks (which [`RegionPartition::validate`] makes impossible for
    /// well-formed partitions — the re-check here catches hand-built ones).
    pub fn compose(
        partition: &RegionPartition,
        placements: &[Placement],
    ) -> Result<ScenarioPlacement, String> {
        if placements.len() != partition.regions.len() {
            return Err(format!(
                "{} placements for {} regions",
                placements.len(),
                partition.regions.len()
            ));
        }
        let (rows, cols) = (partition.array_rows, partition.array_cols);
        let mut assign: Vec<Option<(u16, u16)>> = vec![None; rows * cols];
        for (t, (region, p)) in partition.regions.iter().zip(placements).enumerate() {
            if p.rows != region.rows || p.cols != region.cols {
                return Err(format!(
                    "task {t}: placement is {}×{} but its region is {}×{}",
                    p.rows, p.cols, region.rows, region.cols
                ));
            }
            for r in 0..p.rows {
                for c in 0..p.cols {
                    let Some(stage) = p.stage_at(r, c) else {
                        continue;
                    };
                    let cell = &mut assign[(region.row0 + r) * cols + (region.col0 + c)];
                    if cell.is_some() {
                        return Err(format!(
                            "PE ({}, {}) claimed by two tasks",
                            region.row0 + r,
                            region.col0 + c
                        ));
                    }
                    *cell = Some((t as u16, stage as u16));
                }
            }
        }
        Ok(ScenarioPlacement { rows, cols, assign })
    }

    /// `(task, stage)` at one PE.
    pub fn at(&self, r: usize, c: usize) -> Option<(usize, usize)> {
        self.assign[r * self.cols + c].map(|(t, s)| (t as usize, s as usize))
    }

    /// PEs owned by one task.
    pub fn task_pes(&self, task: usize) -> usize {
        self.assign
            .iter()
            .filter(|a| matches!(a, Some((t, _)) if *t as usize == task))
            .count()
    }

    pub fn idle_pes(&self) -> usize {
        self.assign.iter().filter(|a| a.is_none()).count()
    }

    /// ASCII rendering: one letter per PE (task index as `a`, `b`, …), `.`
    /// for idle — the co-scheduling analogue of `Placement::render`.
    pub fn render(&self) -> String {
        let mut s = String::with_capacity((self.cols + 1) * self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                match self.at(r, c) {
                    Some((t, _)) => s.push((b'a' + (t % 26) as u8) as char),
                    None => s.push('.'),
                }
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spatial::Organization;

    #[test]
    fn vertical_bands_tile_left_to_right() {
        let p = RegionPartition::vertical(8, 16, &[4, 8, 4]);
        p.validate().unwrap();
        assert_eq!(p.idle_pes(), 0);
        assert_eq!(p.regions[1].col0, 4);
        assert_eq!(p.regions[2].col0, 12);
        assert!(p.regions.iter().all(|r| r.rows == 8));
    }

    #[test]
    fn even_split_covers_all_columns() {
        assert_eq!(even_widths(16, 3), vec![6, 5, 5]);
        assert_eq!(even_widths(32, 3), vec![11, 11, 10]);
        assert_eq!(even_widths(8, 4), vec![2, 2, 2, 2]);
        let p = RegionPartition::even_split(8, 17, 5);
        p.validate().unwrap();
        assert_eq!(p.idle_pes(), 0);
    }

    #[test]
    fn validate_rejects_overlap_and_out_of_bounds() {
        let mut p = RegionPartition::vertical(8, 16, &[8, 8]);
        p.regions[1].col0 = 4; // now overlaps region 0
        assert!(p.validate().unwrap_err().contains("overlap"));
        let p = RegionPartition::vertical(8, 16, &[12, 8]); // 20 > 16 cols
        assert!(p.validate().is_err());
        let p = RegionPartition::vertical(8, 16, &[16, 0]);
        assert!(p.validate().unwrap_err().contains("empty"));
    }

    #[test]
    fn region_overlap_geometry() {
        let a = Region {
            row0: 0,
            col0: 0,
            rows: 4,
            cols: 4,
        };
        let b = Region {
            row0: 0,
            col0: 4,
            rows: 4,
            cols: 4,
        };
        assert!(!a.overlaps(&b), "adjacent bands do not overlap");
        let c = Region {
            row0: 2,
            col0: 2,
            rows: 4,
            cols: 4,
        };
        assert!(a.overlaps(&c) && c.overlaps(&a));
        assert!(a.contains(3, 3) && !a.contains(3, 4));
    }

    #[test]
    fn region_config_scales_shared_resources_only() {
        let cfg = ArchConfig::default(); // 32×32
        let half = Region {
            row0: 0,
            col0: 0,
            rows: 32,
            cols: 16,
        };
        let rc = region_config(&cfg, &half);
        rc.validate().unwrap();
        assert_eq!(rc.num_pes(), 512);
        assert_eq!(rc.sram_bytes, cfg.sram_bytes / 2);
        assert!((rc.dram_bytes_per_cycle - cfg.dram_bytes_per_cycle / 2.0).abs() < 1e-9);
        // Per-PE resources are untouched.
        assert_eq!(rc.pe_dot_product, cfg.pe_dot_product);
        assert_eq!(rc.rf_bytes_per_pe, cfg.rf_bytes_per_pe);
        assert_eq!(rc.link_words_per_cycle, cfg.link_words_per_cycle);
    }

    #[test]
    fn compose_embeds_placements_and_counts_pes() {
        let partition = RegionPartition::vertical(4, 8, &[4, 4]);
        let p0 = Placement::build(4, 4, Organization::FineStriped1D, &[1, 1]);
        let p1 = Placement::build(4, 4, Organization::Sequential, &[1]);
        let sp = ScenarioPlacement::compose(&partition, &[p0, p1]).unwrap();
        assert_eq!(sp.task_pes(0), 16);
        assert_eq!(sp.task_pes(1), 16);
        assert_eq!(sp.idle_pes(), 0);
        // Task 1 owns the right half.
        assert_eq!(sp.at(0, 4).map(|(t, _)| t), Some(1));
        let lines: Vec<&str> = sp.render().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("aaaa"));
        assert!(lines[0].ends_with("bbbb"));
    }

    #[test]
    fn compose_rejects_double_assignment_and_dim_mismatch() {
        let mut partition = RegionPartition::vertical(4, 8, &[4, 4]);
        partition.regions[1].col0 = 2; // overlap cols 2..6
        let p0 = Placement::build(4, 4, Organization::Sequential, &[1]);
        let p1 = Placement::build(4, 4, Organization::Sequential, &[1]);
        let err = ScenarioPlacement::compose(&partition, &[p0.clone(), p1]).unwrap_err();
        assert!(err.contains("two tasks"), "{err}");
        // Placement dims must match the region dims.
        let partition = RegionPartition::vertical(4, 8, &[4, 4]);
        let wrong = Placement::build(4, 8, Organization::Sequential, &[1]);
        assert!(ScenarioPlacement::compose(&partition, &[p0, wrong]).is_err());
    }
}
