//! Multi-workload scenarios: the unit the co-scheduler plans.
//!
//! The paper's headline deployment is XR, where several small models run
//! *concurrently* — eye segmentation and gaze estimation per camera frame,
//! keyword detection on the audio stream — rather than one model owning the
//! accelerator. A [`Scenario`] names such a task set: each [`TaskSpec`]
//! carries its model graph plus the rate it must sustain and the
//! per-inference deadline it must meet.
//!
//! Rates use a one-second scheduling frame: a task at `rate_hz` runs
//! `ceil(rate_hz)` inferences per frame, and the scenario *makespan* is how
//! many cycles the busiest resource needs to finish one frame of work.
//! Deadlines default to the task's own frame time (`1000/rate_hz` ms — an
//! inference must finish before the next input arrives) and can be
//! tightened per task.
//!
//! The canned scenarios below are built from the `workloads::tasks` zoo at
//! rates typical for the cited XR pipelines (camera-rate eye tracking,
//! display-rate hand tracking, audio-chunk-rate keyword/speech models).

use crate::ir::ModelGraph;
use crate::workloads;

/// One concurrent task: a model plus its service rate and deadline.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub graph: ModelGraph,
    /// Invocation rate in Hz (how often a new input arrives).
    pub rate_hz: f64,
    /// Per-inference deadline in milliseconds. Defaults to the frame time
    /// `1000 / rate_hz`.
    pub deadline_ms: f64,
}

impl TaskSpec {
    pub fn new(graph: ModelGraph, rate_hz: f64) -> TaskSpec {
        assert!(rate_hz > 0.0, "task rate must be positive");
        TaskSpec {
            deadline_ms: 1000.0 / rate_hz,
            graph,
            rate_hz,
        }
    }

    /// Override the default frame-time deadline.
    pub fn with_deadline_ms(mut self, deadline_ms: f64) -> TaskSpec {
        assert!(deadline_ms > 0.0, "deadline must be positive");
        self.deadline_ms = deadline_ms;
        self
    }

    pub fn name(&self) -> &str {
        &self.graph.name
    }

    /// Inferences inside one one-second scheduling frame.
    pub fn invocations(&self) -> u64 {
        self.rate_hz.ceil().max(1.0) as u64
    }
}

/// A named set of tasks that share the PE array concurrently.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub tasks: Vec<TaskSpec>,
}

impl Scenario {
    pub fn new(name: &str, tasks: Vec<TaskSpec>) -> Scenario {
        Scenario {
            name: name.to_string(),
            tasks,
        }
    }

    /// Non-empty, distinct task names, positive rates/deadlines.
    pub fn validate(&self) -> Result<(), String> {
        if self.tasks.is_empty() {
            return Err(format!("scenario `{}` has no tasks", self.name));
        }
        let mut seen = std::collections::BTreeSet::new();
        for t in &self.tasks {
            if !seen.insert(t.name().to_string()) {
                return Err(format!(
                    "scenario `{}` lists task `{}` twice",
                    self.name,
                    t.name()
                ));
            }
            if t.rate_hz <= 0.0 || t.deadline_ms <= 0.0 {
                return Err(format!(
                    "scenario `{}` task `{}` has a non-positive rate or deadline",
                    self.name,
                    t.name()
                ));
            }
        }
        Ok(())
    }
}

/// The paper's headline trio: per-eye-frame segmentation and gaze
/// estimation at camera rate plus always-on keyword detection.
pub fn xr_core() -> Scenario {
    Scenario::new(
        "xr-core",
        vec![
            TaskSpec::new(workloads::eye_segmentation(), 120.0),
            TaskSpec::new(workloads::gaze_estimation(), 120.0),
            TaskSpec::new(workloads::keyword_detection(), 10.0),
        ],
    )
}

/// Interaction pipeline: display-rate hand tracking alongside gaze and the
/// audio hotword model.
pub fn xr_hands() -> Scenario {
    Scenario::new(
        "xr-hands",
        vec![
            TaskSpec::new(workloads::hand_tracking(), 60.0),
            TaskSpec::new(workloads::gaze_estimation(), 120.0),
            TaskSpec::new(workloads::keyword_detection(), 10.0),
        ],
    )
}

/// World understanding: depth at camera rate, plane detection on keyframes,
/// streaming speech chunks for world-locked audio.
pub fn xr_world() -> Scenario {
    Scenario::new(
        "xr-world",
        vec![
            TaskSpec::new(workloads::depth_estimation(), 30.0),
            TaskSpec::new(workloads::plane_detection(), 10.0),
            TaskSpec::new(workloads::world_locking(), 12.5),
        ],
    )
}

/// All canned scenarios, in reporting order.
pub fn canned_scenarios() -> Vec<Scenario> {
    vec![xr_core(), xr_hands(), xr_world()]
}

pub fn scenario_by_name(name: &str) -> Option<Scenario> {
    canned_scenarios().into_iter().find(|s| s.name == name)
}

pub fn scenario_names() -> Vec<String> {
    canned_scenarios().into_iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canned_scenarios_validate_and_have_distinct_names() {
        let all = canned_scenarios();
        assert!(all.len() >= 2, "co-scheduling needs ≥2 canned scenarios");
        let mut names = std::collections::BTreeSet::new();
        for s in &all {
            s.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
            assert!(s.tasks.len() >= 2, "{} is not concurrent", s.name);
            assert!(names.insert(s.name.clone()), "duplicate {}", s.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        for name in scenario_names() {
            assert!(scenario_by_name(&name).is_some(), "missing {name}");
        }
        assert!(scenario_by_name("nope").is_none());
    }

    #[test]
    fn invocations_and_default_deadline() {
        let t = TaskSpec::new(workloads::keyword_detection(), 10.0);
        assert_eq!(t.invocations(), 10);
        assert!((t.deadline_ms - 100.0).abs() < 1e-9);
        // Fractional rates round invocations up (12.5 Hz → 13 per frame).
        let t = TaskSpec::new(workloads::world_locking(), 12.5);
        assert_eq!(t.invocations(), 13);
        // Sub-Hz rates still run at least once per frame.
        let t = TaskSpec::new(workloads::keyword_detection(), 0.5);
        assert_eq!(t.invocations(), 1);
        assert!((t.deadline_ms - 2000.0).abs() < 1e-9);
        let t = t.with_deadline_ms(50.0);
        assert!((t.deadline_ms - 50.0).abs() < 1e-9);
    }

    #[test]
    fn duplicate_tasks_rejected() {
        let s = Scenario::new(
            "twice",
            vec![
                TaskSpec::new(workloads::keyword_detection(), 10.0),
                TaskSpec::new(workloads::keyword_detection(), 20.0),
            ],
        );
        assert!(s.validate().is_err());
        assert!(Scenario::new("empty", vec![]).validate().is_err());
    }
}
