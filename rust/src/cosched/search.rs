//! The co-scheduling search: choose per-task regions jointly.
//!
//! Stage A (parallel, memoized): every (task, candidate region) pair is
//! planned and costed on its region-scoped architecture
//! (`region_config`) — by the closed-form PipeOrgan mapper, or by the
//! budgeted tuned search under `CoschedConfig::tuned`. Heuristic plans are
//! costed *through the shared `dse::EvalCache`* at the same cache
//! coordinates the DSE uses (heuristic segments always live at granularity
//! scale 1), so repeated scenarios, repeated shapes, and persistent cache
//! files all hit instead of re-evaluating. The pair sweep fans out over
//! `coordinator::run_queue`.
//!
//! Stage B (exact, cheap): a dynamic program over tasks whose state is
//! *array occupancy* — how many columns are already committed. Each state
//! holds a Pareto set of labels (frame makespan, energy, DRAM, worst
//! channel load), pruned with the DSE's own `pareto_filter_first`;
//! makespan and load compose by `max`, which is monotone, so prefix
//! dominance is sound exactly as in the segment DP. The final winner is
//! the minimum-(makespan, energy) complete label. The even-column split
//! is additionally seeded as a complete candidate, so the co-scheduled
//! plan **never loses to the naive even split** — the same never-lose
//! construction the tuned mapper uses against the heuristic.
//!
//! Under `PartitionKind::Guillotine` a second search runs on top: a
//! memoized beam over guillotine [`CutTree`]s — for every (rectangle,
//! task-set) state it enumerates cut axis × cut position (quantum grid) ×
//! task-to-leaf assignment and keeps a Pareto set of labels; leaves
//! additionally choose a per-region NoC topology (the paper's modified
//! mesh vs a conventional mesh). The vertical-band winner is seeded as a
//! complete candidate, so the 2-D plan **never loses to 1-D** by
//! construction.
//!
//! The beam is engineered for warm-cache replan latency (see
//! `docs/PERFORMANCE.md`): task subsets are [`TaskSet`] `u64` bitsets
//! packed with the rectangle dims into a `Copy` memo key, labels are
//! `Copy` parent-pointer records (no cut tree is ever cloned in the inner
//! product loop — the winner's tree is rebuilt once from the memo), and
//! states are expanded bottom-up by task-set size with each level fanned
//! out over `coordinator::run_queue` in sorted state order, so any worker
//! count produces bit-identical results.
//!
//! Three allocations are reported per scenario: `solo` (each task owns the
//! whole array, one frame of work time-multiplexed — makespan is the sum),
//! `even_split` (one equal vertical band per task, makespan is the max),
//! and `cosched` (the searched partition, makespan is the max).

use std::cell::RefCell;
use std::collections::{BTreeSet, HashMap, HashSet};
use std::rc::Rc;

use crate::config::{ArchConfig, TopologyKind};
use crate::coordinator::run_queue;
use crate::cost::{evaluate_segment, Mapper, MappingPlan};
use crate::dse::{
    arch_fingerprint, combine_fingerprints, context_fingerprint, graph_fingerprint,
    heuristic_segment_key, pareto_filter_first, tuned_plan, DseConfig, EvalCache, ParetoPoint,
    RunCounters,
};
use crate::energy::EnergyModel;
use crate::ir::ModelGraph;
use crate::mapper::PipeOrgan;
use crate::noc::Topology;
use crate::spatial::Placement;

use super::cut::{CutAxis, CutTree};
use super::region::{even_widths, region_config, Region, RegionPartition, ScenarioPlacement};
use super::scenario::Scenario;
use super::{CoschedConfig, PartitionKind};

/// One task's share of an allocation, fully costed.
#[derive(Debug, Clone)]
pub struct TaskAssignment {
    pub task: String,
    pub region: Region,
    /// NoC topology instantiated inside the region (the guillotine search
    /// chooses it per rectangle; bands inherit the array topology).
    pub topology: TopologyKind,
    pub rate_hz: f64,
    /// Inferences per one-second scheduling frame.
    pub invocations: u64,
    /// One inference's latency on the assigned region (cycles).
    pub latency_cycles: f64,
    /// The same latency in milliseconds (`latency_cycles / clock`), kept so
    /// reports can show deadline slack without re-threading the clock.
    pub latency_ms: f64,
    /// The task's per-inference deadline (from its `TaskSpec`).
    pub deadline_ms: f64,
    /// One frame of work: `invocations × latency_cycles`.
    pub busy_cycles: f64,
    /// Energy of one inference; one frame costs `invocations ×` this
    /// (see [`TaskAssignment::frame_energy`]).
    pub energy: f64,
    /// DRAM words of one inference.
    pub dram_words: u64,
    /// Worst per-interval channel load inside the region (Fig. 15 metric).
    pub worst_channel_load: f64,
    /// Predicted bandwidth-independent compute floor of one inference:
    /// the segments' summed `max(pipeline, NoC, GB)` cycles. With
    /// [`TaskAssignment::stretch_cycles`] this is the plan-time half of
    /// the predicted-vs-observed attribution comparison (`obs::attr`
    /// and `report::attr` consume it as the skew baseline).
    pub floor_cycles: f64,
    /// Predicted DRAM-contention stretch of one inference at the static
    /// bandwidth share: `latency_cycles − floor_cycles` (accumulated
    /// per segment, so equal only up to float association).
    pub stretch_cycles: f64,
    /// Does one inference finish inside the task's deadline?
    pub deadline_met: bool,
    /// The mapping plan the costs above were evaluated from — kept so
    /// downstream telemetry (report::noc's link-load maps) can re-derive
    /// per-link data with [`crate::cost::segment_loadmap`] on the region's
    /// config without re-running the search.
    pub plan: MappingPlan,
}

impl TaskAssignment {
    /// Energy of one frame of this task's work.
    pub fn frame_energy(&self) -> f64 {
        self.energy * self.invocations as f64
    }

    /// Deadline slack of one inference: `deadline_ms − latency_ms`.
    /// Negative exactly when the deadline is missed.
    pub fn slack_ms(&self) -> f64 {
        self.deadline_ms - self.latency_ms
    }
}

/// One allocation mode of a scenario, fully costed.
#[derive(Debug, Clone)]
pub struct CoschedOutcome {
    /// `"solo"`, `"even_split"`, or `"cosched"`.
    pub mode: &'static str,
    pub assignments: Vec<TaskAssignment>,
    /// Cycles to finish one frame of every task's work: max over tasks for
    /// spatial splits (tasks run concurrently), sum for `solo` (the whole
    /// array is time-multiplexed).
    pub makespan_cycles: f64,
    /// Total energy of one frame of work.
    pub energy: f64,
}

/// Outcome of co-scheduling one scenario.
#[derive(Debug, Clone)]
pub struct CoschedResult {
    pub scenario: String,
    /// Region family that produced [`CoschedResult::cut_tree`].
    pub partition: PartitionKind,
    /// The winning partition as a guillotine cut tree (a right-leaning
    /// chain of vertical cuts under `bands`); serializable through
    /// [`CutTree::to_json`], so plans round-trip through JSON reports.
    pub cut_tree: CutTree,
    pub solo: CoschedOutcome,
    pub even_split: CoschedOutcome,
    pub cosched: CoschedOutcome,
    /// Whole-array occupancy of the co-scheduled winner (validated
    /// non-overlapping by construction).
    pub placement: ScenarioPlacement,
    /// Cost-model evaluations this run added to the cache (cache misses).
    pub evaluations: u64,
    /// Lookups served from the cache during this run.
    pub cache_hits: u64,
    /// Context fingerprints this scenario's search can hit — the live set
    /// cache eviction must keep (full-array plus every candidate region
    /// config, per task).
    pub contexts: Vec<u64>,
}

impl CoschedResult {
    /// Naive-even-split over co-scheduled makespan (≥ 1 by the even-split
    /// seed).
    pub fn speedup(&self) -> f64 {
        self.even_split.makespan_cycles / self.cosched.makespan_cycles.max(1e-12)
    }
}

/// A planned-and-costed (task, region) pair: stage A's table entry.
#[derive(Debug, Clone)]
struct PlannedCost {
    plan: MappingPlan,
    cycles: f64,
    /// Summed per-segment compute floors (`max(pipeline, NoC, GB)`).
    floor_cycles: f64,
    /// Summed per-segment DRAM stretch (`cycles − floor` per segment).
    stretch_cycles: f64,
    energy: f64,
    dram_words: u64,
    worst_load: f64,
}

/// Cost `plan`'s segments through the shared cache. Only valid for
/// heuristic plans: their segments live at granularity scale 1, the same
/// cache coordinates the DSE's seed path uses (`dse::space::build_planned`
/// rebuilds them bit-identically), so entries are shared with any DSE or
/// tuned search over the same (workload, config) context.
fn evaluate_plan_cached(
    graph: &ModelGraph,
    plan: MappingPlan,
    cfg: &ArchConfig,
    cache: &EvalCache,
    run: &RunCounters,
) -> PlannedCost {
    let ctx = context_fingerprint(graph, cfg);
    let topo = Topology::cached(plan.topology, cfg.pe_rows, cfg.pe_cols);
    let em = EnergyModel::default();
    let mut cycles = 0.0f64;
    let mut floor_cycles = 0.0f64;
    let mut stretch_cycles = 0.0f64;
    let mut energy = 0.0f64;
    let mut dram_words = 0u64;
    let mut worst_load = 0.0f64;
    for ps in &plan.segments {
        let key = heuristic_segment_key(ctx, ps, plan.topology);
        let c = cache.get_or_eval_in(key, || evaluate_segment(graph, ps, cfg, &topo, &em), run);
        let floor = c.pipeline_cycles.max(c.noc_cycles).max(c.gb_cycles);
        cycles += c.cycles;
        floor_cycles += floor;
        stretch_cycles += c.cycles - floor;
        energy += c.energy;
        dram_words += c.dram_words;
        worst_load = worst_load.max(c.worst_channel_load_per_interval);
    }
    PlannedCost {
        plan,
        cycles,
        floor_cycles,
        stretch_cycles,
        energy,
        dram_words,
        worst_load,
    }
}

/// Recompute the floor/stretch split for an already-chosen plan by direct
/// segment evaluation — no cache. Tuned plans may carry segments at
/// non-unit granularity, where `heuristic_segment_key` coordinates would
/// collide with the scale-1 entries, so the cached path is off-limits.
/// One extra pass per *winning* tuned plan is noise next to the search.
fn plan_breakdown(graph: &ModelGraph, plan: &MappingPlan, cfg: &ArchConfig) -> (f64, f64) {
    let topo = Topology::cached(plan.topology, cfg.pe_rows, cfg.pe_cols);
    let em = EnergyModel::default();
    let mut floor_cycles = 0.0f64;
    let mut stretch_cycles = 0.0f64;
    for ps in &plan.segments {
        let c = evaluate_segment(graph, ps, cfg, &topo, &em);
        let floor = c.pipeline_cycles.max(c.noc_cycles).max(c.gb_cycles);
        floor_cycles += floor;
        stretch_cycles += c.cycles - floor;
    }
    (floor_cycles, stretch_cycles)
}

/// Plan one task inside one (full-array or region) config.
///
/// Pipeline depth is additionally capped to the region's narrow dimension:
/// the 1-D organizations give each stage at least one column (and the 2-D
/// stage grid at least one cell), so a region can never host more
/// concurrent stages than its narrow side has lanes. On square arrays this
/// equals the usual `√numPEs` cap, so full-array plans are unchanged.
fn plan_in(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    cs: &CoschedConfig,
    cache: &EvalCache,
    run: &RunCounters,
) -> PlannedCost {
    let geom_cap = cfg.pe_rows.min(cfg.pe_cols).max(1);
    let base = PipeOrgan {
        topology: cfg.topology,
        depth_cap: Some(geom_cap),
    };
    if cs.tuned {
        let mut dse = DseConfig::tuned(cfg.topology);
        dse.depth_cap = dse.depth_cap.min(geom_cap);
        if let Some(b) = cs.budget {
            dse.budget = Some(b);
        }
        // Fresh meter per plan: the budget is an exact per-(task, width)
        // window even though the whole scenario shares one cache and one
        // aggregate report counter.
        let plan_run = RunCounters::new();
        let point = tuned_plan(graph, cfg, &base, &dse, cache, &plan_run);
        run.absorb(plan_run.stats());
        let (floor_cycles, stretch_cycles) = plan_breakdown(graph, &point.plan, cfg);
        PlannedCost {
            plan: point.plan,
            cycles: point.cycles,
            floor_cycles,
            stretch_cycles,
            energy: point.energy,
            dram_words: point.dram_words,
            worst_load: point.worst_channel_load,
        }
    } else {
        let plan = base.plan(graph, cfg);
        evaluate_plan_cached(graph, plan, cfg, cache, run)
    }
}

/// Candidate band widths for `n` tasks on `cols` columns: multiples of the
/// quantum, plus the even-split widths (so the naive baseline is always in
/// the searched set), capped so the remaining tasks can still fit.
fn candidate_widths(cols: usize, n: usize, quantum: usize) -> Vec<usize> {
    debug_assert!(n >= 1 && cols >= n);
    let q = quantum.max(1);
    let even = even_widths(cols, n);
    let min_even = *even.iter().min().expect("n >= 1");
    // The narrowest candidate any task may take; every even width fits
    // under the cap this induces (see the partition feasibility argument in
    // DESIGN.md §Cosched).
    let w_min = q.min(min_even).max(1);
    let w_max = cols - (n - 1) * w_min;
    let mut ws: Vec<usize> = (1..).map(|k| k * q).take_while(|&w| w <= w_max).collect();
    ws.extend(even.iter().copied().filter(|&w| w <= w_max));
    ws.sort_unstable();
    ws.dedup();
    ws
}

/// An occupancy-DP label: one frame's objective vector plus the widths
/// chosen so far. Makespan and channel load compose by `max` (tasks run
/// concurrently); energy and DRAM are *frame-scaled* (per-inference cost ×
/// invocations, consistent with the makespan axis) and compose by sum —
/// all monotone, so Pareto pruning of prefixes is sound.
#[derive(Debug, Clone)]
struct AllocLabel {
    makespan: f64,
    energy: f64,
    dram: u64,
    load: f64,
    widths: Vec<usize>,
}

impl ParetoPoint for AllocLabel {
    fn objectives(&self) -> [f64; 4] {
        [self.makespan, self.energy, self.dram as f64, self.load]
    }
}

/// Prune on all four axes (load included, so congestion-diverse
/// allocations survive to compete on the energy tie-break), truncated to
/// `cap` keeping the lowest-makespan labels — the makespan optimum always
/// survives, which is what makes both DPs exact on makespan.
fn prune_labels<T: ParetoPoint>(labels: &mut Vec<T>, cap: usize) {
    if labels.len() <= 1 {
        return;
    }
    let mut kept = pareto_filter_first(std::mem::take(labels), 4);
    kept.truncate(cap.max(1));
    *labels = kept;
}

/// A co-scheduling job of stage A: cost one task on the full array (solo)
/// or inside a band of `width` columns.
enum Job {
    Solo { task: usize },
    Width { task: usize, width: usize },
}

/// Per-region NoC choices the guillotine search considers: the paper's
/// modified mesh (AMP) vs a conventional mesh, plus the configured array
/// topology when it is neither. The configured topology comes first so
/// exact ties keep today's choice.
fn region_topologies(cfg: &ArchConfig) -> Vec<TopologyKind> {
    let mut topos = vec![cfg.topology];
    for t in [TopologyKind::Mesh, TopologyKind::Amp] {
        if !topos.contains(&t) {
            topos.push(t);
        }
    }
    topos
}

/// The architecture restricted to a `rows × cols` region on an explicit
/// per-region topology (costs are translation-invariant, so only the
/// dimensions reach the config).
fn region_topo_config(
    cfg: &ArchConfig,
    rows: usize,
    cols: usize,
    topo: TopologyKind,
) -> ArchConfig {
    let mut rcfg = region_config(
        cfg,
        &Region {
            row0: 0,
            col0: 0,
            rows,
            cols,
        },
    );
    rcfg.topology = topo;
    rcfg
}

/// Candidate guillotine cut offsets inside a `dim`-long side: multiples of
/// the quantum strictly inside `(0, dim)`.
fn cut_positions(dim: usize, quantum: usize) -> Vec<usize> {
    let q = quantum.max(1);
    (1..).map(|k| k * q).take_while(|&a| a < dim).collect()
}

/// All side lengths reachable from `dim` by recursive guillotine cuts on
/// the quantum grid — the fixpoint that lets stage A pre-cost every
/// rectangle the cut-tree DP can visit, in parallel.
fn reachable_dims(dim: usize, quantum: usize) -> Vec<usize> {
    let mut seen: BTreeSet<usize> = BTreeSet::new();
    seen.insert(dim);
    let mut work = vec![dim];
    while let Some(h) = work.pop() {
        for a in cut_positions(h, quantum) {
            for side in [a, h - a] {
                if seen.insert(side) {
                    work.push(side);
                }
            }
        }
    }
    seen.into_iter().collect()
}

/// Context fingerprints the canned scenarios can reach under `cfg` at the
/// default quantum, for *both* partition families. The CLI unions this
/// into the live set of *every* cache save (`dse`, `e2e --tuned`,
/// `cosched`, `serve`), so one shared persistent cache file keeps default
/// co-scheduling — bands and guillotine alike — warm instead of having
/// another subcommand's save prune its region-config entries as stale.
/// Non-default quanta or hand-built scenarios stay warm through their own
/// run's saves (touched contexts are always live) but may be pruned by
/// other subcommands' saves — keep those in a separate `--cache-file`.
pub fn canned_live_contexts(cfg: &ArchConfig) -> HashSet<u64> {
    let mut out = HashSet::new();
    for sc in super::scenario::canned_scenarios() {
        for partition in [PartitionKind::Bands, PartitionKind::Guillotine] {
            let cs = CoschedConfig {
                partition,
                ..CoschedConfig::default()
            };
            out.extend(scenario_contexts(&sc, cfg, &cs));
        }
    }
    out
}

/// Context fingerprints one scenario can reach under `cfg` and `cs`:
/// full-array plus every candidate band config per task, and — under the
/// guillotine partitioner — every reachable rectangle × per-region
/// topology (costs are translation-invariant, so `row0`/`col0` never
/// matter). The single source of truth for both a run's reported live set
/// and the canned static one — they must enumerate identically or cache
/// eviction would wrongly prune warm entries.
fn scenario_contexts(scenario: &Scenario, cfg: &ArchConfig, cs: &CoschedConfig) -> HashSet<u64> {
    let mut out = HashSet::new();
    let n = scenario.tasks.len();
    if n == 0 || cfg.pe_cols < n {
        return out;
    }
    let widths = candidate_widths(cfg.pe_cols, n, cs.quantum);
    // Contexts are a cross product of (task graph) × (region config), so
    // hash each half once and combine: n graph walks + G config JSON
    // serializations instead of n×G full fingerprints. The combined
    // values are identical to `context_fingerprint` by definition.
    let mut arch_fps: Vec<u64> = vec![arch_fingerprint(cfg)];
    for &width in &widths {
        arch_fps.push(arch_fingerprint(&region_topo_config(
            cfg,
            cfg.pe_rows,
            width,
            cfg.topology,
        )));
    }
    if cs.partition == PartitionKind::Guillotine {
        let rset = reachable_dims(cfg.pe_rows, cs.quantum);
        let cset = reachable_dims(cfg.pe_cols, cs.quantum);
        let topos = region_topologies(cfg);
        for &r in &rset {
            for &c in &cset {
                for &topo in &topos {
                    arch_fps.push(arch_fingerprint(&region_topo_config(cfg, r, c, topo)));
                }
            }
        }
    }
    for spec in &scenario.tasks {
        let gfp = graph_fingerprint(&spec.graph);
        for &afp in &arch_fps {
            out.insert(combine_fingerprints(gfp, afp));
        }
    }
    out
}

/// Stage A's table entry for `(task, width)` — `width` must be one of the
/// candidate widths.
fn lookup<'a>(
    table: &'a [Vec<Option<PlannedCost>>],
    widths: &[usize],
    task: usize,
    width: usize,
) -> &'a PlannedCost {
    let wi = widths.iter().position(|&x| x == width).expect("known width");
    table[task][wi].as_ref().expect("stage A filled the table")
}

/// Lazily planned-and-costed (task × rectangle × topology) entries for
/// the guillotine search — pre-warmed in parallel over the reachable
/// rectangle grid and stage A's band entries; anything else (only the
/// vertical-band seed's off-grid widths, in practice) is costed on first
/// use through the same shared `EvalCache`.
struct CostTable<'a> {
    scenario: &'a Scenario,
    cfg: &'a ArchConfig,
    cs: &'a CoschedConfig,
    cache: &'a EvalCache,
    run: &'a RunCounters,
    map: RefCell<HashMap<(usize, usize, usize, TopologyKind), Rc<PlannedCost>>>,
}

impl CostTable<'_> {
    fn insert(&self, task: usize, rows: usize, cols: usize, topo: TopologyKind, pc: PlannedCost) {
        self.map
            .borrow_mut()
            .entry((task, rows, cols, topo))
            .or_insert_with(|| Rc::new(pc));
    }

    fn contains(&self, task: usize, rows: usize, cols: usize, topo: TopologyKind) -> bool {
        self.map.borrow().contains_key(&(task, rows, cols, topo))
    }

    fn cost(&self, task: usize, rows: usize, cols: usize, topo: TopologyKind) -> Rc<PlannedCost> {
        if let Some(pc) = self.map.borrow().get(&(task, rows, cols, topo)) {
            return Rc::clone(pc);
        }
        let rcfg = region_topo_config(self.cfg, rows, cols, topo);
        let pc = Rc::new(plan_in(
            &self.scenario.tasks[task].graph,
            &rcfg,
            self.cs,
            self.cache,
            self.run,
        ));
        Rc::clone(
            self.map
                .borrow_mut()
                .entry((task, rows, cols, topo))
                .or_insert(pc),
        )
    }
}

/// A set of task indices encoded as a `u64` bitset (bit `t` set ⇔ task
/// `t` in the set) — the guillotine DP's memo-key representation of task
/// subsets. Replaces sorted `Vec<usize>` keys: it is `Copy`, hashes as
/// one word, and subset enumeration is two bit operations per step. Two
/// sets are equal exactly when they contain the same tasks, whatever
/// order they were built in — the agreement with sorted-Vec keys that
/// `tests/property_invariants.rs` checks on random subsets.
///
/// # Examples
///
/// ```
/// use pipeorgan::cosched::TaskSet;
///
/// let s = TaskSet::from_tasks(&[2, 0, 2]);
/// assert_eq!(s.to_sorted_vec(), vec![0, 2]);
/// assert_eq!(s.len(), 2);
/// assert!(s.contains(2) && !s.contains(1));
/// // Proper subsets of {0, 2}: {2} then {0}, descending bitset order.
/// let subs: Vec<_> = s.proper_subsets().map(TaskSet::to_sorted_vec).collect();
/// assert_eq!(subs, vec![vec![2], vec![0]]);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskSet(u64);

impl TaskSet {
    /// Largest task index a set can hold (+1): one bit per task.
    pub const MAX_TASKS: usize = 64;

    /// The empty set.
    pub fn empty() -> TaskSet {
        TaskSet(0)
    }

    /// The full set `{0, …, n-1}`.
    pub fn full(n: usize) -> TaskSet {
        assert!(n <= Self::MAX_TASKS, "at most {} tasks", Self::MAX_TASKS);
        if n == Self::MAX_TASKS {
            TaskSet(u64::MAX)
        } else {
            TaskSet((1u64 << n) - 1)
        }
    }

    /// The set of exactly the given task indices; order and duplicates
    /// are irrelevant, which is what makes the bitset a sound stand-in
    /// for a sorted, deduplicated `Vec<usize>` key.
    pub fn from_tasks(tasks: &[usize]) -> TaskSet {
        let mut bits = 0u64;
        for &t in tasks {
            assert!(t < Self::MAX_TASKS, "task index {t} out of range");
            bits |= 1u64 << t;
        }
        TaskSet(bits)
    }

    /// The raw bit pattern (bit `t` ⇔ task `t`).
    pub fn bits(self) -> u64 {
        self.0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// Number of tasks in the set.
    pub fn len(self) -> usize {
        self.0.count_ones() as usize
    }

    pub fn contains(self, task: usize) -> bool {
        task < Self::MAX_TASKS && self.0 & (1u64 << task) != 0
    }

    /// The single member of a singleton set, `None` otherwise.
    pub fn sole_member(self) -> Option<usize> {
        if self.len() == 1 {
            Some(self.0.trailing_zeros() as usize)
        } else {
            None
        }
    }

    /// Members in ascending order — the sorted-Vec key this set replaces.
    pub fn to_sorted_vec(self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.len());
        let mut bits = self.0;
        while bits != 0 {
            out.push(bits.trailing_zeros() as usize);
            bits &= bits - 1;
        }
        out
    }

    /// Set difference `universe \ self` — the high side of a split whose
    /// low side is `self`.
    pub fn complement_in(self, universe: TaskSet) -> TaskSet {
        TaskSet(universe.0 & !self.0)
    }

    /// Every non-empty *proper* subset, in descending bitset order — the
    /// exact order the classic `lo = (lo - 1) & mask` loop walks, which
    /// the DP relies on for reproducible label accumulation.
    pub fn proper_subsets(self) -> ProperSubsets {
        ProperSubsets {
            mask: self.0,
            next: self.0.wrapping_sub(1) & self.0,
        }
    }
}

/// Iterator returned by [`TaskSet::proper_subsets`].
pub struct ProperSubsets {
    mask: u64,
    next: u64,
}

impl Iterator for ProperSubsets {
    type Item = TaskSet;

    fn next(&mut self) -> Option<TaskSet> {
        if self.next == 0 {
            return None;
        }
        let cur = self.next;
        self.next = cur.wrapping_sub(1) & self.mask;
        Some(TaskSet(cur))
    }
}

/// A guillotine DP state: rectangle dimensions plus the task subset to
/// place, packed `Copy`-small (dims fit `u16` comfortably) so memo keys
/// hash as a few words instead of a heap vector. `Ord` gives the
/// deterministic per-level expansion order of the parallel beam.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct StateKey {
    rows: u16,
    cols: u16,
    tasks: TaskSet,
}

impl StateKey {
    fn new(rows: usize, cols: usize, tasks: TaskSet) -> StateKey {
        debug_assert!(rows <= u16::MAX as usize && cols <= u16::MAX as usize);
        StateKey {
            rows: rows as u16,
            cols: cols as u16,
            tasks,
        }
    }

    fn rows(self) -> usize {
        self.rows as usize
    }

    fn cols(self) -> usize {
        self.cols as usize
    }
}

/// Where a beam label came from: a leaf assignment, or a cut composing
/// two child labels referenced by (child state, index into that state's
/// *final pruned* label vector — children are always finished before any
/// parent expands). Labels are `Copy`, so the beam's inner product loop
/// never clones a cut tree; [`GuillotineBeam::rebuild`] re-materializes
/// the tree for the one winning label by walking these parent pointers.
#[derive(Debug, Clone, Copy)]
enum LabelSrc {
    Leaf {
        task: usize,
        topology: TopologyKind,
    },
    Cut {
        axis: CutAxis,
        at: u16,
        lo: (StateKey, u32),
        hi: (StateKey, u32),
    },
}

/// A guillotine-DP label: one frame's objective vector for a (rectangle,
/// task-set) state plus the provenance that reconstructs its cut tree on
/// demand. Composition mirrors the band labels: makespan/load by `max`,
/// energy/DRAM by sum.
#[derive(Debug, Clone, Copy)]
struct BeamLabel {
    makespan: f64,
    energy: f64,
    dram: u64,
    load: f64,
    src: LabelSrc,
}

impl ParetoPoint for BeamLabel {
    fn objectives(&self) -> [f64; 4] {
        [self.makespan, self.energy, self.dram as f64, self.load]
    }
}

/// Visit every feasible (cut, low child, high child) decomposition of a
/// composed state, in the DP's canonical order: vertical cuts then
/// horizontal, positions ascending on the quantum grid, low-side subsets
/// in descending bitset order. Every proper non-empty subset goes to the
/// low side once; the complement takes the high side. Both orientations
/// are enumerated (the grid need not be symmetric around the cut), so
/// nothing is lost. Shared by state discovery and expansion so the two
/// can never disagree about which children exist.
fn for_each_split(
    key: StateKey,
    quantum: usize,
    mut f: impl FnMut(CutAxis, usize, StateKey, StateKey),
) {
    let (rows, cols) = (key.rows(), key.cols());
    let q = quantum.max(1);
    for (axis, dim) in [(CutAxis::Vertical, cols), (CutAxis::Horizontal, rows)] {
        for at in (1..).map(|k| k * q).take_while(|&a| a < dim) {
            for lo in key.tasks.proper_subsets() {
                let hi = lo.complement_in(key.tasks);
                let ((lr, lc), (hr, hc)) = match axis {
                    CutAxis::Vertical => ((rows, at), (rows, cols - at)),
                    CutAxis::Horizontal => ((at, cols), (rows - at, cols)),
                };
                if lr * lc >= lo.len() && hr * hc >= hi.len() {
                    f(axis, at, StateKey::new(lr, lc, lo), StateKey::new(hr, hc, hi));
                }
            }
        }
    }
}

/// Expand one composed (≥ 2 tasks) state against fully-computed child
/// levels. A free function on purpose: the per-level parallel sweep
/// shares `memo` read-only across `run_queue` workers, and borrowing the
/// whole beam struct would drag the non-`Sync` cost table (interior
/// `RefCell`) into the closure. Returns the state's final pruned labels
/// plus counter deltas (memo lookups, labels pruned) the caller reports
/// to obs in one batch.
fn expand_composed(
    key: StateKey,
    memo: &HashMap<StateKey, Vec<BeamLabel>>,
    quantum: usize,
    max_labels: usize,
) -> (Vec<BeamLabel>, u64, u64) {
    let count = key.tasks.len();
    let mut labels: Vec<BeamLabel> = Vec::new();
    let mut lookups = 0u64;
    let mut pruned = 0u64;
    if key.rows() * key.cols() >= count {
        for_each_split(key, quantum, |axis, at, lo_key, hi_key| {
            let lo_labels = memo.get(&lo_key).expect("children finished level-by-level");
            let hi_labels = memo.get(&hi_key).expect("children finished level-by-level");
            lookups += 2;
            for (i, a) in lo_labels.iter().enumerate() {
                for (j, b) in hi_labels.iter().enumerate() {
                    labels.push(BeamLabel {
                        makespan: a.makespan.max(b.makespan),
                        energy: a.energy + b.energy,
                        dram: a.dram.saturating_add(b.dram),
                        load: a.load.max(b.load),
                        src: LabelSrc::Cut {
                            axis,
                            at: at as u16,
                            lo: (lo_key, i as u32),
                            hi: (hi_key, j as u32),
                        },
                    });
                }
            }
            if labels.len() > 8 * max_labels {
                let before = labels.len();
                prune_labels(&mut labels, max_labels);
                pruned += (before - labels.len()) as u64;
            }
        });
    }
    let before = labels.len();
    prune_labels(&mut labels, max_labels);
    pruned += (before - labels.len()) as u64;
    (labels, lookups, pruned)
}

/// The beam over cut trees: a memoized DP on (rectangle dims, task set)
/// states. Single-task states pick the best per-region topology; larger
/// states enumerate cut axis × quantum-grid position × every proper
/// task-subset split, composing child Pareto sets and pruning each state
/// to `max_labels` lowest-makespan-first (so the makespan optimum over
/// the cut grid always survives). Dims are translation-invariant, which
/// is what makes the memoization sound. States are solved bottom-up by
/// task-set size, each level fanned out over `coordinator::run_queue`.
struct GuillotineBeam<'a, 'b> {
    table: &'b CostTable<'a>,
    /// Per-task invocations per frame (frame-scales energy/DRAM/busy).
    inv: &'b [f64],
    topos: &'b [TopologyKind],
    quantum: usize,
    max_labels: usize,
    memo: HashMap<StateKey, Vec<BeamLabel>>,
}

impl GuillotineBeam<'_, '_> {
    /// Every state reachable from `root`, grouped by task-set size
    /// (`levels[k]` holds the sorted size-`k` states). The structural
    /// walk visits exactly the feasible child pairs `for_each_split`
    /// yields, so the bottom-up sweep computes precisely the states a
    /// top-down memoized recursion would have.
    fn reachable_states(&self, root: StateKey) -> Vec<Vec<StateKey>> {
        let mut seen: HashSet<StateKey> = HashSet::new();
        seen.insert(root);
        let mut stack = vec![root];
        while let Some(s) = stack.pop() {
            if s.tasks.len() <= 1 || s.rows() * s.cols() < s.tasks.len() {
                continue;
            }
            for_each_split(s, self.quantum, |_axis, _at, lo, hi| {
                for child in [lo, hi] {
                    if seen.insert(child) {
                        stack.push(child);
                    }
                }
            });
        }
        let mut levels: Vec<Vec<StateKey>> = vec![Vec::new(); root.tasks.len() + 1];
        for s in seen {
            levels[s.tasks.len()].push(s);
        }
        for level in levels.iter_mut() {
            level.sort_unstable();
        }
        levels
    }

    /// Labels of a single-task state: one per candidate per-region
    /// topology, straight from the (pre-warmed) cost table.
    fn expand_leaf(&self, key: StateKey) -> Vec<BeamLabel> {
        let task = key.tasks.sole_member().expect("leaf states hold one task");
        let mut labels = Vec::with_capacity(self.topos.len());
        for &topo in self.topos {
            let pc = self.table.cost(task, key.rows(), key.cols(), topo);
            labels.push(BeamLabel {
                makespan: pc.cycles * self.inv[task],
                energy: pc.energy * self.inv[task],
                dram: pc.dram_words.saturating_mul(self.inv[task] as u64),
                load: pc.worst_load,
                src: LabelSrc::Leaf {
                    task,
                    topology: topo,
                },
            });
        }
        labels
    }

    /// Run the bottom-up sweep and return the root's final labels.
    ///
    /// Level 1 reads the `RefCell`-backed cost table and stays
    /// sequential (after the parallel grid pre-warm these are pure memo
    /// lookups); every larger level fans its states out over
    /// `run_queue`. Per-state label accumulation is byte-for-byte the
    /// sequential order, children are always final before parents, and
    /// results merge in the level's sorted state order (`run_queue`
    /// preserves input order) — so any worker count produces
    /// bit-identical label sets, which `tests/cosched_integration.rs`
    /// asserts against a forced single-thread run.
    fn solve(&mut self, root: StateKey, workers: usize) -> Vec<BeamLabel> {
        let obs = self.table.cs.obs.clone();
        let levels = self.reachable_states(root);
        for (size, level) in levels.iter().enumerate().skip(1) {
            if level.is_empty() {
                continue;
            }
            if size == 1 {
                for &key in level {
                    let mut labels = self.expand_leaf(key);
                    let before = labels.len();
                    prune_labels(&mut labels, self.max_labels);
                    obs.count("cosched.guillotine.state_expanded", 1);
                    obs.count(
                        "cosched.guillotine.labels_pruned",
                        (before - labels.len()) as u64,
                    );
                    self.memo.insert(key, labels);
                }
                continue;
            }
            let (quantum, max_labels) = (self.quantum, self.max_labels);
            let results = {
                let memo = &self.memo;
                run_queue(level.clone(), workers, |key| {
                    expand_composed(key, memo, quantum, max_labels)
                })
            };
            for (key, (labels, lookups, pruned)) in level.iter().zip(results) {
                obs.count("cosched.guillotine.state_expanded", 1);
                obs.count("cosched.guillotine.memo_hit", lookups);
                obs.count("cosched.guillotine.labels_pruned", pruned);
                self.memo.insert(*key, labels);
            }
        }
        self.memo.get(&root).cloned().unwrap_or_default()
    }

    /// Re-materialize the cut tree of one surviving label by walking its
    /// parent pointers through the memo — the only place the guillotine
    /// search ever builds a tree.
    fn rebuild(&self, key: StateKey, idx: usize) -> CutTree {
        match self.memo[&key][idx].src {
            LabelSrc::Leaf { task, topology } => CutTree::Leaf { task, topology },
            LabelSrc::Cut { axis, at, lo, hi } => CutTree::Cut {
                axis,
                at: at as usize,
                low: Box::new(self.rebuild(lo.0, lo.1 as usize)),
                high: Box::new(self.rebuild(hi.0, hi.1 as usize)),
            },
        }
    }
}

/// Makespan/energy of a complete cut tree, costed through the table —
/// used to seed the vertical-band winner into the guillotine finals (its
/// leaf costs were already computed by stage A, so this is pure lookup).
/// Only the tie-break axes are needed; the caller already owns the tree.
struct SeedLabel {
    makespan: f64,
    energy: f64,
}

fn tree_label(
    tree: &CutTree,
    rows: usize,
    cols: usize,
    table: &CostTable<'_>,
    inv: &[f64],
) -> Result<SeedLabel, String> {
    let (partition, topos) = tree.partition(rows, cols)?;
    let mut lab = SeedLabel {
        makespan: 0.0,
        energy: 0.0,
    };
    for (task, (region, &topo)) in partition.regions.iter().zip(&topos).enumerate() {
        let pc = table.cost(task, region.rows, region.cols, topo);
        lab.makespan = lab.makespan.max(pc.cycles * inv[task]);
        lab.energy += pc.energy * inv[task];
    }
    Ok(lab)
}

/// Co-schedule one scenario onto the array described by `cfg`.
///
/// The cache is caller-owned and shared: pass one hydrated via
/// `EvalCache::load_file` to warm-start repeated scenarios across
/// processes. `workers` parallelizes the per-(task, region) costing sweep
/// and the guillotine beam's per-level state expansion; results are
/// bit-identical for any worker count.
///
/// # Examples
///
/// ```
/// use pipeorgan::config::ArchConfig;
/// use pipeorgan::cosched::{schedule, CoschedConfig, Scenario, TaskSpec};
/// use pipeorgan::dse::EvalCache;
/// use pipeorgan::workloads::synthetic;
///
/// let cfg = ArchConfig { pe_rows: 8, pe_cols: 8, ..ArchConfig::default() };
/// let scenario = Scenario::new(
///     "doc-pair",
///     vec![
///         TaskSpec::new(synthetic::aw_chain(2.0, 3), 30.0),
///         TaskSpec::new(synthetic::pointwise_conv_segment(2), 60.0),
///     ],
/// );
/// let cache = EvalCache::new();
/// let result = schedule(&scenario, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
///
/// // One region per task, and the searched split never loses to the
/// // naive even split (the even-split label is seeded into the DP).
/// assert_eq!(result.cosched.assignments.len(), 2);
/// assert!(result.cosched.makespan_cycles <= result.even_split.makespan_cycles);
/// ```
pub fn schedule(
    scenario: &Scenario,
    cfg: &ArchConfig,
    cs: &CoschedConfig,
    cache: &EvalCache,
    workers: usize,
) -> Result<CoschedResult, String> {
    scenario.validate()?;
    let n = scenario.tasks.len();
    let rows = cfg.pe_rows;
    let cols = cfg.pe_cols;
    if cols < n {
        return Err(format!(
            "scenario `{}` has {n} tasks but the array has only {cols} columns",
            scenario.name
        ));
    }
    if cs.partition == PartitionKind::Guillotine && n > 8 {
        return Err(format!(
            "scenario `{}` has {n} tasks; the guillotine search supports at most 8 \
             (use --partition bands)",
            scenario.name
        ));
    }
    let run = RunCounters::new();
    let widths = candidate_widths(cols, n, cs.quantum);

    // ---- stage A: parallel, memoized (task × width) costing --------------
    let mut jobs: Vec<Job> = Vec::with_capacity(n * (widths.len() + 1));
    for task in 0..n {
        jobs.push(Job::Solo { task });
        for &width in &widths {
            jobs.push(Job::Width { task, width });
        }
    }
    let outcomes: Vec<(usize, Option<usize>, PlannedCost)> = cs.obs.timed("cosched.stage_a", || {
        run_queue(jobs, workers, |job| match job {
            Job::Solo { task } => {
                let pc = plan_in(&scenario.tasks[task].graph, cfg, cs, cache, &run);
                (task, None, pc)
            }
            Job::Width { task, width } => {
                let rcfg = region_topo_config(cfg, rows, width, cfg.topology);
                let pc = plan_in(&scenario.tasks[task].graph, &rcfg, cs, cache, &run);
                (task, Some(width), pc)
            }
        })
    });
    let mut solo: Vec<Option<PlannedCost>> = vec![None; n];
    let mut table: Vec<Vec<Option<PlannedCost>>> = vec![vec![None; widths.len()]; n];
    for (task, width, pc) in outcomes {
        match width {
            None => solo[task] = Some(pc),
            Some(w) => {
                let wi = widths.iter().position(|&x| x == w).expect("known width");
                table[task][wi] = Some(pc);
            }
        }
    }

    // The live-context set this run can hit (see `scenario_contexts`).
    let contexts = scenario_contexts(scenario, cfg, cs);

    let inv: Vec<f64> = scenario.tasks.iter().map(|t| t.invocations() as f64).collect();

    // ---- stage B: occupancy-state DP over tasks --------------------------
    let even = even_widths(cols, n);
    let best = cs.obs.timed("cosched.stage_b", || {
        let w_min = *widths.first().expect("candidate set is never empty");
        let mut states: Vec<Vec<AllocLabel>> = vec![Vec::new(); cols + 1];
        states[0].push(AllocLabel {
            makespan: 0.0,
            energy: 0.0,
            dram: 0,
            load: 0.0,
            widths: Vec::new(),
        });
        for task in 0..n {
            let remaining = n - task - 1;
            let mut next: Vec<Vec<AllocLabel>> = vec![Vec::new(); cols + 1];
            for (used, labels) in states.iter().enumerate() {
                if labels.is_empty() {
                    continue;
                }
                for (wi, &w) in widths.iter().enumerate() {
                    if used + w > cols {
                        break; // widths ascend
                    }
                    if cols - used - w < remaining * w_min {
                        continue; // later tasks could no longer fit
                    }
                    let pc = table[task][wi].as_ref().expect("stage A filled the table");
                    let busy = pc.cycles * inv[task];
                    let frame_energy = pc.energy * inv[task];
                    let frame_dram = pc.dram_words.saturating_mul(inv[task] as u64);
                    for lab in labels {
                        let mut widths_so_far = lab.widths.clone();
                        widths_so_far.push(w);
                        next[used + w].push(AllocLabel {
                            makespan: lab.makespan.max(busy),
                            energy: lab.energy + frame_energy,
                            dram: lab.dram.saturating_add(frame_dram),
                            load: lab.load.max(pc.worst_load),
                            widths: widths_so_far,
                        });
                    }
                }
            }
            for labels in next.iter_mut() {
                prune_labels(labels, cs.max_labels);
            }
            states = next;
        }
        let mut finals: Vec<AllocLabel> = states.into_iter().flatten().collect();

        // Seed the even split as a complete label: truncation can never
        // lose it, so cosched ≤ even_split by construction.
        let even_label = {
            let mut lab = AllocLabel {
                makespan: 0.0,
                energy: 0.0,
                dram: 0,
                load: 0.0,
                widths: even.clone(),
            };
            for (task, &w) in even.iter().enumerate() {
                let pc = lookup(&table, &widths, task, w);
                lab.makespan = lab.makespan.max(pc.cycles * inv[task]);
                lab.energy += pc.energy * inv[task];
                lab.dram = lab
                    .dram
                    .saturating_add(pc.dram_words.saturating_mul(inv[task] as u64));
                lab.load = lab.load.max(pc.worst_load);
            }
            lab
        };
        finals.push(even_label);
        finals
            .into_iter()
            .min_by(|a, b| {
                (a.makespan, a.energy)
                    .partial_cmp(&(b.makespan, b.energy))
                    .expect("objectives are finite")
            })
            .expect("the even-split seed is always present")
    });

    // ---- shared cost table (both partition families draw from it) --------
    // The guillotine grid is computed up front so the table can be sized
    // once for everything the cut-tree DP can possibly touch — stage A's
    // band entries plus the full (task × reachable rect × topology)
    // grid — instead of rehashing as the lazy fills trickle in.
    let guillotine_grid = if cs.partition == PartitionKind::Guillotine {
        Some((
            reachable_dims(rows, cs.quantum),
            reachable_dims(cols, cs.quantum),
            region_topologies(cfg),
        ))
    } else {
        None
    };
    let table_capacity = n * (widths.len() + 1)
        + guillotine_grid
            .as_ref()
            .map_or(0, |(rset, cset, topos)| n * rset.len() * cset.len() * topos.len());
    let cost_table = CostTable {
        scenario,
        cfg,
        cs,
        cache,
        run: &run,
        map: RefCell::new(HashMap::with_capacity(table_capacity)),
    };
    for (task, row) in table.iter().enumerate() {
        for (wi, pc) in row.iter().enumerate() {
            if let Some(pc) = pc {
                cost_table.insert(task, rows, widths[wi], cfg.topology, pc.clone());
            }
        }
    }

    // The 1-D winner as a cut tree (unused trailing columns become an
    // explicit idle rectangle, so realized regions match the DP label
    // exactly): the bands result itself, and the seed that makes the
    // guillotine search never-lose against it.
    let bands_tree = CutTree::vertical_bands(&best.widths, cols, cfg.topology);

    // ---- stage C (guillotine only): beam over cut trees ------------------
    let cut_tree = match cs.partition {
        PartitionKind::Bands => bands_tree,
        PartitionKind::Guillotine => cs.obs.timed("cosched.stage_c", || {
            let (rset, cset, topos) = guillotine_grid
                .as_ref()
                .expect("guillotine grid precomputed for this partition kind");
            // Pre-cost every rectangle on the cut grid, in parallel.
            let mut grid_jobs: Vec<(usize, usize, usize, TopologyKind)> = Vec::new();
            for task in 0..n {
                for &r in rset {
                    for &c in cset {
                        for &topo in topos {
                            if !cost_table.contains(task, r, c, topo) {
                                grid_jobs.push((task, r, c, topo));
                            }
                        }
                    }
                }
            }
            let costed = run_queue(grid_jobs, workers, |(task, r, c, topo)| {
                let rcfg = region_topo_config(cfg, r, c, topo);
                let pc = plan_in(&scenario.tasks[task].graph, &rcfg, cs, cache, &run);
                (task, r, c, topo, pc)
            });
            for (task, r, c, topo, pc) in costed {
                cost_table.insert(task, r, c, topo, pc);
            }
            let mut gs = GuillotineBeam {
                table: &cost_table,
                inv: &inv,
                topos,
                quantum: cs.quantum,
                max_labels: cs.max_labels,
                memo: HashMap::new(),
            };
            let root = StateKey::new(rows, cols, TaskSet::full(n));
            let gfinals = gs.solve(root, workers);
            // The beam's pick: first label minimizing (makespan, energy) —
            // the same first-minimal rule `min_by` applied before.
            let beam_best = gfinals.iter().enumerate().min_by(|(_, a), (_, b)| {
                (a.makespan, a.energy)
                    .partial_cmp(&(b.makespan, b.energy))
                    .expect("objectives are finite")
            });
            // Seed the vertical-band winner: 2-D never loses to 1-D. The
            // seed was historically appended *after* the beam labels, so
            // on exact ties the beam label wins — preserved here by only
            // falling back to the bands tree on a strictly worse beam.
            let seed = tree_label(&bands_tree, rows, cols, &cost_table, &inv)?;
            Ok::<CutTree, String>(match beam_best {
                Some((idx, lab))
                    if (lab.makespan, lab.energy)
                        .partial_cmp(&(seed.makespan, seed.energy))
                        .expect("objectives are finite")
                        != std::cmp::Ordering::Greater =>
                {
                    gs.rebuild(root, idx)
                }
                _ => bands_tree,
            })
        })?,
    };

    // ---- assemble the three reported outcomes ----------------------------
    let band_outcome = |mode: &'static str, widths_of: &[usize]| -> CoschedOutcome {
        let partition = RegionPartition::vertical(rows, cols, widths_of);
        let assignments: Vec<TaskAssignment> = scenario
            .tasks
            .iter()
            .zip(&partition.regions)
            .enumerate()
            .map(|(task, (spec, &region))| {
                assignment(
                    spec,
                    region,
                    cfg.topology,
                    lookup(&table, &widths, task, region.cols),
                    cfg,
                )
            })
            .collect();
        outcome(mode, assignments, false)
    };
    let even_outcome = band_outcome("even_split", &even);

    let full = Region {
        row0: 0,
        col0: 0,
        rows,
        cols,
    };
    let solo_assignments: Vec<TaskAssignment> = scenario
        .tasks
        .iter()
        .enumerate()
        .map(|(task, spec)| {
            let pc = solo[task].as_ref().expect("stage A filled solo plans");
            assignment(spec, full, cfg.topology, pc, cfg)
        })
        .collect();
    let solo_outcome = outcome("solo", solo_assignments, true);

    // The winner, realized: regions indexed by task, costed through the
    // shared table (pure lookups), composed into a validated whole-array
    // placement (structural non-overlap).
    let (partition, region_topos) = cut_tree.partition(rows, cols)?;
    let cosched_assignments: Vec<TaskAssignment> = scenario
        .tasks
        .iter()
        .enumerate()
        .map(|(task, spec)| {
            let region = partition.regions[task];
            let topo = region_topos[task];
            let pc = cost_table.cost(task, region.rows, region.cols, topo);
            assignment(spec, region, topo, &pc, cfg)
        })
        .collect();
    let cosched_outcome = outcome("cosched", cosched_assignments, false);

    let placements: Vec<Placement> = partition
        .regions
        .iter()
        .enumerate()
        .map(|(task, region)| {
            let pc = cost_table.cost(task, region.rows, region.cols, region_topos[task]);
            representative_placement(&pc, region)
        })
        .collect();
    let placement = ScenarioPlacement::compose(&partition, &placements)?;

    let stats = run.stats();
    cs.obs.count("cosched.cache.hits", stats.hits);
    cs.obs.count("cosched.cache.misses", stats.misses);
    Ok(CoschedResult {
        scenario: scenario.name.clone(),
        partition: cs.partition,
        cut_tree,
        solo: solo_outcome,
        even_split: even_outcome,
        cosched: cosched_outcome,
        placement,
        evaluations: stats.misses,
        cache_hits: stats.hits,
        contexts: contexts.into_iter().collect(),
    })
}

/// Cost one task's share of an allocation.
fn assignment(
    spec: &super::scenario::TaskSpec,
    region: Region,
    topology: TopologyKind,
    pc: &PlannedCost,
    cfg: &ArchConfig,
) -> TaskAssignment {
    let invocations = spec.invocations();
    let latency_s = pc.cycles / cfg.clock_hz.max(1.0);
    TaskAssignment {
        task: spec.name().to_string(),
        region,
        topology,
        rate_hz: spec.rate_hz,
        invocations,
        latency_cycles: pc.cycles,
        latency_ms: latency_s * 1e3,
        deadline_ms: spec.deadline_ms,
        busy_cycles: pc.cycles * invocations as f64,
        energy: pc.energy,
        dram_words: pc.dram_words,
        worst_channel_load: pc.worst_load,
        floor_cycles: pc.floor_cycles,
        stretch_cycles: pc.stretch_cycles,
        // Compared in ms so the verdict agrees bit-for-bit with `slack_ms`.
        deadline_met: latency_s * 1e3 <= spec.deadline_ms,
        plan: pc.plan.clone(),
    }
}

/// Roll assignments up into an outcome. `time_multiplexed` sums busy
/// cycles (solo: one array shared in time); spatial splits take the max
/// (regions run concurrently). Energy is always frame-scaled — a task at
/// 120 Hz spends 120× its per-inference energy per frame.
fn outcome(
    mode: &'static str,
    assignments: Vec<TaskAssignment>,
    time_multiplexed: bool,
) -> CoschedOutcome {
    let busies = assignments.iter().map(|a| a.busy_cycles);
    let makespan_cycles = if time_multiplexed {
        busies.sum()
    } else {
        busies.fold(0.0, f64::max)
    };
    CoschedOutcome {
        energy: assignments.iter().map(TaskAssignment::frame_energy).sum(),
        mode,
        assignments,
        makespan_cycles,
    }
}

/// The placement rendered for a task inside its region: its deepest
/// segment's stage layout (the most spatially interesting moment of the
/// plan; other segments time-multiplex the same region).
fn representative_placement(pc: &PlannedCost, region: &Region) -> Placement {
    let seg = pc
        .plan
        .segments
        .iter()
        .max_by_key(|s| s.depth())
        .expect("plans are never empty");
    Placement::build(region.rows, region.cols, seg.organization, &seg.pe_alloc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosched::TaskSpec;
    use crate::workloads::synthetic;

    fn small_cfg() -> ArchConfig {
        ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        }
    }

    /// A fast synthetic scenario (real zoo scenarios are covered by the
    /// integration tests).
    fn tiny_scenario() -> Scenario {
        let mut a = synthetic::aw_chain(3.0, 4);
        a.name = "chain_a".into();
        let mut b = synthetic::pointwise_conv_segment(3);
        b.name = "chain_b".into();
        Scenario::new("tiny", vec![TaskSpec::new(a, 30.0), TaskSpec::new(b, 60.0)])
    }

    #[test]
    fn candidate_widths_include_even_split_and_fit() {
        let ws = candidate_widths(32, 3, 4);
        for w in even_widths(32, 3) {
            assert!(ws.contains(&w), "even width {w} missing from {ws:?}");
        }
        assert!(ws.windows(2).all(|p| p[0] < p[1]), "sorted: {ws:?}");
        // Oversized quantum still leaves the even widths.
        let ws = candidate_widths(16, 3, 10);
        assert!(!ws.is_empty());
        assert!(ws.contains(&5) && ws.contains(&6));
        let max = *ws.iter().max().unwrap();
        assert!(max <= 16 - 2 * ws[0]);
    }

    #[test]
    fn cut_grid_helpers_cover_the_quantum_lattice() {
        assert_eq!(cut_positions(16, 4), vec![4, 8, 12]);
        assert_eq!(cut_positions(4, 4), Vec::<usize>::new());
        assert_eq!(cut_positions(17, 8), vec![8, 16]);
        let dims = reachable_dims(16, 4);
        assert_eq!(dims, vec![4, 8, 12, 16]);
        // Non-multiple array sides reach both residue classes.
        let dims = reachable_dims(17, 8);
        assert!(dims.contains(&17) && dims.contains(&8) && dims.contains(&9));
        for &d in &dims {
            assert!((1..=17).contains(&d));
        }
    }

    #[test]
    fn cosched_never_loses_to_even_split_on_synthetic_scenario() {
        let cfg = small_cfg();
        let cs = CoschedConfig::default();
        let r = schedule(&tiny_scenario(), &cfg, &cs, &EvalCache::new(), 2).unwrap();
        assert!(
            r.cosched.makespan_cycles <= r.even_split.makespan_cycles * 1.0001,
            "cosched {} vs even {}",
            r.cosched.makespan_cycles,
            r.even_split.makespan_cycles
        );
        assert!(r.speedup() >= 0.9999);
        assert_eq!(r.partition, PartitionKind::Bands);
        // Two tasks assigned, regions non-overlapping, everything positive.
        for o in [&r.solo, &r.even_split, &r.cosched] {
            assert_eq!(o.assignments.len(), 2, "{}", o.mode);
            assert!(o.makespan_cycles > 0.0 && o.energy > 0.0, "{}", o.mode);
            for a in &o.assignments {
                assert!(a.latency_cycles > 0.0 && a.busy_cycles >= a.latency_cycles);
                assert_eq!(a.topology, cfg.topology, "bands keep the array topology");
            }
        }
        assert!(r.evaluations > 0);
        assert!(!r.contexts.is_empty());
        // The bands winner round-trips through its cut tree.
        let (p, topos) = r.cut_tree.partition(cfg.pe_rows, cfg.pe_cols).unwrap();
        let regions: Vec<Region> = r.cosched.assignments.iter().map(|a| a.region).collect();
        assert_eq!(p.regions, regions);
        assert_eq!(topos, vec![cfg.topology; 2]);
    }

    #[test]
    fn guillotine_never_loses_to_bands_on_synthetic_scenario() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let bands = schedule(
            &tiny_scenario(),
            &cfg,
            &CoschedConfig::default(),
            &cache,
            2,
        )
        .unwrap();
        let gcs = CoschedConfig {
            partition: PartitionKind::Guillotine,
            ..CoschedConfig::default()
        };
        let g = schedule(&tiny_scenario(), &cfg, &gcs, &cache, 2).unwrap();
        assert_eq!(g.partition, PartitionKind::Guillotine);
        assert!(
            g.cosched.makespan_cycles <= bands.cosched.makespan_cycles * 1.0001,
            "guillotine {} vs bands {}",
            g.cosched.makespan_cycles,
            bands.cosched.makespan_cycles
        );
        // The winner's tree realizes exactly the reported regions and
        // topologies, and the composed placement tiles the array.
        let (p, topos) = g.cut_tree.partition(cfg.pe_rows, cfg.pe_cols).unwrap();
        for (task, a) in g.cosched.assignments.iter().enumerate() {
            assert_eq!(p.regions[task], a.region);
            assert_eq!(topos[task], a.topology);
            assert!(a.region.num_pes() > 0);
        }
        let owned: usize = (0..2).map(|t| g.placement.task_pes(t)).sum();
        assert_eq!(owned + g.placement.idle_pes(), cfg.num_pes());
        // Guillotine live contexts strictly contain the band ones.
        let band_ctx: HashSet<u64> = bands.contexts.iter().copied().collect();
        let g_ctx: HashSet<u64> = g.contexts.iter().copied().collect();
        assert!(band_ctx.is_subset(&g_ctx));
        assert!(g_ctx.len() > band_ctx.len());
    }

    #[test]
    fn solo_makespan_is_the_sum_spatial_is_the_max() {
        let cfg = small_cfg();
        let cs = CoschedConfig::default();
        let r = schedule(&tiny_scenario(), &cfg, &cs, &EvalCache::new(), 1).unwrap();
        let solo_sum: f64 = r.solo.assignments.iter().map(|a| a.busy_cycles).sum();
        assert!((r.solo.makespan_cycles - solo_sum).abs() < 1e-6 * solo_sum.max(1.0));
        let even_max = r
            .even_split
            .assignments
            .iter()
            .map(|a| a.busy_cycles)
            .fold(0.0, f64::max);
        assert_eq!(r.even_split.makespan_cycles, even_max);
    }

    #[test]
    fn shared_cache_makes_rescheduling_free() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let cs = CoschedConfig {
            partition: PartitionKind::Guillotine,
            ..CoschedConfig::default()
        };
        let cold = schedule(&tiny_scenario(), &cfg, &cs, &cache, 1).unwrap();
        assert!(cold.evaluations > 0);
        let warm = schedule(&tiny_scenario(), &cfg, &cs, &cache, 1).unwrap();
        assert_eq!(warm.evaluations, 0, "warm reschedule must be all hits");
        assert!(warm.cache_hits > 0);
        assert_eq!(warm.cosched.makespan_cycles, cold.cosched.makespan_cycles);
        assert_eq!(warm.cut_tree, cold.cut_tree, "memoized reschedule agrees");
    }

    #[test]
    fn placement_is_composed_and_non_overlapping() {
        let cfg = small_cfg();
        let cs = CoschedConfig::default();
        let r = schedule(&tiny_scenario(), &cfg, &cs, &EvalCache::new(), 1).unwrap();
        let sp = &r.placement;
        assert_eq!(sp.rows, cfg.pe_rows);
        assert_eq!(sp.cols, cfg.pe_cols);
        let owned: usize = (0..2).map(|t| sp.task_pes(t)).sum();
        assert_eq!(owned + sp.idle_pes(), cfg.num_pes());
        assert!(sp.task_pes(0) > 0 && sp.task_pes(1) > 0);
    }

    #[test]
    fn too_many_tasks_for_the_array_errors() {
        let cfg = ArchConfig {
            pe_rows: 4,
            pe_cols: 1,
            ..ArchConfig::default()
        };
        let cs = CoschedConfig::default();
        let r = schedule(&tiny_scenario(), &cfg, &cs, &EvalCache::new(), 1);
        assert!(r.is_err());
    }

    #[test]
    fn tuned_cosched_never_loses_to_heuristic_cosched() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let cs = CoschedConfig::default();
        let heur = schedule(&tiny_scenario(), &cfg, &cs, &cache, 1).unwrap();
        let tuned_cs = CoschedConfig {
            tuned: true,
            budget: Some(256),
            ..CoschedConfig::default()
        };
        let tuned = schedule(&tiny_scenario(), &cfg, &tuned_cs, &cache, 1).unwrap();
        assert!(
            tuned.cosched.makespan_cycles <= heur.cosched.makespan_cycles * 1.0001,
            "tuned {} vs heuristic {}",
            tuned.cosched.makespan_cycles,
            heur.cosched.makespan_cycles
        );
    }

    /// The predicted floor/stretch split is conservative: per assignment,
    /// `floor + stretch` recovers `latency_cycles` (to summation-order
    /// float tolerance) on both the heuristic-cached and the tuned
    /// (`plan_breakdown`) evaluation paths, and neither part is negative
    /// beyond rounding.
    #[test]
    fn predicted_breakdown_sums_to_latency_on_both_paths() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let runs = [
            schedule(&tiny_scenario(), &cfg, &CoschedConfig::default(), &cache, 1).unwrap(),
            schedule(
                &tiny_scenario(),
                &cfg,
                &CoschedConfig {
                    tuned: true,
                    budget: Some(256),
                    ..CoschedConfig::default()
                },
                &cache,
                1,
            )
            .unwrap(),
        ];
        for r in &runs {
            for o in [&r.solo, &r.even_split, &r.cosched] {
                for a in &o.assignments {
                    let tol = 1e-9 * a.latency_cycles.max(1.0);
                    assert!(a.floor_cycles > 0.0, "{} {}: no floor", o.mode, a.task);
                    assert!(a.stretch_cycles >= -tol, "{} {}: negative stretch", o.mode, a.task);
                    let sum = a.floor_cycles + a.stretch_cycles;
                    assert!(
                        (sum - a.latency_cycles).abs() <= tol,
                        "{} {}: floor {} + stretch {} != cycles {}",
                        o.mode,
                        a.task,
                        a.floor_cycles,
                        a.stretch_cycles,
                        a.latency_cycles
                    );
                }
            }
        }
    }

    #[test]
    fn taskset_roundtrips_and_counts() {
        assert!(TaskSet::empty().is_empty());
        assert_eq!(TaskSet::full(0).len(), 0);
        assert_eq!(TaskSet::full(64).len(), 64);
        let s = TaskSet::from_tasks(&[5, 1, 3, 1]);
        assert_eq!(s.to_sorted_vec(), vec![1, 3, 5]);
        assert_eq!(s.len(), 3);
        assert!(s.contains(3) && !s.contains(0) && !s.contains(63));
        assert_eq!(s.sole_member(), None);
        assert_eq!(TaskSet::from_tasks(&[7]).sole_member(), Some(7));
        assert_eq!(
            s.complement_in(TaskSet::full(6)).to_sorted_vec(),
            vec![0, 2, 4]
        );
    }

    #[test]
    fn taskset_proper_subsets_match_the_classic_mask_walk() {
        let mask: u64 = 0b101101;
        let set = TaskSet::from_tasks(&[0, 2, 3, 5]);
        assert_eq!(set.bits(), mask);
        let mut expected = Vec::new();
        let mut lo = mask.wrapping_sub(1) & mask;
        while lo != 0 {
            expected.push(lo);
            lo = lo.wrapping_sub(1) & mask;
        }
        let got: Vec<u64> = set.proper_subsets().map(TaskSet::bits).collect();
        assert_eq!(got, expected);
        assert_eq!(got.len(), (1 << set.len()) - 2);
        assert_eq!(TaskSet::from_tasks(&[4]).proper_subsets().count(), 0);
        assert_eq!(TaskSet::empty().proper_subsets().count(), 0);
    }

    /// The parallel per-level beam must be invisible in the results: any
    /// worker count yields the same labels, hence the same tree.
    #[test]
    fn guillotine_is_identical_across_worker_counts() {
        let cfg = small_cfg();
        let cs = CoschedConfig {
            partition: PartitionKind::Guillotine,
            ..CoschedConfig::default()
        };
        let cache = EvalCache::new();
        let one = schedule(&tiny_scenario(), &cfg, &cs, &cache, 1).unwrap();
        let four = schedule(&tiny_scenario(), &cfg, &cs, &cache, 4).unwrap();
        assert_eq!(one.cut_tree.encode(), four.cut_tree.encode());
        assert_eq!(
            one.cosched.makespan_cycles.to_bits(),
            four.cosched.makespan_cycles.to_bits()
        );
        assert_eq!(one.cosched.energy.to_bits(), four.cosched.energy.to_bits());
    }
}
