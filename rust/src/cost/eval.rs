//! Plan evaluation: latency, DRAM traffic and energy of a mapping.
//!
//! A segment's wall clock is the max of four bounds:
//! 1. the Fig. 3 compute waterfall (init + steady state of the bottleneck
//!    stage),
//! 2. NoC serialization — the busiest link must carry its whole-segment
//!    traffic at `link_words_per_cycle`,
//! 3. global-buffer serialization for via-GB handoffs,
//! 4. DRAM bandwidth for the segment's off-chip traffic.

use std::sync::Arc;

use crate::config::ArchConfig;
use crate::energy::EnergyModel;
use crate::ir::ModelGraph;
use crate::memory::{bandwidth_cycles, segment_dram_traffic};
use crate::noc::{LinkLoadMap, Topology};
use crate::pipeline::{pipeline_latency, StageInterval};
use crate::sim::analyze;
use crate::spatial::Placement;
use crate::traffic::{derive_flows, Flow, StageHandoff};

use super::plan::{MappingPlan, PlannedSegment};

/// Global-buffer bandwidth for coarse-grained (via-GB) handoffs, in words
/// per cycle: a wide SRAM port at Table III sizes.
pub const GB_WORDS_PER_CYCLE: f64 = 32.0;

/// Cost of one planned segment.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentCost {
    /// Fig. 3 compute-waterfall latency in cycles.
    pub pipeline_cycles: f64,
    /// NoC serialization bound in cycles.
    pub noc_cycles: f64,
    /// Global-buffer serialization bound in cycles.
    pub gb_cycles: f64,
    /// DRAM-bandwidth bound in cycles.
    pub dram_cycles: f64,
    /// max of the four bounds — the segment's wall clock.
    pub cycles: f64,
    pub dram_words: u64,
    /// Worst-case channel load *per bottleneck interval* (words) — the
    /// Fig. 15 metric.
    pub worst_channel_load_per_interval: f64,
    /// Compute interval of the bottleneck stage (cycles).
    pub bottleneck_compute_interval: f64,
    pub energy: f64,
    /// NoC share of the energy.
    pub noc_energy: f64,
}

impl SegmentCost {
    /// Is the segment NoC-bound ("congested" in the paper's sense)?
    pub fn noc_bound(&self) -> bool {
        self.noc_cycles > self.pipeline_cycles
    }

    /// Serialize for the persistent DSE cache (`dse::EvalCache::save_file`).
    /// Field-for-field; [`SegmentCost::from_json`] is the exact inverse
    /// (f64 values survive because the JSON writer emits shortest-roundtrip
    /// representations).
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("pipeline_cycles", self.pipeline_cycles)
            .set("noc_cycles", self.noc_cycles)
            .set("gb_cycles", self.gb_cycles)
            .set("dram_cycles", self.dram_cycles)
            .set("cycles", self.cycles)
            .set("dram_words", self.dram_words)
            .set(
                "worst_channel_load_per_interval",
                self.worst_channel_load_per_interval,
            )
            .set(
                "bottleneck_compute_interval",
                self.bottleneck_compute_interval,
            )
            .set("energy", self.energy)
            .set("noc_energy", self.noc_energy);
        o
    }

    /// Inverse of [`SegmentCost::to_json`]. `None` on any missing or
    /// mistyped field — persistent-cache readers treat that as a skippable
    /// corrupt entry, never an error.
    pub fn from_json(v: &crate::util::json::Json) -> Option<SegmentCost> {
        let f = |key: &str| v.get(key).and_then(|x| x.as_f64());
        Some(SegmentCost {
            pipeline_cycles: f("pipeline_cycles")?,
            noc_cycles: f("noc_cycles")?,
            gb_cycles: f("gb_cycles")?,
            dram_cycles: f("dram_cycles")?,
            cycles: f("cycles")?,
            dram_words: f("dram_words")? as u64,
            worst_channel_load_per_interval: f("worst_channel_load_per_interval")?,
            bottleneck_compute_interval: f("bottleneck_compute_interval")?,
            energy: f("energy")?,
            noc_energy: f("noc_energy")?,
        })
    }
}

/// Whole-model cost.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCost {
    pub per_segment: Vec<SegmentCost>,
    pub cycles: f64,
    pub dram_words: u64,
    pub energy: f64,
}

/// Evaluate a full mapping plan.
pub fn evaluate(graph: &ModelGraph, plan: &MappingPlan, cfg: &ArchConfig) -> ModelCost {
    let topo = Topology::cached(plan.topology, cfg.pe_rows, cfg.pe_cols);
    let energy = EnergyModel::default();
    let per_segment: Vec<SegmentCost> = plan
        .segments
        .iter()
        .map(|s| evaluate_segment(graph, s, cfg, &topo, &energy))
        .collect();
    ModelCost {
        cycles: per_segment.iter().map(|s| s.cycles).sum(),
        dram_words: per_segment.iter().map(|s| s.dram_words).sum(),
        energy: per_segment.iter().map(|s| s.energy).sum(),
        per_segment,
    }
}

/// The Fig. 3 compute waterfall of one segment: per-stage intervals plus
/// the bottleneck stage's `(compute_interval, interval_count)`.
///
/// Shared by [`evaluate_segment`] and [`segment_loadmap`] so the
/// per-interval scaling of link loads can never diverge from the scalar
/// `worst_channel_load_per_interval` — the bit-exactness invariant holds
/// by construction, not by parallel maintenance.
fn stage_waterfall(
    seg: &PlannedSegment,
    cfg: &ArchConfig,
    macs: &[u64],
) -> (Vec<StageInterval>, f64, u64) {
    let depth = seg.depth();
    let dot = cfg.pe_dot_product as f64;
    let intervals_of = |stage: usize| -> u64 {
        seg.handoffs
            .iter()
            .find(|h| !h.is_skip && h.from_stage == stage)
            .or_else(|| {
                seg.handoffs
                    .iter()
                    .find(|h| !h.is_skip && h.to_stage == stage)
            })
            .map(|h| h.intervals.max(1))
            .unwrap_or(1)
    };
    let mut stage_intervals = Vec::with_capacity(depth);
    let mut bottleneck_compute = 0f64;
    let mut bottleneck_t = 1u64;
    for s in 0..depth {
        let pes = seg.pe_alloc[s].max(1) as f64;
        let total_compute = macs[s] as f64 / (pes * dot);
        let t = intervals_of(s);
        let compute_interval = total_compute / t as f64;
        if compute_interval > bottleneck_compute {
            bottleneck_compute = compute_interval;
            bottleneck_t = t;
        }
        stage_intervals.push(StageInterval {
            compute_delay: compute_interval,
            comm_delay: 0.0,
            intervals: t,
        });
    }
    (stage_intervals, bottleneck_compute, bottleneck_t)
}

/// Route a segment's NoC handoffs (whole-segment volumes, via-GB traffic
/// excluded) over a topology — the flow set both the cost model and the
/// loadmap accumulate.
fn noc_flows(seg: &PlannedSegment, cfg: &ArchConfig, topo: &Topology) -> Vec<Flow> {
    let placement = Placement::build(cfg.pe_rows, cfg.pe_cols, seg.organization, &seg.pe_alloc);
    let noc_handoffs: Vec<StageHandoff> = seg
        .handoffs
        .iter()
        .filter(|h| !h.via_gb)
        .map(|h| StageHandoff {
            from_stage: h.from_stage,
            to_stage: h.to_stage,
            words_per_interval: (h.words_per_interval * h.intervals) as f64,
            is_skip: h.is_skip,
        })
        .collect();
    derive_flows(topo, &placement, &noc_handoffs)
}

/// Link-resolved load map of one planned segment, scaled per bottleneck
/// interval. `map.max()` equals [`evaluate_segment`]'s
/// `worst_channel_load_per_interval` bit-exactly: same flows, same routes,
/// same `bottleneck_t`, and IEEE division by a positive constant is
/// monotone, so max-then-divide equals divide-then-max.
pub fn segment_loadmap(
    graph: &ModelGraph,
    seg: &PlannedSegment,
    cfg: &ArchConfig,
    topo: &Arc<Topology>,
) -> LinkLoadMap {
    let macs: Vec<u64> = seg.segment.layers().map(|i| graph.layer(i).macs()).collect();
    let (_, _, bottleneck_t) = stage_waterfall(seg, cfg, &macs);
    let load = analyze(topo, &noc_flows(seg, cfg, topo));
    LinkLoadMap::from_analysis(Arc::clone(topo), &load, bottleneck_t.max(1) as f64)
}

/// Link-resolved load map of a whole plan: element-wise max over its
/// segments, mirroring how plan scalars fold per-segment
/// `worst_channel_load_per_interval` with `f64::max` — so
/// `plan_loadmap(..).max()` equals that fold bit-exactly.
pub fn plan_loadmap(graph: &ModelGraph, plan: &MappingPlan, cfg: &ArchConfig) -> LinkLoadMap {
    let topo = Topology::cached(plan.topology, cfg.pe_rows, cfg.pe_cols);
    let mut map = LinkLoadMap::empty(Arc::clone(&topo));
    for seg in &plan.segments {
        map.merge_max(&segment_loadmap(graph, seg, cfg, &topo))
            .expect("plan segments share one topology");
    }
    map
}

/// Evaluate one planned segment on a topology.
pub fn evaluate_segment(
    graph: &ModelGraph,
    seg: &PlannedSegment,
    cfg: &ArchConfig,
    topo: &Topology,
    em: &EnergyModel,
) -> SegmentCost {
    let macs: Vec<u64> = seg.segment.layers().map(|i| graph.layer(i).macs()).collect();

    // ---- bound 1: Fig. 3 compute waterfall -------------------------------
    let (stage_intervals, bottleneck_compute, bottleneck_t) = stage_waterfall(seg, cfg, &macs);
    let lat = pipeline_latency(&stage_intervals);

    // ---- bound 2: NoC serialization --------------------------------------
    // Route each NoC handoff's *whole-segment* volume; the busiest link
    // sets the serialization bound.
    let load = analyze(topo, &noc_flows(seg, cfg, topo));
    let noc_cycles = load.worst_channel_load / cfg.link_words_per_cycle;

    // ---- bound 3: global-buffer serialization -----------------------------
    let gb_words: u64 = seg
        .handoffs
        .iter()
        .filter(|h| h.via_gb)
        .map(|h| 2 * h.words_per_interval * h.intervals)
        .sum();
    let gb_cycles = gb_words as f64 / GB_WORDS_PER_CYCLE;

    // ---- bound 4: DRAM bandwidth ------------------------------------------
    let handoff_words: Vec<u64> = seg
        .handoffs
        .iter()
        .filter(|h| !h.is_skip)
        .map(|h| h.words_per_interval)
        .collect();
    let dram = segment_dram_traffic(graph, &seg.segment, &handoff_words, cfg);
    let dram_cycles = bandwidth_cycles(dram.total(), cfg);

    let cycles = lat
        .total
        .max(noc_cycles)
        .max(gb_cycles)
        .max(dram_cycles);

    // ---- energy ------------------------------------------------------------
    let noc_energy = em.noc_interval_energy(&load); // totals, not per interval
    let total_energy = em.compute_energy(macs.iter().sum())
        + noc_energy
        + em.sram_energy(gb_words)
        + em.dram_energy(dram.total());

    SegmentCost {
        pipeline_cycles: lat.total,
        noc_cycles,
        gb_cycles,
        dram_cycles,
        cycles,
        dram_words: dram.total(),
        worst_channel_load_per_interval: load.worst_channel_load / bottleneck_t.max(1) as f64,
        bottleneck_compute_interval: bottleneck_compute,
        energy: total_energy,
        noc_energy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::cost::plan::{PlannedHandoff, PlannedSegment};
    use crate::dataflow::DataflowStyle;
    use crate::pipeline::Segment;
    use crate::spatial::Organization;
    use crate::workloads::synthetic;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    /// Hand-built depth-2 fine-grained plan over a memory-bound segment.
    fn depth2_plan(org: Organization, via_gb: bool) -> (crate::ir::ModelGraph, MappingPlan) {
        let g = synthetic::pointwise_conv_segment(2);
        let rows = g.layer(0).op.output_rows();
        let words = g.layer(0).output_act_words() / rows;
        let plan = MappingPlan {
            mapper_name: "hand".into(),
            topology: TopologyKind::Mesh,
            segments: vec![PlannedSegment {
                segment: Segment::new(0, 2),
                organization: org,
                pe_alloc: vec![512, 512],
                styles: vec![DataflowStyle::OutputStationary; 2],
                handoffs: vec![PlannedHandoff {
                    from_stage: 0,
                    to_stage: 1,
                    words_per_interval: words,
                    intervals: rows,
                    via_gb,
                    is_skip: false,
                }],
            }],
        };
        (g, plan)
    }

    fn op_by_op_plan(g: &crate::ir::ModelGraph) -> MappingPlan {
        MappingPlan {
            mapper_name: "opbyop".into(),
            topology: TopologyKind::Mesh,
            segments: (0..g.num_layers())
                .map(|i| PlannedSegment {
                    segment: Segment::new(i, 1),
                    organization: Organization::Sequential,
                    pe_alloc: vec![1024],
                    styles: vec![DataflowStyle::OutputStationary],
                    handoffs: vec![],
                })
                .collect(),
        }
    }

    #[test]
    fn pipelined_beats_op_by_op_on_activation_heavy() {
        let (g, plan) = depth2_plan(Organization::FineStriped1D, false);
        let pipe = evaluate(&g, &plan, &cfg());
        let op = evaluate(&g, &op_by_op_plan(&g), &cfg());
        assert!(pipe.dram_words < op.dram_words);
        assert!(
            pipe.cycles < op.cycles,
            "pipe {} op {}",
            pipe.cycles,
            op.cycles
        );
    }

    #[test]
    fn striped_outruns_blocked_when_congested() {
        let (g, blocked) = depth2_plan(Organization::Blocked1D, false);
        let (_, striped) = depth2_plan(Organization::FineStriped1D, false);
        let cb = evaluate(&g, &blocked, &cfg());
        let cs = evaluate(&g, &striped, &cfg());
        assert!(cb.per_segment[0].noc_cycles > cs.per_segment[0].noc_cycles);
        assert!(cs.cycles <= cb.cycles);
    }

    #[test]
    fn amp_relieves_blocked_congestion() {
        let (g, mut plan) = depth2_plan(Organization::Blocked1D, false);
        let mesh = evaluate(&g, &plan, &cfg());
        plan.topology = TopologyKind::Amp;
        let amp = evaluate(&g, &plan, &cfg());
        assert!(amp.per_segment[0].noc_cycles < mesh.per_segment[0].noc_cycles);
        assert!(amp.cycles <= mesh.cycles);
    }

    #[test]
    fn gb_handoff_serializes_and_costs_sram_energy() {
        let (g, noc_plan) = depth2_plan(Organization::Blocked1D, false);
        let (_, gb_plan) = depth2_plan(Organization::Blocked1D, true);
        let n = evaluate(&g, &noc_plan, &cfg());
        let b = evaluate(&g, &gb_plan, &cfg());
        assert_eq!(b.per_segment[0].noc_cycles, 0.0);
        assert!(b.per_segment[0].gb_cycles > 0.0);
        assert!(b.energy > n.energy - n.per_segment[0].noc_energy);
    }

    #[test]
    fn dram_bound_segment_reports_bandwidth_limit() {
        // Depth-1 giant GEMM: bandwidth dominates.
        let mut g = crate::ir::ModelGraph::new("fc");
        g.add_root(crate::ir::Layer::new("fc", crate::ir::Op::gemm(8, 4096, 4096)));
        let c = evaluate(&g, &op_by_op_plan(&g), &cfg());
        assert!(c.per_segment[0].dram_cycles > c.per_segment[0].pipeline_cycles);
        assert_eq!(c.cycles, c.per_segment[0].dram_cycles);
    }

    #[test]
    fn congestion_flag_matches_bounds() {
        let (g, blocked) = depth2_plan(Organization::Blocked1D, false);
        let cb = evaluate(&g, &blocked, &cfg());
        // Blocked fine-grained at compute interval ~2 cycles congests
        // (Fig. 8): the NoC bound exceeds the compute waterfall.
        assert!(cb.per_segment[0].noc_bound());
        let (_, striped) = depth2_plan(Organization::FineStriped1D, false);
        let cs = evaluate(&g, &striped, &cfg());
        assert!(!cs.per_segment[0].noc_bound());
    }

    #[test]
    fn costs_are_positive_and_additive() {
        let (g, plan) = depth2_plan(Organization::FineStriped1D, false);
        let c = evaluate(&g, &plan, &cfg());
        assert!(c.cycles > 0.0 && c.energy > 0.0 && c.dram_words > 0);
        let sum: f64 = c.per_segment.iter().map(|s| s.cycles).sum();
        assert_eq!(c.cycles, sum);
    }

    #[test]
    fn loadmap_max_matches_scalar_bit_exactly_on_all_topologies() {
        // The tentpole invariant at segment and plan granularity, on every
        // topology kind and both fine-grained organizations.
        for kind in [
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::Torus,
            TopologyKind::FlattenedButterfly,
        ] {
            for org in [Organization::Blocked1D, Organization::FineStriped1D] {
                let (g, mut plan) = depth2_plan(org, false);
                plan.topology = kind;
                let cfg = cfg();
                let cost = evaluate(&g, &plan, &cfg);
                let topo = Topology::cached(kind, cfg.pe_rows, cfg.pe_cols);
                for (seg, sc) in plan.segments.iter().zip(&cost.per_segment) {
                    let map = segment_loadmap(&g, seg, &cfg, &topo);
                    assert_eq!(
                        map.max(),
                        sc.worst_channel_load_per_interval,
                        "{kind:?} {org:?}"
                    );
                }
                let plan_map = plan_loadmap(&g, &plan, &cfg);
                let scalar = cost
                    .per_segment
                    .iter()
                    .map(|s| s.worst_channel_load_per_interval)
                    .fold(0.0, f64::max);
                assert_eq!(plan_map.max(), scalar, "{kind:?} {org:?} plan fold");
            }
        }
    }

    #[test]
    fn segment_cost_json_roundtrip_is_exact() {
        let (g, plan) = depth2_plan(Organization::FineStriped1D, false);
        let c = evaluate(&g, &plan, &cfg());
        for s in &c.per_segment {
            let text = s.to_json().to_pretty();
            let parsed = crate::util::json::Json::parse(&text).unwrap();
            let back = SegmentCost::from_json(&parsed).unwrap();
            assert_eq!(&back, s, "roundtrip changed a field:\n{text}");
        }
    }

    #[test]
    fn segment_cost_from_json_rejects_missing_fields() {
        let (g, plan) = depth2_plan(Organization::FineStriped1D, false);
        let full = evaluate(&g, &plan, &cfg()).per_segment[0].to_json();
        assert!(SegmentCost::from_json(&full).is_some());
        let mut truncated = full.clone();
        if let crate::util::json::Json::Obj(m) = &mut truncated {
            m.remove("energy");
        }
        assert!(SegmentCost::from_json(&truncated).is_none());
        assert!(SegmentCost::from_json(&crate::util::json::Json::Null).is_none());
    }
}
