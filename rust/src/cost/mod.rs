//! End-to-end cost model: composes the compute-interval model (Fig. 3),
//! the NoC channel-load analysis, and the memory/bandwidth model into
//! per-segment and per-model latency, DRAM traffic and energy.

mod eval;
mod plan;

pub use eval::{
    evaluate, evaluate_segment, plan_loadmap, segment_loadmap, ModelCost, SegmentCost,
};
pub use plan::{Mapper, MappingPlan, PlannedHandoff, PlannedSegment};
