//! Mapping plans: the common output format of the PipeOrgan mapper and the
//! TANGRAM-like / SIMBA-like baselines, consumed by the evaluator.

use crate::config::{ArchConfig, TopologyKind};
use crate::dataflow::DataflowStyle;
use crate::ir::ModelGraph;
use crate::pipeline::Segment;
use crate::spatial::Organization;

/// One stage-to-stage data handoff inside a planned segment.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedHandoff {
    pub from_stage: usize,
    pub to_stage: usize,
    /// Words exchanged per pipeline interval.
    pub words_per_interval: u64,
    /// Number of pipeline intervals for this handoff.
    pub intervals: u64,
    /// True when the handoff exceeds the register files and must round-trip
    /// the global buffer.
    pub via_gb: bool,
    /// True for skip-connection handoffs.
    pub is_skip: bool,
}

/// A segment with all stage-2 decisions attached.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedSegment {
    pub segment: Segment,
    pub organization: Organization,
    /// PEs allocated per stage (sums to ≤ the array size; Sequential uses
    /// the whole array per stage).
    pub pe_alloc: Vec<usize>,
    /// Dataflow style per stage.
    pub styles: Vec<DataflowStyle>,
    pub handoffs: Vec<PlannedHandoff>,
}

impl PlannedSegment {
    pub fn depth(&self) -> usize {
        self.segment.depth
    }

    /// Structural validation against the model and the array size.
    pub fn validate(&self, graph: &ModelGraph, cfg: &ArchConfig) -> Result<(), String> {
        let d = self.depth();
        if self.pe_alloc.len() != d || self.styles.len() != d {
            return Err(format!(
                "segment at {}: alloc/styles arity mismatch (depth {d})",
                self.segment.start
            ));
        }
        if self.segment.end() > graph.num_layers() {
            return Err("segment exceeds model".into());
        }
        let total: usize = self.pe_alloc.iter().sum();
        if self.organization != Organization::Sequential && total > cfg.num_pes() {
            return Err(format!("allocated {total} PEs > array {}", cfg.num_pes()));
        }
        for h in &self.handoffs {
            if h.from_stage >= d || h.to_stage >= d || h.from_stage >= h.to_stage {
                return Err(format!(
                    "bad handoff {}→{} in depth-{d} segment",
                    h.from_stage, h.to_stage
                ));
            }
        }
        Ok(())
    }
}

/// A whole-model mapping: the unit both mappers produce and Fig. 13/14
/// evaluate.
#[derive(Debug, Clone, PartialEq)]
pub struct MappingPlan {
    pub mapper_name: String,
    pub topology: TopologyKind,
    pub segments: Vec<PlannedSegment>,
}

impl MappingPlan {
    pub fn validate(&self, graph: &ModelGraph, cfg: &ArchConfig) -> Result<(), String> {
        let segs: Vec<Segment> = self.segments.iter().map(|s| s.segment.clone()).collect();
        crate::pipeline::segment::segments_cover(&segs, graph.num_layers())?;
        for s in &self.segments {
            s.validate(graph, cfg)?;
        }
        Ok(())
    }

    pub fn mean_depth(&self) -> f64 {
        if self.segments.is_empty() {
            return 0.0;
        }
        self.segments.iter().map(|s| s.depth() as f64).sum::<f64>() / self.segments.len() as f64
    }
}

/// A mapping strategy: PipeOrgan or one of the baselines.
pub trait Mapper {
    fn name(&self) -> &'static str;
    /// The NoC this mapper assumes.
    fn topology(&self) -> TopologyKind;
    fn plan(&self, graph: &ModelGraph, cfg: &ArchConfig) -> MappingPlan;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::synthetic;

    fn trivial_plan(graph: &ModelGraph) -> MappingPlan {
        MappingPlan {
            mapper_name: "trivial".into(),
            topology: TopologyKind::Mesh,
            segments: (0..graph.num_layers())
                .map(|i| PlannedSegment {
                    segment: Segment::new(i, 1),
                    organization: Organization::Sequential,
                    pe_alloc: vec![1024],
                    styles: vec![DataflowStyle::ActivationStationary],
                    handoffs: vec![],
                })
                .collect(),
        }
    }

    #[test]
    fn trivial_plan_validates() {
        let g = synthetic::equal_conv_segment(4);
        let p = trivial_plan(&g);
        p.validate(&g, &ArchConfig::default()).unwrap();
        assert_eq!(p.mean_depth(), 1.0);
    }

    #[test]
    fn coverage_gap_fails() {
        let g = synthetic::equal_conv_segment(4);
        let mut p = trivial_plan(&g);
        p.segments.remove(1);
        assert!(p.validate(&g, &ArchConfig::default()).is_err());
    }

    #[test]
    fn arity_mismatch_fails() {
        let g = synthetic::equal_conv_segment(4);
        let mut p = trivial_plan(&g);
        p.segments[0].styles.clear();
        assert!(p.validate(&g, &ArchConfig::default()).is_err());
    }

    #[test]
    fn over_allocation_fails() {
        let g = synthetic::equal_conv_segment(2);
        let mut p = trivial_plan(&g);
        p.segments[0].organization = Organization::Blocked1D;
        p.segments[0].pe_alloc = vec![2048];
        assert!(p.validate(&g, &ArchConfig::default()).is_err());
    }
}
