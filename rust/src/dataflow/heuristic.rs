//! The paper's intra-operator dataflow heuristic (Sec. IV-A, "Determining
//! Intra-operation Dataflows"): choose the loop order from the A/W ratio.
//!
//! - weight-heavy (A/W < 1): weight-stationary — weight ranks (K, C)
//!   outermost, maximizing weight reuse; *not* pipeline-friendly (the
//!   contracted rank C sits outside the output ranks).
//! - strongly activation-heavy (A/W ≥ `AS_THRESHOLD`): fully activation
//!   stationary, NHWKCRS.
//! - moderately activation-heavy (1 ≤ A/W < `AS_THRESHOLD`): allow some
//!   weight reuse, NHKCWRS (the paper's example).

use crate::ir::{Layer, OpKind};

use super::nest::Rank;

/// Ratio above which the heuristic goes fully activation-stationary.
pub const AS_THRESHOLD: f64 = 64.0;

/// Dataflow families used by stage 1 and the baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataflowStyle {
    /// Weight ranks outermost (KCNHWRS for conv, KC H for gemm).
    WeightStationary,
    /// Fully activation-stationary: NHWKCRS / H K C.
    ActivationStationary,
    /// Activation-stationary with some weight reuse: NHKCWRS.
    MixedActivation,
    /// Output-stationary: output ranks outer, contracted inner (TANGRAM
    /// producer side). Same rank order as ActivationStationary for conv but
    /// kept distinct for reporting.
    OutputStationary,
    /// Input-stationary: input ranks outer, K innermost of the outer group
    /// (TANGRAM consumer side): NHWCKRS.
    InputStationary,
}

impl DataflowStyle {
    pub fn name(self) -> &'static str {
        match self {
            DataflowStyle::WeightStationary => "weight_stationary",
            DataflowStyle::ActivationStationary => "activation_stationary",
            DataflowStyle::MixedActivation => "mixed_activation",
            DataflowStyle::OutputStationary => "output_stationary",
            DataflowStyle::InputStationary => "input_stationary",
        }
    }

    /// Temporal rank order (outermost first) for an operator kind.
    pub fn rank_order(self, kind: OpKind) -> Vec<Rank> {
        use Rank::*;
        match kind {
            OpKind::Gemm => match self {
                // Unified: H=M, K=cols, C=contracted.
                DataflowStyle::WeightStationary => vec![K, C, H],
                DataflowStyle::ActivationStationary | DataflowStyle::OutputStationary => {
                    vec![H, K, C] // MNK
                }
                DataflowStyle::MixedActivation => vec![H, K, C],
                DataflowStyle::InputStationary => vec![H, C, K], // MKN
            },
            // Depthwise conv has no K rank; C is both output and contracted.
            OpKind::DwConv2d => match self {
                DataflowStyle::WeightStationary => vec![C, N, H, W, R, S],
                _ => vec![N, H, W, C, R, S],
            },
            _ => match self {
                DataflowStyle::WeightStationary => vec![K, C, N, H, W, R, S],
                DataflowStyle::ActivationStationary | DataflowStyle::OutputStationary => {
                    vec![N, H, W, K, C, R, S]
                }
                DataflowStyle::MixedActivation => vec![N, H, K, C, W, R, S],
                DataflowStyle::InputStationary => vec![N, H, W, C, K, R, S],
            },
        }
    }

    /// Pipeline-friendliness: a producer can stage output to a consumer only
    /// if its outermost loop is an output rank (Fig. 4 condition 2) that is
    /// *not* also a weight rank — staging must advance along batch/spatial
    /// dims so the consumer sees complete rows. Weight-stationary orders
    /// (K or C outermost) produce K-major and are "not friendly to
    /// pipelining" (Sec. IV-A).
    pub fn producer_pipeline_friendly(self, kind: OpKind) -> bool {
        let order = self.rank_order(kind);
        let out = super::nest::output_ranks(kind);
        order
            .first()
            .map(|r| out.contains(r) && !matches!(r, Rank::K | Rank::C))
            .unwrap_or(false)
    }
}

/// The stage-1 heuristic: pick a dataflow style for a layer from its A/W
/// ratio (Sec. IV-A).
pub fn choose_dataflow(layer: &Layer) -> DataflowStyle {
    let ratio = layer.aw_ratio();
    if ratio < 1.0 {
        DataflowStyle::WeightStationary
    } else if ratio >= AS_THRESHOLD {
        DataflowStyle::ActivationStationary
    } else {
        DataflowStyle::MixedActivation
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Layer, Op};

    #[test]
    fn rank_orders_match_paper_strings() {
        use crate::dataflow::LoopNest;
        let conv = Op::conv2d(1, 8, 8, 4, 4, 3, 3, 1, 1);
        let s = |st: DataflowStyle| LoopNest::for_op(&conv, st).order_string();
        assert_eq!(s(DataflowStyle::ActivationStationary), "NHWKCRS");
        assert_eq!(s(DataflowStyle::MixedActivation), "NHKCWRS");
        assert_eq!(s(DataflowStyle::InputStationary), "NHWCKRS");
        assert_eq!(s(DataflowStyle::WeightStationary), "KCNHWRS");
    }

    #[test]
    fn weight_stationary_is_not_pipeline_friendly() {
        assert!(!DataflowStyle::WeightStationary.producer_pipeline_friendly(OpKind::Conv2d));
        assert!(DataflowStyle::ActivationStationary.producer_pipeline_friendly(OpKind::Conv2d));
        assert!(DataflowStyle::InputStationary.producer_pipeline_friendly(OpKind::Conv2d));
        assert!(!DataflowStyle::WeightStationary.producer_pipeline_friendly(OpKind::Gemm));
        assert!(DataflowStyle::ActivationStationary.producer_pipeline_friendly(OpKind::Gemm));
    }

    #[test]
    fn heuristic_by_ratio() {
        // weight heavy FC
        let fc = Layer::new("fc", Op::gemm(1, 2048, 1000));
        assert_eq!(choose_dataflow(&fc), DataflowStyle::WeightStationary);
        // huge feature map conv
        let big = Layer::new("big", Op::conv2d(1, 256, 256, 8, 8, 3, 3, 1, 1));
        assert_eq!(choose_dataflow(&big), DataflowStyle::ActivationStationary);
        // moderate conv
        let mid = Layer::new("mid", Op::conv2d(1, 28, 28, 96, 96, 3, 3, 1, 1));
        let r = mid.aw_ratio();
        assert!(r >= 1.0 && r < AS_THRESHOLD, "r={r}");
        assert_eq!(choose_dataflow(&mid), DataflowStyle::MixedActivation);
    }

    #[test]
    fn dwconv_orders_skip_k() {
        let dw = Op::dwconv2d(1, 16, 16, 32, 3, 1);
        let order = DataflowStyle::ActivationStationary.rank_order(dw.kind());
        assert!(!order.contains(&Rank::K));
        assert_eq!(order[0], Rank::N);
    }
}
