//! Arithmetic intensity and buffer-fit analysis.
//!
//! The paper validates its dataflow heuristic by checking that the chosen
//! dataflow reaches *best-case arithmetic intensity* (only cold misses) for
//! 99.94 % of XR-bench layers with a 512 KB buffer and 97.2 % with 256 KB
//! (Sec. IV-A, footnote 3). This module reproduces that experiment (E14).

use crate::ir::Layer;

use super::heuristic::DataflowStyle;

/// Best-case arithmetic intensity (MACs per word of off-chip traffic),
/// counting each tensor exactly once (cold misses only).
pub fn best_case_intensity(layer: &Layer) -> f64 {
    let traffic =
        layer.input_act_words() + layer.output_act_words() + layer.weight_words();
    if traffic == 0 {
        return 0.0;
    }
    layer.macs() as f64 / traffic as f64
}

/// Minimum on-chip buffer (in words) for a layer to achieve best-case
/// (cold-miss-only) intensity.
///
/// Cold-miss-only traffic is achievable iff *one* operand tensor can stay
/// resident while the others stream through double-buffered slices:
///
/// - weights resident + activations streamed row-by-row, or
/// - input activations resident + weights streamed one output-channel
///   filter-set at a time (output rows drain as produced).
///
/// The achievable requirement is the smaller of the two. Note "stationary"
/// in the style names describes the *reuse order* (which tensor the loop
/// nest keeps hot), not DRAM residency — e.g. a weight-stationary FC layer
/// with huge weights pins its small input activations on-chip and streams
/// the weights exactly once, which is still cold-miss-only. Hence all loop
/// orders share the same requirement and `style` only matters for the
/// (rare) explicitly-constrained InputStationary case.
pub fn required_buffer_words(layer: &Layer, style: DataflowStyle) -> u64 {
    let w = layer.weight_words();
    let a_in = layer.input_act_words();
    let a_out = layer.output_act_words();
    let rows = layer.op.output_rows().max(1);
    let in_slice = crate::util::ceil_div(a_in, rows);
    let out_slice = crate::util::ceil_div(a_out, rows);
    // One output-channel filter set (K-slice of the weights).
    let k_extent = super::rank_extent(&layer.op, super::Rank::K).max(1);
    let w_kslice = crate::util::ceil_div(w, k_extent);
    let weights_resident = w + 2 * (in_slice + out_slice);
    let input_resident = a_in + 2 * (w_kslice + out_slice);
    match style {
        DataflowStyle::InputStationary => input_resident,
        // Every other loop order can keep whichever operand is cheaper
        // resident without extra misses.
        _ => weights_resident.min(input_resident),
    }
}

/// Does `layer` under `style` achieve best-case intensity with
/// `buffer_words` of on-chip storage?
pub fn buffer_fit(layer: &Layer, style: DataflowStyle, buffer_words: u64) -> bool {
    required_buffer_words(layer, style) <= buffer_words
}

/// Achieved intensity: best-case when the buffer fits; otherwise degraded by
/// re-fetching the streamed large tensor once per tile pass of the
/// stationary one (a standard tiling lower bound).
pub fn achieved_intensity(layer: &Layer, style: DataflowStyle, buffer_words: u64) -> f64 {
    if buffer_fit(layer, style, buffer_words) {
        return best_case_intensity(layer);
    }
    let w = layer.weight_words().max(1);
    let a = layer.input_act_words() + layer.output_act_words();
    // Number of passes over the streamed tensor ≈ stationary / buffer.
    let stationary = match style {
        DataflowStyle::WeightStationary => w,
        _ => a.max(1),
    };
    let passes = crate::util::ceil_div(stationary, buffer_words.max(1)).max(1);
    let traffic = match style {
        DataflowStyle::WeightStationary => w + passes * a,
        _ => a + passes * w,
    };
    layer.macs() as f64 / traffic as f64
}

/// Result of the E14 heuristic-validation sweep over a set of layers.
#[derive(Debug, Clone, PartialEq)]
pub struct IntensityReport {
    pub total_layers: usize,
    pub achieving_best_case: usize,
    pub buffer_words: u64,
}

impl IntensityReport {
    /// Fraction of einsum layers whose *heuristically chosen* dataflow
    /// reaches best-case intensity at this buffer size.
    pub fn sweep<'a>(
        layers: impl IntoIterator<Item = &'a Layer>,
        buffer_words: u64,
    ) -> IntensityReport {
        let mut total = 0;
        let mut ok = 0;
        for layer in layers {
            if !layer.is_einsum() {
                continue;
            }
            total += 1;
            let style = super::choose_dataflow(layer);
            if buffer_fit(layer, style, buffer_words) {
                ok += 1;
            }
        }
        IntensityReport {
            total_layers: total,
            achieving_best_case: ok,
            buffer_words,
        }
    }

    pub fn fraction(&self) -> f64 {
        if self.total_layers == 0 {
            0.0
        } else {
            self.achieving_best_case as f64 / self.total_layers as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::choose_dataflow;
    use crate::ir::{Layer, Op};

    #[test]
    fn best_case_intensity_conv() {
        let l = Layer::new("c", Op::conv2d(1, 32, 32, 16, 32, 3, 3, 1, 1));
        let ai = best_case_intensity(&l);
        let traffic = (32 * 32 * 16 + 32 * 32 * 32 + 32 * 16 * 9) as f64;
        assert!((ai - l.macs() as f64 / traffic).abs() < 1e-9);
    }

    #[test]
    fn fits_when_buffer_large() {
        let l = Layer::new("c", Op::conv2d(1, 16, 16, 8, 8, 3, 3, 1, 1));
        let style = choose_dataflow(&l);
        assert!(buffer_fit(&l, style, 1 << 20));
        assert!(!buffer_fit(&l, style, 16));
    }

    #[test]
    fn achieved_degrades_when_too_small() {
        let l = Layer::new("fc", Op::gemm(4, 4096, 4096));
        let style = choose_dataflow(&l);
        let best = best_case_intensity(&l);
        let small = achieved_intensity(&l, style, 1024);
        assert!(small < best, "small={small} best={best}");
        let big = achieved_intensity(&l, style, 1 << 26);
        assert!((big - best).abs() < 1e-12);
    }

    #[test]
    fn e14_validation_shape_on_zoo() {
        // Reproduce the Sec. IV-A validation: ≳95 % of zoo einsum layers hit
        // best-case AI at 512 KB, and the fraction is monotone in buffer
        // size. (Paper: 99.94 % @512 KB, 97.2 % @256 KB.)
        let tasks = crate::workloads::all_tasks();
        let layers: Vec<_> = tasks.iter().flat_map(|g| g.layers().iter()).collect();
        let at = |kb: u64| {
            IntensityReport::sweep(layers.iter().copied(), kb * 1024).fraction()
        };
        let f512 = at(512);
        let f256 = at(256);
        assert!(f512 >= 0.9, "512KB fraction {f512}");
        assert!(f256 <= f512 + 1e-12);
        assert!(f256 >= 0.75, "256KB fraction {f256}");
    }
}
