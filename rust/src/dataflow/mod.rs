//! Intra-operator dataflows (Sec. II-A, III-B): loop orders over the einsum
//! ranks, the A/W-driven selection heuristic, and arithmetic-intensity /
//! buffer-fit analysis.
//!
//! Rank vocabulary is unified across operator types so producer/consumer
//! loop nests can be compared rank-by-rank in Algorithm 1:
//!
//! | rank | conv meaning                | GEMM meaning (Eq. 1) |
//! |------|-----------------------------|----------------------|
//! | N    | batch                       | —                    |
//! | H    | output rows                 | M (output rows)      |
//! | W    | output cols                 | —                    |
//! | K    | output channels             | N (output cols)      |
//! | C    | input channels (contracted) | K (contracted)       |
//! | R,S  | filter window (contracted)  | —                    |
//!
//! With this mapping the paper's examples read directly: NHWKCRS–NHWCKRS is
//! the finest-grained conv pair, MNK–MKN (= HKC–HCK here) the finest GEMM
//! pair.

mod heuristic;
mod intensity;
mod nest;

pub use heuristic::{choose_dataflow, DataflowStyle};
pub use intensity::{achieved_intensity, best_case_intensity, buffer_fit, IntensityReport};
pub use nest::{
    input_ranks, output_ranks, producer_to_consumer_rank, rank_extent, LoopDim, LoopNest, Rank,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    #[test]
    fn module_level_example_from_paper() {
        // NHWKCRS for a conv lowers to a nest whose outermost rank is N.
        let op = Op::conv2d(1, 16, 16, 8, 8, 3, 3, 1, 1);
        let nest = LoopNest::for_op(&op, DataflowStyle::ActivationStationary);
        assert_eq!(nest.dims[0].rank, Rank::N);
        assert_eq!(nest.dims[1].rank, Rank::H);
    }
}
