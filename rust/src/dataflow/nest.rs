//! Loop-nest representation: ordered ranks with extents and tile sizes.

use crate::ir::{Op, OpKind};

use super::DataflowStyle;

/// Einsum rank in the unified vocabulary (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Rank {
    N,
    H,
    W,
    K,
    C,
    R,
    S,
}

impl Rank {
    pub fn letter(self) -> char {
        match self {
            Rank::N => 'N',
            Rank::H => 'H',
            Rank::W => 'W',
            Rank::K => 'K',
            Rank::C => 'C',
            Rank::R => 'R',
            Rank::S => 'S',
        }
    }

    /// Contracted (reduction) ranks of a standard einsum.
    pub fn is_contracted(self) -> bool {
        matches!(self, Rank::C | Rank::R | Rank::S)
    }
}

/// One temporal loop level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoopDim {
    pub rank: Rank,
    /// Full trip count of this rank.
    pub extent: u64,
    /// Tile size: the loop advances in steps of `tile` (1 = untiled).
    pub tile: u64,
}

impl LoopDim {
    pub fn new(rank: Rank, extent: u64) -> Self {
        Self {
            rank,
            extent,
            tile: 1,
        }
    }

    /// Number of iterations of this loop level.
    pub fn trips(&self) -> u64 {
        crate::util::ceil_div(self.extent, self.tile)
    }
}

/// An ordered temporal loop nest (outermost first) for one operator.
#[derive(Debug, Clone, PartialEq)]
pub struct LoopNest {
    pub dims: Vec<LoopDim>,
    /// Kind of the operator this nest was derived from.
    pub op_kind: OpKind,
}

impl LoopNest {
    /// Build the loop nest of `op` under a dataflow style. Ranks with unit
    /// extent are kept (they matter for order comparisons but contribute
    /// trip count 1).
    pub fn for_op(op: &Op, style: DataflowStyle) -> LoopNest {
        let order = style.rank_order(op.kind());
        let dims = order
            .into_iter()
            .map(|rank| LoopDim::new(rank, rank_extent(op, rank)))
            .collect();
        LoopNest {
            dims,
            op_kind: op.kind(),
        }
    }

    /// The rank order as a compact string, e.g. `"NHWKCRS"`.
    pub fn order_string(&self) -> String {
        self.dims.iter().map(|d| d.rank.letter()).collect()
    }

    /// Position of `rank` in the nest, if present.
    pub fn position(&self, rank: Rank) -> Option<usize> {
        self.dims.iter().position(|d| d.rank == rank)
    }

    /// Set the tile size of `rank` (no-op if absent).
    pub fn set_tile(&mut self, rank: Rank, tile: u64) {
        if let Some(d) = self.dims.iter_mut().find(|d| d.rank == rank) {
            d.tile = tile.max(1).min(d.extent.max(1));
        }
    }

    /// Ranks indexing the operator's *output* tensor.
    pub fn output_ranks(&self) -> Vec<Rank> {
        output_ranks(self.op_kind)
    }

    /// Ranks indexing the operator's *input activation* tensor.
    pub fn input_ranks(&self) -> Vec<Rank> {
        input_ranks(self.op_kind)
    }

    /// Total MAC-loop trip count (product of all trips × tiles ≈ extents).
    pub fn total_iterations(&self) -> u64 {
        self.dims.iter().map(|d| d.extent.max(1)).product()
    }
}

/// Extent of `rank` for operator `op` (1 when the rank does not apply).
pub fn rank_extent(op: &Op, rank: Rank) -> u64 {
    match *op {
        Op::Conv2d(p) | Op::DwConv2d(p) => match rank {
            Rank::N => p.n as u64,
            Rank::H => p.oh() as u64,
            Rank::W => p.ow() as u64,
            Rank::K => {
                if matches!(op.kind(), OpKind::DwConv2d) {
                    1
                } else {
                    p.k as u64
                }
            }
            Rank::C => p.c as u64,
            Rank::R => p.r as u64,
            Rank::S => p.s as u64,
        },
        Op::Gemm { m, k, n } => match rank {
            Rank::H => m as u64,
            Rank::K => n as u64,
            Rank::C => k as u64,
            _ => 1,
        },
        Op::Pool {
            n,
            h,
            w,
            c,
            window,
            stride,
        } => match rank {
            Rank::N => n as u64,
            Rank::H => (h.saturating_sub(window) / stride + 1) as u64,
            Rank::W => (w.saturating_sub(window) / stride + 1) as u64,
            Rank::C => c as u64,
            Rank::R | Rank::S => window as u64,
            Rank::K => 1,
        },
        Op::EltwiseAdd { n, h, w, c, .. } | Op::Upsample { n, h, w, c, .. } => match rank {
            Rank::N => n as u64,
            Rank::H => h as u64,
            Rank::W => w as u64,
            Rank::C => c as u64,
            _ => 1,
        },
        Op::Concat {
            n, h, w, c_each, ..
        } => match rank {
            Rank::N => n as u64,
            Rank::H => h as u64,
            Rank::W => w as u64,
            Rank::C => c_each as u64,
            _ => 1,
        },
        Op::RoiAlign { rois, out, c } => match rank {
            Rank::N => rois as u64,
            Rank::H | Rank::W => out as u64,
            Rank::C => c as u64,
            _ => 1,
        },
        Op::Rpn { h, w, c, anchors } => match rank {
            Rank::H => h as u64,
            Rank::W => w as u64,
            Rank::C => c as u64,
            Rank::K => anchors as u64,
            _ => 1,
        },
    }
}

/// Ranks of the output tensor per operator kind.
pub fn output_ranks(kind: OpKind) -> Vec<Rank> {
    match kind {
        OpKind::Conv2d => vec![Rank::N, Rank::H, Rank::W, Rank::K],
        OpKind::DwConv2d => vec![Rank::N, Rank::H, Rank::W, Rank::C],
        OpKind::Gemm => vec![Rank::H, Rank::K],
        _ => vec![Rank::N, Rank::H, Rank::W, Rank::C],
    }
}

/// Ranks of the input activation tensor per operator kind.
pub fn input_ranks(kind: OpKind) -> Vec<Rank> {
    match kind {
        OpKind::Conv2d | OpKind::DwConv2d => vec![Rank::N, Rank::H, Rank::W, Rank::C],
        OpKind::Gemm => vec![Rank::H, Rank::C],
        _ => vec![Rank::N, Rank::H, Rank::W, Rank::C],
    }
}

/// Map a rank of the producer's *output* tensor to the rank under which the
/// consumer reads the same tensor as *input*. Standard chains:
/// conv→conv: K→C, N/H/W identity (spatial dims align row-for-row for
/// stride-1; staging still works per-row otherwise). GEMM→GEMM: K→C, H→H.
pub fn producer_to_consumer_rank(
    producer_kind: OpKind,
    consumer_kind: OpKind,
    rank: Rank,
) -> Option<Rank> {
    // Producer output ranks in the unified vocabulary.
    let out = output_ranks(producer_kind);
    if !out.contains(&rank) {
        return None;
    }
    let mapped = match rank {
        // Output channels become the consumer's contracted input channels.
        Rank::K => Rank::C,
        // DWConv producers already emit under C.
        r => r,
    };
    if input_ranks(consumer_kind).contains(&mapped) {
        Some(mapped)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    #[test]
    fn conv_rank_extents() {
        let op = Op::conv2d(2, 32, 32, 16, 64, 3, 3, 1, 1);
        assert_eq!(rank_extent(&op, Rank::N), 2);
        assert_eq!(rank_extent(&op, Rank::H), 32);
        assert_eq!(rank_extent(&op, Rank::K), 64);
        assert_eq!(rank_extent(&op, Rank::C), 16);
        assert_eq!(rank_extent(&op, Rank::R), 3);
    }

    #[test]
    fn gemm_maps_to_unified_ranks() {
        let op = Op::gemm(64, 256, 512);
        assert_eq!(rank_extent(&op, Rank::H), 64); // M
        assert_eq!(rank_extent(&op, Rank::K), 512); // N
        assert_eq!(rank_extent(&op, Rank::C), 256); // contracted K
        assert_eq!(rank_extent(&op, Rank::W), 1);
    }

    #[test]
    fn dwconv_has_no_k_rank() {
        let op = Op::dwconv2d(1, 16, 16, 32, 3, 1);
        assert_eq!(rank_extent(&op, Rank::K), 1);
        assert_eq!(rank_extent(&op, Rank::C), 32);
        assert_eq!(output_ranks(op.kind()), vec![Rank::N, Rank::H, Rank::W, Rank::C]);
    }

    #[test]
    fn producer_consumer_rank_mapping() {
        use OpKind::*;
        // conv K → conv C
        assert_eq!(producer_to_consumer_rank(Conv2d, Conv2d, Rank::K), Some(Rank::C));
        // conv H → conv H
        assert_eq!(producer_to_consumer_rank(Conv2d, Conv2d, Rank::H), Some(Rank::H));
        // contracted producer rank is not in its output
        assert_eq!(producer_to_consumer_rank(Conv2d, Conv2d, Rank::C), None);
        // gemm H (M) → gemm H
        assert_eq!(producer_to_consumer_rank(Gemm, Gemm, Rank::H), Some(Rank::H));
        // gemm K (cols) → gemm C (contracted)
        assert_eq!(producer_to_consumer_rank(Gemm, Gemm, Rank::K), Some(Rank::C));
        // conv W does not exist in a gemm consumer
        assert_eq!(producer_to_consumer_rank(Conv2d, Gemm, Rank::W), None);
    }

    #[test]
    fn tile_clamping_and_trips() {
        let op = Op::conv2d(1, 32, 32, 8, 8, 3, 3, 1, 1);
        let mut nest = LoopNest::for_op(&op, DataflowStyle::ActivationStationary);
        nest.set_tile(Rank::H, 5);
        let h = nest.dims[nest.position(Rank::H).unwrap()];
        assert_eq!(h.tile, 5);
        assert_eq!(h.trips(), 7); // ceil(32/5)
        nest.set_tile(Rank::H, 1000); // clamps to extent
        assert_eq!(nest.dims[nest.position(Rank::H).unwrap()].tile, 32);
    }

    #[test]
    fn order_string_smoke() {
        let op = Op::conv2d(1, 8, 8, 4, 4, 3, 3, 1, 1);
        let nest = LoopNest::for_op(&op, DataflowStyle::ActivationStationary);
        assert_eq!(nest.order_string(), "NHWKCRS");
    }
}
