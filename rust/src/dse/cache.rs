//! Memoized segment-cost cache.
//!
//! Candidate partitions overlap heavily: the segment `[i, i+d)` under a
//! given organization, granularity scale and topology appears in every
//! partition that cuts at `i` and `i+d`. Costing it once and sharing the
//! result across the whole search (and across searches — the cache is
//! caller-owned) is what makes exhaustive enumeration tractable; the
//! `benches/dse_search.rs` microbench tracks the warm-vs-cold win.
//!
//! The map is sharded 16 ways so parallel per-topology searches rarely
//! contend, and hit/miss counters double as the search-budget meter.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::SegmentCost;
use crate::ir::ModelGraph;
use crate::spatial::Organization;

/// Cache coordinates of one evaluated segment:
/// `(workload/config fingerprint, start, depth, organization, granularity
/// scale, topology)`. The leading fingerprint ([`context_fingerprint`])
/// makes it safe to share one caller-owned cache across workloads and
/// architecture configs — without it, segment `(0, 1, Sequential, 1, Amp)`
/// of two different models would collide silently.
pub type SegmentKey = (u64, usize, usize, Organization, u64, TopologyKind);

/// Fingerprint of the (workload, architecture) evaluation context a
/// [`SegmentKey`] is scoped to. Hashes the full per-layer structure (order
/// matters — segment coordinates are positional) and the edge list, not
/// just aggregates, so structurally different graphs never share keys.
pub fn context_fingerprint(graph: &ModelGraph, cfg: &ArchConfig) -> u64 {
    let mut h = DefaultHasher::new();
    graph.name.hash(&mut h);
    graph.num_layers().hash(&mut h);
    for layer in graph.layers() {
        layer.name.hash(&mut h);
        layer.macs().hash(&mut h);
        layer.weight_words().hash(&mut h);
        layer.input_act_words().hash(&mut h);
        layer.output_act_words().hash(&mut h);
        layer.is_complex().hash(&mut h);
    }
    for edge in graph.edges() {
        edge.src.hash(&mut h);
        edge.dst.hash(&mut h);
    }
    // ArchConfig holds f64s, so hash its canonical JSON rendering.
    cfg.to_json().to_string().hash(&mut h);
    h.finish()
}

const SHARDS: usize = 16;

/// Hit/miss counters of an [`EvalCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Sharded memoization table for segment evaluations.
pub struct EvalCache {
    shards: Vec<Mutex<HashMap<SegmentKey, SegmentCost>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for EvalCache {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &SegmentKey) -> &Mutex<HashMap<SegmentKey, SegmentCost>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// Return the cached cost for `key`, or compute it with `eval`, insert,
    /// and return it. `eval` runs *outside* the shard lock so parallel
    /// searches never serialize on shard collisions; the miss counter
    /// counts distinct inserted keys (exact in sequential runs — budgeted
    /// searches are sequential, so the budget meter stays precise; a rare
    /// concurrent duplicate evaluation under contention is benign and
    /// counted as a hit).
    pub fn get_or_eval(
        &self,
        key: SegmentKey,
        eval: impl FnOnce() -> SegmentCost,
    ) -> SegmentCost {
        let shard = self.shard(&key);
        if let Some(cost) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return cost.clone();
        }
        let cost = eval();
        let mut map = shard.lock().unwrap();
        if let Some(existing) = map.get(&key) {
            // Another thread won the race; its value is identical.
            self.hits.fetch_add(1, Ordering::Relaxed);
            return existing.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        map.insert(key, cost.clone());
        cost
    }

    /// Peek without evaluating (used by tests).
    pub fn get(&self, key: &SegmentKey) -> Option<SegmentCost> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct evaluated keys.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(start: usize, scale: u64) -> SegmentKey {
        (
            0xC0FFEE,
            start,
            2,
            Organization::FineStriped1D,
            scale,
            TopologyKind::Mesh,
        )
    }

    fn cost(cycles: f64) -> SegmentCost {
        SegmentCost {
            pipeline_cycles: cycles,
            noc_cycles: 0.0,
            gb_cycles: 0.0,
            dram_cycles: 0.0,
            cycles,
            dram_words: 1,
            worst_channel_load_per_interval: 0.0,
            bottleneck_compute_interval: 1.0,
            energy: 1.0,
            noc_energy: 0.0,
        }
    }

    #[test]
    fn misses_then_hits() {
        let c = EvalCache::new();
        let a = c.get_or_eval(key(0, 1), || cost(10.0));
        assert_eq!(a.cycles, 10.0);
        // Second lookup must not re-evaluate.
        let b = c.get_or_eval(key(0, 1), || panic!("re-evaluated"));
        assert_eq!(b.cycles, 10.0);
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn distinct_keys_are_distinct_entries() {
        let c = EvalCache::new();
        for i in 0..100 {
            c.get_or_eval(key(i, 1), || cost(i as f64));
            c.get_or_eval(key(i, 4), || cost(i as f64 + 0.5));
        }
        assert_eq!(c.len(), 200);
        assert_eq!(c.stats().misses, 200);
        assert_eq!(c.get(&key(7, 4)).unwrap().cycles, 7.5);
        assert!(c.get(&key(7, 16)).is_none());
    }

    #[test]
    fn different_contexts_never_collide() {
        let c = EvalCache::new();
        let (ctx_a, rest) = (1u64, key(0, 1));
        let a = (ctx_a, rest.1, rest.2, rest.3, rest.4, rest.5);
        let b = (2u64, rest.1, rest.2, rest.3, rest.4, rest.5);
        c.get_or_eval(a, || cost(1.0));
        let got = c.get_or_eval(b, || cost(2.0));
        assert_eq!(got.cycles, 2.0, "same coordinates, different context");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn context_fingerprint_separates_workloads_and_configs() {
        use crate::workloads::synthetic;
        let cfg = ArchConfig::default();
        let g1 = synthetic::equal_conv_segment(3);
        let g2 = synthetic::pointwise_conv_segment(3);
        assert_ne!(
            context_fingerprint(&g1, &cfg),
            context_fingerprint(&g2, &cfg)
        );
        let small = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        assert_ne!(
            context_fingerprint(&g1, &cfg),
            context_fingerprint(&g1, &small)
        );
        // Deterministic for the same inputs.
        assert_eq!(
            context_fingerprint(&g1, &cfg),
            context_fingerprint(&g1, &cfg)
        );
    }

    #[test]
    fn context_fingerprint_is_layer_order_sensitive() {
        // Same name, same layer multiset, same aggregates — different
        // order must still get distinct keys (coordinates are positional).
        use crate::ir::{Layer, ModelGraph, Op};
        let small = Op::conv2d(1, 8, 8, 4, 4, 3, 3, 1, 1);
        let big = Op::conv2d(1, 8, 8, 4, 16, 3, 3, 1, 1);
        let mut ab = ModelGraph::new("twin");
        ab.add_root(Layer::new("a", small.clone()));
        ab.push(Layer::new("b", big.clone()));
        let mut ba = ModelGraph::new("twin");
        ba.add_root(Layer::new("b", big));
        ba.push(Layer::new("a", small));
        let cfg = ArchConfig::default();
        assert_ne!(
            context_fingerprint(&ab, &cfg),
            context_fingerprint(&ba, &cfg)
        );
    }

    #[test]
    fn hit_rate_math() {
        let s = CacheStats { hits: 3, misses: 1 };
        assert_eq!(s.lookups(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn shared_across_threads() {
        let c = EvalCache::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for i in 0..50 {
                        c.get_or_eval(key(i, 1), || cost(i as f64));
                    }
                });
            }
        });
        // 50 distinct keys, 200 lookups: every key evaluated exactly once.
        assert_eq!(c.len(), 50);
        let s = c.stats();
        assert_eq!(s.misses, 50);
        assert_eq!(s.lookups(), 200);
    }
}
