//! Memoized segment-cost cache.
//!
//! Candidate partitions overlap heavily: the segment `[i, i+d)` under a
//! given organization, granularity scale and topology appears in every
//! partition that cuts at `i` and `i+d`. Costing it once and sharing the
//! result across the whole search (and across searches — the cache is
//! caller-owned) is what makes exhaustive enumeration tractable; the
//! `benches/dse_search.rs` microbench tracks the warm-vs-cold win.
//!
//! The map is sharded 16 ways so parallel per-topology searches rarely
//! contend, and hit/miss counters double as the search-budget meter.
//!
//! The cache is also *persistent*: [`EvalCache::save_file`] /
//! [`EvalCache::load_file`] serialize it through `util::json` (versioned,
//! fingerprint-keyed) so repeated CLI sweeps and CI runs start warm across
//! processes. Loading is corruption-tolerant by design — a missing,
//! truncated, version-skewed, or garbage file degrades to a cold start,
//! and individually malformed entries are skipped: the cache is an
//! optimization, never a correctness dependency.
//!
//! Persistence makes growth a problem: a cache file fed by repeated sweeps
//! would grow without bound (and would keep entries whose workload
//! definition has since changed, which can never hit again because the
//! fingerprint changed with it). Two bounded-size levers fix that before a
//! save: [`EvalCache::retain_contexts`] drops entries whose context
//! fingerprint is no longer live, and [`EvalCache::prune_to_cap`] evicts
//! least-recently-used entries beyond a cap ([`CACHE_DEFAULT_CAP`] unless
//! `--cache-cap` overrides it). Recency is a per-process access tick:
//! entries hydrated from a file start at tick 0, so untouched hydrated
//! entries are always the first to go.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::SegmentCost;
use crate::ir::ModelGraph;
use crate::spatial::Organization;
use crate::util::json::Json;

/// On-disk cache format version. Bump on any change to the entry layout or
/// to the [`context_fingerprint`] recipe (old fingerprints would silently
/// alias new ones otherwise); loaders reject any other version and fall
/// back to a cold start. Version 2: the fingerprint became a combination
/// of separately-hashed graph and architecture halves.
pub const CACHE_FILE_VERSION: u64 = 2;

/// Default entry cap applied before [`EvalCache::save_file`] by the CLI
/// (`--cache-cap` overrides). One serialized entry is ~300 bytes of
/// pretty JSON, so a capped file stays around 5 MB.
pub const CACHE_DEFAULT_CAP: usize = 16_384;

/// Cache coordinates of one evaluated segment:
/// `(workload/config fingerprint, start, depth, organization, granularity
/// scale, topology)`. The leading fingerprint ([`context_fingerprint`])
/// makes it safe to share one caller-owned cache across workloads and
/// architecture configs — without it, segment `(0, 1, Sequential, 1, Amp)`
/// of two different models would collide silently.
pub type SegmentKey = (u64, usize, usize, Organization, u64, TopologyKind);

/// Cache coordinates of a *heuristic-planned* segment: granularity scale
/// is always 1, so the segment lives exactly where the DSE enumerator
/// would put it (`dse::space::build_planned(.., org, 1)` rebuilds it
/// bit-identically). Both the DSE's seed path and cosched's plan costing
/// key through this helper, so the layout can never drift between them —
/// that shared layout is what lets one persistent cache warm-start dse,
/// tuned planning, and co-scheduling alike.
pub fn heuristic_segment_key(
    ctx: u64,
    ps: &crate::cost::PlannedSegment,
    topology: TopologyKind,
) -> SegmentKey {
    (
        ctx,
        ps.segment.start,
        ps.segment.depth,
        ps.organization,
        1,
        topology,
    )
}

/// Fingerprint of the (workload, architecture) evaluation context a
/// [`SegmentKey`] is scoped to: [`graph_fingerprint`] and
/// [`arch_fingerprint`] combined via [`combine_fingerprints`].
///
/// The split matters on the co-scheduler's hot path: enumerating a
/// scenario's live contexts crosses every task graph with every candidate
/// region config, and hashing each half once — n graph walks plus G
/// config serializations instead of n×G full fingerprints — collapses the
/// dominant JSON-rendering cost of the sweep (see `docs/PERFORMANCE.md`).
pub fn context_fingerprint(graph: &ModelGraph, cfg: &ArchConfig) -> u64 {
    combine_fingerprints(graph_fingerprint(graph), arch_fingerprint(cfg))
}

/// Workload half of [`context_fingerprint`]. Hashes the full per-layer
/// structure (order matters — segment coordinates are positional) and the
/// edge list, not just aggregates, so structurally different graphs never
/// share keys.
pub fn graph_fingerprint(graph: &ModelGraph) -> u64 {
    let mut h = DefaultHasher::new();
    graph.name.hash(&mut h);
    graph.num_layers().hash(&mut h);
    for layer in graph.layers() {
        layer.name.hash(&mut h);
        layer.macs().hash(&mut h);
        layer.weight_words().hash(&mut h);
        layer.input_act_words().hash(&mut h);
        layer.output_act_words().hash(&mut h);
        layer.is_complex().hash(&mut h);
    }
    for edge in graph.edges() {
        edge.src.hash(&mut h);
        edge.dst.hash(&mut h);
    }
    h.finish()
}

/// Architecture half of [`context_fingerprint`]. ArchConfig holds f64s,
/// so hash its canonical JSON rendering.
pub fn arch_fingerprint(cfg: &ArchConfig) -> u64 {
    let mut h = DefaultHasher::new();
    cfg.to_json().to_string().hash(&mut h);
    h.finish()
}

/// Combine the two fingerprint halves into one context fingerprint. By
/// definition `context_fingerprint(g, c) ==
/// combine_fingerprints(graph_fingerprint(g), arch_fingerprint(c))`, so
/// callers that sweep one axis may hash each half once and cross-combine.
pub fn combine_fingerprints(graph_fp: u64, arch_fp: u64) -> u64 {
    let mut h = DefaultHasher::new();
    graph_fp.hash(&mut h);
    arch_fp.hash(&mut h);
    h.finish()
}

const SHARDS: usize = 16;

/// Hit/miss counters of an [`EvalCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Per-run hit/miss accumulator for lookups made through
/// [`EvalCache::get_or_eval_in`]. The cache's own counters are global to
/// its lifetime (and shared by every concurrent user), so budget metering
/// and per-run evaluation reporting go through one of these instead: a
/// fresh `RunCounters` sees exactly its own run's lookups, no matter how
/// many other searches hammer the same cache concurrently.
#[derive(Debug, Default)]
pub struct RunCounters {
    hits: AtomicU64,
    misses: AtomicU64,
}

impl RunCounters {
    pub fn new() -> RunCounters {
        RunCounters::default()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Fold another meter's totals into this one — used when inner
    /// searches get fresh per-plan budget windows but an outer sweep still
    /// reports aggregate evaluations/hits (e.g. cosched's per-(task, width)
    /// tuned plans under one scenario).
    pub fn absorb(&self, stats: CacheStats) {
        self.hits.fetch_add(stats.hits, Ordering::Relaxed);
        self.misses.fetch_add(stats.misses, Ordering::Relaxed);
    }
}

/// One cached evaluation plus its last-access tick (the LRU clock of
/// [`EvalCache::prune_to_cap`]). Hydrated entries start at tick 0; every
/// lookup through `get_or_eval*` bumps the tick.
struct Slot {
    cost: SegmentCost,
    tick: u64,
}

/// Sharded memoization table for segment evaluations.
pub struct EvalCache {
    shards: Vec<Mutex<HashMap<SegmentKey, Slot>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Monotone access clock shared by all shards.
    tick: AtomicU64,
}

impl Default for EvalCache {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            tick: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &SegmentKey) -> &Mutex<HashMap<SegmentKey, Slot>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// Next value of the access clock (never 0, so tick 0 uniquely marks
    /// hydrated-and-untouched entries).
    fn now(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Return the cached cost for `key`, or compute it with `eval`, insert,
    /// and return it. `eval` runs *outside* the shard lock so parallel
    /// searches never serialize on shard collisions; the miss counter
    /// counts distinct inserted keys (a rare concurrent duplicate
    /// evaluation under contention is benign and counted as a hit).
    pub fn get_or_eval(
        &self,
        key: SegmentKey,
        eval: impl FnOnce() -> SegmentCost,
    ) -> SegmentCost {
        self.get_or_eval_in(key, eval, &RunCounters::default())
    }

    /// [`EvalCache::get_or_eval`] that additionally charges the lookup to a
    /// caller-owned [`RunCounters`]. Search budgets and per-run evaluation
    /// reports meter on `run`, not on the cache's global counters, so one
    /// run's accounting stays exact even when other tasks/plans miss into
    /// the same shared cache concurrently.
    pub fn get_or_eval_in(
        &self,
        key: SegmentKey,
        eval: impl FnOnce() -> SegmentCost,
        run: &RunCounters,
    ) -> SegmentCost {
        let shard = self.shard(&key);
        if let Some(slot) = shard.lock().unwrap().get_mut(&key) {
            slot.tick = self.now();
            self.hits.fetch_add(1, Ordering::Relaxed);
            run.hits.fetch_add(1, Ordering::Relaxed);
            return slot.cost.clone();
        }
        let cost = eval();
        let mut map = shard.lock().unwrap();
        if let Some(slot) = map.get_mut(&key) {
            // Another thread won the race; its value is identical.
            slot.tick = self.now();
            self.hits.fetch_add(1, Ordering::Relaxed);
            run.hits.fetch_add(1, Ordering::Relaxed);
            return slot.cost.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        run.misses.fetch_add(1, Ordering::Relaxed);
        map.insert(
            key,
            Slot {
                cost: cost.clone(),
                tick: self.now(),
            },
        );
        cost
    }

    /// Peek without evaluating or touching the access clock (used by
    /// tests).
    pub fn get(&self, key: &SegmentKey) -> Option<SegmentCost> {
        self.shard(key)
            .lock()
            .unwrap()
            .get(key)
            .map(|s| s.cost.clone())
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct evaluated keys.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert an already-known cost without touching the hit/miss counters
    /// or the access clock: hydrated entries are neither hits nor misses of
    /// this process's searches (so the budget meter and the warm-vs-cold
    /// evaluation counts stay exact), and at tick 0 they are the first
    /// candidates for LRU eviction until a lookup touches them.
    pub fn preload(&self, key: SegmentKey, cost: SegmentCost) {
        self.shard(&key)
            .lock()
            .unwrap()
            .insert(key, Slot { cost, tick: 0 });
    }

    /// Drop every entry whose context fingerprint is not in `live`,
    /// returning how many were removed. A fingerprint goes dead when the
    /// workload or architecture it hashes changes — those entries can never
    /// hit again, so pruning them before [`EvalCache::save_file`] keeps
    /// persistent caches from accreting garbage across zoo edits.
    pub fn retain_contexts(&self, live: &HashSet<u64>) -> usize {
        let mut removed = 0;
        for shard in &self.shards {
            let mut map = shard.lock().unwrap();
            let before = map.len();
            map.retain(|k, _| live.contains(&k.0));
            removed += before - map.len();
        }
        removed
    }

    /// Context fingerprints of entries inserted or hit by *this process*
    /// (hydrated-but-untouched entries excluded). Callers union this with
    /// their statically-known live set before [`EvalCache::retain_contexts`]
    /// so contexts only this run knows about (e.g. per-region configs of a
    /// cosched search) survive the save.
    pub fn touched_contexts(&self) -> HashSet<u64> {
        let mut out = HashSet::new();
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            out.extend(map.iter().filter(|(_, s)| s.tick > 0).map(|(k, _)| k.0));
        }
        out
    }

    /// Evict least-recently-used entries until at most `cap` remain,
    /// returning how many were evicted. Ties (notably the tick-0 hydrated
    /// entries) break on the key coordinates, so eviction is deterministic.
    pub fn prune_to_cap(&self, cap: usize) -> usize {
        if self.len() <= cap {
            return 0;
        }
        let mut order: Vec<(u64, SegmentKey)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            order.extend(map.iter().map(|(k, s)| (s.tick, *k)));
        }
        order.sort_by_key(|&(tick, (ctx, start, depth, org, scale, topo))| {
            (tick, ctx, start, depth, org.name(), scale, topo.name())
        });
        let evict = order.len().saturating_sub(cap);
        for &(_, key) in order.iter().take(evict) {
            self.shard(&key).lock().unwrap().remove(&key);
        }
        evict
    }

    /// Every `(key, cost)` entry, sorted by key coordinates so serialized
    /// caches are byte-stable across runs (shard/HashMap order is not).
    fn entries(&self) -> Vec<(SegmentKey, SegmentCost)> {
        let mut out: Vec<(SegmentKey, SegmentCost)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            out.extend(map.iter().map(|(k, s)| (*k, s.cost.clone())));
        }
        out.sort_by_key(|((ctx, start, depth, org, scale, topo), _)| {
            (*ctx, *start, *depth, org.name(), *scale, topo.name())
        });
        out
    }

    /// Serialize to the versioned on-disk format. Context fingerprints are
    /// hex strings (they are full u64 hashes, which `Json::Num`'s f64 would
    /// truncate); everything else is numeric or a stable enum name.
    pub fn to_json(&self) -> Json {
        let mut entries = Json::Arr(Vec::new());
        for ((ctx, start, depth, org, scale, topo), cost) in self.entries() {
            let mut e = Json::obj();
            e.set("ctx", format!("{ctx:016x}"))
                .set("start", start)
                .set("depth", depth)
                .set("org", org.name())
                .set("scale", scale)
                .set("topology", topo.name())
                .set("cost", cost.to_json());
            entries.push(e);
        }
        let mut o = Json::obj();
        o.set("version", CACHE_FILE_VERSION).set("entries", entries);
        o
    }

    /// Rebuild from a parsed cache document. A missing/unsupported version
    /// or a malformed top level is an error (the caller degrades it to a
    /// cold start); individually malformed *entries* are skipped so one
    /// corrupt line never throws away the rest of a warm cache.
    pub fn from_json(v: &Json) -> Result<EvalCache, String> {
        let version = v
            .get("version")
            .and_then(Json::as_f64)
            .ok_or("cache file has no version field")? as u64;
        if version != CACHE_FILE_VERSION {
            return Err(format!(
                "unsupported cache version {version} (expected {CACHE_FILE_VERSION})"
            ));
        }
        let entries = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("cache file has no entries array")?;
        let cache = EvalCache::new();
        for e in entries {
            if let Some((key, cost)) = parse_entry(e) {
                cache.preload(key, cost);
            }
        }
        Ok(cache)
    }

    /// Persist to `path` (pretty JSON, written via a sibling temp file +
    /// rename so a crash mid-write never leaves a truncated cache behind).
    pub fn save_file(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_pretty())?;
        std::fs::rename(&tmp, path)
    }

    /// Load from `path`, degrading to an empty (cold) cache on *any*
    /// failure — missing file, unreadable file, truncated/garbage JSON, or
    /// version skew. The outcome reports which of those happened so the
    /// CLI can tell the user, but no failure mode is fatal.
    pub fn load_file(path: &Path) -> (EvalCache, CacheLoadOutcome) {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => return (EvalCache::new(), CacheLoadOutcome::Cold),
        };
        match Json::parse(&text).and_then(|v| EvalCache::from_json(&v)) {
            Ok(cache) => {
                let entries = cache.len();
                (cache, CacheLoadOutcome::Warm { entries })
            }
            Err(reason) => (EvalCache::new(), CacheLoadOutcome::Rejected { reason }),
        }
    }
}

/// One serialized cache entry back into `(key, cost)`; `None` (skip) on any
/// malformed field.
fn parse_entry(e: &Json) -> Option<(SegmentKey, SegmentCost)> {
    let ctx = u64::from_str_radix(e.get("ctx")?.as_str()?, 16).ok()?;
    let start = e.get("start")?.as_usize()?;
    let depth = e.get("depth")?.as_usize()?;
    let org = Organization::from_name(e.get("org")?.as_str()?)?;
    let scale = e.get("scale")?.as_f64()? as u64;
    let topo = TopologyKind::from_name(e.get("topology")?.as_str()?)?;
    let cost = SegmentCost::from_json(e.get("cost")?)?;
    Some(((ctx, start, depth, org, scale, topo), cost))
}

/// What [`EvalCache::load_file`] found at the path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheLoadOutcome {
    /// No readable file — a normal cold start.
    Cold,
    /// Hydrated `entries` prior evaluations.
    Warm { entries: usize },
    /// A file existed but was rejected (corrupt or version-skewed); the
    /// run proceeds from a cold cache.
    Rejected { reason: String },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(start: usize, scale: u64) -> SegmentKey {
        (
            0xC0FFEE,
            start,
            2,
            Organization::FineStriped1D,
            scale,
            TopologyKind::Mesh,
        )
    }

    fn cost(cycles: f64) -> SegmentCost {
        SegmentCost {
            pipeline_cycles: cycles,
            noc_cycles: 0.0,
            gb_cycles: 0.0,
            dram_cycles: 0.0,
            cycles,
            dram_words: 1,
            worst_channel_load_per_interval: 0.0,
            bottleneck_compute_interval: 1.0,
            energy: 1.0,
            noc_energy: 0.0,
        }
    }

    #[test]
    fn misses_then_hits() {
        let c = EvalCache::new();
        let a = c.get_or_eval(key(0, 1), || cost(10.0));
        assert_eq!(a.cycles, 10.0);
        // Second lookup must not re-evaluate.
        let b = c.get_or_eval(key(0, 1), || panic!("re-evaluated"));
        assert_eq!(b.cycles, 10.0);
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn distinct_keys_are_distinct_entries() {
        let c = EvalCache::new();
        for i in 0..100 {
            c.get_or_eval(key(i, 1), || cost(i as f64));
            c.get_or_eval(key(i, 4), || cost(i as f64 + 0.5));
        }
        assert_eq!(c.len(), 200);
        assert_eq!(c.stats().misses, 200);
        assert_eq!(c.get(&key(7, 4)).unwrap().cycles, 7.5);
        assert!(c.get(&key(7, 16)).is_none());
    }

    #[test]
    fn different_contexts_never_collide() {
        let c = EvalCache::new();
        let (ctx_a, rest) = (1u64, key(0, 1));
        let a = (ctx_a, rest.1, rest.2, rest.3, rest.4, rest.5);
        let b = (2u64, rest.1, rest.2, rest.3, rest.4, rest.5);
        c.get_or_eval(a, || cost(1.0));
        let got = c.get_or_eval(b, || cost(2.0));
        assert_eq!(got.cycles, 2.0, "same coordinates, different context");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn context_fingerprint_separates_workloads_and_configs() {
        use crate::workloads::synthetic;
        let cfg = ArchConfig::default();
        let g1 = synthetic::equal_conv_segment(3);
        let g2 = synthetic::pointwise_conv_segment(3);
        assert_ne!(
            context_fingerprint(&g1, &cfg),
            context_fingerprint(&g2, &cfg)
        );
        let small = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        assert_ne!(
            context_fingerprint(&g1, &cfg),
            context_fingerprint(&g1, &small)
        );
        // Deterministic for the same inputs.
        assert_eq!(
            context_fingerprint(&g1, &cfg),
            context_fingerprint(&g1, &cfg)
        );
    }

    #[test]
    fn context_fingerprint_is_layer_order_sensitive() {
        // Same name, same layer multiset, same aggregates — different
        // order must still get distinct keys (coordinates are positional).
        use crate::ir::{Layer, ModelGraph, Op};
        let small = Op::conv2d(1, 8, 8, 4, 4, 3, 3, 1, 1);
        let big = Op::conv2d(1, 8, 8, 4, 16, 3, 3, 1, 1);
        let mut ab = ModelGraph::new("twin");
        ab.add_root(Layer::new("a", small.clone()));
        ab.push(Layer::new("b", big.clone()));
        let mut ba = ModelGraph::new("twin");
        ba.add_root(Layer::new("b", big));
        ba.push(Layer::new("a", small));
        let cfg = ArchConfig::default();
        assert_ne!(
            context_fingerprint(&ab, &cfg),
            context_fingerprint(&ba, &cfg)
        );
    }

    #[test]
    fn hit_rate_math() {
        let s = CacheStats { hits: 3, misses: 1 };
        assert_eq!(s.lookups(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn run_counters_isolate_runs_sharing_one_cache() {
        let c = EvalCache::new();
        let run_a = RunCounters::new();
        let run_b = RunCounters::new();
        for i in 0..10 {
            c.get_or_eval_in(key(i, 1), || cost(i as f64), &run_a);
        }
        for i in 0..10 {
            c.get_or_eval_in(key(i, 1), || panic!("cached"), &run_b);
        }
        assert_eq!(run_a.stats(), CacheStats { hits: 0, misses: 10 });
        assert_eq!(run_b.stats(), CacheStats { hits: 10, misses: 0 });
        // The cache's own counters stay global across both runs.
        assert_eq!(c.stats().lookups(), 20);
    }

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "pipeorgan_cache_test_{}_{tag}.json",
            std::process::id()
        ))
    }

    #[test]
    fn save_load_roundtrip_preserves_every_entry() {
        let c = EvalCache::new();
        for i in 0..25 {
            c.get_or_eval(key(i, 1), || cost(i as f64 + 0.125));
            c.get_or_eval(key(i, 4), || cost(i as f64 * 3.5));
        }
        let path = tmp_path("roundtrip");
        c.save_file(&path).unwrap();
        let (loaded, outcome) = EvalCache::load_file(&path);
        assert_eq!(outcome, CacheLoadOutcome::Warm { entries: 50 });
        assert_eq!(loaded.len(), c.len());
        // Hydration counts as neither hits nor misses...
        assert_eq!(loaded.stats(), CacheStats::default());
        // ...and every lookup on the hydrated cache is a hit with the
        // exact original value (no re-evaluation).
        for i in 0..25 {
            let got = loaded.get_or_eval(key(i, 1), || panic!("re-evaluated"));
            assert_eq!(got, cost(i as f64 + 0.125));
        }
        assert_eq!(loaded.stats().hits, 25);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_a_cold_start() {
        let (c, outcome) = EvalCache::load_file(&tmp_path("never_written"));
        assert_eq!(outcome, CacheLoadOutcome::Cold);
        assert!(c.is_empty());
    }

    #[test]
    fn garbage_and_truncated_files_degrade_to_cold_start() {
        for (tag, text) in [
            ("garbage", "not json at all"),
            ("truncated", "{\"version\": 1, \"entries\": [{\"ctx\""),
            ("wrong_shape", "[1, 2, 3]"),
            ("no_version", "{\"entries\": []}"),
        ] {
            let path = tmp_path(tag);
            std::fs::write(&path, text).unwrap();
            let (c, outcome) = EvalCache::load_file(&path);
            assert!(
                matches!(outcome, CacheLoadOutcome::Rejected { .. }),
                "{tag}: expected rejection, got {outcome:?}"
            );
            assert!(c.is_empty(), "{tag}: rejected file must yield a cold cache");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn version_skew_is_rejected() {
        let c = EvalCache::new();
        c.get_or_eval(key(0, 1), || cost(1.0));
        let mut doc = c.to_json();
        doc.set("version", CACHE_FILE_VERSION + 1);
        let path = tmp_path("version_skew");
        std::fs::write(&path, doc.to_pretty()).unwrap();
        let (loaded, outcome) = EvalCache::load_file(&path);
        assert!(matches!(outcome, CacheLoadOutcome::Rejected { .. }));
        assert!(loaded.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_entries_are_skipped_not_fatal() {
        let c = EvalCache::new();
        c.get_or_eval(key(0, 1), || cost(1.0));
        c.get_or_eval(key(1, 1), || cost(2.0));
        let mut doc = c.to_json();
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Arr(entries)) = m.get_mut("entries") {
                // One bogus organization, one non-object entry.
                let mut bad = entries[0].clone();
                bad.set("org", "hexagonal");
                entries.push(bad);
                entries.push(Json::from("noise"));
            }
        }
        let good = EvalCache::from_json(&doc).unwrap();
        assert_eq!(good.len(), 2, "both well-formed entries survive");
    }

    #[test]
    fn serialized_form_is_stable_and_parseable() {
        let c = EvalCache::new();
        for i in 0..10 {
            c.get_or_eval(key(i, 1), || cost(i as f64));
        }
        let a = c.to_json().to_pretty();
        let b = c.to_json().to_pretty();
        assert_eq!(a, b, "serialization must be deterministic");
        Json::parse(&a).unwrap();
    }

    #[test]
    fn prune_to_cap_respects_cap_and_keeps_recently_used() {
        let c = EvalCache::new();
        for i in 0..20 {
            c.get_or_eval(key(i, 1), || cost(i as f64));
        }
        // Re-touch the first three keys: they become the most recent.
        for i in 0..3 {
            c.get_or_eval(key(i, 1), || panic!("cached"));
        }
        let evicted = c.prune_to_cap(5);
        assert_eq!(evicted, 15);
        assert_eq!(c.len(), 5);
        // Survivors: the three re-touched keys plus the two most recently
        // inserted ones.
        for i in [0, 1, 2, 18, 19] {
            assert!(c.get(&key(i, 1)).is_some(), "key {i} evicted");
        }
        for i in 3..18 {
            assert!(c.get(&key(i, 1)).is_none(), "key {i} survived");
        }
        // Already under cap: a no-op.
        assert_eq!(c.prune_to_cap(5), 0);
        assert_eq!(c.prune_to_cap(1000), 0);
    }

    #[test]
    fn hydrated_entries_are_evicted_before_touched_ones() {
        let c = EvalCache::new();
        for i in 0..10 {
            c.preload(key(i, 1), cost(i as f64)); // tick 0
        }
        for i in 10..15 {
            c.get_or_eval(key(i, 1), || cost(i as f64)); // ticked
        }
        assert_eq!(c.prune_to_cap(5), 10);
        for i in 10..15 {
            assert!(c.get(&key(i, 1)).is_some(), "touched key {i} evicted");
        }
        for i in 0..10 {
            assert!(c.get(&key(i, 1)).is_none(), "hydrated key {i} survived");
        }
    }

    #[test]
    fn retain_contexts_drops_dead_fingerprints() {
        let c = EvalCache::new();
        let mk = |ctx: u64, start: usize| -> SegmentKey {
            (
                ctx,
                start,
                2,
                Organization::FineStriped1D,
                1,
                TopologyKind::Mesh,
            )
        };
        for i in 0..5 {
            c.get_or_eval(mk(0xA, i), || cost(1.0));
            c.get_or_eval(mk(0xB, i), || cost(2.0));
        }
        let live: HashSet<u64> = [0xB].into_iter().collect();
        assert_eq!(c.retain_contexts(&live), 5);
        assert_eq!(c.len(), 5);
        assert!(c.get(&mk(0xA, 0)).is_none());
        assert!(c.get(&mk(0xB, 0)).is_some());
        // Touched contexts reports only what this process looked up.
        assert_eq!(c.touched_contexts(), [0xB].into_iter().collect());
    }

    #[test]
    fn touched_contexts_excludes_hydrated_entries() {
        let c = EvalCache::new();
        c.preload(key(0, 1), cost(1.0));
        assert!(c.touched_contexts().is_empty());
        c.get_or_eval(key(0, 1), || panic!("cached"));
        assert_eq!(c.touched_contexts().len(), 1);
    }

    #[test]
    fn pruned_cache_roundtrips_through_disk() {
        let c = EvalCache::new();
        for i in 0..30 {
            c.get_or_eval(key(i, 1), || cost(i as f64));
        }
        c.prune_to_cap(10);
        let path = tmp_path("pruned");
        c.save_file(&path).unwrap();
        let (loaded, outcome) = EvalCache::load_file(&path);
        assert_eq!(outcome, CacheLoadOutcome::Warm { entries: 10 });
        assert_eq!(loaded.len(), 10);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shared_across_threads() {
        let c = EvalCache::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for i in 0..50 {
                        c.get_or_eval(key(i, 1), || cost(i as f64));
                    }
                });
            }
        });
        // 50 distinct keys, 200 lookups: every key evaluated exactly once.
        assert_eq!(c.len(), 50);
        let s = c.stats();
        assert_eq!(s.misses, 50);
        assert_eq!(s.lookups(), 200);
    }
}
