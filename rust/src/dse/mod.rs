//! Design-space exploration (DSE) over the inter-operator pipelining
//! mapping space (see DESIGN.md §6).
//!
//! The paper's observation is that the pipelining design space — depth ×
//! granularity × spatial organization × interconnect — is huge and shape-
//! dependent, and the closed-form heuristics of Sec. IV only cover a slice
//! of it. This subsystem searches the space directly so the heuristic can
//! be measured against a true optimum:
//!
//! - `space`: enumerate candidate segments — every contiguous layer
//!   partition up to a depth cap, crossed with a granularity ladder
//!   (powers of 4 over the Algorithm-1 finest granularity) and the oracle
//!   organization candidates, on each NoC topology;
//! - `cache`: a sharded, memoized evaluation cache so a sub-plan shared
//!   by many candidate partitions is costed through `cost::evaluate_segment`
//!   exactly once;
//! - `search`: exhaustive and beam-width-bounded multi-objective dynamic
//!   programming over segment boundaries (per-segment costs are additive,
//!   so Pareto-optimal plans have Pareto-optimal prefixes);
//! - `pareto`: extraction of the latency/energy/DRAM-traffic frontier
//!   (plus, behind [`DseConfig::channel_load_objective`], the Fig. 15
//!   worst-channel-load axis, so congestion-free trade-offs stay visible).
//!
//! The searched frontier is seeded with the heuristic mapper's plan
//! whenever its topology is inside the searched set (always true for the
//! default configuration), so the reported best is never costlier than the
//! heuristic — the gap between the two is exactly what `report::dse_gap`
//! tabulates. Restricting `--topologies` to exclude the heuristic's NoC
//! keeps the frontier inside the restriction; the gap may then honestly
//! drop below 1.

mod cache;
mod pareto;
mod search;
mod space;

pub use cache::{
    arch_fingerprint, combine_fingerprints, context_fingerprint, graph_fingerprint,
    heuristic_segment_key, CacheLoadOutcome, CacheStats, EvalCache, RunCounters, SegmentKey,
    CACHE_DEFAULT_CAP, CACHE_FILE_VERSION,
};
pub use pareto::{dominates, dominates_first, pareto_filter, pareto_filter_first, ParetoPoint};
pub use search::{explore, tuned_plan, DseResult, PlanPoint};
pub use space::{legal_depths, segment_candidates, CandidateSegment};

use crate::config::TopologyKind;

/// Default plan-time evaluation budget (cost-model calls, i.e. cache
/// misses) of the tuned mapper. Sized so a cold plan of the largest zoo
/// task stays interactive while still covering the shallow-depth slice of
/// the space where the paper's Fig. 16–17 optima live; warm caches make it
/// mostly irrelevant.
pub const TUNED_DEFAULT_BUDGET: u64 = 4096;

/// Search strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchStrategy {
    /// Exact multi-objective DP (Pareto sets bounded only by
    /// [`DseConfig::max_labels`]).
    Exhaustive,
    /// DP with each boundary's label set truncated to
    /// [`DseConfig::beam_width`] labels (kept in ascending latency, so the
    /// latency-optimal prefix always survives).
    Beam,
}

impl SearchStrategy {
    pub fn name(self) -> &'static str {
        match self {
            SearchStrategy::Exhaustive => "exhaustive",
            SearchStrategy::Beam => "beam",
        }
    }

    pub fn from_name(s: &str) -> Option<SearchStrategy> {
        match s {
            "exhaustive" => Some(SearchStrategy::Exhaustive),
            "beam" => Some(SearchStrategy::Beam),
            _ => None,
        }
    }
}

/// Knobs of one DSE run. CLI flags map 1:1 onto these (see [`DSE_FLAGS`]).
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub strategy: SearchStrategy,
    /// Labels kept per segment boundary under [`SearchStrategy::Beam`].
    pub beam_width: usize,
    /// Maximum segment depth enumerated (further capped by
    /// `ArchConfig::max_pipeline_depth`).
    pub depth_cap: usize,
    /// Granularity-ladder rungs per handoff (rung r scales the finest
    /// granularity by `4^r`; the ladder stops early once handoffs
    /// saturate at whole-tensor).
    pub ladder_rungs: usize,
    /// NoC topologies searched (the plan-level axis of the space).
    pub topologies: Vec<TopologyKind>,
    /// Optional cap on cost-model evaluations (cache misses). Once
    /// exhausted, enumeration narrows to the heuristic variant per segment
    /// so the search still completes with valid plans.
    pub budget: Option<u64>,
    /// Safety cap on per-boundary Pareto sets under
    /// [`SearchStrategy::Exhaustive`].
    pub max_labels: usize,
    /// Make the Fig. 15 worst-case channel load a fourth Pareto objective
    /// (`--channel-load-objective`). Off by default: the frontier then
    /// reproduces the original latency/energy/DRAM front exactly, while
    /// the load value is still computed and reported on every point.
    pub channel_load_objective: bool,
    /// Observability handle (`--obs` / `--trace-out`): per-candidate eval
    /// timing and cache hit/miss counters. Disabled (free) by default.
    pub obs: crate::obs::Obs,
}

impl Default for DseConfig {
    fn default() -> Self {
        Self {
            strategy: SearchStrategy::Beam,
            beam_width: 8,
            depth_cap: 8,
            ladder_rungs: 4,
            topologies: vec![
                TopologyKind::Amp,
                TopologyKind::Mesh,
                TopologyKind::FlattenedButterfly,
                TopologyKind::Torus,
            ],
            budget: None,
            max_labels: 256,
            channel_load_objective: false,
            obs: crate::obs::Obs::disabled(),
        }
    }
}

impl DseConfig {
    /// A reduced configuration for tests and smoke runs: beam search over
    /// the two headline topologies with a shallow depth cap.
    pub fn quick() -> Self {
        Self {
            strategy: SearchStrategy::Beam,
            beam_width: 6,
            depth_cap: 4,
            ladder_rungs: 2,
            topologies: vec![TopologyKind::Amp, TopologyKind::Mesh],
            budget: None,
            max_labels: 64,
            channel_load_objective: false,
            obs: crate::obs::Obs::disabled(),
        }
    }

    /// How many leading objectives participate in Pareto dominance:
    /// 3 (cycles, energy, DRAM) normally, 4 with the channel-load axis
    /// enabled.
    pub fn objective_count(&self) -> usize {
        if self.channel_load_objective {
            4
        } else {
            3
        }
    }

    /// Plan-time knobs of the tuned mapper: beam search over the mapper's
    /// own `topology` under the default evaluation budget. Depth cap and
    /// ladder are the full defaults — the budget, not the enumeration, is
    /// what keeps plan-time search cheap.
    pub fn tuned(topology: TopologyKind) -> Self {
        Self {
            strategy: SearchStrategy::Beam,
            topologies: vec![topology],
            budget: Some(TUNED_DEFAULT_BUDGET),
            ..Self::default()
        }
    }

    /// Build from parsed CLI flags (the `dse` subcommand).
    pub fn from_cli(args: &crate::cli::Args) -> Result<DseConfig, String> {
        let mut dse = DseConfig::default();
        if let Some(s) = args.get("strategy") {
            dse.strategy = SearchStrategy::from_name(s)
                .ok_or_else(|| format!("unknown strategy `{s}` (expected `beam` or `exhaustive`)"))?;
        }
        dse.beam_width = args.get_usize("beam", dse.beam_width)?.max(1);
        dse.depth_cap = args.get_usize("depth-cap", dse.depth_cap)?.max(1);
        dse.ladder_rungs = args.get_usize("rungs", dse.ladder_rungs)?.max(1);
        if args.has("budget") {
            dse.budget = Some(args.get_u64("budget", 0)?);
        }
        if let Some(list) = args.get("topologies") {
            let mut topos = Vec::new();
            for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                topos.push(
                    TopologyKind::from_name(name)
                        .ok_or_else(|| format!("unknown topology `{name}`"))?,
                );
            }
            if topos.is_empty() {
                return Err("flag `--topologies` lists no topologies".into());
            }
            dse.topologies = topos;
        }
        dse.channel_load_objective = args.has("channel-load-objective");
        dse.obs = crate::obs::Obs::from_cli(args);
        Ok(dse)
    }
}

/// Flags accepted by the `dse` subcommand on top of the global ones
/// (`(name, takes_value)` — the `cli::Args` strict-flag table format).
/// `--cache-file` names the persistent [`EvalCache`] file: loaded (warm
/// start) before the sweep, pruned to `--cache-cap` entries
/// ([`CACHE_DEFAULT_CAP`] by default) and saved back after it.
/// `--channel-load-objective` adds the Fig. 15 worst-channel-load metric
/// as a fourth Pareto axis. `--obs` enables the observability counters;
/// `--trace-out FILE` additionally writes the Perfetto trace there (and
/// implies `--obs`).
pub const DSE_FLAGS: &[(&str, bool)] = &[
    ("workload", true),
    ("strategy", true),
    ("beam", true),
    ("depth-cap", true),
    ("rungs", true),
    ("budget", true),
    ("topologies", true),
    ("cache-file", true),
    ("cache-cap", true),
    ("channel-load-objective", false),
    ("obs", false),
    ("trace-out", true),
    ("noc-out", true),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    fn parse_dse(v: &[&str]) -> Result<DseConfig, String> {
        let mut flags: Vec<(&str, bool)> = vec![("out", true), ("workers", true)];
        flags.extend_from_slice(DSE_FLAGS);
        let args = Args::parse(&s(v), &flags)?;
        DseConfig::from_cli(&args)
    }

    #[test]
    fn defaults_are_sane() {
        let d = DseConfig::default();
        assert!(d.beam_width >= 1 && d.depth_cap >= 1 && d.ladder_rungs >= 1);
        assert!(!d.topologies.is_empty());
        assert_eq!(d.strategy, SearchStrategy::Beam);
    }

    #[test]
    fn strategy_names_roundtrip() {
        for st in [SearchStrategy::Exhaustive, SearchStrategy::Beam] {
            assert_eq!(SearchStrategy::from_name(st.name()), Some(st));
        }
        assert_eq!(SearchStrategy::from_name("bogus"), None);
    }

    #[test]
    fn cli_flags_parse_into_config() {
        let d = parse_dse(&[
            "dse",
            "--strategy",
            "exhaustive",
            "--beam",
            "12",
            "--depth-cap",
            "6",
            "--budget",
            "500",
            "--topologies",
            "amp,mesh",
        ])
        .unwrap();
        assert_eq!(d.strategy, SearchStrategy::Exhaustive);
        assert_eq!(d.beam_width, 12);
        assert_eq!(d.depth_cap, 6);
        assert_eq!(d.budget, Some(500));
        assert_eq!(
            d.topologies,
            vec![TopologyKind::Amp, TopologyKind::Mesh]
        );
        assert!(!d.channel_load_objective);
        assert_eq!(d.objective_count(), 3);
    }

    #[test]
    fn channel_load_objective_flag_widens_the_front() {
        let d = parse_dse(&["dse", "--channel-load-objective"]).unwrap();
        assert!(d.channel_load_objective);
        assert_eq!(d.objective_count(), 4);
    }

    #[test]
    fn tuned_config_is_budgeted_and_single_topology() {
        let t = DseConfig::tuned(TopologyKind::Mesh);
        assert_eq!(t.topologies, vec![TopologyKind::Mesh]);
        assert_eq!(t.budget, Some(TUNED_DEFAULT_BUDGET));
        assert_eq!(t.strategy, SearchStrategy::Beam);
    }

    #[test]
    fn obs_flags_enable_the_handle() {
        assert!(!parse_dse(&["dse"]).unwrap().obs.is_enabled());
        assert!(parse_dse(&["dse", "--obs"]).unwrap().obs.is_enabled());
        assert!(parse_dse(&["dse", "--trace-out", "t.json"])
            .unwrap()
            .obs
            .is_enabled());
    }

    #[test]
    fn bad_strategy_and_topology_rejected() {
        assert!(parse_dse(&["dse", "--strategy", "dfs"]).is_err());
        assert!(parse_dse(&["dse", "--topologies", "ring"]).is_err());
        assert!(parse_dse(&["dse", "--topologies", ""]).is_err());
    }
}
