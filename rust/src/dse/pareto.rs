//! Pareto-frontier extraction over the three reported objectives:
//! latency (cycles), energy, and DRAM traffic — all minimized.

/// Anything with a fixed objective vector (smaller is better on every
/// axis).
pub trait ParetoPoint {
    fn objectives(&self) -> [f64; 3];
}

/// `a` dominates `b`: no worse everywhere, strictly better somewhere.
pub fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    let mut strictly = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Keep the non-dominated subset of `points` (exact duplicates collapse to
/// one), returned in ascending order of the first objective.
pub fn pareto_filter<T: ParetoPoint>(points: Vec<T>) -> Vec<T> {
    let mut points = points;
    points.sort_by(|a, b| {
        a.objectives()
            .partial_cmp(&b.objectives())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut kept: Vec<T> = Vec::new();
    'next: for p in points {
        let po = p.objectives();
        for k in &kept {
            let ko = k.objectives();
            if ko == po || dominates(&ko, &po) {
                continue 'next;
            }
        }
        kept.retain(|k| !dominates(&po, &k.objectives()));
        kept.push(p);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, PartialEq)]
    struct P([f64; 3]);

    impl ParetoPoint for P {
        fn objectives(&self) -> [f64; 3] {
            self.0
        }
    }

    #[test]
    fn dominance_rules() {
        assert!(dominates(&[1.0, 1.0, 1.0], &[2.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0])); // equal
        assert!(!dominates(&[1.0, 3.0, 1.0], &[2.0, 1.0, 1.0])); // trade-off
    }

    #[test]
    fn filter_keeps_tradeoffs_drops_dominated() {
        let pts = vec![
            P([3.0, 1.0, 2.0]),
            P([1.0, 3.0, 2.0]),
            P([2.0, 2.0, 2.0]),
            P([3.0, 3.0, 3.0]), // dominated by all three above
        ];
        let f = pareto_filter(pts);
        assert_eq!(f.len(), 3);
        // ascending by first objective
        assert!(f.windows(2).all(|w| w[0].0[0] <= w[1].0[0]));
        assert!(!f.contains(&P([3.0, 3.0, 3.0])));
    }

    #[test]
    fn duplicates_collapse() {
        let f = pareto_filter(vec![P([1.0, 1.0, 1.0]), P([1.0, 1.0, 1.0])]);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn single_and_empty() {
        assert!(pareto_filter(Vec::<P>::new()).is_empty());
        assert_eq!(pareto_filter(vec![P([5.0, 5.0, 5.0])]).len(), 1);
    }

    #[test]
    fn ties_on_some_axes_are_kept_as_tradeoffs() {
        // Equal on two axes, trading off on the third: neither dominates,
        // both must survive.
        let f = pareto_filter(vec![P([1.0, 5.0, 2.0]), P([1.0, 4.0, 3.0])]);
        assert_eq!(f.len(), 2);
        // Equal on two axes and strictly better on the third: dominated.
        let f = pareto_filter(vec![P([1.0, 5.0, 2.0]), P([1.0, 5.0, 3.0])]);
        assert_eq!(f, vec![P([1.0, 5.0, 2.0])]);
    }

    #[test]
    fn many_equal_points_collapse_to_one() {
        let f = pareto_filter(vec![P([2.0, 2.0, 2.0]); 7]);
        assert_eq!(f, vec![P([2.0, 2.0, 2.0])]);
    }

    #[test]
    fn degenerate_single_objective_front_keeps_only_the_minimum() {
        // All points identical on two axes — the frontier degenerates to
        // the single best point of the remaining objective, regardless of
        // which axis varies.
        for axis in 0..3 {
            let pts: Vec<P> = [5.0, 3.0, 9.0, 3.5]
                .iter()
                .map(|&v| {
                    let mut o = [1.0, 1.0, 1.0];
                    o[axis] = v;
                    P(o)
                })
                .collect();
            let f = pareto_filter(pts);
            assert_eq!(f.len(), 1, "axis {axis}");
            assert_eq!(f[0].0[axis], 3.0, "axis {axis}");
        }
    }

    #[test]
    fn dominance_is_irreflexive_and_antisymmetric_on_ties() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 2.0, 4.0];
        assert!(!dominates(&a, &a), "irreflexive");
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a), "antisymmetric");
        // Ties on every axis dominate in neither direction.
        let c = [1.0, 2.0, 3.0];
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
    }

    #[test]
    fn no_point_dominates_another_in_output() {
        let pts: Vec<P> = (0..50)
            .map(|i| {
                let x = (i * 7 % 13) as f64;
                let y = (i * 11 % 17) as f64;
                P([x, y, (x + y) % 5.0])
            })
            .collect();
        let f = pareto_filter(pts);
        for a in &f {
            for b in &f {
                assert!(
                    std::ptr::eq(a, b) || !dominates(&a.objectives(), &b.objectives()),
                    "{a:?} dominates {b:?}"
                );
            }
        }
    }
}
