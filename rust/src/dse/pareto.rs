//! Pareto-frontier extraction over the reported objectives: latency
//! (cycles), energy, DRAM traffic, and — behind
//! `DseConfig::channel_load_objective` — the Fig. 15 worst-case channel
//! load. All objectives are minimized.
//!
//! Points always *carry* the full four-dimensional objective vector; how
//! many leading axes participate in dominance is the caller's choice
//! (`dominates_first` / `pareto_filter_first`). The default three-axis
//! filter reproduces the original latency/energy/DRAM frontier exactly;
//! enabling the fourth axis surfaces congestion-free trade-off points that
//! a three-axis filter would collapse away.

/// Anything with a fixed objective vector (smaller is better on every
/// axis). Order: `[cycles, energy, DRAM words, worst channel load]`.
pub trait ParetoPoint {
    fn objectives(&self) -> [f64; 4];
}

/// `a` dominates `b` on all four objectives: no worse everywhere, strictly
/// better somewhere.
pub fn dominates(a: &[f64; 4], b: &[f64; 4]) -> bool {
    dominates_first(a, b, 4)
}

/// `a` dominates `b` on the first `k` objectives (`k` clamped to `1..=4`).
pub fn dominates_first(a: &[f64; 4], b: &[f64; 4], k: usize) -> bool {
    let k = k.clamp(1, 4);
    let mut strictly = false;
    for (x, y) in a.iter().zip(b.iter()).take(k) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Keep the subset of `points` non-dominated on all four objectives.
pub fn pareto_filter<T: ParetoPoint>(points: Vec<T>) -> Vec<T> {
    pareto_filter_first(points, 4)
}

/// Keep the subset of `points` non-dominated on the first `k` objectives
/// (exact duplicates on those axes collapse to one — the sort below makes
/// the survivor the one with the smallest trailing objectives, so the
/// choice is deterministic). Returned in ascending order of the first
/// objective.
pub fn pareto_filter_first<T: ParetoPoint>(points: Vec<T>, k: usize) -> Vec<T> {
    let k = k.clamp(1, 4);
    let mut points = points;
    points.sort_by(|a, b| {
        a.objectives()
            .partial_cmp(&b.objectives())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut kept: Vec<T> = Vec::new();
    'next: for p in points {
        let po = p.objectives();
        for q in &kept {
            let qo = q.objectives();
            if qo[..k] == po[..k] || dominates_first(&qo, &po, k) {
                continue 'next;
            }
        }
        kept.retain(|q| !dominates_first(&po, &q.objectives(), k));
        kept.push(p);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, PartialEq)]
    struct P([f64; 4]);

    impl ParetoPoint for P {
        fn objectives(&self) -> [f64; 4] {
            self.0
        }
    }

    /// Three-axis point with the load axis pinned to zero (the legacy
    /// frontier shape).
    fn p3(a: f64, b: f64, c: f64) -> P {
        P([a, b, c, 0.0])
    }

    #[test]
    fn dominance_rules() {
        assert!(dominates(&[1.0, 1.0, 1.0, 1.0], &[2.0, 1.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0, 1.0, 1.0], &[1.0, 1.0, 1.0, 1.0])); // equal
        assert!(!dominates(&[1.0, 3.0, 1.0, 1.0], &[2.0, 1.0, 1.0, 1.0])); // trade-off
        // The fourth axis participates in full dominance...
        assert!(dominates(&[1.0, 1.0, 1.0, 0.5], &[1.0, 1.0, 1.0, 1.0]));
        // ...but not in the three-axis restriction.
        assert!(!dominates_first(&[1.0, 1.0, 1.0, 0.5], &[1.0, 1.0, 1.0, 1.0], 3));
    }

    #[test]
    fn filter_keeps_tradeoffs_drops_dominated() {
        let pts = vec![
            p3(3.0, 1.0, 2.0),
            p3(1.0, 3.0, 2.0),
            p3(2.0, 2.0, 2.0),
            p3(3.0, 3.0, 3.0), // dominated by all three above
        ];
        let f = pareto_filter(pts);
        assert_eq!(f.len(), 3);
        // ascending by first objective
        assert!(f.windows(2).all(|w| w[0].0[0] <= w[1].0[0]));
        assert!(!f.contains(&p3(3.0, 3.0, 3.0)));
    }

    #[test]
    fn duplicates_collapse() {
        let f = pareto_filter(vec![p3(1.0, 1.0, 1.0), p3(1.0, 1.0, 1.0)]);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn single_and_empty() {
        assert!(pareto_filter(Vec::<P>::new()).is_empty());
        assert_eq!(pareto_filter(vec![p3(5.0, 5.0, 5.0)]).len(), 1);
    }

    #[test]
    fn ties_on_some_axes_are_kept_as_tradeoffs() {
        // Equal on all but one axis, trading off on that one: neither
        // dominates, both must survive.
        let f = pareto_filter(vec![p3(1.0, 5.0, 2.0), p3(1.0, 4.0, 3.0)]);
        assert_eq!(f.len(), 2);
        // Equal on all but one axis and strictly better there: dominated.
        let f = pareto_filter(vec![p3(1.0, 5.0, 2.0), p3(1.0, 5.0, 3.0)]);
        assert_eq!(f, vec![p3(1.0, 5.0, 2.0)]);
    }

    #[test]
    fn many_equal_points_collapse_to_one() {
        let f = pareto_filter(vec![p3(2.0, 2.0, 2.0); 7]);
        assert_eq!(f, vec![p3(2.0, 2.0, 2.0)]);
    }

    #[test]
    fn degenerate_single_objective_front_keeps_only_the_minimum() {
        // All points identical on the other axes — the frontier degenerates
        // to the single best point of the remaining objective, regardless of
        // which axis varies.
        for axis in 0..4 {
            let pts: Vec<P> = [5.0, 3.0, 9.0, 3.5]
                .iter()
                .map(|&v| {
                    let mut o = [1.0, 1.0, 1.0, 1.0];
                    o[axis] = v;
                    P(o)
                })
                .collect();
            let f = pareto_filter(pts);
            assert_eq!(f.len(), 1, "axis {axis}");
            assert_eq!(f[0].0[axis], 3.0, "axis {axis}");
        }
    }

    #[test]
    fn dominance_is_irreflexive_and_antisymmetric_on_ties() {
        let a = [1.0, 2.0, 3.0, 0.0];
        let b = [1.0, 2.0, 4.0, 0.0];
        assert!(!dominates(&a, &a), "irreflexive");
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a), "antisymmetric");
        // Ties on every axis dominate in neither direction.
        let c = [1.0, 2.0, 3.0, 0.0];
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
    }

    #[test]
    fn no_point_dominates_another_in_output() {
        let pts: Vec<P> = (0..50)
            .map(|i| {
                let x = (i * 7 % 13) as f64;
                let y = (i * 11 % 17) as f64;
                P([x, y, (x + y) % 5.0, (x * y) % 3.0])
            })
            .collect();
        for k in [3, 4] {
            let f = pareto_filter_first(pts.clone(), k);
            for a in &f {
                for b in &f {
                    assert!(
                        std::ptr::eq(a, b)
                            || !dominates_first(&a.objectives(), &b.objectives(), k),
                        "k={k}: {a:?} dominates {b:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn three_axis_filter_ignores_the_load_axis() {
        // Two points equal on the first three axes: the 3-axis filter keeps
        // exactly one (the lower-load one, deterministically); the 4-axis
        // filter also keeps one because the lower-load point dominates.
        let pts = vec![P([1.0, 1.0, 1.0, 9.0]), P([1.0, 1.0, 1.0, 2.0])];
        let f3 = pareto_filter_first(pts.clone(), 3);
        assert_eq!(f3, vec![P([1.0, 1.0, 1.0, 2.0])]);
        let f4 = pareto_filter_first(pts, 4);
        assert_eq!(f4, vec![P([1.0, 1.0, 1.0, 2.0])]);
        // A point worse on cycles but better on load survives only under
        // the four-axis filter.
        let pts = vec![P([1.0, 1.0, 1.0, 9.0]), P([2.0, 1.0, 1.0, 2.0])];
        assert_eq!(pareto_filter_first(pts.clone(), 3).len(), 1);
        assert_eq!(pareto_filter_first(pts, 4).len(), 2);
    }

    #[test]
    fn widening_the_objective_count_never_shrinks_the_front() {
        let pts: Vec<P> = (0..40)
            .map(|i| {
                let x = (i * 5 % 11) as f64;
                let y = (i * 3 % 7) as f64;
                P([x, y, ((x + 2.0 * y) as usize % 6) as f64, (i % 4) as f64])
            })
            .collect();
        let f3 = pareto_filter_first(pts.clone(), 3).len();
        let f4 = pareto_filter_first(pts, 4).len();
        assert!(f4 >= f3, "4-axis front {f4} smaller than 3-axis {f3}");
    }
}
