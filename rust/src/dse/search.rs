//! The search engines: exhaustive and beam multi-objective dynamic
//! programming over segment boundaries.
//!
//! Per-segment costs are additive across a plan (`cost::evaluate` sums
//! them), so the principle of optimality holds per objective *and* for the
//! Pareto set: a plan with a dominated prefix is itself dominated. The DP
//! therefore keeps, at every layer boundary, the Pareto set of prefix
//! labels (truncated to the beam width under `SearchStrategy::Beam`; the
//! minimum-latency prefix always survives truncation, so beam search is
//! exact for the latency objective whenever the depth cap covers the
//! optimum).

use crate::config::{ArchConfig, TopologyKind};
use crate::coordinator::run_queue;
use crate::cost::{evaluate, evaluate_segment, Mapper, MappingPlan};
use crate::energy::EnergyModel;
use crate::ir::ModelGraph;
use crate::mapper::PipeOrgan;
use crate::noc::Topology;
use crate::pipeline::Segment;
use crate::spatial::Organization;

use super::cache::{EvalCache, RunCounters};
use super::pareto::{pareto_filter_first, ParetoPoint};
use super::space;
use super::{DseConfig, SearchStrategy};

/// A full plan with its objective vector, as returned by the search.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    pub plan: MappingPlan,
    pub cycles: f64,
    pub energy: f64,
    pub dram_words: u64,
    /// Worst per-interval channel load over the plan's segments (the
    /// Fig. 15 metric). Always computed and reported; participates in
    /// dominance only under [`DseConfig::channel_load_objective`].
    pub worst_channel_load: f64,
    /// `"search"` for explored points, `"heuristic"` for the seeded
    /// heuristic-mapper plan, `"tuned"` for the budgeted plan-time search
    /// behind `mapper::TunedPipeOrgan`.
    pub source: &'static str,
}

impl ParetoPoint for PlanPoint {
    fn objectives(&self) -> [f64; 4] {
        [
            self.cycles,
            self.energy,
            self.dram_words as f64,
            self.worst_channel_load,
        ]
    }
}

/// Worst per-interval channel load over a whole-model cost (max across
/// segments — congestion does not add up over time-multiplexed segments).
fn max_channel_load(cost: &crate::cost::ModelCost) -> f64 {
    cost.per_segment
        .iter()
        .map(|s| s.worst_channel_load_per_interval)
        .fold(0.0, f64::max)
}

/// Outcome of one workload's exploration.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub workload: String,
    pub strategy: SearchStrategy,
    /// The heuristic mapper's plan — always evaluated (it is the gap
    /// baseline), and seeded into the frontier candidates whenever its
    /// topology is inside the searched set.
    pub heuristic: PlanPoint,
    /// The plan `mapper::TunedPipeOrgan` would ship at plan time: a
    /// budgeted beam search on the heuristic mapper's own topology (or the
    /// first searched topology when a `--topologies` restriction excludes
    /// it), seeded with that topology's heuristic plan. Always on a
    /// searched topology and always a frontier candidate, so
    /// [`DseResult::best`] is never costlier than it; never costlier than
    /// [`DseResult::heuristic`] whenever the heuristic's topology is
    /// searched (always true for the defaults).
    pub tuned: PlanPoint,
    /// Pareto frontier over (cycles, energy, DRAM words) — plus worst
    /// channel load under [`DseConfig::channel_load_objective`] — ascending
    /// by cycles. Non-empty, and restricted to the searched topologies
    /// (plus the heuristic and tuned seeds when their topology is
    /// searched).
    pub frontier: Vec<PlanPoint>,
    /// Cost-model evaluations this run added to the cache (cache misses).
    pub evaluations: u64,
    /// Lookups served from the cache during this run.
    pub cache_hits: u64,
}

impl DseResult {
    /// The latency-optimal explored point. Whenever the heuristic's
    /// topology is inside the searched set (true for the default
    /// configuration), the heuristic plan is one of the frontier
    /// candidates, so this is never costlier than
    /// [`DseResult::heuristic`]. Under a topology restriction that
    /// excludes it, [`DseResult::gap`] may honestly drop below 1.
    pub fn best(&self) -> &PlanPoint {
        self.frontier
            .iter()
            .min_by(|a, b| a.cycles.partial_cmp(&b.cycles).unwrap())
            .expect("frontier is never empty")
    }

    /// Heuristic-over-best latency ratio (≥ 1: how much the heuristic
    /// leaves on the table).
    pub fn gap(&self) -> f64 {
        self.heuristic.cycles / self.best().cycles
    }

    /// Heuristic-over-tuned latency ratio: the share of [`DseResult::gap`]
    /// the production tuned mapper actually recovers. ≥ 1 by the tuned
    /// mapper's never-lose fallback whenever the heuristic's topology is
    /// searched; under a `--topologies` restriction excluding it, tuned is
    /// confined to the restriction and the ratio may honestly drop below 1
    /// (mirroring [`DseResult::gap`]).
    pub fn tuned_gap(&self) -> f64 {
        self.heuristic.cycles / self.tuned.cycles
    }
}

/// A DP prefix label: objective sums plus the segment coordinates needed to
/// rebuild the plan.
#[derive(Debug, Clone)]
struct Label {
    cycles: f64,
    energy: f64,
    dram: u64,
    /// Max per-interval worst-channel-load over the prefix's segments.
    /// Max-composition is monotone, so prefix dominance still implies plan
    /// dominance and the DP's principle of optimality survives the fourth
    /// objective.
    load: f64,
    segs: Vec<(usize, usize, Organization, u64)>,
}

impl ParetoPoint for Label {
    fn objectives(&self) -> [f64; 4] {
        [self.cycles, self.energy, self.dram as f64, self.load]
    }
}

/// Has this *run* spent its evaluation budget? Metered on the run's own
/// [`RunCounters`], not the cache's global counters, so neither a warm
/// (possibly file-hydrated) cache nor other tasks missing into the same
/// shared cache concurrently can spend this run's budget.
fn budget_exhausted(dse: &DseConfig, run: &RunCounters) -> bool {
    dse.budget
        .map(|b| run.stats().misses >= b)
        .unwrap_or(false)
}

/// Prune a label set: Pareto filter over the first `k` objectives, then
/// truncate to `cap` keeping the lowest-latency labels
/// (`pareto_filter_first` returns ascending cycles).
fn prune(labels: &mut Vec<Label>, cap: usize, k: usize) {
    if labels.len() <= 1 {
        return;
    }
    let mut kept = pareto_filter_first(std::mem::take(labels), k);
    kept.truncate(cap.max(1));
    *labels = kept;
}

/// DP over one topology. Returns the Pareto labels of complete plans.
///
/// `seed` (the heuristic mapper's plan, when its topology matches) is
/// injected as prefix labels at each of its segment boundaries before the
/// DP runs: the search explores *around* the heuristic's cuts from the
/// start instead of rediscovering them, and the complete seeded label makes
/// the heuristic plan itself a member of the final label set.
fn search_topology(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    dse: &DseConfig,
    cache: &EvalCache,
    topology: TopologyKind,
    run: &RunCounters,
    seed: Option<&MappingPlan>,
) -> Vec<Label> {
    let n = graph.num_layers();
    if n == 0 {
        return Vec::new();
    }
    let ctx = super::cache::context_fingerprint(graph, cfg);
    let topo = Topology::cached(topology, cfg.pe_rows, cfg.pe_cols);
    let em = EnergyModel::default();
    let cap = match dse.strategy {
        SearchStrategy::Exhaustive => dse.max_labels.max(1),
        SearchStrategy::Beam => dse.beam_width.max(1),
    };
    let k = dse.objective_count();
    let mut frontiers: Vec<Vec<Label>> = (0..=n).map(|_| Vec::new()).collect();
    frontiers[0].push(Label {
        cycles: 0.0,
        energy: 0.0,
        dram: 0,
        load: 0.0,
        segs: Vec::new(),
    });
    if let Some(plan) = seed.filter(|p| p.topology == topology) {
        let mut acc = Label {
            cycles: 0.0,
            energy: 0.0,
            dram: 0,
            load: 0.0,
            segs: Vec::new(),
        };
        for ps in &plan.segments {
            let key = super::cache::heuristic_segment_key(ctx, ps, topology);
            let cost =
                cache.get_or_eval_in(key, || evaluate_segment(graph, ps, cfg, &topo, &em), run);
            acc.cycles += cost.cycles;
            acc.energy += cost.energy;
            acc.dram += cost.dram_words;
            acc.load = acc.load.max(cost.worst_channel_load_per_interval);
            acc.segs
                .push((ps.segment.start, ps.segment.depth, ps.organization, 1u64));
            frontiers[ps.segment.end()].push(acc.clone());
        }
    }
    for i in 0..n {
        prune(&mut frontiers[i], cap, k);
        if frontiers[i].is_empty() {
            continue;
        }
        for d in space::legal_depths(graph, cfg, i, dse.depth_cap) {
            let seg = Segment::new(i, d);
            let candidates = if budget_exhausted(dse, run) {
                vec![space::heuristic_candidate(graph, cfg, &seg)]
            } else {
                space::segment_candidates(graph, cfg, &seg, dse.ladder_rungs)
            };
            for cand in candidates {
                let key = (ctx, i, d, cand.organization, cand.gran_scale, topology);
                // `timed` is a no-op branch when obs is off; when on, every
                // candidate evaluation lands in the `time.dse.eval_candidate`
                // histogram (hits and misses alike, so the distribution
                // shows what the cache saves).
                let cost = dse.obs.timed("dse.eval_candidate", || {
                    cache.get_or_eval_in(
                        key,
                        || evaluate_segment(graph, &cand.planned, cfg, &topo, &em),
                        run,
                    )
                });
                let fresh: Vec<Label> = frontiers[i]
                    .iter()
                    .map(|lab| {
                        let mut segs = lab.segs.clone();
                        segs.push((i, d, cand.organization, cand.gran_scale));
                        Label {
                            cycles: lab.cycles + cost.cycles,
                            energy: lab.energy + cost.energy,
                            dram: lab.dram + cost.dram_words,
                            load: lab.load.max(cost.worst_channel_load_per_interval),
                            segs,
                        }
                    })
                    .collect();
                let dst = &mut frontiers[i + d];
                dst.extend(fresh);
                // Keep intermediate sets bounded so exhaustive pruning
                // stays O(labels²) on small sets.
                if dst.len() > cap.saturating_mul(8).max(64) {
                    prune(dst, cap, k);
                }
            }
        }
    }
    let mut last = std::mem::take(&mut frontiers[n]);
    prune(&mut last, cap, k);
    last
}

fn rebuild(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    dse: &DseConfig,
    topology: TopologyKind,
    label: &Label,
) -> PlanPoint {
    let segments = label
        .segs
        .iter()
        .map(|&(start, depth, org, scale)| {
            space::build_planned(graph, cfg, &Segment::new(start, depth), org, scale)
        })
        .collect();
    PlanPoint {
        plan: MappingPlan {
            mapper_name: format!("dse_{}", dse.strategy.name()),
            topology,
            segments,
        },
        cycles: label.cycles,
        energy: label.energy,
        dram_words: label.dram,
        worst_channel_load: label.load,
        source: "search",
    }
}

/// Explore one workload's design space.
///
/// The cache is caller-owned so repeated sweeps (and the warm half of
/// `benches/dse_search.rs`) share evaluations; keys are scoped by a
/// workload/config fingerprint, so one cache can safely serve many
/// workloads and architecture configs. `workers > 1` searches the
/// configured topologies in parallel (the cache is shared and sharded),
/// except when an evaluation budget is set — budgeted runs stay sequential
/// so the budget cutoff is deterministic.
pub fn explore(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    dse: &DseConfig,
    cache: &EvalCache,
    workers: usize,
) -> DseResult {
    // All of this run's lookups are metered here, so the reported
    // evaluations/hit counts (and the budget) stay exact even when other
    // tasks share the cache concurrently.
    let run = RunCounters::new();
    let heur_plan = PipeOrgan::default().plan(graph, cfg);
    let heur_cost = evaluate(graph, &heur_plan, cfg);
    let heuristic = PlanPoint {
        plan: heur_plan,
        cycles: heur_cost.cycles,
        energy: heur_cost.energy,
        dram_words: heur_cost.dram_words,
        worst_channel_load: max_channel_load(&heur_cost),
        source: "heuristic",
    };

    let topologies: Vec<TopologyKind> = if dse.topologies.is_empty() {
        vec![cfg.topology]
    } else {
        dse.topologies.clone()
    };
    let heuristic_in_space = topologies.contains(&heuristic.plan.topology);
    // The tuned mapper searches the heuristic's own topology when it is
    // inside the searched set; under a `--topologies` restriction that
    // excludes it, tuned searches the first *searched* topology instead so
    // the reported tuned plan never violates the restriction.
    let tuned_base = if heuristic_in_space {
        PipeOrgan::default()
    } else {
        PipeOrgan::on(topologies[0])
    };
    let parallel = workers > 1 && topologies.len() > 1 && dse.budget.is_none();
    let per_topology: Vec<(TopologyKind, Vec<Label>)> = if parallel {
        run_queue(topologies, workers, |t| {
            (t, search_topology(graph, cfg, dse, cache, t, &run, None))
        })
    } else {
        topologies
            .into_iter()
            .map(|t| (t, search_topology(graph, cfg, dse, cache, t, &run, None)))
            .collect()
    };

    // The production tuned-mapper plan, for the heuristic-vs-tuned-vs-
    // oracle gap report. It shares this run's cache, so when its topology
    // was just searched this costs (almost) no extra evaluations; its
    // budget is its own plan-time window either way.
    let mut tuned_cfg = dse.clone();
    if tuned_cfg.budget.is_none() {
        tuned_cfg.budget = Some(super::TUNED_DEFAULT_BUDGET);
    }
    let tuned_run = RunCounters::new();
    let tuned = tuned_plan(graph, cfg, &tuned_base, &tuned_cfg, cache, &tuned_run);

    // The tuned plan always lives on a searched topology (see above), so
    // it is always a frontier candidate — the reported oracle can never
    // lose to it. The heuristic seed joins only when its topology is
    // searched, so a `--topologies` restriction is never violated.
    let mut points = vec![tuned.clone()];
    if heuristic_in_space {
        points.push(heuristic.clone());
    }
    for (topology, labels) in per_topology {
        for label in labels {
            points.push(rebuild(graph, cfg, dse, topology, &label));
        }
    }
    let frontier = pareto_filter_first(points, dse.objective_count());
    let run_stats = run.stats();
    let tuned_stats = tuned_run.stats();
    dse.obs
        .count("dse.cache.hits", run_stats.hits + tuned_stats.hits);
    dse.obs
        .count("dse.cache.misses", run_stats.misses + tuned_stats.misses);
    DseResult {
        workload: graph.name.clone(),
        strategy: dse.strategy,
        heuristic,
        tuned,
        frontier,
        evaluations: run_stats.misses + tuned_stats.misses,
        cache_hits: run_stats.hits + tuned_stats.hits,
    }
}

/// The plan-time budgeted search behind `mapper::TunedPipeOrgan` (and the
/// `tuned` column of `report::dse_gap`): beam-search `base`'s own topology
/// under `dse`'s knobs and evaluation budget, seeded with `base`'s
/// heuristic plan, and return the latency-best result. The heuristic plan
/// is the fallback whenever the search cannot strictly improve on it, so
/// **tuned never loses to the heuristic** — the only question is how much
/// of the oracle gap the budget recovers.
///
/// The cache is caller-owned and usually persistent
/// (`EvalCache::load_file`), which is what makes a plan-time search
/// affordable: across CLI sweeps and CI runs, repeated shapes hit the
/// memoized segment costs instead of the cost model. `run` meters this
/// search's evaluations (pass a fresh [`RunCounters`] per plan call so the
/// budget is an exact per-plan window, even when many plans share one
/// cache concurrently).
///
/// # Examples
///
/// ```
/// use pipeorgan::config::ArchConfig;
/// use pipeorgan::dse::{tuned_plan, DseConfig, EvalCache, RunCounters};
/// use pipeorgan::mapper::PipeOrgan;
/// use pipeorgan::workloads::synthetic;
///
/// let cfg = ArchConfig { pe_rows: 8, pe_cols: 8, ..ArchConfig::default() };
/// let graph = synthetic::aw_chain(2.0, 3);
/// let base = PipeOrgan { topology: cfg.topology, depth_cap: Some(8) };
/// let mut dse = DseConfig::tuned(cfg.topology);
/// dse.budget = Some(64);
/// let cache = EvalCache::new();
///
/// let point = tuned_plan(&graph, &cfg, &base, &dse, &cache, &RunCounters::new());
/// assert!(point.cycles > 0.0 && !point.plan.segments.is_empty());
///
/// // Never worse than the heuristic it was seeded with: a warm re-plan
/// // returns the same point without new cost-model evaluations.
/// let warm = tuned_plan(&graph, &cfg, &base, &dse, &cache, &RunCounters::new());
/// assert_eq!(warm.cycles, point.cycles);
/// ```
pub fn tuned_plan(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    base: &PipeOrgan,
    dse: &DseConfig,
    cache: &EvalCache,
    run: &RunCounters,
) -> PlanPoint {
    let heur_plan = base.plan(graph, cfg);
    let heur_cost = evaluate(graph, &heur_plan, cfg);
    let labels = search_topology(graph, cfg, dse, cache, base.topology, run, Some(&heur_plan));
    let best = labels
        .into_iter()
        .min_by(|a, b| a.cycles.partial_cmp(&b.cycles).unwrap());
    if let Some(label) = best {
        if label.cycles < heur_cost.cycles {
            let mut point = rebuild(graph, cfg, dse, base.topology, &label);
            point.plan.mapper_name = crate::mapper::TUNED_MAPPER_NAME.into();
            point.source = "tuned";
            return point;
        }
    }
    let mut plan = heur_plan;
    plan.mapper_name = crate::mapper::TUNED_MAPPER_NAME.into();
    PlanPoint {
        plan,
        cycles: heur_cost.cycles,
        energy: heur_cost.energy,
        dram_words: heur_cost.dram_words,
        worst_channel_load: max_channel_load(&heur_cost),
        source: "tuned",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::dominates;
    use crate::workloads::synthetic;

    fn small_cfg() -> ArchConfig {
        ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        }
    }

    fn tiny_dse(strategy: SearchStrategy) -> DseConfig {
        DseConfig {
            strategy,
            beam_width: 6,
            depth_cap: 4,
            ladder_rungs: 2,
            topologies: vec![TopologyKind::Amp, TopologyKind::Mesh],
            budget: None,
            max_labels: 64,
            channel_load_objective: false,
            obs: Default::default(),
        }
    }

    #[test]
    fn exhaustive_never_loses_to_heuristic_on_synthetic_chain() {
        let g = synthetic::aw_chain(3.0, 6);
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let r = explore(&g, &cfg, &tiny_dse(SearchStrategy::Exhaustive), &cache, 1);
        assert!(
            r.best().cycles <= r.heuristic.cycles * 1.0001,
            "best {} vs heuristic {}",
            r.best().cycles,
            r.heuristic.cycles
        );
        assert!(r.gap() >= 0.9999);
        assert!(r.evaluations > 0);
    }

    #[test]
    fn frontier_plans_validate_and_match_their_objectives() {
        let g = synthetic::pointwise_conv_segment(4);
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let r = explore(&g, &cfg, &tiny_dse(SearchStrategy::Exhaustive), &cache, 1);
        assert!(!r.frontier.is_empty());
        for p in &r.frontier {
            p.plan
                .validate(&g, &cfg)
                .unwrap_or_else(|e| panic!("{}: {e}", p.plan.mapper_name));
            if p.source == "search" {
                let re = evaluate(&g, &p.plan, &cfg);
                assert!(
                    (re.cycles - p.cycles).abs() <= 1e-6 * p.cycles.max(1.0),
                    "label {} vs re-evaluated {}",
                    p.cycles,
                    re.cycles
                );
                assert_eq!(re.dram_words, p.dram_words);
            }
        }
    }

    #[test]
    fn frontier_is_mutually_non_dominating() {
        let g = synthetic::aw_chain(1.0, 8);
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let r = explore(&g, &cfg, &tiny_dse(SearchStrategy::Beam), &cache, 1);
        for (i, a) in r.frontier.iter().enumerate() {
            for (j, b) in r.frontier.iter().enumerate() {
                assert!(
                    i == j
                        || !crate::dse::dominates_first(&a.objectives(), &b.objectives(), 3),
                    "frontier point {i} dominates {j}"
                );
            }
        }
    }

    #[test]
    fn four_objective_frontier_dominates_correctly_and_never_shrinks() {
        let g = synthetic::pointwise_conv_segment(4);
        let cfg = small_cfg();
        let three = explore(
            &g,
            &cfg,
            &tiny_dse(SearchStrategy::Exhaustive),
            &EvalCache::new(),
            1,
        );
        let mut dse4 = tiny_dse(SearchStrategy::Exhaustive);
        dse4.channel_load_objective = true;
        let four = explore(&g, &cfg, &dse4, &EvalCache::new(), 1);
        // Every reported point carries a finite, non-negative load.
        for p in three.frontier.iter().chain(four.frontier.iter()) {
            assert!(p.worst_channel_load.is_finite() && p.worst_channel_load >= 0.0);
        }
        // The four-axis front is mutually non-dominating on all four axes
        // and at least as large as the three-axis one (a point dominated on
        // three axes can survive by trading congestion).
        for (i, a) in four.frontier.iter().enumerate() {
            for (j, b) in four.frontier.iter().enumerate() {
                assert!(
                    i == j || !dominates(&a.objectives(), &b.objectives()),
                    "4-obj frontier point {i} dominates {j}"
                );
            }
        }
        assert!(
            four.frontier.len() >= three.frontier.len(),
            "4-obj front {} smaller than 3-obj front {}",
            four.frontier.len(),
            three.frontier.len()
        );
        // The latency oracle is unchanged: the extra axis only widens the
        // reported front, it never hides the latency-best plan.
        assert!((four.best().cycles - three.best().cycles).abs() <= 1e-9 * three.best().cycles);
    }

    #[test]
    fn beam_matches_exhaustive_on_latency_for_small_chain() {
        // Beam keeps the min-latency prefix at every boundary, so its best
        // latency equals the exhaustive optimum.
        let g = synthetic::aw_chain(2.0, 5);
        let cfg = small_cfg();
        let ex = explore(
            &g,
            &cfg,
            &tiny_dse(SearchStrategy::Exhaustive),
            &EvalCache::new(),
            1,
        );
        let beam = explore(
            &g,
            &cfg,
            &tiny_dse(SearchStrategy::Beam),
            &EvalCache::new(),
            1,
        );
        let rel = (ex.best().cycles - beam.best().cycles).abs() / ex.best().cycles;
        assert!(
            rel < 1e-9,
            "beam {} vs exhaustive {}",
            beam.best().cycles,
            ex.best().cycles
        );
    }

    #[test]
    fn warm_cache_run_is_all_hits_and_identical() {
        let g = synthetic::pointwise_conv_segment(3);
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let dse = tiny_dse(SearchStrategy::Beam);
        let cold = explore(&g, &cfg, &dse, &cache, 1);
        assert!(cold.evaluations > 0);
        let warm = explore(&g, &cfg, &dse, &cache, 1);
        assert_eq!(warm.evaluations, 0, "warm run must be fully memoized");
        assert!(warm.cache_hits > 0);
        assert_eq!(warm.best().cycles, cold.best().cycles);
        assert_eq!(warm.frontier.len(), cold.frontier.len());
    }

    #[test]
    fn budget_caps_evaluations() {
        let g = synthetic::aw_chain(1.5, 8);
        let cfg = small_cfg();
        let unbounded = explore(
            &g,
            &cfg,
            &tiny_dse(SearchStrategy::Exhaustive),
            &EvalCache::new(),
            1,
        );
        let mut capped_cfg = tiny_dse(SearchStrategy::Exhaustive);
        capped_cfg.budget = Some(10);
        let capped = explore(&g, &cfg, &capped_cfg, &EvalCache::new(), 1);
        assert!(
            capped.evaluations < unbounded.evaluations,
            "budget {} vs unbounded {}",
            capped.evaluations,
            unbounded.evaluations
        );
        // Budgeted search still completes with a full, valid frontier.
        assert!(!capped.frontier.is_empty());
        capped.best().plan.validate(&g, &cfg).unwrap();
        assert!(capped.best().cycles <= capped.heuristic.cycles * 1.0001);
    }

    #[test]
    fn tuned_point_sits_between_heuristic_and_oracle() {
        let g = synthetic::aw_chain(2.0, 6);
        let cfg = small_cfg();
        let r = explore(
            &g,
            &cfg,
            &tiny_dse(SearchStrategy::Beam),
            &EvalCache::new(),
            1,
        );
        assert_eq!(r.tuned.source, "tuned");
        assert_eq!(r.tuned.plan.mapper_name, crate::mapper::TUNED_MAPPER_NAME);
        r.tuned.plan.validate(&g, &cfg).unwrap();
        assert!(
            r.tuned.cycles <= r.heuristic.cycles * 1.0001,
            "tuned {} must never lose to heuristic {}",
            r.tuned.cycles,
            r.heuristic.cycles
        );
        assert!(
            r.best().cycles <= r.tuned.cycles * 1.0001,
            "oracle {} must never lose to tuned {}",
            r.best().cycles,
            r.tuned.cycles
        );
        assert!(r.tuned_gap() >= 0.9999);
    }

    #[test]
    fn tuned_plan_under_zero_budget_is_valid_and_never_loses() {
        let g = synthetic::pointwise_conv_segment(3);
        let cfg = small_cfg();
        let mut dse = tiny_dse(SearchStrategy::Beam);
        dse.budget = Some(0);
        let cache = EvalCache::new();
        let point = tuned_plan(&g, &cfg, &PipeOrgan::default(), &dse, &cache, &RunCounters::new());
        point.plan.validate(&g, &cfg).unwrap();
        assert_eq!(point.plan.mapper_name, crate::mapper::TUNED_MAPPER_NAME);
        let heur = evaluate(&g, &PipeOrgan::default().plan(&g, &cfg), &cfg);
        assert!(point.cycles <= heur.cycles * 1.0001);
    }

    #[test]
    fn budget_is_relative_to_run_not_cache_lifetime() {
        let g = synthetic::pointwise_conv_segment(3);
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let mut dse = tiny_dse(SearchStrategy::Beam);
        dse.budget = Some(100_000);
        let cold = explore(&g, &cfg, &dse, &cache, 1);
        assert!(cold.evaluations > 0);
        // A second budgeted run over the warm cache must not mistake past
        // misses for spent budget: it completes fully memoized with the
        // same optimum instead of degrading to heuristic-only enumeration.
        let warm = explore(&g, &cfg, &dse, &cache, 1);
        assert_eq!(warm.evaluations, 0);
        assert_eq!(warm.best().cycles, cold.best().cycles);
    }

    #[test]
    fn topology_restriction_keeps_frontier_inside_it() {
        // The heuristic defaults to AMP; restricting the search to Mesh
        // must keep AMP out of the reported frontier and oracle.
        let g = synthetic::pointwise_conv_segment(3);
        let cfg = small_cfg();
        let mut dse = tiny_dse(SearchStrategy::Beam);
        dse.topologies = vec![TopologyKind::Mesh];
        let r = explore(&g, &cfg, &dse, &EvalCache::new(), 1);
        assert_eq!(r.heuristic.plan.topology, TopologyKind::Amp);
        assert!(!r.frontier.is_empty());
        for p in &r.frontier {
            assert_eq!(
                p.plan.topology,
                TopologyKind::Mesh,
                "excluded topology leaked into the frontier"
            );
        }
        // The tuned plan is confined to the restriction too, and the
        // reported oracle never loses to it.
        assert_eq!(r.tuned.plan.topology, TopologyKind::Mesh);
        assert!(r.best().cycles <= r.tuned.cycles * 1.0001);
    }

    #[test]
    fn parallel_topology_search_matches_sequential() {
        let g = synthetic::pointwise_conv_segment(3);
        let cfg = small_cfg();
        let dse = tiny_dse(SearchStrategy::Beam);
        let seq = explore(&g, &cfg, &dse, &EvalCache::new(), 1);
        let par = explore(&g, &cfg, &dse, &EvalCache::new(), 4);
        assert_eq!(seq.best().cycles, par.best().cycles);
        assert_eq!(seq.frontier.len(), par.frontier.len());
    }
}
