//! Candidate enumeration: the concrete points of the pipelining design
//! space the search walks.
//!
//! A candidate segment is identified by four coordinates — `(start, depth,
//! organization, granularity scale)` — which together with the topology
//! form the memoization key (`dse::cache`). Candidates are *built* here by
//! reusing the heuristic mapper's own planning path
//! (`mapper::plan_segment_scaled`), so the heuristic's exact segment is
//! always one of the enumerated points (organization from the Sec. IV-B
//! chooser, granularity scale 1).

use crate::config::ArchConfig;
use crate::ir::ModelGraph;
use crate::mapper::{organization_candidates, plan_segment_scaled};
use crate::pipeline::Segment;
use crate::spatial::Organization;

use crate::cost::PlannedSegment;

/// One enumerated point: a fully planned segment plus its cache
/// coordinates.
#[derive(Debug, Clone)]
pub struct CandidateSegment {
    pub segment: Segment,
    pub organization: Organization,
    /// Granularity-ladder scale: the finest Algorithm-1 granularity times
    /// this factor (always a power of 4; 1 = the heuristic's granularity).
    pub gran_scale: u64,
    pub planned: PlannedSegment,
}

/// Segment depths legal at `start`: bounded by the depth cap, the
/// architecture's `√numPEs` pipeline-depth cap, the end of the model, and
/// the rule that complex layers (ROIAlign/RPN) never pipeline with
/// neighbors (Sec. IV-A).
pub fn legal_depths(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    start: usize,
    depth_cap: usize,
) -> Vec<usize> {
    let n = graph.num_layers();
    debug_assert!(start < n);
    if graph.layer(start).is_complex() {
        return vec![1];
    }
    let max_d = depth_cap
        .max(1)
        .min(cfg.max_pipeline_depth().max(1))
        .min(n - start);
    let mut out = Vec::with_capacity(max_d);
    for d in 1..=max_d {
        if d > 1 && graph.layer(start + d - 1).is_complex() {
            break;
        }
        out.push(d);
    }
    out
}

/// The granularity ladder for one segment: scale 1 (finest, the heuristic's
/// choice) then powers of 4, stopping early once every handoff has
/// saturated (scaling further changes nothing) or after `rungs` rungs.
fn ladder(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    seg: &Segment,
    rungs: usize,
) -> Vec<(u64, PlannedSegment)> {
    let mut out: Vec<(u64, PlannedSegment)> = Vec::new();
    let mut scale = 1u64;
    for _ in 0..rungs.max(1) {
        let planned = plan_segment_scaled(graph, cfg, seg, scale);
        if let Some((_, prev)) = out.last() {
            if prev.handoffs == planned.handoffs {
                break; // saturated: coarser rungs are identical
            }
        }
        out.push((scale, planned));
        if seg.depth == 1 {
            break; // no handoffs to scale
        }
        scale = scale.saturating_mul(4);
    }
    out
}

/// All candidates for one segment: granularity ladder × oracle organization
/// candidates. The heuristic's own (organization, scale 1) point is always
/// included even if the chooser picked an organization outside the oracle
/// candidate list (defensive — it never does today).
pub fn segment_candidates(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    seg: &Segment,
    rungs: usize,
) -> Vec<CandidateSegment> {
    let mut out = Vec::new();
    for (scale, base) in ladder(graph, cfg, seg, rungs) {
        let orgs = organization_candidates(seg.depth);
        if !orgs.contains(&base.organization) {
            out.push(CandidateSegment {
                segment: seg.clone(),
                organization: base.organization,
                gran_scale: scale,
                planned: base.clone(),
            });
        }
        for org in orgs {
            let mut planned = base.clone();
            planned.organization = org;
            out.push(CandidateSegment {
                segment: seg.clone(),
                organization: org,
                gran_scale: scale,
                planned,
            });
        }
    }
    out
}

/// The single heuristic point for a segment — the fallback once the search
/// budget is exhausted (cheap, usually already cached, always valid).
pub fn heuristic_candidate(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    seg: &Segment,
) -> CandidateSegment {
    let planned = plan_segment_scaled(graph, cfg, seg, 1);
    CandidateSegment {
        segment: seg.clone(),
        organization: planned.organization,
        gran_scale: 1,
        planned,
    }
}

/// Rebuild the planned segment for a cache coordinate (used when turning a
/// winning search label back into a full `MappingPlan`).
pub fn build_planned(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    seg: &Segment,
    organization: Organization,
    gran_scale: u64,
) -> PlannedSegment {
    let mut planned = plan_segment_scaled(graph, cfg, seg, gran_scale);
    planned.organization = organization;
    planned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Layer, Op};
    use crate::workloads::synthetic;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn depth_one_has_single_sequential_candidate() {
        let g = synthetic::equal_conv_segment(4);
        let cands = segment_candidates(&g, &cfg(), &Segment::new(0, 1), 4);
        assert_eq!(cands.len(), 1);
        assert_eq!(cands[0].organization, Organization::Sequential);
        assert_eq!(cands[0].gran_scale, 1);
        assert!(cands[0].planned.handoffs.is_empty());
    }

    #[test]
    fn ladder_scales_are_powers_of_four_and_saturate() {
        let g = synthetic::pointwise_conv_segment(2);
        let cands = segment_candidates(&g, &cfg(), &Segment::new(0, 2), 8);
        let mut scales: Vec<u64> = cands.iter().map(|c| c.gran_scale).collect();
        scales.sort_unstable();
        scales.dedup();
        for w in scales.windows(2) {
            assert_eq!(w[1], w[0] * 4, "{scales:?}");
        }
        // Saturation: coarsest rung's handoffs stop growing before u64 blows.
        let total = g.layer(0).output_act_words();
        for c in &cands {
            for h in &c.planned.handoffs {
                assert!(h.words_per_interval <= total);
            }
        }
    }

    #[test]
    fn scale_one_matches_heuristic_segment() {
        let g = synthetic::pointwise_conv_segment(3);
        let seg = Segment::new(0, 3);
        let heur = heuristic_candidate(&g, &cfg(), &seg);
        let cands = segment_candidates(&g, &cfg(), &seg, 3);
        assert!(
            cands.iter().any(|c| c.gran_scale == 1
                && c.organization == heur.organization
                && c.planned == heur.planned),
            "heuristic point must be enumerated"
        );
    }

    #[test]
    fn legal_depths_stop_at_complex_layers() {
        let mut g = synthetic::aw_chain(2.0, 3);
        g.push(Layer::new("roi", Op::roi_align(32, 7, 64)));
        g.push(Layer::new(
            "after",
            Op::conv2d(1, 64, 64, 16, 16, 3, 3, 1, 1),
        ));
        let c = cfg();
        // From layer 0 we can grow up to the ROI layer but not across it.
        assert_eq!(legal_depths(&g, &c, 0, 8), vec![1, 2, 3]);
        // The complex layer itself only runs alone.
        assert_eq!(legal_depths(&g, &c, 3, 8), vec![1]);
        // The tail layer is bounded by the model end.
        assert_eq!(legal_depths(&g, &c, 4, 8), vec![1]);
    }

    #[test]
    fn legal_depths_respect_caps() {
        let g = synthetic::aw_chain(3.0, 12);
        let c = cfg();
        let d = legal_depths(&g, &c, 0, 5);
        assert_eq!(d, vec![1, 2, 3, 4, 5]);
        let deep = legal_depths(&g, &c, 0, 1_000);
        assert!(*deep.last().unwrap() <= c.max_pipeline_depth().min(12));
    }

    #[test]
    fn rebuilt_planned_matches_candidate() {
        let g = synthetic::pointwise_conv_segment(2);
        let c = cfg();
        let seg = Segment::new(0, 2);
        for cand in segment_candidates(&g, &c, &seg, 2) {
            let rebuilt = build_planned(&g, &c, &seg, cand.organization, cand.gran_scale);
            assert_eq!(rebuilt, cand.planned);
        }
    }
}
