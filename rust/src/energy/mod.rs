//! Relative energy model (Eyeriss-style cost ratios, per word / per MAC).
//!
//! The paper reports only *normalized* energy and performance, so what
//! matters is the ratio structure: register-file accesses are cheap, NoC
//! hops cost a router traversal plus wire length, SRAM is several times a
//! hop, DRAM is two orders of magnitude above everything. AMP's long links
//! pay one router + `L` wire units instead of `L` routers + `L` wire units,
//! which is exactly the hop-energy argument of Sec. IV-D.

use crate::sim::LoadAnalysis;

/// Energy cost constants in normalized units (1.0 = one MAC).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// One multiply-accumulate.
    pub mac: f64,
    /// One register-file word access.
    pub rf_word: f64,
    /// One router traversal (per word per hop).
    pub router_word: f64,
    /// Wire energy per word per PE-pitch of distance.
    pub wire_word_per_pe: f64,
    /// One global-buffer (SRAM) word access.
    pub sram_word: f64,
    /// One DRAM word access.
    pub dram_word: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Eyeriss (ISCA'16) normalized hierarchy: RF 1, NoC ~2, SRAM ~6,
        // DRAM ~200 (per 16-bit word, relative to one MAC).
        Self {
            mac: 1.0,
            rf_word: 1.0,
            router_word: 1.5,
            wire_word_per_pe: 0.5,
            sram_word: 6.0,
            dram_word: 200.0,
        }
    }
}

impl EnergyModel {
    /// Energy of one word traversing one link of physical length `len`.
    pub fn link_energy(&self, len: u32) -> f64 {
        self.router_word + self.wire_word_per_pe * len as f64
    }

    /// NoC energy of one interval's traffic, from a load analysis:
    /// `Σ words×hops × router + Σ words×wire × wire_cost`.
    pub fn noc_interval_energy(&self, analysis: &LoadAnalysis) -> f64 {
        analysis.total_word_hops * self.router_word
            + analysis.total_word_wire * self.wire_word_per_pe
    }

    /// Compute energy for `macs` multiply-accumulates (plus one RF access
    /// per operand pair, folded into the constant).
    pub fn compute_energy(&self, macs: u64) -> f64 {
        macs as f64 * (self.mac + self.rf_word)
    }

    pub fn sram_energy(&self, words: u64) -> f64 {
        words as f64 * self.sram_word
    }

    pub fn dram_energy(&self, words: u64) -> f64 {
        words as f64 * self.dram_word
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::noc::Topology;
    use crate::sim::analyze;
    use crate::traffic::{derive_flows, scenarios};

    #[test]
    fn hierarchy_ordering() {
        let e = EnergyModel::default();
        assert!(e.rf_word < e.sram_word);
        assert!(e.sram_word < e.dram_word);
        assert!(e.link_energy(1) < e.sram_word);
    }

    #[test]
    fn express_link_cheaper_than_equivalent_hops() {
        // One length-4 express hop vs four single hops (Sec. IV-D).
        let e = EnergyModel::default();
        assert!(e.link_energy(4) < 4.0 * e.link_energy(1));
    }

    #[test]
    fn amp_saves_noc_energy_on_blocked_traffic() {
        let e = EnergyModel::default();
        let s = scenarios::fig8_depth2_blocked(32, 32);
        let mesh = Topology::new(TopologyKind::Mesh, 32, 32);
        let amp = Topology::new(TopologyKind::Amp, 32, 32);
        let em = e.noc_interval_energy(&analyze(&mesh, &derive_flows(&mesh, &s.placement, &s.handoffs)));
        let ea = e.noc_interval_energy(&analyze(&amp, &derive_flows(&amp, &s.placement, &s.handoffs)));
        assert!(ea < em, "amp {ea} mesh {em}");
    }

    #[test]
    fn dram_dominates() {
        let e = EnergyModel::default();
        // moving 1 word from DRAM ≈ 100 hops of NoC
        assert!(e.dram_energy(1) > 50.0 * e.link_energy(1));
    }
}
