//! The model DAG. Layers are stored in construction order, which the
//! builders keep topological (the "chain order" of the network); any edge
//! that jumps more than one position in that order is a *skip connection*
//! (Sec. II-D, Fig. 6).

use super::Layer;
use std::collections::VecDeque;

/// Index of a layer within its [`ModelGraph`].
pub type LayerId = usize;

/// A directed producer→consumer dependency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Edge {
    pub src: LayerId,
    pub dst: LayerId,
}

/// A DNN model as a DAG of layers.
#[derive(Debug, Clone, Default)]
pub struct ModelGraph {
    pub name: String,
    layers: Vec<Layer>,
    edges: Vec<Edge>,
}

impl ModelGraph {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            layers: Vec::new(),
            edges: Vec::new(),
        }
    }

    /// Append a layer with no incoming edge (a model input stem).
    pub fn add_root(&mut self, layer: Layer) -> LayerId {
        self.layers.push(layer);
        self.layers.len() - 1
    }

    /// Append a layer consuming `preds` (first listed predecessor is the
    /// "chain" input; extras are typically skip inputs).
    pub fn add_layer(&mut self, layer: Layer, preds: &[LayerId]) -> LayerId {
        let id = self.layers.len();
        for &p in preds {
            assert!(p < id, "predecessor {p} must precede layer {id}");
            self.edges.push(Edge { src: p, dst: id });
        }
        self.layers.push(layer);
        id
    }

    /// Convenience: append consuming the previous layer.
    pub fn push(&mut self, layer: Layer) -> LayerId {
        if self.layers.is_empty() {
            self.add_root(layer)
        } else {
            let prev = self.layers.len() - 1;
            self.add_layer(layer, &[prev])
        }
    }

    /// Add an extra (skip) edge between existing layers.
    pub fn add_edge(&mut self, src: LayerId, dst: LayerId) {
        assert!(src < dst, "edges must go forward in layer order");
        assert!(dst < self.layers.len(), "dst out of range");
        let e = Edge { src, dst };
        if !self.edges.contains(&e) {
            self.edges.push(e);
        }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    pub fn predecessors(&self, id: LayerId) -> Vec<LayerId> {
        self.edges
            .iter()
            .filter(|e| e.dst == id)
            .map(|e| e.src)
            .collect()
    }

    pub fn successors(&self, id: LayerId) -> Vec<LayerId> {
        self.edges
            .iter()
            .filter(|e| e.src == id)
            .map(|e| e.dst)
            .collect()
    }

    /// Edges whose endpoints are not adjacent in layer order — the paper's
    /// skip connections.
    pub fn skip_edges(&self) -> Vec<Edge> {
        self.edges
            .iter()
            .copied()
            .filter(|e| e.dst - e.src > 1)
            .collect()
    }

    /// Kahn topological order. Layer order is kept topological by the
    /// builders, but this validates it and is what analyses iterate over.
    pub fn topo_order(&self) -> Result<Vec<LayerId>, String> {
        let n = self.layers.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<LayerId>> = vec![Vec::new(); n];
        for e in &self.edges {
            indeg[e.dst] += 1;
            succ[e.src].push(e.dst);
        }
        let mut q: VecDeque<LayerId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = q.pop_front() {
            order.push(u);
            for &v in &succ[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    q.push_back(v);
                }
            }
        }
        if order.len() != n {
            return Err(format!(
                "model '{}' contains a cycle ({} of {} layers ordered)",
                self.name,
                order.len(),
                n
            ));
        }
        Ok(order)
    }

    /// Structural validation: acyclic, connected-forward, and construction
    /// order already topological (builders guarantee this; analyses rely on
    /// it for reuse-distance arithmetic).
    pub fn validate(&self) -> Result<(), String> {
        let order = self.topo_order()?;
        // Construction order must itself be topological: every edge forward.
        for e in &self.edges {
            if e.src >= e.dst {
                return Err(format!(
                    "edge {}→{} is not forward in construction order",
                    e.src, e.dst
                ));
            }
        }
        // All non-root layers reachable (have at least one predecessor).
        for id in 1..self.layers.len() {
            if self.predecessors(id).is_empty() {
                // multiple roots are allowed only for explicit multi-input
                // models; treat orphan mid-graph layers as an error.
                return Err(format!(
                    "layer {id} ('{}') has no predecessor",
                    self.layers[id].name
                ));
            }
        }
        let _ = order;
        Ok(())
    }

    // ---- whole-model aggregates ----------------------------------------

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_weight_words(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_words()).sum()
    }

    pub fn total_output_act_words(&self) -> u64 {
        self.layers.iter().map(|l| l.output_act_words()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    fn tiny_chain() -> ModelGraph {
        let mut g = ModelGraph::new("tiny");
        g.push(Layer::new("c0", Op::conv2d(1, 16, 16, 3, 8, 3, 3, 1, 1)));
        g.push(Layer::new("c1", Op::conv2d(1, 16, 16, 8, 8, 3, 3, 1, 1)));
        g.push(Layer::new("c2", Op::conv2d(1, 16, 16, 8, 8, 3, 3, 1, 1)));
        g
    }

    #[test]
    fn chain_has_no_skips() {
        let g = tiny_chain();
        assert!(g.validate().is_ok());
        assert!(g.skip_edges().is_empty());
        assert_eq!(g.topo_order().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn skip_edge_detected_with_distance() {
        let mut g = tiny_chain();
        g.add_edge(0, 2); // residual
        let skips = g.skip_edges();
        assert_eq!(skips.len(), 1);
        assert_eq!(skips[0], Edge { src: 0, dst: 2 });
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut g = tiny_chain();
        g.add_edge(0, 2);
        g.add_edge(0, 2);
        assert_eq!(g.skip_edges().len(), 1);
    }

    #[test]
    fn predecessors_successors() {
        let mut g = tiny_chain();
        g.add_edge(0, 2);
        assert_eq!(g.predecessors(2), vec![1, 0]);
        assert_eq!(g.successors(0), vec![1, 2]);
    }

    #[test]
    #[should_panic]
    fn backward_edge_panics() {
        let mut g = tiny_chain();
        g.add_edge(2, 2);
    }

    #[test]
    fn orphan_layer_fails_validation() {
        let mut g = tiny_chain();
        g.add_root(Layer::new("orphan", Op::conv2d(1, 8, 8, 3, 3, 1, 1, 1, 0)));
        assert!(g.validate().is_err());
    }

    #[test]
    fn aggregates_sum_layers() {
        let g = tiny_chain();
        let macs: u64 = g.layers().iter().map(|l| l.macs()).sum();
        assert_eq!(g.total_macs(), macs);
        assert!(g.total_weight_words() > 0);
    }
}
