//! Model intermediate representation: einsum-style operators, layers, and
//! the model DAG (with first-class skip connections — Sec. II-D / Fig. 6).

mod graph;
mod op;
pub mod skips;

pub use graph::{Edge, LayerId, ModelGraph};
pub use op::{ConvParams, Op, OpKind};

/// One layer of a model: a named operator instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub op: Op,
}

impl Layer {
    pub fn new(name: impl Into<String>, op: Op) -> Self {
        Self {
            name: name.into(),
            op,
        }
    }

    /// Input activation volume in words (sum over all inputs).
    pub fn input_act_words(&self) -> u64 {
        self.op.input_act_words()
    }

    /// Output activation volume in words.
    pub fn output_act_words(&self) -> u64 {
        self.op.output_act_words()
    }

    /// Weight (parameter) volume in words.
    pub fn weight_words(&self) -> u64 {
        self.op.weight_words()
    }

    /// Multiply-accumulate count (or op count for non-MAC layers).
    pub fn macs(&self) -> u64 {
        self.op.macs()
    }

    /// Activation/weight ratio — the key metric of Fig. 5. Activation volume
    /// is input + output; weight-free ops map to +inf.
    pub fn aw_ratio(&self) -> f64 {
        let act = (self.input_act_words() + self.output_act_words()) as f64;
        let w = self.weight_words() as f64;
        if w == 0.0 {
            f64::INFINITY
        } else {
            act / w
        }
    }

    /// "Complex" layers (ROIAlign, RPN, …) cut pipeline segments (Sec. IV-A).
    pub fn is_complex(&self) -> bool {
        matches!(self.op.kind(), OpKind::RoiAlign | OpKind::Rpn)
    }

    /// True for einsum-based (MAC-dominated) operators that the mapper
    /// treats as pipeline-stage candidates.
    pub fn is_einsum(&self) -> bool {
        matches!(
            self.op.kind(),
            OpKind::Conv2d | OpKind::DwConv2d | OpKind::Gemm
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(h: usize, c: usize, k: usize, r: usize) -> Op {
        Op::conv2d(1, h, h, c, k, r, r, 1, r / 2)
    }

    #[test]
    fn aw_ratio_activation_vs_weight_heavy() {
        // Large feature map, few weights → activation heavy.
        let act_heavy = Layer::new("a", conv(128, 8, 8, 3));
        assert!(act_heavy.aw_ratio() > 100.0);
        // Tiny feature map, many channels → weight heavy.
        let w_heavy = Layer::new("w", conv(4, 512, 512, 3));
        assert!(w_heavy.aw_ratio() < 0.1);
    }

    #[test]
    fn weight_free_ops_have_infinite_ratio() {
        let l = Layer::new("add", Op::eltwise_add(1, 16, 16, 32));
        assert!(l.aw_ratio().is_infinite());
        assert_eq!(l.weight_words(), 0);
    }

    #[test]
    fn complex_layer_detection() {
        assert!(Layer::new("roi", Op::roi_align(64, 7, 256)).is_complex());
        assert!(!Layer::new("c", conv(8, 8, 8, 1)).is_complex());
    }
}
