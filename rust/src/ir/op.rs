//! Operator definitions and their volume/MAC accounting.
//!
//! All volumes are in *words*; `ArchConfig::bytes_per_word` converts to
//! bytes where needed. Shapes follow the paper's einsum conventions
//! (Eq. 1–2): GEMM is `O[m,n] = Σ_k A[m,k] B[k,n]`; convolution is NHWC
//! activations with RSCK weights.

/// Convolution shape parameters (shared by Conv2d / DwConv2d / Pool-like).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvParams {
    /// Batch.
    pub n: usize,
    /// Input feature-map height.
    pub h: usize,
    /// Input feature-map width.
    pub w: usize,
    /// Input channels.
    pub c: usize,
    /// Output channels (ignored / equal to `c` for depthwise).
    pub k: usize,
    /// Filter height.
    pub r: usize,
    /// Filter width.
    pub s: usize,
    /// Stride (same in both dims).
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
}

impl ConvParams {
    /// Output spatial height.
    pub fn oh(&self) -> usize {
        (self.h + 2 * self.pad).saturating_sub(self.r) / self.stride + 1
    }

    /// Output spatial width.
    pub fn ow(&self) -> usize {
        (self.w + 2 * self.pad).saturating_sub(self.s) / self.stride + 1
    }
}

/// Coarse operator class, used for dispatch without matching full payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Conv2d,
    DwConv2d,
    Gemm,
    Pool,
    EltwiseAdd,
    Upsample,
    Concat,
    RoiAlign,
    Rpn,
}

impl OpKind {
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Conv2d => "conv2d",
            OpKind::DwConv2d => "dwconv2d",
            OpKind::Gemm => "gemm",
            OpKind::Pool => "pool",
            OpKind::EltwiseAdd => "eltwise_add",
            OpKind::Upsample => "upsample",
            OpKind::Concat => "concat",
            OpKind::RoiAlign => "roi_align",
            OpKind::Rpn => "rpn",
        }
    }
}

/// A tensor operator with concrete shape.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Standard convolution (Eq. 2).
    Conv2d(ConvParams),
    /// Depthwise convolution: one filter per channel (`k` unused).
    DwConv2d(ConvParams),
    /// General matrix multiply (Eq. 1): `[m,k] × [k,n] → [m,n]`.
    Gemm { m: usize, k: usize, n: usize },
    /// Max/avg pooling over `window × window`, stride `stride`.
    Pool {
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        window: usize,
        stride: usize,
    },
    /// Elementwise addition of `arity` same-shaped activations (skip joins).
    EltwiseAdd {
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        arity: usize,
    },
    /// Nearest/bilinear upsample by `factor` (decoder paths, RITNet UpBlock).
    Upsample {
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        factor: usize,
    },
    /// Channel concatenation of dense-block inputs.
    Concat {
        n: usize,
        h: usize,
        w: usize,
        c_each: usize,
        arity: usize,
    },
    /// ROIAlign over `rois` regions, `out` output resolution, `c` channels —
    /// a "complex layer" that cuts pipelining (Sec. IV-A).
    RoiAlign { rois: usize, out: usize, c: usize },
    /// Region proposal network head (complex layer).
    Rpn {
        h: usize,
        w: usize,
        c: usize,
        anchors: usize,
    },
}

impl Op {
    // ---- constructors -------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        k: usize,
        r: usize,
        s: usize,
        stride: usize,
        pad: usize,
    ) -> Op {
        Op::Conv2d(ConvParams {
            n,
            h,
            w,
            c,
            k,
            r,
            s,
            stride,
            pad,
        })
    }

    pub fn dwconv2d(n: usize, h: usize, w: usize, c: usize, r: usize, stride: usize) -> Op {
        Op::DwConv2d(ConvParams {
            n,
            h,
            w,
            c,
            k: c,
            r,
            s: r,
            stride,
            pad: r / 2,
        })
    }

    pub fn gemm(m: usize, k: usize, n: usize) -> Op {
        Op::Gemm { m, k, n }
    }

    pub fn pool(n: usize, h: usize, w: usize, c: usize, window: usize, stride: usize) -> Op {
        Op::Pool {
            n,
            h,
            w,
            c,
            window,
            stride,
        }
    }

    pub fn eltwise_add(n: usize, h: usize, w: usize, c: usize) -> Op {
        Op::EltwiseAdd {
            n,
            h,
            w,
            c,
            arity: 2,
        }
    }

    pub fn eltwise_add_n(n: usize, h: usize, w: usize, c: usize, arity: usize) -> Op {
        Op::EltwiseAdd { n, h, w, c, arity }
    }

    pub fn upsample(n: usize, h: usize, w: usize, c: usize, factor: usize) -> Op {
        Op::Upsample { n, h, w, c, factor }
    }

    pub fn concat(n: usize, h: usize, w: usize, c_each: usize, arity: usize) -> Op {
        Op::Concat {
            n,
            h,
            w,
            c_each,
            arity,
        }
    }

    pub fn roi_align(rois: usize, out: usize, c: usize) -> Op {
        Op::RoiAlign { rois, out, c }
    }

    pub fn rpn(h: usize, w: usize, c: usize, anchors: usize) -> Op {
        Op::Rpn { h, w, c, anchors }
    }

    // ---- classification ------------------------------------------------

    pub fn kind(&self) -> OpKind {
        match self {
            Op::Conv2d(_) => OpKind::Conv2d,
            Op::DwConv2d(_) => OpKind::DwConv2d,
            Op::Gemm { .. } => OpKind::Gemm,
            Op::Pool { .. } => OpKind::Pool,
            Op::EltwiseAdd { .. } => OpKind::EltwiseAdd,
            Op::Upsample { .. } => OpKind::Upsample,
            Op::Concat { .. } => OpKind::Concat,
            Op::RoiAlign { .. } => OpKind::RoiAlign,
            Op::Rpn { .. } => OpKind::Rpn,
        }
    }

    // ---- volumes -------------------------------------------------------

    /// Total input activation words (all operands).
    pub fn input_act_words(&self) -> u64 {
        match *self {
            Op::Conv2d(p) | Op::DwConv2d(p) => (p.n * p.h * p.w * p.c) as u64,
            Op::Gemm { m, k, .. } => (m * k) as u64,
            Op::Pool { n, h, w, c, .. } => (n * h * w * c) as u64,
            Op::EltwiseAdd { n, h, w, c, arity } => (n * h * w * c * arity) as u64,
            Op::Upsample { n, h, w, c, .. } => (n * h * w * c) as u64,
            Op::Concat {
                n,
                h,
                w,
                c_each,
                arity,
            } => (n * h * w * c_each * arity) as u64,
            Op::RoiAlign { rois, out, c } => (rois * out * out * c * 4) as u64,
            Op::Rpn { h, w, c, .. } => (h * w * c) as u64,
        }
    }

    /// Output activation words.
    pub fn output_act_words(&self) -> u64 {
        match *self {
            Op::Conv2d(p) => (p.n * p.oh() * p.ow() * p.k) as u64,
            Op::DwConv2d(p) => (p.n * p.oh() * p.ow() * p.c) as u64,
            Op::Gemm { m, n, .. } => (m * n) as u64,
            Op::Pool {
                n,
                h,
                w,
                c,
                window,
                stride,
            } => {
                let oh = h.saturating_sub(window) / stride + 1;
                let ow = w.saturating_sub(window) / stride + 1;
                (n * oh * ow * c) as u64
            }
            Op::EltwiseAdd { n, h, w, c, .. } => (n * h * w * c) as u64,
            Op::Upsample { n, h, w, c, factor } => (n * h * factor * w * factor * c) as u64,
            Op::Concat {
                n,
                h,
                w,
                c_each,
                arity,
            } => (n * h * w * c_each * arity) as u64,
            Op::RoiAlign { rois, out, c } => (rois * out * out * c) as u64,
            Op::Rpn { h, w, anchors, .. } => (h * w * anchors * 5) as u64,
        }
    }

    /// Weight (parameter) words.
    pub fn weight_words(&self) -> u64 {
        match *self {
            Op::Conv2d(p) => (p.k * p.c * p.r * p.s) as u64,
            // Depthwise: one r×s filter per channel.
            Op::DwConv2d(p) => (p.c * p.r * p.s) as u64,
            Op::Gemm { k, n, .. } => (k * n) as u64,
            Op::Rpn { c, anchors, .. } => (c * anchors * 5 * 9) as u64,
            _ => 0,
        }
    }

    /// Multiply-accumulates (op count for non-MAC layers).
    pub fn macs(&self) -> u64 {
        match *self {
            Op::Conv2d(p) => (p.n * p.oh() * p.ow() * p.k) as u64 * (p.c * p.r * p.s) as u64,
            Op::DwConv2d(p) => (p.n * p.oh() * p.ow() * p.c) as u64 * (p.r * p.s) as u64,
            Op::Gemm { m, k, n } => (m * k) as u64 * n as u64,
            Op::Pool {
                n,
                h,
                w,
                c,
                window,
                stride,
            } => {
                let oh = h.saturating_sub(window) / stride + 1;
                let ow = w.saturating_sub(window) / stride + 1;
                (n * oh * ow * c * window * window) as u64
            }
            Op::EltwiseAdd { n, h, w, c, arity } => (n * h * w * c * (arity - 1)) as u64,
            Op::Upsample { n, h, w, c, factor } => (n * h * factor * w * factor * c) as u64,
            Op::Concat {
                n,
                h,
                w,
                c_each,
                arity,
            } => (n * h * w * c_each * arity) as u64,
            Op::RoiAlign { rois, out, c } => (rois * out * out * c * 4) as u64,
            Op::Rpn { h, w, c, anchors } => (h * w * c * anchors * 5 * 9) as u64,
        }
    }

    /// Output feature-map "rows" — the natural unit of fine-grained
    /// pipelining granularity for spatial ops (one H-row of the output).
    pub fn output_rows(&self) -> u64 {
        match *self {
            Op::Conv2d(p) => (p.n * p.oh()) as u64,
            Op::DwConv2d(p) => (p.n * p.oh()) as u64,
            Op::Gemm { m, .. } => m as u64,
            Op::Pool {
                n, h, window, stride, ..
            } => (n * (h.saturating_sub(window) / stride + 1)) as u64,
            Op::EltwiseAdd { n, h, .. } => (n * h) as u64,
            Op::Upsample { n, h, factor, .. } => (n * h * factor) as u64,
            Op::Concat { n, h, .. } => (n * h) as u64,
            Op::RoiAlign { rois, out, .. } => (rois * out) as u64,
            Op::Rpn { h, .. } => h as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims_with_padding() {
        // 3x3 stride-1 same-pad keeps spatial dims.
        let p = ConvParams {
            n: 1,
            h: 32,
            w: 32,
            c: 16,
            k: 32,
            r: 3,
            s: 3,
            stride: 1,
            pad: 1,
        };
        assert_eq!(p.oh(), 32);
        assert_eq!(p.ow(), 32);
        // stride 2 halves.
        let p2 = ConvParams { stride: 2, ..p };
        assert_eq!(p2.oh(), 16);
    }

    #[test]
    fn conv_volume_accounting() {
        let op = Op::conv2d(1, 32, 32, 16, 32, 3, 3, 1, 1);
        assert_eq!(op.input_act_words(), 32 * 32 * 16);
        assert_eq!(op.output_act_words(), 32 * 32 * 32);
        assert_eq!(op.weight_words(), 32 * 16 * 3 * 3);
        assert_eq!(op.macs(), (32 * 32 * 32) as u64 * (16 * 3 * 3) as u64);
    }

    #[test]
    fn dwconv_is_activation_heavy_by_construction() {
        let dw = Op::dwconv2d(1, 56, 56, 128, 3, 1);
        let cv = Op::conv2d(1, 56, 56, 128, 128, 3, 3, 1, 1);
        // Same spatial shape: depthwise has 128x fewer weights and macs.
        assert_eq!(cv.weight_words() / dw.weight_words(), 128);
        assert_eq!(cv.macs() / dw.macs(), 128);
        assert_eq!(dw.output_act_words(), cv.output_act_words());
    }

    #[test]
    fn gemm_volumes() {
        let g = Op::gemm(64, 256, 512);
        assert_eq!(g.input_act_words(), 64 * 256);
        assert_eq!(g.weight_words(), 256 * 512);
        assert_eq!(g.output_act_words(), 64 * 512);
        assert_eq!(g.macs(), 64 * 256 * 512);
    }

    #[test]
    fn eltwise_add_arity() {
        // DenseNet-style 4-way combine (RITNet block).
        let add = Op::eltwise_add_n(1, 16, 16, 32, 4);
        assert_eq!(add.input_act_words(), 4 * 16 * 16 * 32);
        assert_eq!(add.output_act_words(), 16 * 16 * 32);
    }

    #[test]
    fn pool_halves_spatial() {
        let p = Op::pool(1, 32, 32, 8, 2, 2);
        assert_eq!(p.output_act_words(), 16 * 16 * 8);
        assert_eq!(p.weight_words(), 0);
    }

    #[test]
    fn upsample_scales_output() {
        let u = Op::upsample(1, 8, 8, 4, 2);
        assert_eq!(u.output_act_words(), 16 * 16 * 4);
    }

    #[test]
    fn output_rows_unit() {
        assert_eq!(Op::conv2d(1, 32, 32, 8, 8, 3, 3, 1, 1).output_rows(), 32);
        assert_eq!(Op::gemm(64, 8, 8).output_rows(), 64);
    }
}
