//! Skip-connection characterization (Fig. 6): reuse distance and density.
//!
//! *Reuse distance* of a skip edge (i → j) is `j - i` in topological chain
//! order — how long the producer's activation must stay alive. *Density* is
//! skip edges per layer. Both vary widely across XR-bench models (RITNet:
//! dense multi-distance skips; MiDaS: one long skip per block) and both push
//! the depth heuristic toward deeper pipelines (Sec. III-A).

use super::{LayerId, ModelGraph};

/// Summary of a model's skip structure.
#[derive(Debug, Clone, PartialEq)]
pub struct SkipProfile {
    /// (src, dst, distance) per skip edge, in edge order.
    pub edges: Vec<(LayerId, LayerId, usize)>,
    /// Skip edges per layer.
    pub density: f64,
    /// Mean reuse distance (0 when there are no skips).
    pub mean_distance: f64,
    /// Maximum reuse distance.
    pub max_distance: usize,
}

impl SkipProfile {
    pub fn of(graph: &ModelGraph) -> Self {
        let edges: Vec<(LayerId, LayerId, usize)> = graph
            .skip_edges()
            .iter()
            .map(|e| (e.src, e.dst, e.dst - e.src))
            .collect();
        let n_layers = graph.num_layers().max(1);
        let density = edges.len() as f64 / n_layers as f64;
        let mean_distance = if edges.is_empty() {
            0.0
        } else {
            edges.iter().map(|&(_, _, d)| d as f64).sum::<f64>() / edges.len() as f64
        };
        let max_distance = edges.iter().map(|&(_, _, d)| d).max().unwrap_or(0);
        Self {
            edges,
            density,
            mean_distance,
            max_distance,
        }
    }

    pub fn num_skips(&self) -> usize {
        self.edges.len()
    }
}

/// Extra activation words a pipeline segment `[l, l+depth)` must hold (or
/// re-fetch) because of skip connections crossing the segment boundary —
/// the `Σ A_i, i ∉ (l, l+D)` term of Sec. III-A. Counts both:
///  - incoming: source outside the segment, destination inside;
///  - outgoing: source inside, destination outside (output must be kept).
pub fn boundary_skip_act_words(graph: &ModelGraph, start: LayerId, depth: usize) -> u64 {
    let end = start + depth; // exclusive
    let mut words = 0u64;
    for e in graph.skip_edges() {
        let src_in = e.src >= start && e.src < end;
        let dst_in = e.dst >= start && e.dst < end;
        if src_in != dst_in {
            // the tensor crossing the boundary is the producer's output
            words += graph.layer(e.src).output_act_words();
        }
    }
    words
}

/// Skip edges fully absorbed inside a segment `[l, l+depth)` — these are the
/// wins of deeper pipelining (their activations never round-trip to DRAM).
pub fn absorbed_skips(graph: &ModelGraph, start: LayerId, depth: usize) -> usize {
    let end = start + depth;
    graph
        .skip_edges()
        .iter()
        .filter(|e| e.src >= start && e.src < end && e.dst >= start && e.dst < end)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Layer, Op};

    /// 6-layer chain with skips 0→2 (dist 2) and 1→4 (dist 3).
    fn skippy() -> ModelGraph {
        let mut g = ModelGraph::new("skippy");
        for i in 0..6 {
            g.push(Layer::new(
                format!("c{i}"),
                Op::conv2d(1, 16, 16, 8, 8, 3, 3, 1, 1),
            ));
        }
        g.add_edge(0, 2);
        g.add_edge(1, 4);
        g
    }

    #[test]
    fn profile_counts_and_distances() {
        let p = SkipProfile::of(&skippy());
        assert_eq!(p.num_skips(), 2);
        assert_eq!(p.max_distance, 3);
        assert!((p.mean_distance - 2.5).abs() < 1e-12);
        assert!((p.density - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn no_skips_profile_is_zero() {
        let mut g = ModelGraph::new("chain");
        for i in 0..3 {
            g.push(Layer::new(
                format!("c{i}"),
                Op::conv2d(1, 8, 8, 4, 4, 3, 3, 1, 1),
            ));
        }
        let p = SkipProfile::of(&g);
        assert_eq!(p.num_skips(), 0);
        assert_eq!(p.mean_distance, 0.0);
        assert_eq!(p.max_distance, 0);
    }

    #[test]
    fn boundary_crossing_accounting() {
        let g = skippy();
        let out_words = g.layer(1).output_act_words();
        // Segment [0,2): edge 0→2 crosses out, edge 1→4 crosses out.
        assert_eq!(
            boundary_skip_act_words(&g, 0, 2),
            g.layer(0).output_act_words() + out_words
        );
        // Segment [0,3): 0→2 absorbed, 1→4 crosses.
        assert_eq!(boundary_skip_act_words(&g, 0, 3), out_words);
        assert_eq!(absorbed_skips(&g, 0, 3), 1);
        // Segment [0,5): everything absorbed.
        assert_eq!(boundary_skip_act_words(&g, 0, 5), 0);
        assert_eq!(absorbed_skips(&g, 0, 5), 2);
    }

    #[test]
    fn deeper_segments_absorb_monotonically() {
        let g = skippy();
        let mut prev = 0;
        for d in 1..=6 {
            let a = absorbed_skips(&g, 0, d);
            assert!(a >= prev);
            prev = a;
        }
    }
}
