//! # PipeOrgan — inter-operation pipelining with flexible spatial
//! organization and interconnects
//!
//! Full-system reproduction of *PipeOrgan* (Garg et al., 2024). The crate
//! implements, end to end:
//!
//! - a model IR with first-class skip connections ([`ir`]) and an
//!   XR-bench-like workload zoo ([`workloads`]);
//! - stage 1 of the paper's flow: intra-operator dataflow selection
//!   ([`dataflow`]), pipeline-depth heuristic and granularity Algorithm 1
//!   ([`pipeline`]);
//! - stage 2: spatial organization strategies ([`spatial`]), NoC topologies
//!   including the proposed AMP ([`noc`]), traffic derivation ([`traffic`])
//!   and congestion analysis / cycle-level simulation ([`sim`]);
//! - memory, energy and end-to-end cost models ([`memory`], [`energy`],
//!   [`cost`]) plus TANGRAM-like and SIMBA-like baselines ([`baselines`])
//!   and the full PipeOrgan mapper ([`mapper`]);
//! - a multi-threaded evaluation coordinator and a functional pipelined
//!   executor driving AOT-compiled JAX/Pallas artifacts through PJRT
//!   ([`coordinator`], [`runtime`]);
//! - a parallel design-space exploration engine with memoized cost
//!   evaluation and Pareto reporting ([`dse`]);
//! - multi-workload co-scheduling of concurrent XR task sets onto one
//!   shared PE array via rectangular region partitioning and an
//!   occupancy-state allocation search ([`cosched`]);
//! - an online serving simulator replaying request streams against the
//!   co-scheduled plan with deadline-aware dispatch and dynamic
//!   cross-region DRAM-bandwidth contention ([`serve`]);
//! - unified observability — zero-cost-when-disabled tracing/counters
//!   with Chrome/Perfetto timeline export across dse/cosched/serve
//!   ([`obs`]);
//! - per-figure report emitters ([`report`]).
//!
//! See `rust/DESIGN.md` for the paper-to-module map, the no-network
//! dependency substitution table (§2), the experiment index (§5) and the
//! DSE engine design (§6). Generated measured-vs-paper artifacts land
//! under `reports/` when the CLI runs.

pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cosched;
pub mod cost;
pub mod dataflow;
pub mod dse;
pub mod energy;
pub mod ir;
pub mod mapper;
pub mod memory;
pub mod noc;
pub mod obs;
pub mod pipeline;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod spatial;
pub mod traffic;
pub mod util;
pub mod workloads;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
