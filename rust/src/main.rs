//! `pipeorgan` — CLI front end for the PipeOrgan reproduction.
//!
//! Subcommands (each regenerates the matching paper artifact; see
//! DESIGN.md §5):
//!
//! ```text
//! pipeorgan characterize        # Fig. 5 + Fig. 6
//! pipeorgan traffic             # Fig. 8–12 scenario analysis + Table II
//! pipeorgan e2e                 # Fig. 13 + Fig. 14 (full zoo sweep)
//! pipeorgan congestion          # Fig. 15
//! pipeorgan depth               # Fig. 16
//! pipeorgan granularity         # Fig. 17
//! pipeorgan validate-dataflow   # Sec. IV-A heuristic validation
//! pipeorgan dse                 # E16: design-space exploration (frontier + gap)
//! pipeorgan cosched             # E17: multi-workload co-scheduling (XR scenarios)
//! pipeorgan serve               # E18: online serving simulation (deadline-aware)
//! pipeorgan fleet               # E19: fleet-scale serving (router + autoscaler)
//! pipeorgan run-segment         # E15: functional pipelined execution (PJRT)
//! pipeorgan all                 # everything above except dse/cosched/serve/run-segment
//! ```
//!
//! Common flags: `--out <dir>` (reports directory, default `reports`),
//! `--workers <n>`, `--config <file>` (key=value ArchConfig overrides),
//! `--artifacts <dir>` (default `artifacts`), `--seed <n>`.
//!
//! `dse`-only flags (rejected on every other subcommand): `--workload
//! <name|all>` (comma lists allowed), `--strategy <beam|exhaustive>`,
//! `--beam <n>`, `--depth-cap <n>`, `--rungs <n>`, `--budget <n>`,
//! `--topologies <a,b,..>`, `--channel-load-objective` (fourth Pareto
//! axis), `--cache-file <file>` (persistent evaluation cache: loaded
//! before the sweep, pruned and saved back after it), `--cache-cap <n>`
//! (entry cap applied before saving), `--obs` (observability counters +
//! `reports/obs.json`), `--trace-out <file>` (Chrome/Perfetto trace of
//! the run; implies `--obs`), `--noc-out <file>` (standalone
//! `pipeorgan-noc-v1` link-load document — per-link load maps and the
//! congestion verifier; implies the `report::noc` table, which otherwise
//! rides `--channel-load-objective`; see docs/OBSERVABILITY.md §NoC
//! telemetry).
//!
//! `e2e`-only flags: `--tuned` (run the search-guided `PipeOrgan::tuned`
//! mapper in the PipeOrgan column), `--cache-file <file>` / `--cache-cap
//! <n>` (shared persistent cache for the tuned sweep).
//!
//! `cosched`-only flags: `--scenario <name|all>` (canned XR scenarios,
//! comma lists allowed), `--partition <bands|guillotine>` (vertical bands
//! vs 2-D guillotine rectangles with per-region topology choice),
//! `--quantum <cols>` (region width / cut-grid quantum), `--tuned`,
//! `--budget <n>`, `--cache-file <file>`, `--cache-cap <n>`, `--obs`,
//! `--trace-out <file>`, `--noc-out <file>` (per-region link-load maps
//! composed into a full-array congestion heatmap, idle rectangles
//! included).
//!
//! `serve`-only flags: `--scenario <name|all>`, `--partition
//! <bands|guillotine>` (partition family of the served plan), `--policy
//! <fifo|edf|rm|all>` (comma lists allowed), `--arrivals
//! <periodic|jittered|poisson>`, `--duration-s <s>`, `--rate-mult <x>`,
//! `--borrow` (cross-task region borrowing), `--bandwidth
//! <dynamic|static>` (DRAM contention model), `--sweep` (binary-search the
//! max sustainable rate multiplier), `--cache-file <file>`, `--cache-cap
//! <n>`, `--obs` (request-lifecycle counters + `reports/obs.json`),
//! `--trace-out <file>` (Perfetto timeline of the event loop: one track
//! per region, counter tracks for queue depth / bandwidth split /
//! utilization; implies `--obs`), `--attr-out <file>` (standalone
//! critical-path latency-attribution report: windowed queue/compute/DRAM
//! breakdown, SLO burn rate, worst requests — also embedded as an `attr`
//! block in `serve.json`), `--flight-out <file>` (arm the flight
//! recorder: a bounded ring of recent events frozen at the first
//! deadline miss, dumped as a Perfetto-compatible snippet plus
//! attribution table; see docs/OBSERVABILITY.md), `--trace-file <file>`
//! (replay a captured device trace: one timestamp column per task,
//! replacing the synthetic `--arrivals`/`--rate-mult` process),
//! `--noc-out <file>` (link-load maps per home region plus time-windowed
//! congestion heatmaps over the replay), `--out-dir <dir>` (ask for every
//! standalone artifact at once as `<dir>/<name>.json`; the per-artifact
//! flags above stay as aliases and win for their artifact — see
//! `report::sink`).
//!
//! `fleet`-only flags (on top of every `serve` flag): `--chips <n>`
//! (array instances), `--chip-dims <RxC,..>` (heterogeneous chip
//! geometries, cycled), `--router <round-robin|jsq|deadline|affinity|all>`
//! (front-door routing policies, comma lists allowed), `--admission
//! <all|deadline>` (reject requests no up chip could finish in time),
//! `--autoscale` + `--min-chips/--spinup-s/--scale-high-s/--scale-low-s/
//! --scale-interval-s` (backlog-watermark chip scaling with a spin-up
//! delay), `--cold-frac`/`--warm-decay-s` (cold-start weight-load model).
//! Arrivals default to the same processes as `serve`; `--arrivals
//! diurnal` drives the autoscaler through a day-curve. Emits the
//! `fleet`/`fleet_chips` reports (tails, miss + rejection rates, per-chip
//! utilization spread, cost as PE-seconds per million completed) and
//! reuses the serve noc/attr/flight emitters per chip. See
//! docs/SERVING.md.

use std::collections::HashSet;
use std::sync::Arc;

use pipeorgan::cli::Args;
use pipeorgan::config::ArchConfig;
use pipeorgan::coordinator as coord;
use pipeorgan::coordinator::MapperKind;
use pipeorgan::cosched::{self, CoschedConfig, COSCHED_FLAGS};
use pipeorgan::dse::{
    context_fingerprint, CacheLoadOutcome, DseConfig, EvalCache, CACHE_DEFAULT_CAP, DSE_FLAGS,
};
use pipeorgan::obs::Obs;
use pipeorgan::report::{self, ArtifactSink};
use pipeorgan::serve::{self, FleetConfig, ServeConfig, FLEET_FLAGS, SERVE_FLAGS};
use pipeorgan::workloads;

const USAGE: &str = "usage: pipeorgan <characterize|traffic|e2e|congestion|depth|granularity|validate-dataflow|ablate|dse|cosched|serve|fleet|run-segment|all> [--out DIR] [--workers N] [--config FILE] [--artifacts DIR] [--seed N] [e2e: --tuned --cache-file FILE --cache-cap N] [dse: --workload NAME|all --strategy beam|exhaustive --beam N --depth-cap N --rungs N --budget N --topologies LIST --channel-load-objective --cache-file FILE --cache-cap N --obs --trace-out FILE --noc-out FILE] [cosched: --scenario NAME|all --partition bands|guillotine --quantum N --tuned --budget N --cache-file FILE --cache-cap N --obs --trace-out FILE --noc-out FILE] [serve: --scenario NAME|all --partition bands|guillotine --policy fifo|edf|rm|all --arrivals periodic|jittered|poisson|diurnal --trace-file FILE --duration-s S --rate-mult X --borrow --bandwidth dynamic|static --sweep --cache-file FILE --cache-cap N --obs --trace-out FILE --noc-out FILE --attr-out FILE --flight-out FILE --out-dir DIR] [fleet: every serve flag plus --chips N --chip-dims RxC,.. --router round-robin|jsq|deadline|affinity|all --admission all|deadline --autoscale --min-chips N --spinup-s S --scale-high-s S --scale-low-s S --scale-interval-s S --cold-frac X --warm-decay-s S]\ndocs: rust/DESIGN.md (architecture), docs/SERVING.md (fleet operator guide), docs/PERFORMANCE.md (bench gate, hot-path design, reading --obs output), docs/OBSERVABILITY.md (traces, latency attribution, NoC telemetry, flight recorder)";

const FLAGS: &[(&str, bool)] = &[
    ("out", true),
    ("workers", true),
    ("config", true),
    ("artifacts", true),
    ("seed", true),
];

/// Strict known-flag table for a subcommand: the `dse` and `e2e` extras
/// are only legal on their own subcommand (typos and misplaced flags stay
/// hard errors).
fn known_flags(subcommand: &str) -> Vec<(&'static str, bool)> {
    let mut flags: Vec<(&'static str, bool)> = FLAGS.to_vec();
    if subcommand == "dse" {
        flags.extend_from_slice(DSE_FLAGS);
    }
    if subcommand == "cosched" {
        flags.extend_from_slice(COSCHED_FLAGS);
    }
    if subcommand == "serve" {
        flags.extend_from_slice(SERVE_FLAGS);
    }
    if subcommand == "fleet" {
        flags.extend_from_slice(SERVE_FLAGS);
        flags.extend_from_slice(FLEET_FLAGS);
    }
    if subcommand == "e2e" {
        flags.push(("tuned", false));
        flags.push(("cache-file", true));
        flags.push(("cache-cap", true));
    }
    flags
}

/// The shared `--cache-file`/`--cache-cap` plumbing of the `e2e`, `dse`
/// and `cosched` arms: reject a cap without a file (`--cache-cap` only
/// matters at save time, which only happens with `--cache-file` — it
/// would be silently dead), then load the cache and parse the cap.
fn load_cache_with_cap(
    args: &Args,
) -> anyhow::Result<(Option<std::path::PathBuf>, EvalCache, usize)> {
    if args.has("cache-cap") && !args.has("cache-file") {
        anyhow::bail!(
            "flag `--cache-cap` requires `--cache-file` (the cap bounds the persistent cache at save time)"
        );
    }
    let (path, cache) = load_cache(args);
    let cap = args
        .get_usize("cache-cap", CACHE_DEFAULT_CAP)
        .map_err(|e| anyhow::anyhow!(e))?;
    Ok((path, cache, cap))
}

/// Load the persistent evaluation cache named by `--cache-file` (cold and
/// silent when the flag is absent), reporting what happened — a rejected
/// file degrades to a cold start by design, never an error.
fn load_cache(args: &Args) -> (Option<std::path::PathBuf>, EvalCache) {
    let Some(path) = args.get("cache-file").map(std::path::PathBuf::from) else {
        return (None, EvalCache::new());
    };
    let (cache, outcome) = EvalCache::load_file(&path);
    match outcome {
        CacheLoadOutcome::Cold => {
            println!("cache: cold start ({} not found)", path.display())
        }
        CacheLoadOutcome::Warm { entries } => {
            println!("cache: warm start ({entries} entries from {})", path.display())
        }
        CacheLoadOutcome::Rejected { reason } => {
            eprintln!(
                "cache: ignoring {} ({reason}); continuing cold",
                path.display()
            )
        }
    }
    (Some(path), cache)
}

/// Save the cache back when `--cache-file` was given, after eviction:
/// entries whose context fingerprint is outside `live` (stale workload or
/// architecture definitions — they can never hit again) are dropped, then
/// the least-recently-used entries beyond `cap` are evicted. Contexts this
/// process actually touched are always considered live, so a run over
/// non-zoo contexts (e.g. cosched region configs) never prunes its own
/// work.
fn save_cache(
    path: &Option<std::path::PathBuf>,
    cache: &EvalCache,
    live: impl FnOnce() -> HashSet<u64>,
    cap: usize,
) -> anyhow::Result<()> {
    let Some(p) = path else {
        return Ok(());
    };
    let mut live = live();
    live.extend(cache.touched_contexts());
    let stale = cache.retain_contexts(&live);
    if stale > 0 {
        println!(
            "cache: pruned {stale} entries from contexts outside this run's live set \
             (stale workload/config fingerprints; custom cosched scenarios keep warm \
             via their own saves — use a separate --cache-file per subcommand if needed)"
        );
    }
    let evicted = cache.prune_to_cap(cap);
    if evicted > 0 {
        println!("cache: evicted {evicted} least-recently-used entries (cap {cap})");
    }
    cache
        .save_file(p)
        .map_err(|e| anyhow::anyhow!("saving cache to {}: {e}", p.display()))?;
    println!("cache: saved {} entries to {}", cache.len(), p.display());
    Ok(())
}

/// The statically-known live set for cache eviction: the whole zoo under
/// `cfg` plus everything the canned cosched scenarios can reach at the
/// default quantum. Every subcommand's save uses this same base, so one
/// shared `--cache-file` stays warm across `dse`, `e2e --tuned`, and
/// default `cosched` runs instead of each save pruning the others'
/// entries.
fn zoo_contexts(cfg: &ArchConfig) -> HashSet<u64> {
    let mut live: HashSet<u64> = workloads::all_tasks()
        .iter()
        .map(|g| context_fingerprint(g, cfg))
        .collect();
    live.extend(cosched::canned_live_contexts(cfg));
    live
}

/// Fold an `--obs` handle into a subcommand's report set: attach the
/// counters registry under an `"obs"` key in every report's JSON and
/// append the `report::obs` summary table. A disabled or silent handle
/// leaves the reports exactly as the subcommand built them.
fn with_obs(mut reports: Vec<report::Report>, obs: &Obs) -> Vec<report::Report> {
    if !obs.is_silent() {
        let counters = obs.counters_json();
        for r in &mut reports {
            if matches!(r.json, pipeorgan::util::json::Json::Obj(_)) {
                r.json.set("obs", counters.clone());
            }
        }
    }
    reports.extend(report::obs_report(obs));
    reports
}

/// The post-emission `--obs` epilogue shared by `dse`, `cosched`,
/// `serve`, and `fleet`: write the Perfetto trace when the sink wants the
/// `trace` artifact (`--trace-out` or `--out-dir`) and flush scoped
/// `time.*` timings to the CI bench recorder (`PIPEORGAN_BENCH_JSON`).
fn finish_obs(obs: &Obs, sink: &ArtifactSink) -> anyhow::Result<()> {
    if let Some(path) = sink.path_for("trace") {
        let path = path.display().to_string();
        obs.write_trace(&path)
            .map_err(|e| anyhow::anyhow!("writing trace to {path}: {e}"))?;
        let dropped = obs.dropped_events();
        let suffix = if dropped > 0 {
            format!(" ({dropped} oldest events dropped at the ring cap)")
        } else {
            String::new()
        };
        println!(
            "trace: wrote {} events to {path}{suffix}",
            obs.events().len()
        );
    }
    let flushed = obs
        .flush_bench_records()
        .map_err(|e| anyhow::anyhow!("flushing bench records: {e}"))?;
    if flushed > 0 {
        println!("obs: appended {flushed} timing records to the bench recorder");
    }
    Ok(())
}

/// Write a named artifact through the sink when anything asked for it
/// (its alias flag or `--out-dir`), logging where it went. Returns true
/// when a file was written.
fn sink_write(
    sink: &ArtifactSink,
    name: &str,
    what: &str,
    json: &pipeorgan::util::json::Json,
) -> anyhow::Result<bool> {
    match sink.write(name, json).map_err(|e| anyhow::anyhow!(e))? {
        Some(p) => {
            println!("{name}: wrote {what} to {}", p.display());
            Ok(true)
        }
        None => Ok(false),
    }
}

/// The `flight` artifact shared by `serve` and `fleet`: prefer the
/// snapshot frozen at a deadline miss (the incident being diagnosed);
/// otherwise the first end-of-run tail (nothing missed anywhere).
fn write_flight(sink: &ArtifactSink, runs: &[serve::ServeRun]) -> anyhow::Result<()> {
    if !sink.wants("flight") {
        return Ok(());
    }
    let snaps: Vec<_> = runs
        .iter()
        .flat_map(|r| r.outcomes.iter())
        .filter_map(|o| o.flight.as_ref().map(|f| (o, f)))
        .collect();
    match snaps.iter().find(|(_, f)| f.missed()).or_else(|| snaps.first()) {
        Some((o, f)) => {
            let doc = f.document(report::flight_table_json(o));
            if let Some(p) = sink.write("flight", &doc).map_err(|e| anyhow::anyhow!(e))? {
                println!(
                    "flight: wrote {} snapshot ({} {}) to {}",
                    f.trigger.kind(),
                    o.scenario,
                    o.policy.name(),
                    p.display()
                );
            }
        }
        None => println!("flight: recorder armed but produced no snapshot"),
    }
    Ok(())
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: &[String]) -> anyhow::Result<()> {
    let flags = known_flags(&raw[0]);
    let args = Args::parse(raw, &flags).map_err(|e| anyhow::anyhow!("{e}\n{USAGE}"))?;
    let cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            ArchConfig::from_kv_text(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        }
        None => ArchConfig::default(),
    };
    let out = args.get_or("out", "reports").to_string();
    let workers = args
        .get_usize(
            "workers",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        )
        .map_err(|e| anyhow::anyhow!(e))?;
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let seed = args.get_usize("seed", 42).map_err(|e| anyhow::anyhow!(e))? as u64;
    // Every standalone JSON artifact resolves through one named sink:
    // the legacy `--trace-out`/`--attr-out`/`--flight-out`/`--noc-out`
    // flags are aliases for their artifact name, and `--out-dir DIR`
    // requests everything a subcommand produces as `DIR/<name>.json`.
    let sink = ArtifactSink::from_cli(&args);

    let emit = |reports: Vec<report::Report>| -> anyhow::Result<()> {
        for r in reports {
            r.emit(&out)?;
            println!();
        }
        println!("reports written to {out}/");
        Ok(())
    };

    match args.subcommand.as_str() {
        "characterize" => emit(vec![report::fig5_aw_ratios(), report::fig6_skips()]),
        "traffic" => emit(vec![
            report::fig8_12_traffic(&cfg),
            report::table2_bottlenecks(&cfg),
        ]),
        "e2e" => {
            if (args.has("cache-file") || args.has("cache-cap")) && !args.has("tuned") {
                anyhow::bail!(
                    "flag `--cache-file`/`--cache-cap` on e2e requires `--tuned` (only the tuned mapper uses the evaluation cache)"
                );
            }
            if args.has("tuned") {
                let (cache_file, cache, cache_cap) = load_cache_with_cap(&args)?;
                let cache = Arc::new(cache);
                emit(vec![
                    report::fig13_with(
                        &cfg,
                        workers,
                        MapperKind::PipeOrganTuned,
                        Some(Arc::clone(&cache)),
                    ),
                    report::fig14_with(
                        &cfg,
                        workers,
                        MapperKind::PipeOrganTuned,
                        Some(Arc::clone(&cache)),
                    ),
                ])?;
                save_cache(&cache_file, &cache, || zoo_contexts(&cfg), cache_cap)
            } else {
                emit(vec![
                    report::fig13_performance(&cfg, workers),
                    report::fig14_dram(&cfg, workers),
                ])
            }
        }
        "congestion" => emit(vec![report::fig15_congestion(&cfg)]),
        "depth" => emit(vec![report::fig16_depth(&cfg)]),
        "granularity" => emit(vec![report::fig17_granularity(&cfg)]),
        "validate-dataflow" => emit(vec![report::validate_dataflow()]),
        "ablate" => emit(vec![
            report::ablation_organization(&cfg),
            report::ablation_topology(&cfg),
            report::ablation_depth(&cfg),
        ]),
        "all" => emit(report::all_reports(&cfg, workers)),
        "dse" => {
            let dse_cfg = DseConfig::from_cli(&args).map_err(|e| anyhow::anyhow!(e))?;
            let tasks = resolve_workloads(args.get_or("workload", "all"))?;
            let (cache_file, cache, cache_cap) = load_cache_with_cap(&args)?;
            let results = report::explore_all(&cfg, tasks.clone(), &dse_cfg, workers, &cache);
            let mut reports = vec![
                report::dse_frontier(&cfg, &dse_cfg, &results),
                report::dse_gap(&dse_cfg, &results),
            ];
            // The link-load distribution rides the fourth Pareto axis (or
            // an explicit artifact request) — it re-evaluates each plan on
            // both fabrics, so it is opt-in.
            if dse_cfg.channel_load_objective || sink.wants("noc") {
                let noc = report::dse_noc_report(&cfg, &tasks, &results);
                sink_write(&sink, "noc", "link-load report", &noc.json)?;
                reports.push(noc);
            }
            emit(with_obs(reports, &dse_cfg.obs))?;
            finish_obs(&dse_cfg.obs, &sink)?;
            save_cache(&cache_file, &cache, || zoo_contexts(&cfg), cache_cap)
        }
        "cosched" => {
            let cs = CoschedConfig::from_cli(&args).map_err(|e| anyhow::anyhow!(e))?;
            let scenarios = resolve_scenarios(args.get_or("scenario", "all"))?;
            let (cache_file, cache, cache_cap) = load_cache_with_cap(&args)?;
            let mut results = Vec::with_capacity(scenarios.len());
            for sc in &scenarios {
                results.push(
                    cosched::schedule(sc, &cfg, &cs, &cache, workers)
                        .map_err(|e| anyhow::anyhow!(e))?,
                );
            }
            for r in &results {
                println!(
                    "{}: co-scheduled makespan {:.3e} cycles ({:.2}x vs naive even split) \
                     [{} {}]",
                    r.scenario,
                    r.cosched.makespan_cycles,
                    r.speedup(),
                    r.partition.name(),
                    r.cut_tree.encode()
                );
            }
            let mut reports = vec![report::cosched_report(&cfg, &results)];
            let noc = report::cosched_noc_report(&cfg, &scenarios, &results);
            sink_write(&sink, "noc", "link-load report", &noc.json)?;
            reports.push(noc);
            emit(with_obs(reports, &cs.obs))?;
            finish_obs(&cs.obs, &sink)?;
            // Live contexts: the shared base plus every candidate region
            // config these scenarios actually reached (covers non-default
            // quanta and custom configs).
            save_cache(
                &cache_file,
                &cache,
                || {
                    let mut live = zoo_contexts(&cfg);
                    for r in &results {
                        live.extend(r.contexts.iter().copied());
                    }
                    live
                },
                cache_cap,
            )
        }
        "serve" => {
            let sv = ServeConfig::from_cli(&args, seed).map_err(|e| anyhow::anyhow!(e))?;
            let scenarios = resolve_scenarios(args.get_or("scenario", "all"))?;
            let (cache_file, cache, cache_cap) = load_cache_with_cap(&args)?;
            let mut runs = Vec::with_capacity(scenarios.len());
            for sc in &scenarios {
                runs.push(
                    serve::run_scenario(sc, &cfg, &sv, &cache, workers)
                        .map_err(|e| anyhow::anyhow!(e))?,
                );
            }
            for r in &runs {
                for o in &r.outcomes {
                    println!(
                        "{}: {} missed {}/{} requests ({:.2}% miss rate{})",
                        r.scenario,
                        o.policy.name(),
                        o.total_missed(),
                        o.total_requests(),
                        100.0 * o.miss_rate(),
                        if o.schedulable() { " — schedulable" } else { "" }
                    );
                }
                for s in &r.sweeps {
                    println!(
                        "{}: {} sustains up to {:.3}x the native rates ({} probes)",
                        r.scenario,
                        s.policy.name(),
                        s.max_mult,
                        s.probes.len()
                    );
                }
            }
            let mut reports = report::serve_reports(&cfg, &sv, &runs);
            // Before `with_obs`/`finish_obs`: the windowed heatmaps also
            // emit per-policy `noc_load` counter samples into the handle.
            let noc = report::serve_noc_report(&cfg, &scenarios, &runs, &sv.obs);
            sink_write(&sink, "noc", "link-load report", &noc.json)?;
            reports.push(noc);
            match report::attr_report(&runs) {
                Some(rep) => {
                    sink_write(&sink, "attr", "attribution report", &rep.json)?;
                    reports.push(rep);
                }
                None => {
                    if sink.wants("attr") {
                        println!("attr: no attribution records (nothing arrived?); skipping --attr-out");
                    }
                }
            }
            write_flight(&sink, &runs)?;
            emit(with_obs(reports, &sv.obs))?;
            finish_obs(&sv.obs, &sink)?;
            // Live contexts: the shared base plus every region config the
            // underlying co-schedules reached (covers custom configs).
            save_cache(
                &cache_file,
                &cache,
                || {
                    let mut live = zoo_contexts(&cfg);
                    for r in &runs {
                        live.extend(r.plan.cosched.contexts.iter().copied());
                    }
                    live
                },
                cache_cap,
            )
        }
        "fleet" => {
            let sv = ServeConfig::from_cli(&args, seed).map_err(|e| anyhow::anyhow!(e))?;
            let fc = FleetConfig::from_cli(&args).map_err(|e| anyhow::anyhow!(e))?;
            let chip_dims = match args.get("chip-dims") {
                Some(spec) => serve::parse_chip_dims(spec).map_err(|e| anyhow::anyhow!(e))?,
                None => Vec::new(),
            };
            let scenarios = resolve_scenarios(args.get_or("scenario", "all"))?;
            let (cache_file, cache, cache_cap) = load_cache_with_cap(&args)?;
            let mut runs = Vec::with_capacity(scenarios.len());
            for sc in &scenarios {
                runs.push(
                    serve::run_fleet_scenario(sc, &cfg, &sv, &fc, &chip_dims, &cache, workers)
                        .map_err(|e| anyhow::anyhow!(e))?,
                );
            }
            for r in &runs {
                for o in &r.outcomes {
                    println!(
                        "{}: {}+{} missed {}/{} requests ({:.2}% miss, {} rejected, \
                         {} scale events, {:.3e} PE·s per M completed)",
                        r.scenario,
                        o.router.name(),
                        o.policy.name(),
                        o.total_missed(),
                        o.total_requests(),
                        100.0 * o.miss_rate(),
                        o.rejected,
                        o.scale_events,
                        o.cost_pe_s_per_m,
                    );
                }
            }
            let mut reports = report::fleet_reports(&cfg, &sv, &fc, &runs);
            // Live cache contexts, captured before the runs are consumed
            // into per-chip pseudo-runs below.
            let live: HashSet<u64> = {
                let mut live = zoo_contexts(&cfg);
                for r in &runs {
                    for p in &r.plans {
                        live.extend(p.cosched.contexts.iter().copied());
                    }
                }
                live
            };
            // Per-chip reuse of the serve emitters: each chip's outcomes
            // become one pseudo serve run against a renamed scenario
            // clone (`<scenario>@chip<c>`), so the noc/attr/flight
            // artifacts carry the same per-chip schemas `serve` emits
            // for one array.
            let mut chip_scenarios = Vec::new();
            let mut chip_runs: Vec<serve::ServeRun> = Vec::new();
            for (run, sc) in runs.into_iter().zip(&scenarios) {
                let mut per_chip: Vec<Vec<serve::ServeOutcome>> =
                    (0..run.plans.len()).map(|_| Vec::new()).collect();
                for o in run.outcomes {
                    for (c, oc) in o.chip_outcomes.into_iter().enumerate() {
                        per_chip[c].push(oc);
                    }
                }
                for (c, (plan, mut outcomes)) in
                    run.plans.into_iter().zip(per_chip).enumerate()
                {
                    let name = format!("{}@chip{c}", run.scenario);
                    for oc in &mut outcomes {
                        oc.scenario = name.clone();
                    }
                    // The noc emitter draws region maps on the base
                    // array dims, so only chips with the base geometry
                    // get a scenario entry (heterogeneous chips still
                    // reach the attr and flight paths).
                    let dims = if chip_dims.is_empty() {
                        (cfg.pe_rows, cfg.pe_cols)
                    } else {
                        chip_dims[c % chip_dims.len()]
                    };
                    if dims == (cfg.pe_rows, cfg.pe_cols) {
                        let mut sc_c = sc.clone();
                        sc_c.name = name.clone();
                        chip_scenarios.push(sc_c);
                    }
                    chip_runs.push(serve::ServeRun {
                        scenario: name,
                        outcomes,
                        sweeps: Vec::new(),
                        plan,
                    });
                }
            }
            let noc = report::serve_noc_report(&cfg, &chip_scenarios, &chip_runs, &sv.obs);
            sink_write(&sink, "noc", "per-chip link-load report", &noc.json)?;
            reports.push(noc);
            match report::attr_report(&chip_runs) {
                Some(rep) => {
                    sink_write(&sink, "attr", "per-chip attribution report", &rep.json)?;
                    reports.push(rep);
                }
                None => {
                    if sink.wants("attr") {
                        println!("attr: no attribution records (nothing arrived?); skipping --attr-out");
                    }
                }
            }
            write_flight(&sink, &chip_runs)?;
            emit(with_obs(reports, &sv.obs))?;
            finish_obs(&sv.obs, &sink)?;
            save_cache(&cache_file, &cache, || live, cache_cap)
        }
        "run-segment" => run_segment(&artifacts, seed),
        other => anyhow::bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
}

/// Resolve `--workload`: `all`, one task name, or a comma-separated list.
fn resolve_workloads(spec: &str) -> anyhow::Result<Vec<pipeorgan::ir::ModelGraph>> {
    if spec == "all" {
        return Ok(workloads::all_tasks());
    }
    let mut tasks = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        tasks.push(workloads::task_by_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown workload `{name}` (known: {})",
                workloads::task_names().join(", ")
            )
        })?);
    }
    anyhow::ensure!(!tasks.is_empty(), "flag `--workload` lists no workloads");
    Ok(tasks)
}

/// Resolve `--scenario`: `all`, one canned scenario, or a comma list.
fn resolve_scenarios(spec: &str) -> anyhow::Result<Vec<cosched::Scenario>> {
    if spec == "all" {
        return Ok(cosched::canned_scenarios());
    }
    let mut scenarios = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        scenarios.push(cosched::scenario_by_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario `{name}` (known: {})",
                cosched::scenario_names().join(", ")
            )
        })?);
    }
    anyhow::ensure!(!scenarios.is_empty(), "flag `--scenario` lists no scenarios");
    Ok(scenarios)
}

/// E15: execute the AOT segment three ways through PJRT and check numerics.
fn run_segment(artifacts: &str, seed: u64) -> anyhow::Result<()> {
    let rt = pipeorgan::runtime::Runtime::new(artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = rt.manifest()?;
    let data = coord::SegmentData::random(manifest.segment, seed);
    println!(
        "segment: {}x{}x{} -> {} -> {} (band {})",
        manifest.segment.h,
        manifest.segment.w,
        manifest.segment.c_in,
        manifest.segment.c_mid,
        manifest.segment.c_out,
        manifest.segment.band
    );
    let op = coord::run_op_by_op(artifacts, &data)?;
    let fused = coord::run_fused(artifacts, &data)?;
    let piped = coord::run_pipelined(artifacts, &data)?;
    for r in [&op, &fused, &piped] {
        println!(
            "{:10} {:>4} tile(s)  {:>10.3} ms",
            r.mode,
            r.tiles,
            r.elapsed.as_secs_f64() * 1e3
        );
    }
    let d_fused = coord::compare_outputs(&op, &fused)?;
    let d_piped = coord::compare_outputs(&op, &piped)?;
    println!("max |op_by_op - fused|     = {d_fused:.3e}");
    println!("max |op_by_op - pipelined| = {d_piped:.3e}");
    anyhow::ensure!(d_fused < 1e-3, "fused output diverges: {d_fused}");
    anyhow::ensure!(d_piped < 1e-3, "pipelined output diverges: {d_piped}");
    println!("numerics OK: pipelined == fused == op-by-op");
    Ok(())
}
