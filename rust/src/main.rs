//! `pipeorgan` — CLI front end for the PipeOrgan reproduction.
//!
//! Subcommands (each regenerates the matching paper artifact; see
//! DESIGN.md §5):
//!
//! ```text
//! pipeorgan characterize        # Fig. 5 + Fig. 6
//! pipeorgan traffic             # Fig. 8–12 scenario analysis + Table II
//! pipeorgan e2e                 # Fig. 13 + Fig. 14 (full zoo sweep)
//! pipeorgan congestion          # Fig. 15
//! pipeorgan depth               # Fig. 16
//! pipeorgan granularity         # Fig. 17
//! pipeorgan validate-dataflow   # Sec. IV-A heuristic validation
//! pipeorgan dse                 # E16: design-space exploration (frontier + gap)
//! pipeorgan run-segment         # E15: functional pipelined execution (PJRT)
//! pipeorgan all                 # everything above except dse/run-segment
//! ```
//!
//! Common flags: `--out <dir>` (reports directory, default `reports`),
//! `--workers <n>`, `--config <file>` (key=value ArchConfig overrides),
//! `--artifacts <dir>` (default `artifacts`), `--seed <n>`.
//!
//! `dse`-only flags (rejected on every other subcommand): `--workload
//! <name|all>` (comma lists allowed), `--strategy <beam|exhaustive>`,
//! `--beam <n>`, `--depth-cap <n>`, `--rungs <n>`, `--budget <n>`,
//! `--topologies <a,b,..>`, `--cache-file <file>` (persistent evaluation
//! cache: loaded before the sweep, saved back after it).
//!
//! `e2e`-only flags: `--tuned` (run the search-guided `PipeOrgan::tuned`
//! mapper in the PipeOrgan column) and `--cache-file <file>` (shared
//! persistent cache for the tuned sweep).

use std::sync::Arc;

use pipeorgan::cli::Args;
use pipeorgan::config::ArchConfig;
use pipeorgan::coordinator as coord;
use pipeorgan::coordinator::MapperKind;
use pipeorgan::dse::{CacheLoadOutcome, DseConfig, EvalCache, DSE_FLAGS};
use pipeorgan::report;
use pipeorgan::workloads;

const USAGE: &str = "usage: pipeorgan <characterize|traffic|e2e|congestion|depth|granularity|validate-dataflow|ablate|dse|run-segment|all> [--out DIR] [--workers N] [--config FILE] [--artifacts DIR] [--seed N] [e2e: --tuned --cache-file FILE] [dse: --workload NAME|all --strategy beam|exhaustive --beam N --depth-cap N --rungs N --budget N --topologies LIST --cache-file FILE]";

const FLAGS: &[(&str, bool)] = &[
    ("out", true),
    ("workers", true),
    ("config", true),
    ("artifacts", true),
    ("seed", true),
];

/// Strict known-flag table for a subcommand: the `dse` and `e2e` extras
/// are only legal on their own subcommand (typos and misplaced flags stay
/// hard errors).
fn known_flags(subcommand: &str) -> Vec<(&'static str, bool)> {
    let mut flags: Vec<(&'static str, bool)> = FLAGS.to_vec();
    if subcommand == "dse" {
        flags.extend_from_slice(DSE_FLAGS);
    }
    if subcommand == "e2e" {
        flags.push(("tuned", false));
        flags.push(("cache-file", true));
    }
    flags
}

/// Load the persistent evaluation cache named by `--cache-file` (cold and
/// silent when the flag is absent), reporting what happened — a rejected
/// file degrades to a cold start by design, never an error.
fn load_cache(args: &Args) -> (Option<std::path::PathBuf>, EvalCache) {
    let Some(path) = args.get("cache-file").map(std::path::PathBuf::from) else {
        return (None, EvalCache::new());
    };
    let (cache, outcome) = EvalCache::load_file(&path);
    match outcome {
        CacheLoadOutcome::Cold => {
            println!("cache: cold start ({} not found)", path.display())
        }
        CacheLoadOutcome::Warm { entries } => {
            println!("cache: warm start ({entries} entries from {})", path.display())
        }
        CacheLoadOutcome::Rejected { reason } => {
            eprintln!(
                "cache: ignoring {} ({reason}); continuing cold",
                path.display()
            )
        }
    }
    (Some(path), cache)
}

/// Save the cache back when `--cache-file` was given.
fn save_cache(path: &Option<std::path::PathBuf>, cache: &EvalCache) -> anyhow::Result<()> {
    if let Some(p) = path {
        cache
            .save_file(p)
            .map_err(|e| anyhow::anyhow!("saving cache to {}: {e}", p.display()))?;
        println!("cache: saved {} entries to {}", cache.len(), p.display());
    }
    Ok(())
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: &[String]) -> anyhow::Result<()> {
    let flags = known_flags(&raw[0]);
    let args = Args::parse(raw, &flags).map_err(|e| anyhow::anyhow!("{e}\n{USAGE}"))?;
    let cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            ArchConfig::from_kv_text(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        }
        None => ArchConfig::default(),
    };
    let out = args.get_or("out", "reports").to_string();
    let workers = args
        .get_usize(
            "workers",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        )
        .map_err(|e| anyhow::anyhow!(e))?;
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let seed = args.get_usize("seed", 42).map_err(|e| anyhow::anyhow!(e))? as u64;

    let emit = |reports: Vec<report::Report>| -> anyhow::Result<()> {
        for r in reports {
            r.emit(&out)?;
            println!();
        }
        println!("reports written to {out}/");
        Ok(())
    };

    match args.subcommand.as_str() {
        "characterize" => emit(vec![report::fig5_aw_ratios(), report::fig6_skips()]),
        "traffic" => emit(vec![
            report::fig8_12_traffic(&cfg),
            report::table2_bottlenecks(&cfg),
        ]),
        "e2e" => {
            if args.has("cache-file") && !args.has("tuned") {
                anyhow::bail!(
                    "flag `--cache-file` on e2e requires `--tuned` (only the tuned mapper uses the evaluation cache)"
                );
            }
            if args.has("tuned") {
                let (cache_file, cache) = load_cache(&args);
                let cache = Arc::new(cache);
                emit(vec![
                    report::fig13_with(
                        &cfg,
                        workers,
                        MapperKind::PipeOrganTuned,
                        Some(Arc::clone(&cache)),
                    ),
                    report::fig14_with(
                        &cfg,
                        workers,
                        MapperKind::PipeOrganTuned,
                        Some(Arc::clone(&cache)),
                    ),
                ])?;
                save_cache(&cache_file, &cache)
            } else {
                emit(vec![
                    report::fig13_performance(&cfg, workers),
                    report::fig14_dram(&cfg, workers),
                ])
            }
        }
        "congestion" => emit(vec![report::fig15_congestion(&cfg)]),
        "depth" => emit(vec![report::fig16_depth(&cfg)]),
        "granularity" => emit(vec![report::fig17_granularity(&cfg)]),
        "validate-dataflow" => emit(vec![report::validate_dataflow()]),
        "ablate" => emit(vec![
            report::ablation_organization(&cfg),
            report::ablation_topology(&cfg),
            report::ablation_depth(&cfg),
        ]),
        "all" => emit(report::all_reports(&cfg, workers)),
        "dse" => {
            let dse_cfg = DseConfig::from_cli(&args).map_err(|e| anyhow::anyhow!(e))?;
            let tasks = resolve_workloads(args.get_or("workload", "all"))?;
            let (cache_file, cache) = load_cache(&args);
            emit(report::run_dse_reports(&cfg, tasks, &dse_cfg, workers, &cache))?;
            save_cache(&cache_file, &cache)
        }
        "run-segment" => run_segment(&artifacts, seed),
        other => anyhow::bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
}

/// Resolve `--workload`: `all`, one task name, or a comma-separated list.
fn resolve_workloads(spec: &str) -> anyhow::Result<Vec<pipeorgan::ir::ModelGraph>> {
    if spec == "all" {
        return Ok(workloads::all_tasks());
    }
    let mut tasks = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        tasks.push(workloads::task_by_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown workload `{name}` (known: {})",
                workloads::task_names().join(", ")
            )
        })?);
    }
    anyhow::ensure!(!tasks.is_empty(), "flag `--workload` lists no workloads");
    Ok(tasks)
}

/// E15: execute the AOT segment three ways through PJRT and check numerics.
fn run_segment(artifacts: &str, seed: u64) -> anyhow::Result<()> {
    let rt = pipeorgan::runtime::Runtime::new(artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = rt.manifest()?;
    let data = coord::SegmentData::random(manifest.segment, seed);
    println!(
        "segment: {}x{}x{} -> {} -> {} (band {})",
        manifest.segment.h,
        manifest.segment.w,
        manifest.segment.c_in,
        manifest.segment.c_mid,
        manifest.segment.c_out,
        manifest.segment.band
    );
    let op = coord::run_op_by_op(artifacts, &data)?;
    let fused = coord::run_fused(artifacts, &data)?;
    let piped = coord::run_pipelined(artifacts, &data)?;
    for r in [&op, &fused, &piped] {
        println!(
            "{:10} {:>4} tile(s)  {:>10.3} ms",
            r.mode,
            r.tiles,
            r.elapsed.as_secs_f64() * 1e3
        );
    }
    let d_fused = coord::compare_outputs(&op, &fused)?;
    let d_piped = coord::compare_outputs(&op, &piped)?;
    println!("max |op_by_op - fused|     = {d_fused:.3e}");
    println!("max |op_by_op - pipelined| = {d_piped:.3e}");
    anyhow::ensure!(d_fused < 1e-3, "fused output diverges: {d_fused}");
    anyhow::ensure!(d_piped < 1e-3, "pipelined output diverges: {d_piped}");
    println!("numerics OK: pipelined == fused == op-by-op");
    Ok(())
}
