//! The full PipeOrgan mapper (Fig. 7): stage 1 — flexible-depth
//! partitioning, intra-operator dataflow selection and granularity — then
//! stage 2 — MAC-ratio PE allocation and spatial-organization selection.
//! Runs on AMP by default (the paper's proposed configuration); a
//! mesh-constrained variant is provided for ablations, and
//! [`PipeOrgan::tuned`] upgrades the closed-form rules to a plan-time
//! budgeted beam search that can only match or beat them (see
//! [`TunedPipeOrgan`]).

mod oracle;
mod tuned;

pub use oracle::{candidates as organization_candidates, OracleOrganization};
pub use tuned::{TunedPipeOrgan, TUNED_MAPPER_NAME};

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{Mapper, MappingPlan, PlannedHandoff, PlannedSegment};
use crate::dataflow::{choose_dataflow, DataflowStyle, LoopNest};
use crate::ir::ModelGraph;
use crate::pipeline::{pair_granularity, partition, Granularity, Segment};
use crate::spatial::{allocate_pes, choose_organization, Organization};

/// The PipeOrgan mapper.
#[derive(Debug, Clone, Copy)]
pub struct PipeOrgan {
    pub topology: TopologyKind,
    /// Optional hard cap on segment depth (ablation: flexible vs fixed
    /// depth — `Some(1)` degenerates to op-by-op, `Some(2)` to
    /// TANGRAM-style pairing with PipeOrgan's organizations).
    pub depth_cap: Option<usize>,
}

impl Default for PipeOrgan {
    fn default() -> Self {
        Self {
            topology: TopologyKind::Amp,
            depth_cap: None,
        }
    }
}

impl PipeOrgan {
    /// PipeOrgan restricted to a plain mesh (ablation: spatial organization
    /// without the AMP links).
    pub fn on_mesh() -> Self {
        Self {
            topology: TopologyKind::Mesh,
            ..Self::default()
        }
    }

    pub fn on(topology: TopologyKind) -> Self {
        Self {
            topology,
            ..Self::default()
        }
    }

    /// Ablation variant with a fixed maximum depth.
    pub fn with_depth_cap(cap: usize) -> Self {
        Self {
            depth_cap: Some(cap.max(1)),
            ..Self::default()
        }
    }
}

/// Clamp a handoff granularity to the legal range: at least one word per
/// producer PE per interval (finer steps cannot leave the MAC pipeline —
/// the same floor the baselines use), at most the whole tensor. Returns
/// `(words_per_interval, intervals)`. Public because the DSE enumerator
/// scales granularities through the same floor (see `dse::space`).
pub fn clamp_granularity(total: u64, words: u64, producer_pes: usize) -> (u64, u64) {
    let min_words = producer_pes.max(1) as u64;
    let words = words.max(min_words).min(total.max(1));
    let intervals = crate::util::ceil_div(total.max(1), words).max(1);
    (words, intervals)
}

impl Mapper for PipeOrgan {
    fn name(&self) -> &'static str {
        match self.topology {
            TopologyKind::Amp => "pipeorgan",
            TopologyKind::Mesh => "pipeorgan_mesh",
            TopologyKind::FlattenedButterfly => "pipeorgan_fb",
            TopologyKind::Torus => "pipeorgan_torus",
        }
    }

    fn topology(&self) -> TopologyKind {
        self.topology
    }

    fn plan(&self, graph: &ModelGraph, cfg: &ArchConfig) -> MappingPlan {
        let decisions = partition(graph, cfg);
        let mut segments = Vec::with_capacity(decisions.len());
        for dec in &decisions {
            // Stage-2 feedback (Sec. IV-B): a handoff whose granularity
            // exceeds the producer's register files would round-trip the
            // global buffer and ramp the waterfall at coarse tiles — cut
            // the segment there instead and let each side pipeline at its
            // own fine granularity.
            for sub in split_at_gb_boundaries(graph, cfg, &dec.segment) {
                for capped in cap_depth(&sub, self.depth_cap) {
                    segments.push(plan_segment(graph, cfg, &capped));
                }
            }
        }
        MappingPlan {
            mapper_name: self.name().into(),
            topology: self.topology,
            segments,
        }
    }
}

/// Chop a segment into chunks of at most `cap` layers (no-op for `None`).
fn cap_depth(seg: &Segment, cap: Option<usize>) -> Vec<Segment> {
    let Some(cap) = cap else {
        return vec![seg.clone()];
    };
    let mut out = Vec::new();
    let mut start = seg.start;
    while start < seg.end() {
        let d = cap.min(seg.end() - start);
        out.push(Segment::new(start, d));
        start += d;
    }
    out
}

/// Split a stage-1 segment wherever the pair granularity cannot stay in the
/// producer-side register files.
fn split_at_gb_boundaries(graph: &ModelGraph, cfg: &ArchConfig, seg: &Segment) -> Vec<Segment> {
    if seg.depth == 1 {
        return vec![seg.clone()];
    }
    let styles: Vec<DataflowStyle> = seg
        .layers()
        .map(|i| choose_dataflow(graph.layer(i)))
        .collect();
    let nests: Vec<LoopNest> = seg
        .layers()
        .zip(styles.iter())
        .map(|(i, &st)| LoopNest::for_op(&graph.layer(i).op, st))
        .collect();
    let macs: Vec<u64> = seg.layers().map(|i| graph.layer(i).macs()).collect();
    let pe_alloc = allocate_pes(&macs, cfg.num_pes());
    let rf_words = cfg.rf_total_bytes() / cfg.bytes_per_word as u64;
    let mut out = Vec::new();
    let mut start = seg.start;
    for s in 0..seg.depth - 1 {
        let producer = graph.layer(seg.start + s);
        let total = producer.output_act_words();
        let g = pair_granularity(&nests[s], &nests[s + 1], total);
        let (words, _) = clamp_granularity(total, g.words, pe_alloc[s]);
        let producer_rf =
            (rf_words * pe_alloc[s] as u64 / cfg.num_pes() as u64).max(1);
        if words > producer_rf {
            let abs = seg.start + s;
            out.push(Segment::new(start, abs - start + 1));
            start = abs + 1;
        }
    }
    out.push(Segment::new(start, seg.end() - start));
    out
}

/// Plan one (already final) segment: styles, allocation, granularities,
/// organization.
fn plan_segment(graph: &ModelGraph, cfg: &ArchConfig, seg: &Segment) -> PlannedSegment {
    plan_segment_scaled(graph, cfg, seg, 1)
}

/// `plan_segment` generalized over a granularity-ladder rung: every
/// handoff's Algorithm-1 finest granularity is multiplied by `gran_scale`
/// before clamping, so `gran_scale == 1` reproduces the heuristic mapper's
/// segment exactly and powers of 4 walk toward whole-tensor handoffs. The
/// DSE enumerator (`dse::space`) uses this to cost the granularity axis of
/// the design space; the organization is still the Sec. IV-B heuristic
/// choice and may be overridden by the caller afterwards.
pub fn plan_segment_scaled(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    seg: &Segment,
    gran_scale: u64,
) -> PlannedSegment {
    let gran_scale = gran_scale.max(1);
    let depth = seg.depth;
    let styles: Vec<DataflowStyle> = seg
        .layers()
        .map(|i| choose_dataflow(graph.layer(i)))
        .collect();
    if depth == 1 {
        return PlannedSegment {
            segment: seg.clone(),
            organization: Organization::Sequential,
            pe_alloc: vec![cfg.num_pes()],
            styles,
            handoffs: vec![],
        };
    }
    let macs: Vec<u64> = seg.layers().map(|i| graph.layer(i).macs()).collect();
    let pe_alloc = allocate_pes(&macs, cfg.num_pes());

    // Granularity per adjacent pair (Alg. 1 on the chosen styles).
    let nests: Vec<LoopNest> = seg
        .layers()
        .zip(styles.iter())
        .map(|(i, &st)| LoopNest::for_op(&graph.layer(i).op, st))
        .collect();
    let mut handoffs = Vec::new();
    let mut finest_words = u64::MAX;
    for s in 0..depth - 1 {
        let producer = graph.layer(seg.start + s);
        let total = producer.output_act_words();
        let g = pair_granularity(&nests[s], &nests[s + 1], total);
        let (words, intervals) =
            clamp_granularity(total, g.words.saturating_mul(gran_scale), pe_alloc[s]);
        finest_words = finest_words.min(words);
        handoffs.push(PlannedHandoff {
            from_stage: s,
            to_stage: s + 1,
            words_per_interval: words,
            intervals,
            via_gb: false, // refined below
            is_skip: false,
        });
    }
    // Skip connections absorbed inside the segment become NoC handoffs at
    // the producer's granularity.
    for e in graph.skip_edges() {
        if seg.contains(e.src) && seg.contains(e.dst) {
            let s_from = e.src - seg.start;
            let s_to = e.dst - seg.start;
            let adj = &handoffs[s_from.min(handoffs.len() - 1)];
            let (words, intervals) = (adj.words_per_interval, adj.intervals);
            handoffs.push(PlannedHandoff {
                from_stage: s_from,
                to_stage: s_to,
                words_per_interval: words,
                intervals,
                via_gb: false,
                is_skip: true,
            });
        }
    }

    // Organization from depth + finest granularity (Sec. IV-B).
    let max_producer_pes = *pe_alloc.iter().max().unwrap_or(&1);
    let choice = choose_organization(cfg, depth, finest_words.max(1), max_producer_pes);
    // Any handoff still larger than its producer RF goes through the GB
    // (rare after splitting — only skip handoffs can trip this).
    let rf_words = cfg.rf_total_bytes() / cfg.bytes_per_word as u64;
    for h in handoffs.iter_mut() {
        let producer_rf = rf_words * pe_alloc[h.from_stage] as u64 / cfg.num_pes() as u64;
        h.via_gb = h.words_per_interval > producer_rf.max(1);
    }
    PlannedSegment {
        segment: seg.clone(),
        organization: choice.organization,
        pe_alloc,
        styles,
        handoffs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{SimbaLike, TangramLike};
    use crate::cost::evaluate;
    use crate::workloads;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn plans_validate_on_whole_zoo() {
        for g in workloads::all_tasks() {
            let plan = PipeOrgan::default().plan(&g, &cfg());
            plan.validate(&g, &cfg())
                .unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn interleaved_organizations_appear_on_fine_grained_segments() {
        let g = workloads::eye_segmentation();
        let plan = PipeOrgan::default().plan(&g, &cfg());
        assert!(
            plan.segments
                .iter()
                .any(|s| s.organization.is_interleaved()),
            "expected fine-grained interleaving somewhere in RITNet"
        );
    }

    #[test]
    fn weight_heavy_models_stay_mostly_sequential() {
        let g = workloads::world_locking();
        let plan = PipeOrgan::default().plan(&g, &cfg());
        let seq = plan
            .segments
            .iter()
            .filter(|s| s.organization == Organization::Sequential)
            .count();
        assert!(
            seq as f64 >= plan.segments.len() as f64 * 0.5,
            "{seq}/{} sequential",
            plan.segments.len()
        );
    }

    #[test]
    fn pipeorgan_beats_baselines_on_activation_heavy_tasks() {
        // The Fig. 13 headline shape on the most favorable task.
        let g = workloads::eye_segmentation();
        let c = cfg();
        let po = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c);
        let tg = evaluate(&g, &TangramLike.plan(&g, &c), &c);
        let sb = evaluate(&g, &SimbaLike.plan(&g, &c), &c);
        assert!(
            po.cycles < tg.cycles,
            "pipeorgan {} vs tangram {}",
            po.cycles,
            tg.cycles
        );
        assert!(
            po.cycles < sb.cycles,
            "pipeorgan {} vs simba {}",
            po.cycles,
            sb.cycles
        );
        assert!(po.dram_words <= tg.dram_words);
    }

    #[test]
    fn amp_does_not_hurt_vs_mesh_variant() {
        let g = workloads::gaze_estimation();
        let c = cfg();
        let amp = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c);
        let mesh = evaluate(&g, &PipeOrgan::on_mesh().plan(&g, &c), &c);
        assert!(amp.cycles <= mesh.cycles * 1.0001);
    }

    #[test]
    fn depth_respects_sqrt_pe_cap() {
        for g in workloads::all_tasks() {
            let plan = PipeOrgan::default().plan(&g, &cfg());
            let cap = cfg().max_pipeline_depth();
            assert!(plan.segments.iter().all(|s| s.depth() <= cap));
        }
    }

    #[test]
    fn absorbed_skips_become_skip_handoffs() {
        let g = workloads::synthetic::skip_conv_segment();
        let plan = PipeOrgan::default().plan(&g, &cfg());
        // the depth heuristic should absorb the 1→3 skip in one segment
        let has_skip_handoff = plan
            .segments
            .iter()
            .any(|s| s.handoffs.iter().any(|h| h.is_skip));
        assert!(has_skip_handoff, "{plan:?}");
    }
}
