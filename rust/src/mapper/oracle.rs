//! Oracle (exhaustive) spatial-organization search — the ablation
//! comparator for the Sec. IV-B selection heuristic.
//!
//! The PipeOrgan mapper picks one organization per segment from the
//! RF-vs-granularity rules; the oracle instead *evaluates* every candidate
//! organization with the full cost model and keeps the cheapest. The gap
//! between the two measures how much the closed-form heuristic leaves on
//! the table (reported by `report::ablation_organization`).

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{evaluate_segment, Mapper, MappingPlan, PlannedSegment};
use crate::energy::EnergyModel;
use crate::ir::ModelGraph;
use crate::noc::Topology;
use crate::spatial::Organization;

use super::PipeOrgan;

/// Exhaustive-organization variant of the PipeOrgan mapper.
#[derive(Debug, Clone, Copy)]
pub struct OracleOrganization {
    pub topology: TopologyKind,
}

impl Default for OracleOrganization {
    fn default() -> Self {
        Self {
            topology: TopologyKind::Amp,
        }
    }
}

/// Candidate organizations for a segment of `depth`.
pub fn candidates(depth: usize) -> Vec<Organization> {
    if depth <= 1 {
        return vec![Organization::Sequential];
    }
    let mut v = vec![
        Organization::Blocked1D,
        Organization::FineStriped1D,
    ];
    if depth >= 4 {
        v.push(Organization::Blocked2D);
        v.push(Organization::Checkerboard2D);
    }
    v
}

impl Mapper for OracleOrganization {
    fn name(&self) -> &'static str {
        "oracle_organization"
    }

    fn topology(&self) -> TopologyKind {
        self.topology
    }

    fn plan(&self, graph: &ModelGraph, cfg: &ArchConfig) -> MappingPlan {
        // Start from the heuristic plan (depth, styles, allocation and
        // granularities are shared — only the organization is searched).
        let base = PipeOrgan::on(self.topology).plan(graph, cfg);
        let topo = Topology::new(self.topology, cfg.pe_rows, cfg.pe_cols);
        let em = EnergyModel::default();
        let segments = base
            .segments
            .into_iter()
            .map(|seg| best_organization(graph, cfg, &topo, &em, seg))
            .collect();
        MappingPlan {
            mapper_name: self.name().into(),
            topology: self.topology,
            segments,
        }
    }
}

fn best_organization(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    topo: &Topology,
    em: &EnergyModel,
    mut seg: PlannedSegment,
) -> PlannedSegment {
    let mut best = seg.organization;
    let mut best_cost = f64::INFINITY;
    for org in candidates(seg.depth()) {
        seg.organization = org;
        let c = evaluate_segment(graph, &seg, cfg, topo, em);
        if c.cycles < best_cost {
            best_cost = c.cycles;
            best = org;
        }
    }
    seg.organization = best;
    seg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::evaluate;
    use crate::workloads;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn oracle_never_loses_to_heuristic() {
        // By construction the oracle explores a superset including the
        // heuristic's choice for pipelined segments.
        let c = cfg();
        for g in workloads::all_tasks() {
            let heur = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c).cycles;
            let orac = evaluate(&g, &OracleOrganization::default().plan(&g, &c), &c).cycles;
            assert!(
                orac <= heur * 1.0001,
                "{}: oracle {orac} worse than heuristic {heur}",
                g.name
            );
        }
    }

    #[test]
    fn heuristic_is_close_to_oracle() {
        // The Sec. IV-B rules should capture most of the benefit: within
        // 15% of the exhaustive search in geomean.
        let c = cfg();
        let mut ratios = Vec::new();
        for g in workloads::all_tasks() {
            let heur = evaluate(&g, &PipeOrgan::default().plan(&g, &c), &c).cycles;
            let orac = evaluate(&g, &OracleOrganization::default().plan(&g, &c), &c).cycles;
            ratios.push(heur / orac);
        }
        let gap = crate::util::stats::geomean(&ratios);
        assert!(gap < 1.15, "heuristic/oracle geomean gap = {gap}");
    }

    #[test]
    fn candidates_shape() {
        assert_eq!(candidates(1), vec![Organization::Sequential]);
        assert_eq!(candidates(2).len(), 2);
        assert_eq!(candidates(4).len(), 4);
    }

    #[test]
    fn oracle_plans_validate() {
        let c = cfg();
        for g in workloads::all_tasks() {
            OracleOrganization::default()
                .plan(&g, &c)
                .validate(&g, &c)
                .unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }
}
