//! `PipeOrgan::tuned` — the search-guided production mapper.
//!
//! The paper's central argument (§V, Fig. 16–17) is that the right
//! pipeline depth/granularity/organization is shape-dependent and must be
//! *searched*, not hard-coded; the `report::dse_gap` table quantifies how
//! much the closed-form Sec. IV heuristic leaves on the table. This mapper
//! closes that gap at plan time: it runs a budgeted beam search
//! (`dse::tuned_plan`) over the heuristic mapper's own topology, reusing
//! the `dse::space` enumeration and the memoized — and usually persistent —
//! `dse::EvalCache`, and ships whichever plan is faster.
//!
//! Two properties make it safe as the default planning path:
//!
//! 1. **Never loses.** The heuristic plan seeds the beam and is the
//!    fallback whenever the search cannot strictly improve on it, so
//!    `tuned` is latency-equal-or-better than `PipeOrgan` on every model,
//!    by construction.
//! 2. **Bounded plan time.** The search charges cost-model evaluations
//!    (cache misses) against a budget; once exhausted, enumeration narrows
//!    to the heuristic candidate per segment and the DP completes cheaply.
//!    With a warm [`EvalCache`] (shared across a sweep, or hydrated from a
//!    `--cache-file`), repeated shapes plan at memo-lookup speed.

use std::sync::Arc;

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{Mapper, MappingPlan};
use crate::dse::{tuned_plan, DseConfig, EvalCache, RunCounters};
use crate::ir::ModelGraph;

use super::PipeOrgan;

/// `MappingPlan::mapper_name` of every plan this mapper ships (both the
/// search-improved and the heuristic-fallback branches).
pub const TUNED_MAPPER_NAME: &str = "pipeorgan_tuned";

/// The search-guided PipeOrgan mapper. Construct via
/// [`PipeOrgan::tuned`], [`TunedPipeOrgan::new`] or
/// [`TunedPipeOrgan::on`].
#[derive(Clone)]
pub struct TunedPipeOrgan {
    /// The closed-form mapper searched around (its plan seeds the beam and
    /// is the never-lose fallback); also fixes the topology.
    pub base: PipeOrgan,
    /// Plan-time search knobs (strategy/beam/depth/ladder/budget). The
    /// topology actually searched is always `base.topology`.
    pub search: DseConfig,
    /// Shared memoized segment-cost cache. Pass one cache across a sweep
    /// (and persist it with `EvalCache::save_file`) so repeated shapes
    /// plan warm.
    pub cache: Arc<EvalCache>,
}

impl TunedPipeOrgan {
    /// Tuned mapper on the paper's default AMP topology.
    pub fn new(cache: Arc<EvalCache>) -> Self {
        Self::on(TopologyKind::Amp, cache)
    }

    /// Tuned mapper on an explicit topology.
    pub fn on(topology: TopologyKind, cache: Arc<EvalCache>) -> Self {
        Self {
            base: PipeOrgan::on(topology),
            search: DseConfig::tuned(topology),
            cache,
        }
    }

    /// Override the plan-time evaluation budget (`0` degenerates to the
    /// heuristic-candidates-only search, which still explores segment
    /// boundaries but no alternative organizations/granularities).
    pub fn with_budget(mut self, budget: u64) -> Self {
        self.search.budget = Some(budget);
        self
    }
}

impl Mapper for TunedPipeOrgan {
    fn name(&self) -> &'static str {
        TUNED_MAPPER_NAME
    }

    fn topology(&self) -> TopologyKind {
        self.base.topology
    }

    fn plan(&self, graph: &ModelGraph, cfg: &ArchConfig) -> MappingPlan {
        // A fresh per-plan meter keeps the search budget an exact per-plan
        // window even when a whole sweep shares `self.cache`.
        tuned_plan(
            graph,
            cfg,
            &self.base,
            &self.search,
            &self.cache,
            &RunCounters::new(),
        )
        .plan
    }
}

impl PipeOrgan {
    /// Ship the search-guided variant of this mapper: a plan-time budgeted
    /// beam search over `self`'s topology that can only match or beat the
    /// closed-form plan (see [`TunedPipeOrgan`]).
    pub fn tuned(self, cache: Arc<EvalCache>) -> TunedPipeOrgan {
        TunedPipeOrgan {
            search: DseConfig::tuned(self.topology),
            base: self,
            cache,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::evaluate;
    use crate::workloads;

    fn small_cfg() -> ArchConfig {
        ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        }
    }

    #[test]
    fn tuned_never_loses_to_heuristic() {
        let cfg = small_cfg();
        let cache = Arc::new(EvalCache::new());
        for g in [
            workloads::keyword_detection(),
            workloads::gaze_estimation(),
        ] {
            let heur = evaluate(&g, &PipeOrgan::default().plan(&g, &cfg), &cfg);
            let mapper = PipeOrgan::default().tuned(Arc::clone(&cache));
            let plan = mapper.plan(&g, &cfg);
            plan.validate(&g, &cfg)
                .unwrap_or_else(|e| panic!("{}: {e}", g.name));
            assert_eq!(plan.mapper_name, TUNED_MAPPER_NAME);
            let tuned = evaluate(&g, &plan, &cfg);
            assert!(
                tuned.cycles <= heur.cycles * 1.0001,
                "{}: tuned {} vs heuristic {}",
                g.name,
                tuned.cycles,
                heur.cycles
            );
        }
    }

    #[test]
    fn warm_cache_makes_replanning_free() {
        let cfg = small_cfg();
        let g = workloads::keyword_detection();
        let cache = Arc::new(EvalCache::new());
        // Unbounded budget: a budget-truncated cold search could otherwise
        // legitimately differ from the warm (all-hits) replan.
        let mapper = TunedPipeOrgan::new(Arc::clone(&cache)).with_budget(u64::MAX);
        let first = mapper.plan(&g, &cfg);
        let cold_misses = cache.stats().misses;
        assert!(cold_misses > 0, "cold plan must evaluate candidates");
        let second = mapper.plan(&g, &cfg);
        assert_eq!(
            cache.stats().misses,
            cold_misses,
            "replanning the same shape must be all cache hits"
        );
        assert_eq!(first, second, "tuned planning is deterministic");
    }

    #[test]
    fn zero_budget_still_plans_and_cannot_lose() {
        let cfg = small_cfg();
        let g = workloads::gaze_estimation();
        let mapper = TunedPipeOrgan::new(Arc::new(EvalCache::new())).with_budget(0);
        let plan = mapper.plan(&g, &cfg);
        plan.validate(&g, &cfg).unwrap();
        let heur = evaluate(&g, &PipeOrgan::default().plan(&g, &cfg), &cfg);
        let tuned = evaluate(&g, &plan, &cfg);
        assert!(tuned.cycles <= heur.cycles * 1.0001);
    }

    #[test]
    fn tuned_respects_its_topology() {
        let cfg = small_cfg();
        let g = workloads::keyword_detection();
        let mapper = TunedPipeOrgan::on(TopologyKind::Mesh, Arc::new(EvalCache::new()));
        assert_eq!(mapper.topology(), TopologyKind::Mesh);
        let plan = mapper.plan(&g, &cfg);
        assert_eq!(plan.topology, TopologyKind::Mesh);
    }
}
