//! Memory-hierarchy model: global-buffer occupancy, DRAM access counting
//! (the quantity Fig. 14 reports) and bandwidth stalls.
//!
//! DRAM traffic accounting per execution style:
//! - *op-by-op*: every layer reads its inputs and weights and writes its
//!   output; each skip consumer re-reads the skipped activation.
//! - *pipelined segment `[l, l+D)`*: the segment input is read once, all D
//!   layers' weights are read, the segment output is written once, and skip
//!   activations crossing the boundary round-trip (write at the producer,
//!   read at the consumer); fully-absorbed intermediates and skips never
//!   touch DRAM. If the segment working set exceeds the global buffer the
//!   overflow spills (write + read back).

use crate::config::ArchConfig;
use crate::ir::skips::boundary_skip_act_words;
use crate::ir::{LayerId, ModelGraph};
use crate::pipeline::Segment;

/// DRAM words moved by a segment (read + write).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DramTraffic {
    pub reads: u64,
    pub writes: u64,
}

impl DramTraffic {
    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }
}

/// Words of global buffer a pipelined segment needs resident: the weights
/// of all stages, the in-flight granularity buffers, and the boundary
/// activations (segment input/output slices are streamed — charge two
/// row-slices each).
pub fn segment_working_set_words(
    graph: &ModelGraph,
    seg: &Segment,
    handoff_words: &[u64],
) -> u64 {
    let weights: u64 = seg.layers().map(|i| graph.layer(i).weight_words()).sum();
    let handoffs: u64 = handoff_words.iter().map(|&w| 2 * w).sum(); // double-buffered
    let first = graph.layer(seg.start);
    let last = graph.layer(seg.end() - 1);
    let in_slice = 2 * crate::util::ceil_div(
        first.input_act_words(),
        first.op.output_rows().max(1),
    );
    let out_slice = 2 * crate::util::ceil_div(
        last.output_act_words(),
        last.op.output_rows().max(1),
    );
    weights + handoffs + in_slice + out_slice
}

/// DRAM traffic of one pipelined segment (depth ≥ 1; depth 1 = op-by-op
/// for that layer).
pub fn segment_dram_traffic(
    graph: &ModelGraph,
    seg: &Segment,
    handoff_words: &[u64],
    cfg: &ArchConfig,
) -> DramTraffic {
    let mut t = DramTraffic::default();
    let first = graph.layer(seg.start);
    let last = graph.layer(seg.end() - 1);
    // Segment boundary activations.
    t.reads += first.input_act_words();
    t.writes += last.output_act_words();
    // All weights stream in once.
    for i in seg.layers() {
        t.reads += graph.layer(i).weight_words();
    }
    // Skip activations crossing the segment boundary: the producer's output
    // is written when produced and re-read when consumed.
    let crossing = boundary_skip_act_words(graph, seg.start, seg.depth);
    t.reads += crossing;
    t.writes += crossing;
    // Working-set overflow spills once per overflow word.
    let ws = segment_working_set_words(graph, seg, handoff_words);
    let sram_words = cfg.sram_bytes / cfg.bytes_per_word as u64;
    if ws > sram_words {
        let spill = ws - sram_words;
        t.writes += spill;
        t.reads += spill;
    }
    t
}

/// Op-by-op DRAM traffic of a single layer (including re-reads of skip
/// inputs, which arrive as part of `input_act_words` for multi-input ops).
pub fn layer_dram_traffic(graph: &ModelGraph, id: LayerId, cfg: &ArchConfig) -> DramTraffic {
    let seg = Segment::new(id, 1);
    segment_dram_traffic(graph, &seg, &[], cfg)
}

/// Whole-model op-by-op traffic — the reference DRAM count.
pub fn op_by_op_dram_traffic(graph: &ModelGraph, cfg: &ArchConfig) -> DramTraffic {
    let mut t = DramTraffic::default();
    for i in 0..graph.num_layers() {
        let lt = layer_dram_traffic(graph, i, cfg);
        t.reads += lt.reads;
        t.writes += lt.writes;
    }
    t
}

/// Cycles stalled on DRAM bandwidth for `words` of traffic (Table III
/// bandwidth), assuming perfect overlap within the segment otherwise.
pub fn bandwidth_cycles(words: u64, cfg: &ArchConfig) -> f64 {
    (words * cfg.bytes_per_word as u64) as f64 / cfg.dram_bytes_per_cycle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Layer, Op};
    use crate::workloads::synthetic;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn pipelining_saves_intermediate_traffic() {
        let g = synthetic::equal_conv_segment(4);
        let op_by_op = op_by_op_dram_traffic(&g, &cfg());
        let seg = Segment::new(0, 4);
        let pipe = segment_dram_traffic(&g, &seg, &[64, 64, 64], &cfg());
        assert!(
            pipe.total() < op_by_op.total(),
            "pipe {} >= op-by-op {}",
            pipe.total(),
            op_by_op.total()
        );
        // The savings are exactly the three intermediate tensors' round
        // trips (each written once + read once op-by-op).
        let inter: u64 = (0..3).map(|i| g.layer(i).output_act_words()).sum();
        assert_eq!(op_by_op.total() - pipe.total(), 2 * inter);
    }

    #[test]
    fn crossing_skip_roundtrips() {
        let g = synthetic::skip_conv_segment(); // skip 1→3 inside depth 4
        // Depth 2 segment [0,2): the 1→3 skip crosses out.
        let seg = Segment::new(0, 2);
        let t = segment_dram_traffic(&g, &seg, &[64], &cfg());
        let base_writes = g.layer(1).output_act_words();
        // output write includes the crossing skip's write
        assert_eq!(t.writes, base_writes + g.layer(1).output_act_words());
        // Depth 4 absorbs the skip: writes = only final output.
        let seg4 = Segment::new(0, 4);
        let t4 = segment_dram_traffic(&g, &seg4, &[64, 64, 64], &cfg());
        assert_eq!(t4.writes, g.layer(3).output_act_words());
    }

    #[test]
    fn overflow_spills() {
        // Huge weights force the working set past 1 MB.
        let mut g = crate::ir::ModelGraph::new("big");
        g.add_root(Layer::new("a", Op::gemm(8, 2048, 2048)));
        g.push(Layer::new("b", Op::gemm(8, 2048, 2048)));
        let seg = Segment::new(0, 2);
        let t = segment_dram_traffic(&g, &seg, &[8 * 2048], &cfg());
        let no_spill_reads = g.layer(0).input_act_words()
            + g.layer(0).weight_words()
            + g.layer(1).weight_words();
        assert!(t.reads > no_spill_reads, "expected spill traffic");
    }

    #[test]
    fn bandwidth_cycles_match_table3() {
        // 256 B/cycle: 1 MB takes 4096 cycles.
        assert_eq!(bandwidth_cycles(1 << 20, &cfg()), 4096.0);
    }

    #[test]
    fn op_by_op_equals_sum_of_depth1_segments() {
        let g = synthetic::skip_conv_segment();
        let total = op_by_op_dram_traffic(&g, &cfg());
        let sum: u64 = (0..g.num_layers())
            .map(|i| layer_dram_traffic(&g, i, &cfg()).total())
            .sum();
        assert_eq!(total.total(), sum);
    }

    #[test]
    fn working_set_scales_with_depth() {
        let g = synthetic::equal_conv_segment(4);
        let w2 = segment_working_set_words(&g, &Segment::new(0, 2), &[64]);
        let w4 = segment_working_set_words(&g, &Segment::new(0, 4), &[64, 64, 64]);
        assert!(w4 > w2);
    }
}
