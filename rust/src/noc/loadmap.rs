//! Link-resolved load maps: the spatial view of the channel-load model.
//!
//! [`crate::sim::analyze`] already accumulates words-per-interval on every
//! directed link; this module keeps that dense vector next to its
//! [`Topology`] (instead of reducing it to one scalar) and scales it to
//! *per bottleneck interval* — the Fig. 15 unit the cost model reports as
//! `worst_channel_load_per_interval`.
//!
//! The load-bearing invariant, pinned by tests and re-checked in Python by
//! `tools/trace_check.py`: **`LinkLoadMap::max()` equals the scalar
//! `worst_channel_load_per_interval` bit-exactly.** Both sides divide the
//! same per-link words by the same positive interval count and fold with
//! `f64::max` from `0.0`; division by a positive constant is monotone in
//! IEEE-754, so the max commutes with the scaling.

use std::sync::Arc;

use crate::config::TopologyKind;
use crate::sim::LoadAnalysis;

use super::topology::{Link, Topology};

/// Compass direction of a directed link, from the source PE's viewpoint.
/// Torus wraparound links point in the *travel* direction (a link from
/// column 0 to column `cols-1` carries westward traffic), so heatmap cells
/// show where words actually leave each PE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkDir {
    East,
    West,
    North,
    South,
}

impl LinkDir {
    pub const ALL: [LinkDir; 4] = [LinkDir::East, LinkDir::West, LinkDir::North, LinkDir::South];

    pub fn name(self) -> &'static str {
        match self {
            LinkDir::East => "east",
            LinkDir::West => "west",
            LinkDir::North => "north",
            LinkDir::South => "south",
        }
    }

    pub fn index(self) -> usize {
        match self {
            LinkDir::East => 0,
            LinkDir::West => 1,
            LinkDir::North => 2,
            LinkDir::South => 3,
        }
    }
}

/// Wire class of a link, for per-class counter tracks: `local` mesh
/// neighbors, `express` long links (AMP express and flattened-butterfly
/// spans), `wrap` torus wraparounds.
pub const LINK_CLASSES: [&str; 3] = ["local", "express", "wrap"];

/// Direction a link carries traffic (see [`LinkDir`]). Every topology here
/// links along exactly one axis, so one coordinate delta is nonzero.
pub fn link_dir(topo: &Topology, link: &Link) -> LinkDir {
    let (fr, fc) = topo.coords(link.from);
    let (tr, tc) = topo.coords(link.to);
    let wrap = is_wrap(topo, link);
    if fc != tc {
        match (tc > fc) ^ wrap {
            true => LinkDir::East,
            false => LinkDir::West,
        }
    } else {
        match (tr > fr) ^ wrap {
            true => LinkDir::South,
            false => LinkDir::North,
        }
    }
}

/// Torus wraparound links are the length-1 links whose endpoints sit on
/// opposite edges; no other topology builds such a link.
fn is_wrap(topo: &Topology, link: &Link) -> bool {
    if topo.kind != TopologyKind::Torus {
        return false;
    }
    let (fr, fc) = topo.coords(link.from);
    let (tr, tc) = topo.coords(link.to);
    fr.abs_diff(tr) > 1 || fc.abs_diff(tc) > 1
}

/// Wire class of a link (one of [`LINK_CLASSES`]).
pub fn link_class(topo: &Topology, link: &Link) -> &'static str {
    if is_wrap(topo, link) {
        "wrap"
    } else if link.length > 1 {
        "express"
    } else {
        "local"
    }
}

/// Nearest-rank percentile over the active (nonzero) entries of a load
/// slice; 0 when all idle. Shared by [`LinkLoadMap::percentile`] and the
/// composed-heatmap stats so both report the same distribution.
pub fn percentile_of(loads: &[f64], p: f64) -> f64 {
    let mut active: Vec<f64> = loads.iter().cloned().filter(|&w| w > 0.0).collect();
    if active.is_empty() {
        return 0.0;
    }
    active.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * active.len() as f64).ceil() as usize;
    active[rank.clamp(1, active.len()) - 1]
}

/// Per-link load in words **per bottleneck interval**, dense by `LinkId`,
/// pinned to the topology it was routed on.
#[derive(Debug, Clone)]
pub struct LinkLoadMap {
    topo: Arc<Topology>,
    loads: Vec<f64>,
}

impl LinkLoadMap {
    /// All-zero map over a topology.
    pub fn empty(topo: Arc<Topology>) -> LinkLoadMap {
        let loads = vec![0.0; topo.num_links()];
        LinkLoadMap { topo, loads }
    }

    /// Scale an [`analyze`](crate::sim::analyze) result to per-interval
    /// units. `interval` must be ≥ 1 (callers pass `bottleneck_t.max(1)`),
    /// matching the cost model's `worst_channel_load / bottleneck_t`.
    pub fn from_analysis(topo: Arc<Topology>, load: &LoadAnalysis, interval: f64) -> LinkLoadMap {
        debug_assert_eq!(topo.num_links(), load.per_link_words.len());
        let loads = load.per_link_words.iter().map(|&w| w / interval).collect();
        LinkLoadMap { topo, loads }
    }

    pub fn topology(&self) -> &Arc<Topology> {
        &self.topo
    }

    pub fn loads(&self) -> &[f64] {
        &self.loads
    }

    /// Busiest link's load — bit-exact equal to the cost model's
    /// `worst_channel_load_per_interval` for a map built by
    /// [`crate::cost::segment_loadmap`] (same fold, same scaling).
    pub fn max(&self) -> f64 {
        self.loads.iter().cloned().fold(0.0, f64::max)
    }

    /// Σ over links — per-interval total word-hops (conservation: equals
    /// `total_word_hops / interval` up to summation order).
    pub fn sum(&self) -> f64 {
        self.loads.iter().sum()
    }

    /// Σ over links of load × physical wire length — the per-interval
    /// hop-energy proxy (`total_word_wire / interval` up to order).
    pub fn wire_weighted_sum(&self) -> f64 {
        self.loads
            .iter()
            .zip(self.topo.links())
            .map(|(&w, l)| w * l.length as f64)
            .sum()
    }

    /// Number of links carrying any traffic.
    pub fn active_links(&self) -> usize {
        self.loads.iter().filter(|&&w| w > 0.0).count()
    }

    /// Nearest-rank percentile over the *active* links (0 when idle);
    /// `p` in [0, 100]. Over active links only, so a mostly-idle fabric
    /// doesn't report p95 = 0 while one link melts.
    pub fn percentile(&self, p: f64) -> f64 {
        percentile_of(&self.loads, p)
    }

    /// Element-wise max with another map over the *same* topology — the
    /// spatial analogue of how plan costs fold per-segment
    /// `worst_channel_load_per_interval` with `f64::max`, so a plan map's
    /// [`max`](Self::max) still equals the plan's scalar bit-exactly.
    pub fn merge_max(&mut self, other: &LinkLoadMap) -> Result<(), String> {
        let (a, b) = (&self.topo, &other.topo);
        if a.kind != b.kind || a.rows != b.rows || a.cols != b.cols {
            return Err(format!(
                "merge_max across topologies: {:?} {}x{} vs {:?} {}x{}",
                a.kind, a.rows, a.cols, b.kind, b.rows, b.cols
            ));
        }
        for (dst, &src) in self.loads.iter_mut().zip(&other.loads) {
            *dst = dst.max(src);
        }
        Ok(())
    }

    /// Return a copy with every load scaled (serve uses busy fractions to
    /// window a region's map in time). Scaling by exactly `1.0` is the
    /// IEEE identity, so unscaled windows stay bit-exact.
    pub fn scaled(&self, factor: f64) -> LinkLoadMap {
        LinkLoadMap {
            topo: Arc::clone(&self.topo),
            loads: self.loads.iter().map(|&w| w * factor).collect(),
        }
    }

    /// Total load per wire class, ordered as [`LINK_CLASSES`].
    pub fn class_totals(&self) -> [(&'static str, f64); 3] {
        let mut totals = [0.0f64; 3];
        for (w, link) in self.loads.iter().zip(self.topo.links()) {
            let class = link_class(&self.topo, link);
            let slot = LINK_CLASSES.iter().position(|&c| c == class).unwrap();
            totals[slot] += w;
        }
        [
            (LINK_CLASSES[0], totals[0]),
            (LINK_CLASSES[1], totals[1]),
            (LINK_CLASSES[2], totals[2]),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::analyze;
    use crate::traffic::{derive_flows, scenarios};

    fn map_for(kind: TopologyKind) -> LinkLoadMap {
        let topo = Topology::cached(kind, 32, 32);
        let s = scenarios::fig8_depth2_blocked(32, 32);
        let flows = derive_flows(&topo, &s.placement, &s.handoffs);
        let load = analyze(&topo, &flows);
        LinkLoadMap::from_analysis(Arc::clone(&topo), &load, 2.0)
    }

    #[test]
    fn max_is_scaled_worst_channel_load() {
        for kind in [
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::Torus,
            TopologyKind::FlattenedButterfly,
        ] {
            let topo = Topology::cached(kind, 32, 32);
            let s = scenarios::fig8_depth2_blocked(32, 32);
            let flows = derive_flows(&topo, &s.placement, &s.handoffs);
            let load = analyze(&topo, &flows);
            for t in [1u64, 2, 7, 640] {
                let map = LinkLoadMap::from_analysis(Arc::clone(&topo), &load, t as f64);
                assert_eq!(
                    map.max(),
                    load.worst_channel_load / t as f64,
                    "{kind:?} t={t}"
                );
            }
        }
    }

    #[test]
    fn sum_conserves_word_hops() {
        let topo = Topology::cached(TopologyKind::Mesh, 32, 32);
        let s = scenarios::fig8_depth2_blocked(32, 32);
        let flows = derive_flows(&topo, &s.placement, &s.handoffs);
        let load = analyze(&topo, &flows);
        let map = LinkLoadMap::from_analysis(Arc::clone(&topo), &load, 1.0);
        assert!((map.sum() - load.total_word_hops).abs() < 1e-6);
        assert!((map.wire_weighted_sum() - load.total_word_wire).abs() < 1e-6);
        assert_eq!(map.active_links(), load.active_links());
    }

    #[test]
    fn merge_max_matches_scalar_fold() {
        let a = map_for(TopologyKind::Mesh);
        let b = a.scaled(0.5);
        let mut merged = b.clone();
        merged.merge_max(&a).unwrap();
        assert_eq!(merged.max(), a.max().max(b.max()));
        // Mismatched topologies refuse to merge.
        let mut amp = map_for(TopologyKind::Amp);
        assert!(amp.merge_max(&a).is_err());
    }

    #[test]
    fn percentiles_are_ordered_and_max_agrees() {
        let map = map_for(TopologyKind::Mesh);
        let (p50, p95, max) = (map.percentile(50.0), map.percentile(95.0), map.max());
        assert!(p50 <= p95 && p95 <= max, "{p50} {p95} {max}");
        assert_eq!(map.percentile(100.0), max);
        let idle = LinkLoadMap::empty(Topology::cached(TopologyKind::Mesh, 4, 4));
        assert_eq!(idle.percentile(95.0), 0.0);
        assert_eq!(idle.max(), 0.0);
    }

    #[test]
    fn directions_cover_mesh_and_wraps_invert() {
        let topo = Topology::cached(TopologyKind::Mesh, 4, 4);
        let east = topo.link_between(topo.node(1, 1), topo.node(1, 2)).unwrap();
        let north = topo.link_between(topo.node(2, 1), topo.node(1, 1)).unwrap();
        assert_eq!(link_dir(&topo, &topo.link(east)), LinkDir::East);
        assert_eq!(link_dir(&topo, &topo.link(north)), LinkDir::North);
        let torus = Topology::cached(TopologyKind::Torus, 4, 4);
        // col 0 → col 3 wraps westward.
        let wrap = torus
            .link_between(torus.node(1, 0), torus.node(1, 3))
            .unwrap();
        assert_eq!(link_dir(&torus, &torus.link(wrap)), LinkDir::West);
        assert_eq!(link_class(&torus, &torus.link(wrap)), "wrap");
    }

    #[test]
    fn classes_split_local_express_wrap() {
        let amp = Topology::cached(TopologyKind::Amp, 32, 32);
        let classes: Vec<&str> = amp
            .links()
            .iter()
            .map(|l| link_class(&amp, l))
            .collect();
        assert!(classes.contains(&"local") && classes.contains(&"express"));
        assert!(!classes.contains(&"wrap"));
        let map = map_for(TopologyKind::Amp);
        let totals = map.class_totals();
        let total: f64 = totals.iter().map(|(_, w)| w).sum();
        assert!((total - map.sum()).abs() < 1e-6);
        assert!(totals[1].1 > 0.0, "express links should carry load on AMP");
    }
}
