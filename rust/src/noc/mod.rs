//! On-chip network model (Sec. IV-C/D): topologies (mesh, torus, flattened
//! butterfly, and the proposed AMP), link enumeration and routing.
//!
//! Links are directed and indexed densely so traffic analysis can
//! accumulate per-link channel load in a flat array.

mod loadmap;
mod routing;
mod topology;
mod verify;

pub use loadmap::{link_class, link_dir, percentile_of, LinkDir, LinkLoadMap, LINK_CLASSES};
pub use routing::{route, route_into, route_wire_length};
pub use topology::{amp_express_len, Link, LinkId, NodeId, Topology};
pub use verify::{congestion_threshold, verify, verify_loads, CongestionVerdict};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;

    #[test]
    fn link_count_complexities() {
        // Paper: AMP increases links < 2× over mesh; flattened butterfly is
        // O(N log N)-ish and much larger.
        let mesh = Topology::new(TopologyKind::Mesh, 32, 32);
        let amp = Topology::new(TopologyKind::Amp, 32, 32);
        let fb = Topology::new(TopologyKind::FlattenedButterfly, 32, 32);
        let m = mesh.num_links() as f64;
        let a = amp.num_links() as f64;
        let f = fb.num_links() as f64;
        assert!(a / m < 2.0, "AMP/mesh = {}", a / m);
        assert!(a / m > 1.5, "AMP should add many express links: {}", a / m);
        assert!(f / m > 10.0, "FB should be an overkill: {}", f / m);
    }
}
