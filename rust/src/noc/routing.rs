//! Deterministic routing per topology.
//!
//! - Mesh: dimension-ordered XY (X first, then Y) — the standard
//!   congestion-analyzable baseline the paper's figures assume.
//! - Torus: XY with wraparound, taking the shorter direction per dimension.
//! - AMP: greedy express-first XY — take length-`L` express hops while the
//!   remaining distance in the dimension is ≥ `L`, finish with single hops.
//! - Flattened butterfly: at most one row hop plus one column hop.

use crate::config::TopologyKind;

use super::topology::{LinkId, NodeId, Topology};

/// Compute the link sequence from `src` to `dst`. Returns an empty route
/// when `src == dst`.
pub fn route(topo: &Topology, src: NodeId, dst: NodeId) -> Vec<LinkId> {
    let mut out = Vec::new();
    route_into(topo, src, dst, &mut out);
    out
}

/// Like [`route`] but appends into a caller-provided buffer (hot path —
/// avoids an allocation per flow).
pub fn route_into(topo: &Topology, src: NodeId, dst: NodeId, out: &mut Vec<LinkId>) {
    if src == dst {
        return;
    }
    match topo.kind {
        TopologyKind::Mesh => xy_route(topo, src, dst, 1, out),
        TopologyKind::Amp => xy_route(topo, src, dst, topo.express_len.max(1), out),
        TopologyKind::Torus => torus_route(topo, src, dst, out),
        TopologyKind::FlattenedButterfly => fb_route(topo, src, dst, out),
    }
}

#[inline]
fn push_link(topo: &Topology, from: NodeId, to: NodeId, out: &mut Vec<LinkId>) {
    let id = topo
        .link_between(from, to)
        .unwrap_or_else(|| panic!("missing link {from}→{to} on {:?}", topo.kind));
    out.push(id);
}

/// Dimension-ordered X-then-Y routing with greedy express hops of length
/// `l` (l = 1 degrades to plain mesh XY).
fn xy_route(topo: &Topology, src: NodeId, dst: NodeId, l: usize, out: &mut Vec<LinkId>) {
    let (mut r, mut c) = topo.coords(src);
    let (dr, dc) = topo.coords(dst);
    // X dimension (columns) first.
    while c != dc {
        let dist = c.abs_diff(dc);
        let step = if l > 1 && dist >= l { l } else { 1 };
        let next_c = if dc > c { c + step } else { c - step };
        push_link(topo, topo.node(r, c), topo.node(r, next_c), out);
        c = next_c;
    }
    // Then Y (rows).
    while r != dr {
        let dist = r.abs_diff(dr);
        let step = if l > 1 && dist >= l { l } else { 1 };
        let next_r = if dr > r { r + step } else { r - step };
        push_link(topo, topo.node(r, c), topo.node(next_r, c), out);
        r = next_r;
    }
}

/// Torus XY: per dimension choose the direction with fewer hops, using the
/// wraparound link when that is shorter.
fn torus_route(topo: &Topology, src: NodeId, dst: NodeId, out: &mut Vec<LinkId>) {
    let (mut r, mut c) = topo.coords(src);
    let (dr, dc) = topo.coords(dst);
    let (rows, cols) = (topo.rows, topo.cols);
    while c != dc {
        let fwd = (dc + cols - c) % cols; // hops going +1 with wraparound
        let next_c = if fwd <= cols - fwd {
            (c + 1) % cols
        } else {
            (c + cols - 1) % cols
        };
        push_link(topo, topo.node(r, c), topo.node(r, next_c), out);
        c = next_c;
    }
    while r != dr {
        let fwd = (dr + rows - r) % rows;
        let next_r = if fwd <= rows - fwd {
            (r + 1) % rows
        } else {
            (r + rows - 1) % rows
        };
        push_link(topo, topo.node(r, c), topo.node(next_r, c), out);
        r = next_r;
    }
}

/// Flattened butterfly: one direct row link then one direct column link.
fn fb_route(topo: &Topology, src: NodeId, dst: NodeId, out: &mut Vec<LinkId>) {
    let (r, c) = topo.coords(src);
    let (dr, dc) = topo.coords(dst);
    let mut cur = src;
    if c != dc {
        let mid = topo.node(r, dc);
        push_link(topo, cur, mid, out);
        cur = mid;
    }
    if r != dr {
        push_link(topo, cur, topo.node(dr, dc), out);
    }
}

/// Total Manhattan-equivalent wire length of a route (Σ link lengths).
pub fn route_wire_length(topo: &Topology, links: &[LinkId]) -> u64 {
    links.iter().map(|&l| topo.link(l).length as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::util::rng::SplitMix64;

    fn check_route_valid(topo: &Topology, src: NodeId, dst: NodeId) {
        let r = route(topo, src, dst);
        // Route is connected and ends at dst.
        let mut cur = src;
        for lid in &r {
            let link = topo.link(*lid);
            assert_eq!(link.from, cur, "route not connected");
            cur = link.to;
        }
        assert_eq!(cur, dst, "route does not reach destination");
    }

    #[test]
    fn mesh_xy_hop_count_is_manhattan() {
        let t = Topology::new(TopologyKind::Mesh, 8, 8);
        let r = route(&t, t.node(1, 1), t.node(5, 6));
        assert_eq!(r.len(), 4 + 5);
        check_route_valid(&t, t.node(1, 1), t.node(5, 6));
    }

    #[test]
    fn amp_uses_express_links() {
        let t = Topology::new(TopologyKind::Amp, 32, 32);
        assert_eq!(t.express_len, 4);
        // 0 → 16 along a row: 4 express hops instead of 16 singles.
        let r = route(&t, t.node(0, 0), t.node(0, 16));
        assert_eq!(r.len(), 4);
        assert!(r.iter().all(|&l| t.link(l).length == 4));
        // Distance 6: one express (4) + 2 singles.
        let r = route(&t, t.node(0, 0), t.node(0, 6));
        assert_eq!(r.len(), 3);
        check_route_valid(&t, t.node(0, 0), t.node(0, 6));
    }

    #[test]
    fn amp_hop_reduction_vs_mesh() {
        // Paper Fig. 12b: AMP reduces both hops and congestion for blocked
        // organizations. Mean hop count over row-crossing pairs must drop.
        let mesh = Topology::new(TopologyKind::Mesh, 32, 32);
        let amp = Topology::new(TopologyKind::Amp, 32, 32);
        let mut mesh_hops = 0usize;
        let mut amp_hops = 0usize;
        for c in 0..16 {
            let (s, d) = (mesh.node(7, c), mesh.node(7, c + 16));
            mesh_hops += route(&mesh, s, d).len();
            amp_hops += route(&amp, s, d).len();
        }
        assert!(
            (amp_hops as f64) < mesh_hops as f64 / 2.5,
            "amp {amp_hops} mesh {mesh_hops}"
        );
    }

    #[test]
    fn torus_wraps_shorter_way() {
        let t = Topology::new(TopologyKind::Torus, 8, 8);
        let r = route(&t, t.node(0, 0), t.node(0, 7));
        assert_eq!(r.len(), 1); // wraparound
        let r = route(&t, t.node(0, 0), t.node(0, 3));
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn fb_routes_in_two_hops() {
        let t = Topology::new(TopologyKind::FlattenedButterfly, 8, 8);
        let r = route(&t, t.node(1, 2), t.node(6, 7));
        assert_eq!(r.len(), 2);
        let r = route(&t, t.node(1, 2), t.node(1, 7));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn self_route_is_empty() {
        for kind in [
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::Torus,
            TopologyKind::FlattenedButterfly,
        ] {
            let t = Topology::new(kind, 8, 8);
            assert!(route(&t, t.node(3, 3), t.node(3, 3)).is_empty());
        }
    }

    #[test]
    fn property_routes_always_reach_destination() {
        // proptest-lite invariant: routing terminates at dst on every
        // topology for random pairs.
        crate::util::proptest_lite::run(300, |rng: &mut SplitMix64| {
            let kind = *rng.choose(&[
                TopologyKind::Mesh,
                TopologyKind::Amp,
                TopologyKind::Torus,
                TopologyKind::FlattenedButterfly,
            ]);
            let rows = rng.gen_usize(2, 33);
            let cols = rng.gen_usize(2, 33);
            let t = Topology::new(kind, rows, cols);
            let src = rng.gen_usize(0, rows * cols) as NodeId;
            let dst = rng.gen_usize(0, rows * cols) as NodeId;
            let r = route(&t, src, dst);
            let mut cur = src;
            for lid in &r {
                let link = t.link(*lid);
                crate::prop_assert!(link.from == cur, "disconnected at {cur}");
                cur = link.to;
            }
            crate::prop_assert!(cur == dst, "ended at {cur}, wanted {dst}");
            // mesh-family routes are minimal in wire length
            if kind == TopologyKind::Mesh {
                let (sr, sc) = t.coords(src);
                let (dr, dc) = t.coords(dst);
                crate::prop_assert!(
                    r.len() == sr.abs_diff(dr) + sc.abs_diff(dc),
                    "mesh route not minimal"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn amp_route_wire_length_matches_manhattan() {
        // Express hops cover distance L: total wire length equals the
        // Manhattan distance even when hop count shrinks.
        let t = Topology::new(TopologyKind::Amp, 32, 32);
        let r = route(&t, t.node(2, 3), t.node(20, 29));
        assert_eq!(route_wire_length(&t, &r), 18 + 26);
    }
}
