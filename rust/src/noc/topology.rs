//! Topology construction: nodes, directed links, adjacency.
//!
//! Perf note (DESIGN.md §Perf): link lookup is a linear scan of the
//! per-node outgoing adjacency list instead of a hash map — out-degree is
//! ≤ 8 for mesh/AMP (≤ 2·(rows+cols) for flattened butterfly), and the scan
//! is both faster per lookup and much faster to construct.

use crate::config::TopologyKind;

/// Node id: `r * cols + c`.
pub type NodeId = u32;
/// Dense link index into [`Topology::links`].
pub type LinkId = u32;

/// A directed physical link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Link {
    pub from: NodeId,
    pub to: NodeId,
    /// Manhattan length in PE pitches (1 for mesh neighbors, `L` for AMP
    /// express links, arbitrary for flattened butterfly).
    pub length: u32,
}

/// AMP express-link length for an array with `rows` rows (Sec. IV-D):
/// `round(√(rows/2))` — the geometric mean of the 1-hop and rows/2-hop
/// cases — rounded up to the next power of two so links tile the array
/// evenly (4 for 32×32, 8 for 64×64, matching the paper's examples).
pub fn amp_express_len(rows: usize) -> usize {
    let raw = ((rows as f64) / 2.0).sqrt();
    let mut l = 1usize;
    while (l as f64) < raw {
        l *= 2;
    }
    l.max(2)
}

/// A concrete NoC instance.
#[derive(Debug, Clone)]
pub struct Topology {
    pub kind: TopologyKind,
    pub rows: usize,
    pub cols: usize,
    links: Vec<Link>,
    /// Outgoing (to, link id) per node — linear-scanned for lookups.
    out: Vec<Vec<(NodeId, LinkId)>>,
    /// AMP express-link length (0 for other topologies).
    pub express_len: usize,
}

impl Topology {
    /// Shared, memoized instance — plan evaluation builds the same handful
    /// of topologies thousands of times during sweeps (§Perf opt. 2).
    pub fn cached(kind: TopologyKind, rows: usize, cols: usize) -> std::sync::Arc<Topology> {
        use once_cell::sync::Lazy;
        use std::collections::HashMap;
        use std::sync::{Arc, Mutex};
        static CACHE: Lazy<Mutex<HashMap<(TopologyKind, usize, usize), Arc<Topology>>>> =
            Lazy::new(|| Mutex::new(HashMap::new()));
        let mut cache = CACHE.lock().unwrap();
        Arc::clone(
            cache
                .entry((kind, rows, cols))
                .or_insert_with(|| Arc::new(Topology::new(kind, rows, cols))),
        )
    }

    pub fn new(kind: TopologyKind, rows: usize, cols: usize) -> Topology {
        let mut t = Topology {
            kind,
            rows,
            cols,
            links: Vec::new(),
            out: vec![Vec::new(); rows * cols],
            express_len: if kind == TopologyKind::Amp {
                amp_express_len(rows)
            } else {
                0
            },
        };
        t.build();
        t
    }

    #[inline]
    pub fn node(&self, r: usize, c: usize) -> NodeId {
        (r * self.cols + c) as NodeId
    }

    #[inline]
    pub fn coords(&self, n: NodeId) -> (usize, usize) {
        let n = n as usize;
        (n / self.cols, n % self.cols)
    }

    pub fn num_nodes(&self) -> usize {
        self.rows * self.cols
    }

    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    pub fn link(&self, id: LinkId) -> Link {
        self.links[id as usize]
    }

    /// Link id between adjacent endpoints, if a physical link exists.
    #[inline]
    pub fn link_between(&self, from: NodeId, to: NodeId) -> Option<LinkId> {
        self.out[from as usize]
            .iter()
            .find(|&&(t, _)| t == to)
            .map(|&(_, id)| id)
    }

    /// Outgoing (neighbor, link id) pairs of a node.
    pub fn outgoing(&self, n: NodeId) -> &[(NodeId, LinkId)] {
        &self.out[n as usize]
    }

    fn add_link(&mut self, from: NodeId, to: NodeId, length: u32) {
        if self.link_between(from, to).is_some() {
            return;
        }
        let id = self.links.len() as LinkId;
        self.links.push(Link { from, to, length });
        self.out[from as usize].push((to, id));
    }

    fn build(&mut self) {
        let (rows, cols) = (self.rows, self.cols);
        // Base mesh neighbors (all kinds except FB use them; FB links rows
        // and columns all-to-all which subsumes neighbors).
        let mesh_base = !matches!(self.kind, TopologyKind::FlattenedButterfly);
        if mesh_base {
            for r in 0..rows {
                for c in 0..cols {
                    let n = self.node(r, c);
                    if c + 1 < cols {
                        let e = self.node(r, c + 1);
                        self.add_link(n, e, 1);
                        self.add_link(e, n, 1);
                    }
                    if r + 1 < rows {
                        let s = self.node(r + 1, c);
                        self.add_link(n, s, 1);
                        self.add_link(s, n, 1);
                    }
                }
            }
        }
        match self.kind {
            TopologyKind::Mesh => {}
            TopologyKind::Torus => {
                for r in 0..rows {
                    let a = self.node(r, 0);
                    let b = self.node(r, cols - 1);
                    self.add_link(a, b, 1);
                    self.add_link(b, a, 1);
                }
                for c in 0..cols {
                    let a = self.node(0, c);
                    let b = self.node(rows - 1, c);
                    self.add_link(a, b, 1);
                    self.add_link(b, a, 1);
                }
            }
            TopologyKind::Amp => {
                // Express links of length L in each direction at every PE
                // where they fit (Sec. IV-D, Fig. 12a).
                let l = self.express_len;
                for r in 0..rows {
                    for c in 0..cols {
                        let n = self.node(r, c);
                        if c + l < cols {
                            let e = self.node(r, c + l);
                            self.add_link(n, e, l as u32);
                            self.add_link(e, n, l as u32);
                        }
                        if r + l < rows {
                            let s = self.node(r + l, c);
                            self.add_link(n, s, l as u32);
                            self.add_link(s, n, l as u32);
                        }
                    }
                }
            }
            TopologyKind::FlattenedButterfly => {
                // All-to-all within each row and each column.
                for r in 0..rows {
                    for c1 in 0..cols {
                        for c2 in 0..cols {
                            if c1 != c2 {
                                let a = self.node(r, c1);
                                let b = self.node(r, c2);
                                self.add_link(a, b, c1.abs_diff(c2) as u32);
                            }
                        }
                    }
                }
                for c in 0..cols {
                    for r1 in 0..rows {
                        for r2 in 0..rows {
                            if r1 != r2 {
                                let a = self.node(r1, c);
                                let b = self.node(r2, c);
                                self.add_link(a, b, r1.abs_diff(r2) as u32);
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amp_express_lengths_match_paper() {
        assert_eq!(amp_express_len(32), 4); // "spans 4 PEs for 32×32"
        assert_eq!(amp_express_len(64), 8); // "8 PEs for a 64×64"
        assert_eq!(amp_express_len(8), 2);
        assert_eq!(amp_express_len(16), 4);
    }

    #[test]
    fn mesh_link_count() {
        // Directed: 2 * (rows*(cols-1) + cols*(rows-1))
        let t = Topology::new(crate::config::TopologyKind::Mesh, 4, 4);
        assert_eq!(t.num_links(), 2 * (4 * 3 + 4 * 3));
    }

    #[test]
    fn torus_adds_wraparound() {
        let t = Topology::new(crate::config::TopologyKind::Torus, 4, 4);
        let mesh = Topology::new(crate::config::TopologyKind::Mesh, 4, 4);
        assert_eq!(t.num_links(), mesh.num_links() + 2 * (4 + 4));
        assert!(t.link_between(t.node(0, 0), t.node(0, 3)).is_some());
    }

    #[test]
    fn amp_links_exist_and_have_length() {
        let t = Topology::new(crate::config::TopologyKind::Amp, 8, 8);
        assert_eq!(t.express_len, 2);
        let id = t.link_between(t.node(0, 0), t.node(0, 2)).unwrap();
        assert_eq!(t.link(id).length, 2);
        // no express link off the edge
        assert!(t.link_between(t.node(0, 7), t.node(0, 9)).is_none());
    }

    #[test]
    fn fb_has_direct_row_links() {
        let t = Topology::new(crate::config::TopologyKind::FlattenedButterfly, 4, 4);
        assert!(t
            .link_between(t.node(2, 0), t.node(2, 3))
            .is_some());
        assert!(t
            .link_between(t.node(0, 1), t.node(3, 1))
            .is_some());
        // but no diagonal shortcut
        assert!(t.link_between(t.node(0, 0), t.node(1, 1)).is_none());
    }

    #[test]
    fn outgoing_degree_mesh_interior() {
        let t = Topology::new(crate::config::TopologyKind::Mesh, 4, 4);
        assert_eq!(t.outgoing(t.node(1, 1)).len(), 4);
        assert_eq!(t.outgoing(t.node(0, 0)).len(), 2);
    }

    #[test]
    fn coords_roundtrip() {
        let t = Topology::new(crate::config::TopologyKind::Mesh, 5, 7);
        for r in 0..5 {
            for c in 0..7 {
                assert_eq!(t.coords(t.node(r, c)), (r, c));
            }
        }
    }
}
