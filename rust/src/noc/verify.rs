//! Congestion-free verification (the paper's headline claim, Sec. IV-C):
//! a link is saturated when its per-interval load cannot drain within the
//! bottleneck compute interval at `link_words_per_cycle`; a plan is
//! congestion-free when no link is.
//!
//! [`verify`] classifies every link of a [`LinkLoadMap`] against a
//! capacity threshold in the same words-per-interval unit and reports the
//! saturated-link count plus the p50/p95/max load distribution — the
//! spatial refinement of `SegmentCost::noc_bound()`.

use super::loadmap::LinkLoadMap;

/// Link capacity in words per interval: what the NoC can drain during one
/// bottleneck compute interval. Loads above this congest (the Fig. 15
/// condition `worst_load / link_bw > compute_interval`, rearranged).
pub fn congestion_threshold(bottleneck_compute_interval: f64, link_words_per_cycle: f64) -> f64 {
    bottleneck_compute_interval * link_words_per_cycle
}

/// Verdict of [`verify`]: the load distribution and the saturated count.
#[derive(Debug, Clone, PartialEq)]
pub struct CongestionVerdict {
    /// Capacity threshold the links were classified against
    /// (words per interval).
    pub threshold: f64,
    pub total_links: usize,
    pub active_links: usize,
    /// Links with load strictly above the threshold.
    pub saturated: usize,
    /// Nearest-rank percentiles over active links.
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
    /// No saturated link anywhere.
    pub congestion_free: bool,
}

impl CongestionVerdict {
    /// Worst link's utilization of the threshold (>1 means congested);
    /// infinite when the threshold is zero but traffic exists.
    pub fn utilization(&self) -> f64 {
        if self.threshold > 0.0 {
            self.max / self.threshold
        } else if self.max > 0.0 {
            f64::INFINITY
        } else {
            0.0
        }
    }
}

/// Classify every link of `map` against `threshold` words per interval.
pub fn verify(map: &LinkLoadMap, threshold: f64) -> CongestionVerdict {
    verify_loads(map.loads(), threshold)
}

/// Slice form of [`verify`], for composed heatmaps whose regions sit on
/// different topologies (the concatenated per-link loads still form one
/// distribution; the fold-max stays bit-exact).
pub fn verify_loads(loads: &[f64], threshold: f64) -> CongestionVerdict {
    let saturated = loads.iter().filter(|&&w| w > threshold).count();
    CongestionVerdict {
        threshold,
        total_links: loads.len(),
        active_links: loads.iter().filter(|&&w| w > 0.0).count(),
        saturated,
        p50: super::loadmap::percentile_of(loads, 50.0),
        p95: super::loadmap::percentile_of(loads, 95.0),
        max: loads.iter().cloned().fold(0.0, f64::max),
        congestion_free: saturated == 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::noc::Topology;
    use crate::sim::analyze;
    use crate::traffic::{derive_flows, scenarios};
    use std::sync::Arc;

    fn blocked_map(kind: TopologyKind) -> LinkLoadMap {
        let topo = Topology::cached(kind, 32, 32);
        let s = scenarios::fig8_depth2_blocked(32, 32);
        let flows = derive_flows(&topo, &s.placement, &s.handoffs);
        let load = analyze(&topo, &flows);
        LinkLoadMap::from_analysis(Arc::clone(&topo), &load, 1.0)
    }

    #[test]
    fn blocked_mesh_congests_striped_does_not() {
        // Fig. 8 vs Fig. 10: the blocked layout saturates boundary links at
        // a 2-cycle interval, the striped one stays below one word/interval.
        let topo = Topology::cached(TopologyKind::Mesh, 32, 32);
        let thresh = congestion_threshold(2.0, 1.0);
        let blocked = verify(&blocked_map(TopologyKind::Mesh), thresh);
        assert!(!blocked.congestion_free);
        assert!(blocked.saturated > 0 && blocked.saturated < blocked.total_links);
        assert!(blocked.utilization() > 1.0);

        let s = scenarios::fig10_striped(32, 32);
        let flows = derive_flows(&topo, &s.placement, &s.handoffs);
        let load = analyze(&topo, &flows);
        let striped = LinkLoadMap::from_analysis(Arc::clone(&topo), &load, 1.0);
        let v = verify(&striped, thresh);
        assert!(v.congestion_free, "striped saturated {} links", v.saturated);
        assert!(v.utilization() <= 1.0);
    }

    #[test]
    fn amp_reduces_saturation_vs_mesh() {
        let thresh = congestion_threshold(2.0, 1.0);
        let mesh = verify(&blocked_map(TopologyKind::Mesh), thresh);
        let amp = verify(&blocked_map(TopologyKind::Amp), thresh);
        assert!(amp.max < mesh.max, "amp {} mesh {}", amp.max, mesh.max);
        assert!(amp.saturated <= mesh.saturated);
    }

    #[test]
    fn verdict_distribution_is_consistent() {
        let v = verify(&blocked_map(TopologyKind::Mesh), 1.0);
        assert!(v.p50 <= v.p95 && v.p95 <= v.max);
        assert!(v.active_links <= v.total_links);
        assert!(v.saturated <= v.active_links, "idle links never saturate");
        let idle = LinkLoadMap::empty(Topology::cached(TopologyKind::Mesh, 4, 4));
        let vi = verify(&idle, 0.0);
        assert!(vi.congestion_free);
        assert_eq!(vi.utilization(), 0.0);
    }
}
