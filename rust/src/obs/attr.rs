//! Critical-path latency attribution over per-request lifecycle records.
//!
//! The serve event loop ([`crate::serve::engine`]) emits one
//! [`RequestAttr`] per finished (or dropped) request, decomposing its
//! measured end-to-end latency into the causal components the paper
//! argues about: queue wait, bandwidth-independent compute floor,
//! DRAM-contention stretch at the plan's static bandwidth share, and
//! the donation received back when the dynamic model granted the
//! region more than its entitlement. This module aggregates those
//! records into windowed bottleneck attribution (what fraction of
//! p50/p99 latency each component explains per time bucket), per-task
//! and per-region rollups, an SLO burn-rate monitor over a sliding
//! window, and the top-k worst requests with their critical paths.
//!
//! # Conservation, bit-exactly
//!
//! The engine derives the components in one canonical order:
//!
//! ```text
//! latency  = now − arrival                (measured, end to end)
//! queue    = start − arrival
//! floor    = floor_cycles / clock         (plan compute floor)
//! stretch  = (nominal − floor_cycles) / clock   (predicted DRAM stretch)
//! donation = stretch − ((latency − queue) − floor)
//! ```
//!
//! so `donation` is *defined* as whatever closes the books: the gap
//! between the predicted DRAM stretch and the stretch actually
//! observed. [`RequestAttr::residual_s`] replays exactly those
//! operations — `(((latency − queue) − floor) − stretch) + donation` —
//! and because IEEE-754 rounding is sign-symmetric the residual is
//! exactly `0.0` for every finite record, not merely small. The naïve
//! check `queue + floor + stretch − donation == latency` is **not**
//! float-guaranteed; tests and `tools/trace_check.py` assert the
//! canonical form.

use crate::util::json::Json;

/// Latency buckets per run used by the report layer when it windows a
/// serve outcome (`span / DEFAULT_WINDOWS` seconds per bucket).
pub const DEFAULT_WINDOWS: usize = 8;

/// Default SLO miss budget (fraction of requests allowed to miss their
/// deadline) that the burn-rate monitor normalizes against: burn rate
/// 1.0 means the window is missing at exactly the budgeted rate.
pub const DEFAULT_SLO_BUDGET: f64 = 0.01;

/// How a request's lifecycle ended.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttrOutcome {
    /// Served to completion; `missed` records whether it finished past
    /// its deadline (+ the engine's epsilon).
    Completed { missed: bool },
    /// Dropped by the dispatch policy (hopeless/doomed pruning); the
    /// whole lifetime is queue wait and the miss is a policy artifact.
    Dropped,
}

/// One request's causal latency decomposition, recorded by the serve
/// event loop at completion (or drop) time.
///
/// All `_s` fields are seconds. For completed requests the invariant
/// `queue + floor + stretch − donation == latency` holds bit-exactly
/// in the canonical evaluation order of [`residual_s`]; for drops the
/// compute components are zero and `latency == queue` (time spent
/// waiting before the policy gave up).
///
/// [`residual_s`]: RequestAttr::residual_s
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestAttr {
    /// Task index in the scenario.
    pub task: usize,
    /// Per-task request sequence number (matches trace/arrival ids).
    pub id: u64,
    /// Region that served (or dropped) the request.
    pub region: usize,
    /// Arrival time.
    pub arrival_s: f64,
    /// Measured end-to-end latency (completion − arrival, or for drops
    /// the time waited before being dropped).
    pub latency_s: f64,
    /// Queue wait (dispatch − arrival).
    pub queue_s: f64,
    /// Bandwidth-independent compute floor from the plan's per-stage
    /// `max(pipeline, NoC, GB)` cycles.
    pub floor_s: f64,
    /// Plan-predicted DRAM-contention stretch at the static bandwidth
    /// share: `(nominal − floor) / clock`.
    pub stretch_s: f64,
    /// Donation received: predicted stretch minus observed stretch.
    /// Positive when dynamic bandwidth splitting served DRAM phases
    /// faster than the static entitlement would have; ~0 under the
    /// static model.
    pub donation_s: f64,
    /// Diagnostic: bytes granted above the region's static entitlement
    /// while this request was being served (the donation in bandwidth
    /// terms rather than time terms).
    pub donated_bytes: f64,
    /// How the lifecycle ended.
    pub outcome: AttrOutcome,
}

impl RequestAttr {
    /// True when the request was served to completion (even if late).
    pub fn completed(&self) -> bool {
        matches!(self.outcome, AttrOutcome::Completed { .. })
    }

    /// True when the request failed its SLO: completed past its
    /// deadline, or dropped.
    pub fn missed(&self) -> bool {
        match self.outcome {
            AttrOutcome::Completed { missed } => missed,
            AttrOutcome::Dropped => true,
        }
    }

    /// Time on a region (latency minus queue wait).
    pub fn service_s(&self) -> f64 {
        self.latency_s - self.queue_s
    }

    /// Observed DRAM stretch (service time above the compute floor) —
    /// equals `stretch_s − donation_s` bit-exactly by construction.
    pub fn actual_stretch_s(&self) -> f64 {
        (self.latency_s - self.queue_s) - self.floor_s
    }

    /// Conservation residual in the canonical evaluation order; this
    /// is exactly `0.0` (not merely small) for every finite record the
    /// engine emits, because `donation_s` is derived as the closing
    /// term of the same float expression. Keep the parenthesization —
    /// reassociating the sum forfeits the bit-exact guarantee.
    pub fn residual_s(&self) -> f64 {
        (((self.latency_s - self.queue_s) - self.floor_s) - self.stretch_s) + self.donation_s
    }

    /// The observed latency components, in critical-path order:
    /// `("queue", "compute", "dram")`. The DRAM component is the
    /// *observed* stretch so the three sum (modulo float) to latency.
    pub fn components(&self) -> [(&'static str, f64); 3] {
        [
            ("queue", self.queue_s),
            ("compute", self.floor_s),
            ("dram", self.actual_stretch_s()),
        ]
    }

    /// The dominant latency component — the critical path's largest
    /// leg. Drops attribute to the dispatch policy rather than any
    /// physical resource.
    pub fn dominant(&self) -> &'static str {
        if !self.completed() {
            return "policy";
        }
        let mut best = ("queue", f64::NEG_INFINITY);
        for (name, v) in self.components() {
            if v > best.1 {
                best = (name, v);
            }
        }
        best.0
    }

    /// Full-precision JSON record. `Json::Num` serializes via Rust's
    /// shortest-round-trip float formatting, so the seconds fields
    /// survive a JSON round trip with identical bits — which is what
    /// lets `tools/trace_check.py` re-assert `residual_s == 0.0` on
    /// the exported documents, and the worker-count determinism test
    /// compare outputs byte-for-byte.
    pub fn to_json(&self) -> Json {
        let (outcome, missed) = match self.outcome {
            AttrOutcome::Completed { missed } => ("completed", missed),
            AttrOutcome::Dropped => ("dropped", true),
        };
        let mut j = Json::obj();
        j.set("task", self.task)
            .set("id", self.id)
            .set("region", self.region)
            .set("arrival_s", self.arrival_s)
            .set("latency_s", self.latency_s)
            .set("queue_s", self.queue_s)
            .set("floor_s", self.floor_s)
            .set("stretch_s", self.stretch_s)
            .set("donation_s", self.donation_s)
            .set("donated_bytes", self.donated_bytes)
            .set("outcome", outcome)
            .set("missed", missed)
            .set("dominant", self.dominant());
        j
    }
}

/// Aggregate attribution for one time bucket (requests bucketed by
/// arrival time). Component sums cover completed requests; the p50/p99
/// shares are the component fractions of the latency-rank request at
/// that percentile (nearest rank), i.e. "what explains the p99".
#[derive(Debug, Clone, PartialEq)]
pub struct WindowAttr {
    /// Bucket start (inclusive).
    pub t0_s: f64,
    /// Bucket end (exclusive).
    pub t1_s: f64,
    /// Requests completed / dropped / SLO-missed in the bucket.
    pub completed: usize,
    pub dropped: usize,
    pub missed: usize,
    /// Summed components over completed requests.
    pub queue_s: f64,
    pub floor_s: f64,
    pub dram_s: f64,
    pub donation_s: f64,
    /// Nearest-rank latency percentiles over completed requests.
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// `[queue, compute, dram]` fractions of the p50/p99 request's
    /// latency (zeros when the bucket completed nothing).
    pub p50_share: [f64; 3],
    pub p99_share: [f64; 3],
}

impl WindowAttr {
    pub fn to_json(&self) -> Json {
        let share = |s: &[f64; 3]| {
            let mut j = Json::obj();
            j.set("queue", s[0]).set("compute", s[1]).set("dram", s[2]);
            j
        };
        let mut j = Json::obj();
        j.set("t0_s", self.t0_s)
            .set("t1_s", self.t1_s)
            .set("completed", self.completed)
            .set("dropped", self.dropped)
            .set("missed", self.missed)
            .set("queue_s", self.queue_s)
            .set("floor_s", self.floor_s)
            .set("dram_s", self.dram_s)
            .set("donation_s", self.donation_s)
            .set("p50_latency_s", self.p50_latency_s)
            .set("p99_latency_s", self.p99_latency_s)
            .set("p50_share", share(&self.p50_share))
            .set("p99_share", share(&self.p99_share));
        j
    }
}

/// Attribution rolled up over one grouping key (a task or a region).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupAttr {
    /// Task index for [`by_task`], region index for [`by_region`].
    pub key: usize,
    pub completed: usize,
    pub dropped: usize,
    pub missed: usize,
    /// Summed components over completed requests.
    pub queue_s: f64,
    pub floor_s: f64,
    pub dram_s: f64,
    pub donation_s: f64,
    /// Summed end-to-end latency over completed requests.
    pub latency_s: f64,
}

impl GroupAttr {
    /// Mean of a summed component over completed requests (0 when the
    /// group completed nothing).
    pub fn mean(&self, total_s: f64) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            total_s / self.completed as f64
        }
    }
}

/// One sliding-window sample of the SLO burn-rate monitor.
#[derive(Debug, Clone, PartialEq)]
pub struct BurnSample {
    /// Window end time (the sample point); covers `(t_s − window, t_s]`.
    pub t_s: f64,
    /// Requests that ended (completed or dropped) in the window.
    pub requests: usize,
    /// Of those, how many missed their SLO.
    pub missed: usize,
    /// `missed / requests` (0 when the window is empty).
    pub miss_rate: f64,
    /// `miss_rate / budget` — 1.0 burns the error budget exactly;
    /// sustained >1.0 is the replan-now signal.
    pub burn_rate: f64,
}

impl BurnSample {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("t_s", self.t_s)
            .set("requests", self.requests)
            .set("missed", self.missed)
            .set("miss_rate", self.miss_rate)
            .set("burn_rate", self.burn_rate);
        j
    }
}

/// Bucket records by arrival time into contiguous `window_s`-wide
/// windows starting at 0 and aggregate per-bucket attribution.
/// Returns an empty vec for empty input or a non-positive window.
pub fn windowed(attrs: &[RequestAttr], window_s: f64) -> Vec<WindowAttr> {
    if attrs.is_empty() || !(window_s > 0.0) {
        return Vec::new();
    }
    let bucket = |t: f64| ((t / window_s).floor().max(0.0)) as usize;
    let last = attrs.iter().map(|a| bucket(a.arrival_s)).max().unwrap_or(0);
    let mut out: Vec<WindowAttr> = (0..=last)
        .map(|i| WindowAttr {
            t0_s: i as f64 * window_s,
            t1_s: (i + 1) as f64 * window_s,
            completed: 0,
            dropped: 0,
            missed: 0,
            queue_s: 0.0,
            floor_s: 0.0,
            dram_s: 0.0,
            donation_s: 0.0,
            p50_latency_s: 0.0,
            p99_latency_s: 0.0,
            p50_share: [0.0; 3],
            p99_share: [0.0; 3],
        })
        .collect();
    let mut members: Vec<Vec<&RequestAttr>> = vec![Vec::new(); last + 1];
    for a in attrs {
        let w = &mut out[bucket(a.arrival_s)];
        if a.missed() {
            w.missed += 1;
        }
        if a.completed() {
            w.completed += 1;
            w.queue_s += a.queue_s;
            w.floor_s += a.floor_s;
            w.dram_s += a.actual_stretch_s();
            w.donation_s += a.donation_s;
            members[bucket(a.arrival_s)].push(a);
        } else {
            w.dropped += 1;
        }
    }
    for (w, m) in out.iter_mut().zip(members.iter_mut()) {
        if m.is_empty() {
            continue;
        }
        // Deterministic total order: latency, then (task, id) to break
        // exact-tie latencies identically on every run.
        m.sort_by(|a, b| {
            a.latency_s
                .total_cmp(&b.latency_s)
                .then(a.task.cmp(&b.task))
                .then(a.id.cmp(&b.id))
        });
        let pick = |q: f64| {
            let rank = ((q * m.len() as f64).ceil() as usize).max(1) - 1;
            m[rank.min(m.len() - 1)]
        };
        let share = |a: &RequestAttr| {
            if a.latency_s > 0.0 {
                [
                    a.queue_s / a.latency_s,
                    a.floor_s / a.latency_s,
                    a.actual_stretch_s() / a.latency_s,
                ]
            } else {
                [0.0; 3]
            }
        };
        let (p50, p99) = (pick(0.50), pick(0.99));
        w.p50_latency_s = p50.latency_s;
        w.p99_latency_s = p99.latency_s;
        w.p50_share = share(p50);
        w.p99_share = share(p99);
    }
    out
}

fn grouped(attrs: &[RequestAttr], key: impl Fn(&RequestAttr) -> usize) -> Vec<GroupAttr> {
    let n = match attrs.iter().map(&key).max() {
        Some(m) => m + 1,
        None => return Vec::new(),
    };
    let mut out: Vec<GroupAttr> = (0..n)
        .map(|k| GroupAttr {
            key: k,
            completed: 0,
            dropped: 0,
            missed: 0,
            queue_s: 0.0,
            floor_s: 0.0,
            dram_s: 0.0,
            donation_s: 0.0,
            latency_s: 0.0,
        })
        .collect();
    for a in attrs {
        let g = &mut out[key(a)];
        if a.missed() {
            g.missed += 1;
        }
        if a.completed() {
            g.completed += 1;
            g.queue_s += a.queue_s;
            g.floor_s += a.floor_s;
            g.dram_s += a.actual_stretch_s();
            g.donation_s += a.donation_s;
            g.latency_s += a.latency_s;
        } else {
            g.dropped += 1;
        }
    }
    out
}

/// Roll attribution up per task index.
pub fn by_task(attrs: &[RequestAttr]) -> Vec<GroupAttr> {
    grouped(attrs, |a| a.task)
}

/// Roll attribution up per serving region.
pub fn by_region(attrs: &[RequestAttr]) -> Vec<GroupAttr> {
    grouped(attrs, |a| a.region)
}

/// SLO burn-rate monitor: slide a `window_s` window (half-window
/// stride) over request *end* times and sample `miss_rate / budget`.
/// The stride widens so no run produces more than ~256 samples.
pub fn burn_rate(attrs: &[RequestAttr], window_s: f64, budget: f64) -> Vec<BurnSample> {
    if attrs.is_empty() || !(window_s > 0.0) || !(budget > 0.0) {
        return Vec::new();
    }
    let mut ends: Vec<(f64, bool, usize, u64)> = attrs
        .iter()
        .map(|a| (a.arrival_s + a.latency_s, a.missed(), a.task, a.id))
        .collect();
    ends.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.2.cmp(&b.2)).then(a.3.cmp(&b.3)));
    let (first, last) = (ends[0].0, ends[ends.len() - 1].0);
    let stride = (window_s / 2.0).max((last - first) / 256.0);
    let mut out = Vec::new();
    let mut t = first;
    loop {
        let lo = t - window_s;
        let (mut requests, mut missed) = (0usize, 0usize);
        for &(end, m, _, _) in &ends {
            if end > lo && end <= t {
                requests += 1;
                if m {
                    missed += 1;
                }
            }
        }
        let miss_rate = if requests == 0 {
            0.0
        } else {
            missed as f64 / requests as f64
        };
        out.push(BurnSample {
            t_s: t,
            requests,
            missed,
            miss_rate,
            burn_rate: miss_rate / budget,
        });
        if t >= last {
            break;
        }
        t = (t + stride).min(last);
    }
    out
}

/// The `k` slowest completed requests, worst first (ties broken by
/// `(task, id)` so the order is identical on every run).
pub fn worst_k(attrs: &[RequestAttr], k: usize) -> Vec<&RequestAttr> {
    let mut done: Vec<&RequestAttr> = attrs.iter().filter(|a| a.completed()).collect();
    done.sort_by(|a, b| {
        b.latency_s
            .total_cmp(&a.latency_s)
            .then(a.task.cmp(&b.task))
            .then(a.id.cmp(&b.id))
    });
    done.truncate(k);
    done
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a record exactly the way the engine does: `donation` is
    /// the closing term of the canonical float expression. `parts` is
    /// `[latency, queue, floor, stretch]` in seconds.
    fn rec(task: usize, id: u64, arrival: f64, parts: [f64; 4], missed: bool) -> RequestAttr {
        let [latency, queue, floor, stretch] = parts;
        let donation = stretch - ((latency - queue) - floor);
        RequestAttr {
            task,
            id,
            region: task,
            arrival_s: arrival,
            latency_s: latency,
            queue_s: queue,
            floor_s: floor,
            stretch_s: stretch,
            donation_s: donation,
            donated_bytes: 0.0,
            outcome: AttrOutcome::Completed { missed },
        }
    }

    #[test]
    fn residual_is_bit_exactly_zero_for_adversarial_components() {
        // Components chosen to be float-hostile: wildly mixed
        // magnitudes where a reassociated sum would NOT cancel.
        let cases = [
            (1.0e-3, 2.5e-4, 1.0e-7, 3.0e-5),
            (17.0 / 3.0, 1.0 / 7.0, 1.0e-12, 2.0 / 3.0),
            (1.0e3 + 1.0e-9, 1.0e-9, 999.0, 0.5),
            (0.1 + 0.2, 0.1, 0.2, 0.05),
            (f64::MIN_POSITIVE * 8.0, f64::MIN_POSITIVE, f64::MIN_POSITIVE * 2.0, 0.0),
        ];
        for (i, &(lat, q, f, s)) in cases.iter().enumerate() {
            let a = rec(0, i as u64, 0.0, [lat, q, f, s], false);
            assert_eq!(a.residual_s(), 0.0, "case {i}: residual must be exactly zero");
        }
    }

    #[test]
    fn dominant_picks_the_largest_component_and_drops_blame_policy() {
        assert_eq!(rec(0, 0, 0.0, [1.0, 0.7, 0.2, 0.1], false).dominant(), "queue");
        assert_eq!(rec(0, 1, 0.0, [1.0, 0.1, 0.8, 0.1], false).dominant(), "compute");
        assert_eq!(rec(0, 2, 0.0, [1.0, 0.1, 0.2, 0.7], false).dominant(), "dram");
        let drop = RequestAttr {
            outcome: AttrOutcome::Dropped,
            floor_s: 0.0,
            stretch_s: 0.0,
            donation_s: 0.0,
            ..rec(0, 3, 0.0, [0.5, 0.5, 0.0, 0.0], true)
        };
        assert_eq!(drop.dominant(), "policy");
        assert!(drop.missed() && !drop.completed());
        assert_eq!(drop.residual_s(), 0.0);
    }

    #[test]
    fn windowed_buckets_by_arrival_and_ranks_percentiles() {
        let attrs: Vec<RequestAttr> = (0..20)
            .map(|i| {
                let lat = 1e-3 * (i + 1) as f64;
                rec(0, i as u64, 0.05 * i as f64, [lat, lat * 0.5, lat * 0.3, lat * 0.2], false)
            })
            .collect();
        let ws = windowed(&attrs, 0.25);
        assert_eq!(ws.len(), 4, "20 arrivals at 50ms spacing over 1s → 4 buckets of 0.25s");
        for w in &ws {
            assert_eq!(w.completed, 5);
            assert_eq!(w.dropped, 0);
            assert!(w.p99_latency_s >= w.p50_latency_s);
            let share_sum: f64 = w.p50_share.iter().sum();
            assert!((share_sum - 1.0).abs() < 1e-9, "shares cover the whole latency");
        }
        // The p99 (nearest-rank) of 5 requests is the max.
        assert_eq!(ws[0].p99_latency_s, 5e-3);
    }

    #[test]
    fn burn_rate_tracks_the_miss_budget() {
        let attrs: Vec<RequestAttr> = (0..100)
            .map(|i| rec(0, i as u64, 0.01 * i as f64, [1e-3, 5e-4, 4e-4, 1e-4], i % 10 == 0))
            .collect();
        let samples = burn_rate(&attrs, 0.2, 0.01);
        assert!(!samples.is_empty());
        for pair in samples.windows(2) {
            assert!(pair[1].t_s > pair[0].t_s, "samples are time-ordered");
        }
        let last = samples.last().unwrap();
        // 10% misses against a 1% budget → burn rate near 10.
        assert!(last.burn_rate > 1.0, "overbudget misses must show burn > 1");
    }

    #[test]
    fn worst_k_orders_by_latency_with_stable_ties() {
        let mut attrs = vec![
            rec(1, 7, 0.0, [3e-3, 1e-3, 1e-3, 1e-3], false),
            rec(0, 2, 0.0, [5e-3, 2e-3, 2e-3, 1e-3], true),
            rec(2, 1, 0.0, [5e-3, 2e-3, 2e-3, 1e-3], true),
            rec(0, 9, 0.0, [1e-3, 5e-4, 4e-4, 1e-4], false),
        ];
        attrs.push(RequestAttr {
            outcome: AttrOutcome::Dropped,
            ..attrs[0]
        });
        let worst = worst_k(&attrs, 3);
        assert_eq!(worst.len(), 3);
        assert_eq!((worst[0].task, worst[0].id), (0, 2), "tie broken by (task, id)");
        assert_eq!((worst[1].task, worst[1].id), (2, 1));
        assert_eq!((worst[2].task, worst[2].id), (1, 7));
    }

    #[test]
    fn group_rollups_split_by_task_and_region() {
        let attrs = vec![
            rec(0, 0, 0.0, [1e-3, 5e-4, 4e-4, 1e-4], false),
            rec(0, 1, 0.1, [2e-3, 1e-3, 8e-4, 2e-4], true),
            rec(1, 0, 0.2, [4e-3, 2e-3, 1e-3, 1e-3], false),
        ];
        let tasks = by_task(&attrs);
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[0].completed, 2);
        assert_eq!(tasks[0].missed, 1);
        assert_eq!(tasks[1].completed, 1);
        assert!((tasks[1].mean(tasks[1].latency_s) - 4e-3).abs() < 1e-12);
        let regions = by_region(&attrs);
        assert_eq!(regions.len(), 2);
        assert_eq!(regions[0].completed + regions[1].completed, 3);
    }

    #[test]
    fn json_round_trip_preserves_component_bits() {
        let a = rec(3, 42, 0.123456789, [17.0 / 3.0, 1.0 / 7.0, 1.0e-12, 2.0 / 3.0], false);
        let text = a.to_json().to_pretty();
        let parsed = Json::parse(&text).expect("attr json parses");
        for key in ["latency_s", "queue_s", "floor_s", "stretch_s", "donation_s"] {
            let got = parsed.get(key).and_then(|v| v.as_f64()).unwrap();
            let want = match key {
                "latency_s" => a.latency_s,
                "queue_s" => a.queue_s,
                "floor_s" => a.floor_s,
                "stretch_s" => a.stretch_s,
                _ => a.donation_s,
            };
            assert_eq!(got.to_bits(), want.to_bits(), "{key} must round-trip bit-exactly");
        }
    }
}
