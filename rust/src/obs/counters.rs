//! Typed counter/gauge/histogram registry.
//!
//! Names are dotted paths (`serve.miss`, `dse.cache.hit`,
//! `time.cosched.schedule`); a name is bound to one cell kind on first use
//! and misuse panics — a counter silently becoming a gauge would corrupt
//! every report built on it. Histogram percentiles go through the
//! sort-once [`Histogram`](crate::util::stats::Histogram) so rendering a
//! cell costs one sort regardless of how many quantiles the report reads.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats::Histogram;

/// One registered metric.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    /// Monotone event count.
    Counter(u64),
    /// Last-write-wins level.
    Gauge(f64),
    /// Raw samples; summarized (p50/p95/p99/mean/min/max) at render time.
    Hist(Vec<f64>),
}

/// Name → cell map behind `Obs`'s mutex; all mutation goes through
/// [`super::Obs::count`]/[`super::Obs::gauge`]/[`super::Obs::observe`].
#[derive(Debug, Default)]
pub struct Registry {
    cells: BTreeMap<String, Cell>,
}

impl Registry {
    pub fn count(&mut self, name: &str, n: u64) {
        match self
            .cells
            .entry(name.to_string())
            .or_insert(Cell::Counter(0))
        {
            Cell::Counter(c) => *c += n,
            other => panic!("obs counter {name} already registered as {other:?}"),
        }
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        match self
            .cells
            .entry(name.to_string())
            .or_insert(Cell::Gauge(0.0))
        {
            Cell::Gauge(g) => *g = v,
            other => panic!("obs gauge {name} already registered as {other:?}"),
        }
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        match self
            .cells
            .entry(name.to_string())
            .or_insert_with(|| Cell::Hist(Vec::new()))
        {
            Cell::Hist(xs) => xs.push(v),
            other => panic!("obs histogram {name} already registered as {other:?}"),
        }
    }

    pub fn get(&self, name: &str) -> Option<&Cell> {
        self.cells.get(name)
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Histogram cells as `(name, samples)` pairs, in name order.
    pub fn histograms(&self) -> Vec<(String, Vec<f64>)> {
        self.cells
            .iter()
            .filter_map(|(name, cell)| match cell {
                Cell::Hist(xs) => Some((name.clone(), xs.clone())),
                _ => None,
            })
            .collect()
    }

    /// JSON rendering: `{name: {"kind": …, …}}`, histograms summarized.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (name, cell) in &self.cells {
            let mut c = Json::obj();
            match cell {
                Cell::Counter(n) => {
                    c.set("kind", "counter").set("value", *n);
                }
                Cell::Gauge(v) => {
                    c.set("kind", "gauge").set("value", *v);
                }
                Cell::Hist(xs) => {
                    let h = Histogram::from_samples(xs);
                    c.set("kind", "histogram")
                        .set("n", xs.len())
                        .set("mean", h.mean())
                        .set("min", h.min())
                        .set("p50", h.percentile(50.0))
                        .set("p95", h.percentile(95.0))
                        .set("p99", h.percentile(99.0))
                        .set("max", h.max());
                }
            }
            j.set(name, c);
        }
        j
    }

    /// Table rows `(name, kind, rendered summary)` for `report::obs`.
    pub fn rows(&self) -> Vec<(String, String, String)> {
        self.cells
            .iter()
            .map(|(name, cell)| {
                let (kind, rendered) = match cell {
                    Cell::Counter(n) => ("counter", format!("{n}")),
                    Cell::Gauge(v) => ("gauge", format!("{v:.4}")),
                    Cell::Hist(xs) => {
                        let h = Histogram::from_samples(xs);
                        (
                            "histogram",
                            format!(
                                "n={} p50={:.3} p95={:.3} p99={:.3}",
                                xs.len(),
                                h.percentile(50.0),
                                h.percentile(95.0),
                                h.percentile(99.0)
                            ),
                        )
                    }
                };
                (name.clone(), kind.to_string(), rendered)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_accumulates() {
        let mut r = Registry::default();
        r.count("a.b", 1);
        r.count("a.b", 2);
        assert_eq!(r.get("a.b"), Some(&Cell::Counter(3)));
    }

    #[test]
    fn gauge_last_write_wins() {
        let mut r = Registry::default();
        r.gauge("g", 1.0);
        r.gauge("g", 7.5);
        assert_eq!(r.get("g"), Some(&Cell::Gauge(7.5)));
    }

    #[test]
    fn hist_summarizes_in_json() {
        let mut r = Registry::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            r.observe("h", v);
        }
        let j = r.to_json();
        let h = j.get("h").unwrap();
        assert_eq!(h.get("kind").and_then(|k| k.as_str()), Some("histogram"));
        assert_eq!(h.get("n").and_then(|n| n.as_usize()), Some(5));
        assert_eq!(h.get("p50").and_then(|p| p.as_f64()), Some(3.0));
    }

    #[test]
    #[should_panic]
    fn kind_mismatch_panics() {
        let mut r = Registry::default();
        r.count("x", 1);
        r.gauge("x", 1.0);
    }

    #[test]
    fn rows_cover_every_cell() {
        let mut r = Registry::default();
        r.count("c", 2);
        r.gauge("g", 0.5);
        r.observe("h", 1.0);
        let rows = r.rows();
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().any(|(n, k, _)| n == "c" && k == "counter"));
        assert!(rows.iter().any(|(n, k, _)| n == "g" && k == "gauge"));
        assert!(rows.iter().any(|(n, k, _)| n == "h" && k == "histogram"));
    }
}
