//! SLO flight recorder: a bounded ring of recent sim events frozen at
//! the first deadline miss.
//!
//! Long sweeps make full traces impractical — a 30 s overload run at
//! trace granularity is tens of millions of events, and the miss you
//! care about is buried in the first second. The flight recorder keeps
//! only the last [`DEFAULT_FLIGHT_CAP`] events (a private [`Obs`] ring,
//! so the Perfetto exporter and track naming are reused wholesale) and
//! *freezes* the ring the moment the first deadline miss completes.
//! What you get is a focused, Perfetto-loadable snippet of the moments
//! leading up to the miss plus a machine-readable trigger record; the
//! CLI (`pipeorgan serve --flight-out FILE`) attaches the worst-request
//! attribution table ([`crate::obs::attr`]) and writes the combined
//! document. Runs that never miss still dump an end-of-run snapshot so
//! `--flight-out` always produces a file.
//!
//! The recorder is independent of the user-facing `--obs`/`--trace-out`
//! handle: it can run with observability otherwise disabled, and its
//! ring cap bounds memory regardless of run length.

use super::Obs;
use crate::util::json::Json;

/// Default event capacity of the flight ring: large enough to hold the
/// last few scheduling epochs of every region at serve granularity,
/// small enough that an always-on recorder costs a few MB at worst.
pub const DEFAULT_FLIGHT_CAP: usize = 4096;

/// Why a snapshot was taken.
#[derive(Debug, Clone, PartialEq)]
pub enum FlightTrigger {
    /// The first request in the run to complete past its deadline.
    DeadlineMiss {
        task: usize,
        id: u64,
        region: usize,
        t_s: f64,
    },
    /// No request missed; the snapshot is the tail of the run.
    EndOfRun { t_s: f64 },
}

impl FlightTrigger {
    /// Stable string tag used in the dumped JSON (`deadline_miss` /
    /// `end_of_run`), matched by `tools/trace_check.py`.
    pub fn kind(&self) -> &'static str {
        match self {
            FlightTrigger::DeadlineMiss { .. } => "deadline_miss",
            FlightTrigger::EndOfRun { .. } => "end_of_run",
        }
    }

    /// Simulated time of the trigger.
    pub fn t_s(&self) -> f64 {
        match *self {
            FlightTrigger::DeadlineMiss { t_s, .. } | FlightTrigger::EndOfRun { t_s } => t_s,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", self.kind()).set("t_s", self.t_s());
        if let FlightTrigger::DeadlineMiss {
            task, id, region, ..
        } = *self
        {
            j.set("task", task).set("id", id).set("region", region);
        }
        j
    }
}

/// The frozen output of a [`FlightRecorder`]: the trigger plus a
/// Perfetto-compatible trace document of the events leading up to it.
#[derive(Debug, Clone, PartialEq)]
pub struct FlightSnapshot {
    /// What froze the ring.
    pub trigger: FlightTrigger,
    doc: Json,
}

impl FlightSnapshot {
    /// True when the snapshot was frozen by a deadline miss (the case
    /// `--flight-out` prefers when several policies ran).
    pub fn missed(&self) -> bool {
        matches!(self.trigger, FlightTrigger::DeadlineMiss { .. })
    }

    /// The dump written to `--flight-out`: the frozen Perfetto trace
    /// (loads unmodified in ui.perfetto.dev, which ignores unknown
    /// top-level keys) with a `"flight"` block carrying the trigger and
    /// the caller-supplied attribution table.
    pub fn document(&self, attr_table: Json) -> Json {
        let mut flight = self.trigger.to_json();
        flight.set("table", attr_table);
        let mut doc = self.doc.clone();
        doc.set("flight", flight);
        doc
    }
}

/// A bounded recorder of recent sim events that freezes on the first
/// deadline miss.
///
/// The serve event loop mirrors every emission (track names, spans,
/// instants, counter samples) into the recorder when
/// `SimOptions::flight` is set; [`trigger_miss`] freezes the ring at
/// the first miss and later emissions become no-ops, so the snapshot
/// shows the lead-up rather than the aftermath. [`finish`] always
/// yields a snapshot — [`FlightTrigger::EndOfRun`] when nothing missed.
///
/// [`trigger_miss`]: FlightRecorder::trigger_miss
/// [`finish`]: FlightRecorder::finish
#[derive(Debug)]
pub struct FlightRecorder {
    sink: Obs,
    frozen: Option<(FlightTrigger, Json)>,
}

impl FlightRecorder {
    /// A recorder whose ring keeps the most recent `cap` events
    /// (drop-oldest beyond that).
    pub fn new(cap: usize) -> Self {
        Self {
            sink: Obs::with_cap(cap),
            frozen: None,
        }
    }

    /// True once the first miss has frozen the ring.
    pub fn triggered(&self) -> bool {
        self.frozen.is_some()
    }

    /// Name a process track (first name wins, like [`Obs`]).
    pub fn name_process(&self, pid: u32, name: &str) {
        self.sink.name_process(pid, name);
    }

    /// Name a thread track (first name wins).
    pub fn name_track(&self, pid: u32, tid: u32, name: &str) {
        self.sink.name_track(pid, tid, name);
    }

    /// Record a complete span; no-op once frozen.
    pub fn span(&self, name: &str, pid: u32, tid: u32, ts_us: f64, dur_us: f64) {
        if self.frozen.is_none() {
            self.sink.span(name, pid, tid, ts_us, dur_us);
        }
    }

    /// Record an instant event; no-op once frozen.
    pub fn instant(&self, name: &str, pid: u32, tid: u32, ts_us: f64) {
        if self.frozen.is_none() {
            self.sink.instant(name, pid, tid, ts_us);
        }
    }

    /// Record a counter sample; no-op once frozen.
    pub fn counter(&self, name: &str, pid: u32, ts_us: f64, series: &[(&str, f64)]) {
        if self.frozen.is_none() {
            self.sink.counter(name, pid, ts_us, series);
        }
    }

    /// Report a deadline miss. The *first* call freezes the ring into
    /// the snapshot (including the miss event itself if the caller
    /// emitted it just before); every later call is a no-op, so one run
    /// produces at most one miss-triggered snapshot.
    pub fn trigger_miss(&mut self, task: usize, id: u64, region: usize, t_s: f64) {
        if self.frozen.is_none() {
            self.frozen = Some((
                FlightTrigger::DeadlineMiss {
                    task,
                    id,
                    region,
                    t_s,
                },
                self.sink.trace_json(),
            ));
        }
    }

    /// Consume the recorder into its snapshot: the miss-frozen ring if
    /// a miss triggered, otherwise the end-of-run tail at `t_s`.
    pub fn finish(self, t_s: f64) -> FlightSnapshot {
        match self.frozen {
            Some((trigger, doc)) => FlightSnapshot { trigger, doc },
            None => FlightSnapshot {
                trigger: FlightTrigger::EndOfRun { t_s },
                doc: self.sink.trace_json(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events_of(doc: &Json) -> Vec<Json> {
        doc.get("traceEvents")
            .and_then(|e| e.as_arr())
            .expect("trace doc has traceEvents")
            .to_vec()
    }

    #[test]
    fn first_miss_freezes_and_later_events_are_excluded() {
        let mut fr = FlightRecorder::new(64);
        fr.name_process(1, "serve-sim");
        fr.name_track(1, 0, "region0");
        fr.instant("arrive t0#0", 1, 0, 10.0);
        fr.instant("miss t0#0", 1, 0, 20.0);
        fr.trigger_miss(0, 0, 0, 2e-5);
        assert!(fr.triggered());
        let frozen_len = {
            let (_, doc) = fr.frozen.as_ref().unwrap();
            events_of(doc).len()
        };
        // Emissions and triggers after the freeze change nothing.
        fr.instant("arrive t0#1", 1, 0, 30.0);
        fr.span("t0 s0", 1, 0, 30.0, 5.0);
        fr.counter("queue_depth", 1, 40.0, &[("t0", 1.0)]);
        fr.trigger_miss(9, 9, 9, 9.0);
        let snap = fr.finish(1.0);
        assert_eq!(
            snap.trigger,
            FlightTrigger::DeadlineMiss {
                task: 0,
                id: 0,
                region: 0,
                t_s: 2e-5
            }
        );
        assert!(snap.missed());
        assert_eq!(events_of(&snap.document(Json::Arr(vec![]))).len(), frozen_len);
    }

    #[test]
    fn no_miss_yields_an_end_of_run_snapshot_with_all_events() {
        let mut fr = FlightRecorder::new(64);
        fr.instant("arrive t0#0", 1, 0, 10.0);
        fr.counter("queue_depth", 1, 20.0, &[("t0", 0.0)]);
        assert!(!fr.triggered());
        let snap = fr.finish(0.5);
        assert_eq!(snap.trigger, FlightTrigger::EndOfRun { t_s: 0.5 });
        assert!(!snap.missed());
        // 2 payload events; meta events (process/thread names) may add more.
        assert!(events_of(&snap.document(Json::Arr(vec![]))).len() >= 2);
    }

    #[test]
    fn ring_cap_bounds_the_snapshot_and_keeps_the_newest_events() {
        let fr = {
            let mut fr = FlightRecorder::new(8);
            for i in 0..100 {
                fr.instant(&format!("e{i}"), 1, 0, i as f64);
            }
            fr.trigger_miss(0, 99, 0, 99e-6);
            fr
        };
        let snap = fr.finish(1.0);
        let events = events_of(&snap.document(Json::Arr(vec![])));
        let payload: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i"))
            .collect();
        assert_eq!(payload.len(), 8, "ring cap bounds the payload");
        let last = payload.last().unwrap();
        assert_eq!(last.get("name").and_then(|n| n.as_str()), Some("e99"));
    }

    #[test]
    fn document_attaches_the_flight_block() {
        let mut fr = FlightRecorder::new(8);
        fr.instant("arrive t0#0", 1, 0, 1.0);
        fr.trigger_miss(2, 7, 1, 0.25);
        let snap = fr.finish(0.5);
        let mut row = Json::obj();
        row.set("task", 2u32).set("id", 7u32);
        let doc = snap.document(Json::Arr(vec![row]));
        let fl = doc.get("flight").expect("flight block present");
        assert_eq!(fl.get("kind").and_then(|k| k.as_str()), Some("deadline_miss"));
        assert_eq!(fl.get("task").and_then(|t| t.as_usize()), Some(2));
        assert_eq!(fl.get("id").and_then(|t| t.as_usize()), Some(7));
        assert_eq!(fl.get("region").and_then(|t| t.as_usize()), Some(1));
        assert_eq!(fl.get("table").and_then(|t| t.as_arr()).map(|a| a.len()), Some(1));
        // The trace body is untouched: still a valid Perfetto doc.
        assert!(doc.get("traceEvents").is_some());
        assert!(doc.get("displayTimeUnit").is_some());
    }
}
