//! Congestion heatmaps and the `pipeorgan-noc-v1` artifact (`--noc-out`).
//!
//! A [`Heatmap`] projects per-link loads onto a rows×cols grid, one grid
//! per compass direction: each cell holds the **max** load over the links
//! leaving that PE in that direction. Max (not sum) keeps the headline
//! invariant recomputable downstream: the max over all four grids equals
//! [`LinkLoadMap::max`], which equals the cost model's
//! `worst_channel_load_per_interval` — `tools/trace_check.py` re-derives
//! the chain from the JSON alone.
//!
//! Heatmaps compose: cosched/serve place each guillotine region's map at
//! its `(row0, col0)` offset (serve additionally scales by the window's
//! busy fraction), and `Idle` rectangles are listed alongside so the
//! artifact tiles the full array. See docs/OBSERVABILITY.md §NoC
//! telemetry for the schema.

use crate::noc::{link_class, link_dir, verify_loads, LinkDir, LinkLoadMap, LINK_CLASSES};
use crate::util::json::Json;

use super::Obs;

/// Schema tag of the `--noc-out` artifact.
pub const NOC_SCHEMA: &str = "pipeorgan-noc-v1";

/// One region's load map placed on the full array at `(row0, col0)`,
/// scaled by `scale` (1.0 everywhere except serve's time windows).
pub struct RegionMap {
    pub label: String,
    pub map: LinkLoadMap,
    pub row0: usize,
    pub col0: usize,
    pub scale: f64,
}

impl RegionMap {
    /// A whole-array map (no offset, no scaling) — the dse/plan case.
    pub fn whole(label: &str, map: LinkLoadMap) -> RegionMap {
        RegionMap {
            label: label.to_string(),
            map,
            row0: 0,
            col0: 0,
            scale: 1.0,
        }
    }
}

/// An idle rectangle of the guillotine partition (no task, zero load).
pub struct IdleRect {
    pub row0: usize,
    pub col0: usize,
    pub rows: usize,
    pub cols: usize,
}

/// rows×cols×direction max-load grids (row-major).
pub struct Heatmap {
    pub rows: usize,
    pub cols: usize,
    grids: [Vec<f64>; 4],
}

impl Heatmap {
    pub fn new(rows: usize, cols: usize) -> Heatmap {
        Heatmap {
            rows,
            cols,
            grids: std::array::from_fn(|_| vec![0.0; rows * cols]),
        }
    }

    /// Fold a region's links into the grids at its offset. Cells take the
    /// max so the grid max stays the map max regardless of placement.
    pub fn add(&mut self, part: &RegionMap) {
        let topo = part.map.topology();
        for (link, &w) in topo.links().iter().zip(part.map.loads()) {
            let w = w * part.scale;
            let (r, c) = topo.coords(link.from);
            let (r, c) = (part.row0 + r, part.col0 + c);
            debug_assert!(r < self.rows && c < self.cols, "region overflows array");
            let cell = &mut self.grids[link_dir(topo, link).index()][r * self.cols + c];
            *cell = cell.max(w);
        }
    }

    pub fn grid(&self, dir: LinkDir) -> &[f64] {
        &self.grids[dir.index()]
    }

    /// Max over every cell of every direction — equals the max over the
    /// constituent maps' loads (a max of maxes over a partition).
    pub fn max(&self) -> f64 {
        self.grids
            .iter()
            .flat_map(|g| g.iter().cloned())
            .fold(0.0, f64::max)
    }
}

/// Build one artifact entry: compose `parts` (plus `idle` rectangles) on a
/// rows×cols array, classify the concatenated link loads against
/// `threshold`, and embed the direction grids.
///
/// `worst_channel_load` is the plan's scalar when the entry is backed by
/// one — `trace_check.py` asserts it equals the recomputed grid max
/// exactly. `window` is serve's `(t0_s, t1_s)` sample window.
#[allow(clippy::too_many_arguments)]
pub fn entry_json(
    label: &str,
    kind: &str,
    topology: &str,
    rows: usize,
    cols: usize,
    parts: &[RegionMap],
    idle: &[IdleRect],
    worst_channel_load: Option<f64>,
    threshold: f64,
    window: Option<(f64, f64)>,
) -> Json {
    let mut heat = Heatmap::new(rows, cols);
    let mut loads = Vec::new();
    let mut class_totals = [0.0f64; 3];
    for part in parts {
        heat.add(part);
        let topo = part.map.topology();
        for (link, &w) in topo.links().iter().zip(part.map.loads()) {
            let w = w * part.scale;
            loads.push(w);
            let slot = LINK_CLASSES
                .iter()
                .position(|&c| c == link_class(topo, link))
                .unwrap();
            class_totals[slot] += w;
        }
    }
    let v = verify_loads(&loads, threshold);

    let mut e = Json::obj();
    e.set("label", label)
        .set("kind", kind)
        .set("topology", topology)
        .set("rows", rows)
        .set("cols", cols)
        .set("max", v.max)
        .set("p50", v.p50)
        .set("p95", v.p95);
    if let Some(w) = worst_channel_load {
        e.set("worst_channel_load", w);
    }
    let mut links = Json::obj();
    links
        .set("total", v.total_links)
        .set("active", v.active_links)
        .set("saturated", v.saturated);
    e.set("links", links);
    let mut verdict = Json::obj();
    verdict
        .set("threshold", v.threshold)
        .set("congestion_free", v.congestion_free)
        .set("utilization", v.utilization());
    e.set("verify", verdict);
    let mut classes = Json::obj();
    for (name, total) in LINK_CLASSES.iter().zip(class_totals) {
        classes.set(name, total);
    }
    e.set("class_load", classes);
    let mut grid = Json::obj();
    for dir in LinkDir::ALL {
        let mut arr = Json::Arr(Vec::new());
        for &w in heat.grid(dir) {
            arr.push(w);
        }
        grid.set(dir.name(), arr);
    }
    e.set("grid", grid);
    let mut regions = Json::Arr(Vec::new());
    for part in parts {
        let topo = part.map.topology();
        let mut r = Json::obj();
        r.set("label", part.label.as_str())
            .set("row0", part.row0)
            .set("col0", part.col0)
            .set("rows", topo.rows)
            .set("cols", topo.cols)
            .set("idle", false);
        regions.push(r);
    }
    for rect in idle {
        let mut r = Json::obj();
        r.set("label", "idle")
            .set("row0", rect.row0)
            .set("col0", rect.col0)
            .set("rows", rect.rows)
            .set("cols", rect.cols)
            .set("idle", true);
        regions.push(r);
    }
    e.set("regions", regions);
    if let Some((t0, t1)) = window {
        let mut w = Json::obj();
        w.set("t0_s", t0).set("t1_s", t1);
        e.set("window", w);
    }
    e
}

/// The `pipeorgan-noc-v1` document: schema tag, producing subcommand,
/// link bandwidth (the words-per-cycle the thresholds assume), entries.
pub fn noc_document(source: &str, link_words_per_cycle: f64, entries: Vec<Json>) -> Json {
    let mut doc = Json::obj();
    doc.set("schema", NOC_SCHEMA)
        .set("source", source)
        .set("link_words_per_cycle", link_words_per_cycle)
        .set("entries", Json::Arr(entries));
    doc
}

/// Emit one `noc_load` counter sample with a series per wire class —
/// Perfetto renders a track with local/express/wrap lines per pid.
pub fn emit_class_counters(
    obs: &Obs,
    pid: u32,
    ts_us: f64,
    class_load: &[(&'static str, f64); 3],
) {
    obs.counter("noc_load", pid, ts_us, class_load);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::noc::Topology;
    use crate::sim::analyze;
    use crate::traffic::{derive_flows, scenarios};
    use std::sync::Arc;

    fn blocked_map(kind: TopologyKind, rows: usize, cols: usize) -> LinkLoadMap {
        let topo = Topology::cached(kind, rows, cols);
        let s = scenarios::fig8_depth2_blocked(rows, cols);
        let flows = derive_flows(&topo, &s.placement, &s.handoffs);
        let load = analyze(&topo, &flows);
        LinkLoadMap::from_analysis(Arc::clone(&topo), &load, 1.0)
    }

    #[test]
    fn grid_max_equals_map_max() {
        for kind in [
            TopologyKind::Mesh,
            TopologyKind::Amp,
            TopologyKind::Torus,
            TopologyKind::FlattenedButterfly,
        ] {
            let map = blocked_map(kind, 16, 16);
            let mut heat = Heatmap::new(16, 16);
            heat.add(&RegionMap::whole("w", map.clone()));
            assert_eq!(heat.max(), map.max(), "{kind:?}");
        }
    }

    #[test]
    fn composition_offsets_preserve_max() {
        // Two 8×8 regions side by side on a 8×16 array.
        let a = blocked_map(TopologyKind::Mesh, 8, 8);
        let b = blocked_map(TopologyKind::Amp, 8, 8);
        let mut heat = Heatmap::new(8, 16);
        heat.add(&RegionMap {
            label: "a".into(),
            map: a.clone(),
            row0: 0,
            col0: 0,
            scale: 1.0,
        });
        heat.add(&RegionMap {
            label: "b".into(),
            map: b.clone(),
            row0: 0,
            col0: 8,
            scale: 1.0,
        });
        assert_eq!(heat.max(), a.max().max(b.max()));
    }

    #[test]
    fn entry_json_embeds_grids_and_verdict() {
        let map = blocked_map(TopologyKind::Mesh, 8, 8);
        let scalar = map.max();
        let parts = [RegionMap::whole("task", map)];
        let idle = [IdleRect {
            row0: 0,
            col0: 0,
            rows: 2,
            cols: 2,
        }];
        let e = entry_json(
            "t/plan",
            "plan",
            "mesh",
            8,
            8,
            &parts,
            &idle,
            Some(scalar),
            2.0,
            Some((0.0, 0.5)),
        );
        assert_eq!(e.get("max").and_then(|v| v.as_f64()), Some(scalar));
        assert_eq!(
            e.get("worst_channel_load").and_then(|v| v.as_f64()),
            Some(scalar)
        );
        // Grid max recomputes to the scalar — the Python-side invariant.
        let grid = e.get("grid").unwrap();
        let gm = LinkDir::ALL
            .iter()
            .flat_map(|d| grid.get(d.name()).and_then(|g| g.as_arr()).unwrap())
            .filter_map(|v| v.as_f64())
            .fold(0.0, f64::max);
        assert_eq!(gm, scalar);
        let regions = e.get("regions").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(regions.len(), 2);
        assert_eq!(
            e.get("window").and_then(|w| w.get("t1_s")).and_then(|v| v.as_f64()),
            Some(0.5)
        );
    }

    #[test]
    fn document_carries_schema_and_source() {
        let doc = noc_document("dse", 1.0, vec![]);
        assert_eq!(
            doc.get("schema").and_then(|s| s.as_str()),
            Some(NOC_SCHEMA)
        );
        assert_eq!(doc.get("source").and_then(|s| s.as_str()), Some("dse"));
    }

    #[test]
    fn class_counter_emits_one_sample_per_call() {
        let obs = Obs::enabled();
        emit_class_counters(&obs, 1, 0.0, &[("local", 1.0), ("express", 2.0), ("wrap", 0.0)]);
        let evs = obs.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "noc_load");
    }
}
