//! Observability: unified tracing, counters, and timeline export.
//!
//! The sensor layer for everything the planner/simulator stack does with
//! time and bytes (DESIGN.md §Obs): a zero-cost-when-disabled [`Obs`]
//! handle records scoped spans, instant events, and counter samples into a
//! bounded ring ([`trace`]), accumulates typed counters/gauges/histograms
//! ([`counters`]), exports Chrome/Perfetto `trace_event` JSON
//! ([`perfetto`], `--trace-out FILE` on `dse`/`cosched`/`serve`), and
//! feeds scoped self-profiling timings into the CI bench recorder
//! ([`selfprof`]). The serve event loop, the cosched guillotine beam, and
//! the dse search all carry an `Obs` in their configs; the future online
//! re-planning controller reads the same counters live. On top of the
//! raw stream sit two analysis layers (docs/OBSERVABILITY.md): [`attr`]
//! decomposes each served request's latency into queue / compute /
//! DRAM-stretch / donation components (conserved bit-exactly) and
//! aggregates windowed bottleneck attribution plus an SLO burn-rate
//! monitor, and [`flight`] is a bounded flight recorder that freezes a
//! Perfetto-loadable snippet at the first deadline miss
//! (`serve --flight-out FILE`).
//!
//! **Zero-cost-when-disabled.** A disabled handle is `inner: None`; every
//! method early-returns before formatting, locking, or allocating, so the
//! instrumented hot paths (the serve event loop foremost — gated by
//! `benches/serve.rs::serve_event_loop_xr_core`) pay one branch per site.
//!
//! **Clock domains.** Timestamps are microseconds, but the *domain* is
//! per-pid: [`PID_SIM`] events carry simulated time (`t_s × 1e6`),
//! [`PID_PLAN`] and [`PID_SELF`] carry wall time since the handle's
//! creation. Perfetto renders each pid as its own process group, so the
//! domains never visually interleave.
//!
//! **Thread safety.** The handle is `Clone + Send + Sync` (an `Arc` over
//! mutex-guarded state), so instrumented closures fanned out over
//! `coordinator::run_queue` record into the same ring/registry as the
//! coordinating thread. Determinism note: sim-domain events are emitted
//! single-threaded in event-loop order, so a fixed seed yields an
//! identical `PID_SIM` sequence; wall-domain events are real timings and
//! are not expected to replay.

pub mod attr;
pub mod counters;
pub mod flight;
pub mod heatmap;
pub mod perfetto;
pub mod selfprof;
pub mod trace;

pub use attr::{AttrOutcome, RequestAttr};
pub use flight::{FlightRecorder, FlightSnapshot, FlightTrigger, DEFAULT_FLIGHT_CAP};
pub use selfprof::ScopedTimer;
pub use trace::{Event, Phase, DEFAULT_RING_CAP};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cli::Args;
use crate::util::json::Json;

/// First sim-time process id: the serve event loop replays each dispatch
/// policy under its own pid (`PID_SIM + policy index`, pids 1..=9
/// reserved) so the per-policy timelines — which all cover the same
/// simulated window — never interleave on one track. `ts` = simulated
/// seconds × 1e6; `tid` = region index.
pub const PID_SIM: u32 = 1;
/// Wall-clock process: planner/search phases (dse sweep, cosched stages).
pub const PID_PLAN: u32 = 10;
/// Wall-clock process: scoped self-profiling timers ([`Obs::scope`]).
pub const PID_SELF: u32 = 11;

/// Human-readable Perfetto track names, keyed by pid and (pid, tid).
#[derive(Debug, Clone, Default)]
pub struct Tracks {
    pub processes: BTreeMap<u32, String>,
    pub threads: BTreeMap<(u32, u32), String>,
}

/// Shared observability handle. Disabled by default ([`Obs::default`] /
/// [`Obs::disabled`]); [`Obs::from_cli`] enables it when `--obs` or
/// `--trace-out` is present. Cloning shares the underlying recorder.
///
/// # Examples
///
/// ```
/// use pipeorgan::obs::Obs;
///
/// // A disabled handle records nothing and costs one branch per site.
/// let off = Obs::disabled();
/// assert!(!off.is_enabled());
/// off.count("demo.events", 3);
/// assert_eq!(off.counter_total("demo.events"), 0);
///
/// // An enabled handle accumulates counters and `time.*` histograms.
/// let obs = Obs::enabled();
/// obs.count("demo.events", 3);
/// obs.count("demo.events", 2);
/// assert_eq!(obs.counter_total("demo.events"), 5);
/// let answer = obs.timed("demo.work", || 6 * 7);
/// assert_eq!(answer, 42);
/// assert!(obs
///     .timer_histograms()
///     .iter()
///     .any(|(name, samples)| name == "time.demo.work" && samples.len() == 1));
/// ```
#[derive(Debug, Clone, Default)]
pub struct Obs {
    inner: Option<Arc<Inner>>,
}

#[derive(Debug)]
struct Inner {
    epoch: Instant,
    ring: Mutex<trace::Ring>,
    counters: Mutex<counters::Registry>,
    tracks: Mutex<Tracks>,
}

impl Obs {
    /// The no-op handle every config defaults to.
    pub fn disabled() -> Self {
        Self { inner: None }
    }

    /// Enabled with the default ring capacity.
    pub fn enabled() -> Self {
        Self::with_cap(DEFAULT_RING_CAP)
    }

    /// Enabled with an explicit ring capacity (events). Sim-time pids are
    /// named by the serve loop itself (one per policy); the wall-clock
    /// processes are fixed, so they are pre-named here.
    pub fn with_cap(cap: usize) -> Self {
        let mut tracks = Tracks::default();
        tracks.processes.insert(PID_PLAN, "planner".to_string());
        tracks.processes.insert(PID_SELF, "selfprof".to_string());
        Self {
            inner: Some(Arc::new(Inner {
                epoch: Instant::now(),
                ring: Mutex::new(trace::Ring::new(cap)),
                counters: Mutex::new(counters::Registry::default()),
                tracks: Mutex::new(tracks),
            })),
        }
    }

    /// Enabled iff the subcommand was invoked with `--obs`, `--trace-out`,
    /// or `--out-dir` (the write-everything artifact directory) — all
    /// registered on `dse`/`cosched`/`serve`/`fleet`.
    pub fn from_cli(args: &Args) -> Self {
        if args.has("obs") || args.get("trace-out").is_some() || args.get("out-dir").is_some() {
            Self::enabled()
        } else {
            Self::disabled()
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Microseconds of wall time since the handle was created (0.0 when
    /// disabled) — the [`PID_PLAN`]/[`PID_SELF`] timestamp source.
    pub fn wall_us(&self) -> f64 {
        match &self.inner {
            Some(i) => i.epoch.elapsed().as_nanos() as f64 / 1e3,
            None => 0.0,
        }
    }

    /// Name the `(pid, tid)` track in the Perfetto export (first name
    /// wins, so call sites can register unconditionally).
    pub fn name_track(&self, pid: u32, tid: u32, name: &str) {
        if let Some(i) = &self.inner {
            i.tracks
                .lock()
                .unwrap()
                .threads
                .entry((pid, tid))
                .or_insert_with(|| name.to_string());
        }
    }

    /// Name the `pid` process group in the Perfetto export (first name
    /// wins).
    pub fn name_process(&self, pid: u32, name: &str) {
        if let Some(i) = &self.inner {
            i.tracks
                .lock()
                .unwrap()
                .processes
                .entry(pid)
                .or_insert_with(|| name.to_string());
        }
    }

    /// Record a complete span (`ts_us` start, `dur_us` length).
    pub fn span(&self, name: &str, pid: u32, tid: u32, ts_us: f64, dur_us: f64) {
        if let Some(i) = &self.inner {
            i.ring.lock().unwrap().push(Event {
                name: name.to_string(),
                pid,
                tid,
                ts_us,
                phase: Phase::Span { dur_us },
            });
        }
    }

    /// Record an instant marker.
    pub fn instant(&self, name: &str, pid: u32, tid: u32, ts_us: f64) {
        if let Some(i) = &self.inner {
            i.ring.lock().unwrap().push(Event {
                name: name.to_string(),
                pid,
                tid,
                ts_us,
                phase: Phase::Instant,
            });
        }
    }

    /// Record a counter sample (one value per named series). Counter
    /// tracks live on `tid` 0 of their pid; Perfetto keys them by name.
    pub fn counter(&self, name: &str, pid: u32, ts_us: f64, series: &[(&str, f64)]) {
        if let Some(i) = &self.inner {
            i.ring.lock().unwrap().push(Event {
                name: name.to_string(),
                pid,
                tid: 0,
                ts_us,
                phase: Phase::Counter {
                    series: series.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
                },
            });
        }
    }

    /// Add `n` to the named monotone counter.
    pub fn count(&self, name: &str, n: u64) {
        if let Some(i) = &self.inner {
            i.counters.lock().unwrap().count(name, n);
        }
    }

    /// Set the named gauge.
    pub fn gauge(&self, name: &str, v: f64) {
        if let Some(i) = &self.inner {
            i.counters.lock().unwrap().gauge(name, v);
        }
    }

    /// Append a sample to the named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        if let Some(i) = &self.inner {
            i.counters.lock().unwrap().observe(name, v);
        }
    }

    /// Current value of a monotone counter (0 when disabled or unset).
    pub fn counter_total(&self, name: &str) -> u64 {
        match &self.inner {
            Some(i) => match i.counters.lock().unwrap().get(name) {
                Some(counters::Cell::Counter(n)) => *n,
                _ => 0,
            },
            None => 0,
        }
    }

    /// Time `f` on the wall clock: a [`PID_SELF`] span plus a nanosecond
    /// sample in the `time.<name>` histogram. Runs `f` bare when disabled.
    pub fn timed<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        if self.inner.is_none() {
            return f();
        }
        let _t = self.scope(name);
        f()
    }

    /// RAII variant of [`Obs::timed`] for scopes that aren't closures.
    pub fn scope(&self, name: &str) -> ScopedTimer<'_> {
        ScopedTimer::new(self, name)
    }

    /// Snapshot of the ring in record order (empty when disabled).
    pub fn events(&self) -> Vec<Event> {
        match &self.inner {
            Some(i) => i.ring.lock().unwrap().events(),
            None => Vec::new(),
        }
    }

    /// Events the ring evicted under pressure.
    pub fn dropped_events(&self) -> u64 {
        match &self.inner {
            Some(i) => i.ring.lock().unwrap().dropped(),
            None => 0,
        }
    }

    /// True when neither the ring nor the registry recorded anything.
    pub fn is_silent(&self) -> bool {
        match &self.inner {
            Some(i) => i.ring.lock().unwrap().is_empty() && i.counters.lock().unwrap().is_empty(),
            None => true,
        }
    }

    /// `time.*` histograms as `(name, ns samples)` for the bench flusher.
    pub fn timer_histograms(&self) -> Vec<(String, Vec<f64>)> {
        match &self.inner {
            Some(i) => i
                .counters
                .lock()
                .unwrap()
                .histograms()
                .into_iter()
                .filter(|(name, _)| name.starts_with(selfprof::TIMER_PREFIX))
                .collect(),
            None => Vec::new(),
        }
    }

    /// The counter registry as JSON (`Json::Null` when disabled, so report
    /// attachment sites can skip it with one check).
    pub fn counters_json(&self) -> Json {
        match &self.inner {
            Some(i) => i.counters.lock().unwrap().to_json(),
            None => Json::Null,
        }
    }

    /// Registry table rows `(name, kind, summary)` for `report::obs`.
    pub fn counter_rows(&self) -> Vec<(String, String, String)> {
        match &self.inner {
            Some(i) => i.counters.lock().unwrap().rows(),
            None => Vec::new(),
        }
    }

    /// The full Perfetto trace document ([`perfetto::trace_json`]).
    pub fn trace_json(&self) -> Json {
        match &self.inner {
            Some(i) => {
                let ring = i.ring.lock().unwrap();
                let tracks = i.tracks.lock().unwrap();
                perfetto::trace_json(&ring.events(), ring.dropped(), &tracks)
            }
            None => perfetto::trace_json(&[], 0, &Tracks::default()),
        }
    }

    /// Write the Perfetto trace to `path` (parent dirs created).
    pub fn write_trace(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.trace_json().to_pretty() + "\n")
    }

    /// Flush `time.*` timings to the CI bench recorder
    /// ([`selfprof::flush_bench_records`]).
    pub fn flush_bench_records(&self) -> std::io::Result<usize> {
        selfprof::flush_bench_records(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_free_and_silent() {
        let obs = Obs::disabled();
        assert!(!obs.is_enabled());
        obs.span("s", PID_SIM, 0, 0.0, 1.0);
        obs.instant("i", PID_SIM, 0, 0.0);
        obs.counter("c", PID_SIM, 0.0, &[("x", 1.0)]);
        obs.count("n", 3);
        obs.gauge("g", 1.0);
        obs.observe("h", 1.0);
        obs.name_track(PID_SIM, 0, "region0");
        assert!(obs.is_silent());
        assert!(obs.events().is_empty());
        assert_eq!(obs.counters_json(), Json::Null);
        assert_eq!(obs.counter_total("n"), 0);
        assert_eq!(obs.timed("t", || 41 + 1), 42);
        assert!(obs.is_silent(), "timed must not record when disabled");
    }

    #[test]
    fn enabled_records_in_order() {
        let obs = Obs::enabled();
        obs.instant("a", PID_SIM, 0, 1.0);
        obs.span("b", PID_SIM, 1, 2.0, 3.0);
        obs.counter("c", PID_SIM, 4.0, &[("q", 7.0)]);
        let evs = obs.events();
        assert_eq!(
            evs.iter().map(|e| e.name.as_str()).collect::<Vec<_>>(),
            vec!["a", "b", "c"]
        );
        assert!(!obs.is_silent());
        assert_eq!(obs.dropped_events(), 0);
    }

    #[test]
    fn clones_share_the_recorder() {
        let obs = Obs::enabled();
        let clone = obs.clone();
        clone.count("shared", 2);
        obs.count("shared", 1);
        assert_eq!(obs.counter_total("shared"), 3);
        clone.instant("e", PID_PLAN, 0, 0.0);
        assert_eq!(obs.events().len(), 1);
    }

    #[test]
    fn from_cli_gates_on_flags() {
        let flags = [("obs", false), ("trace-out", true), ("seed", true)];
        let parse = |argv: &[&str]| {
            let raw: Vec<String> = std::iter::once("serve".to_string())
                .chain(argv.iter().map(|s| s.to_string()))
                .collect();
            Args::parse(&raw, &flags).unwrap()
        };
        assert!(!Obs::from_cli(&parse(&[])).is_enabled());
        assert!(Obs::from_cli(&parse(&["--obs"])).is_enabled());
        assert!(Obs::from_cli(&parse(&["--trace-out", "t.json"])).is_enabled());
        assert!(!Obs::from_cli(&parse(&["--seed", "7"])).is_enabled());
    }

    #[test]
    fn trace_json_names_registered_tracks() {
        let obs = Obs::enabled();
        obs.name_process(PID_SIM, "serve-sim [fifo]");
        obs.name_track(PID_SIM, 2, "region2");
        obs.instant("e", PID_SIM, 2, 1.0);
        let doc = obs.trace_json();
        let evs = doc.get("traceEvents").and_then(|a| a.as_arr()).unwrap();
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
            .filter_map(|e| e.get("args")?.get("name")?.as_str())
            .collect();
        assert!(names.contains(&"serve-sim [fifo]"), "{names:?}");
        assert!(names.contains(&"planner"), "{names:?}");
        assert!(names.contains(&"region2"), "{names:?}");
    }

    #[test]
    fn ring_pressure_surfaces_dropped_count() {
        let obs = Obs::with_cap(4);
        for i in 0..10 {
            obs.instant("e", PID_SIM, 0, i as f64);
        }
        assert_eq!(obs.events().len(), 4);
        assert_eq!(obs.dropped_events(), 6);
        assert_eq!(
            obs.trace_json().get("droppedEvents").and_then(|d| d.as_f64()),
            Some(6.0)
        );
    }
}
