//! Chrome/Perfetto `trace_event` JSON exporter.
//!
//! Emits the JSON *object* format — `{"traceEvents": [...]}` — loadable by
//! `ui.perfetto.dev` and `chrome://tracing`. Every event (including the
//! `"M"` metadata events that name tracks) carries `ph`/`ts`/`pid`/`tid`,
//! which is what `tools/trace_check.py` validates in CI. Timestamps are
//! microseconds; the clock *domain* is per-pid (sim-time vs wall-time, see
//! the `PID_*` constants in [`super`]), which is exactly what the
//! trace_event format's process grouping is for — Perfetto renders each
//! pid as its own process group with an independent time origin story told
//! by its `process_name`.

use super::trace::{Event, Phase};
use super::Tracks;
use crate::util::json::Json;

/// Build the full trace document from a recorded event snapshot.
pub fn trace_json(events: &[Event], dropped: u64, tracks: &Tracks) -> Json {
    let mut arr = Json::Arr(Vec::new());
    for (pid, name) in &tracks.processes {
        arr.push(meta_event("process_name", *pid, 0, name));
    }
    for ((pid, tid), name) in &tracks.threads {
        arr.push(meta_event("thread_name", *pid, *tid, name));
    }
    for ev in events {
        arr.push(event_json(ev));
    }
    let mut top = Json::obj();
    top.set("traceEvents", arr)
        .set("displayTimeUnit", "ms")
        .set("droppedEvents", dropped);
    top
}

fn meta_event(kind: &str, pid: u32, tid: u32, name: &str) -> Json {
    let mut args = Json::obj();
    args.set("name", name);
    let mut m = Json::obj();
    m.set("name", kind)
        .set("ph", "M")
        .set("ts", 0.0)
        .set("pid", pid)
        .set("tid", tid)
        .set("args", args);
    m
}

fn event_json(ev: &Event) -> Json {
    let mut j = Json::obj();
    j.set("name", ev.name.as_str())
        .set("ts", ev.ts_us)
        .set("pid", ev.pid)
        .set("tid", ev.tid);
    match &ev.phase {
        Phase::Span { dur_us } => {
            j.set("ph", "X").set("dur", *dur_us);
        }
        Phase::Instant => {
            // "s":"t" scopes the instant to its thread track.
            j.set("ph", "i").set("s", "t");
        }
        Phase::Counter { series } => {
            let mut args = Json::obj();
            for (k, v) in series {
                args.set(k, *v);
            }
            j.set("ph", "C").set("args", args);
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracks() -> Tracks {
        let mut t = Tracks::default();
        t.processes.insert(1, "serve-sim".to_string());
        t.threads.insert((1, 0), "region0".to_string());
        t
    }

    #[test]
    fn every_event_has_required_fields() {
        let events = vec![
            Event {
                name: "stage".into(),
                pid: 1,
                tid: 0,
                ts_us: 10.0,
                phase: Phase::Span { dur_us: 5.0 },
            },
            Event {
                name: "arrive".into(),
                pid: 1,
                tid: 0,
                ts_us: 11.0,
                phase: Phase::Instant,
            },
            Event {
                name: "queue_depth".into(),
                pid: 1,
                tid: 0,
                ts_us: 12.0,
                phase: Phase::Counter {
                    series: vec![("chain_a".to_string(), 2.0)],
                },
            },
        ];
        let doc = trace_json(&events, 3, &tracks());
        let evs = doc.get("traceEvents").and_then(|a| a.as_arr()).unwrap();
        // 2 metadata + 3 payload events.
        assert_eq!(evs.len(), 5);
        for e in evs {
            for key in ["ph", "ts", "pid", "tid"] {
                assert!(e.get(key).is_some(), "missing {key} in {e}");
            }
        }
        assert_eq!(doc.get("droppedEvents").and_then(|d| d.as_f64()), Some(3.0));
    }

    #[test]
    fn phases_map_to_trace_event_ph() {
        let span = Event {
            name: "s".into(),
            pid: 2,
            tid: 1,
            ts_us: 0.0,
            phase: Phase::Span { dur_us: 1.0 },
        };
        let j = event_json(&span);
        assert_eq!(j.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(j.get("dur").and_then(|d| d.as_f64()), Some(1.0));

        let ctr = Event {
            name: "c".into(),
            pid: 1,
            tid: 0,
            ts_us: 0.0,
            phase: Phase::Counter {
                series: vec![("a".to_string(), 4.0), ("b".to_string(), 5.0)],
            },
        };
        let j = event_json(&ctr);
        assert_eq!(j.get("ph").and_then(|p| p.as_str()), Some("C"));
        let args = j.get("args").unwrap();
        assert_eq!(args.get("a").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(args.get("b").and_then(|v| v.as_f64()), Some(5.0));
    }

    #[test]
    fn document_round_trips_through_parser() {
        let events = vec![Event {
            name: "e".into(),
            pid: 1,
            tid: 0,
            ts_us: 1.5,
            phase: Phase::Instant,
        }];
        let doc = trace_json(&events, 0, &tracks());
        let reparsed = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(reparsed, doc);
    }
}
