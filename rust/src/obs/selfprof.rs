//! Scoped wall-clock self-profiling timers.
//!
//! [`Obs::timed`]/[`Obs::scope`] record each timed section twice: as a
//! span on the self-profiling track (`PID_SELF`) and as a nanosecond
//! sample in a `time.<name>` histogram cell. [`flush_bench_records`]
//! then appends one record per `time.*` histogram to the JSONL file named
//! by `PIPEORGAN_BENCH_JSON` — byte-compatible with what
//! `benches/common::bench` writes — so CLI hot-path timings flow into the
//! same `reports/BENCH_ci.json` trajectory the CI bench gate aggregates
//! (run-only records are reported as "new" by `tools/bench_check.py`,
//! never fatal).

use super::{Obs, PID_SELF};
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Histogram-name prefix marking nanosecond self-profiling samples.
pub const TIMER_PREFIX: &str = "time.";

/// RAII timer: records a span + histogram sample for `name` when dropped.
/// Obtain via [`Obs::scope`]; disabled handles make both ends no-ops.
pub struct ScopedTimer<'a> {
    obs: &'a Obs,
    name: String,
    start_us: f64,
}

impl<'a> ScopedTimer<'a> {
    pub(super) fn new(obs: &'a Obs, name: &str) -> Self {
        Self {
            obs,
            name: name.to_string(),
            start_us: obs.wall_us(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        if !self.obs.is_enabled() {
            return;
        }
        let dur_us = self.obs.wall_us() - self.start_us;
        self.obs
            .span(&self.name, PID_SELF, 0, self.start_us, dur_us);
        self.obs
            .observe(&format!("{TIMER_PREFIX}{}", self.name), dur_us * 1e3);
    }
}

/// Append every `time.*` histogram as one bench-shaped JSONL record to the
/// `PIPEORGAN_BENCH_JSON` file (no-op when the variable is unset or the
/// handle is disabled). Returns the number of records written.
pub fn flush_bench_records(obs: &Obs) -> std::io::Result<usize> {
    let Ok(path) = std::env::var("PIPEORGAN_BENCH_JSON") else {
        return Ok(0);
    };
    let mut written = 0;
    for (name, samples) in obs.timer_histograms() {
        if samples.is_empty() {
            continue;
        }
        append_record(&path, &name, &Summary::from_ns(&samples))?;
        written += 1;
    }
    Ok(written)
}

/// One compact JSON line, field-for-field the `benches/common` record.
fn append_record(path: &str, name: &str, s: &Summary) -> std::io::Result<()> {
    use std::io::Write as _;
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut j = Json::obj();
    j.set("bench", name)
        .set("n", s.n)
        .set("mean_ns", s.mean_ns)
        .set("stddev_ns", s.stddev_ns)
        .set("min_ns", s.min_ns)
        .set("p50_ns", s.p50_ns)
        .set("p95_ns", s.p95_ns)
        .set("max_ns", s.max_ns);
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "{j}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Phase;

    #[test]
    fn scope_records_span_and_histogram() {
        let obs = Obs::enabled();
        {
            let _t = obs.scope("unit.work");
        }
        let events = obs.events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].name, "unit.work");
        assert_eq!(events[0].pid, PID_SELF);
        assert!(matches!(events[0].phase, Phase::Span { .. }));
        let hists = obs.timer_histograms();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].0, "time.unit.work");
        assert_eq!(hists[0].1.len(), 1);
    }

    #[test]
    fn disabled_scope_is_silent() {
        let obs = Obs::disabled();
        {
            let _t = obs.scope("unit.work");
        }
        assert!(obs.events().is_empty());
        assert!(obs.timer_histograms().is_empty());
    }
}
