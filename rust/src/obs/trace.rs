//! Event model and ring buffer for the trace recorder.
//!
//! Three event shapes cover everything the subsystems emit: complete spans
//! (Perfetto `"X"`, a name + start + duration on one track), instant
//! markers (`"i"`, request lifecycle edges like arrive/finish/miss/drop),
//! and counter samples (`"C"`, a named multi-series sample such as the
//! per-task queue depths at one sim instant). Events are recorded into a
//! bounded [`Ring`] that drops the *oldest* events under pressure — the
//! tail of a run is what the re-planning controller and a human debugging
//! a deadline miss care about — and counts what it dropped so the exporter
//! can say so instead of silently truncating.

use std::collections::VecDeque;

/// Default ring capacity (events). At the serve event loop's emission rate
/// (a handful of events per heap pop) this holds several simulated seconds
/// of the canned scenarios; raise via [`super::Obs::with_cap`] for long
/// traces.
pub const DEFAULT_RING_CAP: usize = 65_536;

/// Event shape; maps 1:1 onto Perfetto `ph` values in `obs::perfetto`.
#[derive(Debug, Clone, PartialEq)]
pub enum Phase {
    /// Complete span (`ph:"X"`): `dur_us` of work starting at the event's
    /// timestamp.
    Span { dur_us: f64 },
    /// Instant marker (`ph:"i"`).
    Instant,
    /// Counter sample (`ph:"C"`): one value per named series, rendered by
    /// Perfetto as a stacked counter track per event name.
    Counter { series: Vec<(String, f64)> },
}

/// One recorded event. `ts_us` is microseconds in the clock domain of
/// `pid` (sim-time or wall-time — see the `PID_*` constants in
/// [`super`]); `tid` picks the track within the domain (e.g. region index
/// on the sim pid).
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: String,
    pub pid: u32,
    pub tid: u32,
    pub ts_us: f64,
    pub phase: Phase,
}

/// Bounded event buffer: drop-oldest on overflow, with a dropped count.
#[derive(Debug)]
pub struct Ring {
    cap: usize,
    buf: VecDeque<Event>,
    dropped: u64,
}

impl Ring {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "trace ring capacity must be positive");
        Self {
            cap,
            buf: VecDeque::new(),
            dropped: 0,
        }
    }

    pub fn push(&mut self, ev: Event) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events evicted to stay within capacity (oldest-first).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Snapshot of the buffered events in record order.
    pub fn events(&self) -> Vec<Event> {
        self.buf.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, ts_us: f64) -> Event {
        Event {
            name: name.to_string(),
            pid: 1,
            tid: 0,
            ts_us,
            phase: Phase::Instant,
        }
    }

    #[test]
    fn ring_keeps_order() {
        let mut r = Ring::new(8);
        for i in 0..5 {
            r.push(ev("e", i as f64));
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.dropped(), 0);
        let ts: Vec<f64> = r.events().iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(ev("e", i as f64));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let ts: Vec<f64> = r.events().iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn ring_rejects_zero_cap() {
        Ring::new(0);
    }
}
