//! The pipeline-depth heuristic (Sec. IV-A, "Determining Depth").
//!
//! Starting at layer `l`, grow the candidate depth `D` while the activation
//! footprint saved by pipelining — `A_l(in) + A_{l+D}(out)` plus activations
//! crossing the segment boundary through skip connections — stays at least
//! as large as the accumulated weight footprint `Σ W_i`. Stop the moment
//! weights win; cut unconditionally at complex layers (ROIAlign/RPN); cap
//! at `√numPEs`.

use crate::config::ArchConfig;
use crate::ir::skips::boundary_skip_act_words;
use crate::ir::{LayerId, ModelGraph};

use super::segment::{segments_cover, Segment};

/// Why a segment stopped growing — recorded for Fig. 16 reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// `Σ W_i` exceeded the activation footprint at the next depth.
    FootprintRule,
    /// The next layer is a complex layer (ROIAlign, RPN).
    ComplexLayer,
    /// Hit the `√numPEs` cap.
    MaxDepth,
    /// Ran out of layers.
    ModelEnd,
}

/// A segment plus the heuristic's bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct DepthDecision {
    pub segment: Segment,
    /// Activation footprint at the chosen depth (words).
    pub act_words: u64,
    /// Weight footprint at the chosen depth (words).
    pub weight_words: u64,
    /// Skip edges fully absorbed inside the segment.
    pub absorbed_skips: usize,
    pub stop: StopReason,
}

/// Activation footprint of candidate segment `[l, l+d)` (Sec. III-A): the
/// segment input, the segment output, and everything crossing the boundary
/// via skip connections. Intermediate activations are assumed forwarded
/// PE-to-PE (their granularity term vanishes — fine-grained case).
fn act_footprint(graph: &ModelGraph, l: LayerId, d: usize) -> u64 {
    let first = graph.layer(l);
    let last = graph.layer(l + d - 1);
    first.input_act_words() + last.output_act_words() + boundary_skip_act_words(graph, l, d)
}

fn weight_footprint(graph: &ModelGraph, l: LayerId, d: usize) -> u64 {
    (l..l + d).map(|i| graph.layer(i).weight_words()).sum()
}

/// Partition a whole model into pipeline segments.
pub fn partition(graph: &ModelGraph, cfg: &ArchConfig) -> Vec<DepthDecision> {
    let n = graph.num_layers();
    let max_depth = cfg.max_pipeline_depth().max(1);
    let mut out = Vec::new();
    let mut l = 0usize;
    while l < n {
        // Complex layers always run alone.
        if graph.layer(l).is_complex() {
            out.push(DepthDecision {
                segment: Segment::new(l, 1),
                act_words: act_footprint(graph, l, 1),
                weight_words: weight_footprint(graph, l, 1),
                absorbed_skips: 0,
                stop: StopReason::ComplexLayer,
            });
            l += 1;
            continue;
        }
        let mut d = 1usize;
        let stop;
        loop {
            if l + d >= n {
                stop = StopReason::ModelEnd;
                break;
            }
            if d + 1 > max_depth {
                stop = StopReason::MaxDepth;
                break;
            }
            if graph.layer(l + d).is_complex() {
                stop = StopReason::ComplexLayer;
                break;
            }
            let cand = d + 1;
            let act = act_footprint(graph, l, cand);
            let w = weight_footprint(graph, l, cand);
            if w > act {
                stop = StopReason::FootprintRule;
                break;
            }
            d = cand;
        }
        out.push(DepthDecision {
            segment: Segment::new(l, d),
            act_words: act_footprint(graph, l, d),
            weight_words: weight_footprint(graph, l, d),
            absorbed_skips: crate::ir::skips::absorbed_skips(graph, l, d),
            stop,
        });
        l += d;
    }
    debug_assert!(segments_cover(
        &out.iter().map(|x| x.segment.clone()).collect::<Vec<_>>(),
        n
    )
    .is_ok());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Layer, Op};
    use crate::workloads;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn activation_heavy_chain_goes_deep() {
        // Huge maps, tiny weights → footprint rule never trips; depth is
        // bounded by the model length or the sqrt(numPEs) cap.
        let g = workloads::synthetic::aw_chain(3.0, 8);
        let parts = partition(&g, &cfg());
        assert_eq!(parts.len(), 1, "{parts:?}");
        assert_eq!(parts[0].segment.depth, 8);
    }

    #[test]
    fn weight_heavy_chain_stays_op_by_op() {
        let g = workloads::synthetic::aw_chain(-2.0, 8);
        let parts = partition(&g, &cfg());
        assert!(parts.iter().all(|p| p.segment.depth == 1), "{parts:?}");
        assert!(parts
            .iter()
            .take(parts.len() - 1)
            .all(|p| p.stop == StopReason::FootprintRule));
    }

    #[test]
    fn depth_capped_at_sqrt_num_pes() {
        let g = workloads::synthetic::aw_chain(3.0, 64);
        let parts = partition(&g, &cfg());
        let max = cfg().max_pipeline_depth();
        assert!(parts.iter().all(|p| p.segment.depth <= max));
        assert!(parts.iter().any(|p| p.stop == StopReason::MaxDepth));
    }

    #[test]
    fn complex_layer_cuts_segment() {
        let mut g = workloads::synthetic::aw_chain(2.0, 4);
        let roi = g.push(Layer::new("roi", Op::roi_align(32, 7, 64)));
        g.push(Layer::new(
            "after",
            Op::conv2d(1, 64, 64, 16, 16, 3, 3, 1, 1),
        ));
        let _ = roi;
        let parts = partition(&g, &cfg());
        // chain(4) | roi(1) | after(1)
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].segment.depth, 4);
        assert_eq!(parts[0].stop, StopReason::ComplexLayer);
        assert_eq!(parts[1].segment.depth, 1);
        assert_eq!(parts[1].stop, StopReason::ComplexLayer);
    }

    #[test]
    fn skip_connections_skew_deeper() {
        // Two identical chains; one gains a skip edge that crosses what
        // would otherwise be the segment boundary. Crossing skips inflate
        // the activation side, so the skipped version pipelines deeper or
        // equal at every segment start.
        let plain = workloads::synthetic::aw_chain(0.05, 8);
        let mut skipped = workloads::synthetic::aw_chain(0.05, 8);
        skipped.add_edge(0, 4);
        let d_plain = partition(&plain, &cfg())[0].segment.depth;
        let d_skip = partition(&skipped, &cfg())[0].segment.depth;
        assert!(
            d_skip >= d_plain,
            "skip should not reduce depth: {d_skip} vs {d_plain}"
        );
    }

    #[test]
    fn segments_tile_every_zoo_model() {
        for g in workloads::all_tasks() {
            let parts = partition(&g, &cfg());
            let segs: Vec<_> = parts.iter().map(|p| p.segment.clone()).collect();
            segments_cover(&segs, g.num_layers()).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn eye_segmentation_pipelines_deeper_than_action_segmentation() {
        // Fig. 16 shape: RITNet-like eye segmentation has the most deep
        // regions; TCN action segmentation stays shallow.
        let mean_depth = |g: &ModelGraph| {
            let parts = partition(g, &cfg());
            parts.iter().map(|p| p.segment.depth as f64).sum::<f64>() / parts.len() as f64
        };
        let eye = mean_depth(&workloads::eye_segmentation());
        let act = mean_depth(&workloads::action_segmentation());
        assert!(eye > act, "eye {eye} vs action {act}");
        assert!(eye >= 2.0, "eye should pipeline, mean depth {eye}");
    }
}
