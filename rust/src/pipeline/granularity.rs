//! Algorithm 1: determine the finest possible pipelining granularity
//! between a producer/consumer pair from their intra-operation loop orders.
//!
//! Walk both loop nests from the outermost level. At each level the pair is
//! *fusible* iff:
//!  1. the producer's rank at this level indexes its output tensor (a
//!     contracted rank here would need complete sums earlier — Fig. 4c);
//!  2. the consumer's rank at this level is the corresponding rank under
//!     which it reads the shared tensor (Fig. 4b — same outermost loop), and
//!     is not one of the consumer's unshared ranks;
//!  3. tile sizes agree — on mismatch the pair only synchronizes every
//!     `LCM(tile_p, tile_c)` iterations (Sec. III-C), so fusion stops.
//!
//! The granularity is the portion of the intermediate tensor produced per
//! iteration of the fused prefix: `volume / Π trips(fused ranks)`.

use crate::dataflow::{producer_to_consumer_rank, LoopNest};
use crate::ir::Layer;
use crate::util::lcm;

/// The finest pipelining granularity of a producer→consumer handoff.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Granularity {
    /// Words of the intermediate tensor exchanged per pipeline interval.
    pub words: u64,
    /// Number of pipeline intervals (= iterations of the fused prefix).
    pub intervals: u64,
    /// How many loop levels fused.
    pub fused_levels: usize,
    /// Human-readable fused prefix, e.g. `"NH"`.
    pub fused_prefix: String,
}

impl Granularity {
    /// Granularity as a fraction of the full intermediate tensor.
    pub fn fraction(&self, total_words: u64) -> f64 {
        if total_words == 0 {
            1.0
        } else {
            self.words as f64 / total_words as f64
        }
    }

    /// Whole-tensor handoff (no pipelining possible): one interval.
    pub fn whole(total_words: u64) -> Self {
        Granularity {
            words: total_words,
            intervals: 1,
            fused_levels: 0,
            fused_prefix: String::new(),
        }
    }
}

/// Algorithm 1 over explicit loop nests.
///
/// `intermediate_words` is the producer-output volume shared with the
/// consumer.
pub fn pair_granularity(
    producer: &LoopNest,
    consumer: &LoopNest,
    intermediate_words: u64,
) -> Granularity {
    let mut intervals: u64 = 1;
    let mut fused = 0usize;
    let mut prefix = String::new();
    let out_ranks = producer.output_ranks();

    for (dp, dc) in producer.dims.iter().zip(consumer.dims.iter()) {
        // Condition 1/Fig. 4c: producer rank must index the output (not be
        // contracted) for staging at this level.
        if !out_ranks.contains(&dp.rank) {
            break;
        }
        // Condition 2/Fig. 4b: consumer must read the shared tensor under
        // the corresponding rank at the same level.
        let Some(expected) = producer_to_consumer_rank(producer.op_kind, consumer.op_kind, dp.rank)
        else {
            break;
        };
        if dc.rank != expected {
            break;
        }
        // Skip unit-extent levels: they fuse trivially but add no intervals.
        if dp.extent <= 1 && dc.extent <= 1 {
            fused += 1;
            prefix.push(dp.rank.letter());
            continue;
        }
        // Condition 3/Sec. III-C: tile sizes must agree, otherwise the pair
        // only synchronizes at LCM boundaries — stop fusing and absorb the
        // LCM factor into this level's effective tile.
        if dp.tile != dc.tile {
            let sync = lcm(dp.tile.max(1), dc.tile.max(1));
            let trips = crate::util::ceil_div(dp.extent.max(dc.extent), sync);
            if trips > 1 {
                intervals = intervals.saturating_mul(trips);
                fused += 1;
                prefix.push(dp.rank.letter());
            }
            break;
        }
        intervals = intervals.saturating_mul(dp.trips().max(1));
        fused += 1;
        prefix.push(dp.rank.letter());
    }

    if fused == 0 || intervals <= 1 {
        return Granularity::whole(intermediate_words);
    }
    Granularity {
        words: crate::util::ceil_div(intermediate_words, intervals),
        intervals,
        fused_levels: fused,
        fused_prefix: prefix,
    }
}

/// Convenience: finest granularity between two layers under given styles.
pub fn finest_granularity(
    producer: &Layer,
    producer_nest: &LoopNest,
    consumer_nest: &LoopNest,
) -> Granularity {
    pair_granularity(producer_nest, consumer_nest, producer.output_act_words())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{DataflowStyle, LoopNest, Rank};
    use crate::ir::{Layer, Op};

    fn conv_pair(style_p: DataflowStyle, style_c: DataflowStyle) -> (Layer, LoopNest, LoopNest) {
        let p = Layer::new("p", Op::conv2d(1, 32, 32, 16, 16, 3, 3, 1, 1));
        let c = Layer::new("c", Op::conv2d(1, 32, 32, 16, 16, 3, 3, 1, 1));
        let np = LoopNest::for_op(&p.op, style_p);
        let nc = LoopNest::for_op(&c.op, style_c);
        (p, np, nc)
    }

    #[test]
    fn paper_example_nhwkcrs_nhwckrs_is_finest() {
        // Producer NHWKCRS, consumer NHWCKRS: N,H,W all fuse, then producer
        // K maps to consumer C at level 3 → fuse through K as well?
        // Producer level-3 rank K is an output rank and maps to consumer C,
        // which is the consumer's level-3 rank → fusible; granularity is a
        // single (n,h,w,*) K-vector per interval... but K itself produces
        // per-k elements consumed as c. Alg. 1 fuses while ranks correspond.
        let (p, np, nc) = conv_pair(
            DataflowStyle::ActivationStationary, // NHWKCRS
            DataflowStyle::InputStationary,      // NHWCKRS
        );
        let g = finest_granularity(&p, &np, &nc);
        assert!(g.fused_prefix.starts_with("NHW"), "{}", g.fused_prefix);
        // at least one element per (h,w) position: very fine
        assert!(g.words <= 16, "words={}", g.words);
        assert_eq!(g.words * g.intervals >= p.output_act_words(), true);
    }

    #[test]
    fn paper_example_nhwkcrs_nhkwcrs_coarser() {
        // Consumer NHKWCRS: fuses only through N,H ("layers can only be
        // staged by NH").
        let p = Layer::new("p", Op::conv2d(1, 32, 32, 16, 16, 3, 3, 1, 1));
        let np = LoopNest::for_op(&p.op, DataflowStyle::ActivationStationary); // NHWKCRS
        let nc = LoopNest::for_op(&p.op, DataflowStyle::MixedActivation); // NHKCWRS
        let g = finest_granularity(&p, &np, &nc);
        assert_eq!(g.fused_prefix, "NH");
        // one output row (W*K words) per interval
        assert_eq!(g.words, 32 * 16);
        assert_eq!(g.intervals, 32);
    }

    #[test]
    fn weight_stationary_producer_cannot_pipeline() {
        // KCNHWRS producer: K is an output rank, but C at level 1 stops
        // fusion after K... K fuses (maps to consumer C)? Consumer
        // activation-stationary NHWKCRS has N at level 0 ≠ expected C → no
        // fusion at all → whole-tensor granularity.
        let (p, np, nc) = conv_pair(
            DataflowStyle::WeightStationary,
            DataflowStyle::ActivationStationary,
        );
        let g = finest_granularity(&p, &np, &nc);
        assert_eq!(g.fused_levels, 0);
        assert_eq!(g.words, p.output_act_words());
        assert_eq!(g.intervals, 1);
    }

    #[test]
    fn gemm_mnk_mkn_is_finest() {
        // Producer MNK (H,K,C), consumer MKN (H,C,K): M fuses, then producer
        // N→consumer K? producer rank K maps to consumer C; consumer level-1
        // rank is C → fuse. (The paper: "MNK-MKN is the finest grained
        // pipelining possible".)
        let p = Layer::new("p", Op::gemm(64, 32, 32));
        let np = LoopNest::for_op(&p.op, DataflowStyle::ActivationStationary); // H K C
        let nc = LoopNest::for_op(&Op::gemm(64, 32, 32), DataflowStyle::InputStationary); // H C K
        let g = finest_granularity(&p, &np, &nc);
        assert_eq!(g.fused_prefix, "HK");
        assert_eq!(g.words, 1); // element-grain
    }

    #[test]
    fn gemm_mnk_mnk_coarser() {
        // MNK-MNK: consumer level-1 rank K(cols) ≠ expected C → only M
        // fuses → one output row per interval.
        let p = Layer::new("p", Op::gemm(64, 32, 48));
        let np = LoopNest::for_op(&p.op, DataflowStyle::ActivationStationary);
        let nc = LoopNest::for_op(&Op::gemm(64, 48, 16), DataflowStyle::ActivationStationary);
        let g = finest_granularity(&p, &np, &nc);
        assert_eq!(g.fused_prefix, "H");
        assert_eq!(g.words, 48);
        assert_eq!(g.intervals, 64);
    }

    #[test]
    fn tile_mismatch_stops_fusion_at_lcm() {
        // Sec. III-C: differing H tiles synchronize at LCM(tile_p, tile_c).
        let p = Layer::new("p", Op::conv2d(1, 32, 32, 16, 16, 3, 3, 1, 1));
        let mut np = LoopNest::for_op(&p.op, DataflowStyle::ActivationStationary);
        let mut nc = LoopNest::for_op(&p.op, DataflowStyle::ActivationStationary);
        np.set_tile(Rank::H, 2);
        nc.set_tile(Rank::H, 3);
        let g = finest_granularity(&p, &np, &nc);
        // N fuses (unit), H stops with LCM(2,3)=6 → ceil(32/6)=6 intervals.
        assert_eq!(g.fused_prefix, "NH");
        assert_eq!(g.intervals, 6);
        assert_eq!(g.words, crate::util::ceil_div(p.output_act_words(), 6));
    }

    #[test]
    fn equal_tiles_fuse_normally() {
        let p = Layer::new("p", Op::conv2d(1, 32, 32, 16, 16, 3, 3, 1, 1));
        let mut np = LoopNest::for_op(&p.op, DataflowStyle::ActivationStationary);
        let mut nc = LoopNest::for_op(&p.op, DataflowStyle::ActivationStationary);
        np.set_tile(Rank::H, 4);
        nc.set_tile(Rank::H, 4);
        let g = finest_granularity(&p, &np, &nc);
        assert!(g.fused_prefix.starts_with("NH"));
        // H contributes ceil(32/4) = 8 intervals, then W level continues
        // fusing (same style) etc.
        assert!(g.intervals >= 8);
    }

    #[test]
    fn granularity_times_intervals_covers_tensor() {
        // Invariant: words * intervals >= total (ceil rounding).
        let (p, np, nc) = conv_pair(
            DataflowStyle::ActivationStationary,
            DataflowStyle::ActivationStationary,
        );
        let g = finest_granularity(&p, &np, &nc);
        assert!(g.words * g.intervals >= p.output_act_words());
        assert!((g.words - 1) * g.intervals < p.output_act_words());
    }

    #[test]
    fn whole_granularity_fraction() {
        let g = Granularity::whole(1000);
        assert_eq!(g.fraction(1000), 1.0);
        assert_eq!(g.intervals, 1);
    }
}
