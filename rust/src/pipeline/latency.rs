//! The pipeline latency equations of Fig. 3.
//!
//! A pipelined segment is a waterfall of stages. Each stage `s` processes
//! the intermediate tensor in intervals; the delay of one interval at stage
//! `s` is the max of its own work (compute/communication) and the
//! producer-side delay — the previous stage's interval delay *normalized by
//! the ratio of work covered by the current vs previous interval* (variable
//! granularity / load imbalance): one consumer interval consumes
//! `T_prev / T_cur` producer intervals' worth of data, so it cannot start
//! faster than `d_prev · T_prev / T_cur`. Overall latency = every interval
//! delay summed once (this covers init/ramp-up) + steady-state of the last
//! stage.

/// Per-stage interval characterization.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageInterval {
    /// Cycles of compute per interval at this stage (temporal reduction
    /// inside the PEs to produce one granularity unit).
    pub compute_delay: f64,
    /// Cycles of NoC/global-buffer communication per interval.
    pub comm_delay: f64,
    /// Number of intervals this stage runs (its granularity count).
    pub intervals: u64,
}

impl StageInterval {
    /// The stage's own per-interval delay, before producer coupling.
    pub fn own_delay(&self) -> f64 {
        self.compute_delay.max(self.comm_delay)
    }
}

/// Result of the Fig. 3 composition.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineLatency {
    /// Effective (producer-coupled) interval delay per stage.
    pub stage_delays: Vec<f64>,
    /// Σ stage delays — the init / ramp-up term.
    pub init: f64,
    /// Steady-state term: (intervals_last − 1) × last stage delay.
    pub steady: f64,
    /// init + steady.
    pub total: f64,
}

/// Compose per-stage interval delays per Fig. 3.
pub fn pipeline_latency(stages: &[StageInterval]) -> PipelineLatency {
    assert!(!stages.is_empty(), "empty pipeline");
    let mut delays = Vec::with_capacity(stages.len());
    let mut prev_delay = 0.0f64;
    let mut prev_t = 0u64;
    for (i, s) in stages.iter().enumerate() {
        let own = s.own_delay();
        let producer_side = if i == 0 {
            0.0
        } else {
            // One interval here consumes T_prev/T_cur producer intervals.
            prev_delay * (prev_t.max(1) as f64 / s.intervals.max(1) as f64)
        };
        let d = own.max(producer_side);
        delays.push(d);
        prev_delay = d;
        prev_t = s.intervals;
    }
    let init: f64 = delays.iter().sum();
    let last = *delays.last().unwrap();
    let last_intervals = stages.last().unwrap().intervals.max(1);
    let steady = (last_intervals - 1) as f64 * last;
    PipelineLatency {
        stage_delays: delays,
        init,
        steady,
        total: init + steady,
    }
}

/// Latency of running a single stage alone (op-by-op): intervals × delay.
pub fn solo_latency(stage: &StageInterval) -> f64 {
    stage.intervals.max(1) as f64 * stage.own_delay()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(compute: f64, comm: f64, intervals: u64) -> StageInterval {
        StageInterval {
            compute_delay: compute,
            comm_delay: comm,
            intervals,
        }
    }

    #[test]
    fn single_stage_is_solo() {
        let s = st(10.0, 2.0, 100);
        let l = pipeline_latency(&[s]);
        assert_eq!(l.total, 10.0 + 99.0 * 10.0);
        assert_eq!(l.total, solo_latency(&s));
    }

    #[test]
    fn balanced_two_stage_overlaps() {
        // Two balanced stages, T intervals each: total = d + d + (T-1)d
        // = (T+1)d, vs op-by-op 2*T*d → speedup → 2 as T grows.
        let s = st(8.0, 0.0, 64);
        let l = pipeline_latency(&[s, s]);
        assert_eq!(l.total, 8.0 * (64.0 + 1.0));
        let op_by_op = 2.0 * solo_latency(&s);
        assert!(op_by_op / l.total > 1.9);
    }

    #[test]
    fn bottleneck_stage_dominates() {
        let fast = st(2.0, 0.0, 32);
        let slow = st(10.0, 0.0, 32);
        let l = pipeline_latency(&[fast, slow, fast]);
        // Stage 2's producer side = 10 × (32/32) = 10 > own 2 → inherits 10.
        assert_eq!(l.stage_delays, vec![2.0, 10.0, 10.0]);
        assert_eq!(l.total, 22.0 + 31.0 * 10.0);
    }

    #[test]
    fn granularity_mismatch_scales_producer_delay() {
        // Consumer runs half as many intervals as the producer → each
        // consumer interval waits for 2 producer intervals.
        let p = st(5.0, 0.0, 64);
        let c = st(3.0, 0.0, 32);
        let l = pipeline_latency(&[p, c]);
        assert_eq!(l.stage_delays[1], 10.0);
        // Totals stay O(max stage work) regardless of interval mismatch:
        // producer work 320, pipeline total = 5 + 10 + 31*10 = 325.
        assert_eq!(l.total, 325.0);
    }

    #[test]
    fn finer_consumer_does_not_stall() {
        // Consumer with 2× the intervals of the producer: each interval
        // needs half a producer interval → producer side 2.5 < own 3.
        let p = st(5.0, 0.0, 32);
        let c = st(3.0, 0.0, 64);
        let l = pipeline_latency(&[p, c]);
        assert_eq!(l.stage_delays[1], 3.0);
    }

    #[test]
    fn comm_bound_interval_uses_comm_delay() {
        // Congested NoC: hop/congestion delay exceeds compute interval —
        // the Fig. 8 "interval becomes hop-count-bound" case.
        let s = st(2.0, 16.0, 10);
        let l = pipeline_latency(&[s, s]);
        assert_eq!(l.stage_delays[0], 16.0);
        assert_eq!(l.total, 32.0 + 9.0 * 16.0);
    }

    #[test]
    fn mixed_interval_chain_is_stable() {
        // A long chain with wildly differing interval counts must stay
        // O(max stage work), not blow up multiplicatively.
        let stages = vec![
            st(1.0, 0.0, 61440),
            st(2560.0, 0.0, 24),
            st(1.0, 0.0, 61440),
            st(2560.0, 0.0, 24),
        ];
        let l = pipeline_latency(&stages);
        let max_work = 2560.0 * 24.0;
        assert!(
            l.total < 4.0 * max_work,
            "total {} ≫ max stage work {max_work}",
            l.total
        );
    }

    #[test]
    #[should_panic]
    fn empty_pipeline_panics() {
        pipeline_latency(&[]);
    }
}
