//! Stage 1 of the PipeOrgan flow (Sec. IV-A): partitioning the model into
//! pipeline segments of flexible depth, and deriving the finest possible
//! pipelining granularity from the intra-operator loop orders (Alg. 1).
//! Also the interval/latency equations of Fig. 3.

mod depth;
mod granularity;
mod latency;
pub(crate) mod segment;

pub use depth::{partition, DepthDecision, StopReason};
pub use granularity::{finest_granularity, pair_granularity, Granularity};
pub use latency::{pipeline_latency, solo_latency, PipelineLatency, StageInterval};
pub use segment::{segments_cover, Segment, SegmentPlan, StagePlan};
