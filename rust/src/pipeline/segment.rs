//! Pipeline segments: a contiguous run of layers executed concurrently on
//! the PE array, plus the per-stage dataflow decisions stage 1 attaches.

use crate::dataflow::{DataflowStyle, LoopNest};
use crate::ir::{LayerId, ModelGraph};

use super::granularity::Granularity;

/// A contiguous run `[start, start+depth)` of layers pipelined together.
/// `depth == 1` means the layer runs op-by-op (no pipelining).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    pub start: LayerId,
    pub depth: usize,
}

impl Segment {
    pub fn new(start: LayerId, depth: usize) -> Self {
        assert!(depth >= 1);
        Self { start, depth }
    }

    pub fn end(&self) -> LayerId {
        self.start + self.depth
    }

    pub fn layers(&self) -> impl Iterator<Item = LayerId> {
        self.start..self.end()
    }

    pub fn contains(&self, id: LayerId) -> bool {
        id >= self.start && id < self.end()
    }

    pub fn is_pipelined(&self) -> bool {
        self.depth > 1
    }
}

/// Stage-level plan: one pipelined layer with its chosen dataflow.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub layer: LayerId,
    pub style: DataflowStyle,
    pub nest: LoopNest,
    /// Granularity of the handoff *to the next stage* (None for the last
    /// stage of a segment or for op-by-op execution).
    pub handoff: Option<Granularity>,
}

/// A fully planned segment: stages in order plus aggregate properties.
#[derive(Debug, Clone)]
pub struct SegmentPlan {
    pub segment: Segment,
    pub stages: Vec<StagePlan>,
}

impl SegmentPlan {
    /// Sum of weights resident during this segment (the `Σ W_i` of the
    /// depth heuristic).
    pub fn weight_footprint_words(&self, graph: &ModelGraph) -> u64 {
        self.segment
            .layers()
            .map(|id| graph.layer(id).weight_words())
            .sum()
    }

    /// MACs per stage — the load-balancing input for PE allocation.
    pub fn stage_macs(&self, graph: &ModelGraph) -> Vec<u64> {
        self.segment
            .layers()
            .map(|id| graph.layer(id).macs())
            .collect()
    }

    /// Finest handoff granularity across stage pairs (words), if pipelined.
    pub fn min_handoff_words(&self) -> Option<u64> {
        self.stages
            .iter()
            .filter_map(|s| s.handoff.as_ref().map(|g| g.words))
            .min()
    }
}

/// Check that a list of segments exactly tiles `0..n_layers` in order.
pub fn segments_cover(segments: &[Segment], n_layers: usize) -> Result<(), String> {
    let mut next = 0;
    for s in segments {
        if s.start != next {
            return Err(format!(
                "segment at {} does not start where previous ended ({next})",
                s.start
            ));
        }
        next = s.end();
    }
    if next != n_layers {
        return Err(format!("segments cover {next} of {n_layers} layers"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_basics() {
        let s = Segment::new(3, 4);
        assert_eq!(s.end(), 7);
        assert!(s.contains(3) && s.contains(6) && !s.contains(7));
        assert!(s.is_pipelined());
        assert!(!Segment::new(0, 1).is_pipelined());
        assert_eq!(s.layers().collect::<Vec<_>>(), vec![3, 4, 5, 6]);
    }

    #[test]
    fn coverage_check() {
        let segs = vec![Segment::new(0, 2), Segment::new(2, 3), Segment::new(5, 1)];
        assert!(segments_cover(&segs, 6).is_ok());
        assert!(segments_cover(&segs, 7).is_err());
        let gap = vec![Segment::new(0, 2), Segment::new(3, 3)];
        assert!(segments_cover(&gap, 6).is_err());
    }

    #[test]
    #[should_panic]
    fn zero_depth_rejected() {
        Segment::new(0, 0);
    }
}
