//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! - **organization**: Sec. IV-B heuristic vs exhaustive (oracle) search;
//! - **topology**: PipeOrgan's spatial organizations on mesh / AMP / torus
//!   / flattened butterfly — isolating how much of the win is the NoC;
//! - **depth**: flexible depth vs hard caps 1/2/4/8 — isolating how much
//!   is the variable-depth heuristic (fixed depth 2 ≈ TANGRAM-style
//!   pairing but with PipeOrgan's organizations).

use crate::config::{ArchConfig, TopologyKind};
use crate::cost::{evaluate, Mapper};
use crate::mapper::{OracleOrganization, PipeOrgan};
use crate::util::json::Json;
use crate::util::stats::geomean;
use crate::util::table::{fnum, Table};
use crate::workloads;

use super::Report;

/// Heuristic vs oracle organization choice.
pub fn ablation_organization(cfg: &ArchConfig) -> Report {
    let mut table = Table::new(
        "Ablation — organization heuristic vs exhaustive search (cycles ratio; 1.0 = optimal)",
        &["task", "heuristic cycles", "oracle cycles", "heuristic/oracle"],
    );
    let mut ratios = Vec::new();
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for g in workloads::all_tasks() {
        let heur = evaluate(&g, &PipeOrgan::default().plan(&g, cfg), cfg).cycles;
        let orac = evaluate(&g, &OracleOrganization::default().plan(&g, cfg), cfg).cycles;
        let r = heur / orac;
        ratios.push(r);
        table.row(&[g.name.clone(), fnum(heur), fnum(orac), fnum(r)]);
        let mut t = Json::obj();
        t.set("task", g.name.clone())
            .set("heuristic_cycles", heur)
            .set("oracle_cycles", orac)
            .set("ratio", r);
        arr.push(t);
    }
    table.row(&[
        "GEOMEAN".into(),
        "".into(),
        "".into(),
        fnum(geomean(&ratios)),
    ]);
    json.set("rows", arr).set("geomean_gap", geomean(&ratios));
    Report {
        name: "ablation_organization",
        table,
        json,
    }
}

/// PipeOrgan across NoC topologies (normalized to mesh).
pub fn ablation_topology(cfg: &ArchConfig) -> Report {
    let kinds = [
        TopologyKind::Mesh,
        TopologyKind::Amp,
        TopologyKind::Torus,
        TopologyKind::FlattenedButterfly,
    ];
    let mut table = Table::new(
        "Ablation — topology (speedup over mesh; links relative to mesh)",
        &["task", "mesh", "AMP", "torus", "flattened butterfly"],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    let mut per_kind: Vec<Vec<f64>> = vec![Vec::new(); kinds.len()];
    for g in workloads::all_tasks() {
        let cycles: Vec<f64> = kinds
            .iter()
            .map(|&k| evaluate(&g, &PipeOrgan::on(k).plan(&g, cfg), cfg).cycles)
            .collect();
        let mesh = cycles[0];
        let mut row = vec![g.name.clone()];
        let mut t = Json::obj();
        t.set("task", g.name.clone());
        for (i, &k) in kinds.iter().enumerate() {
            let sp = mesh / cycles[i];
            per_kind[i].push(sp);
            row.push(fnum(sp));
            t.set(k.name(), sp);
        }
        table.row(&row);
        arr.push(t);
    }
    let mut row = vec!["GEOMEAN".to_string()];
    for r in &per_kind {
        row.push(fnum(geomean(r)));
    }
    table.row(&row);
    // link complexity context
    let mesh_links = crate::noc::Topology::new(TopologyKind::Mesh, cfg.pe_rows, cfg.pe_cols)
        .num_links() as f64;
    let mut links_row = vec!["links vs mesh".to_string()];
    for &k in &kinds {
        let l = crate::noc::Topology::new(k, cfg.pe_rows, cfg.pe_cols).num_links() as f64;
        links_row.push(fnum(l / mesh_links));
    }
    table.row(&links_row);
    json.set("rows", arr);
    Report {
        name: "ablation_topology",
        table,
        json,
    }
}

/// Flexible depth vs fixed caps.
pub fn ablation_depth(cfg: &ArchConfig) -> Report {
    let caps = [Some(1usize), Some(2), Some(4), Some(8), None];
    let cap_name = |c: Option<usize>| match c {
        Some(d) => format!("cap {d}"),
        None => "flexible".into(),
    };
    let mut table = Table::new(
        "Ablation — pipeline depth (speedup over depth-1 / op-by-op)",
        &["task", "cap 1", "cap 2", "cap 4", "cap 8", "flexible"],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    let mut per_cap: Vec<Vec<f64>> = vec![Vec::new(); caps.len()];
    for g in workloads::all_tasks() {
        let cycles: Vec<f64> = caps
            .iter()
            .map(|&c| {
                let m = match c {
                    Some(d) => PipeOrgan::with_depth_cap(d),
                    None => PipeOrgan::default(),
                };
                evaluate(&g, &m.plan(&g, cfg), cfg).cycles
            })
            .collect();
        let base = cycles[0];
        let mut row = vec![g.name.clone()];
        let mut t = Json::obj();
        t.set("task", g.name.clone());
        for (i, &c) in caps.iter().enumerate() {
            let sp = base / cycles[i];
            per_cap[i].push(sp);
            row.push(fnum(sp));
            t.set(&cap_name(c), sp);
        }
        table.row(&row);
        arr.push(t);
    }
    let mut row = vec!["GEOMEAN".to_string()];
    for r in &per_cap {
        row.push(fnum(geomean(r)));
    }
    table.row(&row);
    json.set("rows", arr);
    Report {
        name: "ablation_depth",
        table,
        json,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_ablation_flexible_wins_geomean() {
        // Flexible depth must beat the shallow caps (1, 2, 4) in geomean —
        // the core "variable depth matters" claim. Very deep segments pay
        // ramp-up, so cap-8 can land within a whisker of flexible; allow
        // 2 % there (the finding is recorded in DESIGN.md §Perf).
        let cfg = ArchConfig::default();
        let r = ablation_depth(&cfg);
        let last = r.table.rows.last().unwrap().clone();
        let flexible: f64 = last[5].parse().unwrap();
        for cap_col in 1..4 {
            let v: f64 = last[cap_col].parse().unwrap();
            assert!(
                flexible >= v - 1e-9,
                "flexible {flexible} < cap column {cap_col} = {v}"
            );
        }
        let cap8: f64 = last[4].parse().unwrap();
        assert!(flexible >= cap8 * 0.98, "flexible {flexible} ≪ cap8 {cap8}");
        assert!(flexible > 1.05, "pipelining should help: {flexible}");
    }

    #[test]
    fn topology_ablation_amp_geomean_ge_one() {
        let cfg = ArchConfig::default();
        let r = ablation_topology(&cfg);
        let geo_row = &r.table.rows[r.table.rows.len() - 2];
        let amp: f64 = geo_row[2].parse().unwrap();
        assert!(amp >= 1.0, "AMP geomean {amp} < mesh");
    }
}
