//! Latency-attribution report: windowed bottleneck breakdown, SLO burn
//! rate, and top-k worst requests with critical paths, per (scenario,
//! policy) — the `pipeorgan serve --attr-out` artifact plus the `attr`
//! block embedded in the serve report (see docs/OBSERVABILITY.md).
//!
//! The observed side comes from the engine's per-request
//! [`RequestAttr`] records (`obs::attr`); the predicted side comes from
//! the serving plan's per-task [`ServedCost`] split
//! (`floor_cycles` / `nominal_cycles − floor_cycles`), so
//! predicted-vs-observed skew is a first-class column rather than a
//! post-hoc join.

use crate::obs::attr::{
    burn_rate, by_region, by_task, windowed, worst_k, GroupAttr, RequestAttr, DEFAULT_SLO_BUDGET,
    DEFAULT_WINDOWS,
};
use crate::serve::{ServeOutcome, ServePlan, ServeRun};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::Report;

/// Schema tag stamped on the standalone attribution document so
/// `tools/trace_check.py` can dispatch its validation mode.
pub const ATTR_SCHEMA: &str = "pipeorgan-attr-v1";

/// Worst requests carried in the report's per-policy block.
const WORST_K_REPORT: usize = 5;

/// Worst requests tabulated in the flight-recorder document.
const WORST_K_FLIGHT: usize = 10;

/// One request as JSON plus its explicit critical path: the observed
/// legs in lifecycle order (queue → compute → dram), which sum to the
/// measured latency modulo the canonical-order donation bookkeeping.
fn request_json(a: &RequestAttr) -> Json {
    let mut j = a.to_json();
    let mut path = Json::Arr(vec![]);
    for (leg, v) in a.components() {
        let mut e = Json::obj();
        e.set("leg", leg).set("s", v);
        path.push(e);
    }
    j.set("critical_path", path);
    j
}

fn group_json(g: &GroupAttr, key_name: &str) -> Json {
    let mut j = Json::obj();
    j.set(key_name, g.key)
        .set("completed", g.completed)
        .set("dropped", g.dropped)
        .set("missed", g.missed)
        .set("queue_s", g.queue_s)
        .set("floor_s", g.floor_s)
        .set("dram_s", g.dram_s)
        .set("donation_s", g.donation_s)
        .set("latency_s", g.latency_s);
    j
}

/// The largest mean observed component of a group (the rollup analogue
/// of [`RequestAttr::dominant`]); "policy" when the group only dropped.
fn group_dominant(g: &GroupAttr) -> &'static str {
    if g.completed == 0 {
        return if g.dropped > 0 { "policy" } else { "idle" };
    }
    let mut best = ("queue", f64::NEG_INFINITY);
    for (name, v) in [
        ("queue", g.queue_s),
        ("compute", g.floor_s),
        ("dram", g.dram_s),
    ] {
        if v > best.1 {
            best = (name, v);
        }
    }
    best.0
}

/// Predicted per-inference (floor ms, dram-stretch ms) of `task` on its
/// home region, from the plan's service-cost matrix diagonal.
fn predicted_ms(plan: &ServePlan, task: usize) -> Option<(f64, f64)> {
    let c = plan.costs.get(task)?.get(task)?;
    let clock = plan.clock_hz.max(1.0);
    Some((
        c.floor_cycles / clock * 1e3,
        (c.nominal_cycles - c.floor_cycles) / clock * 1e3,
    ))
}

/// One policy's full attribution block, or `None` when the outcome
/// carries no records (attribution disabled, or nothing arrived).
pub fn policy_attr_json(plan: &ServePlan, o: &ServeOutcome) -> Option<Json> {
    if o.attr.is_empty() {
        return None;
    }
    let window_s = (o.span_s / DEFAULT_WINDOWS as f64).max(1e-9);

    let mut totals = GroupAttr {
        key: 0,
        completed: 0,
        dropped: 0,
        missed: 0,
        queue_s: 0.0,
        floor_s: 0.0,
        dram_s: 0.0,
        donation_s: 0.0,
        latency_s: 0.0,
    };
    for a in &o.attr {
        if a.missed() {
            totals.missed += 1;
        }
        if a.completed() {
            totals.completed += 1;
            totals.queue_s += a.queue_s;
            totals.floor_s += a.floor_s;
            totals.dram_s += a.actual_stretch_s();
            totals.donation_s += a.donation_s;
            totals.latency_s += a.latency_s;
        } else {
            totals.dropped += 1;
        }
    }
    let mut totals_json = group_json(&totals, "requests");
    totals_json
        .set("requests", o.attr.len())
        .set("dominant", group_dominant(&totals));

    let mut tasks = Json::Arr(vec![]);
    for g in by_task(&o.attr) {
        let mut t = group_json(&g, "task");
        if let Some(m) = o.tasks.get(g.key) {
            t.set("name", m.task.clone());
        }
        t.set("mean_queue_ms", g.mean(g.queue_s) * 1e3)
            .set("mean_compute_ms", g.mean(g.floor_s) * 1e3)
            .set("mean_dram_ms", g.mean(g.dram_s) * 1e3)
            .set("mean_donation_ms", g.mean(g.donation_s) * 1e3)
            .set("mean_latency_ms", g.mean(g.latency_s) * 1e3)
            .set("dominant", group_dominant(&g));
        if let Some((floor_ms, dram_ms)) = predicted_ms(plan, g.key) {
            // Skew: observed mean service time vs the plan's nominal
            // (floor + static-share stretch) prediction, in percent.
            // Positive = slower than planned (contention, borrowing a
            // foreign region); negative = donation sped service up.
            let pred = floor_ms + dram_ms;
            let obs = g.mean(g.floor_s + g.dram_s) * 1e3;
            t.set("pred_compute_ms", floor_ms).set("pred_dram_ms", dram_ms);
            if pred > 0.0 && g.completed > 0 {
                t.set("skew_pct", 100.0 * (obs - pred) / pred);
            }
        }
        tasks.push(t);
    }

    let mut regions = Json::Arr(vec![]);
    for g in by_region(&o.attr) {
        let mut r = group_json(&g, "region");
        r.set("dominant", group_dominant(&g));
        regions.push(r);
    }

    let mut windows = Json::Arr(vec![]);
    for w in windowed(&o.attr, window_s) {
        windows.push(w.to_json());
    }
    let mut burn = Json::Arr(vec![]);
    for b in burn_rate(&o.attr, window_s, DEFAULT_SLO_BUDGET) {
        burn.push(b.to_json());
    }
    let mut worst = Json::Arr(vec![]);
    for a in worst_k(&o.attr, WORST_K_REPORT) {
        worst.push(request_json(a));
    }

    let mut j = Json::obj();
    j.set("window_s", window_s)
        .set("slo_budget", DEFAULT_SLO_BUDGET)
        .set("totals", totals_json)
        .set("tasks", tasks)
        .set("regions", regions)
        .set("windows", windows)
        .set("burn", burn)
        .set("worst", worst);
    Some(j)
}

/// Tabulate the flight-recorder's attribution context: the worst
/// completed requests (exact seconds, full precision) so the frozen
/// trace snippet ships with the numbers that explain it.
pub fn flight_table_json(o: &ServeOutcome) -> Json {
    let mut rows = Json::Arr(vec![]);
    for a in worst_k(&o.attr, WORST_K_FLIGHT) {
        rows.push(request_json(a));
    }
    let mut j = Json::obj();
    j.set("policy", o.policy.name())
        .set("scenario", o.scenario.clone())
        .set("requests", o.attr.len())
        .set("worst", rows);
    j
}

/// The standalone attribution report (`--attr-out`, `report/attr.*`):
/// one stacked-breakdown row per (scenario, policy, task) plus the
/// top-[`WORST_K_REPORT`] worst requests per policy, with the plan's
/// predicted compute/DRAM split and the skew column beside the
/// observed means. `None` when no outcome recorded attribution.
pub fn attr_report(runs: &[ServeRun]) -> Option<Report> {
    let mut table = Table::new(
        "Attr — critical-path latency attribution (observed vs plan-predicted)",
        &[
            "scenario",
            "policy",
            "row",
            "who",
            "queue ms",
            "compute ms",
            "dram ms",
            "donation ms",
            "latency ms",
            "pred compute ms",
            "pred dram ms",
            "skew %",
            "dominant",
        ],
    );
    let mut scenarios = Json::Arr(vec![]);
    let mut any = false;
    for r in runs {
        let mut policies = Json::Arr(vec![]);
        for o in &r.outcomes {
            let Some(mut block) = policy_attr_json(&r.plan, o) else {
                continue;
            };
            any = true;
            block.set("policy", o.policy.name());
            for g in by_task(&o.attr) {
                let who = o
                    .tasks
                    .get(g.key)
                    .map(|m| m.task.clone())
                    .unwrap_or_else(|| format!("task{}", g.key));
                let pred = predicted_ms(&r.plan, g.key);
                let skew = pred.and_then(|(f, d)| {
                    let p = f + d;
                    (p > 0.0 && g.completed > 0)
                        .then(|| 100.0 * (g.mean(g.floor_s + g.dram_s) * 1e3 - p) / p)
                });
                table.row(&[
                    r.scenario.clone(),
                    o.policy.name().to_string(),
                    "task".into(),
                    who,
                    fnum(g.mean(g.queue_s) * 1e3),
                    fnum(g.mean(g.floor_s) * 1e3),
                    fnum(g.mean(g.dram_s) * 1e3),
                    fnum(g.mean(g.donation_s) * 1e3),
                    fnum(g.mean(g.latency_s) * 1e3),
                    pred.map(|(f, _)| fnum(f)).unwrap_or_default(),
                    pred.map(|(_, d)| fnum(d)).unwrap_or_default(),
                    skew.map(fnum).unwrap_or_default(),
                    group_dominant(&g).into(),
                ]);
            }
            for a in worst_k(&o.attr, WORST_K_REPORT) {
                let who = o
                    .tasks
                    .get(a.task)
                    .map(|m| format!("{}#{}", m.task, a.id))
                    .unwrap_or_else(|| format!("task{}#{}", a.task, a.id));
                table.row(&[
                    r.scenario.clone(),
                    o.policy.name().to_string(),
                    "worst".into(),
                    who,
                    fnum(a.queue_s * 1e3),
                    fnum(a.floor_s * 1e3),
                    fnum(a.actual_stretch_s() * 1e3),
                    fnum(a.donation_s * 1e3),
                    fnum(a.latency_s * 1e3),
                    "".into(),
                    "".into(),
                    "".into(),
                    a.dominant().into(),
                ]);
            }
            policies.push(block);
        }
        let mut s = Json::obj();
        s.set("scenario", r.scenario.clone()).set("policies", policies);
        scenarios.push(s);
    }
    if !any {
        return None;
    }
    let mut json = Json::obj();
    json.set("schema", ATTR_SCHEMA).set("scenarios", scenarios);
    Some(Report {
        name: "attr",
        table,
        json,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::cosched::{Scenario, TaskSpec};
    use crate::dse::EvalCache;
    use crate::serve::{run_scenario, Policy, ServeConfig};
    use crate::workloads::synthetic;

    fn runs() -> Vec<ServeRun> {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let mut a = synthetic::aw_chain(2.0, 4);
        a.name = "a".into();
        let mut b = synthetic::pointwise_conv_segment(2);
        b.name = "b".into();
        let sc = Scenario::new("pair", vec![TaskSpec::new(a, 30.0), TaskSpec::new(b, 60.0)]);
        let sv = ServeConfig {
            policies: vec![Policy::Fifo, Policy::Edf],
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        vec![run_scenario(&sc, &cfg, &sv, &EvalCache::new(), 1).unwrap()]
    }

    #[test]
    fn attr_report_tabulates_tasks_and_worst_and_parses() {
        let runs = runs();
        let r = attr_report(&runs).expect("attr recorded by default");
        assert_eq!(r.name, "attr");
        let md = r.table.to_markdown();
        for needle in ["task", "worst", "dominant", "skew %"] {
            assert!(md.contains(needle), "missing {needle} in:\n{md}");
        }
        // 2 policies × (2 task rows + ≤5 worst rows); at least one worst
        // row exists because something completed.
        assert!(r.table.rows.len() >= 2 * 2 + 2, "rows: {}", r.table.rows.len());
        let text = r.json.to_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(|s| s.as_str()),
            Some(ATTR_SCHEMA)
        );
        let scenarios = parsed.get("scenarios").and_then(|s| s.as_arr()).unwrap();
        let policies = scenarios[0].get("policies").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(policies.len(), 2);
        for p in policies {
            for key in ["totals", "tasks", "regions", "windows", "burn", "worst"] {
                assert!(p.get(key).is_some(), "policy block missing {key}");
            }
            // Windows are contiguous and time-ordered.
            let ws = p.get("windows").and_then(|w| w.as_arr()).unwrap();
            for pair in ws.windows(2) {
                let t1 = pair[0].get("t1_s").and_then(|v| v.as_f64()).unwrap();
                let t0 = pair[1].get("t0_s").and_then(|v| v.as_f64()).unwrap();
                assert!((t1 - t0).abs() < 1e-12, "windows tile the span");
            }
            // Worst rows conserve: queue + compute + dram ≈ latency
            // (reassociated here, so float tolerance rather than the
            // bit-exact canonical form trace_check.py asserts).
            for w in p.get("worst").and_then(|w| w.as_arr()).unwrap() {
                let f = |k: &str| w.get(k).and_then(|v| v.as_f64()).unwrap();
                let path = w.get("critical_path").and_then(|c| c.as_arr()).unwrap();
                assert_eq!(path.len(), 3);
                let sum: f64 = path.iter().map(|e| e.get("s").and_then(|v| v.as_f64()).unwrap()).sum();
                assert!(
                    (sum - f("latency_s")).abs() <= 1e-12 * f("latency_s").max(1e-9),
                    "critical path legs must cover the latency"
                );
            }
        }
    }

    #[test]
    fn attr_report_is_none_without_records() {
        let mut runs = runs();
        for o in &mut runs[0].outcomes {
            o.attr.clear();
        }
        assert!(attr_report(&runs).is_none());
        assert!(policy_attr_json(&runs[0].plan, &runs[0].outcomes[0]).is_none());
    }

    #[test]
    fn flight_table_lists_worst_requests_with_paths() {
        let runs = runs();
        let o = &runs[0].outcomes[0];
        let j = flight_table_json(o);
        let text = j.to_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("requests").and_then(|v| v.as_usize()),
            Some(o.attr.len())
        );
        let worst = parsed.get("worst").and_then(|w| w.as_arr()).unwrap();
        assert!(!worst.is_empty() && worst.len() <= 10);
        for w in worst {
            assert!(w.get("critical_path").is_some());
            assert_eq!(w.get("outcome").and_then(|v| v.as_str()), Some("completed"));
        }
    }
}
