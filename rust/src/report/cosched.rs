//! Co-scheduling report: per-task latency/energy and scenario makespan for
//! solo-array vs naive even-split vs co-scheduled allocations (the
//! `pipeorgan cosched` artifact; see DESIGN.md §Cosched).

use crate::config::ArchConfig;
use crate::cosched::{CoschedOutcome, CoschedResult};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::Report;

fn outcome_json(o: &CoschedOutcome) -> Json {
    let mut tasks = Json::Arr(vec![]);
    for a in &o.assignments {
        let mut t = Json::obj();
        t.set("task", a.task.clone())
            .set("region_rows", a.region.rows)
            .set("region_cols", a.region.cols)
            .set("region_row0", a.region.row0)
            .set("region_col0", a.region.col0)
            .set("topology", a.topology.name())
            .set("rate_hz", a.rate_hz)
            .set("invocations", a.invocations)
            .set("latency_cycles", a.latency_cycles)
            .set("latency_ms", a.latency_ms)
            .set("deadline_ms", a.deadline_ms)
            .set("slack_ms", a.slack_ms())
            .set("busy_cycles", a.busy_cycles)
            .set("energy_per_inference", a.energy)
            .set("frame_energy", a.frame_energy())
            .set("dram_words_per_inference", a.dram_words)
            .set("worst_channel_load", a.worst_channel_load)
            // Plan-time predicted latency split — the skew baseline the
            // serve-side `attr` report compares observed behavior against.
            .set("floor_cycles", a.floor_cycles)
            .set("stretch_cycles", a.stretch_cycles)
            .set("deadline_met", a.deadline_met);
        tasks.push(t);
    }
    let mut out = Json::obj();
    out.set("mode", o.mode)
        .set("makespan_cycles", o.makespan_cycles)
        .set("energy", o.energy)
        .set("tasks", tasks);
    out
}

/// One table row per (scenario, mode, task) plus a MAKESPAN rollup row per
/// mode whose `cut tree` cell carries the winning partition's compact
/// [`crate::cosched::CutTree::encode`] rendering; JSON mirrors the full
/// nested structure (per-region geometry and topology, the serialized cut
/// tree, and the ASCII occupancy rendering of the co-scheduled placement).
pub fn cosched_report(cfg: &ArchConfig, results: &[CoschedResult]) -> Report {
    let mut table = Table::new(
        "Cosched — concurrent XR tasks on one shared PE array",
        &[
            "scenario",
            "mode",
            "task",
            "region",
            "topo",
            "rate Hz",
            "latency cycles",
            "busy cycles",
            "deadline",
            "slack ms",
            "frame energy",
            "worst chan load",
            "cut tree",
        ],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for r in results {
        for o in [&r.solo, &r.even_split, &r.cosched] {
            for a in &o.assignments {
                let slack = a.slack_ms();
                table.row(&[
                    r.scenario.clone(),
                    o.mode.to_string(),
                    a.task.clone(),
                    format!(
                        "{}x{}@r{}c{}",
                        a.region.rows, a.region.cols, a.region.row0, a.region.col0
                    ),
                    a.topology.name().to_string(),
                    fnum(a.rate_hz),
                    fnum(a.latency_cycles),
                    fnum(a.busy_cycles),
                    if a.deadline_met { "met" } else { "MISS" }.to_string(),
                    // Negative slack (a structural deadline miss) is
                    // flagged so it stands out in a column of numbers.
                    format!("{}{}", fnum(slack), if slack < 0.0 { " !" } else { "" }),
                    fnum(a.frame_energy()),
                    fnum(a.worst_channel_load),
                    "".into(),
                ]);
            }
            table.row(&[
                r.scenario.clone(),
                o.mode.to_string(),
                "MAKESPAN".into(),
                "".into(),
                "".into(),
                "".into(),
                "".into(),
                fnum(o.makespan_cycles),
                "".into(),
                "".into(),
                fnum(o.energy),
                "".into(),
                if o.mode == "cosched" {
                    r.cut_tree.encode()
                } else {
                    "".into()
                },
            ]);
        }
        let mut s = Json::obj();
        s.set("scenario", r.scenario.clone())
            .set("partition", r.partition.name())
            .set("cut_tree", r.cut_tree.to_json())
            .set("cut_tree_str", r.cut_tree.encode())
            .set("speedup_vs_even_split", r.speedup())
            .set("evaluations", r.evaluations)
            .set("cache_hits", r.cache_hits)
            .set("placement", r.placement.render())
            .set("solo", outcome_json(&r.solo))
            .set("even_split", outcome_json(&r.even_split))
            .set("cosched", outcome_json(&r.cosched));
        arr.push(s);
    }
    json.set("config", cfg.to_json()).set("scenarios", arr);
    Report {
        name: "cosched",
        table,
        json,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosched::{schedule, CoschedConfig, Scenario, TaskSpec};
    use crate::dse::EvalCache;
    use crate::workloads::synthetic;

    fn results() -> Vec<CoschedResult> {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let mut a = synthetic::aw_chain(2.0, 4);
        a.name = "a".into();
        let mut b = synthetic::pointwise_conv_segment(2);
        b.name = "b".into();
        let sc = Scenario::new("pair", vec![TaskSpec::new(a, 30.0), TaskSpec::new(b, 60.0)]);
        vec![schedule(&sc, &cfg, &CoschedConfig::default(), &EvalCache::new(), 1).unwrap()]
    }

    #[test]
    fn report_tabulates_all_modes_and_parses() {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let r = cosched_report(&cfg, &results());
        assert_eq!(r.name, "cosched");
        let md = r.table.to_markdown();
        for mode in ["solo", "even_split", "cosched"] {
            assert!(md.contains(mode), "{md}");
        }
        assert!(md.contains("MAKESPAN"), "{md}");
        assert!(md.contains("slack ms"), "{md}");
        let text = r.json.to_pretty();
        crate::util::json::Json::parse(&text).unwrap();
        assert!(text.contains("speedup_vs_even_split"), "{text}");
        assert!(text.contains("slack_ms"), "{text}");
        assert!(text.contains("cut_tree"), "{text}");
        assert!(text.contains("topology"), "{text}");
        assert!(text.contains("floor_cycles"), "{text}");
        assert!(text.contains("stretch_cycles"), "{text}");
        // 2 tasks × 3 modes + 3 makespan rows.
        assert_eq!(r.table.rows.len(), 9);
    }

    #[test]
    fn cut_tree_round_trips_through_the_emitted_json() {
        use crate::cosched::CutTree;
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let rs = results();
        let report = cosched_report(&cfg, &rs);
        let parsed = crate::util::json::Json::parse(&report.json.to_pretty()).unwrap();
        let scenarios = parsed.get("scenarios").and_then(|s| s.as_arr()).unwrap();
        let tree_json = scenarios[0].get("cut_tree").unwrap();
        let tree = CutTree::from_json(tree_json).unwrap();
        assert_eq!(tree, rs[0].cut_tree, "serialized plan must round-trip");
        assert_eq!(
            scenarios[0].get("cut_tree_str").and_then(|v| v.as_str()),
            Some(rs[0].cut_tree.encode().as_str())
        );
        assert_eq!(
            scenarios[0].get("partition").and_then(|v| v.as_str()),
            Some("bands")
        );
    }

    #[test]
    fn slack_sign_agrees_with_the_deadline_verdict() {
        for r in results() {
            for o in [&r.solo, &r.even_split, &r.cosched] {
                for a in &o.assignments {
                    assert!((a.latency_ms - a.latency_cycles / 1e9 * 1e3).abs() < 1e-9);
                    assert_eq!(
                        a.slack_ms() >= 0.0,
                        a.deadline_met,
                        "{} {}: slack {} vs verdict {}",
                        o.mode,
                        a.task,
                        a.slack_ms(),
                        a.deadline_met
                    );
                }
            }
        }
    }
}
