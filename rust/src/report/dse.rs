//! DSE report emitters: the Pareto frontier per workload and the
//! heuristic-vs-oracle gap table (the `pipeorgan dse` artifacts; see
//! DESIGN.md §6).

use crate::config::ArchConfig;
use crate::coordinator::run_queue;
use crate::dse::{explore, DseConfig, DseResult, EvalCache};
use crate::ir::ModelGraph;
use crate::util::json::Json;
use crate::util::stats::geomean;
use crate::util::table::{fnum, Table};

use super::Report;

/// Explore every task (parallel across tasks; a single task parallelizes
/// across its topologies instead) and return the per-task results.
///
/// The cache is caller-owned and shared by the whole sweep — keys are
/// scoped by a workload/config fingerprint, so tasks never collide. Pass a
/// cache hydrated via `EvalCache::load_file` to start the sweep warm
/// across processes, and save it back afterwards.
pub fn explore_all(
    cfg: &ArchConfig,
    tasks: Vec<ModelGraph>,
    dse: &DseConfig,
    workers: usize,
    cache: &EvalCache,
) -> Vec<DseResult> {
    // Split the worker budget: tasks fan out over the queue, and each task
    // spends its share on per-topology parallelism inside `explore`.
    let inner_workers = (workers / tasks.len().max(1)).max(1);
    run_queue(tasks, workers, |g| explore(&g, cfg, dse, cache, inner_workers))
}

/// Run the exploration and emit both reports (`pipeorgan dse`).
pub fn run_dse_reports(
    cfg: &ArchConfig,
    tasks: Vec<ModelGraph>,
    dse: &DseConfig,
    workers: usize,
    cache: &EvalCache,
) -> Vec<Report> {
    let results = explore_all(cfg, tasks, dse, workers, cache);
    vec![dse_frontier(cfg, dse, &results), dse_gap(dse, &results)]
}

fn plan_point_json(p: &crate::dse::PlanPoint) -> Json {
    let mut o = Json::obj();
    let mut segs = Json::Arr(vec![]);
    for s in &p.plan.segments {
        let mut so = Json::obj();
        so.set("start", s.segment.start)
            .set("depth", s.depth())
            .set("organization", s.organization.name());
        segs.push(so);
    }
    o.set("cycles", p.cycles)
        .set("energy", p.energy)
        .set("dram_words", p.dram_words)
        .set("worst_channel_load", p.worst_channel_load)
        .set("topology", p.plan.topology.name())
        .set("mean_depth", p.plan.mean_depth())
        .set("source", p.source)
        .set("segments", segs);
    o
}

/// The latency/energy/DRAM Pareto frontier, one row per frontier point.
pub fn dse_frontier(cfg: &ArchConfig, dse: &DseConfig, results: &[DseResult]) -> Report {
    let mut table = Table::new(
        "DSE — latency/energy/DRAM Pareto frontier per workload",
        &[
            "task",
            "source",
            "topology",
            "cycles",
            "energy",
            "DRAM words",
            "worst chan load",
            "mean depth",
            "segments",
        ],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for r in results {
        for p in &r.frontier {
            table.row(&[
                r.workload.clone(),
                p.source.to_string(),
                p.plan.topology.name().to_string(),
                fnum(p.cycles),
                fnum(p.energy),
                p.dram_words.to_string(),
                fnum(p.worst_channel_load),
                fnum(p.plan.mean_depth()),
                p.plan.segments.len().to_string(),
            ]);
        }
        let mut t = Json::obj();
        let mut frontier = Json::Arr(vec![]);
        for p in &r.frontier {
            frontier.push(plan_point_json(p));
        }
        t.set("task", r.workload.clone())
            .set("strategy", r.strategy.name())
            .set("evaluations", r.evaluations)
            .set("cache_hits", r.cache_hits)
            .set("heuristic", plan_point_json(&r.heuristic))
            .set("tuned", plan_point_json(&r.tuned))
            .set("best", plan_point_json(r.best()))
            .set("frontier", frontier);
        arr.push(t);
    }
    json.set("strategy", dse.strategy.name())
        .set("depth_cap", dse.depth_cap)
        .set("ladder_rungs", dse.ladder_rungs)
        .set("beam_width", dse.beam_width)
        .set("channel_load_objective", dse.channel_load_objective)
        .set("config", cfg.to_json())
        .set("workloads", arr);
    Report {
        name: "dse_frontier",
        table,
        json,
    }
}

/// Heuristic-vs-tuned-vs-oracle gap table: how much latency/DRAM the
/// closed-form mapper leaves on the table versus the searched optimum, and
/// how much of it the production `PipeOrgan::tuned` mapper recovers at
/// plan time under its budget.
pub fn dse_gap(dse: &DseConfig, results: &[DseResult]) -> Report {
    let mut table = Table::new(
        "DSE — heuristic mapper vs tuned mapper vs searched oracle",
        &[
            "task",
            "heuristic cycles",
            "tuned cycles",
            "oracle cycles",
            "gap (heur/tuned)",
            "gap (heur/oracle)",
            "heuristic DRAM",
            "tuned DRAM",
            "oracle DRAM",
            "oracle topology",
            "evals",
            "hit rate",
        ],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    let mut gaps = Vec::new();
    let mut tuned_gaps = Vec::new();
    for r in results {
        let best = r.best();
        gaps.push(r.gap());
        tuned_gaps.push(r.tuned_gap());
        table.row(&[
            r.workload.clone(),
            fnum(r.heuristic.cycles),
            fnum(r.tuned.cycles),
            fnum(best.cycles),
            fnum(r.tuned_gap()),
            fnum(r.gap()),
            r.heuristic.dram_words.to_string(),
            r.tuned.dram_words.to_string(),
            best.dram_words.to_string(),
            best.plan.topology.name().to_string(),
            r.evaluations.to_string(),
            fnum(if r.evaluations + r.cache_hits == 0 {
                0.0
            } else {
                r.cache_hits as f64 / (r.evaluations + r.cache_hits) as f64
            }),
        ]);
        let mut t = Json::obj();
        t.set("task", r.workload.clone())
            .set("heuristic_cycles", r.heuristic.cycles)
            .set("tuned_cycles", r.tuned.cycles)
            .set("oracle_cycles", best.cycles)
            .set("tuned_gap", r.tuned_gap())
            .set("gap", r.gap())
            .set("heuristic_dram_words", r.heuristic.dram_words)
            .set("tuned_dram_words", r.tuned.dram_words)
            .set("oracle_dram_words", best.dram_words)
            .set("oracle_topology", best.plan.topology.name())
            .set("evaluations", r.evaluations)
            .set("cache_hits", r.cache_hits);
        arr.push(t);
    }
    if !gaps.is_empty() {
        table.row(&[
            "GEOMEAN".into(),
            "".into(),
            "".into(),
            "".into(),
            fnum(geomean(&tuned_gaps)),
            fnum(geomean(&gaps)),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
        ]);
        json.set("geomean_gap", geomean(&gaps))
            .set("geomean_tuned_gap", geomean(&tuned_gaps));
    }
    json.set("strategy", dse.strategy.name()).set("workloads", arr);
    Report {
        name: "dse_gap",
        table,
        json,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::dse::SearchStrategy;
    use crate::workloads::synthetic;

    fn small() -> (ArchConfig, DseConfig) {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let dse = DseConfig {
            strategy: SearchStrategy::Beam,
            beam_width: 4,
            depth_cap: 3,
            ladder_rungs: 2,
            topologies: vec![TopologyKind::Amp],
            budget: None,
            max_labels: 32,
            channel_load_objective: false,
            obs: Default::default(),
        };
        (cfg, dse)
    }

    #[test]
    fn reports_cover_all_requested_workloads() {
        let (cfg, dse) = small();
        let tasks = vec![
            synthetic::aw_chain(2.0, 4),
            synthetic::pointwise_conv_segment(3),
        ];
        let reports = run_dse_reports(&cfg, tasks, &dse, 2, &EvalCache::new());
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].name, "dse_frontier");
        assert_eq!(reports[1].name, "dse_gap");
        let frontier_json = reports[0].json.to_pretty();
        // Both tasks appear, and the JSON round-trips through the parser.
        assert!(frontier_json.contains("pointwise"), "{frontier_json}");
        crate::util::json::Json::parse(&frontier_json).unwrap();
        crate::util::json::Json::parse(&reports[1].json.to_pretty()).unwrap();
        // Gap table carries the geomean rollup row and the tuned column.
        let gap_md = reports[1].table.to_markdown();
        assert!(gap_md.contains("GEOMEAN"));
        assert!(gap_md.contains("tuned cycles"), "{gap_md}");
    }

    #[test]
    fn gap_json_reports_tuned_between_heuristic_and_oracle() {
        let (cfg, dse) = small();
        let tasks = vec![synthetic::aw_chain(2.0, 4)];
        let results = explore_all(&cfg, tasks, &dse, 1, &EvalCache::new());
        let gap = dse_gap(&dse, &results);
        for t in gap.json.get("workloads").unwrap().as_arr().unwrap() {
            let heur = t.get("heuristic_cycles").and_then(|x| x.as_f64()).unwrap();
            let tuned = t.get("tuned_cycles").and_then(|x| x.as_f64()).unwrap();
            let orac = t.get("oracle_cycles").and_then(|x| x.as_f64()).unwrap();
            assert!(tuned <= heur * 1.0001, "tuned {tuned} vs heuristic {heur}");
            assert!(orac <= tuned * 1.0001, "oracle {orac} vs tuned {tuned}");
        }
    }

    #[test]
    fn explore_all_keeps_task_order() {
        let (cfg, dse) = small();
        let tasks = vec![
            synthetic::aw_chain(2.0, 4),
            synthetic::equal_conv_segment(3),
        ];
        let names: Vec<String> = tasks.iter().map(|g| g.name.clone()).collect();
        let results = explore_all(&cfg, tasks, &dse, 4, &EvalCache::new());
        let got: Vec<String> = results.iter().map(|r| r.workload.clone()).collect();
        assert_eq!(got, names);
    }

    #[test]
    fn shared_cache_makes_second_sweep_free() {
        let (cfg, dse) = small();
        let cache = EvalCache::new();
        let mk_tasks = || vec![synthetic::pointwise_conv_segment(3)];
        let cold = explore_all(&cfg, mk_tasks(), &dse, 1, &cache);
        assert!(cold[0].evaluations > 0);
        let warm = explore_all(&cfg, mk_tasks(), &dse, 1, &cache);
        assert_eq!(warm[0].evaluations, 0, "sweep-shared cache must be warm");
        assert_eq!(warm[0].best().cycles, cold[0].best().cycles);
    }
}
