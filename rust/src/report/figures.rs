//! The per-figure generators. Each reproduces one paper artifact's rows
//! (see DESIGN.md §5 for the experiment index).

use std::sync::Arc;

use crate::config::{ArchConfig, TopologyKind};
use crate::coordinator::{run_jobs_with_cache, EvalJob, MapperKind};
use crate::dataflow::IntensityReport;
use crate::ir::skips::SkipProfile;
use crate::noc::Topology;
use crate::pipeline::partition;
use crate::sim::{analyze, simulate_interval};
use crate::spatial::{Organization, Placement};
use crate::traffic::{derive_flows, scenarios, StageHandoff};
use crate::util::json::Json;
use crate::util::stats::geomean;
use crate::util::table::{fnum, Table};
use crate::workloads;

use super::Report;

/// E1 / Fig. 5: per-layer A/W ratios across the zoo (min/geomean/max per
/// task plus the global spread).
pub fn fig5_aw_ratios() -> Report {
    let mut table = Table::new(
        "Fig. 5 — activation/weight ratios across XR-bench-like tasks",
        &["task", "layers", "min A/W", "geomean A/W", "max A/W"],
    );
    let mut json = Json::obj();
    let mut tasks_json = Json::Arr(vec![]);
    let (mut glo, mut ghi) = (f64::INFINITY, 0f64);
    for g in workloads::all_tasks() {
        let ratios: Vec<f64> = g
            .layers()
            .iter()
            .filter(|l| l.weight_words() > 0 && l.is_einsum())
            .map(|l| l.aw_ratio())
            .collect();
        let (lo, hi) = (
            ratios.iter().cloned().fold(f64::INFINITY, f64::min),
            ratios.iter().cloned().fold(0.0, f64::max),
        );
        glo = glo.min(lo);
        ghi = ghi.max(hi);
        table.row(&[
            g.name.clone(),
            ratios.len().to_string(),
            fnum(lo),
            fnum(geomean(&ratios)),
            fnum(hi),
        ]);
        let mut t = Json::obj();
        t.set("task", g.name.clone())
            .set("ratios", ratios.clone());
        tasks_json.push(t);
    }
    table.row(&[
        "ALL (spread)".into(),
        "".into(),
        fnum(glo),
        format!("{:.1} orders", (ghi / glo).log10()),
        fnum(ghi),
    ]);
    json.set("tasks", tasks_json)
        .set("global_min", glo)
        .set("global_max", ghi);
    Report {
        name: "fig5_aw_ratios",
        table,
        json,
    }
}

/// E2 / Fig. 6: skip-connection structure per task.
pub fn fig6_skips() -> Report {
    let mut table = Table::new(
        "Fig. 6 — skip connections across XR-bench-like tasks",
        &["task", "skips", "density", "mean dist", "max dist"],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for g in workloads::all_tasks() {
        let p = SkipProfile::of(&g);
        table.row(&[
            g.name.clone(),
            p.num_skips().to_string(),
            fnum(p.density),
            fnum(p.mean_distance),
            p.max_distance.to_string(),
        ]);
        let mut t = Json::obj();
        t.set("task", g.name.clone())
            .set("num_skips", p.num_skips())
            .set("density", p.density)
            .set("mean_distance", p.mean_distance)
            .set("max_distance", p.max_distance);
        arr.push(t);
    }
    json.set("tasks", arr);
    Report {
        name: "fig6_skips",
        table,
        json,
    }
}

/// E3–E7 / Fig. 8–12: traffic analysis of the scenario library on mesh and
/// AMP, analytic + cycle-level cross-check.
pub fn fig8_12_traffic(cfg: &ArchConfig) -> Report {
    let mut table = Table::new(
        "Fig. 8-12 — traffic analysis (worst channel load per interval, hops, congestion)",
        &[
            "scenario",
            "topology",
            "worst load",
            "total word-hops",
            "max hops",
            "congestion@I=2",
            "cycle-sim makespan",
        ],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for scen in scenarios::all(cfg.pe_rows, cfg.pe_cols) {
        for kind in [TopologyKind::Mesh, TopologyKind::Amp] {
            let topo = Topology::new(kind, cfg.pe_rows, cfg.pe_cols);
            let flows = derive_flows(&topo, &scen.placement, &scen.handoffs);
            let a = analyze(&topo, &flows);
            // cycle-level validation on integer-rounded volumes
            let int_flows: Vec<_> = flows
                .iter()
                .map(|f| crate::traffic::Flow {
                    words_per_interval: f.words_per_interval.ceil(),
                    ..*f
                })
                .collect();
            let sim = simulate_interval(&topo, &int_flows, 1);
            table.row(&[
                scen.name.to_string(),
                kind.name().to_string(),
                fnum(a.worst_channel_load),
                fnum(a.total_word_hops),
                a.max_route_hops.to_string(),
                fnum(a.congestion_factor(scen.compute_interval, cfg.link_words_per_cycle)),
                sim.makespan.to_string(),
            ]);
            let mut t = Json::obj();
            t.set("scenario", scen.name)
                .set("topology", kind.name())
                .set("worst_channel_load", a.worst_channel_load)
                .set("total_word_hops", a.total_word_hops)
                .set("max_route_hops", a.max_route_hops)
                .set("cycle_sim_makespan", sim.makespan);
            arr.push(t);
        }
    }
    json.set("rows", arr);
    Report {
        name: "fig8_12_traffic",
        table,
        json,
    }
}

/// E8 / Table II: mesh bottleneck summary derived from scenario deltas.
pub fn table2_bottlenecks(cfg: &ArchConfig) -> Report {
    let mesh = Topology::new(TopologyKind::Mesh, cfg.pe_rows, cfg.pe_cols);
    let load = |s: &scenarios::Scenario| {
        let flows = derive_flows(&mesh, &s.placement, &s.handoffs);
        analyze(&mesh, &flows)
    };
    let blocked = load(&scenarios::fig8_depth2_blocked(cfg.pe_rows, cfg.pe_cols));
    let striped = load(&scenarios::fig10_striped(cfg.pe_rows, cfg.pe_cols));
    let skip = load(&scenarios::fig9a_skip_blocked(cfg.pe_rows, cfg.pe_cols));
    let b2d = load(&scenarios::fig11_blocked2d(cfg.pe_rows, cfg.pe_cols, false));
    let b2d_skip = load(&scenarios::fig11_blocked2d(cfg.pe_rows, cfg.pe_cols, true));

    let mut table = Table::new(
        "Table II — mesh bottlenecks (measured)",
        &["cause", "effect (measured)", "prevalent in"],
    );
    table.row(&[
        "many long overlapping paths".into(),
        format!(
            "worst load {}x vs interleaved ({} vs {})",
            fnum(blocked.worst_channel_load / striped.worst_channel_load.max(1e-9)),
            fnum(blocked.worst_channel_load),
            fnum(striped.worst_channel_load)
        ),
        "blocked 1D and 2D".into(),
    ]);
    table.row(&[
        "many long overlapping paths".into(),
        format!(
            "hop energy {}x vs interleaved ({} vs {} word-hops)",
            fnum(blocked.total_word_hops / striped.total_word_hops.max(1e-9)),
            fnum(blocked.total_word_hops),
            fnum(striped.total_word_hops)
        ),
        "blocked 1D and 2D".into(),
    ]);
    table.row(&[
        "extra BW for skip connections".into(),
        format!(
            "worst load +{}%",
            fnum(100.0 * (skip.worst_channel_load / blocked.worst_channel_load - 1.0))
        ),
        "all organizations".into(),
    ]);
    table.row(&[
        "extra hops with skip connections".into(),
        format!(
            "word-hops +{}%",
            fnum(100.0 * (b2d_skip.total_word_hops / b2d.total_word_hops - 1.0))
        ),
        "all configurations".into(),
    ]);
    table.row(&[
        "routing in multiple directions".into(),
        format!(
            "2D blocked word-hops {} vs 1D {}",
            fnum(b2d.total_word_hops),
            fnum(blocked.total_word_hops)
        ),
        "2D organizations".into(),
    ]);
    let mut json = Json::obj();
    json.set("blocked_worst_load", blocked.worst_channel_load)
        .set("striped_worst_load", striped.worst_channel_load)
        .set("skip_worst_load", skip.worst_channel_load)
        .set("blocked2d_word_hops", b2d.total_word_hops)
        .set("blocked2d_skip_word_hops", b2d_skip.total_word_hops);
    Report {
        name: "table2_bottlenecks",
        table,
        json,
    }
}

/// Display label of the mapper filling the "PipeOrgan" column of the e2e
/// reports.
fn primary_label(primary: MapperKind) -> &'static str {
    match primary {
        MapperKind::PipeOrganTuned => "PipeOrgan-tuned",
        _ => "PipeOrgan",
    }
}

fn e2e_outcomes(
    cfg: &ArchConfig,
    workers: usize,
    primary: MapperKind,
    cache: Option<Arc<crate::dse::EvalCache>>,
) -> Vec<(String, [crate::cost::ModelCost; 3], f64)> {
    let tasks = workloads::all_tasks();
    let mut jobs = Vec::new();
    for g in &tasks {
        let graph = Arc::new(g.clone());
        for mapper in [primary, MapperKind::TangramLike, MapperKind::SimbaLike] {
            jobs.push(EvalJob {
                graph: Arc::clone(&graph),
                mapper,
                cfg: cfg.clone(),
            });
        }
    }
    let outcomes = run_jobs_with_cache(jobs, workers, cache);
    outcomes
        .chunks(3)
        .map(|c| {
            (
                c[0].task.clone(),
                [c[0].cost.clone(), c[1].cost.clone(), c[2].cost.clone()],
                c[0].mean_depth,
            )
        })
        .collect()
}

/// E9 / Fig. 13: end-to-end performance normalized to TANGRAM-like.
pub fn fig13_performance(cfg: &ArchConfig, workers: usize) -> Report {
    fig13_with(cfg, workers, MapperKind::PipeOrgan, None)
}

/// [`fig13_performance`] with the PipeOrgan column filled by `primary` —
/// the `pipeorgan e2e --tuned` path runs [`MapperKind::PipeOrganTuned`]
/// here with a (possibly file-hydrated) shared evaluation cache, turning
/// the DSE into the production planning path of the whole-zoo sweep.
pub fn fig13_with(
    cfg: &ArchConfig,
    workers: usize,
    primary: MapperKind,
    cache: Option<Arc<crate::dse::EvalCache>>,
) -> Report {
    let rows = e2e_outcomes(cfg, workers, primary, cache);
    let mut table = Table::new(
        "Fig. 13 — end-to-end performance (normalized to TANGRAM-like; higher is better)",
        &["task", primary_label(primary), "TANGRAM-like", "SIMBA-like"],
    );
    let mut sp_po = Vec::new();
    let mut sp_sb = Vec::new();
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for (task, [po, tg, sb], _) in &rows {
        let norm_po = tg.cycles / po.cycles;
        let norm_sb = tg.cycles / sb.cycles;
        sp_po.push(norm_po);
        sp_sb.push(norm_sb);
        table.row(&[
            task.clone(),
            fnum(norm_po),
            "1.000".into(),
            fnum(norm_sb),
        ]);
        let mut t = Json::obj();
        t.set("task", task.clone())
            .set("pipeorgan", norm_po)
            .set("tangram_like", 1.0)
            .set("simba_like", norm_sb)
            .set("pipeorgan_cycles", po.cycles)
            .set("tangram_cycles", tg.cycles)
            .set("simba_cycles", sb.cycles);
        arr.push(t);
    }
    table.row(&[
        "GEOMEAN".into(),
        fnum(geomean(&sp_po)),
        "1.000".into(),
        fnum(geomean(&sp_sb)),
    ]);
    json.set("rows", arr)
        .set("geomean_pipeorgan_vs_tangram", geomean(&sp_po))
        .set("primary_mapper", primary_label(primary))
        .set("paper_geomean", 1.95);
    Report {
        name: "fig13_performance",
        table,
        json,
    }
}

/// E10 / Fig. 14: normalized DRAM accesses (lower is better).
pub fn fig14_dram(cfg: &ArchConfig, workers: usize) -> Report {
    fig14_with(cfg, workers, MapperKind::PipeOrgan, None)
}

/// [`fig14_dram`] with the PipeOrgan column filled by `primary` (see
/// [`fig13_with`]).
pub fn fig14_with(
    cfg: &ArchConfig,
    workers: usize,
    primary: MapperKind,
    cache: Option<Arc<crate::dse::EvalCache>>,
) -> Report {
    let rows = e2e_outcomes(cfg, workers, primary, cache);
    let mut table = Table::new(
        "Fig. 14 — end-to-end DRAM accesses (normalized to TANGRAM-like; lower is better)",
        &["task", primary_label(primary), "TANGRAM-like", "SIMBA-like"],
    );
    let mut ratios = Vec::new();
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for (task, [po, tg, sb], _) in &rows {
        let r_po = po.dram_words as f64 / tg.dram_words as f64;
        let r_sb = sb.dram_words as f64 / tg.dram_words as f64;
        ratios.push(r_po);
        table.row(&[task.clone(), fnum(r_po), "1.000".into(), fnum(r_sb)]);
        let mut t = Json::obj();
        t.set("task", task.clone())
            .set("pipeorgan", r_po)
            .set("simba_like", r_sb)
            .set("pipeorgan_dram_words", po.dram_words)
            .set("tangram_dram_words", tg.dram_words);
        arr.push(t);
    }
    table.row(&[
        "GEOMEAN".into(),
        fnum(geomean(&ratios)),
        "1.000".into(),
        "".into(),
    ]);
    json.set("rows", arr)
        .set("geomean_reduction", 1.0 - geomean(&ratios))
        .set("primary_mapper", primary_label(primary))
        .set("paper_reduction", 0.31);
    Report {
        name: "fig14_dram",
        table,
        json,
    }
}

/// E11 / Fig. 15: worst-case channel load (delay factor) vs compute
/// interval for blocked / fine-striped / AMP, depth-2 1-D, equal and 1×1
/// vs 3×3 unequal allocation.
pub fn fig15_congestion(cfg: &ArchConfig) -> Report {
    let mut table = Table::new(
        "Fig. 15 — interval delay factor vs compute interval (depth-2, 1-D)",
        &[
            "compute interval",
            "alloc",
            "blocked/mesh",
            "fine-1D/mesh",
            "blocked/AMP",
        ],
    );
    let mesh = Topology::new(TopologyKind::Mesh, cfg.pe_rows, cfg.pe_cols);
    let amp = Topology::new(TopologyKind::Amp, cfg.pe_rows, cfg.pe_cols);
    let delay_factor = |topo: &Topology, placement: &Placement, interval: f64| -> f64 {
        let w = placement.stage_size(0) as f64;
        let flows = derive_flows(
            topo,
            placement,
            &[StageHandoff::pipeline(0, 1, w)],
        );
        let a = analyze(topo, &flows);
        let comm = a.worst_channel_load / cfg.link_words_per_cycle;
        (comm / interval).max(1.0)
    };
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for &(alloc_name, shares) in &[("equal", [1usize, 1]), ("1x1-vs-3x3", [1, 9])] {
        let blocked = Placement::build(cfg.pe_rows, cfg.pe_cols, Organization::Blocked1D, &shares);
        let striped =
            Placement::build(cfg.pe_rows, cfg.pe_cols, Organization::FineStriped1D, &shares);
        for interval in [1.0f64, 2.0, 4.0, 8.0, 16.0, 32.0] {
            let b_mesh = delay_factor(&mesh, &blocked, interval);
            let s_mesh = delay_factor(&mesh, &striped, interval);
            let b_amp = delay_factor(&amp, &blocked, interval);
            table.row(&[
                fnum(interval),
                alloc_name.into(),
                fnum(b_mesh),
                fnum(s_mesh),
                fnum(b_amp),
            ]);
            let mut t = Json::obj();
            t.set("compute_interval", interval)
                .set("alloc", alloc_name)
                .set("blocked_mesh", b_mesh)
                .set("fine1d_mesh", s_mesh)
                .set("blocked_amp", b_amp);
            arr.push(t);
        }
    }
    json.set("rows", arr);
    Report {
        name: "fig15_congestion",
        table,
        json,
    }
}

/// E12 / Fig. 16: pipeline depths chosen per task.
pub fn fig16_depth(cfg: &ArchConfig) -> Report {
    let mut table = Table::new(
        "Fig. 16 — pipeline depths per task (stage-1 heuristic)",
        &["task", "segments", "mean depth", "max depth", "depths"],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for g in workloads::all_tasks() {
        let parts = partition(&g, cfg);
        let depths: Vec<usize> = parts.iter().map(|p| p.segment.depth).collect();
        let mean = depths.iter().sum::<usize>() as f64 / depths.len() as f64;
        let shown: Vec<String> = depths.iter().map(|d| d.to_string()).collect();
        table.row(&[
            g.name.clone(),
            depths.len().to_string(),
            fnum(mean),
            depths.iter().max().unwrap().to_string(),
            shown.join(","),
        ]);
        let mut t = Json::obj();
        t.set(
            "depths",
            depths.iter().map(|&d| d as u64).collect::<Vec<u64>>(),
        )
        .set("task", g.name.clone());
        arr.push(t);
    }
    json.set("tasks", arr);
    Report {
        name: "fig16_depth",
        table,
        json,
    }
}

/// E13 / Fig. 17: finest pipelining granularity per task (fraction of the
/// intermediate tensor exchanged per interval).
pub fn fig17_granularity(cfg: &ArchConfig) -> Report {
    use crate::dataflow::{choose_dataflow, LoopNest};
    use crate::pipeline::pair_granularity;
    let mut table = Table::new(
        "Fig. 17 — finest granularity per task (median fraction of intermediate tensor)",
        &["task", "pairs", "median fraction", "finest", "coarsest"],
    );
    let mut json = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for g in workloads::all_tasks() {
        let parts = partition(&g, cfg);
        let mut fracs = Vec::new();
        for p in &parts {
            let seg = &p.segment;
            for s in 0..seg.depth.saturating_sub(1) {
                let a = g.layer(seg.start + s);
                let b = g.layer(seg.start + s + 1);
                let na = LoopNest::for_op(&a.op, choose_dataflow(a));
                let nb = LoopNest::for_op(&b.op, choose_dataflow(b));
                let gr = pair_granularity(&na, &nb, a.output_act_words());
                fracs.push(gr.fraction(a.output_act_words()));
            }
        }
        if fracs.is_empty() {
            table.row(&[g.name.clone(), "0".into(), "-".into(), "-".into(), "-".into()]);
            continue;
        }
        let med = crate::util::stats::percentile(&fracs, 50.0);
        let lo = fracs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = fracs.iter().cloned().fold(0.0, f64::max);
        table.row(&[
            g.name.clone(),
            fracs.len().to_string(),
            fnum(med),
            fnum(lo),
            fnum(hi),
        ]);
        let mut t = Json::obj();
        t.set("task", g.name.clone()).set("fractions", fracs.clone());
        arr.push(t);
    }
    json.set("tasks", arr);
    Report {
        name: "fig17_granularity",
        table,
        json,
    }
}

/// E14 / Sec. IV-A validation: fraction of zoo layers achieving best-case
/// arithmetic intensity vs buffer size (paper: 99.94 % @512 KB, 97.2 %
/// @256 KB).
pub fn validate_dataflow() -> Report {
    let tasks = workloads::all_tasks();
    let layers: Vec<_> = tasks.iter().flat_map(|g| g.layers().iter()).collect();
    let mut table = Table::new(
        "Sec. IV-A — dataflow heuristic validation (best-case AI achieved)",
        &["buffer", "layers", "achieving best-case", "fraction", "paper"],
    );
    let mut json = Json::obj();
    for (kb, paper) in [(512u64, "99.94%"), (256, "97.2%")] {
        let rep = IntensityReport::sweep(layers.iter().copied(), kb * 1024);
        table.row(&[
            format!("{kb} KB"),
            rep.total_layers.to_string(),
            rep.achieving_best_case.to_string(),
            format!("{:.2}%", 100.0 * rep.fraction()),
            paper.into(),
        ]);
        json.set(&format!("fraction_{kb}kb"), rep.fraction());
    }
    Report {
        name: "validate_dataflow",
        table,
        json,
    }
}
