//! Fleet report: cluster-level tail latencies, miss/rejection accounting
//! and cost per million requests for every router × dispatch policy pair,
//! plus a per-chip utilization-spread table (the `pipeorgan fleet`
//! artifacts; see docs/SERVING.md).

use crate::config::ArchConfig;
use crate::serve::{ChipStats, FleetConfig, FleetOutcome, FleetRun, ServeConfig};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::Report;

fn chip_json(c: &ChipStats) -> Json {
    let mut out = Json::obj();
    out.set("chip", c.chip)
        .set("pes", c.pes)
        .set("routed", c.routed)
        .set("completed", c.completed)
        .set("missed", c.missed)
        .set("mean_util", c.mean_util)
        .set("up_s", c.up_s)
        .set("cold_loads", c.cold_loads);
    out
}

/// Max-minus-min mean utilization across chips: the router's load-balance
/// quality in one number (0 = perfectly even).
fn util_spread(o: &FleetOutcome) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for c in &o.chips {
        lo = lo.min(c.mean_util);
        hi = hi.max(c.mean_util);
    }
    if o.chips.is_empty() {
        0.0
    } else {
        hi - lo
    }
}

fn outcome_json(o: &FleetOutcome) -> Json {
    let mut tasks = Json::Arr(vec![]);
    for m in &o.tasks {
        let mut t = Json::obj();
        t.set("task", m.task.clone())
            .set("rate_hz", m.rate_hz)
            .set("deadline_ms", m.deadline_ms)
            .set("requests", m.requests)
            .set("completed", m.completed)
            .set("dropped", m.dropped)
            .set("missed", m.missed)
            .set("miss_rate", m.miss_rate())
            .set("p50_ms", m.p50_ms)
            .set("p95_ms", m.p95_ms)
            .set("p99_ms", m.p99_ms)
            .set("mean_wait_ms", m.mean_wait_ms)
            .set("max_queue_depth", m.max_queue_depth)
            .set("utilization", m.utilization);
        tasks.push(t);
    }
    let mut chips = Json::Arr(vec![]);
    for c in &o.chips {
        chips.push(chip_json(c));
    }
    let mut out = Json::obj();
    out.set("router", o.router.name())
        .set("policy", o.policy.name())
        .set("span_s", o.span_s)
        .set("miss_rate", o.miss_rate())
        .set("rejected", o.rejected)
        .set("scale_events", o.scale_events)
        .set("cost_pe_s_per_m", o.cost_pe_s_per_m)
        .set("util_spread", util_spread(o))
        .set("tasks", tasks)
        .set("chips", chips);
    out
}

fn fleet_config_json(fc: &FleetConfig) -> Json {
    let mut routers = Json::Arr(vec![]);
    for r in &fc.routers {
        routers.push(r.name());
    }
    let mut out = Json::obj();
    out.set("chips", fc.chips)
        .set("routers", routers)
        .set("admission", fc.admission.name());
    match fc.autoscale {
        Some(a) => {
            let mut aj = Json::obj();
            aj.set("min_chips", a.min_chips)
                .set("spinup_s", a.spinup_s)
                .set("high_backlog_s", a.high_backlog_s)
                .set("low_backlog_s", a.low_backlog_s)
                .set("interval_s", a.interval_s);
            out.set("autoscale", aj);
        }
        None => {
            out.set("autoscale", Json::Null);
        }
    }
    match fc.warm {
        Some((cold_frac, decay_s)) => {
            let mut wj = Json::obj();
            wj.set("cold_frac", cold_frac).set("decay_s", decay_s);
            out.set("warm", wj);
        }
        None => {
            out.set("warm", Json::Null);
        }
    }
    out
}

/// One row per (scenario, router, policy, task) plus a FLEET rollup row
/// carrying the cluster-only numbers (rejections, utilization spread,
/// cost per million completed); a second report tabulates per-chip stats
/// so uneven routing is visible at a glance. JSON mirrors everything.
pub fn fleet_reports(
    cfg: &ArchConfig,
    sv: &ServeConfig,
    fc: &FleetConfig,
    runs: &[FleetRun],
) -> Vec<Report> {
    let mut table = Table::new(
        "Fleet — routed serving across array instances",
        &[
            "scenario",
            "router",
            "policy",
            "task",
            "requests",
            "served",
            "missed",
            "rejected",
            "miss %",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "util spread %",
            "PE·s per M",
        ],
    );
    let mut chip_table = Table::new(
        "Fleet — per-chip routing and utilization",
        &[
            "scenario",
            "router",
            "policy",
            "chip",
            "PEs",
            "routed",
            "served",
            "missed",
            "util %",
            "up s",
            "cold loads",
        ],
    );
    let mut arr = Json::Arr(vec![]);
    for r in runs {
        let mut outcomes = Json::Arr(vec![]);
        for o in &r.outcomes {
            for m in &o.tasks {
                table.row(&[
                    r.scenario.clone(),
                    o.router.name().to_string(),
                    o.policy.name().to_string(),
                    m.task.clone(),
                    m.requests.to_string(),
                    m.completed.to_string(),
                    m.missed.to_string(),
                    "".into(),
                    fnum(100.0 * m.miss_rate()),
                    fnum(m.p50_ms),
                    fnum(m.p95_ms),
                    fnum(m.p99_ms),
                    "".into(),
                    "".into(),
                ]);
            }
            table.row(&[
                r.scenario.clone(),
                o.router.name().to_string(),
                o.policy.name().to_string(),
                "FLEET".into(),
                o.total_requests().to_string(),
                "".into(),
                o.total_missed().to_string(),
                o.rejected.to_string(),
                fnum(100.0 * o.miss_rate()),
                "".into(),
                "".into(),
                "".into(),
                fnum(100.0 * util_spread(o)),
                fnum(o.cost_pe_s_per_m),
            ]);
            for c in &o.chips {
                chip_table.row(&[
                    r.scenario.clone(),
                    o.router.name().to_string(),
                    o.policy.name().to_string(),
                    c.chip.to_string(),
                    c.pes.to_string(),
                    c.routed.to_string(),
                    c.completed.to_string(),
                    c.missed.to_string(),
                    fnum(100.0 * c.mean_util),
                    fnum(c.up_s),
                    c.cold_loads.to_string(),
                ]);
            }
            outcomes.push(outcome_json(o));
        }
        // Chip geometry: dims per chip are enough to reconstruct which
        // plan each chip ran (full region detail lives in the serve
        // report path; repeating it per chip would dwarf the document).
        let mut chips = Json::Arr(vec![]);
        for plan in &r.plans {
            let pes: usize = plan.regions.iter().map(|g| g.num_pes()).sum();
            let mut cj = Json::obj();
            cj.set("regions", plan.regions.len())
                .set("pes", pes)
                .set("evaluations", plan.evaluations)
                .set("cache_hits", plan.cache_hits);
            chips.push(cj);
        }
        let mut s = Json::obj();
        s.set("scenario", r.scenario.clone())
            .set("chips", chips)
            .set("outcomes", outcomes);
        arr.push(s);
    }
    let mut json = Json::obj();
    json.set("config", cfg.to_json())
        .set("fleet", fleet_config_json(fc))
        .set("arrivals", sv.arrivals.name())
        .set("duration_s", sv.duration_s)
        .set("rate_mult", sv.rate_mult)
        .set("seed", sv.seed)
        .set("borrow", sv.borrow)
        .set("bandwidth", sv.bandwidth.name())
        .set("scenarios", arr);
    vec![
        Report {
            name: "fleet",
            table,
            json,
        },
        Report {
            name: "fleet_chips",
            table: chip_table,
            json: Json::obj(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosched::{Scenario, TaskSpec};
    use crate::dse::EvalCache;
    use crate::serve::{run_fleet_scenario, Policy, RouterPolicy};
    use crate::workloads::synthetic;

    #[test]
    fn fleet_reports_cover_every_router_policy_task_row() {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let mut a = synthetic::aw_chain(2.0, 4);
        a.name = "a".into();
        let mut b = synthetic::pointwise_conv_segment(2);
        b.name = "b".into();
        let sc = Scenario::new("pair", vec![TaskSpec::new(a, 30.0), TaskSpec::new(b, 60.0)]);
        let sv = ServeConfig {
            policies: vec![Policy::Fifo],
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        let fc = FleetConfig {
            chips: 2,
            routers: vec![RouterPolicy::RoundRobin, RouterPolicy::Jsq],
            ..FleetConfig::default()
        };
        let cache = EvalCache::new();
        let run = run_fleet_scenario(&sc, &cfg, &sv, &fc, &[], &cache, 1).unwrap();
        let reports = fleet_reports(&cfg, &sv, &fc, &[run]);
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].name, "fleet");
        assert_eq!(reports[1].name, "fleet_chips");

        // 2 routers × 1 policy × (2 task rows + 1 FLEET row).
        assert_eq!(reports[0].table.rows.len(), 2 * 3);
        // 2 routers × 1 policy × 2 chips.
        assert_eq!(reports[1].table.rows.len(), 2 * 2);

        let doc = Json::parse(&reports[0].json.to_pretty()).unwrap();
        let scenarios = doc.get("scenarios").and_then(Json::as_arr).unwrap();
        assert_eq!(scenarios.len(), 1);
        let outcomes = scenarios[0].get("outcomes").and_then(Json::as_arr).unwrap();
        assert_eq!(outcomes.len(), 2);
        for o in outcomes {
            assert!(o.get("cost_pe_s_per_m").is_some());
            assert!(o.get("util_spread").is_some());
            let chips = o.get("chips").and_then(Json::as_arr).unwrap();
            assert_eq!(chips.len(), 2);
        }
        let fleet = doc.get("fleet").unwrap();
        assert_eq!(
            fleet.get("routers").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
    }
}
