//! Figure/table emitters: every reproduced paper artifact (E1–E14 in
//! DESIGN.md) as an aligned markdown table on stdout plus CSV + JSON files
//! under a reports directory, so external tooling can re-plot them.

mod ablations;
mod attr;
mod cosched;
mod dse;
mod figures;
mod fleet;
mod noc;
mod obs;
mod serve;
pub mod sink;

pub use ablations::{ablation_depth, ablation_organization, ablation_topology};
pub use attr::{attr_report, flight_table_json, policy_attr_json, ATTR_SCHEMA};
pub use cosched::cosched_report;
pub use dse::{dse_frontier, dse_gap, explore_all, run_dse_reports};
pub use fleet::fleet_reports;
pub use noc::{cosched_noc_report, dse_noc_report, serve_noc_report, NOC_WINDOWS};
pub use obs::obs_report;
pub use serve::serve_reports;
pub use sink::{ArtifactSink, ARTIFACT_ALIASES};
pub use figures::{
    fig13_performance, fig13_with, fig14_dram, fig14_with, fig15_congestion, fig16_depth,
    fig17_granularity, fig5_aw_ratios, fig6_skips, fig8_12_traffic, table2_bottlenecks,
    validate_dataflow,
};

use std::path::Path;

use crate::util::json::Json;
use crate::util::table::Table;

/// One emitted artifact: a table for humans, JSON for tooling.
pub struct Report {
    pub name: &'static str,
    pub table: Table,
    pub json: Json,
}

impl Report {
    /// Print to stdout and persist CSV + JSON under `out_dir`.
    pub fn emit(&self, out_dir: impl AsRef<Path>) -> std::io::Result<()> {
        let dir = out_dir.as_ref();
        std::fs::create_dir_all(dir)?;
        print!("{}", self.table.to_markdown());
        std::fs::write(dir.join(format!("{}.csv", self.name)), self.table.to_csv())?;
        std::fs::write(
            dir.join(format!("{}.json", self.name)),
            self.json.to_pretty(),
        )?;
        Ok(())
    }
}

/// All report generators in paper order, for `pipeorgan all`.
pub fn all_reports(cfg: &crate::config::ArchConfig, workers: usize) -> Vec<Report> {
    vec![
        fig5_aw_ratios(),
        fig6_skips(),
        fig8_12_traffic(cfg),
        table2_bottlenecks(cfg),
        fig13_performance(cfg, workers),
        fig14_dram(cfg, workers),
        fig15_congestion(cfg),
        fig16_depth(cfg),
        fig17_granularity(cfg),
        validate_dataflow(),
        ablation_organization(cfg),
        ablation_topology(cfg),
        ablation_depth(cfg),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_emit_to_disk() {
        let dir = std::env::temp_dir().join("pipeorgan_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let r = fig5_aw_ratios();
        r.emit(&dir).unwrap();
        assert!(dir.join("fig5_aw_ratios.csv").exists());
        assert!(dir.join("fig5_aw_ratios.json").exists());
        let text = std::fs::read_to_string(dir.join("fig5_aw_ratios.json")).unwrap();
        crate::util::json::Json::parse(&text).unwrap();
    }
}
