//! The `report::noc` emitters: per-link load distributions (Fig. 15-style
//! mesh-vs-AMP, heuristic-vs-tuned), composed full-array congestion
//! heatmaps, and time-windowed serve heatmaps — the table/JSON side of the
//! NoC telemetry layer (docs/OBSERVABILITY.md §NoC telemetry).
//!
//! Each emitter's `Report::json` *is* the `pipeorgan-noc-v1` document, so
//! `--noc-out FILE` and the `reports/noc_*.json` file are the same
//! artifact and both validate under `tools/trace_check.py`.

use crate::config::{ArchConfig, TopologyKind};
use crate::cosched::{region_config, CoschedResult, Scenario, TaskAssignment};
use crate::cost::{evaluate, plan_loadmap, MappingPlan};
use crate::dse::DseResult;
use crate::ir::ModelGraph;
use crate::noc::{congestion_threshold, verify, LinkLoadMap};
use crate::obs::heatmap::{emit_class_counters, entry_json, noc_document, IdleRect, RegionMap};
use crate::obs::{Obs, PID_SIM};
use crate::serve::{busy_windows, Policy, ServeRun};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::Report;

/// Time windows per serve scenario in the windowed heatmap sampling.
pub const NOC_WINDOWS: usize = 8;

/// A plan's link-load view: the map, the scalar it must agree with, and
/// the (conservative) congestion threshold.
struct PlanNoc {
    map: LinkLoadMap,
    /// Fold of per-segment `worst_channel_load_per_interval` with
    /// `f64::max` — bit-exact equal to `map.max()`.
    worst: f64,
    /// Min over segments of `bottleneck_compute_interval × link_bw`: the
    /// tightest interval any segment must drain within, so one threshold
    /// classifies the merged map conservatively.
    threshold: f64,
}

fn plan_noc(graph: &ModelGraph, plan: &MappingPlan, cfg: &ArchConfig) -> PlanNoc {
    let cost = evaluate(graph, plan, cfg);
    let worst = cost
        .per_segment
        .iter()
        .map(|s| s.worst_channel_load_per_interval)
        .fold(0.0, f64::max);
    let threshold = cost
        .per_segment
        .iter()
        .map(|s| congestion_threshold(s.bottleneck_compute_interval, cfg.link_words_per_cycle))
        .fold(f64::INFINITY, f64::min);
    PlanNoc {
        map: plan_loadmap(graph, plan, cfg),
        worst,
        threshold: if threshold.is_finite() { threshold } else { 0.0 },
    }
}

/// One table row + one artifact entry for a whole-array plan map.
#[allow(clippy::too_many_arguments)]
fn plan_row(
    table: &mut Table,
    entries: &mut Vec<Json>,
    label: &str,
    source: &str,
    topology: &str,
    rows: usize,
    cols: usize,
    pn: &PlanNoc,
) {
    let v = verify(&pn.map, pn.threshold);
    table.row(&[
        label.to_string(),
        source.to_string(),
        topology.to_string(),
        fnum(pn.worst),
        fnum(v.p50),
        fnum(v.p95),
        format!("{}/{}", v.active_links, v.total_links),
        v.saturated.to_string(),
        fnum(v.threshold),
        if v.congestion_free { "yes" } else { "NO" }.to_string(),
    ]);
    entries.push(entry_json(
        label,
        source,
        topology,
        rows,
        cols,
        &[RegionMap::whole(label, pn.map.clone())],
        &[],
        Some(pn.worst),
        pn.threshold,
        None,
    ));
}

fn noc_table(title: &str) -> Table {
    Table::new(
        title,
        &[
            "entry",
            "kind",
            "topology",
            "worst load",
            "p50",
            "p95",
            "active",
            "saturated",
            "thresh",
            "congestion-free",
        ],
    )
}

/// DSE link-load report: for every explored workload, the heuristic plan
/// on mesh *and* AMP (Fig. 15's comparison — same plan, both fabrics)
/// plus the tuned winner on its own topology. `tasks` are the graphs the
/// exploration ran over, matched to results by workload name.
pub fn dse_noc_report(cfg: &ArchConfig, tasks: &[ModelGraph], results: &[DseResult]) -> Report {
    let mut table = noc_table("NoC link load — mesh vs AMP, heuristic vs tuned (Fig. 15-style)");
    let mut entries = Vec::new();
    for r in results {
        let Some(graph) = tasks.iter().find(|g| g.name == r.workload) else {
            continue;
        };
        let native = r.heuristic.plan.topology;
        // Heuristic on its native fabric: the scalar comes straight from
        // the search, so the artifact pins the bit-exact crosscheck.
        let pn = plan_noc(graph, &r.heuristic.plan, cfg);
        plan_row(
            &mut table,
            &mut entries,
            &format!("{}/heuristic", r.workload),
            "heuristic",
            native.name(),
            cfg.pe_rows,
            cfg.pe_cols,
            &pn,
        );
        // The same plan retargeted onto the fabrics the paper compares.
        for kind in [TopologyKind::Mesh, TopologyKind::Amp] {
            if kind == native {
                continue;
            }
            let mut plan = r.heuristic.plan.clone();
            plan.topology = kind;
            let pn = plan_noc(graph, &plan, cfg);
            plan_row(
                &mut table,
                &mut entries,
                &format!("{}/heuristic@{}", r.workload, kind.name()),
                "heuristic",
                kind.name(),
                cfg.pe_rows,
                cfg.pe_cols,
                &pn,
            );
        }
        let pn = plan_noc(graph, &r.tuned.plan, cfg);
        plan_row(
            &mut table,
            &mut entries,
            &format!("{}/tuned", r.workload),
            "tuned",
            r.tuned.plan.topology.name(),
            cfg.pe_rows,
            cfg.pe_cols,
            &pn,
        );
    }
    Report {
        name: "noc_dse",
        table,
        json: noc_document("dse", cfg.link_words_per_cycle, entries),
    }
}

/// Region-local maps of a co-schedule's assignments, in assignment order:
/// `(assignment, its PlanNoc on the region config)`. Tasks whose graph is
/// not in `scenario` (never the case for results produced from it) are
/// skipped.
fn assignment_maps<'a>(
    scenario: &Scenario,
    assignments: &'a [TaskAssignment],
    cfg: &ArchConfig,
) -> Vec<(&'a TaskAssignment, PlanNoc)> {
    assignments
        .iter()
        .filter_map(|a| {
            let spec = scenario.tasks.iter().find(|t| t.name() == a.task)?;
            let mut rcfg = region_config(cfg, &a.region);
            rcfg.topology = a.topology;
            Some((a, plan_noc(&spec.graph, &a.plan, &rcfg)))
        })
        .collect()
}

/// Compose per-region maps into one full-array entry (task regions at
/// their offsets, idle rectangles listed), plus the composed table row.
fn composed_entry(
    table: &mut Table,
    entries: &mut Vec<Json>,
    label: &str,
    cfg: &ArchConfig,
    maps: &[(&TaskAssignment, PlanNoc)],
    idle: &[IdleRect],
) {
    let parts: Vec<RegionMap> = maps
        .iter()
        .map(|(a, pn)| RegionMap {
            label: a.task.clone(),
            map: pn.map.clone(),
            row0: a.region.row0,
            col0: a.region.col0,
            scale: 1.0,
        })
        .collect();
    let worst = maps.iter().map(|(a, _)| a.worst_channel_load).fold(0.0, f64::max);
    let threshold = maps
        .iter()
        .map(|(_, pn)| pn.threshold)
        .fold(f64::INFINITY, f64::min);
    let threshold = if threshold.is_finite() { threshold } else { 0.0 };
    let e = entry_json(
        label,
        "composed",
        "composite",
        cfg.pe_rows,
        cfg.pe_cols,
        &parts,
        idle,
        Some(worst),
        threshold,
        None,
    );
    table.row(&[
        label.to_string(),
        "composed".to_string(),
        "composite".to_string(),
        fnum(worst),
        fnum(e.get("p50").and_then(|v| v.as_f64()).unwrap_or(0.0)),
        fnum(e.get("p95").and_then(|v| v.as_f64()).unwrap_or(0.0)),
        e.get("links")
            .map(|l| {
                format!(
                    "{}/{}",
                    l.get("active").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    l.get("total").and_then(|v| v.as_f64()).unwrap_or(0.0)
                )
            })
            .unwrap_or_default(),
        e.get("links")
            .and_then(|l| l.get("saturated"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
            .to_string(),
        fnum(threshold),
        e.get("verify")
            .and_then(|v| v.get("congestion_free"))
            .map(|v| {
                if *v == Json::Bool(true) {
                    "yes".to_string()
                } else {
                    "NO".to_string()
                }
            })
            .unwrap_or_default(),
    ]);
    entries.push(e);
}

fn region_row(table: &mut Table, entries: &mut Vec<Json>, label: &str, a: &TaskAssignment, pn: &PlanNoc) {
    let v = verify(&pn.map, pn.threshold);
    table.row(&[
        label.to_string(),
        "region".to_string(),
        a.topology.name().to_string(),
        fnum(a.worst_channel_load),
        fnum(v.p50),
        fnum(v.p95),
        format!("{}/{}", v.active_links, v.total_links),
        v.saturated.to_string(),
        fnum(v.threshold),
        if v.congestion_free { "yes" } else { "NO" }.to_string(),
    ]);
    entries.push(entry_json(
        label,
        "region",
        a.topology.name(),
        a.region.rows,
        a.region.cols,
        &[RegionMap::whole(&a.task, pn.map.clone())],
        &[],
        Some(a.worst_channel_load),
        pn.threshold,
        None,
    ));
}

/// Cosched link-load report: one region-local entry per task assignment
/// plus the composed full-array heatmap per scenario (idle rectangles
/// included, so the grids tile the array).
pub fn cosched_noc_report(
    cfg: &ArchConfig,
    scenarios: &[Scenario],
    results: &[CoschedResult],
) -> Report {
    let mut table = noc_table("NoC link load — per-region maps and composed array heatmaps");
    let mut entries = Vec::new();
    for r in results {
        let Some(scenario) = scenarios.iter().find(|s| s.name == r.scenario) else {
            continue;
        };
        let maps = assignment_maps(scenario, &r.cosched.assignments, cfg);
        for (a, pn) in &maps {
            region_row(&mut table, &mut entries, &format!("{}/{}", r.scenario, a.task), a, pn);
        }
        let idle: Vec<IdleRect> = r
            .cut_tree
            .idle_rects(cfg.pe_rows, cfg.pe_cols)
            .into_iter()
            .map(|rect| IdleRect {
                row0: rect.row0,
                col0: rect.col0,
                rows: rect.rows,
                cols: rect.cols,
            })
            .collect();
        composed_entry(
            &mut table,
            &mut entries,
            &format!("{}/array", r.scenario),
            cfg,
            &maps,
            &idle,
        );
    }
    Report {
        name: "noc_cosched",
        table,
        json: noc_document("cosched", cfg.link_words_per_cycle, entries),
    }
}

/// Serve link-load report: the cosched-style per-region and composed
/// entries for each run's plan, plus [`NOC_WINDOWS`] time-windowed
/// heatmaps (each region's map scaled by its busy fraction in the window,
/// from the first replayed policy's trace) so hotspot drift under load is
/// visible. Every policy additionally gets per-window `noc_load` counter
/// samples (one series per wire class) on its sim-time Perfetto track.
pub fn serve_noc_report(
    cfg: &ArchConfig,
    scenarios: &[Scenario],
    runs: &[ServeRun],
    obs: &Obs,
) -> Report {
    let mut table = noc_table("NoC link load — serve: plan maps and time-windowed heatmaps");
    let mut entries = Vec::new();
    for run in runs {
        let Some(scenario) = scenarios.iter().find(|s| s.name == run.scenario) else {
            continue;
        };
        let maps = assignment_maps(scenario, &run.plan.cosched.cosched.assignments, cfg);
        for (a, pn) in &maps {
            region_row(&mut table, &mut entries, &format!("{}/{}", run.scenario, a.task), a, pn);
        }
        let idle: Vec<IdleRect> = run
            .plan
            .cosched
            .cut_tree
            .idle_rects(cfg.pe_rows, cfg.pe_cols)
            .into_iter()
            .map(|rect| IdleRect {
                row0: rect.row0,
                col0: rect.col0,
                rows: rect.rows,
                cols: rect.cols,
            })
            .collect();
        composed_entry(
            &mut table,
            &mut entries,
            &format!("{}/array", run.scenario),
            cfg,
            &maps,
            &idle,
        );

        // Busy fractions index regions by task (region i = task i's home),
        // matching assignment order; maps[] preserved that order.
        for outcome in &run.outcomes {
            let windows = busy_windows(outcome, run.plan.regions.len(), NOC_WINDOWS);
            let pid = PID_SIM
                + Policy::ALL
                    .iter()
                    .position(|&p| p == outcome.policy)
                    .unwrap_or(0) as u32;
            let first_policy = outcome.policy == run.outcomes[0].policy;
            for (k, (w0, w1, fracs)) in windows.iter().enumerate() {
                let mut class_load: [(&'static str, f64); 3] =
                    [("local", 0.0), ("express", 0.0), ("wrap", 0.0)];
                for ((_, pn), &frac) in maps.iter().zip(fracs.iter()) {
                    for (slot, (_, total)) in pn.map.class_totals().iter().enumerate() {
                        class_load[slot].1 += total * frac;
                    }
                }
                emit_class_counters(obs, pid, w0 * 1e6, &class_load);
                if !first_policy {
                    continue;
                }
                // Windowed artifact entries only for the first policy —
                // one drift timeline per scenario keeps the file bounded.
                let parts: Vec<RegionMap> = maps
                    .iter()
                    .zip(fracs.iter())
                    .map(|((a, pn), &frac)| RegionMap {
                        label: a.task.clone(),
                        map: pn.map.clone(),
                        row0: a.region.row0,
                        col0: a.region.col0,
                        scale: frac,
                    })
                    .collect();
                let threshold = maps
                    .iter()
                    .map(|(_, pn)| pn.threshold)
                    .fold(f64::INFINITY, f64::min);
                let e = entry_json(
                    &format!("{}/{} w{}", run.scenario, outcome.policy.name(), k),
                    "window",
                    "composite",
                    cfg.pe_rows,
                    cfg.pe_cols,
                    &parts,
                    &idle,
                    None,
                    if threshold.is_finite() { threshold } else { 0.0 },
                    Some((*w0, *w1)),
                );
                table.row(&[
                    format!("{}/{} w{}", run.scenario, outcome.policy.name(), k),
                    "window".to_string(),
                    "composite".to_string(),
                    fnum(e.get("max").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                    fnum(e.get("p50").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                    fnum(e.get("p95").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
                entries.push(e);
            }
        }
    }
    Report {
        name: "noc_serve",
        table,
        json: noc_document("serve", cfg.link_words_per_cycle, entries),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosched::{scenario_by_name, CoschedConfig};
    use crate::dse::{explore, DseConfig, EvalCache};
    use crate::obs::heatmap::NOC_SCHEMA;
    use crate::serve::{run_scenario, ServeConfig};
    use crate::workloads::synthetic;

    fn small_cfg() -> ArchConfig {
        ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        }
    }

    fn entry_grid_max(e: &Json) -> f64 {
        ["east", "west", "north", "south"]
            .iter()
            .flat_map(|d| {
                e.get("grid")
                    .and_then(|g| g.get(d))
                    .and_then(|a| a.as_arr())
                    .unwrap()
                    .iter()
            })
            .filter_map(|v| v.as_f64())
            .fold(0.0, f64::max)
    }

    #[test]
    fn dse_noc_report_pins_scalar_and_compares_fabrics() {
        let cfg = small_cfg();
        let g = synthetic::pointwise_conv_segment(3);
        let r = explore(&g, &cfg, &DseConfig::default(), &EvalCache::new(), 1);
        let rep = dse_noc_report(&cfg, &[g], &[r]);
        assert_eq!(rep.json.get("schema").and_then(|s| s.as_str()), Some(NOC_SCHEMA));
        let entries = rep.json.get("entries").and_then(|e| e.as_arr()).unwrap();
        // heuristic native + at least one retarget + tuned.
        assert!(entries.len() >= 3, "{} entries", entries.len());
        let topos: Vec<&str> = entries
            .iter()
            .filter_map(|e| e.get("topology").and_then(|t| t.as_str()))
            .collect();
        assert!(topos.contains(&"mesh") && topos.contains(&"amp"), "{topos:?}");
        for e in entries {
            // The headline invariant, via the JSON alone: grid max ==
            // reported max == the plan scalar, all bit-exact.
            let max = e.get("max").and_then(|v| v.as_f64()).unwrap();
            assert_eq!(entry_grid_max(e), max);
            assert_eq!(e.get("worst_channel_load").and_then(|v| v.as_f64()), Some(max));
        }
    }

    #[test]
    fn cosched_noc_report_composes_regions_bit_exactly() {
        let cfg = small_cfg();
        let scenario = scenario_by_name("xr-core").unwrap();
        let r = crate::cosched::schedule(
            &scenario,
            &cfg,
            &CoschedConfig::default(),
            &EvalCache::new(),
            2,
        )
        .unwrap();
        let rep = cosched_noc_report(&cfg, &[scenario], &[r.clone()]);
        let entries = rep.json.get("entries").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(entries.len(), r.cosched.assignments.len() + 1);
        for (e, a) in entries.iter().zip(&r.cosched.assignments) {
            let max = e.get("max").and_then(|v| v.as_f64()).unwrap();
            assert_eq!(max, a.worst_channel_load, "{}", a.task);
            assert_eq!(entry_grid_max(e), max);
        }
        // The composed entry's max is the fold of the region scalars.
        let composed = entries.last().unwrap();
        let worst = r
            .cosched
            .assignments
            .iter()
            .map(|a| a.worst_channel_load)
            .fold(0.0, f64::max);
        assert_eq!(composed.get("max").and_then(|v| v.as_f64()), Some(worst));
        assert_eq!(entry_grid_max(composed), worst);
        assert_eq!(composed.get("kind").and_then(|v| v.as_str()), Some("composed"));
    }

    #[test]
    fn serve_noc_report_windows_and_counters() {
        let cfg = small_cfg();
        let scenario = scenario_by_name("xr-core").unwrap();
        let sv = ServeConfig {
            policies: vec![Policy::Fifo, Policy::Edf],
            duration_s: 0.05,
            obs: Obs::enabled(),
            ..ServeConfig::default()
        };
        let run = run_scenario(&scenario, &cfg, &sv, &EvalCache::new(), 1).unwrap();
        let rep = serve_noc_report(&cfg, &[scenario], &[run], &sv.obs);
        assert_eq!(rep.json.get("source").and_then(|s| s.as_str()), Some("serve"));
        let entries = rep.json.get("entries").and_then(|e| e.as_arr()).unwrap();
        let windows: Vec<&Json> = entries
            .iter()
            .filter(|e| e.get("kind").and_then(|k| k.as_str()) == Some("window"))
            .collect();
        assert_eq!(windows.len(), NOC_WINDOWS, "first policy's drift timeline");
        for w in &windows {
            assert!(w.get("window").and_then(|x| x.get("t0_s")).is_some());
            assert_eq!(entry_grid_max(w), w.get("max").and_then(|v| v.as_f64()).unwrap());
        }
        // Both policies emitted per-window class counters on their pids.
        let noc_events: Vec<_> = sv
            .obs
            .events()
            .into_iter()
            .filter(|e| e.name == "noc_load")
            .collect();
        assert_eq!(noc_events.len(), 2 * NOC_WINDOWS);
        let pids: std::collections::BTreeSet<u32> =
            noc_events.iter().map(|e| e.pid).collect();
        assert_eq!(pids.len(), 2, "one sim pid per policy");
    }
}
