//! The observability summary table (`report::obs`): every registry
//! counter, gauge, and histogram of a run that carried `--obs` /
//! `--trace-out`, as one table + JSON artifact (`reports/obs.json`)
//! alongside whatever reports the subcommand already emits.

use crate::obs::Obs;
use crate::util::json::Json;
use crate::util::table::Table;

use super::Report;

/// Roll an observability handle up into a report. `None` when the handle
/// is disabled or recorded nothing, so call sites can append the result
/// unconditionally without growing the default report set.
pub fn obs_report(obs: &Obs) -> Option<Report> {
    if obs.is_silent() {
        return None;
    }
    let mut table = Table::new("Observability counters", &["counter", "kind", "value"]);
    for (name, kind, value) in obs.counter_rows() {
        table.row(&[name, kind, value]);
    }
    let mut json = Json::obj();
    json.set("counters", obs.counters_json());
    json.set("trace_events", obs.events().len() as u64);
    json.set("dropped_events", obs.dropped_events());
    Some(Report {
        name: "obs",
        table,
        json,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_or_silent_handles_produce_no_report() {
        assert!(obs_report(&Obs::disabled()).is_none());
        assert!(obs_report(&Obs::enabled()).is_none(), "silent handle");
    }

    #[test]
    fn recorded_counters_land_in_table_and_json() {
        let obs = Obs::enabled();
        obs.count("serve.fifo.arrivals", 5);
        obs.gauge("serve.fifo.span_s", 0.25);
        obs.observe("serve.fifo.latency_ms", 1.5);
        obs.instant("e", crate::obs::PID_SIM, 0, 0.0);
        let r = obs_report(&obs).expect("non-silent handle reports");
        assert_eq!(r.name, "obs");
        assert_eq!(r.table.rows.len(), 3);
        assert!(r.table.rows.iter().any(|row| row[0] == "serve.fifo.arrivals"));
        let counters = r.json.get("counters").expect("counters key");
        assert!(counters.get("serve.fifo.latency_ms").is_some());
        assert_eq!(
            r.json.get("trace_events").and_then(|v| v.as_f64()),
            Some(1.0)
        );
        // The artifact round-trips through the JSON parser.
        let text = r.json.to_pretty();
        assert_eq!(Json::parse(&text).unwrap(), r.json);
    }
}
