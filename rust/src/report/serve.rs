//! Serving report: per-policy tail latencies, deadline-miss accounting and
//! schedulability verdicts per scenario, plus the rate-sweep boundary
//! table (the `pipeorgan serve` artifacts; see DESIGN.md §Serve).

use crate::config::ArchConfig;
use crate::serve::{ServeConfig, ServeOutcome, ServeRun, SweepResult};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::Report;

fn outcome_json(o: &ServeOutcome) -> Json {
    let mut tasks = Json::Arr(vec![]);
    for m in &o.tasks {
        let mut t = Json::obj();
        t.set("task", m.task.clone())
            .set("rate_hz", m.rate_hz)
            .set("deadline_ms", m.deadline_ms)
            .set("requests", m.requests)
            .set("completed", m.completed)
            .set("dropped", m.dropped)
            .set("missed", m.missed)
            .set("miss_rate", m.miss_rate())
            .set("p50_ms", m.p50_ms)
            .set("p95_ms", m.p95_ms)
            .set("p99_ms", m.p99_ms)
            .set("mean_wait_ms", m.mean_wait_ms)
            .set("max_queue_depth", m.max_queue_depth)
            .set("utilization", m.utilization);
        tasks.push(t);
    }
    let mut out = Json::obj();
    out.set("policy", o.policy.name())
        .set("bandwidth", o.bandwidth.name())
        .set("schedulable", o.schedulable())
        .set("span_s", o.span_s)
        .set("miss_rate", o.miss_rate())
        .set("tasks", tasks);
    out
}

fn sweep_json(s: &SweepResult) -> Json {
    let mut probes = Json::Arr(vec![]);
    for &(m, ok) in &s.probes {
        let mut p = Json::Arr(vec![]);
        p.push(m).push(ok);
        probes.push(p);
    }
    let mut out = Json::obj();
    out.set("policy", s.policy.name())
        .set("max_mult", s.max_mult)
        .set("probes", probes);
    out
}

/// One row per (scenario, policy, task) plus a VERDICT rollup row per
/// policy; when sweeps ran, a second report tabulates the schedulability
/// boundary per (scenario, policy). JSON mirrors everything, probes
/// included.
pub fn serve_reports(cfg: &ArchConfig, sv: &ServeConfig, runs: &[ServeRun]) -> Vec<Report> {
    let mut table = Table::new(
        "Serve — online deadline-aware serving on the co-scheduled array",
        &[
            "scenario",
            "policy",
            "task",
            "rate Hz",
            "requests",
            "served",
            "dropped",
            "missed",
            "miss %",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "max queue",
            "util %",
        ],
    );
    let mut arr = Json::Arr(vec![]);
    for r in runs {
        for o in &r.outcomes {
            for m in &o.tasks {
                table.row(&[
                    r.scenario.clone(),
                    o.policy.name().to_string(),
                    m.task.clone(),
                    fnum(m.rate_hz * sv.rate_mult),
                    m.requests.to_string(),
                    m.completed.to_string(),
                    m.dropped.to_string(),
                    m.missed.to_string(),
                    fnum(100.0 * m.miss_rate()),
                    fnum(m.p50_ms),
                    fnum(m.p95_ms),
                    fnum(m.p99_ms),
                    m.max_queue_depth.to_string(),
                    fnum(100.0 * m.utilization),
                ]);
            }
            table.row(&[
                r.scenario.clone(),
                o.policy.name().to_string(),
                "VERDICT".into(),
                "".into(),
                o.total_requests().to_string(),
                "".into(),
                "".into(),
                o.total_missed().to_string(),
                fnum(100.0 * o.miss_rate()),
                "".into(),
                "".into(),
                "".into(),
                "".into(),
                if o.schedulable() {
                    "SCHEDULABLE".into()
                } else {
                    "UNSCHEDULABLE".into()
                },
            ]);
        }
        let mut s = Json::obj();
        let mut sweeps = Json::Arr(vec![]);
        for sw in &r.sweeps {
            sweeps.push(sweep_json(sw));
        }
        let mut outcomes = Json::Arr(vec![]);
        for o in &r.outcomes {
            let mut oj = outcome_json(o);
            // Per-policy latency attribution (windowed breakdown, burn
            // rate, worst requests) when the run recorded it.
            if let Some(a) = super::attr::policy_attr_json(&r.plan, o) {
                oj.set("attr", a);
            }
            outcomes.push(oj);
        }
        // Per-region geometry of the plan being served (home region of
        // task `i` at index `i`), plus the cut tree that produced it —
        // serialized so external tooling can reconstruct the partition.
        let mut regions = Json::Arr(vec![]);
        for (i, (region, &topo)) in r.plan.regions.iter().zip(&r.plan.topologies).enumerate() {
            let mut g = Json::obj();
            g.set("task", r.plan.cosched.cosched.assignments[i].task.clone())
                .set("row0", region.row0)
                .set("col0", region.col0)
                .set("rows", region.rows)
                .set("cols", region.cols)
                .set("topology", topo.name())
                .set("entitlement_bytes_per_cycle", r.plan.entitlements[i]);
            regions.push(g);
        }
        s.set("scenario", r.scenario.clone())
            .set("partition", r.plan.cosched.partition.name())
            .set("cut_tree", r.plan.cosched.cut_tree.to_json())
            .set("regions", regions)
            .set("evaluations", r.plan.evaluations)
            .set("cache_hits", r.plan.cache_hits)
            .set("policies", outcomes)
            .set("sweeps", sweeps);
        arr.push(s);
    }
    let mut json = Json::obj();
    json.set("config", cfg.to_json())
        .set("arrivals", sv.arrivals.name())
        .set("duration_s", sv.duration_s)
        .set("rate_mult", sv.rate_mult)
        .set("seed", sv.seed)
        .set("borrow", sv.borrow)
        .set("bandwidth", sv.bandwidth.name())
        .set("scenarios", arr);
    let mut reports = vec![Report {
        name: "serve",
        table,
        json,
    }];

    if runs.iter().any(|r| !r.sweeps.is_empty()) {
        let mut sweep_table = Table::new(
            "Serve — max sustainable uniform rate multiplier (sweep)",
            &["scenario", "policy", "max rate mult", "probes", "schedulable @1x"],
        );
        let mut sweep_arr = Json::Arr(vec![]);
        for r in runs {
            for sw in &r.sweeps {
                let at_native = sw
                    .probes
                    .iter()
                    .find(|(m, _)| *m == 1.0)
                    .map(|&(_, ok)| ok)
                    .unwrap_or(false);
                sweep_table.row(&[
                    r.scenario.clone(),
                    sw.policy.name().to_string(),
                    fnum(sw.max_mult),
                    sw.probes.len().to_string(),
                    if at_native { "yes" } else { "no" }.to_string(),
                ]);
                let mut s = sweep_json(sw);
                s.set("scenario", r.scenario.clone());
                sweep_arr.push(s);
            }
        }
        let mut sweep_doc = Json::obj();
        sweep_doc
            .set("config", cfg.to_json())
            .set("duration_s", sv.duration_s)
            .set("sweeps", sweep_arr);
        reports.push(Report {
            name: "serve_sweep",
            table: sweep_table,
            json: sweep_doc,
        });
    }
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosched::{Scenario, TaskSpec};
    use crate::dse::EvalCache;
    use crate::serve::{run_scenario, Policy};
    use crate::workloads::synthetic;

    fn runs(sweep: bool) -> (ArchConfig, ServeConfig, Vec<ServeRun>) {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let mut a = synthetic::aw_chain(2.0, 4);
        a.name = "a".into();
        let mut b = synthetic::pointwise_conv_segment(2);
        b.name = "b".into();
        let sc = Scenario::new("pair", vec![TaskSpec::new(a, 30.0), TaskSpec::new(b, 60.0)]);
        let sv = ServeConfig {
            policies: vec![Policy::Fifo, Policy::Edf],
            duration_s: 0.05,
            sweep,
            ..ServeConfig::default()
        };
        let run = run_scenario(&sc, &cfg, &sv, &EvalCache::new(), 1).unwrap();
        (cfg, sv, vec![run])
    }

    #[test]
    fn report_tabulates_policies_and_parses() {
        let (cfg, sv, runs) = runs(false);
        let reports = serve_reports(&cfg, &sv, &runs);
        assert_eq!(reports.len(), 1, "no sweep requested, no sweep report");
        let r = &reports[0];
        assert_eq!(r.name, "serve");
        let md = r.table.to_markdown();
        for needle in ["fifo", "edf", "VERDICT", "SCHEDULABLE"] {
            assert!(md.contains(needle), "missing {needle} in:\n{md}");
        }
        // 2 tasks × 2 policies + 2 verdict rows.
        assert_eq!(r.table.rows.len(), 6);
        let text = r.json.to_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let scenarios = parsed.get("scenarios").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(scenarios.len(), 1);
        let policies = scenarios[0].get("policies").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(policies.len(), 2);
        // Attribution rides along on every policy (recorded by default).
        for p in policies {
            let a = p.get("attr").expect("attr block present");
            assert!(a.get("totals").is_some() && a.get("windows").is_some());
        }
        // Per-region geometry and the serialized cut tree ride along.
        let regions = scenarios[0].get("regions").and_then(|g| g.as_arr()).unwrap();
        assert_eq!(regions.len(), 2);
        for g in regions {
            assert!(g.get("topology").and_then(|t| t.as_str()).is_some());
            assert!(g.get("rows").and_then(|x| x.as_usize()).unwrap() > 0);
        }
        let tree = crate::cosched::CutTree::from_json(scenarios[0].get("cut_tree").unwrap());
        assert!(tree.is_ok(), "{tree:?}");
        assert_eq!(
            scenarios[0].get("partition").and_then(|p| p.as_str()),
            Some("bands")
        );
    }

    #[test]
    fn sweep_report_emitted_when_swept() {
        let (cfg, sv, runs) = runs(true);
        let reports = serve_reports(&cfg, &sv, &runs);
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[1].name, "serve_sweep");
        let md = reports[1].table.to_markdown();
        assert!(md.contains("max rate mult"), "{md}");
        // Two policies swept on one scenario.
        assert_eq!(reports[1].table.rows.len(), 2);
        let text = reports[1].json.to_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let sweeps = parsed.get("sweeps").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(sweeps.len(), 2);
        for sw in sweeps {
            let probes = sw.get("probes").and_then(|p| p.as_arr()).unwrap();
            assert!(!probes.is_empty());
        }
    }
}
