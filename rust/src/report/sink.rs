//! The artifact sink: one named-artifact registry behind every
//! standalone JSON export flag.
//!
//! Historically each artifact had its own scattered plumbing in
//! `main.rs` (`--trace-out`, `--attr-out`, `--flight-out`, `--noc-out`,
//! each with its own `if let Some(path)` and write call).
//! [`ArtifactSink`] centralizes that: artifacts are *named* (`trace`,
//! `attr`, `flight`, `noc`, `fleet`, ...), every legacy flag keeps
//! working as an alias for its name, and `--out-dir DIR` asks for *every*
//! artifact the subcommand produces, written as `DIR/<name>.json`.
//! Explicit per-artifact flags win over `--out-dir` for their artifact.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::cli::Args;
use crate::util::json::Json;

/// The legacy flag aliases: `(artifact name, flag)`. Registered on every
/// subcommand that can produce the artifact; `ArtifactSink` accepts any
/// of them whether or not the subcommand ever writes the name.
pub const ARTIFACT_ALIASES: &[(&str, &str)] = &[
    ("trace", "trace-out"),
    ("attr", "attr-out"),
    ("flight", "flight-out"),
    ("noc", "noc-out"),
];

/// Where standalone JSON artifacts go, resolved once from the CLI.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ArtifactSink {
    out_dir: Option<PathBuf>,
    explicit: BTreeMap<String, PathBuf>,
}

impl ArtifactSink {
    /// Resolve `--out-dir` plus every [`ARTIFACT_ALIASES`] flag present.
    pub fn from_cli(args: &Args) -> ArtifactSink {
        let mut explicit = BTreeMap::new();
        for &(name, flag) in ARTIFACT_ALIASES {
            if let Some(p) = args.get_path(flag) {
                explicit.insert(name.to_string(), p);
            }
        }
        ArtifactSink {
            out_dir: args.get_path("out-dir"),
            explicit,
        }
    }

    /// Should the producer of `name` bother building it? True when its
    /// alias flag was passed or `--out-dir` wants everything.
    pub fn wants(&self, name: &str) -> bool {
        self.out_dir.is_some() || self.explicit.contains_key(name)
    }

    /// The path `name` would be written to, if wanted: the explicit alias
    /// flag's path, else `out_dir/<name>.json`.
    pub fn path_for(&self, name: &str) -> Option<PathBuf> {
        self.explicit.get(name).cloned().or_else(|| {
            self.out_dir
                .as_ref()
                .map(|d| d.join(format!("{name}.json")))
        })
    }

    /// Write artifact `name` if anything asked for it; returns the path
    /// written (`None` when the artifact was not requested).
    pub fn write(&self, name: &str, json: &Json) -> Result<Option<PathBuf>, String> {
        let Some(path) = self.path_for(name) else {
            return Ok(None);
        };
        write_json_file(&path, json)?;
        Ok(Some(path))
    }
}

/// Write one standalone pretty-printed JSON document, creating parent
/// directories as needed — the single write path every artifact export
/// goes through (relocated from `main.rs`).
pub fn write_json_file(path: &Path, json: &Json) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("creating {}: {e}", dir.display()))?;
        }
    }
    std::fs::write(path, json.to_pretty()).map_err(|e| format!("writing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLAGS: &[(&str, bool)] = &[
        ("trace-out", true),
        ("attr-out", true),
        ("flight-out", true),
        ("noc-out", true),
        ("out-dir", true),
    ];

    fn sink(v: &[&str]) -> ArtifactSink {
        let raw: Vec<String> = std::iter::once("serve")
            .chain(v.iter().copied())
            .map(str::to_string)
            .collect();
        ArtifactSink::from_cli(&Args::parse(&raw, FLAGS).unwrap())
    }

    #[test]
    fn alias_flags_name_their_artifacts() {
        let s = sink(&["--attr-out", "x/a.json", "--noc-out", "n.json"]);
        assert!(s.wants("attr") && s.wants("noc"));
        assert!(!s.wants("trace") && !s.wants("flight"));
        assert_eq!(s.path_for("attr"), Some(PathBuf::from("x/a.json")));
        assert_eq!(s.path_for("noc"), Some(PathBuf::from("n.json")));
        assert_eq!(s.path_for("trace"), None);
    }

    #[test]
    fn out_dir_wants_everything_and_aliases_win() {
        let s = sink(&["--out-dir", "arts", "--attr-out", "custom.json"]);
        for name in ["trace", "attr", "flight", "noc", "fleet"] {
            assert!(s.wants(name), "{name}");
        }
        assert_eq!(s.path_for("attr"), Some(PathBuf::from("custom.json")));
        assert_eq!(s.path_for("noc"), Some(PathBuf::from("arts/noc.json")));
        assert_eq!(s.path_for("fleet"), Some(PathBuf::from("arts/fleet.json")));
    }

    #[test]
    fn write_creates_parents_and_skips_unrequested() {
        let dir = std::env::temp_dir().join("pipeorgan_sink_test");
        let _ = std::fs::remove_dir_all(&dir);
        let target = dir.join("deep/nested/a.json");
        let s = sink(&["--attr-out", target.to_str().unwrap()]);
        let mut doc = Json::obj();
        doc.set("ok", true);
        let written = s.write("attr", &doc).unwrap();
        assert_eq!(written, Some(target.clone()));
        let text = std::fs::read_to_string(&target).unwrap();
        assert!(Json::parse(&text).is_ok());
        // An artifact nobody asked for is a silent no-op.
        assert_eq!(s.write("noc", &doc).unwrap(), None);
        std::fs::remove_dir_all(&dir).ok();
    }
}
