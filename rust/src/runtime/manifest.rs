//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProgramSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    pub role: String,
}

/// The canonical segment dimensions the artifacts were built for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentSpec {
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    pub c_mid: usize,
    pub c_out: usize,
    pub band: usize,
    pub r: usize,
    pub s: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub segment: SegmentSpec,
    programs: Vec<(String, ProgramSpec)>,
}

fn tensor(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor missing shape")?
        .iter()
        .map(|x| x.as_usize().context("non-numeric dim"))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .unwrap_or("f32")
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest JSON: {e}"))?;
        let seg = root.get("segment").context("manifest missing `segment`")?;
        let d = |k: &str| -> Result<usize> {
            seg.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("segment missing `{k}`"))
        };
        let segment = SegmentSpec {
            h: d("h")?,
            w: d("w")?,
            c_in: d("c_in")?,
            c_mid: d("c_mid")?,
            c_out: d("c_out")?,
            band: d("band")?,
            r: d("r")?,
            s: d("s")?,
        };
        let progs = root
            .get("programs")
            .context("manifest missing `programs`")?;
        let Json::Obj(map) = progs else {
            anyhow::bail!("`programs` must be an object");
        };
        let mut programs = Vec::new();
        for (name, p) in map {
            let inputs = p
                .get("inputs")
                .and_then(Json::as_arr)
                .context("program missing inputs")?
                .iter()
                .map(tensor)
                .collect::<Result<Vec<_>>>()?;
            programs.push((
                name.clone(),
                ProgramSpec {
                    file: p
                        .get("file")
                        .and_then(Json::as_str)
                        .context("program missing file")?
                        .to_string(),
                    inputs,
                    output: tensor(p.get("output").context("program missing output")?)?,
                    role: p
                        .get("role")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                },
            ));
        }
        Ok(Manifest { segment, programs })
    }

    pub fn program(&self, name: &str) -> Option<&ProgramSpec> {
        self.programs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p)
    }

    pub fn program_names(&self) -> Vec<&str> {
        self.programs.iter().map(|(n, _)| n.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "segment": {"h": 32, "w": 32, "c_in": 8, "c_mid": 16, "c_out": 8,
                   "band": 8, "r": 3, "s": 3},
      "programs": {
        "gemm": {
          "file": "gemm.hlo.txt",
          "inputs": [{"shape": [64, 64], "dtype": "f32"},
                      {"shape": [64, 64], "dtype": "f32"}],
          "output": {"shape": [64, 64], "dtype": "f32"},
          "role": "quickstart"
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.segment.h, 32);
        assert_eq!(m.segment.band, 8);
        let g = m.program("gemm").unwrap();
        assert_eq!(g.file, "gemm.hlo.txt");
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.output.shape, vec![64, 64]);
        assert!(m.program("nope").is_none());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"segment": {}}"#).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            for name in ["segment_fused", "layer0", "layer1", "tile_layer0", "tile_layer1", "gemm"] {
                assert!(m.program(name).is_some(), "missing {name}");
            }
        }
    }
}
