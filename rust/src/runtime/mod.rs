//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path. Python never runs here — the Rust binary is
//! self-contained once `make artifacts` has produced `artifacts/*.hlo.txt`.
//!
//! Pattern follows /opt/xla-example/load_hlo: text (not serialized proto)
//! is the interchange format because xla_extension 0.5.1 rejects the
//! 64-bit instruction ids jax ≥ 0.5 emits.

mod manifest;

pub use manifest::{Manifest, ProgramSpec, SegmentSpec, TensorSpec};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A PJRT CPU runtime owning its client. NOT `Send`: each coordinator
/// worker thread builds its own `Runtime` and compiles its own programs
/// (compilation is cached per thread, not shared).
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

/// A compiled program ready to execute.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    /// Declared input shapes (row-major dims), from the manifest.
    pub input_shapes: Vec<Vec<usize>>,
    /// Declared output shape.
    pub output_shape: Vec<usize>,
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load the manifest describing all artifacts.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(self.artifacts_dir.join("manifest.json"))
    }

    /// Load + compile one program by manifest name.
    pub fn load_program(&self, name: &str) -> Result<Program> {
        let manifest = self.manifest()?;
        let spec = manifest
            .program(name)
            .with_context(|| format!("program `{name}` not in manifest"))?;
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Program {
            exe,
            input_shapes: spec.inputs.iter().map(|t| t.shape.clone()).collect(),
            output_shape: spec.output.shape.clone(),
            name: name.to_string(),
        })
    }
}

impl Program {
    /// Execute on f32 buffers. Inputs must match the declared shapes; the
    /// output is the flattened f32 result.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            inputs.len() == self.input_shapes.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.input_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&self.input_shapes) {
            let n: usize = shape.iter().product();
            anyhow::ensure!(
                buf.len() == n,
                "{}: input has {} elements, shape {:?} needs {n}",
                self.name,
                buf.len(),
                shape
            );
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .context("reshaping input literal")?,
            );
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let v = out.to_vec::<f32>().context("reading f32 result")?;
        let n: usize = self.output_shape.iter().product();
        anyhow::ensure!(
            v.len() == n,
            "{}: output has {} elements, expected {n}",
            self.name,
            v.len()
        );
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trip tests live in rust/tests/runtime_roundtrip.rs (they
    // need artifacts/ built); manifest parsing is tested in manifest.rs.
}
