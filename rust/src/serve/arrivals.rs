//! Per-task arrival processes: when requests show up.
//!
//! Every process is generated *up front* into a sorted `Vec<f64>` of
//! arrival instants over the simulated window. Pre-materializing (rather
//! than drawing lazily inside the event loop) keeps the whole stream a
//! pure function of `(process, rate, duration, seed)`, so different
//! dispatch policies replay byte-identical traffic and two runs with the
//! same seed are bit-identical — the determinism the integration tests
//! assert.
//!
//! Randomness goes through the seedable [`SplitMix64`] like everything
//! else in the crate (DESIGN.md §2).

use crate::cosched::Scenario;
use crate::util::rng::SplitMix64;

/// Jitter amplitude of [`ArrivalProcess::Jittered`] as a fraction of the
/// period, when selected by name on the CLI (`--arrivals jittered`).
pub const DEFAULT_JITTER_FRAC: f64 = 0.1;

/// Swing of [`ArrivalProcess::Diurnal`] when selected by name on the CLI
/// (`--arrivals diurnal`): peak rate is `1 + amp` times the nominal rate,
/// trough is `1 - amp` times (floored at 0.1% of nominal).
pub const DEFAULT_DIURNAL_AMP: f64 = 0.8;

/// How one task's requests arrive.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Strict-periodic: one request every `1/rate_hz` seconds, phase 0 —
    /// the frame clock of a camera or display pipeline.
    Periodic,
    /// Periodic with per-request uniform jitter of `± frac/2` periods —
    /// a frame clock with transport wobble.
    Jittered(f64),
    /// Poisson: i.i.d. exponential gaps at `rate_hz` — open-loop traffic
    /// such as voice activity or network-fed requests.
    Poisson,
    /// Deterministic diurnal load curve: instantaneous rate follows one
    /// sinusoidal "day" of `period_s` seconds (the whole window when
    /// `period_s <= 0`), starting at the trough and peaking mid-window.
    /// `amp` is the swing as a fraction of the nominal rate. Consumes no
    /// randomness, so the curve is seed-independent like `Periodic` —
    /// the fleet autoscaler tests replay it exactly.
    Diurnal { period_s: f64, amp: f64 },
    /// Replay of an externally captured timestamp trace (seconds).
    Trace(Vec<f64>),
}

impl ArrivalProcess {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Periodic => "periodic",
            ArrivalProcess::Jittered(_) => "jittered",
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Diurnal { .. } => "diurnal",
            ArrivalProcess::Trace(_) => "trace",
        }
    }

    /// CLI names. `Trace` is API-only (a trace has no flag syntax). The
    /// named diurnal curve spans the whole simulated window once
    /// (`period_s <= 0`) at the default swing.
    pub fn from_name(s: &str) -> Option<ArrivalProcess> {
        match s {
            "periodic" => Some(ArrivalProcess::Periodic),
            "jittered" => Some(ArrivalProcess::Jittered(DEFAULT_JITTER_FRAC)),
            "poisson" => Some(ArrivalProcess::Poisson),
            "diurnal" => Some(ArrivalProcess::Diurnal {
                period_s: 0.0,
                amp: DEFAULT_DIURNAL_AMP,
            }),
            _ => None,
        }
    }
}

/// Arrival instants in `[0, duration_s)`, sorted ascending. The RNG is
/// consumed only by the stochastic processes, so periodic streams are
/// seed-independent by construction.
pub fn arrival_times(
    process: &ArrivalProcess,
    rate_hz: f64,
    duration_s: f64,
    rng: &mut SplitMix64,
) -> Vec<f64> {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    assert!(duration_s > 0.0, "arrival window must be positive");
    let period = 1.0 / rate_hz;
    let mut out: Vec<f64> = match process {
        ArrivalProcess::Periodic => (0..)
            .map(|k| k as f64 * period)
            .take_while(|&t| t < duration_s)
            .collect(),
        ArrivalProcess::Jittered(frac) => (0..)
            .map(|k| k as f64 * period)
            .take_while(|&t| t < duration_s)
            .map(|t| (t + frac * period * (rng.gen_f64() - 0.5)).max(0.0))
            .collect(),
        ArrivalProcess::Poisson => {
            let mut out = Vec::new();
            let mut t = 0.0f64;
            loop {
                // Exponential gap; `1 - u` is in (0, 1], so ln is finite.
                t += -(1.0 - rng.gen_f64()).ln() * period;
                if t >= duration_s {
                    break;
                }
                out.push(t);
            }
            out
        }
        ArrivalProcess::Diurnal { period_s, amp } => {
            // Step the clock by the instantaneous period 1/r(t): a gap is
            // long near the trough and short near the peak. The phase
            // shift puts the trough at t = 0, so load ramps up, crests at
            // half a day, and ebbs — the shape the fleet autoscaler
            // chases. Rate is floored at 0.1% of nominal so amp >= 1
            // cannot stall the generator.
            let p = if *period_s > 0.0 { *period_s } else { duration_s };
            let amp = amp.max(0.0);
            let rate_at = |t: f64| {
                let phase = std::f64::consts::TAU * t / p - std::f64::consts::FRAC_PI_2;
                (rate_hz * (1.0 + amp * phase.sin())).max(1e-3 * rate_hz)
            };
            let mut out = Vec::new();
            let mut t = 1.0 / rate_at(0.0);
            while t < duration_s {
                out.push(t);
                t += 1.0 / rate_at(t);
            }
            out
        }
        ArrivalProcess::Trace(ts) => ts
            .iter()
            .copied()
            .filter(|&t| (0.0..duration_s).contains(&t))
            .collect(),
    };
    // Jitter can reorder neighbours and traces may arrive unsorted; the
    // event loop requires ascending instants.
    out.sort_by(|a, b| a.total_cmp(b));
    out.retain(|&t| t < duration_s);
    out
}

/// One arrival stream per task of `scenario`, each task's RNG derived
/// from the master `seed` in task order. This is the *single* source of
/// truth for the seed → streams mapping: the engine, the rate sweep, the
/// benches and the determinism tests all generate traffic through it, so
/// "same seed, same streams" can never drift between them.
pub fn streams(
    scenario: &Scenario,
    process: &ArrivalProcess,
    rate_mult: f64,
    duration_s: f64,
    seed: u64,
) -> Vec<Vec<f64>> {
    let mut master = SplitMix64::new(seed);
    scenario
        .tasks
        .iter()
        .map(|t| {
            let mut rng = SplitMix64::new(master.next_u64());
            arrival_times(process, t.rate_hz * rate_mult, duration_s, &mut rng)
        })
        .collect()
}

/// Parse a `--trace-file` body: one row per line, one whitespace- (or
/// comma-) separated timestamp column per task, in seconds. Blank lines
/// and `#` comments are skipped; `-` marks a missing cell, so columns may
/// have different lengths. Every data row must have the same number of
/// columns as the first.
pub fn parse_trace_columns(text: &str) -> Result<Vec<Vec<f64>>, String> {
    let mut columns: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let cells: Vec<&str> = line
            .split(|c: char| c.is_whitespace() || c == ',')
            .filter(|s| !s.is_empty())
            .collect();
        if columns.is_empty() {
            columns = vec![Vec::new(); cells.len()];
        } else if cells.len() != columns.len() {
            return Err(format!(
                "trace line {}: {} columns, expected {}",
                lineno + 1,
                cells.len(),
                columns.len()
            ));
        }
        for (col, cell) in columns.iter_mut().zip(cells) {
            if cell == "-" {
                continue;
            }
            let t: f64 = cell
                .parse()
                .map_err(|_| format!("trace line {}: bad timestamp {cell:?}", lineno + 1))?;
            if !t.is_finite() {
                return Err(format!("trace line {}: non-finite timestamp {cell:?}", lineno + 1));
            }
            col.push(t);
        }
    }
    if columns.is_empty() {
        return Err("trace file has no data rows".to_string());
    }
    Ok(columns)
}

/// One replay stream per trace column, through [`arrival_times`]'s
/// `Trace` arm so the sort/window semantics (ascending, `[0, duration_s)`)
/// are identical to API-driven replays.
pub fn trace_streams(columns: &[Vec<f64>], duration_s: f64) -> Vec<Vec<f64>> {
    let mut rng = SplitMix64::new(0); // Trace consumes no randomness.
    columns
        .iter()
        .map(|ts| arrival_times(&ArrivalProcess::Trace(ts.clone()), 1.0, duration_s, &mut rng))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_counts_and_phase() {
        let mut rng = SplitMix64::new(1);
        let ts = arrival_times(&ArrivalProcess::Periodic, 10.0, 1.0, &mut rng);
        assert_eq!(ts.len(), 10);
        assert_eq!(ts[0], 0.0);
        assert!((ts[9] - 0.9).abs() < 1e-12);
        // Seed-independent: no randomness consumed.
        let mut other = SplitMix64::new(999);
        assert_eq!(ts, arrival_times(&ArrivalProcess::Periodic, 10.0, 1.0, &mut other));
    }

    #[test]
    fn jittered_stays_sorted_and_in_window() {
        let mut rng = SplitMix64::new(7);
        let ts = arrival_times(&ArrivalProcess::Jittered(0.5), 100.0, 1.0, &mut rng);
        assert!(!ts.is_empty());
        assert!(ts.windows(2).all(|p| p[0] <= p[1]), "unsorted: {ts:?}");
        assert!(ts.iter().all(|&t| (0.0..1.0).contains(&t)));
    }

    #[test]
    fn poisson_is_deterministic_per_seed_and_differs_across_seeds() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        let ta = arrival_times(&ArrivalProcess::Poisson, 100.0, 1.0, &mut a);
        let tb = arrival_times(&ArrivalProcess::Poisson, 100.0, 1.0, &mut b);
        assert_eq!(ta, tb, "same seed must replay identically");
        let mut c = SplitMix64::new(43);
        let tc = arrival_times(&ArrivalProcess::Poisson, 100.0, 1.0, &mut c);
        assert_ne!(ta, tc, "different seeds must differ");
        // Roughly the right rate (100 expected over 1 s).
        assert!(ta.len() > 50 && ta.len() < 200, "n={}", ta.len());
        assert!(ta.windows(2).all(|p| p[0] <= p[1]));
    }

    #[test]
    fn diurnal_is_seed_independent_and_peaks_mid_window() {
        let proc = ArrivalProcess::Diurnal { period_s: 0.0, amp: 0.8 };
        let mut rng = SplitMix64::new(3);
        let ts = arrival_times(&proc, 100.0, 1.0, &mut rng);
        assert!(ts.windows(2).all(|p| p[0] <= p[1]));
        assert!(ts.iter().all(|&t| (0.0..1.0).contains(&t)));
        // No randomness consumed: any seed replays the same curve.
        let mut other = SplitMix64::new(777);
        assert_eq!(ts, arrival_times(&proc, 100.0, 1.0, &mut other));
        // The middle third of the day (around the crest) carries more
        // traffic than the first third (which starts at the trough).
        let first = ts.iter().filter(|&&t| t < 1.0 / 3.0).count();
        let mid = ts.iter().filter(|&&t| (1.0 / 3.0..2.0 / 3.0).contains(&t)).count();
        assert!(mid > first, "mid={mid} first={first}");
        // amp = 0 degenerates to (phase-shifted) periodic spacing.
        let flat = ArrivalProcess::Diurnal { period_s: 0.0, amp: 0.0 };
        let fts = arrival_times(&flat, 100.0, 1.0, &mut rng);
        assert!(fts.windows(2).all(|p| (p[1] - p[0] - 0.01).abs() < 1e-9));
    }

    #[test]
    fn trace_replay_filters_and_sorts() {
        let mut rng = SplitMix64::new(0);
        let trace = ArrivalProcess::Trace(vec![0.5, 0.1, 2.0, -0.3, 0.1]);
        let ts = arrival_times(&trace, 1.0, 1.0, &mut rng);
        assert_eq!(ts, vec![0.1, 0.1, 0.5]);
    }

    #[test]
    fn streams_are_seed_deterministic_and_per_task_independent() {
        use crate::cosched::TaskSpec;
        use crate::workloads::synthetic;
        let mut a = synthetic::aw_chain(2.0, 3);
        a.name = "a".into();
        let mut b = synthetic::pointwise_conv_segment(2);
        b.name = "b".into();
        let sc = Scenario::new("pair", vec![TaskSpec::new(a, 50.0), TaskSpec::new(b, 80.0)]);
        let x = streams(&sc, &ArrivalProcess::Poisson, 1.0, 0.5, 7);
        assert_eq!(x.len(), 2);
        assert_eq!(x, streams(&sc, &ArrivalProcess::Poisson, 1.0, 0.5, 7));
        assert_ne!(x, streams(&sc, &ArrivalProcess::Poisson, 1.0, 0.5, 8));
        // The rate multiplier scales every task's stream.
        let dense = streams(&sc, &ArrivalProcess::Periodic, 4.0, 0.5, 7);
        let sparse = streams(&sc, &ArrivalProcess::Periodic, 1.0, 0.5, 7);
        assert!(dense[0].len() > sparse[0].len());
    }

    #[test]
    fn trace_columns_parse_comments_ragged_and_commas() {
        let text = "# device capture\n0.1 0.2\n0.3, -\n\n0.05 0.4 # tail\n";
        let cols = parse_trace_columns(text).unwrap();
        assert_eq!(cols, vec![vec![0.1, 0.3, 0.05], vec![0.2, 0.4]]);
        // Streams come back sorted and windowed like any trace replay.
        let streams = trace_streams(&cols, 0.35);
        assert_eq!(streams, vec![vec![0.05, 0.1, 0.3], vec![0.2]]);
    }

    #[test]
    fn trace_columns_reject_bad_shapes() {
        assert!(parse_trace_columns("").is_err(), "no data rows");
        assert!(parse_trace_columns("# only comments\n").is_err());
        assert!(parse_trace_columns("0.1 0.2\n0.3\n").is_err(), "ragged row");
        assert!(parse_trace_columns("0.1 oops\n").is_err(), "bad number");
        assert!(parse_trace_columns("inf\n").is_err(), "non-finite");
    }

    #[test]
    fn names_roundtrip() {
        for name in ["periodic", "jittered", "poisson", "diurnal"] {
            let p = ArrivalProcess::from_name(name).unwrap();
            assert_eq!(p.name(), name);
        }
        assert!(ArrivalProcess::from_name("bursty").is_none());
        assert_eq!(ArrivalProcess::Trace(vec![]).name(), "trace");
    }
}
