//! The array-agnostic discrete-event core: a versioned binary-heap event
//! loop shared by the single-array simulator ([`super::engine`]) and the
//! fleet layer ([`super::fleet`]).
//!
//! The split is an API seam, not a behavior change: [`EventCore`] owns
//! exactly the heap + sequence counter the engine's loop used to own
//! inline, events order by `(t_s, seq)` with the same `total_cmp`
//! tie-break, and [`drive`] replays the engine's loop skeleton —
//! stale-version internal events are skipped *before* any model state
//! (including its clock) advances, so cancelled completions can never
//! stretch the reported span. Everything array-specific (queues, regions,
//! bandwidth splits, tracing) lives behind [`ServiceModel`]; the
//! single-array model is [`super::engine::ArrayModel`] and the fleet
//! composes one `ArrayModel` per chip behind a front-door router.
//!
//! The model owns its own clock(s): [`drive`] hands each handler the
//! event's absolute instant and the model drains elapsed work itself
//! (lazily per chip, in the fleet's case — sound because a chip's drain
//! rates only change at that chip's own events).

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use super::dispatch::Request;

/// One event of the shared loop.
///
/// `Internal` is the versioned-completion mechanism: models schedule
/// completions under a `(slot, version)` pair and cancel them wholesale by
/// bumping the slot's version — [`drive`] asks [`ServiceModel::is_stale`]
/// and discards stale events without touching the model. A *slot* is a
/// model-defined service-station index; the single-array model uses its
/// region index, the fleet offsets each chip's regions by a per-chip base.
#[derive(Debug, Clone, Copy)]
pub enum CoreEvent {
    /// An external request entering the system.
    Arrival(Request),
    /// A model-scheduled (cancellable) internal event, e.g. a stage
    /// completion on service station `slot`.
    Internal { slot: usize, version: u64 },
}

struct Ev {
    t_s: f64,
    seq: u64,
    kind: CoreEvent,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t_s.total_cmp(&other.t_s).then(self.seq.cmp(&other.seq))
    }
}

/// The reusable event loop: a min-heap of [`CoreEvent`]s ordered by
/// `(t_s, seq)`. The sequence number makes simultaneous events replay in
/// push order — the determinism tie-break the whole serve stack relies on.
///
/// [`EventCore::clear`] resets the counter but keeps the heap's buffer,
/// so scratch reuse across rate-sweep probes stays allocation-free
/// ([`super::SimScratch`]).
#[derive(Default)]
pub struct EventCore {
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
}

impl EventCore {
    pub fn new() -> EventCore {
        EventCore::default()
    }

    /// Schedule `kind` at `t_s`, tie-broken after everything already
    /// pushed.
    pub fn push(&mut self, t_s: f64, kind: CoreEvent) {
        self.heap.push(Reverse(Ev {
            t_s,
            seq: self.seq,
            kind,
        }));
        self.seq += 1;
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(f64, CoreEvent)> {
        self.heap.pop().map(|Reverse(ev)| (ev.t_s, ev.kind))
    }

    /// Drop all pending events and restart the sequence counter; the
    /// heap keeps its capacity.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// What an array (or a cluster of arrays) must implement to be driven by
/// [`drive`]. Handlers receive the event's absolute instant and the core,
/// so they can schedule further internal events; the model keeps its own
/// clock(s) and drains elapsed in-flight work before mutating state.
pub trait ServiceModel {
    /// Is this `(slot, version)` internal event cancelled? Asked *before*
    /// the model sees the event; stale events are discarded without
    /// advancing any clock.
    fn is_stale(&self, slot: usize, version: u64) -> bool;

    /// An external request arrives at `t_s`.
    fn on_arrival(&mut self, req: Request, t_s: f64, core: &mut EventCore);

    /// A live internal event on `slot` fires at `t_s`.
    fn on_internal(&mut self, slot: usize, t_s: f64, core: &mut EventCore);
}

/// Run the loop to quiescence and return the instant of the last *live*
/// event (0.0 when nothing ran) — the served span. Stale internal events
/// advance nothing, exactly like the pre-split engine loop.
pub fn drive<M: ServiceModel>(model: &mut M, core: &mut EventCore) -> f64 {
    let mut last_s = 0.0f64;
    while let Some((t_s, kind)) = core.pop() {
        match kind {
            CoreEvent::Internal { slot, version } => {
                if model.is_stale(slot, version) {
                    continue;
                }
                last_s = t_s;
                model.on_internal(slot, t_s, core);
            }
            CoreEvent::Arrival(req) => {
                last_s = t_s;
                model.on_arrival(req, t_s, core);
            }
        }
    }
    last_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_order_by_time_then_sequence() {
        let mut core = EventCore::new();
        core.push(2.0, CoreEvent::Internal { slot: 0, version: 1 });
        core.push(1.0, CoreEvent::Internal { slot: 1, version: 1 });
        core.push(1.0, CoreEvent::Internal { slot: 2, version: 1 });
        let order: Vec<usize> = std::iter::from_fn(|| core.pop())
            .map(|(_, k)| match k {
                CoreEvent::Internal { slot, .. } => slot,
                CoreEvent::Arrival(_) => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 0], "time first, then push order");
        assert!(core.is_empty());
    }

    #[test]
    fn clear_resets_the_sequence_counter() {
        let mut core = EventCore::new();
        core.push(1.0, CoreEvent::Internal { slot: 0, version: 0 });
        core.clear();
        assert_eq!(core.len(), 0);
        // Two same-instant pushes after a clear replay in push order —
        // the counter restarted, it did not keep climbing from before.
        core.push(5.0, CoreEvent::Internal { slot: 7, version: 0 });
        core.push(5.0, CoreEvent::Internal { slot: 8, version: 0 });
        let (_, first) = core.pop().unwrap();
        assert!(matches!(first, CoreEvent::Internal { slot: 7, .. }));
    }

    /// A minimal model: every arrival schedules one completion, half of
    /// which get cancelled by a version bump; `drive` must skip the stale
    /// ones without counting them into the span.
    struct Toy {
        versions: Vec<u64>,
        arrivals: u64,
        completions: u64,
    }

    impl ServiceModel for Toy {
        fn is_stale(&self, slot: usize, version: u64) -> bool {
            self.versions[slot] != version
        }
        fn on_arrival(&mut self, req: Request, t_s: f64, core: &mut EventCore) {
            self.arrivals += 1;
            let slot = req.task;
            self.versions[slot] += 1;
            core.push(
                t_s + 1.0,
                CoreEvent::Internal {
                    slot,
                    version: self.versions[slot],
                },
            );
        }
        fn on_internal(&mut self, _slot: usize, _t_s: f64, _core: &mut EventCore) {
            self.completions += 1;
        }
    }

    #[test]
    fn drive_skips_stale_events_and_reports_the_live_span() {
        let mut core = EventCore::new();
        // Two arrivals on one slot: the second cancels the first's
        // completion (version bump), so exactly one completion fires.
        let req = |t| Request {
            task: 0,
            id: 0,
            arrival_s: t,
            deadline_s: t + 1.0,
        };
        core.push(0.0, CoreEvent::Arrival(req(0.0)));
        core.push(0.5, CoreEvent::Arrival(req(0.5)));
        let mut toy = Toy {
            versions: vec![0],
            arrivals: 0,
            completions: 0,
        };
        let span = drive(&mut toy, &mut core);
        assert_eq!(toy.arrivals, 2);
        assert_eq!(toy.completions, 1, "stale completion skipped");
        assert_eq!(span, 1.5, "span is the last live event, not the stale one");
    }
}
