//! Dispatch policies: which queued request a freed region serves next.
//!
//! Each task owns one region of the co-scheduled partition and keeps its
//! own FIFO arrival queue. Within a single task's queue every policy
//! agrees on the order (deadlines are `arrival + constant`, so EDF order
//! *is* arrival order); policies differ in two places:
//!
//! - **deadline awareness**: [`Policy::Edf`] and [`Policy::Rm`] never
//!   spend a region on a request that cannot meet its deadline even at
//!   the best-case service time (full-array DRAM bandwidth donated) — such
//!   requests are dropped at dispatch time and counted as misses, instead
//!   of being served late *and* delaying everything behind them.
//!   [`Policy::Fifo`] is the deadline-blind baseline: it serves strictly
//!   in arrival order, doomed requests included.
//! - **cross-task borrowing** (opt-in): when a region is idle and its own
//!   queue is empty it may serve another task's queued request. Which
//!   queue it steals from is the policy's choice: FIFO takes the oldest
//!   request, EDF the most urgent, RM the highest-rate (shortest-period)
//!   task's — the classic rate-monotonic priority order.
//!
//! Dropping only ever removes requests that would miss under *any*
//! policy: without borrowing, a request's home region is the only server
//! it will ever see, so "best case on the home region already misses" is
//! final; with borrowing, a request is dropped only when the best case on
//! *every* region misses (`doomed`), and a foreign front that this region
//! cannot save — but its own (or a wider) region still could — is merely
//! *skipped*, left queued for a better server. This is what makes the
//! deadline-aware policies no worse than FIFO on miss rate in the regimes
//! the integration tests pin down.

use std::collections::VecDeque;

/// Dispatch order of a freed region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First-in-first-out by arrival instant; deadline-blind.
    Fifo,
    /// Earliest (absolute) deadline first; drops hopeless requests.
    Edf,
    /// Rate-monotonic: highest-rate task first; drops hopeless requests.
    Rm,
}

impl Policy {
    /// All policies, in reporting order.
    pub const ALL: [Policy; 3] = [Policy::Fifo, Policy::Edf, Policy::Rm];

    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Edf => "edf",
            Policy::Rm => "rm",
        }
    }

    pub fn from_name(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "edf" => Some(Policy::Edf),
            "rm" => Some(Policy::Rm),
            _ => None,
        }
    }

    /// Deadline-aware policies drop requests that cannot meet their
    /// deadline even in the best case instead of serving them late.
    pub fn deadline_aware(self) -> bool {
        !matches!(self, Policy::Fifo)
    }
}

/// One queued inference request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Task (and home region) index within the scenario.
    pub task: usize,
    /// Per-task arrival sequence number.
    pub id: u64,
    /// Arrival instant (seconds).
    pub arrival_s: f64,
    /// Absolute deadline (seconds): `arrival + deadline_ms / 1e3`.
    pub deadline_s: f64,
}

/// Pop droppable requests off the front of `q`. Within one task's queue
/// deadlines ascend with arrival order and best-case service times are
/// per-(task, region) constants, so both drop rules are monotone in queue
/// position: once the front survives, everything behind it does too — the
/// front-only purge is complete.
fn purge_hopeless(
    q: &mut VecDeque<Request>,
    rule: &dyn Fn(&Request) -> bool,
    dropped: &mut Vec<Request>,
) {
    while let Some(front) = q.front() {
        if rule(front) {
            dropped.push(q.pop_front().expect("front exists"));
        } else {
            break;
        }
    }
}

/// Choose the next request for the region owned by task `home`.
///
/// Returns the requests dropped as unsalvageable (deadline-aware policies
/// only) and the chosen request, already popped from its queue.
/// `hopeless_here` answers for the *serving* region ("can this request
/// still meet its deadline if service starts here, now, at best-case
/// speed?"); `doomed` answers for *every* region ("does even the fastest
/// region's best case miss?"). Without borrowing the home region is a
/// request's only possible server, so `hopeless_here` is already final
/// and drives the drops; with borrowing only `doomed` requests are
/// dropped, and a foreign front that is merely hopeless *here* is
/// skipped — left queued for its own or a faster region.
pub fn select_next(
    policy: Policy,
    queues: &mut [VecDeque<Request>],
    home: usize,
    borrow: bool,
    rates_hz: &[f64],
    hopeless_here: &dyn Fn(&Request) -> bool,
    doomed: &dyn Fn(&Request) -> bool,
) -> (Vec<Request>, Option<Request>) {
    let mut dropped = Vec::new();
    let drop_rule = if borrow { doomed } else { hopeless_here };
    if policy.deadline_aware() {
        purge_hopeless(&mut queues[home], drop_rule, &mut dropped);
    }
    let candidates: Vec<usize> = if !queues[home].is_empty() {
        vec![home]
    } else if borrow {
        if policy.deadline_aware() {
            for q in queues.iter_mut() {
                purge_hopeless(q, drop_rule, &mut dropped);
            }
        }
        (0..queues.len())
            .filter(|&t| match queues[t].front() {
                // Aware borrowers skip foreign fronts they cannot save:
                // serving one late here would waste the region *and* the
                // request, while a better region may still meet it.
                Some(front) => !(policy.deadline_aware() && hopeless_here(front)),
                None => false,
            })
            .collect()
    } else {
        Vec::new()
    };
    if candidates.is_empty() {
        return (dropped, None);
    }
    // Per-candidate sort key: primary then secondary objective, with the
    // task index as the final deterministic tie-break.
    let key = |t: usize| -> (f64, f64) {
        let front = queues[t].front().expect("candidates are non-empty");
        match policy {
            Policy::Fifo => (front.arrival_s, front.deadline_s),
            Policy::Edf => (front.deadline_s, front.arrival_s),
            Policy::Rm => (1.0 / rates_hz[t].max(1e-12), front.arrival_s),
        }
    };
    let chosen = candidates
        .iter()
        .copied()
        .min_by(|&a, &b| {
            let (a0, a1) = key(a);
            let (b0, b1) = key(b);
            a0.total_cmp(&b0).then(a1.total_cmp(&b1)).then(a.cmp(&b))
        })
        .expect("candidates are non-empty");
    let req = queues[chosen].pop_front();
    (dropped, req)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(task: usize, id: u64, arrival_s: f64, deadline_s: f64) -> Request {
        Request {
            task,
            id,
            arrival_s,
            deadline_s,
        }
    }

    fn queues(reqs: &[&[Request]]) -> Vec<VecDeque<Request>> {
        reqs.iter().map(|q| q.iter().copied().collect()).collect()
    }

    const NEVER: fn(&Request) -> bool = |_| false;

    #[test]
    fn names_roundtrip_and_awareness() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_name(p.name()), Some(p));
        }
        assert!(Policy::from_name("lifo").is_none());
        assert!(!Policy::Fifo.deadline_aware());
        assert!(Policy::Edf.deadline_aware() && Policy::Rm.deadline_aware());
    }

    #[test]
    fn own_queue_wins_even_when_borrowing() {
        let mut qs = queues(&[
            &[req(0, 0, 5.0, 6.0)],
            &[req(1, 0, 0.0, 0.5)], // older and more urgent, but foreign
        ]);
        let rates = [10.0, 100.0];
        for p in Policy::ALL {
            let (dropped, got) = select_next(p, &mut qs.clone(), 0, true, &rates, &NEVER, &NEVER);
            assert!(dropped.is_empty());
            assert_eq!(got.unwrap().task, 0, "{p:?} must serve its home queue first");
        }
        // Without borrowing an empty home queue serves nothing.
        qs[0].clear();
        let (_, got) = select_next(Policy::Fifo, &mut qs, 0, false, &rates, &NEVER, &NEVER);
        assert!(got.is_none());
    }

    #[test]
    fn borrow_order_is_policy_specific() {
        // Task 1: older arrival, later deadline, low rate.
        // Task 2: newer arrival, earlier deadline, high rate.
        let build = || {
            queues(&[
                &[],
                &[req(1, 0, 0.0, 10.0)],
                &[req(2, 0, 1.0, 2.0)],
            ])
        };
        let rates = [10.0, 5.0, 50.0];
        let (_, fifo) = select_next(Policy::Fifo, &mut build(), 0, true, &rates, &NEVER, &NEVER);
        assert_eq!(fifo.unwrap().task, 1, "FIFO borrows the oldest");
        let (_, edf) = select_next(Policy::Edf, &mut build(), 0, true, &rates, &NEVER, &NEVER);
        assert_eq!(edf.unwrap().task, 2, "EDF borrows the most urgent");
        let (_, rm) = select_next(Policy::Rm, &mut build(), 0, true, &rates, &NEVER, &NEVER);
        assert_eq!(rm.unwrap().task, 2, "RM borrows the highest-rate task");
    }

    #[test]
    fn aware_policies_drop_hopeless_fifo_serves_them() {
        let hopeless = |r: &Request| r.deadline_s < 1.0;
        let build = || {
            queues(&[&[
                req(0, 0, 0.0, 0.5), // doomed
                req(0, 1, 0.1, 0.6), // doomed
                req(0, 2, 0.2, 5.0), // viable
            ]])
        };
        let rates = [10.0];
        // Without borrowing the home region is the only server, so the
        // here-hopeless rule drives the drops.
        let (dropped, got) =
            select_next(Policy::Edf, &mut build(), 0, false, &rates, &hopeless, &NEVER);
        assert_eq!(dropped.len(), 2);
        assert_eq!(got.unwrap().id, 2, "EDF skips straight to the viable request");
        let (dropped, got) =
            select_next(Policy::Fifo, &mut build(), 0, false, &rates, &hopeless, &NEVER);
        assert!(dropped.is_empty(), "FIFO is deadline-blind");
        assert_eq!(got.unwrap().id, 0);
    }

    #[test]
    fn borrowers_skip_but_never_drop_requests_other_regions_could_save() {
        // This (narrow) region cannot meet task 1's front, but some other
        // region still can: the front must stay queued, not be dropped,
        // and the borrower must fall through to a front it can serve.
        let hopeless_here = |r: &Request| r.task == 1;
        let build = || queues(&[&[], &[req(1, 0, 0.0, 0.2)], &[req(2, 0, 1.0, 9.0)]]);
        let rates = [10.0, 10.0, 10.0];
        let mut qs = build();
        let (dropped, got) =
            select_next(Policy::Edf, &mut qs, 0, true, &rates, &hopeless_here, &NEVER);
        assert!(dropped.is_empty(), "viable-elsewhere requests are never dropped");
        assert_eq!(got.unwrap().task, 2, "the borrower serves what it can save");
        assert_eq!(qs[1].len(), 1, "task 1's front stays queued for a better region");
        // Globally doomed requests are dropped even from foreign queues.
        let doomed = |r: &Request| r.task == 1;
        let mut qs = build();
        let (dropped, got) =
            select_next(Policy::Edf, &mut qs, 0, true, &rates, &hopeless_here, &doomed);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].task, 1);
        assert_eq!(got.unwrap().task, 2);
        // FIFO remains blind either way: it serves the hopeless front.
        let mut qs = build();
        let (dropped, got) =
            select_next(Policy::Fifo, &mut qs, 0, true, &rates, &hopeless_here, &doomed);
        assert!(dropped.is_empty());
        assert_eq!(got.unwrap().task, 1);
    }

    #[test]
    fn tie_breaks_are_deterministic_by_task_index() {
        let twin = |t| req(t, 0, 1.0, 2.0);
        let mut qs = queues(&[&[], &[twin(1)], &[twin(2)]]);
        let rates = [1.0, 10.0, 10.0];
        let (_, got) = select_next(Policy::Edf, &mut qs, 0, true, &rates, &NEVER, &NEVER);
        assert_eq!(got.unwrap().task, 1, "identical keys fall back to task order");
    }
}
