//! The discrete-event serving engine.
//!
//! [`plan_scenario`] turns a [`Scenario`] into a [`ServePlan`]: the
//! co-scheduled region partition (via `cosched::schedule`, so serving
//! replays against exactly the plan the offline stack would deploy) plus
//! every (task × region) service cost, derived from the same memoized
//! per-segment costs the DSE and co-scheduler share. Each planned segment
//! contributes one [`ServiceStage`] — a bandwidth-independent *compute
//! floor* (the max of its pipeline/NoC/GB bounds) and its DRAM bytes. At
//! a region's static bandwidth share the stage takes
//! `max(floor, bytes/share)` cycles, which reproduces the offline
//! `SegmentCost::cycles` bit-for-bit; under [`BandwidthModel::Dynamic`]
//! the bytes instead drain at whatever the epoch's contention split
//! grants, so donated headroom shortens DRAM-bound stages online.
//!
//! [`simulate`] then replays pre-generated arrival streams. The event
//! loop itself lives in [`super::core`]: a binary-heap [`EventCore`] over
//! arrivals and (versioned, hence cancellable) stage completions, driven
//! against this module's [`ArrayModel`] — the per-array [`ServiceModel`]
//! holding the queues, regions, bandwidth split, and recorders. Between
//! two events the in-flight work drains linearly at the epoch's rates; at
//! every event the bandwidth split and each busy region's next completion
//! are recomputed. Everything is indexed by task order and tie-broken by
//! sequence number, so a run is a pure function of its inputs — the
//! determinism the property tests assert. The fleet layer
//! ([`super::fleet`]) drives many `ArrayModel`s from one core, offsetting
//! each chip's regions by a slot base; the single-array entry points
//! below are unchanged by that split, bit for bit.

use std::collections::VecDeque;

use crate::config::{ArchConfig, TopologyKind};
use crate::cosched::{self, region_config, CoschedConfig, CoschedResult, Region, Scenario};
use crate::cost::{evaluate_segment, Mapper};
use crate::dse::{context_fingerprint, heuristic_segment_key, EvalCache, RunCounters};
use crate::energy::EnergyModel;
use crate::ir::ModelGraph;
use crate::mapper::PipeOrgan;
use crate::noc::Topology;
use crate::obs::attr::{AttrOutcome, RequestAttr};
use crate::obs::flight::FlightRecorder;
use crate::obs::{Obs, PID_SIM};
use crate::util::stats::Histogram;

use super::core::{drive, CoreEvent, EventCore, ServiceModel};
use super::dispatch::{select_next, Policy, Request};
use super::interference::{donated_bandwidth, donated_rate, BandwidthCache, BandwidthModel};
use super::metrics::{sweep_max_rate, ServeOutcome, SweepResult, TaskMetrics};
use super::ServeConfig;

/// One pipeline stage of a request's service, from one planned segment.
#[derive(Debug, Clone, Copy)]
pub struct ServiceStage {
    /// Bandwidth-independent cycles: `max(pipeline, NoC, GB)` bounds.
    pub floor_cycles: f64,
    /// DRAM traffic of the stage; drains at the allocated bytes/cycle.
    pub dram_bytes: f64,
}

/// A task planned and costed on one region of the partition.
#[derive(Debug, Clone)]
pub struct ServedCost {
    pub stages: Vec<ServiceStage>,
    /// Latency at the region's static bandwidth share — identical to the
    /// offline cost model's segment-summed cycles by construction.
    pub nominal_cycles: f64,
    /// Bandwidth-independent compute floor: the stages' summed
    /// `max(pipeline, NoC, GB)` cycles. `nominal_cycles − floor_cycles`
    /// is the plan-predicted DRAM-contention stretch at the static
    /// share — the predicted half of the attribution split
    /// (`obs::attr`); always `floor ≤ best_case ≤ nominal`.
    pub floor_cycles: f64,
    /// Latency if the whole array's DRAM bandwidth were donated: the
    /// certificate the deadline-aware dispatchers use to drop requests
    /// that cannot meet their deadline under *any* contention outcome.
    pub best_case_cycles: f64,
    /// Energy of one inference (bandwidth-independent in our model).
    pub energy: f64,
    pub dram_words: u64,
}

/// The serving plan of one scenario: regions, shares, and service costs.
pub struct ServePlan {
    /// Region `i` is task `i`'s home region of the co-scheduled partition
    /// (a full-height band, or an arbitrary guillotine rectangle).
    pub regions: Vec<Region>,
    /// Per-region NoC topology the co-schedule chose.
    pub topologies: Vec<TopologyKind>,
    /// Static DRAM bytes/cycle share of each region (plan-time model;
    /// proportional to the region's PE share, whatever its shape).
    pub entitlements: Vec<f64>,
    /// Whole-array DRAM bytes/cycle — the pool the dynamic model splits.
    pub total_bandwidth: f64,
    pub clock_hz: f64,
    pub rates_hz: Vec<f64>,
    pub deadlines_s: Vec<f64>,
    /// `costs[task][region]`: service cost of `task` on any region, so
    /// cross-task borrowing knows what a foreign band costs it.
    pub costs: Vec<Vec<ServedCost>>,
    /// The co-scheduling outcome the plan was derived from.
    pub cosched: CoschedResult,
    /// Cost-model evaluations this planning added to the cache.
    pub evaluations: u64,
    /// Lookups served from the cache during planning.
    pub cache_hits: u64,
}

/// Simulation knobs orthogonal to the dispatch policy.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Allow idle regions with empty home queues to serve other tasks.
    pub borrow: bool,
    pub bandwidth: BandwidthModel,
    /// Record the full [`TraceEvent`] log. On by default (it is the
    /// determinism witness); the rate sweep turns it off — its probes
    /// only read the schedulability verdict, and high-multiplier probes
    /// would otherwise allocate traces of hundreds of thousands of
    /// events just to drop them.
    pub record_trace: bool,
    /// Record one [`RequestAttr`] per finished/dropped request
    /// (`ServeOutcome::attr`). On by default — a few flops per request
    /// plus one per-epoch donation accumulate, no allocation beyond the
    /// record vector; the rate sweep turns it off alongside the trace.
    pub record_attr: bool,
    /// Run a flight recorder with this ring capacity
    /// ([`crate::obs::flight::DEFAULT_FLIGHT_CAP`] is the CLI default);
    /// `None` (the default) records nothing and keeps the hot loop
    /// identical to an untraced run.
    pub flight: Option<usize>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            borrow: false,
            bandwidth: BandwidthModel::Dynamic,
            record_trace: true,
            record_attr: true,
            flight: None,
        }
    }
}

/// One recorded simulator transition (the determinism witness).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t_s: f64,
    pub task: usize,
    pub id: u64,
    pub kind: TraceKind,
}

/// What happened at a [`TraceEvent`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceKind {
    Arrive,
    Start { region: usize },
    Complete { region: usize },
    /// Dropped as hopeless by a deadline-aware dispatcher.
    Drop { region: usize },
}

/// Plan a scenario for serving: co-schedule the partition under `cs`
/// (bands or guillotine — `ServeConfig::partition` maps onto it), then
/// cost every task on every region (repeat shapes hit the shared cache,
/// so the extra columns of the borrow table are effectively free).
pub fn plan_scenario(
    scenario: &Scenario,
    cfg: &ArchConfig,
    cs: &CoschedConfig,
    cache: &EvalCache,
    workers: usize,
) -> Result<ServePlan, String> {
    scenario.validate()?;
    let cosched = cosched::schedule(scenario, cfg, cs, cache, workers)?;
    let run = RunCounters::new();
    let regions: Vec<Region> = cosched
        .cosched
        .assignments
        .iter()
        .map(|a| a.region)
        .collect();
    let topologies: Vec<TopologyKind> = cosched
        .cosched
        .assignments
        .iter()
        .map(|a| a.topology)
        .collect();
    let entitlements: Vec<f64> = regions
        .iter()
        .map(|r| region_config(cfg, r).dram_bytes_per_cycle)
        .collect();
    let costs: Vec<Vec<ServedCost>> = scenario
        .tasks
        .iter()
        .map(|spec| {
            regions
                .iter()
                .zip(&topologies)
                .map(|(r, &topo)| cost_on_region(&spec.graph, cfg, r, topo, cache, &run))
                .collect()
        })
        .collect();
    let stats = run.stats();
    let evaluations = cosched.evaluations + stats.misses;
    let cache_hits = cosched.cache_hits + stats.hits;
    Ok(ServePlan {
        regions,
        topologies,
        entitlements,
        total_bandwidth: cfg.dram_bytes_per_cycle.max(1e-9),
        clock_hz: cfg.clock_hz.max(1.0),
        rates_hz: scenario.tasks.iter().map(|t| t.rate_hz).collect(),
        deadlines_s: scenario.tasks.iter().map(|t| t.deadline_ms / 1e3).collect(),
        costs,
        cosched,
        evaluations,
        cache_hits,
    })
}

/// Plan and cost one task inside one region on its chosen topology,
/// through the shared cache at the same coordinates the DSE and
/// co-scheduler use (heuristic segments live at granularity scale 1), so
/// serving warm-starts from their files.
fn cost_on_region(
    graph: &ModelGraph,
    cfg: &ArchConfig,
    region: &Region,
    topo_kind: TopologyKind,
    cache: &EvalCache,
    run: &RunCounters,
) -> ServedCost {
    // Costs are translation-invariant: only the region's dimensions and
    // topology reach the config, so borrowed-region costs share entries
    // with home regions of the same shape.
    let mut rcfg = region_config(cfg, region);
    rcfg.topology = topo_kind;
    let geom_cap = rcfg.pe_rows.min(rcfg.pe_cols).max(1);
    let mapper = PipeOrgan {
        topology: rcfg.topology,
        depth_cap: Some(geom_cap),
    };
    let plan = mapper.plan(graph, &rcfg);
    let ctx = context_fingerprint(graph, &rcfg);
    let topo = Topology::cached(plan.topology, rcfg.pe_rows, rcfg.pe_cols);
    let em = EnergyModel::default();
    let bytes_per_word = rcfg.bytes_per_word as f64;
    let total_b = cfg.dram_bytes_per_cycle.max(1e-9);
    let mut stages = Vec::with_capacity(plan.segments.len());
    let mut nominal = 0.0f64;
    let mut floor_total = 0.0f64;
    let mut best = 0.0f64;
    let mut energy = 0.0f64;
    let mut dram_words = 0u64;
    for ps in &plan.segments {
        let key = heuristic_segment_key(ctx, ps, plan.topology);
        let c = cache.get_or_eval_in(key, || evaluate_segment(graph, ps, &rcfg, &topo, &em), run);
        let floor = c.pipeline_cycles.max(c.noc_cycles).max(c.gb_cycles);
        let bytes = c.dram_words as f64 * bytes_per_word;
        if floor > 0.0 || bytes > 0.0 {
            stages.push(ServiceStage {
                floor_cycles: floor,
                dram_bytes: bytes,
            });
        }
        nominal += c.cycles;
        floor_total += floor;
        best += floor.max(bytes / total_b);
        energy += c.energy;
        dram_words += c.dram_words;
    }
    if stages.is_empty() {
        // Degenerate zero-cost plans never happen for real workloads, but
        // the event loop relies on every service having positive work.
        stages.push(ServiceStage {
            floor_cycles: 1.0,
            dram_bytes: 0.0,
        });
        nominal = nominal.max(1.0);
        floor_total = floor_total.max(1.0);
        best = best.max(1.0);
    }
    ServedCost {
        stages,
        nominal_cycles: nominal,
        floor_cycles: floor_total,
        best_case_cycles: best,
        energy,
        dram_words,
    }
}

/// An in-flight request on one region.
struct Service {
    req: Request,
    start_s: f64,
    stage: usize,
    /// When the current stage started (seconds) — the obs stage-span
    /// anchor; dead weight (one f64) when tracing is off.
    stage_start_s: f64,
    /// Remaining compute floor of the current stage (cycles).
    floor_rem: f64,
    /// Remaining DRAM traffic of the current stage (bytes).
    bytes_rem: f64,
    /// Bytes/cycle granted for the current epoch.
    alloc: f64,
    /// Bytes granted above the region's static entitlement while this
    /// request has been in service — the attribution layer's
    /// donation-received diagnostic; dead weight when attribution is off.
    donated_bytes: f64,
}

struct RegionSt {
    serving: Option<Service>,
    /// Completion events carry the version they were scheduled under;
    /// bumping it on every epoch change cancels stale ones.
    version: u64,
    busy_cycles: f64,
}

/// Completed-request record. `pub(super)` so the fleet layer can pool the
/// raw per-chip samples into cluster-level percentiles before each chip
/// model is finished into its own [`ServeOutcome`].
#[derive(Debug, Clone, Copy)]
pub(super) struct Rec {
    pub(super) latency_s: f64,
    pub(super) wait_s: f64,
    pub(super) missed: bool,
}

/// Cold-start model of the fleet layer: a task whose weights have not
/// touched a chip recently pays `cold_frac` of its total DRAM traffic
/// again on its first stage (the weights reload), and a completion keeps
/// the chip warm for that task for `decay_s`. Single-array runs pass
/// `None` — the dispatch path then executes zero extra float operations,
/// which is what keeps the pre-split engine output bit-identical. The
/// penalty only ever *adds* service time, so the deadline-aware drop
/// certificates (built from `best_case_cycles`) stay optimistic and sound.
pub(super) struct Warmth {
    cold_frac: f64,
    decay_s: f64,
    /// Per task: warm until this instant. Starts at `NEG_INFINITY` — the
    /// first request of every task is always cold.
    until_s: Vec<f64>,
    cold_loads: u64,
}

impl Warmth {
    pub(super) fn new(cold_frac: f64, decay_s: f64, tasks: usize) -> Warmth {
        Warmth {
            cold_frac: cold_frac.max(0.0),
            decay_s: decay_s.max(0.0),
            until_s: vec![f64::NEG_INFINITY; tasks],
            cold_loads: 0,
        }
    }
}

/// Slack added to deadline comparisons so exact-boundary float residue
/// never flips a verdict.
const DEADLINE_EPS_S: f64 = 1e-9;

/// Reusable allocations of one simulation run: the event heap, the
/// per-epoch demand vector, and the one-entry bandwidth-split memo.
///
/// One `simulate` call makes tens of thousands of event epochs, and the
/// rate sweep makes dozens of `simulate` calls back to back — reusing
/// this scratch across probes keeps the heap's and demand vector's
/// buffers warm instead of regrowing them from empty every probe. The
/// scratch carries no results: every run clears it first, so reuse can
/// never change an outcome (the determinism tests replay both ways).
#[derive(Default)]
pub struct SimScratch {
    events: EventCore,
    demands: Vec<Option<f64>>,
    bw: BandwidthCache,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch::default()
    }
}

/// Replay `arrivals` (one ascending stream per task, seconds) against the
/// plan under one policy. Deterministic: same inputs, same
/// [`ServeOutcome`], bit for bit. Thin wrapper over [`simulate_traced`]
/// with a disabled observability handle.
pub fn simulate(
    scenario: &Scenario,
    plan: &ServePlan,
    policy: Policy,
    arrivals: &[Vec<f64>],
    opts: SimOptions,
) -> ServeOutcome {
    simulate_traced(scenario, plan, policy, arrivals, opts, &Obs::disabled())
}

/// [`simulate`] with an observability handle. When `obs` is enabled the
/// event loop additionally emits, in the sim-time clock domain (pid
/// `PID_SIM + policy index`, one Perfetto process per replayed policy, one
/// thread track per region):
///
/// - the request lifecycle as instants (`arrive`/`dispatch` and
///   `finish`/`miss`/`drop`) and each service stage as a span on its
///   region's track;
/// - counter tracks sampled once per event epoch: `queue_depth` (one
///   series per task), `dram_bw` + `dram_bw_donated` (the epoch's
///   bandwidth split), `region_util` (cumulative busy fraction), and
///   `worst_channel_load` (max planned load among busy regions);
/// - registry counters (`serve.<policy>.arrivals`/`completions`/
///   `misses`/`drops`/`dispatches`/`epochs`) and the
///   `serve.<policy>.latency_ms` histogram for `report::obs`.
///
/// Sim-domain emission is single-threaded in event-loop order, so a fixed
/// seed produces an identical event sequence (asserted by
/// `tests/obs_integration.rs`). Disabled handles cost one branch per site.
///
/// Independently of the handle, [`SimOptions::record_attr`] fills
/// [`ServeOutcome::attr`] with one per-request latency attribution record
/// (queue/compute/DRAM-stretch/donation, conserved bit-exactly — see
/// [`crate::obs::attr`]), and [`SimOptions::flight`] mirrors the same
/// event stream into a bounded [`FlightRecorder`] that freezes at the
/// first deadline miss ([`ServeOutcome::flight`]).
pub fn simulate_traced(
    scenario: &Scenario,
    plan: &ServePlan,
    policy: Policy,
    arrivals: &[Vec<f64>],
    opts: SimOptions,
    obs: &Obs,
) -> ServeOutcome {
    simulate_with_scratch(scenario, plan, policy, arrivals, opts, obs, &mut SimScratch::new())
}

/// [`simulate_traced`] with caller-owned [`SimScratch`], so tight probe
/// loops (the rate sweep) amortize the heap/demand-vector allocations and
/// keep the bandwidth-split memo warm across runs. Results are identical
/// to a fresh-scratch run.
pub fn simulate_with_scratch(
    scenario: &Scenario,
    plan: &ServePlan,
    policy: Policy,
    arrivals: &[Vec<f64>],
    opts: SimOptions,
    obs: &Obs,
    scratch: &mut SimScratch,
) -> ServeOutcome {
    let n = scenario.tasks.len();
    assert_eq!(arrivals.len(), n, "one arrival stream per task");
    // Split the scratch into disjoint fields (the event core for the
    // loop, demands + bw memo for `reallocate`) and reset what carries
    // state; the buffers keep their capacity, the memo keeps its entry
    // (keyed on exact inputs, so staleness is impossible). The demand
    // vector and memo are lent to the model and recovered from
    // `finish_parts`, so reuse across probes stays allocation-free.
    let SimScratch { events, demands, bw } = scratch;
    events.clear();
    push_arrivals(events, plan, arrivals);
    let mut model = ArrayModel::with_parts(
        scenario,
        plan,
        policy,
        opts,
        obs,
        None,
        0,
        std::mem::take(demands),
        std::mem::take(bw),
        None,
    );
    let last_s = drive(&mut model, events);
    let (out, demands_back, bw_back) = model.finish_parts(last_s.max(1e-12));
    *demands = demands_back;
    *bw = bw_back;
    out
}

/// Schedule every pre-generated arrival into the core, in task order with
/// ascending ids — the exact push order (hence same-instant tie-break
/// order) the pre-split engine used. The fleet front door pushes the same
/// streams and routes each [`CoreEvent::Arrival`] as it fires.
pub fn push_arrivals(events: &mut EventCore, plan: &ServePlan, arrivals: &[Vec<f64>]) {
    for (task, times) in arrivals.iter().enumerate() {
        for (k, &t) in times.iter().enumerate() {
            events.push(
                t,
                CoreEvent::Arrival(Request {
                    task,
                    id: k as u64,
                    arrival_s: t,
                    deadline_s: t + plan.deadlines_s[task],
                }),
            );
        }
    }
}

/// The per-array [`ServiceModel`]: all the state the pre-split event loop
/// held in locals — queues, region service slots, recorders, the epoch
/// clock — behind the handler methods the shared core calls. A
/// single-array run instantiates one (see [`ArrayModel::new`]); the fleet
/// layer instantiates one per chip with a nonzero `slot_base` (so region
/// slots stay globally unique in the shared core), a per-chip obs
/// identity, and an optional cold-start model.
///
/// Each model keeps its own `now` and advances it lazily, only at its own
/// events. That is exact, not an approximation: drain rates change only
/// at the owning model's events, and the shared heap delivers events in
/// global time order, so by the time a model reads its state at `t` every
/// earlier event of its own has already been applied.
pub struct ArrayModel<'a> {
    scenario: &'a Scenario,
    plan: &'a ServePlan,
    policy: Policy,
    opts: SimOptions,
    obs: &'a Obs,
    // All per-event emission is guarded on `rec_on` (the obs handle, the
    // flight recorder, or both are live), so an untraced run costs the
    // hot loop one branch per site; the name tables are only materialized
    // when some recorder is live. Every emission site formats its event
    // name once and fans it out to both sinks — the flight recorder sees
    // exactly the stream `--trace-out` would, which is why its frozen
    // snippet passes the same schema checks.
    obs_on: bool,
    rec_on: bool,
    pid: u32,
    task_names: Vec<String>,
    region_keys: Vec<String>,
    cprefix: String,
    flight: Option<FlightRecorder>,
    slot_base: usize,
    queues: Vec<VecDeque<Request>>,
    regions: Vec<RegionSt>,
    recs: Vec<Vec<Rec>>,
    attr: Vec<RequestAttr>,
    drops: Vec<u64>,
    max_depth: Vec<usize>,
    trace: Vec<TraceEvent>,
    /// A request is *doomed* when even the fastest region's best case
    /// misses its deadline — the only condition under which a borrowing
    /// dispatcher may drop it (some region might still save anything
    /// less).
    min_best_cycles: Vec<f64>,
    now: f64,
    /// Requests this model has accepted, per task — `requests` in the
    /// finished metrics. Counted at arrival (not from the pre-generated
    /// streams) because under a fleet router a chip only sees its share.
    arrived: Vec<u64>,
    demands: Vec<Option<f64>>,
    bw: BandwidthCache,
    bw_hits0: u64,
    bw_misses0: u64,
    warm: Option<Warmth>,
}

impl<'a> ArrayModel<'a> {
    /// A fresh single-array model: chip-less obs identity, slot base 0,
    /// fresh scratch buffers, no cold-start model — the configuration
    /// under which [`push_arrivals`] + [`drive`] + [`ArrayModel::finish`]
    /// reproduces [`simulate`] bit for bit (asserted by
    /// `tests/fleet_integration.rs`).
    pub fn new(
        scenario: &'a Scenario,
        plan: &'a ServePlan,
        policy: Policy,
        opts: SimOptions,
        obs: &'a Obs,
    ) -> ArrayModel<'a> {
        ArrayModel::with_parts(
            scenario,
            plan,
            policy,
            opts,
            obs,
            None,
            0,
            Vec::new(),
            BandwidthCache::new(),
            None,
        )
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn with_parts(
        scenario: &'a Scenario,
        plan: &'a ServePlan,
        policy: Policy,
        opts: SimOptions,
        obs: &'a Obs,
        chip: Option<usize>,
        slot_base: usize,
        demands: Vec<Option<f64>>,
        bw: BandwidthCache,
        warm: Option<Warmth>,
    ) -> ArrayModel<'a> {
        let n = scenario.tasks.len();
        let obs_on = obs.is_enabled();
        let flight = opts.flight.map(FlightRecorder::new);
        let rec_on = obs_on || flight.is_some();
        let policy_idx = Policy::ALL.iter().position(|&p| p == policy).unwrap_or(0) as u32;
        let pid = match chip {
            None => PID_SIM + policy_idx,
            // One Perfetto process per chip. Nine sim-domain pids are
            // reserved, so very wide fleets wrap; tracks stay distinct
            // per region within each pid.
            Some(c) => PID_SIM + (c % 9) as u32,
        };
        let mut task_names: Vec<String> = Vec::new();
        let mut region_keys: Vec<String> = Vec::new();
        let mut cprefix = String::new();
        if rec_on {
            task_names = scenario.tasks.iter().map(|t| t.name().to_string()).collect();
            region_keys = (0..n).map(|r| format!("region{r}")).collect();
            let pname = match chip {
                None => {
                    cprefix = format!("serve.{}", policy.name());
                    format!("serve-sim [{}]", policy.name())
                }
                Some(c) => {
                    cprefix = format!("fleet.chip{c}.{}", policy.name());
                    format!("fleet-chip{c} [{}]", policy.name())
                }
            };
            obs.name_process(pid, &pname);
            if let Some(f) = &flight {
                f.name_process(pid, &pname);
            }
            for (r, name) in task_names.iter().enumerate() {
                let tname = format!("region{r} ({name})");
                obs.name_track(pid, r as u32, &tname);
                if let Some(f) = &flight {
                    f.name_track(pid, r as u32, &tname);
                }
            }
        }
        let (bw_hits0, bw_misses0) = bw.stats();
        let min_best_cycles: Vec<f64> = (0..n)
            .map(|t| {
                plan.costs[t]
                    .iter()
                    .map(|c| c.best_case_cycles)
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        ArrayModel {
            scenario,
            plan,
            policy,
            opts,
            obs,
            obs_on,
            rec_on,
            pid,
            task_names,
            region_keys,
            cprefix,
            flight,
            slot_base,
            queues: vec![VecDeque::new(); n],
            regions: (0..n)
                .map(|_| RegionSt {
                    serving: None,
                    version: 0,
                    busy_cycles: 0.0,
                })
                .collect(),
            recs: (0..n).map(|_| Vec::new()).collect(),
            attr: Vec::new(),
            drops: vec![0; n],
            max_depth: vec![0; n],
            trace: Vec::new(),
            min_best_cycles,
            now: 0.0,
            arrived: vec![0; n],
            demands,
            bw,
            bw_hits0,
            bw_misses0,
            warm,
        }
    }

    // --- read-only views the fleet router and autoscaler consult ---

    /// Requests of `task` waiting in this model's queue.
    pub(super) fn queue_len(&self, task: usize) -> usize {
        self.queues[task].len()
    }

    /// Is `region` serving something right now (as of this model's last
    /// event — exact at any global instant, see the lazy-clock note)?
    pub(super) fn region_busy(&self, region: usize) -> bool {
        self.regions[region].serving.is_some()
    }

    /// Queued + in-service requests — the JSQ tie-break's whole-chip load.
    pub(super) fn total_in_system(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum::<usize>()
            + self.regions.iter().filter(|r| r.serving.is_some()).count()
    }

    /// Raw completed-request samples per task, for fleet-level pooled
    /// percentiles (a chip's own [`ServeOutcome`] only keeps quantiles).
    pub(super) fn records(&self) -> &[Vec<Rec>] {
        &self.recs
    }

    /// Requests dropped as doomed by the dispatcher, per task.
    pub(super) fn drop_counts(&self) -> &[u64] {
        &self.drops
    }

    /// Cold-start weight reloads this chip paid (0 without a [`Warmth`]).
    pub(super) fn cold_loads(&self) -> u64 {
        self.warm.as_ref().map_or(0, |w| w.cold_loads)
    }

    // --- the event-loop body, relocated verbatim from the pre-split
    //     engine; `drive` calls these through the `ServiceModel` impl ---

    /// Drain the epoch that just elapsed at its (constant) rates and move
    /// this model's clock to `t_s`.
    fn advance_to(&mut self, t_s: f64) {
        let dt = (t_s - self.now).max(0.0);
        if dt > 0.0 {
            let dt_cycles = dt * self.plan.clock_hz;
            let record_attr = self.opts.record_attr;
            let entitlements = &self.plan.entitlements;
            for (ri, r) in self.regions.iter_mut().enumerate() {
                if let Some(s) = r.serving.as_mut() {
                    s.floor_rem = (s.floor_rem - dt_cycles).max(0.0);
                    s.bytes_rem = (s.bytes_rem - dt_cycles * s.alloc).max(0.0);
                    r.busy_cycles += dt_cycles;
                    if record_attr {
                        s.donated_bytes += dt_cycles * donated_rate(entitlements[ri], s.alloc);
                    }
                }
            }
        }
        self.now = t_s;
    }

    fn handle_arrival(&mut self, req: Request) {
        let now = self.now;
        if self.opts.record_trace {
            self.trace.push(TraceEvent {
                t_s: now,
                task: req.task,
                id: req.id,
                kind: TraceKind::Arrive,
            });
        }
        self.arrived[req.task] += 1;
        self.queues[req.task].push_back(req);
        self.max_depth[req.task] = self.max_depth[req.task].max(self.queues[req.task].len());
        if self.rec_on {
            let name = format!("arrive {}#{}", self.task_names[req.task], req.id);
            self.obs.instant(&name, self.pid, req.task as u32, now * 1e6);
            if let Some(f) = &self.flight {
                f.instant(&name, self.pid, req.task as u32, now * 1e6);
            }
            if self.obs_on {
                self.obs.count(&format!("{}.arrivals", self.cprefix), 1);
            }
        }
    }

    fn handle_completion(&mut self, region: usize) {
        let now = self.now;
        let finished = {
            let s = self.regions[region]
                .serving
                .as_mut()
                .expect("completion fired on an idle region");
            let stages = &self.plan.costs[s.req.task][region].stages;
            if self.rec_on {
                let name = format!("{} s{}", self.task_names[s.req.task], s.stage);
                let ts = s.stage_start_s * 1e6;
                self.obs.span(&name, self.pid, region as u32, ts, now * 1e6 - ts);
                if let Some(f) = &self.flight {
                    f.span(&name, self.pid, region as u32, ts, now * 1e6 - ts);
                }
            }
            s.stage += 1;
            s.stage_start_s = now;
            if s.stage < stages.len() {
                s.floor_rem = stages[s.stage].floor_cycles;
                s.bytes_rem = stages[s.stage].dram_bytes;
                None
            } else {
                Some((s.req, s.start_s, s.donated_bytes))
            }
        };
        if let Some((req, start_s, donated_bytes)) = finished {
            self.regions[region].serving = None;
            // A completion leaves the chip warm for this task (fleet-only;
            // `warm` is None on a single array).
            if let Some(w) = self.warm.as_mut() {
                w.until_s[req.task] = now + w.decay_s;
            }
            let missed = now > req.deadline_s + DEADLINE_EPS_S;
            let latency_s = now - req.arrival_s;
            let queue_s = start_s - req.arrival_s;
            self.recs[req.task].push(Rec {
                latency_s,
                wait_s: queue_s,
                missed,
            });
            if self.opts.record_attr {
                // Canonical decomposition order — donation is the
                // closing term of this exact float expression, which
                // is what makes `RequestAttr::residual_s` bit-exactly
                // zero (see obs::attr's module docs).
                let cost = &self.plan.costs[req.task][region];
                let clock = self.plan.clock_hz;
                let floor_s = cost.floor_cycles / clock;
                let stretch_s = (cost.nominal_cycles - cost.floor_cycles) / clock;
                let donation_s = stretch_s - ((latency_s - queue_s) - floor_s);
                self.attr.push(RequestAttr {
                    task: req.task,
                    id: req.id,
                    region,
                    arrival_s: req.arrival_s,
                    latency_s,
                    queue_s,
                    floor_s,
                    stretch_s,
                    donation_s,
                    donated_bytes,
                    outcome: AttrOutcome::Completed { missed },
                });
            }
            if self.opts.record_trace {
                self.trace.push(TraceEvent {
                    t_s: now,
                    task: req.task,
                    id: req.id,
                    kind: TraceKind::Complete { region },
                });
            }
            if self.rec_on {
                let what = if missed { "miss" } else { "finish" };
                let name = format!("{what} {}#{}", self.task_names[req.task], req.id);
                self.obs.instant(&name, self.pid, region as u32, now * 1e6);
                if let Some(f) = &self.flight {
                    f.instant(&name, self.pid, region as u32, now * 1e6);
                }
                if self.obs_on {
                    self.obs.count(&format!("{}.completions", self.cprefix), 1);
                    if missed {
                        self.obs.count(&format!("{}.misses", self.cprefix), 1);
                    }
                    self.obs
                        .observe(&format!("{}.latency_ms", self.cprefix), latency_s * 1e3);
                }
            }
            if missed {
                // After the miss instant above, so the frozen snippet
                // ends on the event being diagnosed. Only the first
                // call freezes; later misses are no-ops.
                if let Some(f) = self.flight.as_mut() {
                    f.trigger_miss(req.task, req.id, region, now);
                }
            }
        }
    }

    /// The shared tail of every live event: put idle regions to work,
    /// re-split bandwidth, reschedule completions under the fresh rates,
    /// sample the epoch's counter tracks.
    fn post_event(&mut self, core: &mut EventCore) {
        let now = self.now;
        let plan = self.plan;
        let clock = plan.clock_hz;
        let n = self.queues.len();
        // Put every idle region to work.
        for region in 0..n {
            if self.regions[region].serving.is_some() {
                continue;
            }
            let hopeless_here = |r: &Request| -> bool {
                now + plan.costs[r.task][region].best_case_cycles / clock
                    > r.deadline_s + DEADLINE_EPS_S
            };
            let min_best_cycles = &self.min_best_cycles;
            let doomed = |r: &Request| -> bool {
                now + min_best_cycles[r.task] / clock > r.deadline_s + DEADLINE_EPS_S
            };
            let (dropped, chosen) = select_next(
                self.policy,
                &mut self.queues,
                region,
                self.opts.borrow,
                &plan.rates_hz,
                &hopeless_here,
                &doomed,
            );
            for d in dropped {
                self.drops[d.task] += 1;
                if self.opts.record_attr {
                    // A drop's whole lifetime is queue wait; the compute
                    // components are zero, so conservation still holds and
                    // the dominant component reads "policy".
                    let waited_s = now - d.arrival_s;
                    self.attr.push(RequestAttr {
                        task: d.task,
                        id: d.id,
                        region,
                        arrival_s: d.arrival_s,
                        latency_s: waited_s,
                        queue_s: waited_s,
                        floor_s: 0.0,
                        stretch_s: 0.0,
                        donation_s: 0.0,
                        donated_bytes: 0.0,
                        outcome: AttrOutcome::Dropped,
                    });
                }
                if self.opts.record_trace {
                    self.trace.push(TraceEvent {
                        t_s: now,
                        task: d.task,
                        id: d.id,
                        kind: TraceKind::Drop { region },
                    });
                }
                if self.rec_on {
                    let name = format!("drop {}#{}", self.task_names[d.task], d.id);
                    self.obs.instant(&name, self.pid, region as u32, now * 1e6);
                    if let Some(f) = &self.flight {
                        f.instant(&name, self.pid, region as u32, now * 1e6);
                    }
                    if self.obs_on {
                        self.obs.count(&format!("{}.drops", self.cprefix), 1);
                    }
                }
                // A drop is a deadline miss by definition, so it freezes
                // the flight recorder exactly like a late completion.
                if let Some(f) = self.flight.as_mut() {
                    f.trigger_miss(d.task, d.id, region, now);
                }
            }
            if let Some(req) = chosen {
                let first = plan.costs[req.task][region].stages[0];
                let mut bytes0 = first.dram_bytes;
                // Cold-start: a chip not warm for this task reloads
                // `cold_frac` of the request's total DRAM traffic up
                // front. None on a single array — this arm then costs
                // zero float operations, preserving bit-identity.
                if let Some(w) = self.warm.as_mut() {
                    if now > w.until_s[req.task] {
                        let total: f64 = plan.costs[req.task][region]
                            .stages
                            .iter()
                            .map(|s| s.dram_bytes)
                            .sum();
                        bytes0 += w.cold_frac * total;
                        w.cold_loads += 1;
                    }
                }
                self.regions[region].serving = Some(Service {
                    req,
                    start_s: now,
                    stage: 0,
                    stage_start_s: now,
                    floor_rem: first.floor_cycles,
                    bytes_rem: bytes0,
                    alloc: 0.0,
                    donated_bytes: 0.0,
                });
                if self.opts.record_trace {
                    self.trace.push(TraceEvent {
                        t_s: now,
                        task: req.task,
                        id: req.id,
                        kind: TraceKind::Start { region },
                    });
                }
                if self.rec_on {
                    let name = format!("dispatch {}#{}", self.task_names[req.task], req.id);
                    self.obs.instant(&name, self.pid, region as u32, now * 1e6);
                    if let Some(f) = &self.flight {
                        f.instant(&name, self.pid, region as u32, now * 1e6);
                    }
                    if self.obs_on {
                        self.obs.count(&format!("{}.dispatches", self.cprefix), 1);
                    }
                }
            }
        }

        // New epoch: re-split bandwidth and reschedule every busy region's
        // completion under the fresh rates (older events go stale).
        reallocate(
            &mut self.regions,
            plan,
            self.opts.bandwidth,
            &mut self.demands,
            &mut self.bw,
        );
        let slot_base = self.slot_base;
        for (ri, r) in self.regions.iter_mut().enumerate() {
            if let Some(s) = &r.serving {
                r.version += 1;
                let dram_t = if s.bytes_rem > 0.0 {
                    s.bytes_rem / s.alloc.max(1e-12)
                } else {
                    0.0
                };
                core.push(
                    now + s.floor_rem.max(dram_t) / clock,
                    CoreEvent::Internal {
                        slot: slot_base + ri,
                        version: r.version,
                    },
                );
            }
        }

        // Sample the epoch's counter tracks after the fresh split, so the
        // timeline shows the state the simulator carries *out* of this
        // event. The flight recorder gets every counter track too, so
        // its frozen snippet satisfies the same schema checks
        // (tools/trace_check.py) a full `--trace-out` export does.
        if self.rec_on {
            let obs = self.obs;
            let pid = self.pid;
            if self.obs_on {
                obs.count(&format!("{}.epochs", self.cprefix), 1);
            }
            let ts = now * 1e6;
            let depths: Vec<(&str, f64)> = self
                .task_names
                .iter()
                .map(String::as_str)
                .zip(self.queues.iter().map(|q| q.len() as f64))
                .collect();
            obs.counter("queue_depth", pid, ts, &depths);
            let granted: Vec<f64> = self
                .regions
                .iter()
                .map(|r| r.serving.as_ref().map_or(0.0, |s| s.alloc))
                .collect();
            let bw: Vec<(&str, f64)> = self
                .region_keys
                .iter()
                .map(String::as_str)
                .zip(granted.iter().copied())
                .collect();
            obs.counter("dram_bw", pid, ts, &bw);
            let donated = donated_bandwidth(&plan.entitlements, &granted);
            obs.counter("dram_bw_donated", pid, ts, &[("donated", donated)]);
            let mut util: Vec<(&str, f64)> = Vec::new();
            if now > 0.0 {
                util = self
                    .region_keys
                    .iter()
                    .map(String::as_str)
                    .zip(
                        self.regions
                            .iter()
                            .map(|r| (r.busy_cycles / (now * clock)).min(1.0)),
                    )
                    .collect();
                obs.counter("region_util", pid, ts, &util);
            }
            let worst = self
                .regions
                .iter()
                .filter_map(|r| r.serving.as_ref())
                .map(|s| plan.cosched.cosched.assignments[s.req.task].worst_channel_load)
                .fold(0.0f64, f64::max);
            obs.counter("worst_channel_load", pid, ts, &[("load", worst)]);
            if let Some(f) = &self.flight {
                f.counter("queue_depth", pid, ts, &depths);
                f.counter("dram_bw", pid, ts, &bw);
                f.counter("dram_bw_donated", pid, ts, &[("donated", donated)]);
                if !util.is_empty() {
                    f.counter("region_util", pid, ts, &util);
                }
                f.counter("worst_channel_load", pid, ts, &[("load", worst)]);
            }
        }
    }

    /// Close the books at `span_s` (the driver's last live event time,
    /// floored at 1e-12): emit the run-level obs summary, reduce the raw
    /// records to [`TaskMetrics`], and hand back the scratch buffers the
    /// model borrowed so `simulate_with_scratch` can restore them.
    pub(super) fn finish_parts(
        self,
        span_s: f64,
    ) -> (ServeOutcome, Vec<Option<f64>>, BandwidthCache) {
        if self.obs_on {
            self.obs.gauge(&format!("{}.span_s", self.cprefix), span_s);
            // This run's split-memo effectiveness, as deltas (the scratch —
            // and so its lifetime totals — may be shared across runs).
            let (bw_hits, bw_misses) = self.bw.stats();
            self.obs.count(
                &format!("{}.bw_cache_hits", self.cprefix),
                bw_hits - self.bw_hits0,
            );
            self.obs.count(
                &format!("{}.bw_cache_misses", self.cprefix),
                bw_misses - self.bw_misses0,
            );
        }
        let clock = self.plan.clock_hz;
        let tasks: Vec<TaskMetrics> = self
            .scenario
            .tasks
            .iter()
            .enumerate()
            .map(|(t, spec)| {
                let lat_ms: Vec<f64> = self.recs[t].iter().map(|r| r.latency_s * 1e3).collect();
                let waits_ms: Vec<f64> = self.recs[t].iter().map(|r| r.wait_s * 1e3).collect();
                let late = self.recs[t].iter().filter(|r| r.missed).count() as u64;
                let lat = Histogram::from_samples(&lat_ms);
                TaskMetrics {
                    task: spec.name().to_string(),
                    rate_hz: spec.rate_hz,
                    deadline_ms: spec.deadline_ms,
                    requests: self.arrived[t],
                    completed: self.recs[t].len() as u64,
                    dropped: self.drops[t],
                    missed: late + self.drops[t],
                    p50_ms: lat.percentile(50.0),
                    p95_ms: lat.percentile(95.0),
                    p99_ms: lat.percentile(99.0),
                    mean_wait_ms: if waits_ms.is_empty() {
                        0.0
                    } else {
                        waits_ms.iter().sum::<f64>() / waits_ms.len() as f64
                    },
                    max_queue_depth: self.max_depth[t],
                    utilization: self.regions[t].busy_cycles / (span_s * clock),
                }
            })
            .collect();
        let out = ServeOutcome {
            policy: self.policy,
            scenario: self.scenario.name.clone(),
            bandwidth: self.opts.bandwidth,
            tasks,
            span_s,
            trace: self.trace,
            attr: self.attr,
            flight: self.flight.map(|f| f.finish(self.now)),
        };
        (out, self.demands, self.bw)
    }

    /// [`ArrayModel::finish_parts`] without the scratch hand-back — the
    /// entry for callers that built the model with fresh buffers.
    pub fn finish(self, span_s: f64) -> ServeOutcome {
        self.finish_parts(span_s).0
    }
}

impl ServiceModel for ArrayModel<'_> {
    fn is_stale(&self, slot: usize, version: u64) -> bool {
        self.regions[slot - self.slot_base].version != version
    }

    fn on_arrival(&mut self, req: Request, t_s: f64, core: &mut EventCore) {
        self.advance_to(t_s);
        self.handle_arrival(req);
        self.post_event(core);
    }

    fn on_internal(&mut self, slot: usize, t_s: f64, core: &mut EventCore) {
        self.advance_to(t_s);
        self.handle_completion(slot - self.slot_base);
        self.post_event(core);
    }
}

/// Re-split DRAM bandwidth for the epoch that starts now. The demand
/// vector and the split itself live in the caller's scratch: the vector
/// is rebuilt in place, and the split is served from the one-entry
/// [`BandwidthCache`] whenever the epoch's inputs are bit-for-bit the
/// previous epoch's (zero-length epochs, all-idle stretches,
/// compute-bound phases — see the cache's docs).
fn reallocate(
    regions: &mut [RegionSt],
    plan: &ServePlan,
    model: BandwidthModel,
    demands: &mut Vec<Option<f64>>,
    bw: &mut BandwidthCache,
) {
    match model {
        BandwidthModel::Static => {
            for (r, &e) in regions.iter_mut().zip(&plan.entitlements) {
                if let Some(s) = r.serving.as_mut() {
                    s.alloc = e;
                }
            }
        }
        BandwidthModel::Dynamic => {
            demands.clear();
            demands.extend(regions.iter().map(|r| {
                r.serving.as_ref().map(|s| {
                    if s.bytes_rem <= 0.0 {
                        0.0
                    } else {
                        // Bandwidth that drains the stage's DRAM no
                        // later than its compute floor — all a
                        // pipelined stage can absorb.
                        (s.bytes_rem / s.floor_rem.max(1e-9)).min(plan.total_bandwidth)
                    }
                })
            }));
            let alloc = bw.allocate(plan.total_bandwidth, &plan.entitlements, demands);
            for (r, &a) in regions.iter_mut().zip(alloc) {
                if let Some(s) = r.serving.as_mut() {
                    s.alloc = a;
                }
            }
        }
    }
}

/// The full serving artifact of one scenario: one outcome per policy on a
/// shared arrival replay, plus optional rate sweeps.
pub struct ServeRun {
    pub scenario: String,
    pub outcomes: Vec<ServeOutcome>,
    pub sweeps: Vec<SweepResult>,
    pub plan: ServePlan,
}

/// Plan and serve one scenario end to end per the CLI-level config: every
/// requested policy replays the *same* pre-generated arrival streams, so
/// policy comparisons are apples to apples at one seed.
///
/// # Examples
///
/// ```
/// use pipeorgan::config::ArchConfig;
/// use pipeorgan::cosched::{Scenario, TaskSpec};
/// use pipeorgan::dse::EvalCache;
/// use pipeorgan::serve::{run_scenario, Policy, ServeConfig};
/// use pipeorgan::workloads::synthetic;
///
/// let cfg = ArchConfig { pe_rows: 8, pe_cols: 8, ..ArchConfig::default() };
/// let scenario = Scenario::new(
///     "doc-serve",
///     vec![
///         TaskSpec::new(synthetic::aw_chain(2.0, 3), 40.0),
///         TaskSpec::new(synthetic::pointwise_conv_segment(2), 80.0),
///     ],
/// );
/// let sv = ServeConfig {
///     policies: vec![Policy::Fifo],
///     duration_s: 0.05,
///     ..ServeConfig::default()
/// };
/// let run = run_scenario(&scenario, &cfg, &sv, &EvalCache::new(), 1).unwrap();
///
/// // One outcome per requested policy; every arrival is accounted for
/// // (completed or dropped — the replay always drains its backlog).
/// assert_eq!(run.outcomes.len(), 1);
/// for tm in &run.outcomes[0].tasks {
///     assert_eq!(tm.completed + tm.dropped, tm.requests);
/// }
/// ```
pub fn run_scenario(
    scenario: &Scenario,
    cfg: &ArchConfig,
    sv: &ServeConfig,
    cache: &EvalCache,
    workers: usize,
) -> Result<ServeRun, String> {
    let cs = CoschedConfig {
        partition: sv.partition,
        obs: sv.obs.clone(),
        ..CoschedConfig::default()
    };
    let plan = sv
        .obs
        .timed("serve.plan_scenario", || {
            plan_scenario(scenario, cfg, &cs, cache, workers)
        })?;
    let opts = SimOptions {
        borrow: sv.borrow,
        bandwidth: sv.bandwidth,
        flight: if sv.flight {
            Some(crate::obs::flight::DEFAULT_FLIGHT_CAP)
        } else {
            None
        },
        ..SimOptions::default()
    };
    let arrivals = match &sv.trace {
        Some(columns) => {
            if columns.len() != scenario.tasks.len() {
                return Err(format!(
                    "trace file has {} columns but scenario `{}` has {} tasks",
                    columns.len(),
                    scenario.name,
                    scenario.tasks.len()
                ));
            }
            super::arrivals::trace_streams(columns, sv.duration_s)
        }
        None => {
            super::arrivals::streams(scenario, &sv.arrivals, sv.rate_mult, sv.duration_s, sv.seed)
        }
    };
    let outcomes: Vec<ServeOutcome> = sv
        .policies
        .iter()
        .map(|&p| {
            sv.obs.timed(&format!("serve.simulate.{}", p.name()), || {
                simulate_traced(scenario, &plan, p, &arrivals, opts, &sv.obs)
            })
        })
        .collect();
    let sweeps: Vec<SweepResult> = if sv.sweep {
        sv.policies
            .iter()
            .map(|&p| sweep_max_rate(scenario, &plan, p, opts, sv.duration_s))
            .collect()
    } else {
        Vec::new()
    };
    Ok(ServeRun {
        scenario: scenario.name.clone(),
        outcomes,
        sweeps,
        plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosched::TaskSpec;
    use crate::serve::arrivals::{streams, ArrivalProcess};
    use crate::workloads::synthetic;

    fn small_cfg() -> ArchConfig {
        ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        }
    }

    fn tiny_scenario() -> Scenario {
        let mut a = synthetic::aw_chain(3.0, 4);
        a.name = "chain_a".into();
        let mut b = synthetic::pointwise_conv_segment(3);
        b.name = "chain_b".into();
        Scenario::new("tiny", vec![TaskSpec::new(a, 30.0), TaskSpec::new(b, 60.0)])
    }

    fn periodic_arrivals(sc: &Scenario, mult: f64, duration_s: f64) -> Vec<Vec<f64>> {
        streams(sc, &ArrivalProcess::Periodic, mult, duration_s, 0)
    }

    #[test]
    fn nominal_cost_matches_cosched_latency() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
        for (t, a) in plan.cosched.cosched.assignments.iter().enumerate() {
            let own = &plan.costs[t][t];
            assert!(
                (own.nominal_cycles - a.latency_cycles).abs()
                    <= 1e-6 * a.latency_cycles.max(1.0),
                "task {t}: serve nominal {} vs cosched latency {}",
                own.nominal_cycles,
                a.latency_cycles
            );
            assert!(own.best_case_cycles <= own.nominal_cycles * (1.0 + 1e-9));
            assert!(!own.stages.is_empty());
        }
        // Planning went through the shared cache.
        assert!(plan.evaluations > 0);
    }

    #[test]
    fn light_periodic_load_serves_every_request_on_time() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
        // When every home latency fits its deadline (= its period, the
        // TaskSpec default), periodic requests never queue: each finishes
        // before the next arrives, so every policy is miss-free. When the
        // model outgrows the 16×16 array the zero-miss claim no longer
        // applies, but the accounting invariants below always must.
        let feasible = plan
            .cosched
            .cosched
            .assignments
            .iter()
            .all(|a| a.deadline_met);
        let arrivals = periodic_arrivals(&sc, 1.0, 0.2);
        for policy in Policy::ALL {
            let out = simulate(&sc, &plan, policy, &arrivals, SimOptions::default());
            if feasible {
                assert!(out.schedulable(), "{}: {:?}", policy.name(), out.tasks);
            }
            for (t, m) in out.tasks.iter().enumerate() {
                assert_eq!(m.requests, arrivals[t].len() as u64);
                assert_eq!(m.completed + m.dropped, m.requests);
                if feasible {
                    assert_eq!(m.dropped, 0);
                    assert!(m.p99_ms <= m.deadline_ms + 1e-9);
                }
                assert!(m.utilization >= 0.0 && m.utilization <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn guillotine_plan_serves_with_consistent_nominals() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let cs = CoschedConfig {
            partition: crate::cosched::PartitionKind::Guillotine,
            ..CoschedConfig::default()
        };
        let plan = plan_scenario(&sc, &cfg, &cs, &cache, 1).unwrap();
        assert_eq!(plan.regions.len(), 2);
        assert_eq!(plan.topologies.len(), 2);
        // Serve's nominal latency on the home region equals the cosched
        // assignment's, whatever the region's shape and topology.
        for (t, a) in plan.cosched.cosched.assignments.iter().enumerate() {
            assert_eq!(plan.regions[t], a.region);
            assert_eq!(plan.topologies[t], a.topology);
            let own = &plan.costs[t][t];
            assert!(
                (own.nominal_cycles - a.latency_cycles).abs()
                    <= 1e-6 * a.latency_cycles.max(1.0),
                "task {t}: serve nominal {} vs cosched latency {}",
                own.nominal_cycles,
                a.latency_cycles
            );
        }
        // Entitlements stay proportional to PE share and inside the pool.
        let total_pes: usize = plan.regions.iter().map(|r| r.num_pes()).sum();
        assert!(total_pes <= cfg.num_pes());
        let granted: f64 = plan.entitlements.iter().sum();
        assert!(granted <= plan.total_bandwidth * (1.0 + 1e-9));
        // And the simulator runs end to end on the guillotine plan.
        let arrivals = periodic_arrivals(&sc, 1.0, 0.1);
        let out = simulate(&sc, &plan, Policy::Fifo, &arrivals, SimOptions::default());
        for (t, m) in out.tasks.iter().enumerate() {
            assert_eq!(m.completed + m.dropped, arrivals[t].len() as u64);
        }
    }

    #[test]
    fn simulate_is_deterministic() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
        let arrivals = streams(&sc, &ArrivalProcess::Poisson, 1.0, 0.2, 9);
        let a = simulate(&sc, &plan, Policy::Edf, &arrivals, SimOptions::default());
        let b = simulate(&sc, &plan, Policy::Edf, &arrivals, SimOptions::default());
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.span_s, b.span_s);
    }

    /// Reusing one scratch across runs — even across different policies
    /// and bandwidth models — must be invisible in the results.
    #[test]
    fn shared_scratch_matches_fresh_scratch_runs() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
        let arrivals = streams(&sc, &ArrivalProcess::Poisson, 1.5, 0.2, 9);
        let mut scratch = SimScratch::new();
        for bandwidth in [BandwidthModel::Dynamic, BandwidthModel::Static] {
            let opts = SimOptions {
                bandwidth,
                ..SimOptions::default()
            };
            for policy in Policy::ALL {
                let fresh = simulate(&sc, &plan, policy, &arrivals, opts);
                let reused = simulate_with_scratch(
                    &sc,
                    &plan,
                    policy,
                    &arrivals,
                    opts,
                    &Obs::disabled(),
                    &mut scratch,
                );
                assert_eq!(fresh.trace, reused.trace, "{}", policy.name());
                assert_eq!(fresh.tasks, reused.tasks, "{}", policy.name());
                assert_eq!(fresh.span_s, reused.span_s, "{}", policy.name());
            }
        }
        // The dynamic runs exercised the split memo.
        let (hits, misses) = scratch.bw.stats();
        assert!(misses > 0, "dynamic runs recompute at least once");
        assert!(hits + misses > 0);
    }

    #[test]
    fn dynamic_bandwidth_never_slows_fifo_down() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
        let arrivals = periodic_arrivals(&sc, 4.0, 0.1);
        let stat = simulate(
            &sc,
            &plan,
            Policy::Fifo,
            &arrivals,
            SimOptions {
                bandwidth: BandwidthModel::Static,
                ..SimOptions::default()
            },
        );
        let dyn_ = simulate(
            &sc,
            &plan,
            Policy::Fifo,
            &arrivals,
            SimOptions {
                bandwidth: BandwidthModel::Dynamic,
                ..SimOptions::default()
            },
        );
        for (s, d) in stat.tasks.iter().zip(&dyn_.tasks) {
            assert_eq!(s.completed, d.completed, "{}", s.task);
            assert!(d.missed <= s.missed, "{}: dyn {} vs static {}", s.task, d.missed, s.missed);
            for (ps, pd) in [(s.p50_ms, d.p50_ms), (s.p95_ms, d.p95_ms), (s.p99_ms, d.p99_ms)] {
                assert!(pd <= ps + 1e-6, "{}: dynamic {pd} > static {ps}", s.task);
            }
        }
        assert!(dyn_.span_s <= stat.span_s + 1e-9);
    }

    #[test]
    fn overload_backs_up_queues_and_borrowing_runs() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let plan = plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap();
        // A rate multiplier that provably overloads every task: the
        // interarrival gap shrinks below a quarter of even the best-case
        // service time, so arrivals pile up while the first request is
        // still in flight.
        let mult = plan
            .rates_hz
            .iter()
            .enumerate()
            .map(|(t, &rate)| 4.0 * plan.clock_hz / (rate * plan.costs[t][t].best_case_cycles))
            .fold(1.0, f64::max);
        let min_rate = plan.rates_hz.iter().copied().fold(f64::INFINITY, f64::min);
        // ~50 requests for the slowest task keeps the test fast while
        // leaving room for real queue buildup.
        let duration_s = 50.0 / (min_rate * mult);
        let arrivals = periodic_arrivals(&sc, mult, duration_s);
        let fifo = simulate(&sc, &plan, Policy::Fifo, &arrivals, SimOptions::default());
        assert!(
            fifo.tasks.iter().any(|t| t.max_queue_depth > 1),
            "a provably overloaded rate must queue somewhere: {:?}",
            fifo.tasks
        );
        // Borrowing must still account for every request exactly once.
        let opts = SimOptions {
            borrow: true,
            ..SimOptions::default()
        };
        for policy in Policy::ALL {
            let out = simulate(&sc, &plan, policy, &arrivals, opts);
            for (t, m) in out.tasks.iter().enumerate() {
                assert_eq!(
                    m.completed + m.dropped,
                    arrivals[t].len() as u64,
                    "{} {}: served + dropped must cover all arrivals",
                    policy.name(),
                    m.task
                );
            }
        }
    }
}
