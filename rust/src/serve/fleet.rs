//! Fleet-scale serving: N array instances behind a front-door router.
//!
//! A fleet is `Vec<ServePlan>` — one planned chip per entry, optionally
//! heterogeneous (different array dims picked from the DSE frontier) —
//! composed behind one [`EventCore`]. Each chip is the unmodified
//! single-array [`ArrayModel`]; the fleet offsets chip `c`'s region slots
//! by a per-chip base so completions route back to the owning chip, and
//! the shared heap delivers events in global time order while each chip
//! drains its in-flight work lazily against its own clock (sound because
//! a chip's drain rates only change at that chip's own events).
//!
//! The front door stacks three decisions per arrival, in order:
//!
//! 1. **Autoscaling** ([`AutoscaleConfig`], optional): a rate-limited
//!    control loop that spins chips up (after a warm-up delay) when mean
//!    backlog crosses the high watermark and drains them down when it
//!    falls below the low one. Down chips finish what they hold but
//!    receive no new requests; up-time is integrated into the fleet's
//!    cost-per-million-requests.
//! 2. **Admission** ([`AdmissionPolicy`]): optionally reject a request
//!    whose deadline no up chip can meet even at its best-case service
//!    time — rejected requests count as missed but never occupy a chip.
//! 3. **Routing** ([`RouterPolicy`]): round-robin baseline,
//!    join-shortest-queue, deadline-aware earliest-finish, or scenario
//!    affinity (a chip kept warm for a task keeps receiving it).
//!
//! With identical chips, static bandwidth, no borrowing and no cold-start
//! penalty, every (chip, task) server has a constant deterministic
//! service time, so greedy least-backlog routing keeps each task's sorted
//! workload vector pointwise minimal — JSQ (and affinity, which differs
//! from JSQ only in *which idle* chip it picks) can never miss a deadline
//! round-robin meets. `tests/fleet_integration.rs` pins that dominance on
//! every canned scenario under the diurnal curve.

use crate::config::ArchConfig;
use crate::cosched::{CoschedConfig, Scenario};
use crate::dse::EvalCache;
use crate::obs::Obs;
use crate::util::stats::Histogram;

use super::core::{drive, EventCore, ServiceModel};
use super::dispatch::{Policy, Request};
use super::engine::{plan_scenario, push_arrivals, ArrayModel, ServePlan, SimOptions, Warmth};
use super::interference::BandwidthCache;
use super::metrics::{ServeOutcome, TaskMetrics};
use super::ServeConfig;

/// Slack for admission's deadline comparison, mirroring the engine's
/// dispatch epsilon so boundary float residue never flips a verdict.
const ADMIT_EPS_S: f64 = 1e-9;

/// Front-door routing policy: which up chip gets the next request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle through up chips in index order — the baseline every other
    /// policy is measured against.
    RoundRobin,
    /// Join-shortest-queue: least per-task backlog (queued + in-service,
    /// in seconds at the chip's nominal service time), ties broken by
    /// whole-chip load then chip index.
    Jsq,
    /// Earliest predicted finish: `now + backlog + nominal`, so a faster
    /// heterogeneous chip wins even with a slightly longer queue.
    Deadline,
    /// Scenario affinity: task `t` sticks to its preferred chip while
    /// that chip has no backlog for it, spilling to JSQ under load —
    /// a chip warm for `xr-world` keeps receiving `xr-world`.
    Affinity,
}

impl RouterPolicy {
    pub const ALL: [RouterPolicy; 4] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::Jsq,
        RouterPolicy::Deadline,
        RouterPolicy::Affinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::Jsq => "jsq",
            RouterPolicy::Deadline => "deadline",
            RouterPolicy::Affinity => "affinity",
        }
    }

    pub fn from_name(s: &str) -> Option<RouterPolicy> {
        RouterPolicy::ALL.into_iter().find(|r| r.name() == s)
    }
}

/// Parse `--router`: `all` or a comma-separated list, deduplicated,
/// order preserved — the same grammar as `--policy`.
pub fn parse_routers(s: &str) -> Result<Vec<RouterPolicy>, String> {
    if s == "all" {
        return Ok(RouterPolicy::ALL.to_vec());
    }
    let names: Vec<&str> = RouterPolicy::ALL.iter().map(|r| r.name()).collect();
    let mut out = Vec::new();
    for name in s.split(',').map(str::trim).filter(|x| !x.is_empty()) {
        let r = RouterPolicy::from_name(name).ok_or_else(|| {
            let mut msg = format!("unknown router `{name}` (known: {})", names.join(", "));
            if let Some(hint) = crate::cli::suggest(name, &names) {
                msg.push_str(&format!("; did you mean `{hint}`?"));
            }
            msg
        })?;
        if !out.contains(&r) {
            out.push(r);
        }
    }
    if out.is_empty() {
        return Err("empty router list".to_string());
    }
    Ok(out)
}

/// Front-door admission control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit everything; overload shows up as queueing and misses.
    All,
    /// Reject a request no up chip can finish by its deadline even at
    /// the best-case (full-bandwidth) service time. A rejection counts
    /// as a miss but never occupies a chip — load shedding.
    Deadline,
}

impl AdmissionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::All => "all",
            AdmissionPolicy::Deadline => "deadline",
        }
    }

    pub fn from_name(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "all" => Some(AdmissionPolicy::All),
            "deadline" => Some(AdmissionPolicy::Deadline),
            _ => None,
        }
    }
}

/// Autoscaler knobs. Watermarks are mean per-up-chip backlog seconds;
/// the control loop runs at most once per `interval_s` and takes one
/// action per tick (spin up one down chip, or drain one up chip).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    /// Never drain below this many up chips.
    pub min_chips: usize,
    /// Spin-up delay: a woken chip serves only after this warm-up.
    pub spinup_s: f64,
    /// Mean backlog above which a down chip is woken.
    pub high_backlog_s: f64,
    /// Mean backlog below which a surplus chip is drained.
    pub low_backlog_s: f64,
    /// Minimum time between control actions.
    pub interval_s: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> AutoscaleConfig {
        AutoscaleConfig {
            min_chips: 1,
            spinup_s: 0.02,
            high_backlog_s: 0.01,
            low_backlog_s: 0.001,
            interval_s: 0.005,
        }
    }
}

/// Lifecycle of one chip under the autoscaler. Without an autoscaler
/// every chip is `Up` for the whole run.
#[derive(Debug, Clone, Copy, PartialEq)]
enum ChipState {
    Up { since_s: f64 },
    Warming { ready_s: f64 },
    Down,
}

/// Fleet-level configuration parsed from the `pipeorgan fleet` CLI.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Number of array instances.
    pub chips: usize,
    /// Routers to simulate (each gets its own run over the same traffic).
    pub routers: Vec<RouterPolicy>,
    pub admission: AdmissionPolicy,
    /// `None` keeps every chip up for the whole run.
    pub autoscale: Option<AutoscaleConfig>,
    /// Cold-start model `(cold_frac, decay_s)`: a chip not serving task
    /// `t` within `decay_s` pays `cold_frac` of the request's DRAM bytes
    /// again on its first stage (weights re-load). `None` = always warm.
    pub warm: Option<(f64, f64)>,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            chips: 3,
            routers: RouterPolicy::ALL.to_vec(),
            admission: AdmissionPolicy::All,
            autoscale: None,
            warm: None,
        }
    }
}

/// Flags of the `fleet` subcommand beyond the shared serve set:
/// `(name, takes_value)` rows merged into `main::known_flags`.
pub const FLEET_FLAGS: &[(&str, bool)] = &[
    ("chips", true),
    ("chip-dims", true),
    ("router", true),
    ("admission", true),
    ("autoscale", false),
    ("min-chips", true),
    ("spinup-s", true),
    ("scale-high-s", true),
    ("scale-low-s", true),
    ("scale-interval-s", true),
    ("cold-frac", true),
    ("warm-decay-s", true),
];

impl FleetConfig {
    pub fn from_cli(args: &crate::cli::Args) -> Result<FleetConfig, String> {
        let chips = args.get_usize("chips", 3)?;
        if chips == 0 {
            return Err("flag `--chips` must be at least 1".to_string());
        }
        let routers = parse_routers(args.get_or("router", "all"))?;
        let admission = match args.get_enum("admission", "all", &["all", "deadline"])? {
            "deadline" => AdmissionPolicy::Deadline,
            _ => AdmissionPolicy::All,
        };
        let autoscale = if args.has("autoscale") {
            let d = AutoscaleConfig::default();
            let min_chips = args.get_usize("min-chips", d.min_chips)?;
            if min_chips == 0 || min_chips > chips {
                return Err(format!(
                    "flag `--min-chips` must be in 1..={chips}, got `{min_chips}`"
                ));
            }
            let spinup_s = args.get_f64("spinup-s", d.spinup_s)?;
            let high_backlog_s = args.get_f64("scale-high-s", d.high_backlog_s)?;
            let low_backlog_s = args.get_f64("scale-low-s", d.low_backlog_s)?;
            let interval_s = args.get_f64("scale-interval-s", d.interval_s)?;
            if spinup_s < 0.0 || high_backlog_s < 0.0 || low_backlog_s < 0.0 || interval_s < 0.0 {
                return Err("autoscale durations and watermarks must be >= 0".to_string());
            }
            if low_backlog_s > high_backlog_s {
                return Err(format!(
                    "flag `--scale-low-s` ({low_backlog_s}) must not exceed `--scale-high-s` ({high_backlog_s})"
                ));
            }
            Some(AutoscaleConfig {
                min_chips,
                spinup_s,
                high_backlog_s,
                low_backlog_s,
                interval_s,
            })
        } else {
            None
        };
        let cold_frac = args.get_f64("cold-frac", 0.0)?;
        if cold_frac < 0.0 {
            return Err(format!("flag `--cold-frac` must be >= 0, got `{cold_frac}`"));
        }
        let warm = if cold_frac > 0.0 {
            let decay_s = args.get_f64("warm-decay-s", 0.05)?;
            if decay_s < 0.0 {
                return Err(format!("flag `--warm-decay-s` must be >= 0, got `{decay_s}`"));
            }
            Some((cold_frac, decay_s))
        } else {
            None
        };
        Ok(FleetConfig {
            chips,
            routers,
            admission,
            autoscale,
            warm,
        })
    }
}

/// One chip's fleet-level accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct ChipStats {
    pub chip: usize,
    /// PEs this chip contributes (regions summed) — the cost weight.
    pub pes: usize,
    /// Requests the router sent here.
    pub routed: u64,
    pub completed: u64,
    pub missed: u64,
    /// Mean home-region utilization across tasks over the fleet span —
    /// the per-chip utilization spread the report surfaces.
    pub mean_util: f64,
    /// Integrated up-time (autoscaler-aware) over the fleet span.
    pub up_s: f64,
    /// Cold-start weight reloads paid (0 without a warm model).
    pub cold_loads: u64,
}

/// One router's full fleet simulation result.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub router: RouterPolicy,
    pub policy: Policy,
    pub scenario: String,
    pub span_s: f64,
    /// Pooled per-task metrics across all chips: percentiles over the
    /// union of raw completion samples (not averaged chip quantiles);
    /// `requests`/`missed` include admission rejections.
    pub tasks: Vec<TaskMetrics>,
    pub chips: Vec<ChipStats>,
    /// Requests shed by admission control (counted as missed).
    pub rejected: u64,
    /// PE-seconds of up-time per million completed requests — the
    /// fleet's cost metric (0 when nothing completed).
    pub cost_pe_s_per_m: f64,
    /// Autoscaler actions taken (spin-ups + drains).
    pub scale_events: u64,
    /// Each chip's own [`ServeOutcome`] (trace, attr, flight), so the
    /// obs/attr/noc machinery reuses the single-array report paths.
    pub chip_outcomes: Vec<ServeOutcome>,
}

impl FleetOutcome {
    pub fn total_requests(&self) -> u64 {
        self.tasks.iter().map(|t| t.requests).sum()
    }

    pub fn total_missed(&self) -> u64 {
        self.tasks.iter().map(|t| t.missed).sum()
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.total_requests();
        if total == 0 {
            0.0
        } else {
            self.total_missed() as f64 / total as f64
        }
    }
}

/// The composite [`ServiceModel`]: front door + N chips on one core.
struct FleetSim<'a> {
    chips: Vec<ArrayModel<'a>>,
    /// Chip `c` owns global slots `[slot_base[c], slot_base[c+1])`
    /// (the last entry is the total slot count).
    slot_base: Vec<usize>,
    router: RouterPolicy,
    admission: AdmissionPolicy,
    autoscale: Option<AutoscaleConfig>,
    states: Vec<ChipState>,
    /// `nominal_s[c][t]`: task `t`'s home-region service seconds on chip
    /// `c` at the static share — the backlog/ETA unit.
    nominal_s: Vec<Vec<f64>>,
    /// Best-case (full-bandwidth) service seconds — admission's bound.
    best_s: Vec<Vec<f64>>,
    rr: usize,
    next_control_s: f64,
    routed: Vec<u64>,
    rejected_per_task: Vec<u64>,
    up_s: Vec<f64>,
    scale_events: u64,
}

impl FleetSim<'_> {
    fn chip_of_slot(&self, slot: usize) -> usize {
        // Few chips: a scan beats a binary search at this size.
        (1..self.slot_base.len())
            .find(|&c| slot < self.slot_base[c])
            .map(|c| c - 1)
            .expect("slot within fleet range")
    }

    fn up_chips(&self) -> Vec<usize> {
        (0..self.chips.len())
            .filter(|&c| matches!(self.states[c], ChipState::Up { .. }))
            .collect()
    }

    /// Task `t`'s backlog on chip `c` in seconds: queued plus in-service
    /// requests at the nominal rate. Queue lengths and serving flags only
    /// change at the chip's own events, so this is exact at any global
    /// instant even though the chip's clock may lag.
    fn backlog_s(&self, c: usize, task: usize) -> f64 {
        let inflight = self.chips[c].queue_len(task) + usize::from(self.chips[c].region_busy(task));
        inflight as f64 * self.nominal_s[c][task]
    }

    fn total_backlog_s(&self, c: usize) -> f64 {
        (0..self.nominal_s[c].len()).map(|t| self.backlog_s(c, t)).sum()
    }

    /// JSQ pick among `ups`: least per-task backlog, then least
    /// whole-chip load, then lowest index. The whole-chip tie-break makes
    /// JSQ prefer a fully idle chip among per-task-idle ties — the
    /// spread round-robin gets by construction.
    fn jsq_pick(&self, ups: &[usize], task: usize) -> usize {
        let mut best = ups[0];
        let mut best_backlog = self.backlog_s(best, task);
        let mut best_load = self.chips[best].total_in_system();
        for &c in &ups[1..] {
            let backlog = self.backlog_s(c, task);
            let load = self.chips[c].total_in_system();
            if backlog < best_backlog || (backlog == best_backlog && load < best_load) {
                best = c;
                best_backlog = backlog;
                best_load = load;
            }
        }
        best
    }

    /// Deadline-aware pick: earliest predicted finish `now + backlog +
    /// nominal`, so a faster chip can win with a longer queue.
    fn deadline_pick(&self, ups: &[usize], task: usize, t_s: f64) -> usize {
        let mut best = ups[0];
        let mut best_eta = t_s + self.backlog_s(best, task) + self.nominal_s[best][task];
        let mut best_load = self.chips[best].total_in_system();
        for &c in &ups[1..] {
            let eta = t_s + self.backlog_s(c, task) + self.nominal_s[c][task];
            let load = self.chips[c].total_in_system();
            if eta < best_eta || (eta == best_eta && load < best_load) {
                best = c;
                best_eta = eta;
                best_load = load;
            }
        }
        best
    }

    fn route(&mut self, task: usize, t_s: f64) -> usize {
        let ups = self.up_chips();
        debug_assert!(!ups.is_empty(), "autoscaler keeps >= min_chips up");
        match self.router {
            RouterPolicy::RoundRobin => {
                let c = ups[self.rr % ups.len()];
                self.rr += 1;
                c
            }
            RouterPolicy::Jsq => self.jsq_pick(&ups, task),
            RouterPolicy::Deadline => self.deadline_pick(&ups, task, t_s),
            RouterPolicy::Affinity => {
                let preferred = ups[task % ups.len()];
                if self.backlog_s(preferred, task) > 0.0 {
                    self.jsq_pick(&ups, task)
                } else {
                    preferred
                }
            }
        }
    }

    fn admit(&self, req: &Request, t_s: f64) -> bool {
        match self.admission {
            AdmissionPolicy::All => true,
            AdmissionPolicy::Deadline => self.up_chips().iter().any(|&c| {
                t_s + self.backlog_s(c, req.task) + self.best_s[c][req.task]
                    <= req.deadline_s + ADMIT_EPS_S
            }),
        }
    }

    /// Autoscaler tick: promote due warm-ups (always), then at most one
    /// watermark action per `interval_s`.
    fn control(&mut self, t_s: f64) {
        let Some(cfg) = self.autoscale else { return };
        for c in 0..self.states.len() {
            if let ChipState::Warming { ready_s } = self.states[c] {
                if ready_s <= t_s {
                    self.states[c] = ChipState::Up { since_s: ready_s };
                }
            }
        }
        if t_s < self.next_control_s {
            return;
        }
        self.next_control_s = t_s + cfg.interval_s;
        let ups = self.up_chips();
        if ups.is_empty() {
            return;
        }
        let mean_backlog =
            ups.iter().map(|&c| self.total_backlog_s(c)).sum::<f64>() / ups.len() as f64;
        if mean_backlog > cfg.high_backlog_s {
            if let Some(c) =
                (0..self.states.len()).find(|&c| matches!(self.states[c], ChipState::Down))
            {
                self.states[c] = ChipState::Warming {
                    ready_s: t_s + cfg.spinup_s,
                };
                self.scale_events += 1;
            }
        } else if mean_backlog < cfg.low_backlog_s && ups.len() > cfg.min_chips {
            // Drain the highest-index up chip: it finishes what it holds
            // (completions still fire) but receives no new requests.
            let c = *ups.last().expect("non-empty");
            if let ChipState::Up { since_s } = self.states[c] {
                self.up_s[c] += (t_s - since_s).max(0.0);
            }
            self.states[c] = ChipState::Down;
            self.scale_events += 1;
        }
    }
}

impl ServiceModel for FleetSim<'_> {
    fn is_stale(&self, slot: usize, version: u64) -> bool {
        let c = self.chip_of_slot(slot);
        self.chips[c].is_stale(slot, version)
    }

    fn on_arrival(&mut self, req: Request, t_s: f64, core: &mut EventCore) {
        self.control(t_s);
        if !self.admit(&req, t_s) {
            self.rejected_per_task[req.task] += 1;
            return;
        }
        let c = self.route(req.task, t_s);
        self.routed[c] += 1;
        self.chips[c].on_arrival(req, t_s, core);
    }

    fn on_internal(&mut self, slot: usize, t_s: f64, core: &mut EventCore) {
        let c = self.chip_of_slot(slot);
        self.chips[c].on_internal(slot, t_s, core);
    }
}

/// Simulate one router over `arrivals` against a fleet of `plans`.
/// Deterministic: same inputs, same [`FleetOutcome`], bit for bit —
/// traffic is routed at arrival instants from the shared heap, chips
/// drain lazily, and every tie-break is total.
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet(
    scenario: &Scenario,
    plans: &[ServePlan],
    policy: Policy,
    router: RouterPolicy,
    fc: &FleetConfig,
    opts: SimOptions,
    arrivals: &[Vec<f64>],
    obs: &Obs,
) -> FleetOutcome {
    assert!(!plans.is_empty(), "fleet needs at least one chip");
    let n = scenario.tasks.len();
    assert_eq!(arrivals.len(), n, "one arrival stream per task");

    let mut chips = Vec::with_capacity(plans.len());
    let mut slot_base = vec![0usize];
    let mut nominal_s = Vec::with_capacity(plans.len());
    let mut best_s = Vec::with_capacity(plans.len());
    for (c, plan) in plans.iter().enumerate() {
        let base = *slot_base.last().expect("seeded");
        let warm = fc
            .warm
            .map(|(cold_frac, decay_s)| Warmth::new(cold_frac, decay_s, n));
        chips.push(ArrayModel::with_parts(
            scenario,
            plan,
            policy,
            opts,
            obs,
            Some(c),
            base,
            Vec::new(),
            BandwidthCache::new(),
            warm,
        ));
        slot_base.push(base + plan.regions.len());
        nominal_s.push((0..n).map(|t| plan.costs[t][t].nominal_cycles / plan.clock_hz).collect());
        best_s.push((0..n).map(|t| plan.costs[t][t].best_case_cycles / plan.clock_hz).collect());
    }

    let mut events = EventCore::new();
    // Deadlines are scenario properties, identical across chip plans.
    push_arrivals(&mut events, &plans[0], arrivals);

    let mut fleet = FleetSim {
        states: vec![ChipState::Up { since_s: 0.0 }; chips.len()],
        routed: vec![0; chips.len()],
        rejected_per_task: vec![0; n],
        up_s: vec![0.0; chips.len()],
        chips,
        slot_base,
        router,
        admission: fc.admission,
        autoscale: fc.autoscale,
        nominal_s,
        best_s,
        rr: 0,
        next_control_s: 0.0,
        scale_events: 0,
    };
    let last_s = drive(&mut fleet, &mut events);
    let span_s = last_s.max(1e-12);

    let FleetSim {
        chips,
        states,
        routed,
        rejected_per_task,
        mut up_s,
        scale_events,
        ..
    } = fleet;
    for (c, st) in states.iter().enumerate() {
        if let ChipState::Up { since_s } = st {
            up_s[c] += (span_s - since_s).max(0.0);
        }
    }

    // Pool raw completion samples before finish() consumes the models —
    // fleet percentiles come from the union of samples, not from
    // averaging per-chip quantiles.
    let mut pooled_lat_ms: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut pooled_wait_ms: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut cold_loads: Vec<u64> = Vec::with_capacity(chips.len());
    for chip in &chips {
        cold_loads.push(chip.cold_loads());
        for (t, recs) in chip.records().iter().enumerate() {
            for r in recs {
                pooled_lat_ms[t].push(r.latency_s * 1e3);
                pooled_wait_ms[t].push(r.wait_s * 1e3);
            }
        }
    }
    let chip_outcomes: Vec<ServeOutcome> =
        chips.into_iter().map(|m| m.finish(span_s)).collect();

    let tasks: Vec<TaskMetrics> = (0..n)
        .map(|t| {
            let sum = |get: fn(&TaskMetrics) -> u64| -> u64 {
                chip_outcomes.iter().map(|o| get(&o.tasks[t])).sum()
            };
            let requests = sum(|m| m.requests) + rejected_per_task[t];
            let hist = Histogram::from_samples(&pooled_lat_ms[t]);
            let mean_wait_ms = if pooled_wait_ms[t].is_empty() {
                0.0
            } else {
                pooled_wait_ms[t].iter().sum::<f64>() / pooled_wait_ms[t].len() as f64
            };
            let proto = &chip_outcomes[0].tasks[t];
            TaskMetrics {
                task: proto.task.clone(),
                rate_hz: proto.rate_hz,
                deadline_ms: proto.deadline_ms,
                requests,
                completed: sum(|m| m.completed),
                dropped: sum(|m| m.dropped),
                missed: sum(|m| m.missed) + rejected_per_task[t],
                p50_ms: hist.percentile(50.0),
                p95_ms: hist.percentile(95.0),
                p99_ms: hist.percentile(99.0),
                mean_wait_ms,
                max_queue_depth: chip_outcomes
                    .iter()
                    .map(|o| o.tasks[t].max_queue_depth)
                    .max()
                    .unwrap_or(0),
                utilization: chip_outcomes
                    .iter()
                    .map(|o| o.tasks[t].utilization)
                    .sum::<f64>()
                    / chip_outcomes.len() as f64,
            }
        })
        .collect();

    let chip_stats: Vec<ChipStats> = chip_outcomes
        .iter()
        .enumerate()
        .map(|(c, o)| ChipStats {
            chip: c,
            pes: plans[c].regions.iter().map(|r| r.num_pes()).sum(),
            routed: routed[c],
            completed: o.tasks.iter().map(|m| m.completed).sum(),
            missed: o.tasks.iter().map(|m| m.missed).sum(),
            mean_util: o.tasks.iter().map(|m| m.utilization).sum::<f64>()
                / o.tasks.len().max(1) as f64,
            up_s: up_s[c],
            cold_loads: cold_loads[c],
        })
        .collect();

    let completed_total: u64 = chip_stats.iter().map(|c| c.completed).sum();
    let pe_s: f64 = chip_stats.iter().map(|c| c.up_s * c.pes as f64).sum();
    let cost_pe_s_per_m = if completed_total > 0 {
        pe_s / (completed_total as f64 / 1e6)
    } else {
        0.0
    };

    FleetOutcome {
        router,
        policy,
        scenario: scenario.name.clone(),
        span_s,
        tasks,
        chips: chip_stats,
        rejected: rejected_per_task.iter().sum(),
        cost_pe_s_per_m,
        scale_events,
        chip_outcomes,
    }
}

/// Parse `--chip-dims "16x16,32x16"`: per-chip array dims for a
/// heterogeneous fleet (e.g. picked from the DSE frontier), cycled when
/// the list is shorter than `--chips`.
pub fn parse_chip_dims(s: &str) -> Result<Vec<(usize, usize)>, String> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|x| !x.is_empty()) {
        let (r, c) = part
            .split_once('x')
            .ok_or_else(|| format!("bad chip dims `{part}` (expected RxC, e.g. 16x16)"))?;
        let rows: usize = r
            .trim()
            .parse()
            .map_err(|_| format!("bad chip rows in `{part}`"))?;
        let cols: usize = c
            .trim()
            .parse()
            .map_err(|_| format!("bad chip cols in `{part}`"))?;
        if rows == 0 || cols == 0 {
            return Err(format!("chip dims must be positive in `{part}`"));
        }
        out.push((rows, cols));
    }
    if out.is_empty() {
        return Err("flag `--chip-dims` lists no dims".to_string());
    }
    Ok(out)
}

/// One scenario's full fleet study: every configured router × dispatch
/// policy replayed over the same arrival streams and chip plans.
pub struct FleetRun {
    pub scenario: String,
    pub outcomes: Vec<FleetOutcome>,
    /// One plan per chip (index = chip id), for geometry in reports and
    /// cache-liveness accounting.
    pub plans: Vec<ServePlan>,
}

/// Plan a fleet and serve one scenario end to end per the CLI-level
/// configs, mirroring [`super::run_scenario`] one level up: chip plans
/// come from the same `plan_scenario` path (heterogeneous dims via
/// `chip_dims`, cycled), and every router × policy pair replays the same
/// pre-generated traffic, so comparisons are apples to apples.
pub fn run_fleet_scenario(
    scenario: &Scenario,
    cfg: &ArchConfig,
    sv: &ServeConfig,
    fc: &FleetConfig,
    chip_dims: &[(usize, usize)],
    cache: &EvalCache,
    workers: usize,
) -> Result<FleetRun, String> {
    let cs = CoschedConfig {
        partition: sv.partition,
        obs: sv.obs.clone(),
        ..CoschedConfig::default()
    };
    let mut plans = Vec::with_capacity(fc.chips);
    for c in 0..fc.chips {
        let cfg_c = if chip_dims.is_empty() {
            cfg.clone()
        } else {
            let (rows, cols) = chip_dims[c % chip_dims.len()];
            ArchConfig {
                pe_rows: rows,
                pe_cols: cols,
                ..cfg.clone()
            }
        };
        // Homogeneous fleets re-plan N times, but the shared cache turns
        // repeats into pure hits.
        plans.push(sv.obs.timed(&format!("fleet.plan_chip{c}"), || {
            plan_scenario(scenario, &cfg_c, &cs, cache, workers)
        })?);
    }
    let opts = SimOptions {
        borrow: sv.borrow,
        bandwidth: sv.bandwidth,
        flight: if sv.flight {
            Some(crate::obs::flight::DEFAULT_FLIGHT_CAP)
        } else {
            None
        },
        ..SimOptions::default()
    };
    let arrivals = match &sv.trace {
        Some(columns) => {
            if columns.len() != scenario.tasks.len() {
                return Err(format!(
                    "trace file has {} columns but scenario `{}` has {} tasks",
                    columns.len(),
                    scenario.name,
                    scenario.tasks.len()
                ));
            }
            super::arrivals::trace_streams(columns, sv.duration_s)
        }
        None => {
            super::arrivals::streams(scenario, &sv.arrivals, sv.rate_mult, sv.duration_s, sv.seed)
        }
    };
    let mut outcomes = Vec::with_capacity(fc.routers.len() * sv.policies.len());
    for &router in &fc.routers {
        for &policy in &sv.policies {
            outcomes.push(sv.obs.timed(
                &format!("fleet.simulate.{}.{}", router.name(), policy.name()),
                || simulate_fleet(scenario, &plans, policy, router, fc, opts, &arrivals, &sv.obs),
            ));
        }
    }
    Ok(FleetRun {
        scenario: scenario.name.clone(),
        outcomes,
        plans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosched::TaskSpec;
    use crate::workloads::synthetic;

    use super::super::arrivals::{streams, ArrivalProcess};
    use super::super::interference::BandwidthModel;

    #[test]
    fn router_and_admission_names_roundtrip() {
        for r in RouterPolicy::ALL {
            assert_eq!(RouterPolicy::from_name(r.name()), Some(r));
        }
        assert_eq!(RouterPolicy::from_name("lru"), None);
        for a in [AdmissionPolicy::All, AdmissionPolicy::Deadline] {
            assert_eq!(AdmissionPolicy::from_name(a.name()), Some(a));
        }
        assert_eq!(AdmissionPolicy::from_name("open"), None);
    }

    #[test]
    fn parse_routers_grammar() {
        assert_eq!(parse_routers("all").unwrap(), RouterPolicy::ALL.to_vec());
        assert_eq!(
            parse_routers("jsq, round-robin, jsq").unwrap(),
            vec![RouterPolicy::Jsq, RouterPolicy::RoundRobin],
            "deduped, order kept"
        );
        let err = parse_routers("jqs").unwrap_err();
        assert!(err.contains("unknown router `jqs`"), "{err}");
        assert!(err.contains("did you mean `jsq`?"), "{err}");
        assert!(parse_routers(" , ").is_err());
    }

    #[test]
    fn chip_dims_parse_and_reject() {
        assert_eq!(parse_chip_dims("16x16").unwrap(), vec![(16, 16)]);
        assert_eq!(
            parse_chip_dims(" 32x16 , 8x24 ").unwrap(),
            vec![(32, 16), (8, 24)]
        );
        assert!(parse_chip_dims("16").is_err());
        assert!(parse_chip_dims("0x16").is_err());
        assert!(parse_chip_dims("axb").is_err());
        assert!(parse_chip_dims("").is_err());
    }

    #[test]
    fn run_fleet_scenario_covers_routers_and_policies() {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let sv = ServeConfig {
            policies: vec![Policy::Edf],
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        let fc = FleetConfig {
            chips: 2,
            routers: vec![RouterPolicy::RoundRobin, RouterPolicy::Jsq],
            ..FleetConfig::default()
        };
        // Heterogeneous dims cycle over the chip count.
        let run = run_fleet_scenario(
            &tiny_scenario(),
            &cfg,
            &sv,
            &fc,
            &[(16, 16), (16, 8)],
            &EvalCache::new(),
            1,
        )
        .unwrap();
        assert_eq!(run.plans.len(), 2);
        assert_eq!(run.outcomes.len(), 2, "2 routers x 1 policy");
        assert!(run.outcomes.iter().all(|o| o.policy == Policy::Edf));
        // The second chip was planned on the narrower 16x8 array: its
        // regions must fit inside 8 columns.
        assert!(run.plans[1].regions.iter().all(|r| r.col_end() <= 8));
    }

    #[test]
    fn fleet_config_from_cli_parses_and_rejects() {
        let parse = |v: &[&str]| {
            let mut raw = vec!["fleet".to_string()];
            raw.extend(v.iter().map(|x| x.to_string()));
            let known: Vec<(&str, bool)> = FLEET_FLAGS.to_vec();
            crate::cli::Args::parse(&raw, &known).unwrap()
        };
        let fc = FleetConfig::from_cli(&parse(&[])).unwrap();
        assert_eq!(fc, FleetConfig::default());
        let fc = FleetConfig::from_cli(&parse(&[
            "--chips", "5", "--router", "jsq", "--admission", "deadline", "--autoscale",
            "--min-chips", "2", "--cold-frac", "0.5",
        ]))
        .unwrap();
        assert_eq!(fc.chips, 5);
        assert_eq!(fc.routers, vec![RouterPolicy::Jsq]);
        assert_eq!(fc.admission, AdmissionPolicy::Deadline);
        assert_eq!(fc.autoscale.unwrap().min_chips, 2);
        assert_eq!(fc.warm, Some((0.5, 0.05)));
        assert!(FleetConfig::from_cli(&parse(&["--chips", "0"])).is_err());
        let err = FleetConfig::from_cli(&parse(&["--admission", "deadlnie"])).unwrap_err();
        assert!(err.contains("did you mean `deadline`?"), "{err}");
        assert!(FleetConfig::from_cli(&parse(&["--autoscale", "--min-chips", "9"])).is_err());
        assert!(FleetConfig::from_cli(&parse(&["--cold-frac", "-1"])).is_err());
    }

    fn tiny_scenario() -> crate::cosched::Scenario {
        let mut a = synthetic::aw_chain(3.0, 4);
        a.name = "chain_a".into();
        let mut b = synthetic::pointwise_conv_segment(3);
        b.name = "chain_b".into();
        crate::cosched::Scenario::new(
            "tiny",
            vec![TaskSpec::new(a, 30.0), TaskSpec::new(b, 60.0)],
        )
    }

    fn tiny_fleet() -> (crate::cosched::Scenario, Vec<ServePlan>) {
        let cfg = ArchConfig {
            pe_rows: 16,
            pe_cols: 16,
            ..ArchConfig::default()
        };
        let cache = EvalCache::new();
        let sc = tiny_scenario();
        let plans: Vec<ServePlan> = (0..3)
            .map(|_| plan_scenario(&sc, &cfg, &CoschedConfig::default(), &cache, 1).unwrap())
            .collect();
        (sc, plans)
    }

    #[test]
    fn fleet_accounting_and_determinism() {
        let (sc, plans) = tiny_fleet();
        let arrivals = streams(
            &sc,
            &ArrivalProcess::Diurnal { period_s: 0.0, amp: 0.8 },
            4.0,
            0.2,
            7,
        );
        let fc = FleetConfig::default();
        let opts = SimOptions {
            bandwidth: BandwidthModel::Static,
            ..SimOptions::default()
        };
        for router in RouterPolicy::ALL {
            let out = simulate_fleet(
                &sc,
                &plans,
                Policy::Edf,
                router,
                &fc,
                opts,
                &arrivals,
                &Obs::disabled(),
            );
            let arrived: u64 = arrivals.iter().map(|a| a.len() as u64).sum();
            assert_eq!(out.total_requests(), arrived, "{}", router.name());
            // Conservation: every arrival completed, was dropped, or was
            // rejected at the front door — nothing vanishes.
            let served: u64 = out.tasks.iter().map(|m| m.completed + m.dropped).sum();
            assert_eq!(served + out.rejected, arrived, "{}", router.name());
            // Every request the router placed landed on some chip.
            let routed: u64 = out.chips.iter().map(|c| c.routed).sum();
            assert_eq!(routed + out.rejected, arrived);
            assert!(out.span_s > 0.0);
            assert!(out.cost_pe_s_per_m > 0.0, "completed work has a cost");
            // Same inputs, same outcome — the determinism contract.
            let again = simulate_fleet(
                &sc,
                &plans,
                Policy::Edf,
                router,
                &fc,
                opts,
                &arrivals,
                &Obs::disabled(),
            );
            assert_eq!(out.tasks, again.tasks);
            assert_eq!(out.chips, again.chips);
            assert_eq!(out.span_s, again.span_s);
        }
    }

    #[test]
    fn warm_model_counts_cold_loads() {
        let (sc, plans) = tiny_fleet();
        let arrivals = streams(&sc, &ArrivalProcess::Periodic, 1.0, 0.2, 0);
        let fc = FleetConfig {
            warm: Some((0.5, 0.001)),
            ..FleetConfig::default()
        };
        let out = simulate_fleet(
            &sc,
            &plans,
            Policy::Fifo,
            RouterPolicy::RoundRobin,
            &fc,
            SimOptions::default(),
            &arrivals,
            &Obs::disabled(),
        );
        let completed: u64 = out.chips.iter().map(|c| c.completed).sum();
        let cold: u64 = out.chips.iter().map(|c| c.cold_loads).sum();
        assert!(completed > 0);
        assert!(cold >= 1, "a fresh fleet pays at least one cold load");
        // Cold loads only ever slow things down: the always-warm fleet
        // serves every task at least as fast at every percentile.
        let warm_free = simulate_fleet(
            &sc,
            &plans,
            Policy::Fifo,
            RouterPolicy::RoundRobin,
            &FleetConfig::default(),
            SimOptions::default(),
            &arrivals,
            &Obs::disabled(),
        );
        for (m_cold, m_warm) in out.tasks.iter().zip(&warm_free.tasks) {
            assert!(m_warm.p99_ms <= m_cold.p99_ms + 1e-9);
        }
    }

    #[test]
    fn autoscaler_drains_surplus_chips() {
        let (sc, plans) = tiny_fleet();
        // Light load: backlog stays near zero, so the scaler drains down
        // to min_chips and the drained chips stop accruing up-time.
        let arrivals = streams(&sc, &ArrivalProcess::Periodic, 1.0, 0.2, 0);
        let fc = FleetConfig {
            autoscale: Some(AutoscaleConfig {
                min_chips: 1,
                spinup_s: 0.01,
                high_backlog_s: 1e6,
                low_backlog_s: 1e6, // always below: drain at every tick
                interval_s: 0.001,
            }),
            ..FleetConfig::default()
        };
        let out = simulate_fleet(
            &sc,
            &plans,
            Policy::Edf,
            RouterPolicy::Jsq,
            &fc,
            SimOptions::default(),
            &arrivals,
            &Obs::disabled(),
        );
        assert!(out.scale_events >= 2, "two surplus chips drained");
        let up: Vec<f64> = out.chips.iter().map(|c| c.up_s).collect();
        assert!(up[0] >= up[2], "highest-index chips drain first: {up:?}");
        assert!(up.iter().all(|&u| u <= out.span_s + 1e-9));
        // All traffic still accounted for.
        let arrived: u64 = arrivals.iter().map(|a| a.len() as u64).sum();
        assert_eq!(out.total_requests(), arrived);
    }

    #[test]
    fn deadline_admission_sheds_hopeless_load() {
        let (sc, plans) = tiny_fleet();
        // Extreme overload: far more work than three chips can serve, so
        // deadline admission must shed some of it.
        let arrivals = streams(&sc, &ArrivalProcess::Periodic, 64.0, 0.05, 0);
        let fc = FleetConfig {
            admission: AdmissionPolicy::Deadline,
            ..FleetConfig::default()
        };
        let out = simulate_fleet(
            &sc,
            &plans,
            Policy::Edf,
            RouterPolicy::Jsq,
            &fc,
            SimOptions::default(),
            &arrivals,
            &Obs::disabled(),
        );
        assert!(out.rejected > 0, "overload must trigger shedding");
        assert!(
            out.total_missed() >= out.rejected,
            "every rejection counts as a miss"
        );
        let arrived: u64 = arrivals.iter().map(|a| a.len() as u64).sum();
        assert_eq!(out.total_requests(), arrived);
    }
}
