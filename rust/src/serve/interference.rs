//! Dynamic cross-region DRAM-bandwidth contention.
//!
//! The planning stack (`cosched::region_config`) splits off-chip bandwidth
//! *statically* by PE share: a region owning `p` of the array's `P` PEs —
//! a full-height band or any guillotine rectangle, shape never matters —
//! is costed at `p/P` of the DRAM bytes/cycle, always. That is the right
//! conservative assumption at plan time — every co-resident task may be
//! active at once — but it wastes headroom online: whenever a region is
//! idle, or busy on a compute-bound phase that cannot use its share, the
//! unclaimed bandwidth just evaporates.
//!
//! [`allocate_bandwidth`] is the online replacement, recomputed at every
//! event epoch (the interval between two discrete events, during which the
//! set of in-flight requests is constant):
//!
//! 1. every busy region is *entitled* to its static share;
//! 2. a region first receives `min(demand, entitlement)` — demand is the
//!    bandwidth its current pipeline phase can actually absorb, so
//!    DRAM-underutilizing tasks claim only what they can use;
//! 3. the pooled headroom (idle regions' entire shares plus busy regions'
//!    unclaimed remainders, plus any columns no region owns) is donated to
//!    regions demanding *more* than their entitlement, pro rata to unmet
//!    demand and capped at demand.
//!
//! Two properties make it safe to use for served latencies: allocations
//! never exceed the physical total, and a busy region never receives less
//! than `min(demand, entitlement)` — so no request is ever served slower
//! than the static plan-time model predicts (the never-worse claim
//! `tests/serve_integration.rs` checks end to end).

/// Which bandwidth model the serving simulator charges requests under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BandwidthModel {
    /// Plan-time proportional shares, always — the conservative baseline.
    Static,
    /// Demand-driven per-epoch splitting with headroom donation.
    Dynamic,
}

impl BandwidthModel {
    pub fn name(self) -> &'static str {
        match self {
            BandwidthModel::Static => "static",
            BandwidthModel::Dynamic => "dynamic",
        }
    }

    pub fn from_name(s: &str) -> Option<BandwidthModel> {
        match s {
            "static" => Some(BandwidthModel::Static),
            "dynamic" => Some(BandwidthModel::Dynamic),
            _ => None,
        }
    }
}

/// Split `total` bytes/cycle across regions for one event epoch.
///
/// `entitlements[i]` is region `i`'s static share; `demands[i]` is `None`
/// for an idle region and `Some(d)` for a busy one, where `d` is the
/// bandwidth its in-flight request can still absorb this epoch. Returns
/// one allocation per region (idle regions get 0).
///
/// Guarantees, up to float rounding: `alloc[i] ≥ min(demand, entitlement)`
/// for every busy region, `alloc[i] ≤ demand`, and `Σ alloc ≤ total`.
/// The proportional donation round is exact water-filling here: grants are
/// capped at unmet demand, and either the surplus covers all unmet demand
/// (everyone saturates) or it is exhausted in the single pro-rata pass.
pub fn allocate_bandwidth(total: f64, entitlements: &[f64], demands: &[Option<f64>]) -> Vec<f64> {
    let mut alloc = Vec::new();
    allocate_bandwidth_into(total, entitlements, demands, &mut alloc);
    alloc
}

/// Allocation-free core of [`allocate_bandwidth`]: writes the split into
/// a caller-owned vector so the event loop (which re-splits at every
/// epoch) never touches the allocator. The arithmetic — floors first,
/// then one pro-rata donation pass over unmet demand, accumulated in
/// index order — is exactly [`allocate_bandwidth`]'s, so the two are
/// bit-identical; the unmet remainder `d − alloc[i]` is simply recomputed
/// in the second pass instead of being staged in a scratch vector.
pub fn allocate_bandwidth_into(
    total: f64,
    entitlements: &[f64],
    demands: &[Option<f64>],
    alloc: &mut Vec<f64>,
) {
    assert_eq!(
        entitlements.len(),
        demands.len(),
        "one demand per entitled region"
    );
    let n = entitlements.len();
    alloc.clear();
    alloc.resize(n, 0.0f64);
    let mut granted = 0.0f64;
    for i in 0..n {
        if let Some(d) = demands[i] {
            alloc[i] = d.max(0.0).min(entitlements[i].max(0.0));
            granted += alloc[i];
        }
    }
    let surplus = (total - granted).max(0.0);
    let mut want = 0.0f64;
    for i in 0..n {
        want += match demands[i] {
            Some(d) if d > alloc[i] => d - alloc[i],
            _ => 0.0,
        };
    }
    if want > 0.0 && surplus > 0.0 {
        let scale = (surplus / want).min(1.0);
        for i in 0..n {
            let unmet = match demands[i] {
                Some(d) if d > alloc[i] => d - alloc[i],
                _ => 0.0,
            };
            alloc[i] += unmet * scale;
        }
    }
}

/// One-entry memo over [`allocate_bandwidth_into`], keyed on the exact
/// bit patterns of `(total, entitlements, demands)`.
///
/// The event loop recomputes the split at *every* epoch, but the inputs
/// only change when a request starts, finishes, or crosses a pipeline
/// phase — zero-length epochs (simultaneous events), all-idle stretches,
/// and compute-bound phases replay the same demand vector back to back.
/// Keying on bits (a) costs one comparison pass, (b) can never merge two
/// splits a float tolerance would, so cached epochs are bit-identical to
/// recomputed ones. `None` (idle) is encoded as `u64::MAX` — a NaN bit
/// pattern the finite demands the simulator derives can never take.
#[derive(Debug, Default)]
pub struct BandwidthCache {
    valid: bool,
    total_bits: u64,
    ent_bits: Vec<u64>,
    demand_bits: Vec<u64>,
    alloc: Vec<f64>,
    hits: u64,
    misses: u64,
}

const IDLE_BITS: u64 = u64::MAX;

impl BandwidthCache {
    pub fn new() -> BandwidthCache {
        BandwidthCache::default()
    }

    /// The split for this epoch — served from the memo when the inputs
    /// are bit-for-bit the previous epoch's, recomputed (and remembered)
    /// otherwise.
    pub fn allocate(
        &mut self,
        total: f64,
        entitlements: &[f64],
        demands: &[Option<f64>],
    ) -> &[f64] {
        let same = self.valid
            && self.total_bits == total.to_bits()
            && self.ent_bits.len() == entitlements.len()
            && self.demand_bits.len() == demands.len()
            && self
                .ent_bits
                .iter()
                .zip(entitlements)
                .all(|(&b, e)| b == e.to_bits())
            && self
                .demand_bits
                .iter()
                .zip(demands)
                .all(|(&b, d)| b == d.map_or(IDLE_BITS, f64::to_bits));
        if same {
            self.hits += 1;
            return &self.alloc;
        }
        self.misses += 1;
        self.total_bits = total.to_bits();
        self.ent_bits.clear();
        self.ent_bits.extend(entitlements.iter().map(|e| e.to_bits()));
        self.demand_bits.clear();
        self.demand_bits
            .extend(demands.iter().map(|d| d.map_or(IDLE_BITS, f64::to_bits)));
        allocate_bandwidth_into(total, entitlements, demands, &mut self.alloc);
        self.valid = true;
        &self.alloc
    }

    /// `(hits, misses)` since construction — the event loop reports the
    /// per-simulation deltas as `serve.<policy>.bw_cache_*` counters.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

/// Total bandwidth granted *above* static entitlements this epoch — the
/// donated headroom the `dram_bw_donated` counter track plots. `granted`
/// is one epoch's allocation vector aligned with `entitlements` (idle
/// regions hold 0.0, exactly as [`allocate_bandwidth`] returns).
pub fn donated_bandwidth(entitlements: &[f64], granted: &[f64]) -> f64 {
    assert_eq!(
        entitlements.len(),
        granted.len(),
        "one grant per entitled region"
    );
    entitlements
        .iter()
        .zip(granted)
        .map(|(&e, &g)| donated_rate(e, g))
        .sum()
}

/// One region's bytes/cycle granted above its static entitlement this
/// epoch (0 when the grant is at or below it). The per-region term of
/// [`donated_bandwidth`], split out so the attribution layer can charge
/// donation *received* to the request being served: the engine
/// integrates `donated_rate × dt_cycles` into the in-flight request's
/// `donated_bytes` (`obs::attr::RequestAttr`), turning the epoch-level
/// split this module computes into per-request accounting.
pub fn donated_rate(entitlement: f64, granted: f64) -> f64 {
    (granted - entitlement).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total_of(alloc: &[f64]) -> f64 {
        alloc.iter().sum()
    }

    #[test]
    fn names_roundtrip() {
        for m in [BandwidthModel::Static, BandwidthModel::Dynamic] {
            assert_eq!(BandwidthModel::from_name(m.name()), Some(m));
        }
        assert!(BandwidthModel::from_name("shared").is_none());
    }

    #[test]
    fn fully_contended_regions_fall_back_to_static_shares() {
        // Everyone demands more than their entitlement and the shares tile
        // the total: nothing to donate, allocation == entitlement.
        let e = [128.0, 64.0, 64.0];
        let d = [Some(500.0), Some(500.0), Some(500.0)];
        let a = allocate_bandwidth(256.0, &e, &d);
        assert_eq!(a, vec![128.0, 64.0, 64.0]);
    }

    #[test]
    fn idle_regions_donate_their_whole_share() {
        let e = [128.0, 128.0];
        let d = [Some(1000.0), None];
        let a = allocate_bandwidth(256.0, &e, &d);
        assert_eq!(a[1], 0.0);
        assert!((a[0] - 256.0).abs() < 1e-9, "idle share donated: {a:?}");
    }

    #[test]
    fn underutilizing_regions_donate_headroom() {
        // Region 1 can only absorb 16 of its 128: region 0 takes the rest,
        // capped at its own demand.
        let e = [128.0, 128.0];
        let d = [Some(200.0), Some(16.0)];
        let a = allocate_bandwidth(256.0, &e, &d);
        assert!((a[1] - 16.0).abs() < 1e-9);
        assert!((a[0] - 200.0).abs() < 1e-9, "capped at demand: {a:?}");
        assert!(total_of(&a) <= 256.0 + 1e-9);
    }

    #[test]
    fn donation_is_pro_rata_to_unmet_demand() {
        let e = [100.0, 100.0, 56.0];
        let d = [Some(200.0), Some(150.0), None]; // 56 + nothing-held-back to donate
        let a = allocate_bandwidth(256.0, &e, &d);
        // Base 100 + 100, surplus 56 split 2:1 (unmet 100 vs 50).
        assert!((a[0] - (100.0 + 56.0 * 100.0 / 150.0)).abs() < 1e-9, "{a:?}");
        assert!((a[1] - (100.0 + 56.0 * 50.0 / 150.0)).abs() < 1e-9, "{a:?}");
        assert_eq!(a[2], 0.0);
        assert!((total_of(&a) - 256.0).abs() < 1e-9);
    }

    #[test]
    fn never_worse_than_static_and_never_over_total() {
        let e = [64.0, 96.0, 96.0];
        let cases: [[Option<f64>; 3]; 4] = [
            [Some(10.0), Some(400.0), None],
            [Some(64.0), Some(96.0), Some(96.0)],
            [None, None, Some(1.0)],
            [Some(0.0), Some(1e6), Some(50.0)],
        ];
        for d in cases {
            let a = allocate_bandwidth(256.0, &e, &d);
            assert!(total_of(&a) <= 256.0 + 1e-9, "{d:?} -> {a:?}");
            for i in 0..3 {
                match d[i] {
                    Some(di) => {
                        assert!(
                            a[i] + 1e-9 >= di.min(e[i]),
                            "region {i} below its static floor: {d:?} -> {a:?}"
                        );
                        assert!(a[i] <= di + 1e-9, "over demand: {d:?} -> {a:?}");
                    }
                    None => assert_eq!(a[i], 0.0),
                }
            }
        }
    }

    #[test]
    fn all_idle_allocates_nothing() {
        let a = allocate_bandwidth(256.0, &[128.0, 128.0], &[None, None]);
        assert_eq!(a, vec![0.0, 0.0]);
    }

    #[test]
    fn into_variant_is_bit_identical_and_reuses_the_buffer() {
        let e = [100.0, 100.0, 56.0];
        let cases: [[Option<f64>; 3]; 3] = [
            [Some(200.0), Some(150.0), None],
            [Some(10.0), None, Some(500.0)],
            [None, None, None],
        ];
        let mut buf = Vec::new();
        for d in cases {
            allocate_bandwidth_into(256.0, &e, &d, &mut buf);
            let fresh = allocate_bandwidth(256.0, &e, &d);
            let got: Vec<u64> = buf.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u64> = fresh.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "{d:?}");
        }
    }

    #[test]
    fn bandwidth_cache_agrees_with_the_direct_allocator() {
        let e = [128.0, 64.0, 64.0];
        let mut cache = BandwidthCache::new();
        let demand_seq: [[Option<f64>; 3]; 4] = [
            [Some(40.0), None, Some(500.0)],
            [Some(40.0), None, Some(500.0)], // repeat → hit
            [None, None, None],
            [Some(40.0), None, Some(500.0)], // changed back → miss again
        ];
        for d in demand_seq {
            let got: Vec<u64> = cache.allocate(256.0, &e, &d).iter().map(|x| x.to_bits()).collect();
            let want: Vec<u64> = allocate_bandwidth(256.0, &e, &d)
                .iter()
                .map(|x| x.to_bits())
                .collect();
            assert_eq!(got, want, "{d:?}");
        }
        assert_eq!(cache.stats(), (1, 3));
    }

    #[test]
    fn bandwidth_cache_distinguishes_total_and_entitlement_changes() {
        let mut cache = BandwidthCache::new();
        let d = [Some(500.0), Some(10.0)];
        // [246, 10]: floor 138, surplus 118 all absorbed by region 0.
        let a1 = cache.allocate(256.0, &[128.0, 128.0], &d).to_vec();
        // [128, 10]: same entitlements, no surplus left at total = 128.
        let a2 = cache.allocate(128.0, &[128.0, 128.0], &d).to_vec();
        // [118, 10]: floor 74, surplus 54 on top of region 0's 64.
        let a3 = cache.allocate(128.0, &[64.0, 64.0], &d).to_vec();
        assert_ne!(a1, a2);
        assert_ne!(a2, a3);
        assert_eq!(cache.stats(), (0, 3));
    }

    #[test]
    fn donated_bandwidth_counts_only_excess_over_entitlement() {
        let e = [128.0, 128.0];
        // Region 0 absorbed all of region 1's idle share: 128 donated.
        assert!((donated_bandwidth(&e, &[256.0, 0.0]) - 128.0).abs() < 1e-9);
        // At or below entitlement nothing counts as donated.
        assert_eq!(donated_bandwidth(&e, &[128.0, 100.0]), 0.0);
        assert_eq!(donated_bandwidth(&e, &[0.0, 0.0]), 0.0);
        // The per-region term the attribution layer integrates.
        assert_eq!(donated_rate(128.0, 256.0), 128.0);
        assert_eq!(donated_rate(128.0, 100.0), 0.0);
        assert_eq!(donated_rate(128.0, 128.0), 0.0);
    }

    #[test]
    fn guillotine_shaped_entitlements_split_like_any_other() {
        // A 2-D partition of a 16×16 array: a 16×8 half plus two 8×8
        // quadrants → PE shares 1/2, 1/4, 1/4 of a 256 B/cycle pool. The
        // allocator only ever sees the entitlement vector, so rectangle
        // shape cannot change any guarantee — floors, demand caps, and
        // conservation hold exactly as for bands.
        let e = [128.0, 64.0, 64.0];
        let d = [Some(40.0), None, Some(500.0)];
        let a = allocate_bandwidth(256.0, &e, &d);
        assert!((a[0] - 40.0).abs() < 1e-9, "capped at demand: {a:?}");
        assert_eq!(a[1], 0.0);
        // Region 2 keeps its floor and absorbs all donated headroom.
        assert!(a[2] + 1e-9 >= 64.0, "{a:?}");
        assert!((total_of(&a) - (40.0 + 216.0)).abs() < 1e-9, "{a:?}");
    }
}
