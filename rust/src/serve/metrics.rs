//! Served-latency metrics, schedulability verdicts, and the rate sweep.
//!
//! A [`ServeOutcome`] rolls one simulation up into per-task tail latencies
//! (nearest-rank percentiles via `util::stats::Histogram`), deadline-miss
//! accounting (late completions *plus* dispatcher drops — a dropped
//! request missed its deadline by definition), queueing depth, and home-
//! region utilization. A scenario is *schedulable* under a policy when no
//! request misses.
//!
//! [`sweep_max_rate`] turns the boolean verdict into a boundary: the
//! largest uniform rate multiplier the plan still serves miss-free.
//! Probes use strict-periodic arrivals — deterministic, and scaling every
//! period by the same factor keeps the feasibility predicate monotone
//! (each band is a work-conserving queue whose per-request response times
//! only shrink when all gaps widen), which is what licenses the
//! exponential-bracket + bisection search. The probe list is recorded so
//! reports (and the monotonicity test) can audit the boundary.

use crate::cosched::Scenario;
use crate::obs::attr::RequestAttr;
use crate::obs::flight::FlightSnapshot;
use crate::util::stats::Histogram;

use super::arrivals::{streams, ArrivalProcess};
use super::dispatch::Policy;
use super::engine::{simulate_with_scratch, ServePlan, SimOptions, SimScratch, TraceEvent};
use super::interference::BandwidthModel;

/// Nearest-rank percentile with an empty-sample guard (no completions →
/// 0, e.g. a task whose every request was dropped). One-shot convenience
/// over [`Histogram`]; sort once via `Histogram::from_samples` instead
/// when taking several percentiles of one sample set.
pub fn pct_or_zero(xs: &[f64], p: f64) -> f64 {
    Histogram::from_samples(xs).percentile(p)
}

/// One task's served-traffic summary.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskMetrics {
    pub task: String,
    pub rate_hz: f64,
    pub deadline_ms: f64,
    /// Requests that arrived inside the window.
    pub requests: u64,
    /// Requests served to completion (on time or late).
    pub completed: u64,
    /// Requests dropped as hopeless by a deadline-aware dispatcher.
    pub dropped: u64,
    /// Deadline misses: late completions + drops.
    pub missed: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_wait_ms: f64,
    pub max_queue_depth: usize,
    /// Busy fraction of the task's home region over the served span.
    pub utilization: f64,
}

impl TaskMetrics {
    pub fn miss_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.missed as f64 / self.requests as f64
        }
    }
}

/// One full simulation's result.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub policy: Policy,
    pub scenario: String,
    pub bandwidth: BandwidthModel,
    pub tasks: Vec<TaskMetrics>,
    /// Last event instant: arrivals stop at the window's end, the span
    /// runs until the backlog drains.
    pub span_s: f64,
    /// The deterministic event trace (the reproducibility witness).
    pub trace: Vec<TraceEvent>,
    /// Per-request latency attribution in completion/drop order
    /// (`obs::attr`); empty when `SimOptions::record_attr` is off
    /// (sweep probes).
    pub attr: Vec<RequestAttr>,
    /// Flight-recorder snapshot when `SimOptions::flight` was set:
    /// frozen at the first deadline miss, or the end-of-run tail.
    pub flight: Option<FlightSnapshot>,
}

impl ServeOutcome {
    /// No request of any task missed its deadline.
    pub fn schedulable(&self) -> bool {
        self.tasks.iter().all(|t| t.missed == 0)
    }

    pub fn total_requests(&self) -> u64 {
        self.tasks.iter().map(|t| t.requests).sum()
    }

    pub fn total_missed(&self) -> u64 {
        self.tasks.iter().map(|t| t.missed).sum()
    }

    /// Scenario-wide deadline-miss rate.
    pub fn miss_rate(&self) -> f64 {
        let total = self.total_requests();
        if total == 0 {
            0.0
        } else {
            self.total_missed() as f64 / total as f64
        }
    }
}

/// Per-region busy fractions in `windows` equal time windows over
/// `[0, span_s]`, reconstructed from an outcome's `Start`/`Complete`
/// trace events (a region is busy from a request's service start to its
/// completion). Returns `(t0_s, t1_s, fraction per region)` per window —
/// the time axis of serve's NoC heatmap sampling (`report::noc` scales
/// each region's link-load map by its window fraction, so hotspot drift
/// under load shows up window by window). Empty when the outcome carries
/// no trace or the span is degenerate.
pub fn busy_windows(
    outcome: &ServeOutcome,
    num_regions: usize,
    windows: usize,
) -> Vec<(f64, f64, Vec<f64>)> {
    if outcome.trace.is_empty() || !(outcome.span_s > 0.0) || windows == 0 {
        return Vec::new();
    }
    // Service intervals per region, from matched Start/Complete pairs.
    let mut open: std::collections::BTreeMap<(usize, u64), (usize, f64)> =
        std::collections::BTreeMap::new();
    let mut intervals: Vec<Vec<(f64, f64)>> = vec![Vec::new(); num_regions];
    for ev in &outcome.trace {
        match ev.kind {
            super::engine::TraceKind::Start { region } => {
                open.insert((ev.task, ev.id), (region, ev.t_s));
            }
            super::engine::TraceKind::Complete { .. } => {
                if let Some((region, t0)) = open.remove(&(ev.task, ev.id)) {
                    if region < num_regions && ev.t_s > t0 {
                        intervals[region].push((t0, ev.t_s));
                    }
                }
            }
            _ => {}
        }
    }
    let width = outcome.span_s / windows as f64;
    (0..windows)
        .map(|k| {
            let (w0, w1) = (k as f64 * width, (k + 1) as f64 * width);
            let fracs = intervals
                .iter()
                .map(|iv| {
                    let busy: f64 = iv
                        .iter()
                        .map(|&(a, b)| (b.min(w1) - a.max(w0)).max(0.0))
                        .sum();
                    // A region serves one request at a time, but guard the
                    // ratio anyway so a malformed trace can't exceed 1.
                    (busy / width).min(1.0)
                })
                .collect();
            (w0, w1, fracs)
        })
        .collect()
}

/// Upper bracket of the rate sweep: beyond 1024× the scenario's native
/// rates the boundary is reported as "at least this".
pub const SWEEP_MAX_MULT: f64 = 1024.0;

/// Lower bracket: below 1/1024× the scenario is reported unschedulable at
/// any rate (its base latencies already blow the deadlines).
pub const SWEEP_MIN_MULT: f64 = 1.0 / 1024.0;

/// Bisection refinements after bracketing (≈3 significant digits).
const SWEEP_BISECT_ITERS: usize = 12;

/// Outcome of one policy's rate sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepResult {
    pub policy: Policy,
    /// Every probe in evaluation order: `(rate multiplier, schedulable)`.
    pub probes: Vec<(f64, bool)>,
    /// Largest multiplier found schedulable; 0 when even
    /// [`SWEEP_MIN_MULT`] misses deadlines.
    pub max_mult: f64,
}

/// Binary-search the largest uniform rate multiplier `scenario` sustains
/// miss-free under `policy`, probing with strict-periodic arrivals over
/// `duration_s`-second windows.
pub fn sweep_max_rate(
    scenario: &Scenario,
    plan: &ServePlan,
    policy: Policy,
    opts: SimOptions,
    duration_s: f64,
) -> SweepResult {
    let mut probes: Vec<(f64, bool)> = Vec::new();
    // Probes only read the verdict: skip the per-event trace and the
    // attribution records, which at high multipliers would dwarf the
    // rest of the probe's work; no flight recorder either.
    let opts = SimOptions {
        record_trace: false,
        record_attr: false,
        flight: None,
        ..opts
    };
    // One scratch for the whole sweep: the event heap and demand vector
    // regrow once instead of once per probe (results are unaffected —
    // `engine::tests::shared_scratch_matches_fresh_scratch_runs`).
    let mut scratch = SimScratch::new();
    let mut feasible = |m: f64, probes: &mut Vec<(f64, bool)>| -> bool {
        // Periodic probes consume no randomness, so the seed is moot.
        let arrivals = streams(scenario, &ArrivalProcess::Periodic, m, duration_s, 0);
        let ok = simulate_with_scratch(
            scenario,
            plan,
            policy,
            &arrivals,
            opts,
            &crate::obs::Obs::disabled(),
            &mut scratch,
        )
        .schedulable();
        probes.push((m, ok));
        ok
    };

    let (mut lo, mut hi);
    if feasible(1.0, &mut probes) {
        // Bracket upward: double until infeasible or capped.
        lo = 1.0;
        hi = 2.0;
        while hi <= SWEEP_MAX_MULT && feasible(hi, &mut probes) {
            lo = hi;
            hi *= 2.0;
        }
        if hi > SWEEP_MAX_MULT {
            return SweepResult {
                policy,
                probes,
                max_mult: lo,
            };
        }
    } else {
        // Bracket downward: halve until feasible or floored.
        hi = 1.0;
        lo = 0.5;
        while lo >= SWEEP_MIN_MULT && !feasible(lo, &mut probes) {
            hi = lo;
            lo *= 0.5;
        }
        if lo < SWEEP_MIN_MULT {
            return SweepResult {
                policy,
                probes,
                max_mult: 0.0,
            };
        }
    }
    for _ in 0..SWEEP_BISECT_ITERS {
        let mid = 0.5 * (lo + hi);
        if feasible(mid, &mut probes) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    SweepResult {
        policy,
        probes,
        max_mult: lo,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tm(requests: u64, missed: u64) -> TaskMetrics {
        TaskMetrics {
            task: "t".into(),
            rate_hz: 10.0,
            deadline_ms: 100.0,
            requests,
            completed: requests - missed,
            dropped: 0,
            missed,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            mean_wait_ms: 0.0,
            max_queue_depth: 1,
            utilization: 0.5,
        }
    }

    fn outcome(tasks: Vec<TaskMetrics>) -> ServeOutcome {
        ServeOutcome {
            policy: Policy::Edf,
            scenario: "s".into(),
            bandwidth: BandwidthModel::Dynamic,
            tasks,
            span_s: 1.0,
            trace: Vec::new(),
            attr: Vec::new(),
            flight: None,
        }
    }

    #[test]
    fn miss_rate_math_and_guards() {
        let m = tm(10, 3);
        assert!((m.miss_rate() - 0.3).abs() < 1e-12);
        assert_eq!(tm(0, 0).miss_rate(), 0.0);
        let o = outcome(vec![tm(10, 3), tm(30, 0)]);
        assert_eq!(o.total_requests(), 40);
        assert_eq!(o.total_missed(), 3);
        assert!((o.miss_rate() - 3.0 / 40.0).abs() < 1e-12);
        assert!(!o.schedulable());
        assert!(outcome(vec![tm(10, 0)]).schedulable());
        assert_eq!(outcome(vec![]).miss_rate(), 0.0);
    }

    #[test]
    fn pct_or_zero_guards_empty() {
        assert_eq!(pct_or_zero(&[], 99.0), 0.0);
        assert_eq!(pct_or_zero(&[5.0, 1.0, 3.0], 50.0), 3.0);
    }

    #[test]
    fn busy_windows_integrate_service_intervals() {
        use super::super::engine::{TraceEvent, TraceKind};
        let mut o = outcome(vec![tm(2, 0)]);
        // Region 0 busy over [0.0, 0.25] and [0.5, 0.75]; region 1 idle.
        o.trace = vec![
            TraceEvent { t_s: 0.0, task: 0, id: 1, kind: TraceKind::Arrive },
            TraceEvent { t_s: 0.0, task: 0, id: 1, kind: TraceKind::Start { region: 0 } },
            TraceEvent { t_s: 0.25, task: 0, id: 1, kind: TraceKind::Complete { region: 0 } },
            TraceEvent { t_s: 0.5, task: 0, id: 2, kind: TraceKind::Start { region: 0 } },
            TraceEvent { t_s: 0.75, task: 0, id: 2, kind: TraceKind::Complete { region: 0 } },
        ];
        let w = busy_windows(&o, 2, 2);
        assert_eq!(w.len(), 2);
        assert_eq!((w[0].0, w[0].1), (0.0, 0.5));
        assert!((w[0].2[0] - 0.5).abs() < 1e-12, "{:?}", w[0].2);
        assert!((w[1].2[0] - 0.5).abs() < 1e-12);
        assert_eq!(w[0].2[1], 0.0, "idle region stays zero");
        assert!(w.iter().all(|(_, _, f)| f.iter().all(|&x| (0.0..=1.0).contains(&x))));
        // No trace → no windows.
        assert!(busy_windows(&outcome(vec![tm(1, 0)]), 1, 4).is_empty());
    }
}
