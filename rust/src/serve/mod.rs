//! Online serving: a deterministic discrete-event simulator that replays
//! request streams against a co-scheduled array plan (DESIGN.md §Serve).
//!
//! The planning stack answers "how should concurrent XR tasks split the
//! array?" ([`crate::cosched`]); this subsystem answers the question one
//! level up the deployment: *does that split actually hold up under live
//! traffic?* Each task's requests arrive on their own clock — strict- or
//! jittered-periodic frame rates, Poisson streams, or replayed traces
//! (`arrivals`) — queue at the task's region, and are admitted by a
//! pluggable dispatcher (FIFO baseline, deadline-aware EDF and
//! rate-monotonic, with opt-in cross-task region borrowing;
//! `dispatch`). Served latencies come from the same memoized segment
//! costs the DSE and co-scheduler share, split into bandwidth-independent
//! compute floors and DRAM traffic so concurrent regions contend for
//! off-chip bandwidth *dynamically*: each event epoch re-splits the pool
//! by demand and DRAM-underutilizing regions donate headroom
//! (`interference`), never serving anyone slower than the static
//! plan-time split. Per-task tail latencies, deadline-miss rates, queue
//! depths, utilization and the schedulability verdict — plus a rate sweep
//! that binary-searches the largest sustainable uniform rate multiplier —
//! land in `metrics`, and `pipeorgan serve` + `report::serve` emit it
//! all.
//!
//! The event loop itself is array-agnostic (`core`): a versioned
//! binary-heap [`EventCore`] driving any [`ServiceModel`]. The
//! single-array simulator implements the trait once (`engine`'s
//! [`ArrayModel`]); `fleet` composes N of them behind a front-door
//! router with admission control and an autoscaler — fleet-scale serving
//! over the same deterministic core (`pipeorgan fleet` +
//! `report::fleet`).
//!
//! Everything is a pure function of `(scenario, config, seed)`: arrivals
//! are pre-materialized, events tie-break on sequence numbers, and all
//! state lives in task-indexed vectors, so two runs with one seed are
//! bit-identical and policy comparisons share one arrival replay.

mod arrivals;
mod core;
mod dispatch;
mod engine;
mod fleet;
mod interference;
mod metrics;

use crate::cosched::PartitionKind;

pub use arrivals::{
    arrival_times, parse_trace_columns, streams, trace_streams, ArrivalProcess,
    DEFAULT_DIURNAL_AMP, DEFAULT_JITTER_FRAC,
};
pub use dispatch::{select_next, Policy, Request};
pub use engine::{
    plan_scenario, push_arrivals, run_scenario, simulate, simulate_traced, simulate_with_scratch,
    ArrayModel, ServePlan, ServeRun, ServedCost, ServiceStage, SimOptions, SimScratch, TraceEvent,
    TraceKind,
};
pub use fleet::{
    parse_chip_dims, parse_routers, run_fleet_scenario, simulate_fleet, AdmissionPolicy,
    AutoscaleConfig, ChipStats, FleetConfig, FleetOutcome, FleetRun, RouterPolicy, FLEET_FLAGS,
};
// `self::` disambiguates from the `core` builtin crate in use paths.
pub use self::core::{drive, CoreEvent, EventCore, ServiceModel};
pub use interference::{
    allocate_bandwidth, allocate_bandwidth_into, donated_bandwidth, donated_rate, BandwidthCache,
    BandwidthModel,
};
pub use metrics::{
    busy_windows, pct_or_zero, sweep_max_rate, ServeOutcome, SweepResult, TaskMetrics,
    SWEEP_MAX_MULT, SWEEP_MIN_MULT,
};

/// Knobs of one serving run. CLI flags map 1:1 onto these (see
/// [`SERVE_FLAGS`]).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Dispatch policies to replay (all three by default, so the emitted
    /// report is a per-policy comparison on one arrival stream).
    pub policies: Vec<Policy>,
    /// Region family the underlying co-schedule searches
    /// (`cosched::PartitionKind`): vertical bands or 2-D guillotine
    /// rectangles.
    pub partition: PartitionKind,
    /// Arrival process shared by every task (each at its own rate).
    pub arrivals: ArrivalProcess,
    /// Arrival window in seconds; the simulation runs until the backlog
    /// drains.
    pub duration_s: f64,
    /// Uniform multiplier on every task's native rate.
    pub rate_mult: f64,
    /// Let idle regions with empty home queues serve other tasks.
    pub borrow: bool,
    /// DRAM bandwidth contention model for served latencies.
    pub bandwidth: BandwidthModel,
    /// Also binary-search the max sustainable rate multiplier per policy.
    pub sweep: bool,
    /// Master seed for the stochastic arrival processes.
    pub seed: u64,
    /// Observability handle (`--obs` / `--trace-out`): request-lifecycle
    /// events, per-region tracks and queue/bandwidth/utilization counter
    /// tracks from the event loop. Disabled (free) by default.
    pub obs: crate::obs::Obs,
    /// Run each simulation with a flight recorder (`--flight-out FILE`):
    /// a bounded ring of recent sim events frozen at the first deadline
    /// miss, dumped with the attribution table. Off by default.
    pub flight: bool,
    /// Captured device trace (`--trace-file FILE`): one timestamp column
    /// per task, replacing the synthetic arrival process. `None` (the
    /// default) generates arrivals from `arrivals`/`rate_mult`/`seed`.
    pub trace: Option<Vec<Vec<f64>>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            policies: Policy::ALL.to_vec(),
            partition: PartitionKind::Bands,
            arrivals: ArrivalProcess::Periodic,
            duration_s: 1.0,
            rate_mult: 1.0,
            borrow: false,
            bandwidth: BandwidthModel::Dynamic,
            sweep: false,
            seed: 42,
            obs: crate::obs::Obs::disabled(),
            flight: false,
            trace: None,
        }
    }
}

impl ServeConfig {
    /// Build from parsed CLI flags (the `serve` subcommand). `seed` is the
    /// global `--seed` main.rs already parsed.
    pub fn from_cli(args: &crate::cli::Args, seed: u64) -> Result<ServeConfig, String> {
        let defaults = ServeConfig::default();
        let policies = parse_policies(args.get_or("policy", "all"))?;
        // Closed-set flags go through `cli::Args::get_enum` for uniform
        // rejection messages (full variant list + did-you-mean).
        let partition_name =
            args.get_enum("partition", defaults.partition.name(), &["bands", "guillotine"])?;
        let partition = PartitionKind::from_name(partition_name).expect("validated variant");
        let arrivals_name = args.get_enum(
            "arrivals",
            "periodic",
            &["periodic", "jittered", "poisson", "diurnal"],
        )?;
        let arrivals = ArrivalProcess::from_name(arrivals_name).expect("validated variant");
        let duration_s = args.get_f64("duration-s", defaults.duration_s)?;
        if !(duration_s > 0.0 && duration_s.is_finite()) {
            return Err(format!(
                "flag `--duration-s` must be a positive finite number of seconds, got `{duration_s}`"
            ));
        }
        let rate_mult = args.get_f64("rate-mult", defaults.rate_mult)?;
        if !(rate_mult > 0.0 && rate_mult.is_finite()) {
            return Err(format!(
                "flag `--rate-mult` must be a positive finite multiplier, got `{rate_mult}`"
            ));
        }
        let bandwidth_name = args.get_enum("bandwidth", "dynamic", &["dynamic", "static"])?;
        let bandwidth = BandwidthModel::from_name(bandwidth_name).expect("validated variant");
        let trace = match args.get("trace-file") {
            Some(path) => {
                // A captured trace carries its own timing; a synthetic
                // process or rate scaling alongside it would silently win
                // or silently no-op, so both combinations are rejected.
                if args.get("arrivals").is_some() {
                    return Err("`--trace-file` replaces `--arrivals`; pass only one".into());
                }
                if args.get("rate-mult").is_some() {
                    return Err(
                        "`--rate-mult` does not rescale a `--trace-file` replay; drop it".into(),
                    );
                }
                let text = std::fs::read_to_string(path)
                    .map_err(|e| format!("cannot read trace file `{path}`: {e}"))?;
                Some(arrivals::parse_trace_columns(&text).map_err(|e| format!("`{path}`: {e}"))?)
            }
            None => None,
        };
        Ok(ServeConfig {
            policies,
            partition,
            arrivals,
            duration_s,
            rate_mult,
            borrow: args.has("borrow"),
            bandwidth,
            sweep: args.has("sweep"),
            seed,
            obs: crate::obs::Obs::from_cli(args),
            // `--out-dir` means "write every artifact", so it arms the
            // flight recorder exactly like an explicit `--flight-out`.
            flight: args.get("flight-out").is_some() || args.get("out-dir").is_some(),
            trace,
        })
    }
}

/// Resolve `--policy`: `all`, one policy, or a comma list.
fn parse_policies(spec: &str) -> Result<Vec<Policy>, String> {
    if spec == "all" {
        return Ok(Policy::ALL.to_vec());
    }
    let mut out = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let p = Policy::from_name(name).ok_or_else(|| {
            let names: Vec<&str> = Policy::ALL.iter().map(|p| p.name()).collect();
            let mut msg = format!("unknown policy `{name}` (known: {})", names.join(", "));
            if let Some(hint) = crate::cli::suggest(name, &names) {
                msg.push_str(&format!("; did you mean `{hint}`?"));
            }
            msg
        })?;
        if !out.contains(&p) {
            out.push(p);
        }
    }
    if out.is_empty() {
        return Err("flag `--policy` lists no policies".into());
    }
    Ok(out)
}

/// Flags accepted by the `serve` subcommand on top of the global ones
/// (`(name, takes_value)` — the `cli::Args` strict-flag table format).
/// `--scenario` and `--partition` behave exactly as on `cosched`;
/// `--cache-file`/`--cache-cap` manage the persistent evaluation cache
/// exactly as on `dse`. `--obs` enables the observability counters;
/// `--trace-out FILE` additionally writes the Perfetto event-loop trace
/// there (and implies `--obs`). `--attr-out FILE` writes the per-request
/// latency-attribution report (`report::attr`), and `--flight-out FILE`
/// arms the flight recorder and writes its first-deadline-miss (or
/// end-of-run) snapshot; neither implies `--obs` — attribution and the
/// flight ring run independently of the trace handle
/// (docs/OBSERVABILITY.md). `--trace-file FILE` replays a captured device
/// trace (one timestamp column per task) instead of a synthetic arrival
/// process, and `--noc-out FILE` writes the `pipeorgan-noc-v1` link-load
/// heatmap artifact (docs/OBSERVABILITY.md §NoC telemetry).
pub const SERVE_FLAGS: &[(&str, bool)] = &[
    ("scenario", true),
    ("partition", true),
    ("policy", true),
    ("arrivals", true),
    ("trace-file", true),
    ("duration-s", true),
    ("rate-mult", true),
    ("borrow", false),
    ("bandwidth", true),
    ("sweep", false),
    ("cache-file", true),
    ("cache-cap", true),
    ("obs", false),
    ("trace-out", true),
    ("attr-out", true),
    ("flight-out", true),
    ("noc-out", true),
    ("out-dir", true),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;

    fn parse_sv(v: &[&str]) -> Result<ServeConfig, String> {
        let mut flags: Vec<(&str, bool)> = vec![("out", true), ("workers", true), ("seed", true)];
        flags.extend_from_slice(SERVE_FLAGS);
        let raw: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        let args = Args::parse(&raw, &flags)?;
        ServeConfig::from_cli(&args, 7)
    }

    #[test]
    fn defaults_are_sane() {
        let sv = ServeConfig::default();
        assert_eq!(sv.policies, Policy::ALL.to_vec());
        assert_eq!(sv.arrivals, ArrivalProcess::Periodic);
        assert!(sv.duration_s > 0.0 && sv.rate_mult > 0.0);
        assert!(!sv.borrow && !sv.sweep);
        assert_eq!(sv.bandwidth, BandwidthModel::Dynamic);
        assert_eq!(sv.partition, PartitionKind::Bands);
    }

    #[test]
    fn cli_flags_parse_into_config() {
        let sv = parse_sv(&[
            "serve",
            "--scenario",
            "xr-core",
            "--partition",
            "guillotine",
            "--policy",
            "edf,fifo",
            "--arrivals",
            "poisson",
            "--duration-s",
            "0.5",
            "--rate-mult",
            "2.5",
            "--borrow",
            "--bandwidth",
            "static",
            "--sweep",
        ])
        .unwrap();
        assert_eq!(sv.policies, vec![Policy::Edf, Policy::Fifo]);
        assert_eq!(sv.partition, PartitionKind::Guillotine);
        assert_eq!(sv.arrivals, ArrivalProcess::Poisson);
        assert_eq!(sv.duration_s, 0.5);
        assert_eq!(sv.rate_mult, 2.5);
        assert!(sv.borrow && sv.sweep);
        assert_eq!(sv.bandwidth, BandwidthModel::Static);
        assert_eq!(sv.seed, 7, "the global seed threads through");
    }

    #[test]
    fn diurnal_arrivals_parse_by_name() {
        let sv = parse_sv(&["serve", "--arrivals", "diurnal"]).unwrap();
        assert_eq!(
            sv.arrivals,
            ArrivalProcess::Diurnal { period_s: 0.0, amp: DEFAULT_DIURNAL_AMP }
        );
    }

    #[test]
    fn enum_flag_errors_carry_did_you_mean_hints() {
        let err = parse_sv(&["serve", "--partition", "bnads"]).unwrap_err();
        assert!(err.contains("did you mean `bands`?"), "{err}");
        let err = parse_sv(&["serve", "--arrivals", "diurnl"]).unwrap_err();
        assert!(err.contains("did you mean `diurnal`?"), "{err}");
        let err = parse_sv(&["serve", "--policy", "edv"]).unwrap_err();
        assert!(err.contains("did you mean `edf`?"), "{err}");
    }

    #[test]
    fn out_dir_arms_flight_and_obs() {
        let sv = parse_sv(&["serve", "--out-dir", "reports/artifacts"]).unwrap();
        assert!(sv.flight, "--out-dir writes the flight snapshot");
        assert!(sv.obs.is_enabled(), "--out-dir writes the Perfetto trace");
    }

    #[test]
    fn bad_flags_rejected() {
        assert!(parse_sv(&["serve", "--policy", "lifo"]).is_err());
        assert!(parse_sv(&["serve", "--partition", "diagonal"]).is_err());
        assert!(parse_sv(&["serve", "--policy", ","]).is_err());
        assert!(parse_sv(&["serve", "--arrivals", "bursty"]).is_err());
        assert!(parse_sv(&["serve", "--bandwidth", "shared"]).is_err());
        assert!(parse_sv(&["serve", "--duration-s", "0"]).is_err());
        assert!(parse_sv(&["serve", "--duration-s", "soon"]).is_err());
        assert!(parse_sv(&["serve", "--rate-mult", "-1"]).is_err());
        assert!(parse_sv(&["serve", "--rate-mult", "inf"]).is_err());
        assert!(parse_sv(&["serve", "--nope"]).is_err());
    }

    #[test]
    fn obs_flags_enable_the_handle() {
        assert!(!parse_sv(&["serve"]).unwrap().obs.is_enabled());
        assert!(parse_sv(&["serve", "--obs"]).unwrap().obs.is_enabled());
        assert!(parse_sv(&["serve", "--trace-out", "t.json"])
            .unwrap()
            .obs
            .is_enabled());
    }

    #[test]
    fn flight_flag_arms_the_recorder_without_obs() {
        assert!(!parse_sv(&["serve"]).unwrap().flight);
        let sv = parse_sv(&["serve", "--flight-out", "f.json"]).unwrap();
        assert!(sv.flight, "--flight-out arms the recorder");
        assert!(!sv.obs.is_enabled(), "the flight ring is independent of --obs");
        // --attr-out parses but needs no config bit: attribution records
        // are on by default and the CLI only picks where to write them.
        assert!(parse_sv(&["serve", "--attr-out", "a.json"]).is_ok());
    }

    #[test]
    fn trace_file_ingests_columns_and_excludes_synthetic_knobs() {
        let path = std::env::temp_dir().join("pipeorgan_trace_file_test.txt");
        std::fs::write(&path, "0.0 0.01\n0.5 -\n").unwrap();
        let path = path.to_str().unwrap().to_string();
        let sv = parse_sv(&["serve", "--trace-file", &path]).unwrap();
        assert_eq!(sv.trace, Some(vec![vec![0.0, 0.5], vec![0.01]]));
        // A trace replaces the synthetic process; mixing the knobs errors.
        assert!(parse_sv(&["serve", "--trace-file", &path, "--arrivals", "poisson"]).is_err());
        assert!(parse_sv(&["serve", "--trace-file", &path, "--rate-mult", "2"]).is_err());
        assert!(parse_sv(&["serve", "--trace-file", "/nonexistent/t.txt"]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn policy_lists_dedupe_and_keep_order() {
        let sv = parse_sv(&["serve", "--policy", "rm,edf,rm"]).unwrap();
        assert_eq!(sv.policies, vec![Policy::Rm, Policy::Edf]);
        let sv = parse_sv(&["serve", "--policy", "all"]).unwrap();
        assert_eq!(sv.policies.len(), 3);
    }
}
